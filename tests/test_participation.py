"""sample_masks edge cases (participation modes, §3.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.participation import MODES, sample_masks


def test_full_participation_is_all_ones():
    tm, dm = sample_masks(jax.random.PRNGKey(0), 4, 6, team_frac=1.0,
                          device_frac=1.0)
    np.testing.assert_array_equal(np.asarray(tm), np.ones(4))
    np.testing.assert_array_equal(np.asarray(dm), np.ones((4, 6)))


def test_tiny_device_frac_keeps_one_device():
    """device_frac small enough that round(n*frac) == 0 still keeps one
    device per participating team (n_d == 1)."""
    m, n = 5, 10
    tm, dm = sample_masks(jax.random.PRNGKey(1), m, n, device_frac=0.01)
    dm = np.asarray(dm)
    assert (dm.sum(axis=1) == 1).all()


def test_tiny_team_frac_keeps_one_team():
    tm, dm = sample_masks(jax.random.PRNGKey(2), 8, 4, team_frac=0.01)
    tm = np.asarray(tm)
    assert tm.sum() == 1


def test_device_mask_zeroed_for_nonparticipating_teams():
    for seed in range(5):
        tm, dm = sample_masks(jax.random.PRNGKey(seed), 8, 6,
                              team_frac=0.5, device_frac=0.5)
        tm, dm = np.asarray(tm), np.asarray(dm)
        assert (dm[tm == 0] == 0).all()
        # participating teams keep exactly n_d = round(0.5*6) = 3 devices
        assert (dm[tm > 0].sum(axis=1) == 3).all()


def test_masks_are_binary_and_counts_exact():
    m, n = 9, 7
    for tf, df in [(0.3, 0.6), (0.7, 0.2), (1.0, 0.5)]:
        tm, dm = sample_masks(jax.random.PRNGKey(3), m, n, team_frac=tf,
                              device_frac=df)
        tm, dm = np.asarray(tm), np.asarray(dm)
        assert set(np.unique(tm)) <= {0.0, 1.0}
        assert set(np.unique(dm)) <= {0.0, 1.0}
        assert tm.sum() == max(1, round(tf * m))
        assert (dm.sum(1)[tm > 0] == max(1, round(df * n))).all()


@pytest.mark.parametrize("mode", sorted(MODES))
def test_modes_always_keep_a_participant(mode):
    tm, dm = sample_masks(jax.random.PRNGKey(4), 4, 10, **MODES[mode])
    assert np.asarray(tm).sum() >= 1
    assert np.asarray(dm).sum() >= 1
