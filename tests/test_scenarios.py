"""Scenario layer: registry completeness, serialization round-trips,
build determinism/caching, engine/sweep routing, and equivalence with
the legacy hand-assembled experiment path."""
import json

import numpy as np
import pytest

from repro.scenarios import (ALGO_METRICS, SCENARIOS, AlgoSpec, DataSpec,
                             FLScenario, ModelSpec, build_scenario,
                             families, get_scenario, run_scenario,
                             sweep_scenario)

NEW_FAMILIES = ("dirichlet", "quantity", "featshift", "teams")


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_covers_paper_and_new_families():
    fams = families()
    for fam in ("table1", "table2", "fig2", "fig3", "fig4", "comm"):
        assert fam in fams, f"paper family {fam} missing"
    for fam in NEW_FAMILIES:
        assert fam in fams, f"new scenario family {fam} missing"
    # every Table-1 cell exists, named by its concrete model kind
    for ds in ("mnist", "fmnist", "emnist10", "synthetic"):
        for algo in ALGO_METRICS:
            kind_ncx = "dnn" if ds == "synthetic" else "cnn"
            assert f"table1/{ds}/mclr/{algo}" in SCENARIOS
            assert f"table1/{ds}/{kind_ncx}/{algo}" in SCENARIOS


def test_registry_names_match_and_table1_refs_attached():
    for name, s in SCENARIOS.items():
        assert s.name == name
        assert s.family == name.split("/")[0]
    refs = dict(SCENARIOS["table1/mnist/mclr/permfl"].paper_ref)
    assert refs == {"pm": 96.87, "gm": 86.92}
    # the paper's AL2GD numbers land on our l2gd cells
    assert dict(SCENARIOS["table1/mnist/mclr/l2gd"].paper_ref)["pm"] == 93.70


def test_get_scenario_accepts_name_spec_and_dict():
    s = SCENARIOS["fig3/mnist/mclr"]
    assert get_scenario("fig3/mnist/mclr") is s
    assert get_scenario(s) is s
    assert get_scenario(s.to_dict()) == s
    with pytest.raises(KeyError, match="fig3"):
        get_scenario("fig3/mnist/bogus")


# ---------------------------------------------------------------------------
# serialization
# ---------------------------------------------------------------------------

def test_round_trip_every_registered_scenario():
    """from_dict(to_dict(s)) == s through actual JSON, hash included."""
    for name, s in SCENARIOS.items():
        rt = FLScenario.from_dict(json.loads(json.dumps(s.to_dict())))
        assert rt == s, name
        assert rt.spec_hash() == s.spec_hash(), name


def test_spec_hash_ignores_presentation_but_not_physics():
    import dataclasses

    s = SCENARIOS["table1/mnist/mclr/permfl"]
    renamed = dataclasses.replace(s, name="x", notes="y", paper_ref=())
    assert renamed.spec_hash() == s.spec_hash()
    moved = dataclasses.replace(
        s, data=dataclasses.replace(s.data, n_devices=5))
    assert moved.spec_hash() != s.spec_hash()


def test_invalid_specs_rejected():
    with pytest.raises(ValueError, match="partitioner"):
        DataSpec(partitioner="bogus")
    with pytest.raises(ValueError, match="tabular"):
        DataSpec(dataset="synthetic", partitioner="label_skew")
    with pytest.raises(ValueError, match="tabular"):
        DataSpec(dataset="mnist", partitioner="tabular")
    with pytest.raises(ValueError, match="override"):
        AlgoSpec("fedavg", (("beta", 0.1),))
    with pytest.raises(ValueError, match="algorithm"):
        AlgoSpec("bogus")
    with pytest.raises(ValueError, match="image"):
        ModelSpec("cnn").config(DataSpec(dataset="synthetic",
                                         partitioner="tabular"))


# ---------------------------------------------------------------------------
# build
# ---------------------------------------------------------------------------

def _tiny(name, **scale):
    return SCENARIOS[name].scaled(m_teams=2, n_devices=3,
                                  samples_per_device=16, **scale)


def test_build_deterministic_and_cached():
    s = _tiny("table1/mnist/mclr/permfl")
    b1 = build_scenario(s, seed=0)
    b2 = build_scenario(s, seed=0)
    # cache: same objects — this is what keys the engine's compiled-
    # program cache across calls
    assert b1.algo is b2.algo and b1.metric_fn is b2.metric_fn
    assert b1.params0 is b2.params0
    np.testing.assert_array_equal(b1.fd.train_x, b2.fd.train_x)
    # different model seed: same (cached) data, different params —
    # checked on a DNN scenario (MCLR's paper init is all-zeros)
    sd = _tiny("featshift/dnn/s2")
    d0, d1 = build_scenario(sd, seed=0), build_scenario(sd, seed=1)
    assert d1.fd is d0.fd
    assert any(
        np.any(np.asarray(a) != np.asarray(b))
        for la, lb in zip(d0.params0.values(), d1.params0.values())
        for a, b in zip(la.values(), lb.values()))


def test_scenarios_sharing_data_spec_share_the_partition():
    """Scenarios differing only in algorithm (the seven cells of one
    Table-1 row) must share one FederatedData and one loss closure —
    no re-partitioning, no duplicate stacked arrays."""
    a = build_scenario(_tiny("table1/mnist/mclr/permfl"))
    b = build_scenario(_tiny("table1/mnist/mclr/fedavg"))
    assert a.fd is b.fd and a.train is b.train
    assert a.loss_fn is b.loss_fn and a.metric_fn is b.metric_fn


def test_comm_scenarios_build_comm_algorithms():
    b = build_scenario(_tiny("comm/mnist/mclr/topk_10"))
    assert b.algo.comm is not None and b.algo.comm.compressor == "topk"
    b0 = build_scenario(_tiny("comm/mnist/mclr/uncompressed"))
    assert b0.algo.comm is None


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------

def test_run_scenario_matches_legacy_assembly():
    """run_scenario on a Table-1 cell reproduces the historical
    hand-assembled path (make_dataset + partition_label_skew + PerMFL +
    run_experiment) exactly."""
    import jax
    import jax.numpy as jnp

    from repro.configs.paper_mclr import CONFIG as MCLR
    from repro.core import PerMFL
    from repro.core.permfl import PerMFLHParams
    from repro.data.federated import partition_label_skew
    from repro.data.synthetic import make_dataset
    from repro.models import paper_models as PM
    from repro.train.engine import run_experiment

    s = _tiny("table1/mnist/mclr/permfl", rounds=3)
    res = run_scenario(s, seed=0)

    # the legacy path, assembled by hand (data seed 0, n_per_class=40*n)
    rng = np.random.default_rng(0)
    x, y = make_dataset("mnist", rng, n_per_class=40 * 3)
    fd = partition_label_skew(rng, x, y, m_teams=2, n_devices=3,
                              classes_per_device=2, samples_per_device=16)
    tr = {"x": jnp.asarray(fd.train_x), "y": jnp.asarray(fd.train_y)}
    va = {"x": jnp.asarray(fd.val_x), "y": jnp.asarray(fd.val_y)}
    loss = lambda p, b: PM.loss_fn(p, MCLR, b)
    met = lambda p, b: PM.accuracy(p, MCLR, b)
    hp = PerMFLHParams(alpha=0.01, eta=0.03, beta=0.6, lam=0.5,
                       gamma=1.5, k_team=5, l_local=10)
    ref = run_experiment(PerMFL(loss, hp),
                         PM.init_params(jax.random.PRNGKey(0), MCLR),
                         tr, va, metric_fn=met, rounds=3, m=2, n=3)

    np.testing.assert_allclose(res.pm_acc, ref.pm_acc, atol=1e-6)
    np.testing.assert_allclose(res.gm_acc, ref.gm_acc, atol=1e-6)
    np.testing.assert_allclose(res.train_loss, ref.train_loss, atol=1e-6)


@pytest.mark.parametrize("family,name", [
    ("dirichlet", "dirichlet/mnist/a0.5"),
    ("quantity", "quantity/mnist/q25"),
    ("featshift", "featshift/mclr/s2"),
    ("teams", "teams/worst/m6n15"),
])
def test_new_families_run_engine_and_sweep(family, name):
    """Every new scenario family must route end-to-end through both the
    scanned engine and the vmapped sweep."""
    s = _tiny(name, rounds=2)
    res = run_scenario(s)
    assert len(res.pm_acc) == 2
    assert np.isfinite(res.pm_acc).all() and np.isfinite(res.gm_acc).all()

    sw = sweep_scenario(s, [{"beta": 0.3}, {"beta": 0.9}], (0,), rounds=2)
    assert len(sw) == 2 and sw.dispatches == 1
    for r in sw:
        assert np.isfinite(r.pm_acc).all()
    # both lanes really ran with their own beta (traced, not baked in):
    # the continuous train-loss trajectories must differ
    assert not np.allclose(sw[0].train_loss, sw[1].train_loss)


def test_sweep_scenario_per_seed_inits_match_run_scenario():
    """A seeds-only sweep reproduces per-seed run_scenario results
    (DNN model: per-seed inits genuinely differ)."""
    s = _tiny("featshift/dnn/s2", rounds=2)
    sw = sweep_scenario(s, [{}], (0, 1), rounds=2)
    assert len(sw) == 2
    for lane, seed in zip(sw, (0, 1)):
        ref = run_scenario(s, rounds=2, seed=seed)
        np.testing.assert_allclose(lane.pm_acc, ref.pm_acc, atol=1e-5)
        np.testing.assert_allclose(lane.gm_acc, ref.gm_acc, atol=1e-5)


def test_participation_scenarios_gate_counts():
    s = _tiny("fig4/mnist/mclr/both_25", rounds=3)
    res = run_scenario(s, seed=5, init_seed=0)
    assert len(res.participation) == 3
    for teams, devs in res.participation:
        assert 1 <= teams <= 2 and devs <= teams * 3


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_list_describe_dump(capsys):
    from repro.scenarios.__main__ import main

    assert main(["list", "--family", "dirichlet"]) == 0
    out = capsys.readouterr().out
    assert "dirichlet/mnist/a0.5" in out

    assert main(["describe", "table1/mnist/mclr/permfl"]) == 0
    out = capsys.readouterr().out
    assert "96.87" in out and "hash=" in out

    assert main(["dump", "quantity/mnist/q25"]) == 0
    dumped = json.loads(capsys.readouterr().out)
    assert FLScenario.from_dict(dumped) == SCENARIOS["quantity/mnist/q25"]


def test_cli_run_smoke(capsys):
    from repro.scenarios.__main__ import main

    assert main(["run", "fig2/fmnist/mclr/permfl", "--smoke"]) == 0
    out = capsys.readouterr().out
    assert "pm=" in out and "train_loss=" in out
