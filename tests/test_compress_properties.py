"""Property tests for the fused compression stack (hypothesis).

Randomized shapes/seeds pin the invariants the deterministic suite
checks pointwise: Pallas(interpret)-vs-XLA bit-exactness, pack->unpack
round-trips, the EF decomposition ``chat + ef_new == msg``, and the
one-step stochastic-rounding error bound for int8. Skips cleanly when
hypothesis is not installed (it is an optional dev dependency).
"""
import os

os.environ.setdefault("FORCE_PALLAS_INTERPRET", "0")

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (optional dev dep)")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kernels.compress import (ef_quantize_int8, ef_randk_compress,
                                    ef_sign_compress, ef_topk_compress,
                                    pack_topk, randk_compress, sign_compress,
                                    sign_unpack, topk_compress, unpack_topk)

COMMON = dict(deadline=None, max_examples=25)

sizes = st.integers(min_value=1, max_value=1500)
seeds = st.integers(min_value=0, max_value=2**31 - 1)
fracs = st.floats(min_value=0.01, max_value=1.0)


def _arrs(p, seed):
    key = jax.random.PRNGKey(seed)
    v = jax.random.normal(jax.random.fold_in(key, 1), (p,))
    ef = 0.1 * jax.random.normal(jax.random.fold_in(key, 2), (p,))
    u = jax.random.uniform(jax.random.fold_in(key, 3), (p,))
    noise = jax.random.uniform(jax.random.fold_in(key, 4), (p,))
    return v, ef, u, noise


def _k(p, frac):
    return max(1, min(p, int(round(frac * p))))


def _eq(a, b, msg):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=msg)


@settings(**COMMON)
@given(p=sizes, seed=seeds, frac=fracs)
def test_select_parity_and_roundtrip(p, seed, frac):
    v, ef, u, _ = _arrs(p, seed)
    k = _k(p, frac)
    for name, out_i, out_x in [
        ("topk", topk_compress(v, k, mode="interpret"),
         topk_compress(v, k, mode="xla")),
        ("randk", randk_compress(u, v, k, mode="interpret"),
         randk_compress(u, v, k, mode="xla")),
    ]:
        dq_i, r_i = out_i
        dq_x, r_x = out_x
        _eq(dq_i, dq_x, f"{name} dq parity")
        _eq(r_i, r_x, f"{name} ranks parity")
        assert int((r_x >= 0).sum()) == k
        vals, idx = pack_topk(dq_x, r_x, k)
        _eq(unpack_topk(vals, idx, p), dq_x, f"{name} roundtrip")


@settings(**COMMON)
@given(p=sizes, seed=seeds, frac=fracs,
       levels=st.integers(min_value=1, max_value=4))
def test_select_parity_with_ties(p, seed, frac, levels):
    """Tie-heavy inputs (values/uniforms quantized to <= 4 levels, so
    duplicate scores and zero-heavy leaves are the norm): the fused
    select must still reproduce lax.top_k's lowest-index-tie kept set
    bit-for-bit, across both backends."""
    v, _, u, _ = _arrs(p, seed)
    v = jnp.round(v * levels) / levels
    u = jnp.floor(u * levels) / levels
    k = _k(p, frac)
    _, tidx = jax.lax.top_k(jnp.abs(v), k)
    _, ridx = jax.lax.top_k(u, k)
    for name, out_i, out_x, legacy in [
        ("topk", topk_compress(v, k, mode="interpret"),
         topk_compress(v, k, mode="xla"),
         jnp.zeros_like(v).at[tidx].set(v[tidx])),
        ("randk", randk_compress(u, v, k, mode="interpret"),
         randk_compress(u, v, k, mode="xla"),
         jnp.zeros_like(v).at[ridx].set(v[ridx])),
    ]:
        _eq(out_i[0], out_x[0], f"{name} tie dq parity")
        _eq(out_i[1], out_x[1], f"{name} tie ranks parity")
        _eq(out_x[0], legacy, f"{name} tie legacy equivalence")
        r = np.asarray(out_x[1])
        np.testing.assert_array_equal(np.sort(r[r >= 0]), np.arange(k),
                                      err_msg=f"{name} tie rank perm")


@settings(**COMMON)
@given(p=sizes, seed=seeds, frac=fracs)
def test_ef_select_decomposition(p, seed, frac):
    v, ef, u, _ = _arrs(p, seed)
    k = _k(p, frac)
    for name, out_i, out_x in [
        ("ef_topk", ef_topk_compress(v, ef, k, mode="interpret"),
         ef_topk_compress(v, ef, k, mode="xla")),
        ("ef_randk", ef_randk_compress(u, v, ef, k, mode="interpret"),
         ef_randk_compress(u, v, ef, k, mode="xla")),
    ]:
        for a, b in zip(out_i, out_x):
            _eq(a, b, f"{name} parity")
        dq, ranks, ef_new = out_x
        # selection writes each coordinate to exactly one side, so the
        # decomposition is exact in floating point, not just approximate
        _eq(dq + ef_new, v + ef, f"{name} decomposition")
        _eq(jnp.where(ranks >= 0, ef_new, 0.0),
            jnp.zeros_like(ef_new), f"{name} kept coords have zero ef")


@settings(**COMMON)
@given(p=sizes, seed=seeds)
def test_int8_parity_and_error_bound(p, seed):
    v, ef, _, noise = _arrs(p, seed)
    out_i = ef_quantize_int8(v, ef, noise, mode="interpret")
    out_x = ef_quantize_int8(v, ef, noise, mode="xla")
    for a, b in zip(out_i, out_x):
        _eq(a, b, "ef_int8 parity")
    q, scales, dq, ef_new = out_x
    assert q.dtype == jnp.int8
    step = np.repeat(np.asarray(scales), 128)[:p]
    err = np.abs(np.asarray(dq) - np.asarray(v + ef))
    assert (err <= step + 1e-12).all(), "stochastic rounding > 1 step"


@settings(**COMMON)
@given(p=sizes, seed=seeds)
def test_sign_parity_and_roundtrip(p, seed):
    v, ef, _, _ = _arrs(p, seed)
    bits_i, scale_i, dq_i = sign_compress(v, mode="interpret")
    bits_x, scale_x, dq_x = sign_compress(v, mode="xla")
    _eq(bits_i, bits_x, "sign bits parity")
    _eq(scale_i, scale_x, "sign scale parity")
    _eq(dq_i, dq_x, "sign dq parity")
    _eq(sign_unpack(bits_x, scale_x, p),
        jnp.where(v >= 0, scale_x, -scale_x), "sign roundtrip")
    for a, b in zip(ef_sign_compress(v, ef, mode="interpret"),
                    ef_sign_compress(v, ef, mode="xla")):
        _eq(a, b, "ef_sign parity")
