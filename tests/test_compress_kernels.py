"""Fused compression stack: Pallas-vs-XLA parity, routing, and VJPs.

The contract pinned here (DESIGN.md §10): for every compressor
(topk / randk / int8 / sign), the Pallas kernel body run in interpret
mode is bit-identical to the jnp reference dispatched as ``xla``, the
fused EF ops match the historical unfused arithmetic, pack->unpack
round-trips are exact, and the custom VJPs have identical gradient
semantics across backends.
"""
import os

os.environ.setdefault("FORCE_PALLAS_INTERPRET", "0")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (CommConfig, compress_tree, compress_tree_ef,
                        leaf_k, leaf_plan, make_leaf_compressor,
                        make_leaf_ef_compressor)
from repro.kernels.compress import (ef_quantize_int8, ef_randk_compress,
                                    ef_sign_compress, ef_topk_compress,
                                    pack_topk, randk_compress,
                                    resolve_leaf_mode, sign_compress,
                                    sign_unpack, topk_compress, unpack_topk)
from repro.kernels.compress.compress import PALLAS_MAX_ELEMS
from repro.kernels.interface import (KernelType, compress_fused,
                                     dispatch_key, kernel_mode)

SIZES = [7, 64, 128, 257, 1000]


def _data(p, seed=0):
    key = jax.random.PRNGKey(seed)
    v = jax.random.normal(jax.random.fold_in(key, 1), (p,))
    ef = 0.1 * jax.random.normal(jax.random.fold_in(key, 2), (p,))
    u = jax.random.uniform(jax.random.fold_in(key, 3), (p,))
    noise = jax.random.uniform(jax.random.fold_in(key, 4), (p,))
    return v, ef, u, noise


def _k(p):
    return max(1, p // 10)


def _assert_same(a, b, what):
    for i, (x, y) in enumerate(zip(a, b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"{what}[{i}]")


# ------------------------------------------------------ interface (modes)

def test_kernel_mode_explicit_arg_wins(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_MODE", "xla")
    assert kernel_mode("interpret") is KernelType.INTERPRET
    assert kernel_mode(KernelType.PALLAS) is KernelType.PALLAS


def test_kernel_mode_env(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    assert kernel_mode() is KernelType.INTERPRET
    monkeypatch.setenv("REPRO_KERNEL_MODE", "PALLAS")   # case-insensitive
    assert kernel_mode() is KernelType.PALLAS


def test_kernel_mode_legacy_interpret_env(monkeypatch):
    monkeypatch.delenv("REPRO_KERNEL_MODE", raising=False)
    monkeypatch.setenv("FORCE_PALLAS_INTERPRET", "1")
    assert kernel_mode() is KernelType.INTERPRET


def test_kernel_mode_backend_default(monkeypatch):
    monkeypatch.delenv("REPRO_KERNEL_MODE", raising=False)
    monkeypatch.setenv("FORCE_PALLAS_INTERPRET", "0")
    expect = (KernelType.PALLAS if jax.default_backend() == "tpu"
              else KernelType.XLA)
    assert kernel_mode() is expect


def test_kernel_mode_rejects_unknown(monkeypatch):
    with pytest.raises(ValueError, match="unknown kernel mode"):
        kernel_mode("metal")
    monkeypatch.setenv("REPRO_KERNEL_MODE", "bogus")
    with pytest.raises(ValueError, match="REPRO_KERNEL_MODE"):
        kernel_mode()


def test_dispatch_key_tracks_env(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_MODE", "xla")
    monkeypatch.delenv("REPRO_COMPRESS_FUSED", raising=False)
    assert dispatch_key() == (KernelType.XLA, True)
    monkeypatch.setenv("REPRO_COMPRESS_FUSED", "0")
    assert not compress_fused()
    assert dispatch_key() == (KernelType.XLA, False)
    monkeypatch.setenv("REPRO_KERNEL_MODE", "interpret")
    assert dispatch_key() == (KernelType.INTERPRET, False)


# ----------------------------------------- Pallas-vs-XLA bit parity (fwd)

@pytest.mark.parametrize("p", SIZES)
def test_topk_parity_and_legacy(p):
    v, ef, _, _ = _data(p)
    k = _k(p)
    out_i = topk_compress(v, k, mode="interpret")
    out_x = topk_compress(v, k, mode="xla")
    _assert_same(out_i, out_x, "topk")
    _, idx = jax.lax.top_k(jnp.abs(v), k)
    legacy = jnp.zeros_like(v).at[idx].set(v[idx])
    np.testing.assert_array_equal(np.asarray(out_x[0]), np.asarray(legacy))
    assert int((out_x[1] >= 0).sum()) == k


@pytest.mark.parametrize("p", SIZES)
def test_ef_topk_parity(p):
    v, ef, _, _ = _data(p)
    k = _k(p)
    out_i = ef_topk_compress(v, ef, k, mode="interpret")
    out_x = ef_topk_compress(v, ef, k, mode="xla")
    _assert_same(out_i, out_x, "ef_topk")
    # EF identity: chat + ef_new reconstructs the message exactly
    # (selection writes each coordinate to exactly one of the two)
    np.testing.assert_array_equal(np.asarray(out_x[0] + out_x[2]),
                                  np.asarray(v + ef))


@pytest.mark.parametrize("p", SIZES)
@pytest.mark.parametrize("unbiased", [False, True])
def test_randk_parity_and_legacy(p, unbiased):
    v, _, u, _ = _data(p)
    k = _k(p)
    out_i = randk_compress(u, v, k, unbiased=unbiased, mode="interpret")
    out_x = randk_compress(u, v, k, unbiased=unbiased, mode="xla")
    _assert_same(out_i, out_x, "randk")
    _, idx = jax.lax.top_k(u, k)
    scale = (p / k) if unbiased else 1.0
    legacy = jnp.zeros_like(v).at[idx].set(v[idx] * scale)
    np.testing.assert_array_equal(np.asarray(out_x[0]), np.asarray(legacy))


@pytest.mark.parametrize("p", SIZES)
def test_ef_randk_parity(p):
    v, ef, u, _ = _data(p)
    k = _k(p)
    out_i = ef_randk_compress(u, v, ef, k, mode="interpret")
    out_x = ef_randk_compress(u, v, ef, k, mode="xla")
    _assert_same(out_i, out_x, "ef_randk")
    np.testing.assert_array_equal(np.asarray(out_x[0] + out_x[2]),
                                  np.asarray(v + ef))


@pytest.mark.parametrize("p", SIZES)
def test_ef_int8_parity(p):
    v, ef, _, noise = _data(p)
    out_i = ef_quantize_int8(v, ef, noise, mode="interpret")
    out_x = ef_quantize_int8(v, ef, noise, mode="xla")
    _assert_same(out_i, out_x, "ef_int8")
    # stochastic rounding stays within one quantization step per row
    q, scales, dq, ef_new = out_x
    rows = -(-p // 128)
    step = np.repeat(np.asarray(scales), 128)[:p]
    assert (np.abs(np.asarray(dq) - np.asarray(v + ef)) <= step).all()


@pytest.mark.parametrize("p", SIZES)
def test_sign_parity_and_scale(p):
    v, ef, _, _ = _data(p)
    out_i = sign_compress(v, mode="interpret")
    out_x = sign_compress(v, mode="xla")
    _assert_same(out_i, out_x, "sign")
    bits, scale, dq = out_x
    np.testing.assert_array_equal(
        np.asarray(dq), np.asarray(jnp.mean(jnp.abs(v)) * jnp.sign(v)))
    out_i = ef_sign_compress(v, ef, mode="interpret")
    out_x = ef_sign_compress(v, ef, mode="xla")
    _assert_same(out_i, out_x, "ef_sign")


# ------------------------------------- ties & degenerate inputs (legacy eq)

def _legacy_topk_dense(v, k):
    _, idx = jax.lax.top_k(jnp.abs(v), k)
    return jnp.zeros_like(v).at[idx].set(v[idx])


TIE_CASES = [
    (jnp.array([3.0, 5.0, 3.0, 5.0, 3.0]), 3),   # ties straddle the k-cut
    (jnp.array([1.0, 1.0, 1.0, 1.0, 1.0, 1.0]), 2),          # all tied
    (jnp.array([-2.0, 2.0, -2.0, 2.0, 0.0, 7.0]), 4),   # sign-mixed ties
]


@pytest.mark.parametrize("mode", ["interpret", "xla"])
@pytest.mark.parametrize("case", range(len(TIE_CASES)))
def test_topk_ties_match_legacy(mode, case):
    """Tied magnitudes keep lax.top_k's exact set: a low-index tie must
    never crowd out a strictly larger entry (the old rank-cap select
    kept a tied 3 and dropped a strictly larger 5)."""
    v, k = TIE_CASES[case]
    dq, ranks = topk_compress(v, k, mode=mode)
    np.testing.assert_array_equal(np.asarray(dq),
                                  np.asarray(_legacy_topk_dense(v, k)))
    r = np.asarray(ranks)
    np.testing.assert_array_equal(np.sort(r[r >= 0]), np.arange(k))
    vals, idx = pack_topk(dq, ranks, k)
    np.testing.assert_array_equal(
        np.asarray(unpack_topk(vals, idx, v.shape[0])), np.asarray(dq))


@pytest.mark.parametrize("mode", ["interpret", "xla"])
def test_topk_zero_heavy_keeps_signal(mode):
    """More than p-k zeros => threshold 0: every nonzero coordinate must
    survive (the old rank-cap kept the first k flat indices — all
    zeros — silently dropping the whole signal)."""
    p, k = 300, 50
    v = jnp.zeros(p).at[250].set(1.5).at[280].set(-2.0).at[299].set(0.5)
    dq, ranks = topk_compress(v, k, mode=mode)
    np.testing.assert_array_equal(np.asarray(dq),
                                  np.asarray(_legacy_topk_dense(v, k)))
    assert float(dq[250]) == 1.5 and float(dq[280]) == -2.0
    assert float(dq[299]) == 0.5
    assert int((ranks >= 0).sum()) == k


@pytest.mark.parametrize("mode", ["interpret", "xla"])
def test_ef_topk_sparse_delta_no_permanent_drop(mode):
    """The catastrophic EF case from the review: a sparse delta with
    > p-k zeros must be transmitted, not zeroed — with error feedback
    an all-zero dq would recur identically every round and the signal
    would never leave the device."""
    p, k = 256, 25
    delta = jnp.zeros(p).at[200].set(3.0).at[130].set(-1.0)
    ef = jnp.zeros(p)
    dq, ranks, ef_new = ef_topk_compress(delta, ef, k, mode=mode)
    assert float(dq[200]) == 3.0 and float(dq[130]) == -1.0
    np.testing.assert_array_equal(np.asarray(ef_new), np.zeros(p))
    assert int((ranks >= 0).sum()) == k


@pytest.mark.parametrize("mode", ["interpret", "xla"])
def test_randk_tied_uniforms_match_legacy(mode):
    """Colliding scores (forced here by quantizing the uniforms to 8
    levels) still reproduce lax.top_k's kept set bit-for-bit — the
    float32-collision case the birthday bound makes likely at real p."""
    p, k = 500, 60
    key = jax.random.PRNGKey(9)
    u = jnp.floor(jax.random.uniform(key, (p,)) * 8.0) / 8.0
    v = jax.random.normal(jax.random.fold_in(key, 1), (p,))
    dq, ranks = randk_compress(u, v, k, mode=mode)
    _, idx = jax.lax.top_k(u, k)
    legacy = jnp.zeros_like(v).at[idx].set(v[idx])
    np.testing.assert_array_equal(np.asarray(dq), np.asarray(legacy))
    vals, iw = pack_topk(dq, ranks, k)
    np.testing.assert_array_equal(np.asarray(unpack_topk(vals, iw, p)),
                                  np.asarray(dq))


# --------------------------------------------------- VMEM-bound fallback

def test_resolve_leaf_mode_vmem_fallback():
    assert resolve_leaf_mode(KernelType.PALLAS,
                             PALLAS_MAX_ELEMS) is KernelType.PALLAS
    assert resolve_leaf_mode(KernelType.PALLAS,
                             PALLAS_MAX_ELEMS + 1) is KernelType.XLA
    assert resolve_leaf_mode(KernelType.INTERPRET,
                             10 ** 9) is KernelType.INTERPRET
    assert resolve_leaf_mode(KernelType.XLA, 10 ** 9) is KernelType.XLA


def test_oversized_leaf_routes_to_xla_reference():
    """A leaf beyond the gridless kernels' VMEM budget must run (via the
    XLA reference) even under explicit pallas dispatch — on this CPU
    host a compiled pallas_call would fail outright, so completing at
    all proves the routing."""
    p = PALLAS_MAX_ELEMS + 128
    v = jnp.zeros(p).at[p - 3].set(4.0).at[17].set(-1.0)
    dq, ranks = topk_compress(v, 2, mode="pallas")
    assert float(dq[p - 3]) == 4.0 and float(dq[17]) == -1.0
    assert int((ranks >= 0).sum()) == 2


# ------------------------------------------------- wire-format roundtrips

@pytest.mark.parametrize("p", SIZES)
def test_topk_pack_unpack_roundtrip(p):
    v, _, u, _ = _data(p)
    k = _k(p)
    for dq, ranks in (topk_compress(v, k, mode="xla"),
                      randk_compress(u, v, k, mode="xla")):
        vals, idx = pack_topk(dq, ranks, k)
        assert vals.shape == (k,) and idx.shape == (k,)
        assert (np.asarray(idx) >= 0).all()
        back = unpack_topk(vals, idx, p)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(dq))


@pytest.mark.parametrize("p", SIZES)
def test_sign_pack_unpack_roundtrip(p):
    v, _, _, _ = _data(p)
    bits, scale, dq = sign_compress(v, mode="xla")
    assert bits.dtype == jnp.uint8 and bits.shape == (-(-p // 128), 16)
    dec = sign_unpack(bits, scale, p)
    np.testing.assert_array_equal(
        np.asarray(dec),
        np.asarray(jnp.where(v >= 0, scale, -scale)))


def test_int8_wire_dequantizes_to_dq():
    from repro.kernels.quantize import dequantize_int8
    p = 500
    v, ef, _, noise = _data(p)
    q, scales, dq, _ = ef_quantize_int8(v, ef, noise, mode="xla")
    np.testing.assert_array_equal(np.asarray(dequantize_int8(q, scales)),
                                  np.asarray(dq))


# ------------------------------------------------ comm routing (the tree)

def _tree(p1=130, p2=70, b=6):
    key = jax.random.PRNGKey(7)
    mk = lambda i, shape: jax.random.normal(jax.random.fold_in(key, i),
                                            shape)
    delta = {"w": mk(0, (2, 3, p1)), "b": mk(1, (2, 3, p2))}
    ef = {"w": 0.1 * mk(2, (2, 3, p1)), "b": 0.1 * mk(3, (2, 3, p2))}
    return delta, ef


@pytest.mark.parametrize("name", ["identity", "topk", "randk", "int8",
                                  "sign"])
def test_compress_tree_fused_matches_legacy(name, monkeypatch):
    """REPRO_COMPRESS_FUSED=0 (historical unfused ops) and the fused
    default produce the identical decompressed tree."""
    delta, _ = _tree()
    cfg = CommConfig(name, k_frac=0.2)
    key = jax.random.PRNGKey(3)
    monkeypatch.setenv("REPRO_COMPRESS_FUSED", "0")
    legacy = compress_tree(cfg, key, delta, (2, 3))
    monkeypatch.setenv("REPRO_COMPRESS_FUSED", "1")
    fused = compress_tree(cfg, key, delta, (2, 3))
    for a, b in zip(jax.tree.leaves(legacy), jax.tree.leaves(fused)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name", ["identity", "topk", "randk", "int8",
                                  "sign"])
def test_compress_tree_ef_matches_manual_arithmetic(name):
    """compress_tree_ef == (msg = delta + ef; chat = C(msg);
    ef_new = msg - chat), with identical PRNG streams."""
    delta, ef = _tree()
    cfg = CommConfig(name, k_frac=0.2)
    key = jax.random.PRNGKey(5)
    chat, ef_new = compress_tree_ef(cfg, key, delta, ef, (2, 3))
    msg = jax.tree.map(lambda d, e: d + e, delta, ef)
    chat2 = compress_tree(cfg, key, msg, (2, 3))
    ef2 = jax.tree.map(lambda m, c: m - c, msg, chat2)
    exact = name in ("identity", "topk", "randk")
    for a, b in zip(jax.tree.leaves(chat), jax.tree.leaves(chat2)):
        if exact:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)
    for a, b in zip(jax.tree.leaves(ef_new), jax.tree.leaves(ef2)):
        if exact:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("name", ["topk", "randk", "int8", "sign"])
def test_leaf_ef_compressor_vmap_parity(name):
    """The per-leaf EF routers agree across interpret/xla under vmap
    (the stacked (M, N) sender axes)."""
    cfg = CommConfig(name, k_frac=0.2)
    p, b = 300, 4
    key = jax.random.PRNGKey(11)
    keys = jax.random.split(key, b)
    d = jax.random.normal(jax.random.fold_in(key, 0), (b, p))
    e = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (b, p))
    f_i = jax.vmap(make_leaf_ef_compressor(cfg, p, mode="interpret"))
    f_x = jax.vmap(make_leaf_ef_compressor(cfg, p, mode="xla"))
    _assert_same(f_i(keys, d, e), f_x(keys, d, e), f"vmap-{name}")


def test_leaf_plan_static_and_cached():
    cfg = CommConfig("topk", k_frac=0.25)
    plan = leaf_plan(cfg, 1000)
    assert plan.k == leaf_k(0.25, 1000) == 250
    assert plan.rows == 8
    assert leaf_plan(cfg, 1000) is plan       # lru-cached, zero per-round work
    sign_plan = leaf_plan(CommConfig("sign"), 1000)
    assert sign_plan.k is None
    assert ("bits", (8, 16), "u8") in sign_plan.wire


# --------------------------------------------------------- custom VJPs

@pytest.mark.parametrize("mode", ["interpret", "xla"])
def test_ef_topk_grad_matches_ref_autodiff(mode):
    """The custom VJP is the exact a.e. gradient: identical to autodiff
    of the reference implementation."""
    from repro.kernels.compress import ref as R
    p, k = 257, 25
    v, ef, _, _ = _data(p, seed=42)

    def loss_op(d, e):
        dq, _, ef_new = ef_topk_compress(d, e, k, mode=mode)
        return jnp.sum(dq ** 2) + jnp.sum(ef_new * d)

    def loss_ref(d, e):
        dq, _, ef_new = R.ef_topk_select_ref(d, e, k)
        return jnp.sum(dq ** 2) + jnp.sum(ef_new * d)

    g_op = jax.grad(loss_op, argnums=(0, 1))(v, ef)
    g_ref = jax.grad(loss_ref, argnums=(0, 1))(v, ef)
    for a, b in zip(g_op, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_grad_parity_across_modes():
    p, k = 300, 30
    v, ef, u, noise = _data(p, seed=43)

    def grads(mode):
        gs = []
        gs.append(jax.grad(lambda x: jnp.sum(
            topk_compress(x, k, mode=mode)[0] ** 2))(v))
        gs.append(jax.grad(lambda x: jnp.sum(
            randk_compress(u, x, k, mode=mode)[0] ** 2))(v))
        gs.append(jax.grad(lambda x: jnp.sum(
            ef_quantize_int8(x, ef, noise, mode=mode)[2] ** 2))(v))
        gs.append(jax.grad(lambda x: jnp.sum(
            ef_sign_compress(x, ef, mode=mode)[2] ** 2))(v))
        return gs

    _assert_same(grads("interpret"), grads("xla"), "grads")


def test_ste_gradients():
    """int8/sign use the straight-through estimator: a loss that touches
    v only through dq sees the identity jacobian."""
    p = 200
    v, ef, _, noise = _data(p, seed=44)
    cot = jax.random.normal(jax.random.PRNGKey(8), (p,))
    g = jax.grad(lambda x: jnp.sum(
        ef_quantize_int8(x, ef, noise, mode="xla")[2] * cot))(v)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(cot))
    g = jax.grad(lambda x: jnp.sum(
        sign_compress(x, mode="xla")[2] * cot))(v)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(cot))
    # selection ops: exact mask gradient, not STE
    dq, ranks = topk_compress(v, 20, mode="xla")
    g = jax.grad(lambda x: jnp.sum(
        topk_compress(x, 20, mode="xla")[0] * cot))(v)
    np.testing.assert_array_equal(
        np.asarray(g), np.asarray(jnp.where(ranks >= 0, cot, 0.0)))
