"""Theorem 1/2 hyperparameter machinery (§3.3) and its use in experiments."""
import numpy as np
import pytest

from repro.core.theory import (inner_iteration_schedule, mclr_constants,
                               nonconvex_bounds, pick_hparams_strongly_convex,
                               strongly_convex_bounds)


def test_strongly_convex_bounds_match_theorem1():
    mu_f, l_f, lam, gamma = 0.1, 1.0, 2.5, 6.25
    b = strongly_convex_bounds(mu_f, l_f, lam, gamma)
    mu_ft = lam * gamma * mu_f / (lam * mu_f + gamma * mu_f + lam * gamma)
    assert np.isclose(b.mu_f_tilde_big, mu_ft)
    assert np.isclose(b.beta_max, mu_ft / (4 * gamma))
    assert np.isclose(b.eta_max, 1 / (2 * (lam + gamma)))
    assert np.isclose(b.alpha_max, 1 / (l_f + lam))
    assert b.gamma_ok  # gamma > 2 lam > 4 L_f fails here? 2.5*2=5<6.25 ok, 2*2.5=5>4 ok
    assert 0 < b.rate < 1


def test_gamma_condition_flags_violations():
    assert not strongly_convex_bounds(0.1, 1.0, 1.0, 10.0).gamma_ok  # 2lam<4Lf
    assert not strongly_convex_bounds(0.1, 1.0, 3.0, 5.0).gamma_ok   # gamma<2lam
    assert strongly_convex_bounds(0.1, 1.0, 2.1, 4.3).gamma_ok


def test_nonconvex_bounds_match_theorem2():
    b = nonconvex_bounds(1.0, 2.5, 6.0)
    assert np.isclose(b.beta_max, 1 / 24.0)
    assert np.isclose(b.eta_max, 1 / 8.5)
    assert np.isclose(b.alpha_max, 1 / 2.5)


def test_inner_schedule_scales_linearly():
    """K = Omega(T), L = Omega(K): doubling T (at fixed constants) must at
    least double K, and L >= K-slope * K."""
    kwargs = dict(mu_f=0.1, l_f=1.0, lam=2.5, gamma=6.25, alpha=0.2,
                  eta=0.05, beta=0.01)
    k1, l1 = inner_iteration_schedule(10, **kwargs)
    k2, l2 = inner_iteration_schedule(20, **kwargs)
    assert k2 >= 2 * k1 - 2
    assert l2 >= 2 * l1 - 2
    assert k1 >= 1 and l1 >= 1


def test_mclr_constants():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(200, 10)).astype(np.float32)
    mu, lf = mclr_constants(x, l2_reg=0.05)
    assert mu == 0.05
    assert lf > mu
    # L_f = 0.5 eig_max + reg
    cov = x.reshape(200, -1).astype(np.float64)
    eig = np.linalg.eigvalsh(cov.T @ cov / 200).max()
    assert np.isclose(lf, 0.5 * eig + 0.05, rtol=1e-5)


def test_pick_hparams_is_admissible():
    hp = pick_hparams_strongly_convex(0.05, 1.0)
    b = strongly_convex_bounds(0.05, 1.0, hp["lam"], hp["gamma"])
    assert b.gamma_ok
    assert hp["alpha"] <= b.alpha_max + 1e-12
    assert hp["eta"] <= b.eta_max + 1e-12
    assert hp["beta"] <= b.beta_max + 1e-12


def test_theory_rate_observed_on_quadratic():
    """The contraction observed on a strongly-convex run must be at least
    as fast as Theorem 1's (1 - beta) bound."""
    import jax
    import jax.numpy as jnp
    from repro.core.permfl import PerMFLHParams, init_state, permfl_round

    mu_f = l_f = 1.0   # quadratic 0.5||th-c||^2
    lam, gamma = 2.5, 6.25
    b = strongly_convex_bounds(mu_f, l_f, lam, gamma)
    hp = PerMFLHParams(alpha=b.alpha_max, eta=b.eta_max, beta=b.beta_max,
                       lam=lam, gamma=gamma, k_team=12, l_local=24)
    rng = np.random.default_rng(3)
    m, n, d = 2, 3, 4
    c = jnp.asarray(rng.normal(size=(m, n, d)).astype(np.float32))
    st = init_state(jnp.zeros(d), m, n)
    x_star = np.asarray(c.mean((0, 1)))
    e0 = float(np.sum((np.asarray(st.x) - x_star) ** 2))

    def loss(p, batch):
        return 0.5 * jnp.sum((p - batch["c"]) ** 2)

    T = 40
    for _ in range(T):
        st = permfl_round(st, {"c": c}, hp, loss, m_teams=m, n_devices=n)
    eT = float(np.sum((np.asarray(st.x) - x_star) ** 2))
    bound = 2 * (1 - hp.beta) ** T * e0
    assert eT <= bound, (eT, bound)
