"""Run-telemetry layer (repro.obs): RunTrace assembly, JSONL event
round-trips, the summarize/regress CLIs, and trace equivalence between
the solo engine and the vmapped sweep."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommConfig
from repro.core import PerMFL
from repro.core.permfl import PerMFLHParams
from repro.obs import RunTrace, TraceConfig, eval_points
from repro.obs import events as E
from repro.obs import regress as R
from repro.obs.__main__ import main as obs_main
from repro.train.engine import run_experiment
from repro.train.sweep import run_sweep

M, N, D = 3, 4, 5


def quad_loss(params, batch):
    return 0.5 * jnp.sum((params - batch["c"]) ** 2)


def neg_loss(params, batch):
    return -quad_loss(params, batch)


@pytest.fixture(scope="module")
def quad_data():
    rng = np.random.default_rng(0)
    return {"c": jnp.asarray(rng.normal(size=(M, N, D)).astype(np.float32))}


HP = PerMFLHParams(alpha=0.05, eta=0.04, beta=0.3, lam=0.8, gamma=2.0,
                   k_team=3, l_local=4)
KW = dict(metric_fn=neg_loss, rounds=6, m=M, n=N, seed=3, eval_every=2,
          team_frac=0.5, device_frac=0.75)


@pytest.fixture(scope="module")
def traced_run(quad_data):
    algo = PerMFL(quad_loss, HP,
                  comm=CommConfig(compressor="topk", k_frac=0.5))
    return algo, run_experiment(algo, jnp.zeros(D), quad_data, quad_data,
                                trace=True, **KW)


# ---------------------------------------------------------------------------
# eval_points / RunTrace
# ---------------------------------------------------------------------------

def test_eval_points_grid():
    assert eval_points(6, 2) == [2, 4, 6]
    assert eval_points(7, 2) == [2, 4, 6, 7]
    assert eval_points(3, 1) == [1, 2, 3]
    assert eval_points(2, 5) == [2]


def test_runtrace_accessors():
    t = RunTrace(config=TraceConfig(),
                 series={"a": [1.0, 2.0, 3.0, 4.0], "b": [0.5] * 4})
    assert len(t) == 4
    assert t.names() == ["a", "b"]
    assert t["a"] == [1.0, 2.0, 3.0, 4.0]
    assert t.last("a") == 4.0
    assert np.isnan(t.last("missing"))


def test_runtrace_at_points_segment_means():
    t = RunTrace(config=TraceConfig(), series={"a": [1.0, 3.0, 5.0, 7.0]})
    segs = t.at_points([2, 4])
    assert segs[0]["a"] == pytest.approx(2.0)   # mean of rounds 1-2
    assert segs[1]["a"] == pytest.approx(6.0)   # mean of rounds 3-4


def test_runtrace_summary():
    t = RunTrace(config=TraceConfig(), series={"a": [1.0, 3.0]})
    s = t.summary()
    assert s["a"] == {"mean": 2.0, "max": 3.0, "last": 3.0}


# ---------------------------------------------------------------------------
# engine integration: probe streams + event log
# ---------------------------------------------------------------------------

def test_engine_trace_streams(traced_run):
    _, res = traced_run
    assert res.trace is not None
    assert len(res.trace) == KW["rounds"]
    # PerMFL with comm emits the full probe set
    assert {"update_norm", "grad_norm", "pers_gap_mean", "pers_gap_max",
            "tier_drift_mean", "tier_drift_max", "ef_dev_norm",
            "ef_team_norm", "part_loss"} <= set(res.trace.names())
    for name in res.trace.names():
        assert np.isfinite(res.trace[name]).all(), name
    assert res.rounds == KW["rounds"]
    assert res.eval_every == KW["eval_every"]
    assert res.dispatches == 1          # 6 rounds / eval_every=2, no rem


def test_trace_off_leaves_result_bare(quad_data):
    algo = PerMFL(quad_loss, HP)
    res = run_experiment(algo, jnp.zeros(D), quad_data, quad_data, **KW)
    assert res.trace is None


def test_events_roundtrip(tmp_path, traced_run):
    algo, res = traced_run
    path = E.write_run(tmp_path, res, algo=algo, meta={"tag": "t1"})
    events = E.read_jsonl(path)
    kinds = [e["event"] for e in events]
    points = eval_points(KW["rounds"], KW["eval_every"])
    assert kinds == ["run_header"] + ["eval"] * len(points) + ["run_footer"]
    header, footer = events[0], events[-1]
    assert header["algo"] == "permfl" and header["tag"] == "t1"
    assert header["rounds"] == KW["rounds"]
    assert set(header["hparams"]) == {"alpha", "eta", "beta", "lam",
                                      "gamma"}
    evals = [e for e in events if e["event"] == "eval"]
    assert [e["round"] for e in evals] == points
    for e in evals:
        assert set(e["metrics"]) == {"pm", "tm", "gm", "train_loss"}
        assert e["cum_bytes"] > 0           # comm run joins bytes
        assert set(e["probes"]) == set(res.trace.names())
    # cumulative bytes must be monotone across eval points
    assert [e["cum_bytes"] for e in evals] == sorted(
        e["cum_bytes"] for e in evals)
    assert footer["final"]["pm"] == pytest.approx(res.pm_acc[-1])
    assert footer["dispatches"] == 1
    assert footer["comm"]["total_bytes"] == res.comm.total_bytes()
    assert set(footer["probes"]) == set(res.trace.names())


def test_split_and_summarize(tmp_path, traced_run):
    algo, res = traced_run
    E.write_run(tmp_path, res, algo=algo, run_id="r1")
    E.write_run(tmp_path, res, algo=algo, run_id="r2")
    runs = E.split_runs(E.read_jsonl(tmp_path))
    assert [r[0]["run"] for r in runs] == ["r1", "r2"]
    s = E.summarize_run(runs[0])
    assert s["run"] == "r1" and s["algo"] == "permfl"
    assert s["evals"] == len(eval_points(KW["rounds"], KW["eval_every"]))
    delta = E.diff_summaries(s, E.summarize_run(runs[1]))
    assert delta["final.pm"] == 0.0


def test_summarize_cli(tmp_path, capsys, traced_run):
    algo, res = traced_run
    E.write_run(tmp_path, res, algo=algo, run_id="r1")
    assert obs_main(["summarize", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "r1" in out and "dispatch" in out
    # diff mode against itself: zero deltas, still exit 0
    assert obs_main(["summarize", str(tmp_path), str(tmp_path)]) == 0
    assert "diff" in capsys.readouterr().out


def test_summarize_cli_empty_dir(tmp_path, capsys):
    assert obs_main(["summarize", str(tmp_path)]) == 1
    assert "no run events" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# sweep trace equivalence + sweep events
# ---------------------------------------------------------------------------

def test_sweep_traces_match_solo_runs(tmp_path, quad_data):
    algo = PerMFL(quad_loss, HP)
    grid = [{"beta": 0.3}, {"beta": 0.7}]
    kw = {k: v for k, v in KW.items() if k != "seed"}
    sw = run_sweep(algo, grid, (3,), jnp.zeros(D), quad_data, quad_data,
                   trace=True, trace_dir=tmp_path, **kw)
    assert sw.events_path is not None
    for g, res in zip(grid, sw):
        import dataclasses
        solo = run_experiment(
            dataclasses.replace(algo,
                                hp=dataclasses.replace(algo.hp, **g)),
            jnp.zeros(D), quad_data, quad_data, trace=True, seed=3, **kw)
        assert res.trace.names() == solo.trace.names()
        for name in solo.trace.names():
            np.testing.assert_allclose(res.trace[name], solo.trace[name],
                                       atol=1e-5)
    events = E.read_jsonl(sw.events_path)
    assert events[0]["event"] == "sweep_header"
    assert events[0]["configs"] == 2
    sections = E.split_runs(events)
    assert len(sections) == 2
    assert sections[0][0]["config"]["beta"] == pytest.approx(0.3)


# ---------------------------------------------------------------------------
# regress gate
# ---------------------------------------------------------------------------

_QUICK = {"mode": "quick",
          "engine": {"rounds_per_sec": {"scan": 10.0, "legacy": 5.0}},
          "sweep": {"configs_per_sec": {"sweep": 4.0, "seq": 1.0}},
          "obs": {"rounds_per_sec_probes": 9.0}}
_SMOKE = {"mode": "smoke",
          "engine": {"rounds_per_sec": 9.5},
          "sweep": {"configs_per_sec": 3.9},
          "obs": {"rounds_per_sec_probes": 8.8}}


def test_load_rates_normalizes_modes():
    q, s = R.load_rates(_QUICK), R.load_rates(_SMOKE)
    # smoke scalars land on the same dotted keys as quick's dict entries
    shared = set(q) & set(s)
    assert {"engine.rounds_per_sec.scan", "sweep.configs_per_sec.sweep",
            "obs.rounds_per_sec.probes"} == shared


def test_compare_passes_within_tolerance():
    failures, report = R.compare(_QUICK, _SMOKE, tol=0.2)
    assert failures == []
    assert any("only in baseline" in ln for ln in report)  # legacy/seq


def test_compare_fails_below_floor():
    slow = json.loads(json.dumps(_SMOKE))
    slow["engine"]["rounds_per_sec"] = 10.0 * 0.79
    failures, _ = R.compare(_QUICK, slow, tol=0.2)
    assert len(failures) == 1
    assert "engine.rounds_per_sec.scan" in failures[0]
    # improvements never fail
    fast = json.loads(json.dumps(_SMOKE))
    fast["engine"]["rounds_per_sec"] = 99.0
    assert R.compare(_QUICK, fast, tol=0.2)[0] == []


def test_regress_main_exit_codes(tmp_path, capsys):
    base = tmp_path / "base.json"
    cur = tmp_path / "cur.json"
    base.write_text(json.dumps(_QUICK))
    cur.write_text(json.dumps(_SMOKE))
    assert R.main([str(base), str(cur)]) == 0
    slow = json.loads(json.dumps(_SMOKE))
    slow["engine"]["rounds_per_sec"] = 1.0
    cur.write_text(json.dumps(slow))
    assert R.main([str(base), str(cur)]) == 1
    assert "REGRESSION" in capsys.readouterr().out
    # missing baseline: warn + pass (first run bootstraps the marker)
    assert R.main([str(tmp_path / "nope.json"), str(cur)]) == 0
    # regress is also reachable through the package CLI
    assert obs_main(["regress", str(base), str(cur), "--tol", "0.99"]) == 0


# ---------------------------------------------------------------------------
# scenarios CLI --json footer
# ---------------------------------------------------------------------------

def test_scenarios_cli_json_footer(capsys, tmp_path):
    from repro.scenarios.__main__ import main as scen_main

    rc = scen_main(["run", "table1/mnist/mclr/permfl", "--smoke",
                    "--trace-dir", str(tmp_path), "--json"])
    assert rc == 0
    ev = json.loads(capsys.readouterr().out)
    assert ev["event"] == "run_footer"
    assert ev["scenario"] == "table1/mnist/mclr/permfl"
    assert ev["spec_hash"]
    assert set(ev["final"]) == {"pm", "tm", "gm", "train_loss"}
    assert ev["events_path"].startswith(str(tmp_path))
    # and the event log it points at parses + summarizes
    assert obs_main(["summarize", str(tmp_path)]) == 0
