"""In-graph health monitors (repro.obs.health): off ⇒ identical
trajectories, scan ≡ dispatch detector streams, fail-fast round naming,
and per-config health on sweeps."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PerMFL
from repro.core.permfl import PerMFLHParams
from repro.obs import TraceConfig
from repro.obs.health import (HealthError, HealthReport, first_bad_round,
                              nonfinite_count)
from repro.train.engine import run_experiment
from repro.train.sweep import run_sweep

M, N, D = 3, 4, 5


def quad_loss(params, batch):
    return 0.5 * jnp.sum((params - batch["c"]) ** 2)


def neg_loss(params, batch):
    return -quad_loss(params, batch)


@pytest.fixture(scope="module")
def quad_data():
    rng = np.random.default_rng(0)
    return {"c": jnp.asarray(rng.normal(size=(M, N, D)).astype(np.float32))}


HP = PerMFLHParams(alpha=0.05, eta=0.04, beta=0.3, lam=0.8, gamma=2.0,
                   k_team=3, l_local=4)
BAD_HP = dataclasses.replace(HP, eta=1e30)  # overflows at round 1
KW = dict(metric_fn=neg_loss, rounds=6, m=M, n=N, seed=3, eval_every=2,
          team_frac=0.5, device_frac=0.75)


def _run(data, *, hp=HP, trace=None, scan=True, rounds=6):
    algo = PerMFL(quad_loss, hp)
    kw = dict(KW, rounds=rounds)
    return run_experiment(algo, jnp.zeros(D), data, data, scan=scan,
                          trace=trace, **kw)


# ---------------------------------------------------------------------------
# unit: the detector primitives
# ---------------------------------------------------------------------------

def test_nonfinite_count_counts_only_inexact_leaves():
    tree = {"w": jnp.array([1.0, jnp.nan, jnp.inf]),
            "steps": jnp.array([1, 2, 3]),      # int leaf: never counted
            "b": jnp.array([[0.0, -jnp.inf]])}
    assert float(nonfinite_count(tree)) == 3.0


def test_first_bad_round_is_one_based():
    assert first_bad_round({"d": [0.0, 0.0, 2.0, 0.0]}) == 3
    assert first_bad_round({"d": [0.0, 0.0]}) is None
    # nonfinite detector value = bad (the reduction itself saw garbage)
    assert first_bad_round({"d": [float("nan"), 0.0]}) == 1
    assert first_bad_round({}) is None
    # earliest round across streams wins
    assert first_bad_round({"a": [0.0, 1.0], "b": [3.0, 0.0]}) == 1


def test_health_report_check_raises_with_round_and_detectors():
    rep = HealthReport(series={"nonfinite_params": [0.0, 5.0],
                               "loss_exploded": [0.0, 0.0]})
    assert not rep.ok()
    assert rep.first_bad_round() == 2
    with pytest.raises(HealthError) as ei:
        rep.check("unit-test")
    assert ei.value.round_index == 2
    assert "round 2" in str(ei.value) and "unit-test" in str(ei.value)
    assert "nonfinite_params" in ei.value.detectors
    assert "loss_exploded" not in ei.value.detectors

    ok = HealthReport(series={"nonfinite_params": [0.0, 0.0]})
    ok.check("never-raises")
    assert ok.summary()["ok"] is True


# ---------------------------------------------------------------------------
# identity: monitors on vs off
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scan", (True, False))
def test_health_on_off_trajectories_and_state_identical(quad_data, scan):
    off = _run(quad_data, trace=TraceConfig(health=False), scan=scan)
    on = _run(quad_data, trace=TraceConfig(health=True), scan=scan)
    bare = _run(quad_data, trace=None, scan=scan)
    for a in (on, bare):
        np.testing.assert_array_equal(np.asarray(off.pm_acc),
                                      np.asarray(a.pm_acc))
        np.testing.assert_array_equal(np.asarray(off.train_loss),
                                      np.asarray(a.train_loss))
        for lo, la in zip(jax.tree.leaves(off.state),
                          jax.tree.leaves(a.state)):
            np.testing.assert_array_equal(np.asarray(lo), np.asarray(la))
    assert off.health is None and bare.health is None
    assert on.health is not None and on.health.ok()


def test_health_series_scan_matches_dispatch(quad_data):
    tc = TraceConfig(health=True)
    rs = _run(quad_data, trace=tc, scan=True)
    rd = _run(quad_data, trace=tc, scan=False)
    assert set(rs.health.series) == set(rd.health.series)
    assert {"nonfinite_params", "nonfinite_update",
            "loss_exploded"} <= set(rs.health.series)
    for k in rs.health.series:
        np.testing.assert_allclose(np.asarray(rs.health.series[k]),
                                   np.asarray(rd.health.series[k]))
        assert len(rs.health.series[k]) == KW["rounds"]


# ---------------------------------------------------------------------------
# fail-fast
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scan", (True, False))
def test_fail_fast_names_first_bad_round(quad_data, scan):
    with pytest.raises(HealthError) as ei:
        _run(quad_data, hp=BAD_HP, scan=scan,
             trace=TraceConfig(health=True, fail_fast=True))
    assert ei.value.round_index == 1
    assert "round 1" in str(ei.value)


def test_no_fail_fast_still_reports(quad_data):
    res = _run(quad_data, hp=BAD_HP,
               trace=TraceConfig(health=True, fail_fast=False))
    assert not res.health.ok()
    assert res.health.first_bad_round() == 1
    s = res.health.summary()
    assert s["ok"] is False and s["first_bad_round"] == 1


def test_health_off_never_raises_on_divergence(quad_data):
    res = _run(quad_data, hp=BAD_HP,
               trace=TraceConfig(health=False, fail_fast=True))
    assert res.health is None  # detectors never ran


# ---------------------------------------------------------------------------
# sweep: per-config health
# ---------------------------------------------------------------------------

SWEEP_KW = {k: v for k, v in KW.items() if k != "seed"}


def test_sweep_attaches_per_config_health(quad_data):
    algo = PerMFL(quad_loss, HP)
    grid = [{"eta": 0.04}, {"eta": 1e30}]
    sweep = run_sweep(algo, grid, (0,), lambda s: jnp.zeros(D),
                      quad_data, quad_data,
                      trace=TraceConfig(health=True), **SWEEP_KW)
    assert len(sweep.results) == 2
    healthy, sick = sweep.results
    assert healthy.health is not None and healthy.health.ok()
    assert not sick.health.ok()
    assert sick.health.first_bad_round() == 1


def test_sweep_fail_fast_names_config(quad_data):
    algo = PerMFL(quad_loss, HP)
    grid = [{"eta": 0.04}, {"eta": 1e30}]
    with pytest.raises(HealthError) as ei:
        run_sweep(algo, grid, (0,), lambda s: jnp.zeros(D),
                  quad_data, quad_data,
                  trace=TraceConfig(health=True, fail_fast=True),
                  **SWEEP_KW)
    assert "config 1" in str(ei.value)
    assert ei.value.round_index == 1
