"""Partitioner invariants across label-skew, Dirichlet, and quantity
skew: per-device class caps, stable shapes/dtypes, per-device train/val
disjointness, and the class-pool exhaustion warning (the silent sample
reuse fix)."""
import warnings

import numpy as np
import pytest

from repro.data.federated import (partition_dirichlet, partition_label_skew,
                                  partition_quantity_skew)
from repro.data.synthetic import make_dataset

M, N, SPD = 3, 4, 32


def _make(n_per_class=200, seed=0):
    rng = np.random.default_rng(seed)
    x, y = make_dataset("mnist", rng, n_per_class=n_per_class)
    return rng, x, y


PARTITIONERS = {
    "label_skew": lambda rng, x, y: partition_label_skew(
        rng, x, y, m_teams=M, n_devices=N, classes_per_device=2,
        samples_per_device=SPD),
    "dirichlet": lambda rng, x, y: partition_dirichlet(
        rng, x, y, m_teams=M, n_devices=N, alpha=0.5,
        samples_per_device=SPD),
    "quantity": lambda rng, x, y: partition_quantity_skew(
        rng, x, y, m_teams=M, n_devices=N, samples_per_device=SPD,
        min_frac=0.25),
}


@pytest.mark.parametrize("name", sorted(PARTITIONERS))
def test_shapes_dtypes_and_split(name):
    rng, x, y = _make()
    fd = PARTITIONERS[name](rng, x, y)
    n_val = SPD // 4
    assert fd.train_x.shape == (M, N, SPD - n_val) + x.shape[1:]
    assert fd.val_x.shape == (M, N, n_val) + x.shape[1:]
    assert fd.train_y.shape == (M, N, SPD - n_val)
    assert fd.train_x.dtype == np.float32 and fd.train_y.dtype == np.int32
    assert fd.val_x.dtype == np.float32 and fd.val_y.dtype == np.int32
    assert fd.m_teams == M and fd.n_devices == N


@pytest.mark.parametrize("name", sorted(PARTITIONERS))
def test_train_val_disjoint_per_device(name):
    """With ample pools, no validation row may appear among a device's
    train rows — duplicated train/val samples inflate accuracy."""
    rng, x, y = _make(n_per_class=400)
    fd = PARTITIONERS[name](rng, x, y)
    for i in range(M):
        for j in range(N):
            tr = {r.tobytes() for r in fd.train_x[i, j]}
            va = {r.tobytes() for r in fd.val_x[i, j]}
            assert tr.isdisjoint(va), f"device ({i},{j}) shares rows"


def test_label_skew_class_cap():
    rng, x, y = _make()
    fd = PARTITIONERS["label_skew"](rng, x, y)
    for i in range(M):
        for j in range(N):
            labels = set(np.unique(fd.train_y[i, j])) | \
                set(np.unique(fd.val_y[i, j]))
            assert len(labels) <= 2, f"device ({i},{j}) has {labels}"


def test_dirichlet_respects_team_pools():
    """Dirichlet skew composes with worst-case team formation: device
    labels stay inside their team's label pool."""
    from repro.core.team_formation import label_pools

    rng, x, y = _make(n_per_class=400)
    fd = partition_dirichlet(rng, x, y, m_teams=2, n_devices=N, alpha=0.5,
                             samples_per_device=SPD, strategy="worst")
    pools = label_pools("worst", 2, 10)
    for i in range(2):
        labels = set(np.unique(fd.train_y[i])) | set(np.unique(fd.val_y[i]))
        assert labels <= set(pools[i]), (i, labels)


def test_dirichlet_alpha_controls_concentration():
    """Small alpha concentrates devices on few classes; large alpha
    approaches a uniform class mix."""
    def mean_classes(alpha):
        rng, x, y = _make(n_per_class=600, seed=1)
        fd = partition_dirichlet(rng, x, y, m_teams=M, n_devices=N,
                                 alpha=alpha, samples_per_device=SPD)
        counts = [len(np.unique(np.concatenate(
            [fd.train_y[i, j], fd.val_y[i, j]])))
            for i in range(M) for j in range(N)]
        return float(np.mean(counts))

    assert mean_classes(0.05) < mean_classes(100.0) - 2.0


def test_quantity_skew_heterogeneous_effective_sizes():
    """Devices must differ in unique-sample counts (that is the skew),
    and every unique row a device's val split holds is unique."""
    rng, x, y = _make(n_per_class=400)
    fd = PARTITIONERS["quantity"](rng, x, y)
    uniq = np.array([[len({r.tobytes() for r in
                           np.concatenate([fd.train_x[i, j],
                                           fd.val_x[i, j]])})
                      for j in range(N)] for i in range(M)])
    assert uniq.min() >= int(0.25 * SPD)
    assert uniq.max() <= SPD
    assert uniq.std() > 0, "no quantity skew"
    # val rows are never duplicated
    for i in range(M):
        for j in range(N):
            va = [r.tobytes() for r in fd.val_x[i, j]]
            assert len(set(va)) == len(va)


def test_exhaustion_warns_on_sample_reuse():
    """Demanding more samples of a class than its pool holds must warn
    (the historical code wrapped modulo the pool silently)."""
    rng, x, y = _make(n_per_class=20)   # tiny pools: 20 per class
    with pytest.warns(UserWarning, match="exhausted"):
        partition_label_skew(rng, x, y, m_teams=4, n_devices=4,
                             classes_per_device=2, samples_per_device=64)


def test_quantity_skew_warns_on_realized_pool_wrap():
    """The exhaustion check must use the realized power-law draws, not
    the minimum-demand lower bound: many devices on a small pool wrap
    the global sample order and must warn."""
    rng, x, y = _make(n_per_class=30)    # pool of 300 samples
    with pytest.warns(UserWarning, match="reused across devices"):
        partition_quantity_skew(rng, x, y, m_teams=4, n_devices=10,
                                samples_per_device=48, min_frac=0.8)


def test_no_warning_with_ample_pools():
    rng, x, y = _make(n_per_class=600)
    with warnings.catch_warnings():
        warnings.simplefilter("error", UserWarning)
        partition_label_skew(rng, x, y, m_teams=2, n_devices=3,
                             classes_per_device=2, samples_per_device=16)
        partition_dirichlet(rng, x, y, m_teams=2, n_devices=3, alpha=0.5,
                            samples_per_device=16)


def test_label_skew_unchanged_by_exhaustion_accounting():
    """The warning is accounting-only: partitions must be bit-identical
    to the historical selection (benchmark trajectories must not move)."""
    rng1, x, y = _make(n_per_class=300, seed=5)
    fd1 = partition_label_skew(rng1, x, y, m_teams=2, n_devices=3,
                               samples_per_device=24)
    rng2 = np.random.default_rng(5)
    x2, y2 = make_dataset("mnist", rng2, n_per_class=300)
    fd2 = partition_label_skew(rng2, x2, y2, m_teams=2, n_devices=3,
                               samples_per_device=24)
    np.testing.assert_array_equal(fd1.train_x, fd2.train_x)
    np.testing.assert_array_equal(fd1.val_y, fd2.val_y)
