"""Baseline algorithms (Table 1 comparators): each runs, learns, and
exposes the structure the paper describes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_mclr import CONFIG as MCLR
from repro.models import paper_models as PM
from repro.train import fl_trainer as FT


@pytest.fixture(scope="module")
def setup(request):
    from repro.data.federated import partition_label_skew
    from repro.data.synthetic import make_dataset

    rng = np.random.default_rng(5)
    x, y = make_dataset("mnist", rng, n_per_class=60)
    fd = partition_label_skew(rng, x, y, m_teams=3, n_devices=3,
                              samples_per_device=32)
    params = PM.init_params(jax.random.PRNGKey(0), MCLR)
    loss = lambda p, b: PM.loss_fn(p, MCLR, b)
    met = lambda p, b: PM.accuracy(p, MCLR, b)
    tr = {"x": jnp.asarray(fd.train_x), "y": jnp.asarray(fd.train_y)}
    va = {"x": jnp.asarray(fd.val_x), "y": jnp.asarray(fd.val_y)}
    return fd, params, loss, met, tr, va


def test_fedavg_learns(setup):
    fd, params, loss, met, tr, va = setup
    res = FT.run_fedavg(params, tr, va, loss_fn=loss, metric_fn=met,
                        lr=0.05, local_steps=5, rounds=10, m=3, n=3)
    assert res.gm_acc[-1] > 0.3
    assert res.gm_acc[-1] >= res.gm_acc[0] - 0.05


def test_perfedavg_pm_beats_gm(setup):
    fd, params, loss, met, tr, va = setup
    res = FT.run_perfedavg(params, tr, va, loss_fn=loss, metric_fn=met,
                           lr=0.05, inner_lr=0.05, local_steps=5, rounds=10,
                           m=3, n=3)
    assert res.pm_acc[-1] > res.gm_acc[-1] - 0.02


def test_pfedme_learns(setup):
    fd, params, loss, met, tr, va = setup
    res = FT.run_pfedme(params, tr, va, loss_fn=loss, metric_fn=met,
                        lr=1.0, inner_lr=0.05, lam=15.0, inner_steps=5,
                        local_rounds=3, rounds=10, m=3, n=3)
    assert res.pm_acc[-1] > 0.5
    assert res.pm_acc[-1] > res.gm_acc[-1] - 0.02


def test_ditto_personal_model_wins(setup):
    fd, params, loss, met, tr, va = setup
    res = FT.run_ditto(params, tr, va, loss_fn=loss, metric_fn=met,
                       lr=0.05, lam=0.5, local_steps=5, rounds=10, m=3, n=3)
    assert res.pm_acc[-1] > 0.5
    assert res.pm_acc[-1] >= res.gm_acc[-1] - 0.02


def test_hsgd_learns(setup):
    fd, params, loss, met, tr, va = setup
    res = FT.run_hsgd(params, tr, va, loss_fn=loss, metric_fn=met,
                      lr=0.05, k_team=3, l_local=3, rounds=10, m=3, n=3)
    assert res.gm_acc[-1] > 0.3


def test_l2gd_learns(setup):
    fd, params, loss, met, tr, va = setup
    res = FT.run_l2gd(params, tr, va, loss_fn=loss, metric_fn=met,
                      lr=0.05, lam_c=0.5, lam_g=0.5, k_team=3, l_local=3,
                      rounds=10, m=3, n=3)
    assert res.pm_acc[-1] > 0.5


def test_permfl_pm_beats_all_gm_baselines(setup):
    """The paper's headline: PerMFL(PM) > single-model baselines under
    label skew."""
    fd, params, loss, met, tr, va = setup
    from repro.core.permfl import PerMFLHParams

    res_p = FT.run_permfl(params, tr, va, loss_fn=loss, metric_fn=met,
                          hp=PerMFLHParams(k_team=3, l_local=5),
                          rounds=10, m=3, n=3)
    res_f = FT.run_fedavg(params, tr, va, loss_fn=loss, metric_fn=met,
                          lr=0.05, local_steps=15, rounds=10, m=3, n=3)
    # the paper's ordering: PerMFL(PM) >= FedAvg(GM), and PM >> its own GM
    assert res_p.pm_acc[-1] >= res_f.gm_acc[-1], \
        (res_p.pm_acc[-1], res_f.gm_acc[-1])
    assert res_p.pm_acc[-1] > res_p.gm_acc[-1] + 0.1


def test_fedavg_equals_one_team_uniform_case():
    """FedAvg on IID quadratic data: the average of local optima equals the
    global optimum; FedAvg must find it."""
    def loss(p, b):
        return 0.5 * jnp.sum((p - b["c"]) ** 2)

    rng = np.random.default_rng(0)
    c = jnp.asarray(rng.normal(size=(2, 3, 4)).astype(np.float32))
    x = jnp.zeros(4)
    from repro.core.baselines import fedavg_round
    for _ in range(60):
        x = fedavg_round(x, {"c": c}, loss_fn=loss, lr=0.3, local_steps=1,
                         m=2, n=3)
    np.testing.assert_allclose(np.asarray(x), np.asarray(c.mean((0, 1))),
                               atol=1e-4)
