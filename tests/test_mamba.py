"""Mamba (S6) mixer: the chunked selective scan (§Perf hillclimb 1) must
be bit-equivalent to the per-timestep recurrence, across chunk sizes and
cache/prefill semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import mamba


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced_config("jamba-1.5-large-398b")
    key = jax.random.PRNGKey(0)
    params = mamba.mamba_init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 37, cfg.d_model)) * 0.3
    return cfg, params, x


def _naive_ssm(params, cfg, x):
    """Per-timestep NumPy recurrence (the mathematical definition)."""
    import numpy as np

    b, s, d = x.shape
    d_in, dt_rank, d_state, d_conv = mamba._dims(cfg)
    xz = np.asarray(x @ params["in_proj"], np.float64)
    xr, z = xz[..., :d_in], xz[..., d_in:]
    xp = np.pad(xr, ((0, 0), (d_conv - 1, 0), (0, 0)))
    w = np.asarray(params["conv_w"], np.float64)
    xc = sum(xp[:, i:i + s, :] * w[i][None, None, :] for i in range(d_conv))
    xc = xc * (1 / (1 + np.exp(-(xc + np.asarray(params["conv_b"])))))  # silu
    xc = np.asarray(jax.nn.silu(jnp.asarray(
        sum(xp[:, i:i + s, :] * w[i][None, None, :]
            for i in range(d_conv)) + np.asarray(params["conv_b"]))),
        np.float64)
    proj = xc @ np.asarray(params["x_proj"], np.float64)
    dt = proj[..., :dt_rank]
    b_mat = proj[..., dt_rank:dt_rank + d_state]
    c_mat = proj[..., dt_rank + d_state:]
    dt = np.logaddexp(0, dt @ np.asarray(params["dt_proj"], np.float64)
                      + np.asarray(params["dt_bias"], np.float64))
    a = -np.exp(np.asarray(params["A_log"], np.float64))
    h = np.zeros((b, d_in, d_state))
    ys = np.zeros((b, s, d_in))
    for t in range(s):
        da = np.exp(dt[:, t, :, None] * a)
        h = da * h + (dt[:, t] * xc[:, t])[..., None] * b_mat[:, t, None, :]
        ys[:, t] = np.einsum("bdn,bn->bd", h, c_mat[:, t])
    y = ys + xc * np.asarray(params["D"], np.float64)
    y = y * (z * (1 / (1 + np.exp(-z))))
    return (y @ np.asarray(params["out_proj"], np.float64)).astype(
        np.float32)


def test_chunked_matches_naive(setup):
    cfg, params, x = setup
    y, _ = mamba.mamba_apply(params, cfg, x, chunk=8)
    want = _naive_ssm(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y), want, atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("chunk", [1, 4, 16, 64])
def test_chunk_size_invariance(setup, chunk):
    cfg, params, x = setup
    y1, _ = mamba.mamba_apply(params, cfg, x, chunk=1)
    y2, _ = mamba.mamba_apply(params, cfg, x, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-5, rtol=1e-5)


def test_prefill_state_continuation(setup):
    """prefill(x[:k]) then mamba_apply on x[k:] with the returned cache
    == full-sequence apply (state carry across the chunk boundary)."""
    cfg, params, x = setup
    d_in, _, d_state, d_conv = mamba._dims(cfg)
    b = x.shape[0]
    cache0 = mamba.init_mamba_cache(cfg, b)
    y_full, _ = mamba.mamba_apply(params, cfg, x,
                                  cache=cache0, chunk=8)
    k = 17
    y1, c1 = mamba.mamba_apply(params, cfg, x[:, :k], cache=cache0, chunk=8)
    y2, _ = mamba.mamba_apply(params, cfg, x[:, k:], cache=c1, chunk=8)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, k:]),
                               atol=1e-5, rtol=1e-5)


def test_decode_matches_apply(setup):
    cfg, params, x = setup
    b = x.shape[0]
    cache = mamba.init_mamba_cache(cfg, b)
    y_full, _ = mamba.mamba_apply(params, cfg, x, cache=cache, chunk=8)
    # roll token by token
    c = mamba.init_mamba_cache(cfg, b)
    outs = []
    for t in range(x.shape[1]):
        y, c = mamba.mamba_decode(params, cfg, x[:, t:t + 1], c)
        outs.append(y)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full),
                               atol=2e-5, rtol=2e-5)


def test_gradients_flow_through_chunks(setup):
    cfg, params, x = setup

    def loss(p):
        y, _ = mamba.mamba_apply(p, cfg, x, chunk=8)
        return jnp.sum(y ** 2)

    g = jax.grad(loss)(params)
    for path, leaf in jax.tree_util.tree_flatten_with_path(g)[0]:
        assert np.isfinite(np.asarray(leaf)).all(), path
    # at least the scan-path params get nonzero grads
    assert float(jnp.abs(g["A_log"]).max()) > 0
    assert float(jnp.abs(g["in_proj"]).max()) > 0
