"""Launch-layer policy units (no 512-device init needed): shape policy,
SWA resolution, cache sizing, mesh helpers."""
import jax
import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config

# importing repro.launch.dryrun sets XLA_FLAGS for 512 host devices, which
# only takes effect if jax is not yet initialized — initialize it first so
# this test file can never change the device count for the rest of the
# session, regardless of test ordering.
jax.devices()


def _resolve(arch, shape):
    # import inside: dryrun sets XLA_FLAGS at module import, which is fine
    # in-process as long as jax was already initialized (flag is ignored).
    from repro.launch.dryrun import cache_len_for, resolve_config
    return resolve_config(arch, shape), cache_len_for


def test_long500k_dense_gets_sliding_window():
    for arch in ("phi3-mini-3.8b", "yi-34b", "qwen3-14b", "qwen1.5-32b",
                 "dbrx-132b", "deepseek-moe-16b", "qwen2-vl-2b"):
        (cfg, skip), _ = _resolve(arch, "long_500k")
        assert skip is None, arch
        assert cfg.sliding_window > 0, arch


def test_long500k_ssm_hybrid_native():
    for arch in ("rwkv6-7b", "jamba-1.5-large-398b"):
        (cfg, skip), _ = _resolve(arch, "long_500k")
        assert skip is None
        assert cfg.sliding_window == 0, f"{arch} should run natively"


def test_long500k_whisper_skipped():
    (cfg, skip), _ = _resolve("whisper-small", "long_500k")
    assert skip is not None and "448" in skip


def test_swa_cache_is_window_sized():
    from repro.launch.dryrun import cache_len_for, resolve_config

    cfg, _ = resolve_config("yi-34b", "long_500k")
    assert cache_len_for(cfg, INPUT_SHAPES["long_500k"]) == \
        cfg.sliding_window
    cfg2, _ = resolve_config("yi-34b", "decode_32k")
    assert cache_len_for(cfg2, INPUT_SHAPES["decode_32k"]) == 32_768


def test_other_shapes_never_skip():
    from repro.launch.dryrun import resolve_config

    for arch in ARCH_IDS:
        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            _, skip = resolve_config(arch, shape)
            assert skip is None, (arch, shape)


def test_mesh_helpers():
    from repro.launch.mesh import batch_axes, mesh_batch_size

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    assert batch_axes(FakeMesh()) == ("pod", "data")
    assert mesh_batch_size(FakeMesh()) == 32


def test_decode_tp_gate_thresholds():
    """The pure-TP serving gate: small dense models qualify; 32B+ and MoE
    banks do not (they would not fit a 16 GB v5e at TP-16)."""
    from repro.configs.base import param_count

    qualifies = {}
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        qualifies[arch] = 2 * param_count(cfg) / 16 < 4e9
    assert qualifies["phi3-mini-3.8b"]
    assert qualifies["rwkv6-7b"]
    assert qualifies["deepseek-moe-16b"]
    assert not qualifies["dbrx-132b"]
    assert not qualifies["jamba-1.5-large-398b"]
    assert not qualifies["yi-34b"]
