"""End-to-end system behaviour: the paper's full loop (data -> teams ->
PerMFL -> three models -> eval) plus a dry-run launch as a subprocess."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_full_paper_loop_mclr(small_fed_data):
    """Data partition -> PerMFL -> PM/TM/GM hierarchy behaves as the paper
    describes: PM >= TM >= GM under label-skew (within tolerance)."""
    from repro.configs.paper_mclr import CONFIG as MCLR
    from repro.core.permfl import PerMFLHParams
    from repro.models import paper_models as PM
    from repro.train.fl_trainer import run_permfl

    fd = small_fed_data
    params = PM.init_params(jax.random.PRNGKey(0), MCLR)
    loss = lambda p, b: PM.loss_fn(p, MCLR, b)
    met = lambda p, b: PM.accuracy(p, MCLR, b)
    tr = {"x": jnp.asarray(fd.train_x), "y": jnp.asarray(fd.train_y)}
    va = {"x": jnp.asarray(fd.val_x), "y": jnp.asarray(fd.val_y)}
    res = run_permfl(params, tr, va, loss_fn=loss, metric_fn=met,
                     hp=PerMFLHParams(k_team=3, l_local=5), rounds=10,
                     m=fd.m_teams, n=fd.n_devices)
    pm, tm, gm = res.pm_acc[-1], res.tm_acc[-1], res.gm_acc[-1]
    assert pm > 0.9
    assert pm >= tm - 0.05, (pm, tm)
    assert tm >= gm - 0.25, (tm, gm)
    # training loss decreased
    assert res.train_loss[-1] < res.train_loss[0]


def test_partial_participation_still_converges(small_fed_data):
    from repro.configs.paper_mclr import CONFIG as MCLR
    from repro.core.permfl import PerMFLHParams
    from repro.models import paper_models as PM
    from repro.train.fl_trainer import run_permfl

    fd = small_fed_data
    params = PM.init_params(jax.random.PRNGKey(0), MCLR)
    loss = lambda p, b: PM.loss_fn(p, MCLR, b)
    met = lambda p, b: PM.accuracy(p, MCLR, b)
    tr = {"x": jnp.asarray(fd.train_x), "y": jnp.asarray(fd.train_y)}
    va = {"x": jnp.asarray(fd.val_x), "y": jnp.asarray(fd.val_y)}
    res = run_permfl(params, tr, va, loss_fn=loss, metric_fn=met,
                     hp=PerMFLHParams(k_team=3, l_local=5), rounds=12,
                     m=fd.m_teams, n=fd.n_devices, team_frac=0.5,
                     device_frac=0.67, seed=1)
    assert res.pm_acc[-1] > 0.75


def test_dryrun_subprocess_single_combo():
    """launch/dryrun.py in its own process (512 host devices) must lower
    and compile whisper-small train_4k on the single-pod mesh."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper-small", "--shape", "train_4k", "--mesh", "pod"],
        capture_output=True, text=True, timeout=420,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        cwd=REPO)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "1/1 combos OK" in out.stdout


def test_mesh_factories_are_lazy():
    """Importing launch.mesh must not initialize jax devices (the dry-run
    device-count env only works pre-init)."""
    import ast
    src = open(os.path.join(REPO, "src/repro/launch/mesh.py")).read()
    assert "jax.make_mesh" in src
    tree = ast.parse(src)
    for node in tree.body:
        assert not (isinstance(node, ast.Expr) and
                    isinstance(node.value, ast.Call)), \
            "module-level call in mesh.py"


def test_dryrun_sets_device_flag_first():
    lines = [l for l in open(
        os.path.join(REPO, "src/repro/launch/dryrun.py")).read().splitlines()
        if l.strip() and not l.strip().startswith("#")]
    assert lines[0] == "import os"
    assert "xla_force_host_platform_device_count=512" in lines[1]
