"""pydocstyle-lite: the public FL API must stay documented.

Every module below must have a module docstring, and every symbol it
exports via __all__ — plus the public methods those classes define in
this repo — must carry a nonempty docstring. Pytree-protocol boilerplate
(tree_flatten / tree_unflatten) is exempt.
"""
import importlib
import inspect

import pytest

PUBLIC_MODULES = (
    "repro.core",
    "repro.core.algorithm",
    "repro.comm",
    "repro.kernels",
    "repro.kernels.interface",
    "repro.kernels.compress",
    "repro.train.engine",
    "repro.train.store",
    "repro.train.sweep",
    "repro.train.fl_trainer",
    "repro.scenarios",
    "repro.scenarios.spec",
    "repro.scenarios.registry",
    "repro.scenarios.runner",
    "repro.system",
    "repro.system.spec",
    "repro.system.simulate",
    "repro.system.timeline",
    "repro.obs",
    "repro.obs.trace",
    "repro.obs.probes",
    "repro.obs.events",
    "repro.obs.profiling",
    "repro.obs.regress",
    "repro.obs.spans",
    "repro.obs.metrics",
    "repro.obs.health",
    "repro.obs.report",
    "repro.train.metrics",
    "repro.serve",
    "repro.serve.store",
    "repro.serve.personalized",
)

_EXEMPT_METHODS = {"tree_flatten", "tree_unflatten"}


def _public_methods(cls):
    for name, member in vars(cls).items():
        if name.startswith("_") or name in _EXEMPT_METHODS:
            continue
        if inspect.isfunction(member):
            yield name, member
        elif isinstance(member, (classmethod, staticmethod)):
            yield name, member.__func__


@pytest.mark.parametrize("modname", PUBLIC_MODULES)
def test_public_api_is_documented(modname):
    mod = importlib.import_module(modname)
    assert (mod.__doc__ or "").strip(), f"{modname}: no module docstring"
    assert hasattr(mod, "__all__"), f"{modname}: no __all__"
    missing = []
    for name in mod.__all__:
        obj = getattr(mod, name)
        if inspect.ismodule(obj):
            if not (obj.__doc__ or "").strip():
                missing.append(f"{name} (module)")
            continue
        if not (inspect.isclass(obj) or inspect.isfunction(obj)):
            continue        # constants, dicts (e.g. ALGORITHMS)
        if not (obj.__doc__ or "").strip():
            missing.append(name)
        if inspect.isclass(obj):
            for mname, meth in _public_methods(obj):
                if not (meth.__doc__ or "").strip():
                    missing.append(f"{name}.{mname}")
    assert not missing, (
        f"{modname}: public symbols without docstrings: {missing}")
