"""Personalized serving subsystem (DESIGN.md §12): serving identity for
every algorithm family, tier fallback, encodings, persistence, replay."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import paper_models
from repro.scenarios import SCENARIOS, build_scenario, run_scenario
from repro.serve.personalized import (PersonalizedServer, replay_traffic,
                                      zipf_requests)
from repro.serve.store import ModelStore

ALGOS = ("permfl", "fedavg", "perfedavg", "pfedme", "ditto", "hsgd",
         "l2gd")


@functools.lru_cache(maxsize=None)
def _trained(algo: str):
    s = SCENARIOS[f"table1/mnist/mclr/{algo}"].scaled(
        m_teams=2, n_devices=3, samples_per_device=16, rounds=1)
    res = run_scenario(s, seed=0)
    b = build_scenario(s, seed=0)
    xv = np.asarray(b.val["x"], np.float32)
    pool = jnp.asarray(xv.reshape((-1,) + xv.shape[3:]))
    apply1 = lambda p, x: paper_models.apply(p, b.config, x[None])[0]
    return b, res.state, apply1, pool


def _all_pairs(m, n):
    return (np.repeat(np.arange(m), n), np.tile(np.arange(n), m))


# ---------------------------------------------------------------------------
# serving identity: store-served == direct evaluation, per family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ALGOS)
def test_served_predictions_bit_identical_per_family(algo):
    b, state, apply1, pool = _trained(algo)
    store = ModelStore.from_state(b.algo, state, m=b.m, n=b.n)
    server = PersonalizedServer(store, apply1)
    ts, ds = _all_pairs(b.m, b.n)
    xs = pool[: b.m * b.n]
    served = server.serve(ts, ds, xs)
    # reference: the device's trained params straight out of the state,
    # through the same vmapped forward program
    direct = jax.tree.map(
        lambda *ls: jnp.stack(ls),
        *[b.algo.serving_params(state, int(t), int(d))
          for t, d in zip(ts, ds)])
    ref = server._fwd(direct, xs)
    np.testing.assert_array_equal(np.asarray(served), np.asarray(ref))
    assert bool(jnp.isfinite(served).all())


@pytest.mark.parametrize("algo", ("permfl", "ditto"))
def test_single_model_forward_agrees(algo):
    # same logits as a plain single-model apply per device (batch-of-one
    # forwards): the batched tier-resolved path adds nothing numerically
    b, state, apply1, pool = _trained(algo)
    store = ModelStore.from_state(b.algo, state, m=b.m, n=b.n)
    server = PersonalizedServer(store, apply1)
    ts, ds = _all_pairs(b.m, b.n)
    xs = pool[: b.m * b.n]
    served = np.asarray(server.serve(ts, ds, xs))
    for i, (t, d) in enumerate(zip(ts, ds)):
        p = b.algo.serving_params(state, int(t), int(d))
        one = paper_models.apply(p, b.config, xs[i][None])[0]
        np.testing.assert_allclose(served[i], np.asarray(one),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# tier fallback
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("encoding", ("delta", "int8", "raw"))
def test_unknown_device_falls_back_to_team(encoding):
    b, state, apply1, pool = _trained("permfl")
    store = ModelStore.from_state(b.algo, state, m=b.m, n=b.n,
                                  encoding=encoding)
    server = PersonalizedServer(store, apply1)
    x = pool[:1]
    for t in range(b.m):
        for bad_d in (-1, b.n, b.n + 7):
            out = server.serve(np.array([t]), np.array([bad_d]), x)
            ref = paper_models.apply(b.algo.serving_params(state, t),
                                     b.config, x)
            np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("encoding", ("delta", "int8"))
def test_unknown_team_falls_back_to_global(encoding):
    b, state, apply1, pool = _trained("permfl")
    store = ModelStore.from_state(b.algo, state, m=b.m, n=b.n,
                                  encoding=encoding)
    server = PersonalizedServer(store, apply1)
    x = pool[:1]
    ref = paper_models.apply(b.algo.serving_params(state), b.config, x)
    for bad_t in (-3, b.m, b.m + 9):
        for d in (0, b.n + 1):
            out = server.serve(np.array([bad_t]), np.array([d]), x)
            np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_params_for_walks_the_same_ladder():
    b, state, _, _ = _trained("permfl")
    store = ModelStore.from_state(b.algo, state, m=b.m, n=b.n)
    g = store.params_for()
    team0 = store.params_for(0)
    dev01 = store.params_for(0, 1)
    for got, want in ((g, b.algo.serving_params(state)),
                      (team0, b.algo.serving_params(state, 0)),
                      (dev01, b.algo.serving_params(state, 0, 1)),
                      (store.params_for(0, b.n + 1),
                       b.algo.serving_params(state, 0)),
                      (store.params_for(b.m + 1, 0),
                       b.algo.serving_params(state))):
        for a, c in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_params_for_lru_caches_and_evicts():
    b, state, _, _ = _trained("permfl")
    store = ModelStore.from_state(b.algo, state, m=b.m, n=b.n,
                                  cache_size=2)
    p = store.params_for(0, 0)
    assert store.params_for(0, 0) is p          # hit: same object
    store.params_for(0, 1)
    store.params_for(0, 2)                      # evicts (0, 0)
    assert store.params_for(0, 0) is not p
    assert len(store._cache) == 2


# ---------------------------------------------------------------------------
# encodings and the cached serve path
# ---------------------------------------------------------------------------

def test_cached_path_bit_identical_for_exact_encodings():
    b, state, apply1, pool = _trained("pfedme")
    ts, ds = _all_pairs(b.m, b.n)
    ts, ds = np.concatenate([ts, ts]), np.concatenate([ds, ds])
    xs = pool[: len(ts)]
    for encoding in ("delta", "raw"):
        store = ModelStore.from_state(b.algo, state, m=b.m, n=b.n,
                                      encoding=encoding)
        server = PersonalizedServer(store, apply1)
        np.testing.assert_array_equal(
            np.asarray(server.serve(ts, ds, xs)),
            np.asarray(server.serve_cached(ts, ds, xs)))


def test_int8_encoding_bounded_error_and_smaller():
    b, state, apply1, pool = _trained("permfl")
    exact = ModelStore.from_state(b.algo, state, m=b.m, n=b.n)
    lossy = ModelStore.from_state(b.algo, state, m=b.m, n=b.n,
                                  encoding="int8")
    assert lossy.device_tier_nbytes() < exact.device_tier_nbytes() / 3
    ts, ds = _all_pairs(b.m, b.n)
    pe = exact.gather(jnp.asarray(ts), jnp.asarray(ds))
    pl = lossy.gather(jnp.asarray(ts), jnp.asarray(ds))
    for e, l, t in zip(jax.tree.leaves(pe), jax.tree.leaves(pl),
                       jax.tree.leaves(exact.team_params)):
        # int8 residual quantization: error per element bounded by the
        # per-128-lane scale = max|residual| / 127
        resid = np.abs(np.asarray(e) - np.asarray(t)[ts])
        bound = resid.reshape(len(ts), -1).max(axis=1) / 127 + 1e-7
        err = np.abs(np.asarray(e) - np.asarray(l)).reshape(len(ts), -1)
        assert (err.max(axis=1) <= bound).all()


def test_unknown_encoding_rejected():
    b, state, _, _ = _trained("permfl")
    with pytest.raises(ValueError, match="encoding"):
        ModelStore.from_state(b.algo, state, m=b.m, n=b.n,
                              encoding="float8")


# ---------------------------------------------------------------------------
# persistence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("encoding", ("delta", "int8"))
def test_save_load_roundtrip_serves_identically(tmp_path, encoding):
    b, state, apply1, pool = _trained("l2gd")
    store = ModelStore.from_state(b.algo, state, m=b.m, n=b.n,
                                  encoding=encoding)
    path = str(tmp_path / "store.zip")
    store.save(path)
    loaded = ModelStore.load(path)
    assert (loaded.encoding, loaded.m, loaded.n) == (encoding, b.m, b.n)
    ts, ds = _all_pairs(b.m, b.n)
    xs = pool[: len(ts)]
    np.testing.assert_array_equal(
        np.asarray(PersonalizedServer(store, apply1).serve(ts, ds, xs)),
        np.asarray(PersonalizedServer(loaded, apply1).serve(ts, ds, xs)))


def test_load_rejects_non_store_checkpoint(tmp_path):
    from repro.train.checkpoint import save_checkpoint

    path = str(tmp_path / "not_store.zip")
    save_checkpoint(path, {"w": jnp.zeros(2)}, metadata={"step": 1})
    with pytest.raises(ValueError, match="ModelStore"):
        ModelStore.load(path)


# ---------------------------------------------------------------------------
# traffic replay
# ---------------------------------------------------------------------------

def test_zipf_requests_skewed_and_fallback_tagged():
    teams, devices = zipf_requests(4, 10, 2000, alpha=1.3,
                                   unknown_frac=0.2, seed=3)
    known = (teams < 4) & (devices < 10)
    assert 0.05 < 1 - known.mean() < 0.4
    assert (teams[known] >= 0).all() and (devices[known] >= 0).all()
    # popularity is skewed: the most popular principal dominates a
    # uniform draw's expected share several-fold
    flat = teams[known] * 10 + devices[known]
    top_share = np.bincount(flat).max() / len(flat)
    assert top_share > 3.0 / 40


def test_replay_traffic_stats_shape():
    b, state, apply1, pool = _trained("permfl")
    store = ModelStore.from_state(b.algo, state, m=b.m, n=b.n)
    server = PersonalizedServer(store, apply1)
    stats = replay_traffic(server, np.asarray(pool), requests=64,
                           batch=16, unknown_frac=0.1, seed=1)
    assert stats["requests"] == 64 and stats["batch"] == 16
    assert stats["qps"] > 0
    assert stats["p50_ms"] <= stats["p95_ms"] <= stats["p99_ms"]
    assert stats["device_tier_bytes"] == store.device_tier_nbytes()


# ---------------------------------------------------------------------------
# serving telemetry (tier counts, LRU stats, metrics)
# ---------------------------------------------------------------------------

def test_tier_counts_sum_to_request_count_and_match_tags():
    b, state, apply1, pool = _trained("permfl")
    store = ModelStore.from_state(b.algo, state, m=b.m, n=b.n)
    server = PersonalizedServer(store, apply1)
    # hand-built batch: 2 personal, 1 unknown device, 1 unknown team
    ts = np.array([0, 1, 0, b.m + 3])
    ds = np.array([0, 2, b.n + 5, 0])
    server.serve(ts, ds, pool[:4])
    assert server.tier_counts == {"device": 2, "team": 1, "global": 1}
    # the cached path counts the same ladder host-side
    server.reset_tier_counts()
    server.serve_cached(ts, ds, pool[:4])
    assert server.tier_counts == {"device": 2, "team": 1, "global": 1}
    assert sum(server.tier_counts.values()) == len(ts)


@pytest.mark.parametrize("cached", (False, True))
def test_replay_tier_counts_sum_to_requests(cached):
    b, state, apply1, pool = _trained("permfl")
    store = ModelStore.from_state(b.algo, state, m=b.m, n=b.n)
    server = PersonalizedServer(store, apply1)
    stats = replay_traffic(server, np.asarray(pool), requests=64,
                           batch=16, unknown_frac=0.2, seed=1,
                           cached=cached)
    tiers = stats["tier_counts"]
    assert set(tiers) == {"device", "team", "global"}
    # the warm-up batch's contribution was reset: counts cover exactly
    # the timed requests
    assert sum(tiers.values()) == stats["requests"] == 64
    assert tiers["team"] + tiers["global"] > 0  # unknown_frac fired


def test_replay_reports_live_lru_hit_rate():
    b, state, apply1, pool = _trained("permfl")
    store = ModelStore.from_state(b.algo, state, m=b.m, n=b.n)
    server = PersonalizedServer(store, apply1)
    stats = replay_traffic(server, np.asarray(pool), requests=64,
                           batch=16, seed=1, cached=True)
    # warm-up populated the hot principals and the counters were reset,
    # so the timed traffic's hit rate is the steady-state one
    assert 0.0 < stats["cache_hit_rate"] <= 1.0
    cs = store.cache_stats()
    assert cs["hits"] + cs["misses"] > 0
    assert cs["hit_rate"] == stats["cache_hit_rate"]


def test_store_cache_stats_count_and_reset():
    b, state, apply1, pool = _trained("permfl")
    store = ModelStore.from_state(b.algo, state, m=b.m, n=b.n)
    store.params_for(0, 0)
    store.params_for(0, 0)
    store.params_for(1, 1)
    assert store.cache_stats()["hits"] == 1
    assert store.cache_stats()["misses"] == 2
    assert store.cache_stats()["hit_rate"] == pytest.approx(1 / 3)
    store.reset_cache_stats()
    cs = store.cache_stats()
    assert cs["hits"] == 0 and cs["misses"] == 0 and cs["hit_rate"] == 0.0
    # cached entries survive the counter reset
    store.params_for(0, 0)
    assert store.cache_stats()["hits"] == 1


def test_replay_publishes_metrics_and_raw_latencies():
    from repro.obs.metrics import MetricsRegistry

    b, state, apply1, pool = _trained("permfl")
    store = ModelStore.from_state(b.algo, state, m=b.m, n=b.n)
    server = PersonalizedServer(store, apply1)
    metrics = MetricsRegistry()
    stats = replay_traffic(server, np.asarray(pool), requests=64,
                           batch=16, unknown_frac=0.1, seed=1,
                           cached=True, metrics=metrics)
    assert len(stats["lat_ms"]) == 64 // 16
    assert stats["stage_gather_ms"] > 0 and stats["stage_forward_ms"] > 0
    snap = {(e["metric"], e["type"]): e for e in metrics.snapshot()}
    assert snap[("serving.requests", "counter")]["value"] == 64
    tier_total = sum(
        snap[(f"serving.tier.{t}", "counter")]["value"]
        for t in ("device", "team", "global"))
    assert tier_total == 64
    lat = snap[("serving.replay.latency_ms", "histogram")]
    assert lat["count"] == 64 // 16
    assert ("serving.cache_hit_rate", "gauge") in snap


# ---------------------------------------------------------------------------
# zipf_requests workload properties
# ---------------------------------------------------------------------------

def test_zipf_requests_deterministic_under_fixed_seed():
    a = zipf_requests(4, 10, 500, alpha=1.3, unknown_frac=0.2, seed=7)
    b = zipf_requests(4, 10, 500, alpha=1.3, unknown_frac=0.2, seed=7)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])
    c = zipf_requests(4, 10, 500, alpha=1.3, unknown_frac=0.2, seed=8)
    assert not (np.array_equal(a[0], c[0]) and np.array_equal(a[1], c[1]))


def test_zipf_requests_unknown_split_device_vs_team():
    m, n, count = 4, 10, 4000
    teams, devices = zipf_requests(m, n, count, alpha=1.3,
                                   unknown_frac=0.3, seed=5)
    bad_dev = devices >= n
    bad_team = teams >= m
    # every unknown-team row is also unknown-device (team badness is a
    # coin flip *within* the bad-device rows), and the split is roughly
    # half/half of a ~unknown_frac share
    assert (bad_team <= bad_dev).all()
    assert 0.2 < bad_dev.mean() < 0.4
    assert 0.3 < bad_team.sum() / bad_dev.sum() < 0.7
    # out-of-range tags are exactly the sentinel values
    assert set(np.unique(devices[bad_dev])) == {n + 1}
    assert set(np.unique(teams[bad_team])) == {m + 1}


def test_zipf_requests_permutation_scatters_hot_set_across_teams():
    m, n = 8, 8
    teams, devices = zipf_requests(m, n, 20000, alpha=1.5, seed=11)
    flat = teams * n + devices
    top8 = np.argsort(np.bincount(flat, minlength=m * n))[-8:]
    # without the permutation the 8 hottest principals would be ranks
    # 0..7 = all of team 0; with it they spread over several teams
    assert len(set(int(p) // n for p in top8)) >= 3
