"""Personalized serving subsystem (DESIGN.md §12): serving identity for
every algorithm family, tier fallback, encodings, persistence, replay."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import paper_models
from repro.scenarios import SCENARIOS, build_scenario, run_scenario
from repro.serve.personalized import (PersonalizedServer, replay_traffic,
                                      zipf_requests)
from repro.serve.store import ModelStore

ALGOS = ("permfl", "fedavg", "perfedavg", "pfedme", "ditto", "hsgd",
         "l2gd")


@functools.lru_cache(maxsize=None)
def _trained(algo: str):
    s = SCENARIOS[f"table1/mnist/mclr/{algo}"].scaled(
        m_teams=2, n_devices=3, samples_per_device=16, rounds=1)
    res = run_scenario(s, seed=0)
    b = build_scenario(s, seed=0)
    xv = np.asarray(b.val["x"], np.float32)
    pool = jnp.asarray(xv.reshape((-1,) + xv.shape[3:]))
    apply1 = lambda p, x: paper_models.apply(p, b.config, x[None])[0]
    return b, res.state, apply1, pool


def _all_pairs(m, n):
    return (np.repeat(np.arange(m), n), np.tile(np.arange(n), m))


# ---------------------------------------------------------------------------
# serving identity: store-served == direct evaluation, per family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ALGOS)
def test_served_predictions_bit_identical_per_family(algo):
    b, state, apply1, pool = _trained(algo)
    store = ModelStore.from_state(b.algo, state, m=b.m, n=b.n)
    server = PersonalizedServer(store, apply1)
    ts, ds = _all_pairs(b.m, b.n)
    xs = pool[: b.m * b.n]
    served = server.serve(ts, ds, xs)
    # reference: the device's trained params straight out of the state,
    # through the same vmapped forward program
    direct = jax.tree.map(
        lambda *ls: jnp.stack(ls),
        *[b.algo.serving_params(state, int(t), int(d))
          for t, d in zip(ts, ds)])
    ref = server._fwd(direct, xs)
    np.testing.assert_array_equal(np.asarray(served), np.asarray(ref))
    assert bool(jnp.isfinite(served).all())


@pytest.mark.parametrize("algo", ("permfl", "ditto"))
def test_single_model_forward_agrees(algo):
    # same logits as a plain single-model apply per device (batch-of-one
    # forwards): the batched tier-resolved path adds nothing numerically
    b, state, apply1, pool = _trained(algo)
    store = ModelStore.from_state(b.algo, state, m=b.m, n=b.n)
    server = PersonalizedServer(store, apply1)
    ts, ds = _all_pairs(b.m, b.n)
    xs = pool[: b.m * b.n]
    served = np.asarray(server.serve(ts, ds, xs))
    for i, (t, d) in enumerate(zip(ts, ds)):
        p = b.algo.serving_params(state, int(t), int(d))
        one = paper_models.apply(p, b.config, xs[i][None])[0]
        np.testing.assert_allclose(served[i], np.asarray(one),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# tier fallback
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("encoding", ("delta", "int8", "raw"))
def test_unknown_device_falls_back_to_team(encoding):
    b, state, apply1, pool = _trained("permfl")
    store = ModelStore.from_state(b.algo, state, m=b.m, n=b.n,
                                  encoding=encoding)
    server = PersonalizedServer(store, apply1)
    x = pool[:1]
    for t in range(b.m):
        for bad_d in (-1, b.n, b.n + 7):
            out = server.serve(np.array([t]), np.array([bad_d]), x)
            ref = paper_models.apply(b.algo.serving_params(state, t),
                                     b.config, x)
            np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("encoding", ("delta", "int8"))
def test_unknown_team_falls_back_to_global(encoding):
    b, state, apply1, pool = _trained("permfl")
    store = ModelStore.from_state(b.algo, state, m=b.m, n=b.n,
                                  encoding=encoding)
    server = PersonalizedServer(store, apply1)
    x = pool[:1]
    ref = paper_models.apply(b.algo.serving_params(state), b.config, x)
    for bad_t in (-3, b.m, b.m + 9):
        for d in (0, b.n + 1):
            out = server.serve(np.array([bad_t]), np.array([d]), x)
            np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_params_for_walks_the_same_ladder():
    b, state, _, _ = _trained("permfl")
    store = ModelStore.from_state(b.algo, state, m=b.m, n=b.n)
    g = store.params_for()
    team0 = store.params_for(0)
    dev01 = store.params_for(0, 1)
    for got, want in ((g, b.algo.serving_params(state)),
                      (team0, b.algo.serving_params(state, 0)),
                      (dev01, b.algo.serving_params(state, 0, 1)),
                      (store.params_for(0, b.n + 1),
                       b.algo.serving_params(state, 0)),
                      (store.params_for(b.m + 1, 0),
                       b.algo.serving_params(state))):
        for a, c in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_params_for_lru_caches_and_evicts():
    b, state, _, _ = _trained("permfl")
    store = ModelStore.from_state(b.algo, state, m=b.m, n=b.n,
                                  cache_size=2)
    p = store.params_for(0, 0)
    assert store.params_for(0, 0) is p          # hit: same object
    store.params_for(0, 1)
    store.params_for(0, 2)                      # evicts (0, 0)
    assert store.params_for(0, 0) is not p
    assert len(store._cache) == 2


# ---------------------------------------------------------------------------
# encodings and the cached serve path
# ---------------------------------------------------------------------------

def test_cached_path_bit_identical_for_exact_encodings():
    b, state, apply1, pool = _trained("pfedme")
    ts, ds = _all_pairs(b.m, b.n)
    ts, ds = np.concatenate([ts, ts]), np.concatenate([ds, ds])
    xs = pool[: len(ts)]
    for encoding in ("delta", "raw"):
        store = ModelStore.from_state(b.algo, state, m=b.m, n=b.n,
                                      encoding=encoding)
        server = PersonalizedServer(store, apply1)
        np.testing.assert_array_equal(
            np.asarray(server.serve(ts, ds, xs)),
            np.asarray(server.serve_cached(ts, ds, xs)))


def test_int8_encoding_bounded_error_and_smaller():
    b, state, apply1, pool = _trained("permfl")
    exact = ModelStore.from_state(b.algo, state, m=b.m, n=b.n)
    lossy = ModelStore.from_state(b.algo, state, m=b.m, n=b.n,
                                  encoding="int8")
    assert lossy.device_tier_nbytes() < exact.device_tier_nbytes() / 3
    ts, ds = _all_pairs(b.m, b.n)
    pe = exact.gather(jnp.asarray(ts), jnp.asarray(ds))
    pl = lossy.gather(jnp.asarray(ts), jnp.asarray(ds))
    for e, l, t in zip(jax.tree.leaves(pe), jax.tree.leaves(pl),
                       jax.tree.leaves(exact.team_params)):
        # int8 residual quantization: error per element bounded by the
        # per-128-lane scale = max|residual| / 127
        resid = np.abs(np.asarray(e) - np.asarray(t)[ts])
        bound = resid.reshape(len(ts), -1).max(axis=1) / 127 + 1e-7
        err = np.abs(np.asarray(e) - np.asarray(l)).reshape(len(ts), -1)
        assert (err.max(axis=1) <= bound).all()


def test_unknown_encoding_rejected():
    b, state, _, _ = _trained("permfl")
    with pytest.raises(ValueError, match="encoding"):
        ModelStore.from_state(b.algo, state, m=b.m, n=b.n,
                              encoding="float8")


# ---------------------------------------------------------------------------
# persistence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("encoding", ("delta", "int8"))
def test_save_load_roundtrip_serves_identically(tmp_path, encoding):
    b, state, apply1, pool = _trained("l2gd")
    store = ModelStore.from_state(b.algo, state, m=b.m, n=b.n,
                                  encoding=encoding)
    path = str(tmp_path / "store.zip")
    store.save(path)
    loaded = ModelStore.load(path)
    assert (loaded.encoding, loaded.m, loaded.n) == (encoding, b.m, b.n)
    ts, ds = _all_pairs(b.m, b.n)
    xs = pool[: len(ts)]
    np.testing.assert_array_equal(
        np.asarray(PersonalizedServer(store, apply1).serve(ts, ds, xs)),
        np.asarray(PersonalizedServer(loaded, apply1).serve(ts, ds, xs)))


def test_load_rejects_non_store_checkpoint(tmp_path):
    from repro.train.checkpoint import save_checkpoint

    path = str(tmp_path / "not_store.zip")
    save_checkpoint(path, {"w": jnp.zeros(2)}, metadata={"step": 1})
    with pytest.raises(ValueError, match="ModelStore"):
        ModelStore.load(path)


# ---------------------------------------------------------------------------
# traffic replay
# ---------------------------------------------------------------------------

def test_zipf_requests_skewed_and_fallback_tagged():
    teams, devices = zipf_requests(4, 10, 2000, alpha=1.3,
                                   unknown_frac=0.2, seed=3)
    known = (teams < 4) & (devices < 10)
    assert 0.05 < 1 - known.mean() < 0.4
    assert (teams[known] >= 0).all() and (devices[known] >= 0).all()
    # popularity is skewed: the most popular principal dominates a
    # uniform draw's expected share several-fold
    flat = teams[known] * 10 + devices[known]
    top_share = np.bincount(flat).max() / len(flat)
    assert top_share > 3.0 / 40


def test_replay_traffic_stats_shape():
    b, state, apply1, pool = _trained("permfl")
    store = ModelStore.from_state(b.algo, state, m=b.m, n=b.n)
    server = PersonalizedServer(store, apply1)
    stats = replay_traffic(server, np.asarray(pool), requests=64,
                           batch=16, unknown_frac=0.1, seed=1)
    assert stats["requests"] == 64 and stats["batch"] == 16
    assert stats["qps"] > 0
    assert stats["p50_ms"] <= stats["p95_ms"] <= stats["p99_ms"]
    assert stats["device_tier_bytes"] == store.device_tier_nbytes()
