"""Sharding spec rules: correct PartitionSpecs per param family, and the
divisibility validator that makes explicit shardings safe."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_reduced_config
from repro.models import model as M
from repro.sharding.specs import (batch_pspecs, cache_pspecs, fl_pspecs,
                                  param_pspecs, validate_pspecs)


def _find(tree, substr):
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if substr in key:
            out[key] = leaf
    return out


def test_attention_params_tp_sharded():
    cfg = get_config("phi3-mini-3.8b")
    specs = M.param_specs(cfg)
    ps = param_pspecs(specs)
    wq = list(_find(ps, "attn/wq").values())
    assert wq and all(s[-1] == "model" and s[-2] == "data" for s in wq)
    wo = list(_find(ps, "attn/wo").values())
    assert wo and all(s[-2] == "model" and s[-1] == "data" for s in wo)


def test_moe_experts_expert_parallel():
    cfg = get_config("dbrx-132b")
    ps = param_pspecs(M.param_specs(cfg))
    for key, spec in _find(ps, "experts/w_gate").items():
        # (n_blocks, E, d, ff): experts over model, d over data
        assert spec[-3] == "model" and spec[-2] == "data", (key, spec)


def test_embed_and_head():
    cfg = get_config("yi-34b")
    ps = param_pspecs(M.param_specs(cfg))
    assert ps["embed"] == P("model", None)
    assert ps["lm_head"] == P(None, "model")


def test_norms_replicated():
    cfg = get_config("qwen3-14b")
    ps = param_pspecs(M.param_specs(cfg))
    for key, spec in _find(ps, "norm1").items():
        assert spec == P(), (key, spec)


def test_fsdp_off_drops_data_axis():
    cfg = get_config("phi3-mini-3.8b")
    ps = param_pspecs(M.param_specs(cfg), fsdp=False)
    for key, spec in _find(ps, "attn/wq").items():
        assert "data" not in [s for s in spec if isinstance(s, str)], \
            (key, spec)
        assert spec[-1] == "model"


def test_validate_drops_nondivisible():
    mesh = jax.make_mesh((1,), ("model",))
    # 1-device mesh: axis size 1 divides everything -> keep
    shapes = {"a": jax.ShapeDtypeStruct((7, 8), jnp.float32)}
    out = validate_pspecs(shapes, {"a": P("model", None)}, mesh)
    assert out["a"] == P("model", None)


def test_validate_drops_nondivisible_sim():
    """Simulate a 16-way axis via a fake mesh-shape mapping."""
    class FakeMesh:
        shape = {"model": 16, "data": 16}

    shapes = {"a": jax.ShapeDtypeStruct((51865, 64), jnp.float32),   # vocab!
              "b": jax.ShapeDtypeStruct((64, 128), jnp.float32)}
    out = validate_pspecs(shapes, {"a": P("model", None),
                                   "b": P("data", "model")}, FakeMesh())
    assert out["a"] == P(None, None)          # 51865 % 16 != 0 -> dropped
    assert out["b"] == P("data", "model")     # 64 % 16 == 0, 128 % 16 == 0


def test_batch_pspecs():
    batch = {"tokens": jax.ShapeDtypeStruct((32, 128), jnp.int32),
             "targets": jax.ShapeDtypeStruct((32, 128), jnp.int32)}
    ps = batch_pspecs(batch, batch_axes=("pod", "data"))
    assert ps["tokens"] == P(("pod", "data"), None)


def test_cache_pspecs_seq_shard_when_batch_one():
    """long_500k: b=1 cache shards its sequence dim over data instead of
    replicating the 500k-token KV."""
    cfg = get_reduced_config("phi3-mini-3.8b")
    cache = M.cache_specs(cfg, batch=1, max_len=4096)
    ps = cache_pspecs(cache, batch_axes="data", mesh_batch=16)
    for key, spec in _find(ps, "/k").items():
        assert spec == P(None, None, "data", "model", None), (key, spec)
    # batch divisible -> batch sharding, seq unsharded
    cache2 = M.cache_specs(cfg, batch=32, max_len=4096)
    ps2 = cache_pspecs(cache2, batch_axes="data", mesh_batch=16)
    for key, spec in _find(ps2, "/k").items():
        assert spec == P(None, "data", None, "model", None), (key, spec)


def test_fl_pspecs_stacked_layout():
    stacked = {"w": jnp.zeros((4, 10, 7, 3)), "b": jnp.zeros((4,))}
    ps = fl_pspecs(stacked)
    assert ps["w"] == P("pod", "data", None, None)
    assert ps["b"] == P("pod")


def test_jit_with_specs_on_cpu_mesh():
    """End-to-end: shard a reduced model on the 1-device mesh and run a
    forward under pjit with explicit shardings (exercises to_named)."""
    from repro.sharding.specs import to_named

    cfg = get_reduced_config("phi3-mini-3.8b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    p_specs = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    shard = to_named(param_pspecs(p_specs), mesh, p_specs)
    batch = {"tokens": jnp.zeros((2, 8), jnp.int32),
             "targets": jnp.zeros((2, 8), jnp.int32)}

    with mesh:
        f = jax.jit(lambda p, b: M.loss_fn(p, cfg, b),
                    in_shardings=(shard, None))
        lv = f(params, batch)
    assert np.isfinite(float(lv))
