"""Virtualized cohort engine vs the stacked engine (DESIGN.md §11).

The lockdown suite for sample-then-materialize training: with
``cohort == n`` the index map is the identity, so every trajectory,
final state, ledger, probe stream, and simulated timeline must be
*bit*-identical to the stacked engine's (assert_array_equal) — for
PerMFL with and without comm/participation and for the baselines. At
``cohort < n`` the scan and dispatch execution models must agree
(allclose, the same tolerance test_engine.py uses for scan-vs-dispatch),
cohort sampling must never perturb the participation mask stream, and
error-feedback residuals of never-sampled devices must stay untouched.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommConfig
from repro.core import PerMFL, baselines as B
from repro.core.permfl import PerMFLHParams
from repro.train.engine import run_experiment
from repro.train.sweep import run_multi_sweep, run_sweep

M, N, D = 3, 6, 5
COHORT = 4


def quad_loss(params, batch):
    return 0.5 * jnp.sum((params - batch["c"]) ** 2)


def neg_loss(params, batch):
    return -quad_loss(params, batch)


@pytest.fixture(scope="module")
def quad_data():
    rng = np.random.default_rng(0)
    return {"c": jnp.asarray(rng.normal(size=(M, N, D)).astype(np.float32))}


HP = PerMFLHParams(alpha=0.05, eta=0.04, beta=0.3, lam=0.8, gamma=2.0,
                   k_team=3, l_local=4)


def _algos():
    return {
        "permfl": PerMFL(quad_loss, HP),
        "permfl_comm": PerMFL(quad_loss, HP,
                              comm=CommConfig("topk", k_frac=0.4)),
        "fedavg": B.FedAvg(quad_loss, lr=0.1, local_steps=3),
        "ditto": B.Ditto(quad_loss, lr=0.05, lam=0.5, local_steps=3),
    }


def _assert_bit_identical(a, b):
    for f in ("pm_acc", "tm_acc", "gm_acc", "train_loss"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)
    for x, y in zip(jax.tree.leaves(a.state), jax.tree.leaves(b.state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert a.participation == b.participation
    if a.comm is not None or b.comm is not None:
        assert a.comm.totals() == b.comm.totals()


# ---------------------------------------------------------------------------
# cohort == n: the identity gather — bit-exact full-population equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["permfl", "permfl_comm", "fedavg",
                                  "ditto"])
def test_full_width_cohort_matches_stacked(quad_data, name):
    algo = _algos()[name]
    kw = dict(metric_fn=neg_loss, rounds=5, m=M, n=N, seed=3)
    stacked = run_experiment(algo, jnp.zeros(D), quad_data, quad_data, **kw)
    cohort = run_experiment(algo, jnp.zeros(D), quad_data, quad_data,
                            cohort=N, **kw)
    _assert_bit_identical(stacked, cohort)
    assert cohort.cohort == N and cohort.population == N
    for per_round in cohort.cohort_indices:
        np.testing.assert_array_equal(np.asarray(per_round),
                                      np.tile(np.arange(N), (M, 1)))


def test_full_width_cohort_matches_stacked_sampled_comm(quad_data):
    """Partial team/device participation + compressed uplinks: masks,
    byte ledgers, and EF residuals all ride the identity gather."""
    algo = _algos()["permfl_comm"]
    kw = dict(metric_fn=neg_loss, rounds=5, m=M, n=N, seed=11,
              team_frac=0.5, device_frac=0.75)
    stacked = run_experiment(algo, jnp.zeros(D), quad_data, quad_data, **kw)
    cohort = run_experiment(algo, jnp.zeros(D), quad_data, quad_data,
                            cohort=N, **kw)
    _assert_bit_identical(stacked, cohort)
    assert len(cohort.comm.rounds) == 5


def test_full_width_cohort_matches_stacked_system(quad_data):
    """The wall-clock simulator prices the materialized masks: at
    cohort == n the simulated timeline is bit-identical to stacked."""
    from repro.system import get_profile

    algo = _algos()["permfl"]
    kw = dict(metric_fn=neg_loss, rounds=4, m=M, n=N, seed=5,
              team_frac=0.5, system=get_profile("wan-cellular"))
    stacked = run_experiment(algo, jnp.zeros(D), quad_data, quad_data, **kw)
    cohort = run_experiment(algo, jnp.zeros(D), quad_data, quad_data,
                            cohort=N, **kw)
    _assert_bit_identical(stacked, cohort)
    np.testing.assert_array_equal(
        np.asarray(stacked.timeline.round_seconds),
        np.asarray(cohort.timeline.round_seconds))


# ---------------------------------------------------------------------------
# cohort < n: scan == dispatch, bookkeeping, key-stream isolation
# ---------------------------------------------------------------------------

def test_cohort_scan_matches_dispatch(quad_data):
    """Both execution models run the same gather -> round -> scatter
    chain (test_engine.py's scan-vs-dispatch tolerance conventions)."""
    algo = _algos()["permfl_comm"]
    kw = dict(metric_fn=neg_loss, rounds=5, m=M, n=N, seed=7, cohort=COHORT,
              team_frac=0.5, device_frac=0.75, trace=True)
    scan = run_experiment(algo, jnp.zeros(D), quad_data, quad_data,
                          scan=True, **kw)
    disp = run_experiment(algo, jnp.zeros(D), quad_data, quad_data,
                          scan=False, **kw)
    for f in ("pm_acc", "tm_acc", "gm_acc", "train_loss"):
        np.testing.assert_allclose(getattr(scan, f), getattr(disp, f),
                                   atol=1e-5, err_msg=f)
    for a, b in zip(jax.tree.leaves(scan.state),
                    jax.tree.leaves(disp.state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    # identical PRNG chain => identical cohorts, masks, and ledgers
    np.testing.assert_array_equal(np.asarray(scan.cohort_indices),
                                  np.asarray(disp.cohort_indices))
    assert scan.participation == disp.participation
    assert scan.comm.totals() == disp.comm.totals()
    assert scan.trace.names() == disp.trace.names()
    for name in scan.trace.names():
        np.testing.assert_allclose(scan.trace[name], disp.trace[name],
                                   atol=1e-5, err_msg=name)
    assert scan.dispatches < disp.dispatches


def test_cohort_indices_and_participation_bookkeeping(quad_data):
    """cohort_indices records one sorted (M, C) map per round; the
    participation ledger counts within the cohort, not the population."""
    res = run_experiment(_algos()["permfl"], jnp.zeros(D), quad_data,
                         quad_data, metric_fn=neg_loss, rounds=6, m=M, n=N,
                         seed=2, cohort=COHORT, device_frac=0.5)
    assert len(res.cohort_indices) == 6
    for per_round in res.cohort_indices:
        arr = np.asarray(per_round)
        assert arr.shape == (M, COHORT)
        for row in arr:
            assert (np.diff(row) > 0).all()
            assert row.min() >= 0 and row.max() < N
    for n_teams, n_devices in res.participation:
        assert n_teams == M
        assert n_devices == M * max(1, round(COHORT * 0.5))


def test_cohort_sampling_never_perturbs_mask_stream(quad_data):
    """Determinism pin: the cohort key is salted off the round's mask
    key, so the same seed yields the same participation mask stream for
    cohort=None and any cohort size — different cohort widths change
    *which* devices materialize, never *how many teams* the masks keep."""
    algo = _algos()["permfl"]
    kw = dict(metric_fn=neg_loss, rounds=6, m=M, n=N, seed=9,
              team_frac=0.5)
    runs = {c: run_experiment(algo, jnp.zeros(D), quad_data, quad_data,
                              cohort=c, **kw)
            for c in (None, 3, 5, N)}
    team_counts = {c: [t for t, _ in r.participation]
                   for c, r in runs.items()}
    for c in (3, 5, N):
        assert team_counts[c] == team_counts[None], c
    # and the full-width run is the stacked run, masks included
    _assert_bit_identical(runs[None], runs[N])


def test_ef_residuals_of_unsampled_devices_untouched(quad_data):
    """Error-feedback state is per-device: a device that was never in
    any cohort must keep its residuals exactly at init (zero)."""
    algo = _algos()["permfl_comm"]
    res = run_experiment(algo, jnp.zeros(D), quad_data, quad_data,
                         metric_fn=neg_loss, rounds=3, m=M, n=N, seed=4,
                         cohort=2)
    sampled = [set() for _ in range(M)]
    for per_round in res.cohort_indices:
        for t, row in enumerate(np.asarray(per_round)):
            sampled[t].update(int(j) for j in row)
    ef = np.asarray(jax.tree.leaves(res.state.comm.ef_dev)[0])
    never = [(t, j) for t in range(M) for j in range(N)
             if j not in sampled[t]]
    assert never, "pick rounds/cohort so some device is never sampled"
    for t, j in never:
        np.testing.assert_array_equal(ef[t, j], np.zeros_like(ef[t, j]))
    # devices that did participate moved their residuals
    assert any(np.any(ef[t, j] != 0) for t in range(M)
               for j in sampled[t])


def test_eval_every_chunking_with_cohort(quad_data):
    """Chunk-boundary evals merge the store back to full width; the
    remainder chunk works and matches the per-round-eval run."""
    algo = _algos()["permfl"]
    kw = dict(metric_fn=neg_loss, rounds=7, m=M, n=N, seed=6,
              cohort=COHORT)
    res = run_experiment(algo, jnp.zeros(D), quad_data, quad_data,
                         eval_every=3, **kw)
    assert len(res.pm_acc) == 3               # rounds 3, 6, remainder 7
    assert len(res.participation) == 7
    full = run_experiment(algo, jnp.zeros(D), quad_data, quad_data, **kw)
    np.testing.assert_allclose(res.pm_acc[-1], full.pm_acc[-1], atol=1e-5)
    for a, b in zip(jax.tree.leaves(res.state),
                    jax.tree.leaves(full.state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_cohort_validation(quad_data):
    algo = _algos()["permfl"]
    kw = dict(metric_fn=neg_loss, rounds=2, m=M, n=N)
    for bad in (0, -1, N + 1):
        with pytest.raises(ValueError, match="cohort"):
            run_experiment(algo, jnp.zeros(D), quad_data, quad_data,
                           cohort=bad, **kw)
    with pytest.raises(ValueError, match="cohort"):
        run_sweep(algo, [{}], (0,), jnp.zeros(D), quad_data, quad_data,
                  cohort=N + 1, **kw)


def test_cohort_system_runs_at_cohort_width(quad_data):
    """The simulator prices exactly the materialized (M, C) slab."""
    from repro.system import get_profile

    res = run_experiment(_algos()["permfl"], jnp.zeros(D), quad_data,
                         quad_data, metric_fn=neg_loss, rounds=3, m=M, n=N,
                         seed=8, cohort=COHORT,
                         system=get_profile("wan-cellular"))
    assert len(res.timeline) == 3
    assert all(t > 0 for t in res.timeline.round_seconds)


# ---------------------------------------------------------------------------
# sweep lanes
# ---------------------------------------------------------------------------

def test_sweep_cohort_lane_matches_solo_run(quad_data):
    """One vmapped sweep lane at cohort < n reproduces the solo scanned
    run — same PRNG chain, same gather/scatter, one dispatch."""
    algo = _algos()["permfl"]
    kw = dict(metric_fn=neg_loss, rounds=4, m=M, n=N, cohort=COHORT)
    solo = run_experiment(algo, jnp.zeros(D), quad_data, quad_data,
                          seed=0, **kw)
    sw = run_sweep(algo, [{}, dict(lam=0.3)], (0,), jnp.zeros(D),
                   quad_data, quad_data, **kw)
    assert sw.dispatches == 1 and len(sw) == 2
    np.testing.assert_allclose(sw[0].pm_acc, solo.pm_acc, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(sw[0].cohort_indices),
                                  np.asarray(solo.cohort_indices))
    assert sw[0].participation == solo.participation
    for a, b in zip(jax.tree.leaves(sw[0].state),
                    jax.tree.leaves(solo.state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    # the perturbed lane actually diverges (the sweep swept something)
    assert sw[1].pm_acc != sw[0].pm_acc


def test_multi_sweep_mixes_cohort_and_stacked_members(quad_data):
    """run_multi_sweep members choose virtualization independently; each
    member must reproduce its solo sweep."""
    algo = _algos()["permfl"]
    kw = dict(metric_fn=neg_loss, rounds=3, m=M, n=N)
    multi = run_multi_sweep(
        [dict(algo=algo, params0=jnp.zeros(D), cohort=COHORT),
         dict(algo=algo, params0=jnp.zeros(D))],
        quad_data, quad_data, **kw)
    solo_c = run_sweep(algo, [{}], (0,), jnp.zeros(D), quad_data,
                       quad_data, cohort=COHORT, **kw)
    solo_s = run_sweep(algo, [{}], (0,), jnp.zeros(D), quad_data,
                       quad_data, **kw)
    np.testing.assert_allclose(multi[0][0].pm_acc, solo_c[0].pm_acc,
                               atol=1e-5)
    np.testing.assert_allclose(multi[1][0].pm_acc, solo_s[0].pm_acc,
                               atol=1e-5)
    assert multi[0][0].cohort == COHORT and multi[1][0].cohort is None
    np.testing.assert_array_equal(np.asarray(multi[0][0].cohort_indices),
                                  np.asarray(solo_c[0].cohort_indices))


# ---------------------------------------------------------------------------
# scenario + events surface
# ---------------------------------------------------------------------------

def test_cohort_scenario_spec_roundtrip_and_clamp():
    from repro.scenarios import get_scenario
    from repro.scenarios.spec import FLScenario

    s = get_scenario("cohort/virtual/n1000")
    assert s.cohort_size == 64 and s.family == "cohort"
    assert FLScenario.from_dict(s.to_dict()) == s
    # legacy specs serialize without the key (spec_hash byte-stability)
    assert "cohort_size" not in get_scenario(
        "table1/mnist/mclr/permfl").to_dict()
    sm = s.scaled(n_devices=3)
    assert sm.cohort_size == 3                # clamped to the population
    with pytest.raises(ValueError, match="cohort_size"):
        dataclasses.replace(s, cohort_size=s.data.n_devices + 1)


def test_run_events_carry_cohort_identity(quad_data):
    """The JSONL schema records cohort/population in the header and the
    per-eval cohort index slices."""
    from repro.obs.events import run_events

    res = run_experiment(_algos()["permfl"], jnp.zeros(D), quad_data,
                         quad_data, metric_fn=neg_loss, rounds=4, m=M, n=N,
                         seed=1, cohort=COHORT, eval_every=2)
    events = run_events(res, run_id="t")
    header = events[0]
    assert header["cohort"] == COHORT and header["population"] == N
    evals = [e for e in events if e["event"] == "eval"]
    assert [len(e["cohort_indices"]) for e in evals] == [2, 2]
    flat = [idx for e in evals for idx in e["cohort_indices"]]
    np.testing.assert_array_equal(np.asarray(flat),
                                  np.asarray(res.cohort_indices))
