"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see the 1 real CPU
device; only launch/dryrun.py (its own process) forces 512 host devices."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def small_fed_data():
    """4 teams x 3 devices of label-skewed synthetic-MNIST, tiny."""
    from repro.data.federated import partition_label_skew
    from repro.data.synthetic import make_dataset

    rng = np.random.default_rng(7)
    x, y = make_dataset("mnist", rng, n_per_class=60)
    return partition_label_skew(rng, x, y, m_teams=4, n_devices=3,
                                samples_per_device=32)


@pytest.fixture(scope="session")
def tabular_fed_data():
    from repro.data.federated import partition_tabular
    from repro.data.synthetic import synthetic_tabular

    rng = np.random.default_rng(11)
    devices = synthetic_tabular(rng, 12, min_samples=40, max_samples=80)
    return partition_tabular(devices, m_teams=4, n_devices=3,
                             samples_per_device=32)
