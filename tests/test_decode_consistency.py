"""Serving correctness: prefill + single-token decode must reproduce the
full-sequence forward logits (teacher forcing) for every cache family —
KV (dense/GQA/SWA), SSM (mamba), RWKV state, and enc-dec cross-attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import model as M

CASES = ["phi3-mini-3.8b", "qwen3-14b", "rwkv6-7b", "jamba-1.5-large-398b",
         "whisper-small", "deepseek-moe-16b"]


def _batch_for(cfg, b, s, key):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.is_encoder_decoder:
        batch["enc_frames"] = jax.random.normal(
            key, (b, cfg.encoder_seq_len, cfg.d_model)) * 0.2
    return batch


@pytest.mark.parametrize("arch", CASES)
def test_prefill_then_decode_matches_forward(arch):
    cfg = get_reduced_config(arch)
    if cfg.moe.num_experts:
        # capacity-based token dropping depends on batch composition, so a
        # (s)-token forward and an (s-1)-prefill legitimately drop different
        # tokens; run the cache-correctness check dropless (cap = gs*k).
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    b, s = 2, 12
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    batch = _batch_for(cfg, b, s, key)

    # ground truth: full forward over all s tokens
    full_logits, _ = M.forward(params, cfg, {**batch,
                                             "targets": batch["tokens"]})

    # prefill on the first s-1 tokens, then decode token s-1
    cache = M.init_cache(cfg, b, s, dtype=jnp.float32)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :-1]
    pre_logits, cache = M.prefill(params, cfg, pre, cache, last_only=True)
    np.testing.assert_allclose(
        np.asarray(pre_logits[:, 0]), np.asarray(full_logits[:, -2]),
        atol=2e-4, rtol=2e-4)

    dec = {"tokens": batch["tokens"][:, -1:]}
    dec_logits, _ = M.decode_step(params, cfg, cache, dec,
                                  jnp.int32(s - 1))
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0]), np.asarray(full_logits[:, -1]),
        atol=2e-4, rtol=2e-4)


def test_vlm_prefill_decode():
    """qwen2-vl: prefill consumes patch embeddings (frontend stub), decode
    consumes tokens; check shapes + finiteness and cache advance."""
    cfg = get_reduced_config("qwen2-vl-2b")
    b, s = 2, 10
    key = jax.random.PRNGKey(1)
    params = M.init_params(key, cfg)
    batch = {
        "embeds": jax.random.normal(key, (b, s, cfg.d_model)) * 0.2,
        "mrope_positions": jnp.tile(
            jnp.arange(s, dtype=jnp.int32)[None, :, None], (b, 1, 3)),
    }
    cache = M.init_cache(cfg, b, s + 4, dtype=jnp.float32)
    logits, cache = M.prefill(params, cfg, batch, cache, last_only=True)
    assert logits.shape == (b, 1, cfg.vocab_size)
    dec = {"tokens": jnp.zeros((b, 1), jnp.int32),
           "mrope_positions": jnp.full((b, 1, 3), s, jnp.int32)}
    logits2, cache = M.decode_step(params, cfg, cache, dec, jnp.int32(s))
    assert logits2.shape == (b, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2)).all()


def test_multi_step_decode_matches_forward():
    """Roll 4 decode steps and compare each against the full forward."""
    cfg = get_reduced_config("phi3-mini-3.8b")
    b, s, tail = 1, 16, 4
    key = jax.random.PRNGKey(2)
    params = M.init_params(key, cfg)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    full_logits, _ = M.forward(params, cfg, {"tokens": toks,
                                             "targets": toks})
    cache = M.init_cache(cfg, b, s, dtype=jnp.float32)
    pre = {"tokens": toks[:, :s - tail]}
    _, cache = M.prefill(params, cfg, pre, cache, last_only=True)
    for i in range(tail):
        pos = s - tail + i
        logits, cache = M.decode_step(params, cfg, cache,
                                      {"tokens": toks[:, pos:pos + 1]},
                                      jnp.int32(pos))
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, pos]),
            atol=2e-4, rtol=2e-4)


def test_sliding_window_decode_matches_full():
    """SWA: with window w, decode at pos >= w must match a full forward of
    the SWA model (the dense long_500k policy path)."""
    cfg = get_reduced_config("yi-34b").replace(sliding_window=8)
    b, s = 1, 20
    key = jax.random.PRNGKey(3)
    params = M.init_params(key, cfg)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    full_logits, _ = M.forward(params, cfg, {"tokens": toks,
                                             "targets": toks})
    cache = M.init_cache(cfg, b, s, dtype=jnp.float32)
    _, cache = M.prefill(params, cfg, {"tokens": toks[:, :-1]}, cache,
                         last_only=True)
    logits, _ = M.decode_step(params, cfg, cache,
                              {"tokens": toks[:, -1:]}, jnp.int32(s - 1))
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               atol=2e-4, rtol=2e-4)
