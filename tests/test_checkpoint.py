"""Checkpoint format: key-path matched restore, metadata, error paths."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import (CheckpointKeyError,
                                    load_checkpoint_arrays,
                                    restore_checkpoint, save_checkpoint)


def _nested_tree():
    return {"enc": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                    "b": jnp.full((4,), -2.5, jnp.float64)},
            "head": [jnp.arange(5, dtype=jnp.int32),
                     {"scale": jnp.asarray(3.0, jnp.bfloat16)}],
            "step": jnp.asarray(7, jnp.int64)}


def test_roundtrip_identity_dtype_shape_value(tmp_path):
    tree = _nested_tree()
    path = str(tmp_path / "ckpt.zip")
    save_checkpoint(path, tree, metadata={"note": "nested"})
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, _ = restore_checkpoint(path, like)
    assert jax.tree.structure(restored) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        assert np.asarray(a).shape == np.asarray(b).shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_metadata_roundtrip(tmp_path):
    path = str(tmp_path / "ckpt.zip")
    meta = {"step": 42, "cfg": {"name": "mclr", "lr": 0.03},
            "tags": ["a", "b"]}
    save_checkpoint(path, {"w": jnp.zeros(3)}, metadata=meta)
    _, got = restore_checkpoint(path, {"w": jnp.zeros(3)})
    assert got == meta
    _, got2 = load_checkpoint_arrays(path)
    assert got2 == meta


def test_restore_ignores_leaf_order(tmp_path):
    # restore matches by key path, not position: a template whose dict
    # insertion order differs must still land every array in its slot
    path = str(tmp_path / "ckpt.zip")
    save_checkpoint(path, {"a": jnp.ones(2), "b": jnp.full(3, 2.0)})
    like = {"b": jnp.zeros(3), "a": jnp.zeros(2)}
    restored, _ = restore_checkpoint(path, like)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.ones(2))
    np.testing.assert_array_equal(np.asarray(restored["b"]),
                                  np.full(3, 2.0))


def test_missing_and_extra_keys_raise_with_paths(tmp_path):
    path = str(tmp_path / "ckpt.zip")
    save_checkpoint(path, {"enc": {"w": jnp.zeros(2)}, "old": jnp.zeros(1)})
    with pytest.raises(CheckpointKeyError) as ei:
        restore_checkpoint(path, {"enc": {"w": jnp.zeros(2)},
                                  "new": jnp.zeros(1)})
    msg = str(ei.value)
    assert "new" in msg and "old" in msg


def test_load_checkpoint_arrays_flat_view(tmp_path):
    path = str(tmp_path / "ckpt.zip")
    tree = {"enc": {"w": jnp.arange(4.0)}, "b": jnp.ones(2)}
    save_checkpoint(path, tree)
    arrays, _ = load_checkpoint_arrays(path)
    assert set(arrays) == {"enc/w", "b"}
    np.testing.assert_array_equal(arrays["enc/w"], np.arange(4.0))
