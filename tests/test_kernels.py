"""Per-kernel correctness: Pallas body (interpret=True on CPU) vs the
pure-jnp oracle in ref.py, swept over shapes and dtypes."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

os.environ.setdefault("FORCE_PALLAS_INTERPRET", "0")  # per-test control


def _interp(monkeypatch):
    monkeypatch.setenv("FORCE_PALLAS_INTERPRET", "1")


# ---------------------------------------------------------------------------
# prox_update — fused PerMFL device step (eq. 4)
# ---------------------------------------------------------------------------

PROX_SHAPES = [(128,), (1024,), (257,), (8, 128), (3, 5, 64), (4096,)]


@pytest.mark.parametrize("shape", PROX_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("momentum,wd", [(0.0, 0.0), (0.9, 0.0), (0.9, 0.01)])
def test_prox_sgd_matches_ref(monkeypatch, shape, dtype, momentum, wd):
    _interp(monkeypatch)
    from repro.kernels.prox_update.ops import prox_sgd
    from repro.kernels.prox_update.ref import prox_sgd_ref

    key = jax.random.PRNGKey(hash((shape, str(dtype))) % 2**31)
    ks = jax.random.split(key, 4)
    theta = jax.random.normal(ks[0], shape).astype(dtype)
    grad = jax.random.normal(ks[1], shape).astype(dtype)
    anchor = jax.random.normal(ks[2], shape).astype(dtype)
    mom = jax.random.normal(ks[3], shape).astype(jnp.float32)

    t_k, m_k = prox_sgd(theta, grad, anchor, mom, alpha=0.05, lam=0.7,
                        momentum=momentum, weight_decay=wd)
    t_r, m_r = prox_sgd_ref(theta, grad, anchor, mom_buf=mom, alpha=0.05,
                            lam=0.7, momentum=momentum, weight_decay=wd)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(t_k, np.float32),
                               np.asarray(t_r, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(m_k), np.asarray(m_r),
                               atol=tol, rtol=tol)


def test_prox_sgd_formula(monkeypatch):
    """theta' = theta - alpha*g - alpha*lam*(theta - w), momentum=0."""
    _interp(monkeypatch)
    from repro.kernels.prox_update.ops import prox_sgd

    k = jax.random.PRNGKey(0)
    theta, grad, anchor = (jax.random.normal(kk, (513,))
                           for kk in jax.random.split(k, 3))
    alpha, lam = 0.03, 1.5
    t_new, _ = prox_sgd(theta, grad, anchor, alpha=alpha, lam=lam)
    expect = theta - alpha * grad - alpha * lam * (theta - anchor)
    np.testing.assert_allclose(np.asarray(t_new), np.asarray(expect),
                               atol=1e-6)


def test_prox_sgd_tree_pytree(monkeypatch):
    _interp(monkeypatch)
    from repro.kernels.prox_update.ops import prox_sgd_tree

    k = jax.random.PRNGKey(1)
    mk = lambda kk: {"a": jax.random.normal(kk, (65, 3)),
                     "b": [jax.random.normal(kk, (7,))]}
    theta, grad, anchor = mk(k), mk(jax.random.split(k)[0]), mk(k)
    t_new, m_new = prox_sgd_tree(theta, grad, anchor, alpha=0.1, lam=0.5)
    assert jax.tree.structure(t_new) == jax.tree.structure(theta)
    assert jax.tree.structure(m_new) == jax.tree.structure(theta)
    for leaf in jax.tree.leaves(t_new):
        assert np.isfinite(np.asarray(leaf)).all()


# ---------------------------------------------------------------------------
# quantize — fused int8 stochastic quantize/pack (comm uplink)
# ---------------------------------------------------------------------------

QUANT_SHAPES = [(128,), (1024,), (257,), (8, 128), (3, 5, 64), (4096,)]


@pytest.mark.parametrize("shape", QUANT_SHAPES)
def test_quantize_int8_pallas_matches_ref(monkeypatch, shape):
    _interp(monkeypatch)
    from repro.kernels.quantize.ops import quantize_int8
    from repro.kernels.quantize.ref import quantize_int8_ref

    key = jax.random.PRNGKey(hash(shape) % 2**31)
    v = jax.random.normal(key, shape) * 2.5
    noise = jax.random.uniform(jax.random.fold_in(key, 1), shape)
    q_k, s_k, dq_k = quantize_int8(v, noise)
    q_r, s_r, dq_r = quantize_int8_ref(v.reshape(-1), noise.reshape(-1))
    # same explicit noise -> bit-identical across backends
    np.testing.assert_array_equal(np.asarray(q_k).reshape(-1),
                                  np.asarray(q_r))
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_r))
    np.testing.assert_array_equal(np.asarray(dq_k).reshape(-1),
                                  np.asarray(dq_r))


def test_quantize_int8_error_bound():
    """Stochastic rounding error < 1 step = rowmax/127, per element."""
    from repro.kernels.quantize.ref import quantize_int8_ref

    key = jax.random.PRNGKey(11)
    v = jax.random.normal(key, (5000,)) * 4.0
    noise = jax.random.uniform(jax.random.fold_in(key, 1), (5000,))
    _, scales, dq = quantize_int8_ref(v, noise)
    step = np.repeat(np.asarray(scales), 128)[:5000]
    assert (np.abs(np.asarray(dq) - np.asarray(v)) <= step + 1e-7).all()


def test_quantize_int8_unbiased():
    """E[dq] = v over the rounding noise."""
    from repro.kernels.quantize.ref import quantize_int8_ref

    v = jax.random.normal(jax.random.PRNGKey(12), (256,))
    keys = jax.random.split(jax.random.PRNGKey(13), 500)
    dqs = jax.vmap(lambda k: quantize_int8_ref(
        v, jax.random.uniform(k, (256,)))[2])(keys)
    err = np.abs(np.asarray(dqs.mean(0)) - np.asarray(v)).max()
    # step ~ 3/127 ~ 0.024; 500 draws shrink the mean error well below it
    assert err < 5e-3, err


def test_quantize_int8_roundtrip_pack_unpack():
    from repro.kernels.quantize.ref import (dequantize_int8_ref,
                                            quantize_int8_ref)

    v = jax.random.normal(jax.random.PRNGKey(14), (777,))
    noise = jax.random.uniform(jax.random.PRNGKey(15), (777,))
    q, s, dq = quantize_int8_ref(v, noise)
    assert q.dtype == jnp.int8 and s.shape == (-(-777 // 128),)
    np.testing.assert_array_equal(np.asarray(dequantize_int8_ref(q, s)),
                                  np.asarray(dq))


# ---------------------------------------------------------------------------
# flash_attention — causal / sliding-window GQA
# ---------------------------------------------------------------------------

def _naive_attention(q, k, v, causal=True, window=0, q_offset=None):
    """Dense O(s^2) oracle for the oracle (independent of ref.py blocking)."""
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    rep = hq // hkv
    k = np.repeat(np.asarray(k, np.float64), rep, axis=2)
    v = np.repeat(np.asarray(v, np.float64), rep, axis=2)
    q = np.asarray(q, np.float64) * d ** -0.5
    if q_offset is None:
        q_offset = skv - sq
    s = np.einsum("bqhd,bkhd->bhqk", q, k)
    q_pos = np.arange(sq) + q_offset
    kv_pos = np.arange(skv)
    mask = np.ones((sq, skv), bool)
    if causal:
        mask &= kv_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= kv_pos[None, :] > q_pos[:, None] - window
    s = np.where(mask[None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = np.where(mask[None, None], p, 0.0)
    p /= np.maximum(p.sum(-1, keepdims=True), 1e-30)
    out = np.einsum("bhqk,bkhd->bqhd", p, v)
    return out.astype(np.float32)


ATTN_CASES = [
    # (b, sq, skv, hq, hkv, d, causal, window)
    (1, 128, 128, 4, 4, 64, True, 0),
    (2, 128, 128, 4, 1, 64, True, 0),       # GQA
    (1, 256, 256, 2, 2, 64, True, 64),      # sliding window
    (1, 64, 64, 4, 2, 32, False, 0),        # non-causal (encoder)
    (2, 1, 96, 4, 2, 64, True, 0),          # decode: 1 query vs cache
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_attention_ref_matches_naive(case, dtype):
    b, sq, skv, hq, hkv, d, causal, window = case
    from repro.kernels.flash_attention.ref import attention_ref

    key = jax.random.PRNGKey(3)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, sq, hq, d)).astype(dtype)
    k = jax.random.normal(kk, (b, skv, hkv, d)).astype(dtype)
    v = jax.random.normal(kv, (b, skv, hkv, d)).astype(dtype)
    out = attention_ref(q, k, v, causal=causal, window=window, kv_chunk=32)
    want = _naive_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), causal, window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), want,
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("case", ATTN_CASES[:3])
def test_attention_pallas_matches_ref(monkeypatch, case):
    _interp(monkeypatch)
    b, sq, skv, hq, hkv, d, causal, window = case
    from repro.kernels.flash_attention.ops import attention
    from repro.kernels.flash_attention.ref import attention_ref

    key = jax.random.PRNGKey(4)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, sq, hq, d))
    k = jax.random.normal(kk, (b, skv, hkv, d))
    v = jax.random.normal(kv, (b, skv, hkv, d))
    out = attention(q, k, v, causal=causal, window=window,
                    block_q=64, block_kv=64)
    want = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_attention_decode_offset():
    """q_offset places the single query at the end of the cache."""
    from repro.kernels.flash_attention.ref import attention_ref

    key = jax.random.PRNGKey(5)
    kq, kk, kv = jax.random.split(key, 3)
    s = 48
    q_full = jax.random.normal(kq, (1, s, 2, 32))
    k_full = jax.random.normal(kk, (1, s, 2, 32))
    v_full = jax.random.normal(kv, (1, s, 2, 32))
    full = attention_ref(q_full, k_full, v_full, causal=True)
    one = attention_ref(q_full[:, -1:], k_full, v_full, causal=True,
                        q_offset=s - 1)
    np.testing.assert_allclose(np.asarray(one[:, 0]), np.asarray(full[:, -1]),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# rwkv6_scan — WKV recurrence with data-dependent decay
# ---------------------------------------------------------------------------

def _naive_wkv6(r, k, v, w, u, state=None):
    b, t, h, n = r.shape
    r, k, v, w = (np.asarray(x, np.float64) for x in (r, k, v, w))
    u = np.asarray(u, np.float64)
    S = np.zeros((b, h, n, n)) if state is None else np.asarray(state, np.float64)
    out = np.zeros((b, t, h, n))
    for bi in range(b):
        for hi in range(h):
            Sl = S[bi, hi].copy()
            for ti in range(t):
                kv = np.outer(k[bi, ti, hi], v[bi, ti, hi])
                out[bi, ti, hi] = r[bi, ti, hi] @ (Sl + u[hi][:, None] * kv)
                Sl = w[bi, ti, hi][:, None] * Sl + kv
            S[bi, hi] = Sl
    return out.astype(np.float32), S.astype(np.float32)


@pytest.mark.parametrize("b,t,h,n", [(1, 16, 1, 8), (2, 33, 2, 16),
                                     (1, 130, 1, 8)])
def test_wkv6_ref_matches_naive(b, t, h, n):
    from repro.kernels.rwkv6_scan.ref import wkv6_ref

    key = jax.random.PRNGKey(6)
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (b, t, h, n)) * 0.3
    k = jax.random.normal(ks[1], (b, t, h, n)) * 0.3
    v = jax.random.normal(ks[2], (b, t, h, n)) * 0.3
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, n)))  # decay in (0,1)
    u = jax.random.normal(ks[4], (h, n)) * 0.1
    out, S = wkv6_ref(r, k, v, w, u)
    want_o, want_S = _naive_wkv6(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), want_o, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(S), want_S, atol=1e-4, rtol=1e-4)


def test_wkv6_pallas_matches_ref(monkeypatch):
    _interp(monkeypatch)
    from repro.kernels.rwkv6_scan.ops import wkv
    from repro.kernels.rwkv6_scan.ref import wkv6_ref

    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 5)
    b, t, h, n = 1, 64, 2, 16
    r = jax.random.normal(ks[0], (b, t, h, n)) * 0.3
    k = jax.random.normal(ks[1], (b, t, h, n)) * 0.3
    v = jax.random.normal(ks[2], (b, t, h, n)) * 0.3
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, n)))
    u = jax.random.normal(ks[4], (h, n)) * 0.1
    out_k, S_k = wkv(r, k, v, w, u, chunk=16)
    out_r, S_r = wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(S_k), np.asarray(S_r),
                               atol=1e-4, rtol=1e-4)


def test_wkv6_state_carry():
    """Splitting a sequence in two and carrying state == one long scan."""
    from repro.kernels.rwkv6_scan.ref import wkv6_ref

    key = jax.random.PRNGKey(8)
    ks = jax.random.split(key, 5)
    b, t, h, n = 1, 40, 1, 8
    r = jax.random.normal(ks[0], (b, t, h, n)) * 0.3
    k = jax.random.normal(ks[1], (b, t, h, n)) * 0.3
    v = jax.random.normal(ks[2], (b, t, h, n)) * 0.3
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, n)))
    u = jax.random.normal(ks[4], (h, n)) * 0.1
    full, S_full = wkv6_ref(r, k, v, w, u)
    h1, S1 = wkv6_ref(r[:, :17], k[:, :17], v[:, :17], w[:, :17], u)
    h2, S2 = wkv6_ref(r[:, 17:], k[:, 17:], v[:, 17:], w[:, 17:], u, state=S1)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(full[:, 17:]),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(S2), np.asarray(S_full),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# moe_router — fused top-k gating
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,e,k", [(64, 8, 2), (128, 64, 6), (37, 16, 4)])
def test_route_topk_properties(t, e, k):
    from repro.kernels.moe_router.ops import route_topk

    logits = jax.random.normal(jax.random.PRNGKey(9), (t, e))
    gates, idx, aux = route_topk(logits, top_k=k)
    assert gates.shape == (t, k) and idx.shape == (t, k)
    g = np.asarray(gates)
    np.testing.assert_allclose(g.sum(-1), 1.0, atol=1e-5)  # renormalized
    assert (g >= 0).all()
    i = np.asarray(idx)
    assert ((i >= 0) & (i < e)).all()
    # top-k indices must be the true argmax set
    want = np.argsort(-np.asarray(logits), axis=-1)[:, :k]
    assert (np.sort(i, -1) == np.sort(want, -1)).all()


def test_route_topk_pallas_matches_ref(monkeypatch):
    _interp(monkeypatch)
    from repro.kernels.moe_router.ops import route_topk

    logits = jax.random.normal(jax.random.PRNGKey(10), (64, 16))
    g_k, i_k, _ = route_topk(logits, top_k=4)
    monkeypatch.setenv("FORCE_PALLAS_INTERPRET", "0")
    g_r, i_r, _ = route_topk(logits, top_k=4)
    # compare as (index -> gate) maps (order of equal gates may differ)
    gk = np.zeros((64, 16)); gr = np.zeros((64, 16))
    np.put_along_axis(gk, np.asarray(i_k), np.asarray(g_k), -1)
    np.put_along_axis(gr, np.asarray(i_r), np.asarray(g_r), -1)
    np.testing.assert_allclose(gk, gr, atol=1e-5)


def test_load_balance_loss_uniform_is_one():
    """Perfectly uniform routing gives aux loss ~= 1 (E * sum f*p)."""
    from repro.kernels.moe_router.ref import load_balance_loss, route_ref

    t, e = 512, 8
    logits = jnp.zeros((t, e))
    _, _, _, aux = route_ref(logits, top_k=2)
    lb = load_balance_loss(aux, e)
    np.testing.assert_allclose(float(lb), 1.0, rtol=0.05)


def test_load_balance_loss_skewed_is_large():
    """All tokens to one expert -> loss ~ E (worst case)."""
    from repro.kernels.moe_router.ref import load_balance_loss, route_ref

    t, e = 256, 8
    logits = jnp.zeros((t, e)).at[:, 0].set(20.0)
    _, _, _, aux = route_ref(logits, top_k=1)
    lb = load_balance_loss(aux, e)
    assert float(lb) > 4.0
