"""Hypothesis property tests pinning the ledger's static wire-byte model
(`compressed_leaf_bytes`) to the *actual* packed array sizes each of the
five compressors would put on the wire, across leaf shapes. The system
simulator (`repro.system`) prices links with these numbers, so a
drifting model silently corrupts both the byte and the time axes."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.comm import CommConfig, compressed_leaf_bytes, leaf_k

SET = dict(max_examples=25, deadline=None)
k_fracs = st.sampled_from([0.01, 0.1, 0.25, 0.5, 1.0])
leaf_sizes = st.integers(min_value=1, max_value=5000)


def _vec(p):
    return np.random.default_rng(p).normal(size=(p,)).astype(np.float32)


@settings(**SET)
@given(p=leaf_sizes)
def test_identity_bytes_are_fp32(p):
    assert compressed_leaf_bytes(CommConfig("identity"), p) == _vec(p).nbytes


@settings(**SET)
@given(p=leaf_sizes, k_frac=k_fracs)
def test_topk_bytes_are_values_plus_indices(p, k_frac):
    v, k = _vec(p), leaf_k(k_frac, p)
    idx = np.argsort(-np.abs(v))[:k].astype(np.int32)
    packed = v[idx].nbytes + idx.nbytes           # 4B value + 4B index
    assert compressed_leaf_bytes(
        CommConfig("topk", k_frac=k_frac), p) == packed


@settings(**SET)
@given(p=leaf_sizes, k_frac=k_fracs)
def test_randk_bytes_are_values_plus_seed(p, k_frac):
    v, k = _vec(p), leaf_k(k_frac, p)
    # the receiver reconstructs the indices from a shared 4-byte seed
    packed = v[:k].nbytes + np.uint32(0).nbytes
    assert compressed_leaf_bytes(
        CommConfig("randk", k_frac=k_frac), p) == packed


@settings(**SET)
@given(p=leaf_sizes)
def test_int8_bytes_match_quantize_kernel_output(p):
    """int8 is the one path whose wire format is materialized for real:
    the model must equal the packed (q, scales) the kernel returns."""
    from repro.kernels.quantize import quantize_int8
    v = _vec(p)
    q, scales, _ = quantize_int8(jnp.asarray(v),
                                 jnp.asarray(np.zeros_like(v)))
    assert q.dtype == jnp.int8 and scales.dtype == jnp.float32
    packed = q.size * q.dtype.itemsize + scales.size * scales.dtype.itemsize
    assert compressed_leaf_bytes(CommConfig("int8"), p) == packed


@settings(**SET)
@given(p=leaf_sizes)
def test_sign_bytes_are_bitpacked_plus_scale(p):
    v = _vec(p)
    packed = np.packbits(v > 0).nbytes + np.float32(0).nbytes
    assert compressed_leaf_bytes(CommConfig("sign"), p) == packed
