"""Training substrate: optimizers, TrainState, central trainer loop,
tier-mode PerMFL step, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import optim
from repro.train.checkpoint import (CheckpointKeyError, restore_checkpoint,
                                    save_checkpoint)
from repro.train.train_state import TrainState


def quad(params, target):
    return 0.5 * jnp.sum((params["w"] - target) ** 2)


@pytest.mark.parametrize("make_opt", [optim.sgd, optim.momentum, optim.adamw])
def test_optimizers_minimize_quadratic(make_opt):
    opt = make_opt()
    target = jnp.arange(4.0)
    params = {"w": jnp.zeros(4)}
    state = TrainState.create(params, opt)
    g = jax.grad(quad)
    for _ in range(300):
        state = state.apply_gradients(g(state.params, target), opt, 0.05)
    np.testing.assert_allclose(np.asarray(state.params["w"]),
                               np.asarray(target), atol=1e-2)
    assert int(state.step) == 300


def test_adamw_weight_decay_shrinks():
    opt = optim.adamw(weight_decay=0.5)
    params = {"w": jnp.full((4,), 10.0)}
    state = TrainState.create(params, opt)
    zero_g = {"w": jnp.zeros(4)}
    for _ in range(50):
        state = state.apply_gradients(zero_g, opt, 0.1)
    assert float(jnp.abs(state.params["w"]).max()) < 10.0


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 10.0), "b": jnp.full((4,), -10.0)}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    new_norm = optim.global_norm(clipped)
    np.testing.assert_allclose(float(new_norm), 1.0, rtol=1e-5)
    # below threshold: unchanged
    clipped2, _ = optim.clip_by_global_norm(g, 1e9)
    np.testing.assert_allclose(np.asarray(clipped2["a"]), np.asarray(g["a"]))


def test_train_loop_lm_loss_decreases():
    from repro.configs import get_reduced_config
    from repro.data.tokens import lm_batches
    from repro.train.trainer import train_loop

    cfg = get_reduced_config("phi3-mini-3.8b").replace(vocab_size=128)
    batches = lm_batches(np.random.default_rng(0), 128, batch=4, seq_len=32,
                         steps=30)
    state, history = train_loop(cfg, batches, opt=optim.adamw(), lr=3e-3,
                                steps=30, log_every=5)
    first, last = history[0][1], history[-1][1]
    assert last < first - 0.2, history


def test_tier_round_runs_and_couples():
    """make_tier_round: x/w/theta move, loss finite, pull structure holds."""
    from repro.configs import get_reduced_config
    from repro.train.trainer import make_tier_round
    from repro.models import model as M

    cfg = get_reduced_config("phi3-mini-3.8b").replace(vocab_size=64)
    key = jax.random.PRNGKey(0)
    theta = M.init_params(key, cfg)
    w = jax.tree.map(jnp.copy, theta)
    x = jax.tree.map(jnp.copy, theta)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, 64),
             "targets": jax.random.randint(key, (2, 16), 0, 64)}
    rf = jax.jit(make_tier_round(cfg, alpha=0.01, lam=0.5, gamma=1.5,
                                 eta=0.03, beta=0.3, l_local=2))
    theta2, w2, x2, metrics = rf(theta, w, x, batch)
    assert np.isfinite(float(metrics["loss"]))
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), theta, theta2)
    assert max(jax.tree.leaves(moved)) > 0.0


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32),
                  "d": [jnp.zeros(2), jnp.full((1,), 7.0)]}}
    path = str(tmp_path / "ckpt.zip")
    save_checkpoint(path, tree, metadata={"step": 12, "arch": "test"})
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, meta = restore_checkpoint(path, like)
    assert meta == {"step": 12, "arch": "test"}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_key_mismatch_raises(tmp_path):
    path = str(tmp_path / "c.zip")
    save_checkpoint(path, {"a": jnp.zeros(2)})
    with pytest.raises(CheckpointKeyError):
        restore_checkpoint(path, {"b": jnp.zeros(2)})


def test_checkpoint_trainstate(tmp_path):
    opt = optim.adamw()
    state = TrainState.create({"w": jnp.arange(3.0)}, opt)
    state = state.apply_gradients({"w": jnp.ones(3)}, opt, 0.1)
    path = str(tmp_path / "ts.zip")
    save_checkpoint(path, state)
    like = TrainState.create({"w": jnp.zeros(3)}, opt)
    restored, _ = restore_checkpoint(path, like)
    np.testing.assert_allclose(np.asarray(restored.params["w"]),
                               np.asarray(state.params["w"]))
    assert int(restored.step) == 1
