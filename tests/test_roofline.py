"""Roofline extraction: HLO collective parser + three-term model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import (_shape_bytes, analyze,
                                     parse_collectives)


def test_shape_bytes():
    assert _shape_bytes("bf16[16,1024]") == 16 * 1024 * 2
    assert _shape_bytes("f32[8]") == 32
    assert _shape_bytes("(bf16[4,4], f32[2])") == 32 + 8
    assert _shape_bytes("u8[100]") == 100
    assert _shape_bytes("pred[7]") == 7


def test_parse_collectives_synthetic_hlo():
    hlo = """
HloModule test

%fused (a: f32[4]) -> f32[4] {
  ROOT %x = f32[4] add(f32[4] %a, f32[4] %a)
}

ENTRY %main (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256] parameter(0)
  %ag = f32[256,256] all-gather(f32[128,256] %p0), replica_groups={}
  %ar = f32[128,256] all-reduce(f32[128,256] %p0), to_apply=%add
  %rs = f32[64,256] reduce-scatter(f32[128,256] %p0), to_apply=%add
  ROOT %out = f32[128,256] add(%p0, %p0)
}
"""
    stats = parse_collectives(hlo)
    assert stats.bytes_by_kind["all-gather"] == 256 * 256 * 4
    assert stats.bytes_by_kind["all-reduce"] == 128 * 256 * 4
    assert stats.bytes_by_kind["reduce-scatter"] == 64 * 256 * 4
    assert stats.count_by_kind["all-gather"] == 1
    assert stats.total_bytes == (256 * 256 + 128 * 256 + 64 * 256) * 4


def test_parse_collectives_trip_count_weighting():
    hlo = """
HloModule loops

%body ( p: (s32[], f32[64]) ) -> (s32[], f32[64]) {
  %ar = f32[64] all-reduce(f32[64] %x), to_apply=%add
  ROOT %t = (s32[], f32[64]) tuple(%i, %ar)
}

ENTRY %main () -> f32[64] {
  %w = (s32[], f32[64]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %r = f32[64] get-tuple-element(%w), index=1
}
"""
    stats = parse_collectives(hlo)
    assert stats.bytes_by_kind["all-reduce"] == 5 * 64 * 4


def test_analyze_compiled_allreduce():
    """End-to-end on a real compiled function with a psum."""
    mesh = jax.make_mesh((1,), ("d",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    def f(x):
        return x @ x.T

    with mesh:
        lowered = jax.jit(f).lower(
            jax.ShapeDtypeStruct((256, 256), jnp.float32))
        compiled = lowered.compile()
    roof = analyze(compiled, chips=1, model_flops=2 * 256 ** 3)
    assert roof.flops > 0
    assert roof.hbm_bytes > 0
    assert roof.compute_s > 0 and roof.memory_s > 0
    assert roof.dominant in ("compute", "memory", "collective")
    assert 0 < roof.useful_ratio <= 2.0


def test_model_flops_helpers():
    from repro.configs import get_config
    from repro.roofline import model_flops_decode, model_flops_train

    cfg = get_config("phi3-mini-3.8b")
    t = 1000
    ftrain = model_flops_train(cfg, t)
    fdec = model_flops_decode(cfg, t)
    assert ftrain == 3 * fdec  # 6ND vs 2ND
    # MoE uses active params
    moe = get_config("dbrx-132b")
    from repro.configs.base import active_param_count, param_count
    assert model_flops_train(moe, t) == 6.0 * active_param_count(moe) * t
    assert model_flops_train(moe, t) < 6.0 * param_count(moe) * t


def test_dryrun_results_complete():
    """The committed sweep artifact must cover all 80 combos with zero
    failures (the multi-pod dry-run deliverable)."""
    import json
    import os

    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "dryrun_all.json")
    if not os.path.exists(path):
        pytest.skip("dry-run sweep artifact not present")
    recs = json.load(open(path))
    assert len(recs) == 80
    bad = [r for r in recs if r["status"] == "FAILED"]
    assert not bad, bad
    skipped = [(r["arch"], r["shape"]) for r in recs
               if r["status"] == "skipped"]
    assert set(skipped) <= {("whisper-small", "long_500k")}, skipped
    for r in recs:
        if r["status"] == "ok":
            assert r["compute_s"] > 0 or r["shape"] != "train_4k"
            assert r["dominant"] in ("compute", "memory", "collective")
