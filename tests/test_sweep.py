"""run_sweep (one vmapped program) vs looped run_experiment: identical
trajectories, final states, participation, and byte ledgers — for PerMFL
with and without comm and for a baseline — plus grid semantics (non-
uniform grids, seeds, per-seed inits, chunking, sharding, validation)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommConfig
from repro.core import PerMFL, baselines as B
from repro.core.permfl import PerMFLHParams
from repro.launch.mesh import make_host_mesh
from repro.sharding.specs import sweep_pspecs
from repro.train.engine import run_experiment
from repro.train.sweep import FLSweepResult, grid_product, run_sweep

M, N, D = 3, 4, 5


def quad_loss(params, batch):
    return 0.5 * jnp.sum((params - batch["c"]) ** 2)


def neg_loss(params, batch):
    return -quad_loss(params, batch)


@pytest.fixture(scope="module")
def quad_data():
    rng = np.random.default_rng(0)
    return {"c": jnp.asarray(rng.normal(size=(M, N, D)).astype(np.float32))}


HP = PerMFLHParams(alpha=0.05, eta=0.04, beta=0.3, lam=0.8, gamma=2.0,
                   k_team=3, l_local=4)

# non-uniform on purpose: different keys set per config
GRID = [dict(lam=0.3), dict(lam=0.9, beta=0.5), dict(gamma=1.0)]


def assert_results_match(sweep_res, looped_res):
    for f in ("pm_acc", "tm_acc", "gm_acc", "train_loss"):
        np.testing.assert_allclose(getattr(sweep_res, f),
                                   getattr(looped_res, f), atol=1e-5)
    assert sweep_res.participation == looped_res.participation
    for a, b in zip(jax.tree.leaves(sweep_res.state),
                    jax.tree.leaves(looped_res.state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_sweep_matches_looped_permfl(quad_data):
    sw = run_sweep(PerMFL(quad_loss, HP), GRID, (0,), jnp.zeros(D),
                   quad_data, quad_data, metric_fn=neg_loss, rounds=5,
                   m=M, n=N)
    assert len(sw) == 3 and sw.dispatches == 1
    for i, g in enumerate(GRID):
        ref = run_experiment(
            PerMFL(quad_loss, dataclasses.replace(HP, **g)), jnp.zeros(D),
            quad_data, quad_data, metric_fn=neg_loss, rounds=5, m=M, n=N)
        assert_results_match(sw[i], ref)
        for k, v in g.items():
            assert sw.configs[i][k] == v
    # the stacked state keeps the (S,) axis
    assert jax.tree.leaves(sw.state_stacked)[0].shape[0] == 3


def test_sweep_matches_looped_permfl_comm_and_participation(quad_data):
    cfg = CommConfig("topk", k_frac=0.4)
    sw = run_sweep(PerMFL(quad_loss, HP, comm=cfg), GRID, (0, 7),
                   jnp.zeros(D), quad_data, quad_data, metric_fn=neg_loss,
                   rounds=4, m=M, n=N, team_frac=0.5)
    assert len(sw) == 6        # grid-major: (g0,s0), (g0,s7), (g1,s0), ...
    i = 0
    for g in GRID:
        for seed in (0, 7):
            ref = run_experiment(
                PerMFL(quad_loss, dataclasses.replace(HP, **g), comm=cfg),
                jnp.zeros(D), quad_data, quad_data, metric_fn=neg_loss,
                rounds=4, m=M, n=N, team_frac=0.5, seed=seed)
            assert sw.configs[i]["seed"] == seed
            assert_results_match(sw[i], ref)
            assert sw[i].comm.total_bytes() == ref.comm.total_bytes()
            assert len(sw[i].comm.rounds) == 4
            np.testing.assert_allclose(
                np.asarray(sw[i].state.comm.ef_team),
                np.asarray(ref.state.comm.ef_team), atol=1e-6)
            i += 1


def test_sweep_matches_looped_baseline(quad_data):
    grid = [dict(lr=0.05), dict(lr=0.1, lam=0.2)]
    algo = B.Ditto(quad_loss, lr=0.05, lam=0.5, local_steps=3)
    sw = run_sweep(algo, grid, (0,), jnp.zeros(D), quad_data, quad_data,
                   metric_fn=neg_loss, rounds=4, m=M, n=N)
    for i, g in enumerate(grid):
        ref = run_experiment(dataclasses.replace(algo, **g), jnp.zeros(D),
                             quad_data, quad_data, metric_fn=neg_loss,
                             rounds=4, m=M, n=N)
        np.testing.assert_allclose(sw[i].pm_acc, ref.pm_acc, atol=1e-5)
        np.testing.assert_allclose(sw[i].gm_acc, ref.gm_acc, atol=1e-5)


def test_sweep_per_seed_init_fn(quad_data):
    """params0 as seed->params callable: each seed trains from its own
    init, matching looped run_experiment with the same params."""
    init_fn = lambda seed: jnp.full((D,), 0.1 * seed, jnp.float32)
    sw = run_sweep(PerMFL(quad_loss, HP), [{}], (0, 2), init_fn, quad_data,
                   quad_data, metric_fn=neg_loss, rounds=3, m=M, n=N)
    for i, seed in enumerate((0, 2)):
        ref = run_experiment(PerMFL(quad_loss, HP), init_fn(seed),
                             quad_data, quad_data, metric_fn=neg_loss,
                             rounds=3, m=M, n=N, seed=seed)
        assert_results_match(sw[i], ref)
    # different inits must actually produce different trajectories
    assert sw[0].pm_acc != sw[1].pm_acc


def test_sweep_eval_every_chunking_and_remainder(quad_data):
    sw = run_sweep(PerMFL(quad_loss, HP), [dict(lam=0.4)], (0,),
                   jnp.zeros(D), quad_data, quad_data, metric_fn=neg_loss,
                   rounds=7, m=M, n=N, eval_every=3)
    assert sw.dispatches == 2      # 2 full chunks + remainder chunk
    assert len(sw[0].pm_acc) == 3  # evals after rounds 3, 6, 7
    assert len(sw[0].participation) == 7
    ref = run_experiment(PerMFL(quad_loss,
                                dataclasses.replace(HP, lam=0.4)),
                         jnp.zeros(D), quad_data, quad_data,
                         metric_fn=neg_loss, rounds=7, m=M, n=N,
                         eval_every=3)
    assert_results_match(sw[0], ref)


def test_sweep_grid_dict_is_product(quad_data):
    sw = run_sweep(PerMFL(quad_loss, HP),
                   {"lam": [0.3, 0.9], "beta": [0.5]}, (0,), jnp.zeros(D),
                   quad_data, quad_data, metric_fn=neg_loss, rounds=2,
                   m=M, n=N)
    assert [c["lam"] for c in sw.configs] == [0.3, 0.9]
    assert all(c["beta"] == 0.5 for c in sw.configs)


def test_grid_product():
    g = grid_product(a=[1, 2], b=[3])
    assert g == [{"a": 1, "b": 3}, {"a": 2, "b": 3}]


def test_sweep_rejects_unknown_hparam(quad_data):
    with pytest.raises(ValueError, match="k_team"):
        run_sweep(PerMFL(quad_loss, HP), [dict(k_team=2)], (0,),
                  jnp.zeros(D), quad_data, quad_data, metric_fn=neg_loss,
                  rounds=2, m=M, n=N)


def test_sweep_rejects_mask_blind_participation(quad_data):
    with pytest.raises(ValueError, match="participation"):
        run_sweep(B.FedAvg(quad_loss, lr=0.1, local_steps=2),
                  [dict(lr=0.2)], (0,), jnp.zeros(D), quad_data, quad_data,
                  metric_fn=neg_loss, rounds=2, m=M, n=N, team_frac=0.5)


def test_sweep_rejects_empty(quad_data):
    with pytest.raises(ValueError, match="empty grid"):
        run_sweep(PerMFL(quad_loss, HP), [], (0,), jnp.zeros(D), quad_data,
                  quad_data, metric_fn=neg_loss, rounds=2, m=M, n=N)
    with pytest.raises(ValueError, match="empty seeds"):
        run_sweep(PerMFL(quad_loss, HP), [{}], (), jnp.zeros(D), quad_data,
                  quad_data, metric_fn=neg_loss, rounds=2, m=M, n=N)


def test_sweep_on_sweep_mesh_matches_unsharded(quad_data):
    """mesh= places the (S,) config axis on the mesh's sweep axis; on the
    CPU host mesh (1 device) this must be a pure no-op numerically."""
    mesh = make_host_mesh(n_sweep=1)
    assert mesh.axis_names == ("sweep", "data", "model")
    plain = run_sweep(PerMFL(quad_loss, HP), GRID, (0,), jnp.zeros(D),
                      quad_data, quad_data, metric_fn=neg_loss, rounds=3,
                      m=M, n=N)
    sharded = run_sweep(PerMFL(quad_loss, HP), GRID, (0,), jnp.zeros(D),
                        quad_data, quad_data, metric_fn=neg_loss, rounds=3,
                        m=M, n=N, mesh=mesh)
    for a, b in zip(plain, sharded):
        assert_results_match(b, a)


def test_sweep_pspecs_axis_mapping():
    """(S, M, N, ...) -> (sweep, data, model); (S, M, ...) -> (sweep,
    data); (S, ...) -> (sweep,) on the leading axis only."""
    from jax.sharding import PartitionSpec as P
    tree = {
        "theta": jnp.zeros((8, M, N, D)),
        "w": jnp.zeros((8, M, D)),
        "x": jnp.zeros((8, D)),
        "round": jnp.zeros((8,), jnp.int32),
    }
    specs = sweep_pspecs(tree, m=M, n=N)
    assert specs["theta"] == P("sweep", "data", "model", None)
    assert specs["w"] == P("sweep", "data", None)
    assert specs["x"] == P("sweep", None)
    assert specs["round"] == P("sweep")


def test_flsweepresult_accessors(quad_data):
    sw = run_sweep(PerMFL(quad_loss, HP), GRID, (0,), jnp.zeros(D),
                   quad_data, quad_data, metric_fn=neg_loss, rounds=2,
                   m=M, n=N)
    assert isinstance(sw, FLSweepResult)
    assert len(sw.best("pm")) == len(sw.final("gm")) == len(GRID)
    assert [r.pm_acc[-1] for r in sw] == sw.final("pm")
