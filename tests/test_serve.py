"""Serving engine: batched generation, samplers, cache reuse."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.serve.sampler import greedy, temperature


def test_greedy_sampler():
    logits = jnp.zeros((2, 1, 8)).at[0, 0, 3].set(5.0).at[1, 0, 6].set(5.0)
    toks = greedy(logits)
    assert toks.shape == (2, 1)
    assert toks[0, 0] == 3 and toks[1, 0] == 6


def test_temperature_sampler_topk():
    logits = jnp.arange(8.0)[None, None, :]
    key = jax.random.PRNGKey(0)
    # with top_k=1 it must behave greedily regardless of temperature
    toks = temperature(logits, key, temp=10.0, top_k=1)
    assert int(toks[0, 0]) == 7


@pytest.mark.parametrize("arch", ["phi3-mini-3.8b", "rwkv6-7b"])
def test_engine_generates(arch):
    cfg = get_reduced_config(arch).replace(vocab_size=64)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg=cfg, params=params, max_len=32)
    prompt = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (3, 8),
                                           0, 64)}
    out = eng.generate(prompt, max_new_tokens=6)
    assert out.shape == (3, 6)
    assert ((np.asarray(out) >= 0) & (np.asarray(out) < 64)).all()


def test_engine_greedy_matches_stepwise_forward():
    """Engine greedy generation == argmax rollout via full forwards."""
    cfg = get_reduced_config("phi3-mini-3.8b").replace(vocab_size=32)
    params = M.init_params(jax.random.PRNGKey(2), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 6), 0, 32)
    eng = ServeEngine(cfg=cfg, params=params, max_len=16)
    out = np.asarray(eng.generate({"tokens": toks}, max_new_tokens=4))

    seq = np.asarray(toks)
    want = []
    for _ in range(4):
        logits, _ = M.forward(params, cfg,
                              {"tokens": jnp.asarray(seq),
                               "targets": jnp.asarray(seq)})
        nxt = int(jnp.argmax(logits[0, -1]))
        want.append(nxt)
        seq = np.concatenate([seq, [[nxt]]], axis=1)
    assert out[0].tolist() == want, (out[0].tolist(), want)


def test_engine_temperature_deterministic_per_seed():
    cfg = get_reduced_config("phi3-mini-3.8b").replace(vocab_size=32)
    params = M.init_params(jax.random.PRNGKey(4), cfg)
    eng = ServeEngine(cfg=cfg, params=params, max_len=16, sample="temp",
                      temp=1.0)
    prompt = {"tokens": jnp.zeros((2, 4), jnp.int32)}
    a = np.asarray(eng.generate(prompt, max_new_tokens=5, seed=7))
    b = np.asarray(eng.generate(prompt, max_new_tokens=5, seed=7))
    c = np.asarray(eng.generate(prompt, max_new_tokens=5, seed=8))
    np.testing.assert_array_equal(a, b)
    assert not (a == c).all() or True  # different seed may still collide
