"""Span log, metrics registry, and the joined `obs report` front door
(repro.obs.spans / .metrics / .report)."""
import json

import pytest

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               percentile)
from repro.obs.report import load_artifacts, report_text
from repro.obs.spans import Span, SpanLog, current_log, span


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

def test_span_nesting_depth_and_durations():
    log = SpanLog(meta={"kind": "test"})
    with log.activate():
        with span("outer", tag="a"):
            with span("inner"):
                pass
        with span("sibling"):
            pass
    names = [s.name for s in log.spans]
    assert names == ["outer", "inner", "sibling"]  # recorded at open
    depths = {s.name: s.depth for s in log.spans}
    assert depths["outer"] == 0 and depths["inner"] == 1
    assert depths["sibling"] == 0
    assert all(s.dur >= 0 for s in log.spans)


def test_span_is_noop_without_active_log():
    assert current_log() is None
    with span("orphan") as sp:
        sp.set(x=1)  # must not raise on the null span
    assert current_log() is None


def test_span_set_after_close_lands_in_chrome_args():
    log = SpanLog()
    with log.activate():
        with span("compile") as sp:
            pass
        sp.set(flops=123.0, skipme=[1, 2])  # late stamp, post-close
    ev = [e for e in log.to_chrome()["traceEvents"]
          if e.get("name") == "compile"]
    assert len(ev) == 1 and ev[0]["ph"] == "X"
    assert ev[0]["args"]["flops"] == 123.0
    # non-scalar args are filtered out of the Chrome export
    assert "skipme" not in ev[0]["args"]
    assert ev[0]["dur"] >= 0 and isinstance(ev[0]["ts"], (int, float))


def test_span_log_save_writes_perfetto_loadable_json(tmp_path):
    log = SpanLog(meta={"kind": "test"})
    with log.activate():
        with span("a"):
            with span("b"):
                pass
    path = log.save(tmp_path, tag="unit/run")
    assert path.name.startswith("spans-unit_run-")
    doc = json.loads(path.read_text())
    assert {e["name"] for e in doc["traceEvents"]} == {"a", "b"}
    tids = {e["name"]: e["tid"] for e in doc["traceEvents"]}
    assert tids["b"] == tids["a"] + 1  # nesting depth as track


def test_span_summary_aggregates_by_name():
    log = SpanLog()
    with log.activate():
        for _ in range(3):
            with span("dispatch"):
                pass
        with span("eval"):
            pass
    s = log.summary()
    assert s["dispatch"]["count"] == 3 and s["eval"]["count"] == 1
    assert s["dispatch"]["total_ms"] >= 0


def test_nested_activation_is_rejected_but_outer_log_collects():
    outer = SpanLog()
    inner = SpanLog()
    with outer.activate():
        # a second layer trying to own a log just contributes spans to
        # the active one instead (the ownership rule engine/runner use)
        assert current_log() is outer
        with span("from-inner-layer"):
            pass
        with pytest.raises(RuntimeError):
            with inner.activate():
                pass
    assert [s.name for s in outer.spans] == ["from-inner-layer"]
    assert inner.spans == []


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_percentile_nearest_rank():
    vals = list(range(1, 101))  # 1..100
    assert percentile(vals, 50) == 50
    assert percentile(vals, 95) == 95
    assert percentile(vals, 99) == 99
    assert percentile(vals, 100) == 100
    assert percentile([7.0], 99) == 7.0
    # 64 samples: p95 and p99 land on different ranks (the smoke-replay
    # sizing fix relies on exactly this)
    v64 = list(range(64))
    assert percentile(v64, 95) != percentile(v64, 99)


def test_counter_gauge_histogram_basics():
    c = Counter()
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = Gauge()
    g.set(2.5)
    assert g.value == 2.5
    h = Histogram()
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 4 and s["sum"] == 10.0 and s["max"] == 4.0
    assert s["p50"] == 2.0


def test_registry_get_or_create_and_kind_mismatch():
    reg = MetricsRegistry()
    assert reg.counter("requests") is reg.counter("requests")
    assert reg.counter("requests", path="a") is not reg.counter("requests")
    with pytest.raises(TypeError):
        reg.gauge("requests")


def test_registry_jsonl_and_prometheus_export(tmp_path):
    reg = MetricsRegistry()
    reg.counter("serving.requests").inc(64)
    reg.gauge("serving.cache_hit_rate").set(0.75)
    h = reg.histogram("serving.replay.latency_ms", path="gather")
    for v in (1.0, 2.0):
        h.observe(v)
    p = reg.write_jsonl(tmp_path / "m.jsonl")
    recs = [json.loads(l) for l in p.read_text().splitlines()]
    assert {r["metric"] for r in recs} == {
        "serving.requests", "serving.cache_hit_rate",
        "serving.replay.latency_ms"}
    prom = reg.to_prometheus()
    assert "serving_requests 64" in prom
    assert "serving_cache_hit_rate 0.75" in prom
    assert 'serving_replay_latency_ms{path="gather",quantile="0.50"}' \
        in prom
    assert "serving_replay_latency_ms_count" in prom


# ---------------------------------------------------------------------------
# the joined report
# ---------------------------------------------------------------------------

def test_report_joins_spans_and_metrics(tmp_path):
    log = SpanLog(meta={"kind": "test"})
    with log.activate():
        with span("compile") as sp:
            pass
        sp.set(flops=10.0)
    log.save(tmp_path, tag="unit")
    reg = MetricsRegistry()
    reg.counter("serving.requests").inc(8)
    reg.write_jsonl(tmp_path / "metrics-unit.jsonl")
    art = load_artifacts(tmp_path)
    assert len(art["spans"]) == 1 and len(art["metrics"]) == 1
    txt = report_text(tmp_path)
    assert "compile" in txt and "serving.requests" in txt


def test_report_empty_dir(tmp_path):
    art = load_artifacts(tmp_path)
    assert not any(art.values())
