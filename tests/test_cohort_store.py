"""Gather/scatter equivalence suite for the virtualized device-state
store (repro.train.store, DESIGN.md §11).

Each property (scatter-after-gather identity, non-sampled-row
immutability, permutation equivariance, sorted/unique/in-range index
maps) is one ``_check_*`` function exercised two ways: a deterministic
seeded grid that always runs, and a Hypothesis fuzz layer over the same
checks when hypothesis is installed (requirements-dev.txt pins it for
CI; the grid keeps the suite meaningful without it). Alongside: the
``device_axes`` split/merge contract and the DeviceStateStore pytree."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommConfig
from repro.core import PerMFL, baselines as B
from repro.core.participation import sample_cohort
from repro.core.permfl import PerMFLHParams
from repro.sharding.specs import store_pspecs
from repro.train.store import (DeviceStateStore, gather_cohort,
                               scatter_cohort, split_device_state)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

M, N, D = 3, 4, 5
HP = PerMFLHParams(alpha=0.05, eta=0.04, beta=0.3, lam=0.8, gamma=2.0,
                   k_team=3, l_local=4)


def quad_loss(params, batch):
    return 0.5 * jnp.sum((params - batch["c"]) ** 2)


def _tree(rng, m, n):
    """A device-tier pytree with leaves of varying trailing shapes."""
    f32 = lambda *s: rng.normal(size=s).astype(np.float32)
    return {"a": jnp.asarray(f32(m, n)),
            "b": jnp.asarray(f32(m, n, 3)),
            "c": [jnp.asarray(f32(m, n, 2, 2))]}


# the deterministic grid: every (m, n, c) is a distinct compile, so keep
# it small but cover the edges (c=1, c=n, n=1)
GRID = [(1, 1, 1, 0), (2, 5, 1, 1), (2, 5, 3, 2), (3, 8, 8, 3),
        (3, 8, 5, 4), (2, 7, 6, 5)]


def _check_index_map(m, n, c, seed):
    idx = np.asarray(sample_cohort(jax.random.PRNGKey(seed), m, n, c))
    assert idx.shape == (m, c) and idx.dtype == np.int32
    for row in idx:
        assert (np.diff(row) > 0).all()        # sorted => also distinct
        assert row.min() >= 0 and row.max() < n


def _check_roundtrip(m, n, c, seed):
    tree = _tree(np.random.default_rng(seed), m, n)
    idx = sample_cohort(jax.random.PRNGKey(seed), m, n, c)
    out = scatter_cohort(tree, idx, gather_cohort(tree, idx))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _check_untouched_rows(m, n, c, seed):
    tree = _tree(np.random.default_rng(seed), m, n)
    idx = sample_cohort(jax.random.PRNGKey(seed), m, n, c)
    update = jax.tree.map(lambda l: l + 1.0, gather_cohort(tree, idx))
    out = scatter_cohort(tree, idx, update)
    idx = np.asarray(idx)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        a, b = np.asarray(a), np.asarray(b)
        for t in range(m):
            sampled = np.zeros(n, bool)
            sampled[idx[t]] = True
            np.testing.assert_array_equal(a[t][~sampled], b[t][~sampled])
            np.testing.assert_array_equal(a[t][sampled], b[t][sampled] + 1)


def _check_permutation_equivariance(m, n, c, seed, perm=None):
    if perm is None:
        perm = np.random.default_rng(seed + 1).permutation(c)
    perm = np.asarray(perm)
    tree = _tree(np.random.default_rng(seed), m, n)
    idx = sample_cohort(jax.random.PRNGKey(seed), m, n, c)
    direct = gather_cohort(tree, jnp.asarray(np.asarray(idx)[:, perm]))
    reordered = jax.tree.map(lambda l: l[:, perm],
                             gather_cohort(tree, idx))
    for a, b in zip(jax.tree.leaves(direct), jax.tree.leaves(reordered)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("m,n,c,seed", GRID)
def test_sample_cohort_sorted_unique_in_range(m, n, c, seed):
    _check_index_map(m, n, c, seed)


def test_sample_cohort_full_width_is_arange():
    """cohort_size == n must degenerate to the identity index map — the
    property that makes full-population equivalence bit-exact."""
    for seed, (m, n) in enumerate(((1, 1), (2, 5), (3, 8))):
        idx = sample_cohort(jax.random.PRNGKey(seed), m, n, n)
        np.testing.assert_array_equal(np.asarray(idx),
                                      np.tile(np.arange(n), (m, 1)))


@pytest.mark.parametrize("m,n,c,seed", GRID)
def test_scatter_after_gather_is_identity(m, n, c, seed):
    _check_roundtrip(m, n, c, seed)


@pytest.mark.parametrize("m,n,c,seed", GRID)
def test_scatter_touches_only_sampled_rows(m, n, c, seed):
    _check_untouched_rows(m, n, c, seed)


@pytest.mark.parametrize("m,n,c,seed", GRID)
def test_gather_is_permutation_equivariant(m, n, c, seed):
    _check_permutation_equivariance(m, n, c, seed)


if HAVE_HYPOTHESIS:
    # fuzz the same checks; shape diversity stays low (every fresh
    # (m, n, c) is a new XLA compile — the properties are about values)
    _SMALL = dict(m=st.integers(1, 3), n=st.integers(1, 8),
                  seed=st.integers(0, 999))

    @settings(max_examples=20, deadline=None)
    @given(data=st.data(), **_SMALL)
    def test_hypothesis_index_map(data, m, n, seed):
        _check_index_map(m, n, data.draw(st.integers(1, n)), seed)

    @settings(max_examples=20, deadline=None)
    @given(data=st.data(), **_SMALL)
    def test_hypothesis_roundtrip(data, m, n, seed):
        _check_roundtrip(m, n, data.draw(st.integers(1, n)), seed)

    @settings(max_examples=20, deadline=None)
    @given(data=st.data(), **_SMALL)
    def test_hypothesis_untouched_rows(data, m, n, seed):
        _check_untouched_rows(m, n, data.draw(st.integers(1, n)), seed)

    @settings(max_examples=20, deadline=None)
    @given(data=st.data(), **_SMALL)
    def test_hypothesis_permutation_equivariance(data, m, n, seed):
        c = data.draw(st.integers(1, n))
        perm = data.draw(st.permutations(range(c)))
        _check_permutation_equivariance(m, n, c, seed, perm=perm)


def test_split_merge_roundtrip_permfl_with_comm():
    """device_axes on PerMFL selects exactly the per-device tiers (theta
    + EF device residuals); merge(split(state)) is the identity."""
    cfg = CommConfig("topk", k_frac=0.5)
    algo = PerMFL(quad_loss, HP, comm=cfg)
    state = algo.init_state(jnp.zeros(D), M, N)
    dev, rest, merge = split_device_state(algo, state, M, N)
    assert len(dev) == 2                      # theta + comm.ef_dev
    assert all(l.shape[:2] == (M, N) for l in dev)
    back = merge(dev, rest)
    assert jax.tree.structure(back) == jax.tree.structure(state)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_split_baselines_device_tier_selection():
    """Purely global baselines expose no device tier; personalized ones
    put exactly their per-device params on it."""
    fa = B.FedAvg(quad_loss, lr=0.1, local_steps=2)
    dev, rest, merge = split_device_state(
        fa, fa.init_state(jnp.zeros(D), M, N), M, N)
    assert dev == ()
    dt = B.Ditto(quad_loss, lr=0.1, lam=0.5, local_steps=2)
    state = dt.init_state(jnp.zeros(D), M, N)
    dev, rest, merge = split_device_state(dt, state, M, N)
    assert len(dev) == 1 and dev[0].shape[:2] == (M, N)
    back = merge(dev, rest)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_split_flag_count_mismatch_raises():
    """A device_axes override that misses leaves must fail loudly, not
    silently misclassify tiers."""
    algo = PerMFL(quad_loss, HP)
    state = algo.init_state(jnp.zeros(D), M, N)

    class Bad(PerMFL):
        def device_axes(self, state, m, n):
            return (True,)                    # wrong flag count

    with pytest.raises(ValueError, match="flags"):
        split_device_state(Bad(quad_loss, HP), state, M, N)


def test_store_pspecs_population_axis():
    """store_pspecs shards exactly the population axis of (M, pop, ...)
    leaves over the mesh data axis; other leaves fully replicate."""
    from jax.sharding import PartitionSpec as P

    tree = {"dev": jnp.zeros((M, 100, 3)), "team": jnp.zeros((M, D)),
            "glob": jnp.zeros((D,))}
    specs = store_pspecs(tree, m=M, population=100)
    assert specs["dev"] == P(None, "data", None)
    assert specs["team"] == P(None, None)
    assert specs["glob"] == P(None)
    swept = store_pspecs(
        jax.tree.map(lambda l: l[None], tree), m=M, population=100,
        sweep=True)
    assert swept["dev"] == P("sweep", None, "data", None)
    assert swept["glob"] == P("sweep", None)


def test_device_state_store_pytree_and_methods():
    """DeviceStateStore is a pytree (scan/vmap-carriable) whose gather/
    scatter methods agree with the functional API."""
    from jax.sharding import PartitionSpec as P

    rng = np.random.default_rng(7)
    store = DeviceStateStore(_tree(rng, M, N), M, N)
    leaves, treedef = jax.tree.flatten(store)
    back = jax.tree.unflatten(treedef, leaves)
    assert (back.m, back.n) == (M, N)
    idx = sample_cohort(jax.random.PRNGKey(0), M, N, 2)
    cohort = store.gather(idx)
    for a, b in zip(jax.tree.leaves(cohort),
                    jax.tree.leaves(gather_cohort(store.tree, idx))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    upd = jax.tree.map(lambda l: l * 2.0, cohort)
    s2 = jax.jit(lambda s: s.scatter(idx, upd))(store)
    assert isinstance(s2, DeviceStateStore) and (s2.m, s2.n) == (M, N)
    for a, b in zip(jax.tree.leaves(s2.tree),
                    jax.tree.leaves(
                        scatter_cohort(store.tree, idx, upd))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for s in jax.tree.leaves(s2.pspecs(),
                             is_leaf=lambda x: isinstance(x, P)):
        assert s[1] == "data"                 # every store leaf is (M, N, ...)
