"""Wall-clock system simulator: spec/profile invariants, in-graph
simulation determinism + monotonicity, deadline-straggler mask
equivalence (scan == dispatch == hand-fed masks), sweep batching of
system profiles, multi-sweep fusion, and scenario/CLI integration."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.system import (SYSTEM_PROFILES, RoundWorkload, SystemSpec,
                          get_profile, simulate_round, workload_for)

WL = RoundWorkload(k_team=5, local_steps=10, n_params=7850,
                   full_bytes=31400, comp_bytes=3200)


def _leaves(profile, **over):
    spec = get_profile(profile)
    if over:
        spec = dataclasses.replace(spec, **over)
    return spec.tree_floats()[0]


# ---------------------------------------------------------------------------
# SystemSpec + profiles
# ---------------------------------------------------------------------------

def test_profiles_round_trip_and_share_skeleton():
    skels = set()
    for name, spec in SYSTEM_PROFILES.items():
        assert spec.name == name
        assert SystemSpec.from_dict(
            json.loads(json.dumps(spec.to_dict()))) == spec
        leaves, rebuild = spec.tree_floats()
        assert rebuild(leaves) == spec
        skels.add(spec.skeleton())
    # one static skeleton -> one compiled program serves every profile
    assert len(skels) == 1


def test_spec_validation():
    with pytest.raises(ValueError):
        SystemSpec(wan_mbps=0.0)
    with pytest.raises(ValueError):
        SystemSpec(compute_sigma=-0.1)
    with pytest.raises(KeyError):
        get_profile("datacenter-nvlink")


def test_get_profile_accepts_spec_dict_and_name():
    spec = SYSTEM_PROFILES["edge-iot"]
    assert get_profile(spec) is spec
    assert get_profile("edge-iot") == spec
    assert get_profile(spec.to_dict()) == spec


def test_with_deadline():
    d = get_profile("uniform").with_deadline(3.5)
    assert d.deadline_s == 3.5
    assert dataclasses.replace(d, deadline_s=0.0) == \
        SYSTEM_PROFILES["uniform"]


def test_workload_for_permfl_and_baselines():
    from repro.comm import CommConfig
    from repro.scenarios import SCENARIOS, build_scenario

    b = build_scenario(SCENARIOS["table1/mnist/mclr/permfl"].scaled(
        m_teams=2, n_devices=3, samples_per_device=16))
    wl = workload_for(b.algo, b.params0)
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree.leaves(b.params0))
    assert wl.k_team == 5 and wl.local_steps == 10
    assert wl.n_params == n_params
    assert wl.full_bytes == wl.comp_bytes == 4 * n_params

    comp = workload_for(dataclasses.replace(
        b.algo, comm=CommConfig(compressor="sign")), b.params0)
    assert comp.comp_bytes < comp.full_bytes == wl.full_bytes

    b2 = build_scenario(SCENARIOS["table1/mnist/mclr/fedavg"].scaled(
        m_teams=2, n_devices=3, samples_per_device=16))
    wl2 = workload_for(b2.algo, b2.params0)
    assert wl2.k_team == 1 and wl2.local_steps == 50


# ---------------------------------------------------------------------------
# simulate_round
# ---------------------------------------------------------------------------

def test_simulate_round_deterministic_and_positive():
    tm, dm = jnp.ones((4,)), jnp.ones((4, 10))
    for profile in SYSTEM_PROFILES:
        a = simulate_round(_leaves(profile), WL, jax.random.PRNGKey(7),
                           tm, dm)
        b = simulate_round(_leaves(profile), WL, jax.random.PRNGKey(7),
                           tm, dm)
        assert float(a[2]) == float(b[2]) > 0.0, profile
        assert np.array_equal(np.asarray(a[0]), np.asarray(b[0]))
        c = simulate_round(_leaves(profile), WL, jax.random.PRNGKey(8),
                           tm, dm)
        if get_profile(profile).compute_sigma > 0:
            assert float(c[2]) != float(a[2]), profile


def test_no_deadline_passes_masks_through():
    key = jax.random.PRNGKey(0)
    from repro.core.participation import sample_masks
    tm, dm = sample_masks(key, 4, 10, team_frac=0.5, device_frac=0.5)
    tm2, dm2, t, dt, dd = simulate_round(
        _leaves("wan-cellular"), WL, jax.random.PRNGKey(1), tm, dm)
    assert np.array_equal(np.asarray(tm2), np.asarray(tm))
    assert np.array_equal(np.asarray(dm2),
                          np.asarray(dm * tm[:, None]))
    assert int(dt) == 0 and int(dd) == 0


def test_zero_sigma_uniform_profile_time_is_closed_form():
    # homogeneous fleet: the critical path is any device's chain
    leaves = _leaves("uniform")
    tm, dm = jnp.ones((3,)), jnp.ones((3, 4))
    _, _, t, _, _ = simulate_round(leaves, WL, jax.random.PRNGKey(0),
                                   tm, dm)
    rate = leaves["compute_gflops"] * 1e9
    lan = leaves["lan_mbps"] * 125e3
    wan = leaves["wan_mbps"] * 125e3
    t_iter = (WL.local_steps * WL.n_params * leaves["flops_per_param"]
              / rate + 2 * leaves["lan_latency_ms"] * 1e-3
              + (WL.full_bytes + WL.comp_bytes) / lan)
    expect = (leaves["wan_latency_ms"] * 1e-3 + WL.full_bytes / wan
              + WL.k_team * t_iter
              + leaves["wan_latency_ms"] * 1e-3 + WL.comp_bytes / wan)
    assert float(t) == pytest.approx(expect, rel=1e-5)


def test_deadline_drops_stragglers_and_keeps_round_nonempty():
    tm, dm = jnp.ones((4,)), jnp.ones((4, 10))
    leaves = _leaves("wan-cellular", deadline_s=0.5)
    tm2, dm2, t, dt, dd = simulate_round(leaves, WL,
                                         jax.random.PRNGKey(0), tm, dm)
    assert int(dd) > 0                       # this seed has stragglers
    assert float(jnp.sum(dm2)) == 40 - int(dd)
    # impossibly tight deadline: the single fastest chain survives
    leaves = _leaves("wan-cellular", deadline_s=1e-6)
    tm3, dm3, t3, dt3, dd3 = simulate_round(leaves, WL,
                                            jax.random.PRNGKey(0), tm, dm)
    assert float(jnp.sum(tm3)) == 1.0 and float(jnp.sum(dm3)) == 1.0
    assert int(dt3) == 3 and int(dd3) == 39
    # the survivor's mask is team-gated (device in the surviving team)
    assert np.array_equal(np.asarray(dm3).sum(axis=1) > 0,
                          np.asarray(tm3) > 0)


def test_keep_fastest_noop_when_alive():
    from repro.core.participation import keep_fastest
    tm = jnp.asarray([1.0, 0.0])
    dm = jnp.asarray([[1.0, 0.0], [1.0, 1.0]])
    score = jnp.ones((2, 2))
    tm2, dm2 = keep_fastest(tm, dm, score, jnp.ones((2, 2)))
    assert np.array_equal(np.asarray(tm2), [1.0, 0.0])
    assert np.array_equal(np.asarray(dm2), [[1.0, 0.0], [0.0, 0.0]])


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_build():
    from repro.scenarios import SCENARIOS, build_scenario
    return build_scenario(SCENARIOS["table1/mnist/mclr/permfl"].scaled(
        m_teams=2, n_devices=3, samples_per_device=16))


def _run(b, **kw):
    from repro.train.engine import run_experiment
    args = dict(metric_fn=b.metric_fn, rounds=5, m=b.m, n=b.n,
                eval_every=2)
    args.update(kw)
    return run_experiment(b.algo, b.params0, b.train, b.val, **args)


def test_engine_timeline_deterministic_monotone(small_build):
    b = small_build
    r1 = _run(b, system="wan-cellular")
    r2 = _run(b, system="wan-cellular")
    assert r1.timeline.round_seconds == r2.timeline.round_seconds
    assert r1.sim_seconds == r2.sim_seconds
    assert len(r1.timeline) == 5 and len(r1.sim_seconds) == 3
    assert all(t > 0 for t in r1.timeline.round_seconds)
    cum = r1.timeline.cum_seconds()
    assert all(b2 >= a for a, b2 in zip(cum, cum[1:]))
    assert r1.sim_seconds == [pytest.approx(cum[1]),
                              pytest.approx(cum[3]),
                              pytest.approx(cum[4])]


@pytest.mark.parametrize("frac", [1.0, 0.5])
def test_engine_system_without_deadline_is_pure_measurement(small_build,
                                                            frac):
    # must hold under sampled participation too: the system stream is
    # folded out of the mask key, never advancing the sampling chain
    b = small_build
    kw = dict(team_frac=frac, device_frac=frac, seed=5)
    plain = _run(b, **kw)
    timed = _run(b, system="lan-campus", **kw)
    assert timed.pm_acc == plain.pm_acc
    assert timed.train_loss == plain.train_loss
    assert timed.participation == plain.participation
    assert plain.timeline is None and plain.sim_seconds == []


def test_engine_scan_matches_dispatch_with_system(small_build):
    b = small_build
    sys = get_profile("wan-cellular").with_deadline(0.6)
    kw = dict(system=sys, team_frac=0.5, device_frac=0.5, seed=3)
    r_scan = _run(b, scan=True, **kw)
    r_disp = _run(b, scan=False, **kw)
    assert r_scan.pm_acc == r_disp.pm_acc
    assert r_scan.train_loss == r_disp.train_loss
    assert r_scan.participation == r_disp.participation
    np.testing.assert_allclose(r_scan.timeline.round_seconds,
                               r_disp.timeline.round_seconds, rtol=1e-6)
    assert r_scan.timeline.dropped_devices == \
        r_disp.timeline.dropped_devices


def test_deadline_trajectory_identical_to_hand_fed_masks(small_build):
    """Acceptance: a deadline-straggler run equals a host loop feeding
    the equivalent participation masks to algo.round directly."""
    from repro.core.participation import sample_masks
    b = small_build
    sys = get_profile("edge-iot").with_deadline(2.0)
    seed, rounds = 11, 4
    res = _run(b, system=sys, team_frac=0.5, device_frac=0.5, seed=seed,
               rounds=rounds, eval_every=1)

    # replicate the engine's PRNG chain + deadline thinning on the host
    from repro.train.engine import _SYSTEM_SALT
    leaves, _ = sys.tree_floats()
    wl = workload_for(b.algo, b.params0)
    state = b.algo.init_state(b.params0, b.m, b.n)
    key = jax.random.PRNGKey(seed)
    fed_masks = []
    for _ in range(rounds):
        key, sub = jax.random.split(key)
        tm, dm = sample_masks(sub, b.m, b.n, team_frac=0.5,
                              device_frac=0.5)
        skey = jax.random.fold_in(sub, _SYSTEM_SALT)
        tm, dm, t, dt, dd = simulate_round(leaves, wl, skey, tm, dm)
        fed_masks.append((int(jnp.sum(tm)),
                          int(jnp.sum(dm * tm[:, None]))))
        state = b.algo.round(state, b.train, team_mask=tm,
                             device_mask=dm)
    assert fed_masks == res.participation
    ref = b.algo.eval(state, b.train, b.val, b.metric_fn)
    assert float(ref["pm"]) == pytest.approx(res.pm_acc[-1], abs=1e-6)
    assert float(ref["train_loss"]) == pytest.approx(res.train_loss[-1],
                                                     abs=1e-6)


def test_seconds_split_sums(small_build):
    r = _run(small_build)
    assert r.compile_seconds >= 0 and r.run_seconds >= 0
    assert r.seconds == pytest.approx(
        r.compile_seconds + r.run_seconds, abs=1e-9)


# ---------------------------------------------------------------------------
# sweep integration
# ---------------------------------------------------------------------------

def test_sweep_batches_system_profiles_one_dispatch(small_build):
    from repro.train.sweep import run_sweep
    b = small_build
    profiles = ["lan-campus", "wan-cellular", "edge-iot"]
    sw = run_sweep(b.algo, [{}], (0,), b.params0, b.train, b.val,
                   metric_fn=b.metric_fn, rounds=4, m=b.m, n=b.n,
                   system=profiles)
    assert sw.dispatches == 1 and len(sw) == 3
    for res, prof in zip(sw, profiles):
        ref = _run(b, system=prof, rounds=4, eval_every=1)
        assert res.pm_acc == ref.pm_acc
        np.testing.assert_allclose(res.timeline.round_seconds,
                                   ref.timeline.round_seconds, rtol=1e-5)
        assert res.timeline.profile == prof
    assert [c["system"] for c in sw.configs] == profiles


def test_sweep_accepts_single_profile_name(small_build):
    from repro.train.sweep import run_sweep
    b = small_build
    sw = run_sweep(b.algo, [dict(lam=0.3), dict(lam=0.8)], (0,),
                   b.params0, b.train, b.val, metric_fn=b.metric_fn,
                   rounds=3, m=b.m, n=b.n, system="uniform")
    assert len(sw) == 2
    assert all(r.timeline is not None and r.timeline.profile == "uniform"
               for r in sw)
    # zero-sigma profile: both configs tick the same simulated clock
    assert sw[0].timeline.round_seconds == sw[1].timeline.round_seconds


def test_multi_sweep_fuses_compressors(small_build):
    from repro.comm import CommConfig
    from repro.train.sweep import run_multi_sweep
    b = small_build
    algos = [dataclasses.replace(b.algo,
                                 comm=CommConfig(compressor=c))
             for c in ("topk", "sign")]
    sweeps = run_multi_sweep(
        [dict(algo=a, params0=b.params0,
              system=["lan-campus", "wan-cellular"]) for a in algos],
        b.train, b.val, metric_fn=b.metric_fn, rounds=4, m=b.m, n=b.n)
    assert len(sweeps) == 2
    for a, sw in zip(algos, sweeps):
        assert sw.dispatches == 1 and len(sw) == 2
        for res, prof in zip(sw, ("lan-campus", "wan-cellular")):
            from repro.train.engine import run_experiment
            ref = run_experiment(a, b.params0, b.train, b.val,
                                 metric_fn=b.metric_fn, rounds=4,
                                 m=b.m, n=b.n, system=prof)
            assert res.pm_acc == ref.pm_acc
            np.testing.assert_allclose(res.timeline.round_seconds,
                                       ref.timeline.round_seconds,
                                       rtol=1e-5)
            assert res.comm.total_bytes() == ref.comm.total_bytes()
    # sign ships fewer bytes than top-10%, so on the WAN-bound profile
    # it must also finish in less simulated time
    assert sweeps[1][1].timeline.total_seconds() < \
        sweeps[0][1].timeline.total_seconds()


# ---------------------------------------------------------------------------
# scenario + CLI integration
# ---------------------------------------------------------------------------

def test_scenario_system_serialization_and_legacy_hash():
    from repro.scenarios import SCENARIOS, FLScenario
    s = SCENARIOS["table1/mnist/mclr/permfl"]
    assert "system" not in s.to_dict()          # legacy dict byte-stable
    timed = s.with_system("wan-cellular")
    assert timed.system == SYSTEM_PROFILES["wan-cellular"]
    rt = FLScenario.from_dict(json.loads(json.dumps(timed.to_dict())))
    assert rt == timed
    assert rt.spec_hash() == timed.spec_hash()
    assert timed.spec_hash() != s.spec_hash()   # system is physics
    assert timed.with_system(None).spec_hash() == s.spec_hash()
    # ...but the profile's label is presentation, like scenario names
    relabeled = timed.with_system(
        dataclasses.replace(timed.system, name="renamed"))
    assert relabeled.spec_hash() == timed.spec_hash()
    # scaled() keeps the system model attached
    assert timed.scaled(rounds=3).system == timed.system


def test_run_scenario_threads_system(small_build):
    from repro.scenarios import run_scenario
    s = small_build.scenario.with_system("wan-cellular")
    res = run_scenario(s, rounds=3)
    assert res.timeline is not None and len(res.timeline) == 3
    # explicit argument overrides the spec's profile
    res2 = run_scenario(s, rounds=3, system="lan-campus")
    assert res2.timeline.profile == "lan-campus"
    assert res2.timeline.total_seconds() < res.timeline.total_seconds()
    # ...and system=None explicitly disables simulation on this spec
    res3 = run_scenario(s, rounds=3, system=None)
    assert res3.timeline is None and res3.pm_acc == res.pm_acc


def test_sweep_scenario_threads_system(small_build):
    from repro.scenarios import sweep_scenario
    sw = sweep_scenario(small_build.scenario, rounds=3,
                        system=["lan-campus", "wan-cellular"])
    assert len(sw) == 2 and sw.dispatches == 1
    assert [r.timeline.profile for r in sw] == ["lan-campus",
                                                "wan-cellular"]


def test_cli_profiles_and_system_run(capsys):
    from repro.scenarios.__main__ import main
    assert main(["profiles"]) == 0
    out = capsys.readouterr().out
    for name in SYSTEM_PROFILES:
        assert name in out
    assert main(["run", "table1/mnist/mclr/permfl", "--smoke",
                 "--system", "wan-cellular", "--deadline", "30"]) == 0
    out = capsys.readouterr().out
    assert "system[wan-cellular]" in out and "simulated" in out
