"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned arch (<=2 layers, d_model<=512, <=4 experts) runs one forward and
one train step on CPU; output shapes are checked and outputs are NaN-free."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced_config
from repro.configs.base import (INPUT_SHAPES, active_param_count,
                                param_count)
from repro.models import model as M
from repro.train import optim
from repro.train.train_state import TrainState
from repro.train.trainer import make_train_step


def _reduced_batch(cfg, b=2, s=16, key=None):
    key = key or jax.random.PRNGKey(0)
    batch = {}
    if cfg.family == "vlm":
        batch["embeds"] = jax.random.normal(key, (b, s, cfg.d_model))
        batch["mrope_positions"] = jnp.tile(
            jnp.arange(s, dtype=jnp.int32)[None, :, None], (b, 1, 3))
    elif cfg.is_encoder_decoder:
        batch["enc_frames"] = jax.random.normal(
            key, (b, cfg.encoder_seq_len, cfg.d_model))
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    else:
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch["targets"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return batch


def test_reduced_configs_respect_limits():
    for arch in ARCH_IDS:
        r = get_reduced_config(arch)
        assert r.num_layers <= 8, arch          # jamba keeps one 1:7 block
        assert r.d_model <= 512, arch
        assert r.moe.num_experts <= 4, arch


def test_full_configs_match_assignment():
    """The exact dimensions from the assignment table."""
    expect = {
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "rwkv6-7b": (32, 4096, 32, 32, 14336, 65536),
    }
    for arch, (nl, dm, nh, nkv, dff, v) in expect.items():
        c = get_config(arch)
        assert c.num_layers == nl, arch
        assert c.d_model == dm, arch
        if c.family != "ssm":
            assert c.num_heads == nh, arch
            assert c.num_kv_heads == nkv, arch
        assert c.d_ff == dff, (arch, c.d_ff)
        assert c.vocab_size == v, arch
        assert c.citation, f"{arch} missing citation"


def test_structural_features():
    assert get_config("qwen3-14b").use_qk_norm
    assert get_config("qwen1.5-32b").use_qkv_bias
    assert get_config("qwen2-vl-2b").use_mrope
    assert get_config("whisper-small").is_encoder_decoder
    dsm = get_config("deepseek-moe-16b").moe
    assert (dsm.num_experts, dsm.num_shared_experts, dsm.top_k) == (64, 2, 6)
    dbrx = get_config("dbrx-132b").moe
    assert (dbrx.num_experts, dbrx.top_k) == (16, 4)
    jamba = get_config("jamba-1.5-large-398b")
    kinds = jamba.layer_kinds()
    assert kinds.count("attn") * 8 == len(kinds)   # 1:7 attn:mamba
    assert jamba.moe.num_experts == 16 and jamba.moe.top_k == 2
    assert get_config("rwkv6-7b").family == "ssm"


def test_param_counts_near_nameplate():
    """Analytic param counts should be within ~35% of the model names
    (names round aggressively; whisper-small is 244M)."""
    nameplate = {
        "phi3-mini-3.8b": 3.8e9, "qwen2-vl-2b": 1.5e9,
        "qwen1.5-32b": 32e9, "deepseek-moe-16b": 16e9,
        "whisper-small": 0.244e9, "qwen3-14b": 14e9, "dbrx-132b": 132e9,
        "jamba-1.5-large-398b": 398e9, "yi-34b": 34e9, "rwkv6-7b": 7e9,
    }
    for arch, want in nameplate.items():
        got = param_count(get_config(arch))
        assert 0.6 * want < got < 1.45 * want, \
            f"{arch}: {got/1e9:.2f}B vs nameplate {want/1e9:.1f}B"


def test_moe_active_params_smaller():
    for arch in ("deepseek-moe-16b", "dbrx-132b", "jamba-1.5-large-398b"):
        cfg = get_config(arch)
        assert active_param_count(cfg) < 0.6 * param_count(cfg), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_reduced_config(arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _reduced_batch(cfg)
    logits, aux = M.forward(params, cfg, batch)
    b, s = batch["targets"].shape
    assert logits.shape == (b, s, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_reduces_loss(arch):
    """One forward + 8 SGD steps on a fixed batch must reduce the loss and
    keep params finite (the per-arch smoke train step)."""
    cfg = get_reduced_config(arch)
    opt = optim.adamw()
    step = jax.jit(make_train_step(cfg, opt, lr=3e-3))
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    state = TrainState.create(params, opt)
    batch = _reduced_batch(cfg)
    first = None
    for i in range(8):
        state, metrics = step(state, batch)
        if first is None:
            first = float(metrics["loss"])
    last = float(metrics["loss"])
    assert np.isfinite(last)
    assert last < first, f"{arch}: loss {first} -> {last}"
    for leaf in jax.tree.leaves(state.params):
        assert np.isfinite(np.asarray(leaf)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_all_shapes(arch):
    cfg = get_config(arch)
    for name, shp in INPUT_SHAPES.items():
        kind = shp.kind
        spec = M.input_specs(cfg, batch=shp.global_batch, seq_len=shp.seq_len,
                             kind=kind)
        assert spec, (arch, name)
        for leaf in jax.tree.leaves(spec):
            assert isinstance(leaf, jax.ShapeDtypeStruct)
