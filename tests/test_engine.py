"""Scanned engine vs the legacy per-round loop: same seed -> same
trajectories, same final state, same byte ledgers — for PerMFL (with and
without comm) and the baselines — plus the unified-API/shim plumbing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import CommConfig, CommLedger
from repro.core import PerMFL, baselines as B
from repro.core.permfl import (PerMFLHParams, eval_stacked, init_state,
                               permfl_round)
from repro.core.participation import sample_masks
from repro.train import fl_trainer as FT
from repro.train.engine import run_experiment

M, N, D = 3, 4, 5


def quad_loss(params, batch):
    return 0.5 * jnp.sum((params - batch["c"]) ** 2)


def neg_loss(params, batch):
    return -quad_loss(params, batch)


@pytest.fixture(scope="module")
def quad_data():
    rng = np.random.default_rng(0)
    return {"c": jnp.asarray(rng.normal(size=(M, N, D)).astype(np.float32))}


HP = PerMFLHParams(alpha=0.05, eta=0.04, beta=0.3, lam=0.8, gamma=2.0,
                   k_team=3, l_local=4)


def legacy_permfl_loop(data, rounds, *, team_frac=1.0, device_frac=1.0,
                       seed=0, comm=None):
    """The pre-engine fl_trainer loop, verbatim semantics: host-side mask
    sampling, one permfl_round dispatch per round, eager eval, ungated-
    but-sampled ledger counts (sample_masks already gates devices)."""
    st = init_state(jnp.zeros(D), M, N, comm=comm)
    key = jax.random.PRNGKey(seed)
    ledger = None if comm is None else CommLedger.for_params(
        comm, jnp.zeros(D))
    pm, tm_acc, gm = [], [], []
    for _ in range(rounds):
        if team_frac < 1.0 or device_frac < 1.0:
            key, sub = jax.random.split(key)
            tm, dm = sample_masks(sub, M, N, team_frac=team_frac,
                                  device_frac=device_frac)
        else:
            tm = dm = None
        st = permfl_round(st, data, HP, quad_loss, m_teams=M, n_devices=N,
                          team_mask=tm, device_mask=dm, comm=comm)
        if ledger is not None:
            ledger.log_round(
                k_team=HP.k_team,
                n_teams=M if tm is None else int(tm.sum()),
                n_devices=M * N if dm is None else int(dm.sum()))
        pm.append(float(eval_stacked(st, data, neg_loss, which="pm").mean()))
        tm_acc.append(float(
            eval_stacked(st, data, neg_loss, which="tm").mean()))
        gm.append(float(eval_stacked(st, data, neg_loss, which="gm").mean()))
    return st, dict(pm=pm, tm=tm_acc, gm=gm), ledger


@pytest.mark.parametrize("team_frac,device_frac",
                         [(1.0, 1.0), (0.5, 0.75)])
def test_scanned_permfl_matches_legacy_loop(quad_data, team_frac,
                                            device_frac):
    st_ref, traj, _ = legacy_permfl_loop(quad_data, 6, team_frac=team_frac,
                                         device_frac=device_frac, seed=3)
    res = run_experiment(PerMFL(quad_loss, HP), jnp.zeros(D), quad_data,
                         quad_data, metric_fn=neg_loss, rounds=6, m=M, n=N,
                         team_frac=team_frac, device_frac=device_frac,
                         seed=3)
    np.testing.assert_allclose(res.pm_acc, traj["pm"], atol=1e-5)
    np.testing.assert_allclose(res.tm_acc, traj["tm"], atol=1e-5)
    np.testing.assert_allclose(res.gm_acc, traj["gm"], atol=1e-5)
    for a, b in zip(jax.tree.leaves((res.state.x, res.state.w,
                                     res.state.theta)),
                    jax.tree.leaves((st_ref.x, st_ref.w, st_ref.theta))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_scanned_permfl_comm_matches_legacy_ledger(quad_data):
    cfg = CommConfig("topk", k_frac=0.4)
    st_ref, traj, led_ref = legacy_permfl_loop(
        quad_data, 5, team_frac=0.5, seed=11, comm=cfg)
    res = run_experiment(PerMFL(quad_loss, HP, comm=cfg), jnp.zeros(D),
                         quad_data, quad_data, metric_fn=neg_loss, rounds=5,
                         m=M, n=N, team_frac=0.5, seed=11)
    np.testing.assert_allclose(res.pm_acc, traj["pm"], atol=1e-5)
    np.testing.assert_allclose(np.asarray(res.state.x),
                               np.asarray(st_ref.x), atol=1e-6)
    np.testing.assert_allclose(np.asarray(res.state.comm.ef_team),
                               np.asarray(st_ref.comm.ef_team), atol=1e-6)
    # byte totals identical between paths (sample_masks pre-gates devices,
    # so the legacy counts happen to be correct here)
    assert res.comm.total_bytes() == led_ref.total_bytes()
    assert len(res.comm.rounds) == len(led_ref.rounds) == 5


@pytest.mark.parametrize("runner,kw,fields", [
    (FT.run_fedavg, dict(lr=0.1, local_steps=3), ("gm_acc",)),
    (FT.run_ditto, dict(lr=0.05, lam=0.5, local_steps=3),
     ("pm_acc", "gm_acc")),
    (FT.run_l2gd, dict(lr=0.05, lam_c=0.5, lam_g=0.5, k_team=2, l_local=2),
     ("pm_acc", "gm_acc")),
])
def test_scanned_baselines_match_dispatch(quad_data, runner, kw, fields):
    common = dict(loss_fn=quad_loss, metric_fn=neg_loss, rounds=5, m=M, n=N)
    scanned = runner(jnp.zeros(D), quad_data, quad_data, **common, **kw)
    dispatch = runner(jnp.zeros(D), quad_data, quad_data, scan=False,
                      **common, **kw)
    for f in fields:
        np.testing.assert_allclose(getattr(scanned, f), getattr(dispatch, f),
                                   atol=1e-5)
        assert len(getattr(scanned, f)) == 5
    for a, b in zip(jax.tree.leaves(scanned.state),
                    jax.tree.leaves(dispatch.state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_all_algorithms_set_final_state(quad_data):
    """Every ALGORITHMS entry runs through the engine and exposes its
    final state (historically only permfl/fedavg did)."""
    kws = {
        "permfl": dict(hp=HP),
        "fedavg": dict(lr=0.1, local_steps=2),
        "perfedavg": dict(lr=0.05, inner_lr=0.05, local_steps=2),
        "pfedme": dict(lr=0.5, inner_lr=0.05, lam=2.0, inner_steps=2,
                       local_rounds=2),
        "ditto": dict(lr=0.05, lam=0.5, local_steps=2),
        "hsgd": dict(lr=0.05, k_team=2, l_local=2),
        "l2gd": dict(lr=0.05, lam_c=0.5, lam_g=0.5, k_team=2, l_local=2),
    }
    assert set(kws) == set(FT.ALGORITHMS)
    for name, runner in FT.ALGORITHMS.items():
        res = runner(jnp.zeros(D), quad_data, quad_data, loss_fn=quad_loss,
                     metric_fn=neg_loss, rounds=2, m=M, n=N, **kws[name])
        assert res.state is not None, name
        assert len(res.participation) == 2, name
        assert all(np.isfinite(np.asarray(l)).all()
                   for l in jax.tree.leaves(res.state)), name


def test_eval_every_chunking_and_remainder(quad_data):
    res = run_experiment(PerMFL(quad_loss, HP), jnp.zeros(D), quad_data,
                         quad_data, metric_fn=neg_loss, rounds=7, m=M, n=N,
                         eval_every=3)
    # evals after rounds 3, 6 and the remainder round 7
    assert len(res.pm_acc) == 3
    assert len(res.participation) == 7
    full = run_experiment(PerMFL(quad_loss, HP), jnp.zeros(D), quad_data,
                          quad_data, metric_fn=neg_loss, rounds=7, m=M, n=N)
    np.testing.assert_allclose(res.pm_acc[-1], full.pm_acc[-1], atol=1e-5)


def test_ledger_counts_gated_by_team_mask(quad_data):
    """Devices marked participating inside a masked-out team must not be
    billed: the engine's counts come from device_mask * team_mask."""
    cfg = CommConfig("topk", k_frac=0.4)
    # with team_frac=0.5 one of M=3 teams drops per round (sample keeps
    # max(1, round(1.5)) = 2): realized counts must be 2 teams, 2*N devices
    res = run_experiment(PerMFL(quad_loss, HP, comm=cfg), jnp.zeros(D),
                         quad_data, quad_data, metric_fn=neg_loss, rounds=3,
                         m=M, n=N, team_frac=0.5, seed=1)
    for n_teams, n_devices in res.participation:
        assert n_teams == 2
        assert n_devices == 2 * N
    r = res.comm.rounds[0]
    from repro.comm import model_bytes
    assert r.wan_up == 2 * model_bytes(res.comm.leaf_sizes, cfg)
    assert r.lan_up == HP.k_team * 2 * N * model_bytes(res.comm.leaf_sizes,
                                                       cfg)


def test_log_round_masks_gates_inconsistent_masks():
    cfg = CommConfig("sign")
    led = CommLedger.for_params(cfg, jnp.zeros(8))
    led.log_round_masks(k_team=2,
                        team_mask=np.array([1.0, 0.0]),
                        device_mask=np.ones((2, 3)))  # team 1 ungated
    ref = CommLedger.for_params(cfg, jnp.zeros(8))
    ref.log_round(k_team=2, n_teams=1, n_devices=3)
    assert led.total_bytes() == ref.total_bytes()


def test_mask_none_vs_array_shares_one_trace(quad_data):
    """Normalizing masks at the permfl_round boundary means flipping
    between None and arrays across rounds never re-traces."""
    from repro.core.permfl import _permfl_round
    d2 = 7  # unique param dim -> first call below is a fresh trace
    data = {"c": jnp.zeros((M, N, d2))}
    n_before = _permfl_round._cache_size()
    st = init_state(jnp.ones(d2), M, N)
    st = permfl_round(st, data, HP, quad_loss, m_teams=M, n_devices=N)
    assert _permfl_round._cache_size() == n_before + 1
    tm = jnp.array([1.0, 0.0, 1.0])
    dm = jnp.ones((M, N), jnp.float32) * tm[:, None]
    st = permfl_round(st, data, HP, quad_loss, m_teams=M, n_devices=N,
                      team_mask=tm, device_mask=dm)
    assert _permfl_round._cache_size() == n_before + 1


def test_algorithm_config_is_immutable_and_cache_safe(quad_data):
    """The engine caches compiled programs per algo instance, so instances
    must be frozen: reconfiguring means constructing a new instance (a
    mutated one would silently reuse the stale compiled program)."""
    import dataclasses

    algo = PerMFL(quad_loss, HP)
    with pytest.raises(dataclasses.FrozenInstanceError):
        algo.hp = PerMFLHParams()
    # equal config -> equal instances -> one shared compiled program
    assert PerMFL(quad_loss, HP) == PerMFL(quad_loss, HP)
    # different hp reaches the engine as a different program
    hp2 = PerMFLHParams(alpha=0.2, eta=0.1, beta=0.5, lam=0.3, gamma=1.0,
                        k_team=2, l_local=2)
    kw = dict(metric_fn=neg_loss, rounds=2, m=M, n=N)
    r1 = run_experiment(PerMFL(quad_loss, HP), jnp.zeros(D), quad_data,
                        quad_data, **kw)
    r2 = run_experiment(PerMFL(quad_loss, hp2), jnp.zeros(D), quad_data,
                        quad_data, **kw)
    assert r1.pm_acc != r2.pm_acc


def test_partial_participation_rejected_for_mask_blind_baselines(quad_data):
    """Baselines ignore the masks, so sampling them would make
    FLResult.participation report an experiment that never ran."""
    with pytest.raises(ValueError, match="participation"):
        run_experiment(B.FedAvg(quad_loss, lr=0.1, local_steps=2),
                       jnp.zeros(D), quad_data, quad_data,
                       metric_fn=neg_loss, rounds=2, m=M, n=N,
                       team_frac=0.5)


def test_fig3_sweep_matches_old_per_value_loop(quad_data):
    """fig3_hparams now runs its 9 grid points as one run_sweep program;
    pin one grid point against the old per-value loop (a fresh
    dataclasses.replace(HP_DEFAULT, ...) + run_experiment per value)."""
    import dataclasses

    from benchmarks.fig3_hparams import SWEEPS, sweep_grid
    from benchmarks.fl_common import HP_DEFAULT
    from repro.train.sweep import run_sweep

    grid = sweep_grid()
    assert len(grid) == 9
    # grid[3] is the first gamma point; rebuild its hp the way the old
    # loop did and check the sweep lane computes the same trajectory
    hname, (values, fixed) = "gamma", SWEEPS["gamma"]
    hp_old = dataclasses.replace(HP_DEFAULT, **fixed, **{hname: values[0]},
                                 alpha=0.01, eta=0.03)
    data = {"c": quad_data["c"]}
    ref = run_experiment(PerMFL(quad_loss, hp_old), jnp.zeros(D), data,
                         data, metric_fn=neg_loss, rounds=3, m=M, n=N)
    sw = run_sweep(PerMFL(quad_loss, HP_DEFAULT), grid, (0,), jnp.zeros(D),
                   data, data, metric_fn=neg_loss, rounds=3, m=M, n=N)
    np.testing.assert_allclose(sw[3].pm_acc, ref.pm_acc, atol=1e-5)
    np.testing.assert_allclose(sw[3].gm_acc, ref.gm_acc, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sw[3].state.x),
                               np.asarray(ref.state.x), atol=1e-6)


def test_engine_learns_on_fed_data(small_fed_data):
    """End-to-end through the unified API on real federated data: two
    algorithms, PM/GM structure preserved."""
    from repro.configs.paper_mclr import CONFIG as MCLR
    from repro.models import paper_models as PM

    fd = small_fed_data
    params = PM.init_params(jax.random.PRNGKey(0), MCLR)
    loss = lambda p, b: PM.loss_fn(p, MCLR, b)
    met = lambda p, b: PM.accuracy(p, MCLR, b)
    tr = {"x": jnp.asarray(fd.train_x), "y": jnp.asarray(fd.train_y)}
    va = {"x": jnp.asarray(fd.val_x), "y": jnp.asarray(fd.val_y)}
    kw = dict(metric_fn=met, rounds=6, m=fd.m_teams, n=fd.n_devices)

    r_p = run_experiment(PerMFL(loss, PerMFLHParams(k_team=3, l_local=5)),
                         params, tr, va, **kw)
    r_f = run_experiment(B.FedAvg(loss, lr=0.05, local_steps=15),
                         params, tr, va, **kw)
    assert r_p.pm_acc[-1] > 0.85
    assert r_p.pm_acc[-1] >= r_f.gm_acc[-1] - 0.02
    assert r_p.train_loss[-1] < r_p.train_loss[0]


# ---------------------------------------------------------------------------
# run-telemetry probes (repro.obs): measurement must not perturb anything
# ---------------------------------------------------------------------------

def test_probes_off_is_bit_identical(quad_data):
    """The observability tentpole's core guarantee: a probes-on run and a
    probes-off run of the same experiment produce exactly equal (not just
    close) trajectories, final states, and byte ledgers — probes only
    read the state."""
    comm = CommConfig(compressor="topk", k_frac=0.5)
    kw = dict(metric_fn=neg_loss, rounds=6, m=M, n=N, seed=3,
              eval_every=2, team_frac=0.5, device_frac=0.75)
    off = run_experiment(PerMFL(quad_loss, HP, comm=comm), jnp.zeros(D),
                         quad_data, quad_data, **kw)
    on = run_experiment(PerMFL(quad_loss, HP, comm=comm), jnp.zeros(D),
                        quad_data, quad_data, trace=True, **kw)
    for f in ("pm_acc", "tm_acc", "gm_acc", "train_loss"):
        np.testing.assert_array_equal(np.asarray(getattr(off, f)),
                                      np.asarray(getattr(on, f)), err_msg=f)
    for a, b in zip(jax.tree.leaves(off.state), jax.tree.leaves(on.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert off.participation == on.participation
    assert off.comm.totals() == on.comm.totals()
    assert off.trace is None and on.trace is not None


def test_probe_streams_scan_matches_dispatch(quad_data):
    """Probe values are per-round scan outputs under scan=True and eager
    per-dispatch values under scan=False; both execution models must
    report the same streams (same masks, same states, same probes)."""
    comm = CommConfig(compressor="topk", k_frac=0.5)
    kw = dict(metric_fn=neg_loss, rounds=5, m=M, n=N, seed=7,
              eval_every=2, team_frac=0.5, device_frac=0.75, trace=True)
    scan = run_experiment(PerMFL(quad_loss, HP, comm=comm), jnp.zeros(D),
                          quad_data, quad_data, scan=True, **kw)
    disp = run_experiment(PerMFL(quad_loss, HP, comm=comm), jnp.zeros(D),
                          quad_data, quad_data, scan=False, **kw)
    assert scan.trace.names() == disp.trace.names()
    assert len(scan.trace) == len(disp.trace) == 5
    for name in scan.trace.names():
        np.testing.assert_allclose(scan.trace[name], disp.trace[name],
                                   atol=1e-5, err_msg=name)
    # dispatch mode pays one call per round + one per eval point
    assert scan.dispatches == 2      # main chunks + remainder
    assert disp.dispatches == 5 + 3  # 5 rounds + evals at 2, 4, 5


def test_baseline_probe_round_generic_update_norm(quad_data):
    """Mask-blind baselines get the FLAlgorithmBase default probe set:
    the whole-state update norm only."""
    res = run_experiment(B.FedAvg(quad_loss, lr=0.05, local_steps=3),
                         jnp.zeros(D), quad_data, quad_data,
                         metric_fn=neg_loss, rounds=3, m=M, n=N,
                         trace=True)
    assert res.trace.names() == ["update_norm"]
    assert all(v > 0 for v in res.trace["update_norm"])
