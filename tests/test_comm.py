"""Tiered communication subsystem: compressors, error feedback, the
compressed PerMFL round, and the per-tier byte ledger."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (CommConfig, CommLedger, compress_tree,
                        compressed_leaf_bytes, full_leaf_bytes, leaf_k,
                        make_leaf_compressor, model_bytes)
from repro.core.permfl import PerMFLHParams, init_state, permfl_round

M, N, D = 3, 4, 5


def quad_loss(params, batch):
    return 0.5 * jnp.sum((params - batch["c"]) ** 2)


def _quad_setup(seed=0):
    rng = np.random.default_rng(seed)
    c = jnp.asarray(rng.normal(size=(M, N, D)).astype(np.float32))
    hp = PerMFLHParams(alpha=0.05, eta=0.04, beta=0.3, lam=0.8, gamma=2.0,
                       k_team=3, l_local=4)
    return {"c": c}, hp


# ---------------------------------------------------------------------------
# leaf compressors
# ---------------------------------------------------------------------------

KEY = jax.random.PRNGKey(0)
V = jax.random.normal(jax.random.PRNGKey(1), (300,))


def test_identity_is_exact():
    fn = make_leaf_compressor(CommConfig("identity"), V.size)
    np.testing.assert_array_equal(np.asarray(fn(KEY, V)), np.asarray(V))


def test_topk_keeps_k_largest_by_magnitude():
    cfg = CommConfig("topk", k_frac=0.1)
    k = leaf_k(cfg.k_frac, V.size)
    out = np.asarray(make_leaf_compressor(cfg, V.size)(KEY, V))
    v = np.asarray(V)
    nz = np.nonzero(out)[0]
    assert len(nz) == k == 30
    want = set(np.argsort(-np.abs(v))[:k])
    assert set(nz) == want
    np.testing.assert_array_equal(out[nz], v[nz])  # kept values untouched


def test_randk_contractive_keeps_k_unscaled():
    cfg = CommConfig("randk", k_frac=0.2, error_feedback=True)
    out = np.asarray(make_leaf_compressor(cfg, V.size)(KEY, V))
    nz = np.nonzero(out)[0]
    assert len(nz) == leaf_k(0.2, V.size)
    np.testing.assert_allclose(out[nz], np.asarray(V)[nz])


def test_randk_unbiased_when_no_error_feedback():
    cfg = CommConfig("randk", k_frac=0.25, error_feedback=False)
    fn = make_leaf_compressor(cfg, V.size)
    keys = jax.random.split(jax.random.PRNGKey(7), 400)
    outs = jax.vmap(lambda k: fn(k, V))(keys)
    # E[C(v)] = v for the p/k-rescaled rand-k
    err = np.abs(np.asarray(outs.mean(0)) - np.asarray(V)).mean()
    assert err < 0.15, err


def test_sign_is_scaled_sign():
    fn = make_leaf_compressor(CommConfig("sign"), V.size)
    out = np.asarray(fn(KEY, V))
    v = np.asarray(V)
    np.testing.assert_allclose(out, np.abs(v).mean() * np.sign(v), rtol=1e-6)


def test_int8_error_bounded_by_row_scale():
    fn = make_leaf_compressor(CommConfig("int8"), V.size)
    out = np.asarray(fn(KEY, V))
    v = np.asarray(V)
    # stochastic rounding error < 1 quantization step = rowmax/127
    rows = np.abs(np.pad(v, (0, (-len(v)) % 128)).reshape(-1, 128)).max(1)
    step = np.repeat(rows / 127.0, 128)[:len(v)]
    assert (np.abs(out - v) <= step + 1e-7).all()


def test_compress_tree_structure_and_batching():
    cfg = CommConfig("topk", k_frac=0.5)
    tree = {"a": jax.random.normal(KEY, (M, N, 6, 7)),
            "b": [jax.random.normal(KEY, (M, N, 9))]}
    out = compress_tree(cfg, KEY, tree, (M, N))
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    for o, t in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        assert o.shape == t.shape
    # per-sender sparsity: each (i, j) slice of "a" keeps exactly k coords
    k = leaf_k(0.5, 42)
    nz = (np.asarray(out["a"]).reshape(M, N, -1) != 0).sum(-1)
    assert (nz == k).all()


def test_error_feedback_transmits_everything_eventually():
    """EF invariant: sum_t C(delta + e_t) = T*delta - e_T; with a
    contractive C (top-k) e_T stays bounded, so the mean transmitted
    value converges to the true delta at rate 1/T."""
    cfg = CommConfig("topk", k_frac=0.25)
    fn = make_leaf_compressor(cfg, V.size)
    delta = np.asarray(V)
    e = np.zeros_like(delta)
    sent = np.zeros_like(delta)
    T = 200
    for t in range(T):
        msg = delta + e
        c = np.asarray(fn(KEY, jnp.asarray(msg)))
        e = msg - c
        sent += c
    np.testing.assert_allclose(sent / T, delta, atol=0.05)


# ---------------------------------------------------------------------------
# compressed PerMFL rounds
# ---------------------------------------------------------------------------

def test_identity_comm_round_matches_plain_round():
    data, hp = _quad_setup()
    cfg = CommConfig("identity")
    s_plain = init_state(jnp.zeros(D), M, N)
    s_comm = init_state(jnp.zeros(D), M, N, comm=cfg)
    for _ in range(3):
        s_plain = permfl_round(s_plain, data, hp, quad_loss,
                               m_teams=M, n_devices=N)
        s_comm = permfl_round(s_comm, data, hp, quad_loss,
                              m_teams=M, n_devices=N, comm=cfg)
    np.testing.assert_allclose(np.asarray(s_comm.x), np.asarray(s_plain.x),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(s_comm.w), np.asarray(s_plain.w),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(s_comm.theta),
                               np.asarray(s_plain.theta), atol=1e-6)
    # identity compression leaves no residual
    assert float(jnp.abs(s_comm.comm.ef_dev).max()) == 0.0
    assert float(jnp.abs(s_comm.comm.ef_team).max()) == 0.0


@pytest.mark.parametrize("name", ["topk", "randk", "int8", "sign"])
def test_comm_round_runs_and_threads_state(name):
    data, hp = _quad_setup()
    cfg = CommConfig(name, k_frac=0.4)
    st = init_state(jnp.zeros(D), M, N, comm=cfg)
    st = permfl_round(st, data, hp, quad_loss, m_teams=M, n_devices=N,
                      comm=cfg)
    assert st.comm is not None
    assert int(st.round) == 1
    for leaf in jax.tree.leaves((st.x, st.w, st.theta, st.comm.ef_dev,
                                 st.comm.ef_team)):
        assert np.isfinite(np.asarray(leaf)).all()
    # lossy compressors leave a nonzero residual somewhere
    assert float(jnp.abs(st.comm.ef_dev).max()) > 0.0


def test_comm_round_requires_comm_state():
    data, hp = _quad_setup()
    cfg = CommConfig("topk")
    st = init_state(jnp.zeros(D), M, N)          # no CommState
    with pytest.raises(ValueError, match="CommState"):
        permfl_round(st, data, hp, quad_loss, m_teams=M, n_devices=N,
                     comm=cfg)


def test_nonparticipating_senders_keep_their_residuals():
    data, hp = _quad_setup()
    cfg = CommConfig("topk", k_frac=0.2)
    tm = jnp.array([1.0, 0.0, 1.0])
    dm = jnp.ones((M, N), jnp.float32) * tm[:, None]
    st = init_state(jnp.zeros(D), M, N, comm=cfg)
    st = permfl_round(st, data, hp, quad_loss, m_teams=M, n_devices=N,
                      team_mask=tm, device_mask=dm, comm=cfg)
    # team 1 (and its devices) never transmitted: residuals stay zero
    assert float(jnp.abs(st.comm.ef_team[1]).max()) == 0.0
    assert float(jnp.abs(st.comm.ef_dev[1]).max()) == 0.0
    assert float(jnp.abs(st.comm.ef_team[0]).max()) > 0.0


def test_inconsistent_masks_do_not_record_undelivered_uplinks():
    """team_mask with device_mask=None: devices of masked-out teams run
    locally but never transmit, so their EF residuals must stay zero."""
    data, hp = _quad_setup()
    cfg = CommConfig("topk", k_frac=0.2)
    tm = jnp.array([1.0, 0.0, 1.0])
    st = init_state(jnp.zeros(D), M, N, comm=cfg)
    st = permfl_round(st, data, hp, quad_loss, m_teams=M, n_devices=N,
                      team_mask=tm, comm=cfg)
    assert float(jnp.abs(st.comm.ef_dev[1]).max()) == 0.0
    assert float(jnp.abs(st.comm.ef_dev[0]).max()) > 0.0


def test_compressed_quadratic_converges_to_neighborhood():
    """EF-compressed PerMFL settles in a small ball around x* = mean(c).

    The device->team deltas (theta - w) are *nonzero* at the fixed point,
    so their compression error never vanishes; error feedback bounds the
    bias, leaving x oscillating in an O(compression error) neighborhood
    rather than converging exactly (||x0 - x*|| here is ~0.5)."""
    data, _ = _quad_setup(seed=3)
    hp = PerMFLHParams(alpha=0.2, eta=0.05, beta=0.2, lam=1.0, gamma=3.0,
                       k_team=4, l_local=10)
    cfg = CommConfig("topk", k_frac=0.4)
    st = init_state(jnp.zeros(D), M, N, comm=cfg)
    x_star = np.asarray(data["c"]).mean(axis=(0, 1))
    for _ in range(150):
        st = permfl_round(st, data, hp, quad_loss, m_teams=M, n_devices=N,
                          comm=cfg)
    assert np.abs(np.asarray(st.x) - x_star).max() < 0.1
    # and the EF residuals stay bounded (no blow-up)
    assert float(jnp.abs(st.comm.ef_dev).max()) < 10.0


# ---------------------------------------------------------------------------
# end-to-end: run_permfl(..., comm=...) on the synthetic task
# ---------------------------------------------------------------------------

def test_run_permfl_comm_end_to_end(small_fed_data):
    from repro.configs.paper_mclr import CONFIG as MCLR
    from repro.models import paper_models as PM
    from repro.train.fl_trainer import run_permfl

    fd = small_fed_data
    params = PM.init_params(jax.random.PRNGKey(0), MCLR)
    hp = PerMFLHParams(k_team=3, l_local=5)
    loss = lambda p, b: PM.loss_fn(p, MCLR, b)
    met = lambda p, b: PM.accuracy(p, MCLR, b)
    tr = {"x": jnp.asarray(fd.train_x), "y": jnp.asarray(fd.train_y)}
    va = {"x": jnp.asarray(fd.val_x), "y": jnp.asarray(fd.val_y)}
    kw = dict(loss_fn=loss, metric_fn=met, hp=hp, rounds=6,
              m=fd.m_teams, n=fd.n_devices)

    base = run_permfl(params, tr, va, **kw)
    comp = run_permfl(params, tr, va,
                      comm=CommConfig("topk", k_frac=0.1), **kw)

    # acceptance: converges within 2 points of the uncompressed run
    assert comp.pm_acc[-1] >= base.pm_acc[-1] - 0.02, \
        (comp.pm_acc[-1], base.pm_acc[-1])
    # per-tier bytes reported in FLResult
    assert comp.comm is not None and len(comp.comm.rounds) == 6
    t = comp.comm.totals()
    assert t.wan_up > 0 and t.lan_up > 0
    # top-10% uplink is far below the fp32 downlink on the same links
    assert t.wan_up < t.wan_down / 4
    assert t.lan_up < t.lan_down / 4
    assert comp.comm.total_bytes() < comp.comm.uncompressed_total()
    assert base.comm is None
    assert comp.state is not None and base.state is not None


# ---------------------------------------------------------------------------
# ledger byte model
# ---------------------------------------------------------------------------

def test_leaf_byte_model():
    p = 1000
    assert full_leaf_bytes(p) == 4000
    assert compressed_leaf_bytes(CommConfig("identity"), p) == 4000
    assert compressed_leaf_bytes(CommConfig("topk", k_frac=0.1), p) == 8 * 100
    assert compressed_leaf_bytes(CommConfig("randk", k_frac=0.1), p) == 404
    assert compressed_leaf_bytes(CommConfig("int8"), p) == 1000 + 4 * 8
    assert compressed_leaf_bytes(CommConfig("sign"), p) == 125 + 4


def test_ledger_round_math():
    cfg = CommConfig("topk", k_frac=0.5)
    params = {"a": jnp.zeros((10, 10)), "b": jnp.zeros((20,))}
    led = CommLedger.for_params(cfg, params)
    assert sorted(led.leaf_sizes) == [20, 100]
    led.log_round(k_team=5, n_teams=3, n_devices=12)
    full = model_bytes(led.leaf_sizes)             # 480
    comp = model_bytes(led.leaf_sizes, cfg)        # 8*(50+10) = 480/...
    r = led.rounds[0]
    assert r.wan_down == 3 * full
    assert r.wan_up == 3 * comp
    assert r.lan_down == 5 * 12 * full
    assert r.lan_up == 5 * 12 * comp
    assert r.total == r.wan_up + r.wan_down + r.lan_up + r.lan_down
    led.log_round(k_team=5, n_teams=1, n_devices=4)
    assert led.totals().wan_down == 4 * full
    s = led.summary()
    assert s["rounds"] == 2 and s["total_bytes"] == led.total_bytes()
    assert s["uncompressed_bytes"] >= s["total_bytes"]


def test_ledger_partial_participation_counts_less():
    cfg = CommConfig("int8")
    params = jnp.zeros((513,))
    led = CommLedger.for_params(cfg, params)
    led.log_round(k_team=2, n_teams=4, n_devices=40)
    led_partial = CommLedger.for_params(cfg, params)
    led_partial.log_round(k_team=2, n_teams=2, n_devices=20)
    assert led_partial.total_bytes() == led.total_bytes() // 2
