"""Direct unit tests for train.metrics: token_accuracy / perplexity /
RunningMean (previously only exercised transitively)."""
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.metrics import RunningMean, perplexity, token_accuracy


def test_token_accuracy_counts_only_unpadded():
    logits = jnp.asarray([[[0.1, 0.9], [0.8, 0.2], [0.3, 0.7]]])
    targets = jnp.asarray([[1, 1, -1]])     # last position is padding
    # predictions: [1, 0, 1] -> correct on pos 0, wrong on pos 1, pos 2
    # masked out entirely
    assert float(token_accuracy(logits, targets)) == pytest.approx(0.5)


def test_token_accuracy_all_padding_is_zero_not_nan():
    logits = jnp.zeros((1, 2, 3))
    targets = jnp.full((1, 2), -1)
    assert float(token_accuracy(logits, targets)) == 0.0


def test_perplexity_is_exp_loss():
    assert float(perplexity(jnp.asarray(0.0))) == pytest.approx(1.0)
    assert float(perplexity(jnp.asarray(2.0))) == pytest.approx(math.e ** 2)


def test_running_mean_weighted():
    rm = RunningMean()
    rm.update(1.0)
    rm.update(4.0, n=3)
    assert rm.mean == pytest.approx((1.0 + 4.0 * 3) / 4)
    assert rm.count == 4


def test_running_mean_empty_is_zero():
    assert RunningMean().mean == 0.0


def test_running_mean_rejects_nonpositive_n():
    rm = RunningMean()
    with pytest.raises(ValueError):
        rm.update(1.0, n=0)
    with pytest.raises(ValueError):
        rm.update(1.0, n=-2)
    # rejected updates must not have touched the aggregate
    assert rm.count == 0 and rm.mean == 0.0


def test_running_mean_rejects_non_integer_n():
    with pytest.raises(TypeError):
        RunningMean().update(1.0, n=2.5)


def test_running_mean_reset():
    rm = RunningMean()
    rm.update(5.0, n=2)
    rm.reset()
    assert rm.count == 0 and rm.mean == 0.0
    rm.update(3.0)
    assert rm.mean == pytest.approx(3.0)


def test_running_mean_accepts_numpy_ints():
    rm = RunningMean()
    rm.update(2.0, n=np.int64(2))
    assert rm.count == 2 and rm.mean == pytest.approx(2.0)
