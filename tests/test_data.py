"""Data pipeline: non-IID partitioners, team formation, synthetic sets."""
import numpy as np
import pytest

from repro.core.team_formation import assign_devices, label_pools
from repro.data.federated import partition_label_skew, partition_tabular
from repro.data.synthetic import make_dataset, synthetic_tabular


def test_label_skew_two_classes_per_device():
    rng = np.random.default_rng(0)
    x, y = make_dataset("mnist", rng, n_per_class=100)
    fd = partition_label_skew(rng, x, y, m_teams=4, n_devices=5,
                              classes_per_device=2, samples_per_device=40)
    assert fd.train_x.shape[:2] == (4, 5)
    assert fd.train_x.shape[2] + fd.val_x.shape[2] == 40
    for i in range(4):
        for j in range(5):
            labels = set(np.unique(fd.train_y[i, j])) | \
                set(np.unique(fd.val_y[i, j]))
            assert len(labels) <= 2, f"device ({i},{j}) has {labels}"


def test_label_skew_team_pools_worst_case():
    """Worst-case formation (paper §4.1.4): team pools are disjoint."""
    rng = np.random.default_rng(1)
    x, y = make_dataset("mnist", rng, n_per_class=100)
    fd = partition_label_skew(rng, x, y, m_teams=2, n_devices=4,
                              strategy="worst", samples_per_device=40)
    t0 = set(np.unique(fd.train_y[0])) | set(np.unique(fd.val_y[0]))
    t1 = set(np.unique(fd.train_y[1])) | set(np.unique(fd.val_y[1]))
    assert t0.isdisjoint(t1), (t0, t1)
    assert t0 <= {0, 1, 2, 3, 4} and t1 <= {5, 6, 7, 8, 9}


def test_label_pools_average_case_overlap():
    pools = label_pools("average", 2, 10)
    s0, s1 = set(pools[0]), set(pools[1])
    assert s0 & s1, "average-case pools must overlap"
    assert s0 | s1 == set(range(10))


def test_label_pools_random_covers_all():
    pools = label_pools("random", 4, 10)
    assert all(set(p) == set(range(10)) for p in pools)


def test_assign_devices_partitions():
    teams = assign_devices(np.random.default_rng(0), 4, 5)
    assert teams.shape == (4, 5)
    assert sorted(teams.ravel().tolist()) == list(range(20))


def test_synthetic_tabular_shapes_and_power_law():
    rng = np.random.default_rng(2)
    devs = synthetic_tabular(rng, 30, alpha=0.5, beta=0.5)
    assert len(devs) == 30
    sizes = np.array([len(y) for _, y in devs])
    assert sizes.min() >= 250 and sizes.max() <= 25_810
    assert sizes.std() > 0  # heterogeneous sizes
    for x, y in devs[:3]:
        assert x.shape[1] == 60
        assert x.dtype == np.float32 and y.dtype == np.int32
        assert ((y >= 0) & (y < 10)).all()


def test_synthetic_tabular_heterogeneity_grows_with_beta():
    """Larger beta-bar = more data heterogeneity: device feature means
    spread further apart."""
    def mean_spread(beta):
        rng = np.random.default_rng(3)
        devs = synthetic_tabular(rng, 20, alpha=0.5, beta=beta)
        means = np.stack([x.mean(0) for x, _ in devs])
        return float(means.std(0).mean())

    assert mean_spread(2.0) > mean_spread(0.01)


def test_partition_tabular_rectangular():
    rng = np.random.default_rng(4)
    devs = synthetic_tabular(rng, 12, min_samples=30, max_samples=60)
    fd = partition_tabular(devs, m_teams=3, n_devices=4,
                           samples_per_device=24)
    assert fd.train_x.shape == (3, 4, 18, 60)
    assert fd.val_x.shape == (3, 4, 6, 60)


def test_make_dataset_separability_ordering():
    """Dataset difficulty must mirror the real suite: a linear probe does
    better on synthetic-mnist than synthetic-fmnist."""
    from repro.configs.paper_mclr import CONFIG as MCLR
    import jax
    import jax.numpy as jnp
    from repro.models import paper_models as PM

    accs = {}
    for name in ("mnist", "fmnist"):
        rng = np.random.default_rng(6)
        x, y = make_dataset(name, rng, n_per_class=120)
        params = PM.init_params(jax.random.PRNGKey(0), MCLR)
        tr = {"x": jnp.asarray(x[:600]), "y": jnp.asarray(y[:600])}
        va = {"x": jnp.asarray(x[600:1200]), "y": jnp.asarray(y[600:1200])}
        grad = jax.jit(jax.grad(lambda p, b: PM.loss_fn(p, MCLR, b)))
        for _ in range(60):
            g = grad(params, tr)
            params = jax.tree.map(lambda p, gg: p - 0.1 * gg, params, g)
        accs[name] = float(PM.loss_fn(params, MCLR, va))  # held-out loss
    # lower val loss = easier dataset (accuracy saturates on both)
    assert accs["mnist"] < accs["fmnist"], accs


def test_token_stream():
    from repro.data.tokens import lm_batches

    it = lm_batches(np.random.default_rng(0), 128, batch=4, seq_len=16,
                    steps=3)
    b1 = next(it)
    assert b1["tokens"].shape == (4, 16)
    assert b1["targets"].shape == (4, 16)
    assert (b1["tokens"] < 128).all() and (b1["tokens"] >= 0).all()
    # next-token alignment: targets are tokens shifted by one
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["targets"][:, :-1])


def test_federated_lm_topic_structure():
    from repro.data.tokens import federated_lm_data

    d = federated_lm_data(np.random.default_rng(1), 64, m_teams=2,
                          n_devices=2, seq_len=8, seqs_per_device=4)
    assert d["tokens"].shape == (2, 2, 4, 8)
    assert d["targets"].shape == (2, 2, 4, 8)
