"""PerMFL Algorithm 1 correctness.

The strongest test: a pure-numpy transliteration of Algorithm 1 for the
quadratic loss f_ij(th) = 0.5 ||th - c_ij||^2 must match `permfl_round`
bit-for-bit (up to f32 accumulation). Plus: contraction to the known
closed-form fixed point, theory-rate validation on MCLR, and
participation-mask semantics."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.permfl import (PerMFLHParams, eval_stacked, init_state,
                               permfl_round)

M, N, D = 3, 4, 5


def quad_loss(params, batch):
    return 0.5 * jnp.sum((params - batch["c"]) ** 2)


def numpy_algorithm1(x0, c, hp, T, team_mask=None, device_mask=None):
    """Pure-python/NumPy Algorithm 1 (full participation unless masked)."""
    m, n, d = c.shape
    tm = np.ones(m) if team_mask is None else np.asarray(team_mask, float)
    dm = np.ones((m, n)) if device_mask is None else np.asarray(device_mask,
                                                                float)
    x = x0.copy()
    w_prev = np.repeat(x0[None], m, 0)
    theta_prev = np.repeat(w_prev[:, None], n, 1)
    for t in range(T):
        w = np.repeat(x[None], m, 0)
        theta = None
        for k in range(hp.k_team):
            theta = np.repeat(w[:, None], n, 1)
            for l in range(hp.l_local):
                grad = theta - c
                theta = theta - hp.alpha * grad - hp.alpha * hp.lam * (
                    theta - w[:, None])
            # masked device mean with fallback w
            num = (theta * dm[..., None]).sum(1)
            den = dm.sum(1)[:, None]
            theta_bar = np.where(den > 0, num / np.maximum(den, 1.0), w)
            cfac = 1 - hp.eta * hp.lam - hp.eta * hp.gamma
            w = cfac * w + hp.eta * hp.gamma * x[None] + \
                hp.lam * hp.eta * theta_bar
        w_eff = np.where(tm[:, None] > 0, w, w_prev)
        num = (w_eff * tm[:, None]).sum(0)
        den = tm.sum()
        w_bar = num / max(den, 1.0) if den > 0 else x
        x = (1 - hp.beta * hp.gamma) * x + hp.beta * hp.gamma * w_bar
        theta_eff = np.where(dm[..., None] > 0, theta, theta_prev)
        w_prev, theta_prev = w_eff, theta_eff
    return x, w_prev, theta_prev


@pytest.mark.parametrize("masked", [False, True])
def test_round_matches_numpy_oracle(masked):
    rng = np.random.default_rng(42)
    c = rng.normal(size=(M, N, D)).astype(np.float32)
    x0 = rng.normal(size=(D,)).astype(np.float32)
    hp = PerMFLHParams(alpha=0.05, eta=0.04, beta=0.3, lam=0.8, gamma=2.0,
                       k_team=3, l_local=4)
    tm = dm = None
    if masked:
        tm = jnp.array([1.0, 0.0, 1.0])
        dm = jnp.array(rng.integers(0, 2, (M, N)), jnp.float32)

    st = init_state(jnp.asarray(x0), M, N)
    data = {"c": jnp.asarray(c)}
    for _ in range(2):
        st = permfl_round(st, data, hp, quad_loss, m_teams=M, n_devices=N,
                          team_mask=tm, device_mask=dm)
    x_np, w_np, th_np = numpy_algorithm1(
        x0, c, hp, T=2,
        team_mask=None if tm is None else np.asarray(tm),
        device_mask=None if dm is None else np.asarray(dm))
    np.testing.assert_allclose(np.asarray(st.x), x_np, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(st.w), w_np, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(st.theta), th_np, atol=1e-5,
                               rtol=1e-5)


def test_quadratic_fixed_point():
    """For quadratic losses the optimum is computable: as T->inf with
    admissible steps, x -> mean(c) and theta interpolates c and w."""
    rng = np.random.default_rng(0)
    c = rng.normal(size=(M, N, D)).astype(np.float32)
    # alpha <= 1/(L_f+lam) = 0.5 (Thm 1); 40 steps at 0.2 contract the
    # device subproblem by (1-0.4)^40 ~ 1e-9, so theta hits its prox point.
    hp = PerMFLHParams(alpha=0.2, eta=0.05, beta=0.2, lam=1.0, gamma=3.0,
                       k_team=8, l_local=40)
    st = init_state(jnp.zeros(D), M, N)
    data = {"c": jnp.asarray(c)}
    for _ in range(200):
        st = permfl_round(st, data, hp, quad_loss, m_teams=M, n_devices=N)
    # Fixed point of the coupled system (see paper eq. 2 with quadratic f):
    # theta* = (c + lam w) / (1 + lam), stationarity up the tiers gives
    # x* = global mean of c.
    x_star = c.mean(axis=(0, 1))
    np.testing.assert_allclose(np.asarray(st.x), x_star, atol=1e-3)
    w = np.asarray(st.w)
    th_star = (c + hp.lam * w[:, None]) / (1 + hp.lam)
    # theta is the prox point of w^{t,K-1} (the anchor of the final team
    # iteration), while st.w is w^{t,K}; near the fixed point those differ
    # by O(eta) -> allow 5e-3.
    np.testing.assert_allclose(np.asarray(st.theta), th_star, atol=5e-3)


def test_linear_rate_strongly_convex():
    """Theorem 1: ||x^T - x*||^2 <= 2 (1-beta)^T ||x0 - x*||^2 — verify a
    linear (geometric) error decay on the quadratic problem."""
    rng = np.random.default_rng(1)
    c = rng.normal(size=(M, N, D)).astype(np.float32)
    hp = PerMFLHParams(alpha=0.1, eta=0.05, beta=0.2, lam=1.0, gamma=3.0,
                       k_team=10, l_local=20)
    st = init_state(jnp.zeros(D), M, N)
    data = {"c": jnp.asarray(c)}
    x_star = c.mean(axis=(0, 1))
    errs = []
    for t in range(30):
        st = permfl_round(st, data, hp, quad_loss, m_teams=M, n_devices=N)
        errs.append(float(np.sum((np.asarray(st.x) - x_star) ** 2)))
    errs = np.array(errs)
    # geometric decay: log-error decreases ~linearly until the noise floor
    logs = np.log(np.maximum(errs[:12], 1e-30))
    slopes = np.diff(logs)
    assert (slopes < 0).all(), f"error not monotone: {errs[:12]}"
    assert np.std(slopes) < 0.35 * abs(np.mean(slopes)), \
        f"decay not linear: slopes={slopes}"


def test_nonparticipating_team_does_not_move():
    rng = np.random.default_rng(2)
    c = rng.normal(size=(M, N, D)).astype(np.float32)
    hp = PerMFLHParams(k_team=2, l_local=2)
    st = init_state(jnp.zeros(D), M, N)
    data = {"c": jnp.asarray(c)}
    st1 = permfl_round(st, data, hp, quad_loss, m_teams=M, n_devices=N)
    tm = jnp.array([1.0, 0.0, 1.0])
    st2 = permfl_round(st1, data, hp, quad_loss, m_teams=M, n_devices=N,
                       team_mask=tm)
    np.testing.assert_array_equal(np.asarray(st2.w[1]), np.asarray(st1.w[1]))
    # participating teams did move
    assert not np.allclose(np.asarray(st2.w[0]), np.asarray(st1.w[0]))


def test_lambda_zero_decouples_devices():
    """lam=0: device steps are plain SGD from w; theta is unregularized."""
    rng = np.random.default_rng(3)
    c = rng.normal(size=(M, N, D)).astype(np.float32)
    hp = PerMFLHParams(alpha=0.5, lam=0.0, gamma=1.0, eta=0.1, beta=0.1,
                       k_team=1, l_local=50)
    st = init_state(jnp.zeros(D), M, N)
    data = {"c": jnp.asarray(c)}
    st = permfl_round(st, data, hp, quad_loss, m_teams=M, n_devices=N)
    # 50 steps of lr=0.5 on a 1-strongly-convex quadratic -> theta ~= c
    np.testing.assert_allclose(np.asarray(st.theta), c, atol=1e-4)


def test_eval_stacked_shapes(small_fed_data):
    from repro.configs.paper_mclr import CONFIG as MCLR
    from repro.models import paper_models as PM

    fd = small_fed_data
    params = PM.init_params(jax.random.PRNGKey(0), MCLR)
    st = init_state(params, fd.m_teams, fd.n_devices)
    val = {"x": jnp.asarray(fd.val_x), "y": jnp.asarray(fd.val_y)}
    met = lambda p, b: PM.accuracy(p, MCLR, b)
    for which in ("pm", "tm", "gm"):
        out = eval_stacked(st, val, met, which=which)
        assert out.shape == (fd.m_teams, fd.n_devices)
        assert np.isfinite(np.asarray(out)).all()


def test_permfl_learns_mclr(small_fed_data):
    """End-to-end on label-skewed image data: PM accuracy >> GM accuracy
    after a few rounds (the paper's core empirical claim)."""
    from repro.configs.paper_mclr import CONFIG as MCLR
    from repro.models import paper_models as PM

    fd = small_fed_data
    params = PM.init_params(jax.random.PRNGKey(0), MCLR)
    st = init_state(params, fd.m_teams, fd.n_devices)
    hp = PerMFLHParams(k_team=3, l_local=5)
    loss = lambda p, b: PM.loss_fn(p, MCLR, b)
    met = lambda p, b: PM.accuracy(p, MCLR, b)
    tr = {"x": jnp.asarray(fd.train_x), "y": jnp.asarray(fd.train_y)}
    va = {"x": jnp.asarray(fd.val_x), "y": jnp.asarray(fd.val_y)}
    for _ in range(8):
        st = permfl_round(st, tr, hp, loss, m_teams=fd.m_teams,
                          n_devices=fd.n_devices)
    pm = float(eval_stacked(st, va, met, which="pm").mean())
    gm = float(eval_stacked(st, va, met, which="gm").mean())
    assert pm > 0.9, pm
    assert pm > gm + 0.1, (pm, gm)
