"""Hypothesis property tests on PerMFL invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.permfl import (PerMFLHParams, _masked_mean, init_state,
                               permfl_round)

SET = dict(max_examples=15, deadline=None)


def quad_loss(params, batch):
    return 0.5 * jnp.sum((params - batch["c"]) ** 2)


small_f = st.floats(min_value=-3.0, max_value=3.0, allow_nan=False,
                    width=32)


# ---------------------------------------------------------------------------
# _masked_mean
# ---------------------------------------------------------------------------

@settings(**SET)
@given(st.integers(2, 5), st.integers(2, 5), st.integers(0))
def test_masked_mean_full_mask_is_mean(m, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, n, 3)).astype(np.float32))
    mask = jnp.ones((m, n), jnp.float32)
    out = _masked_mean({"a": x}, mask, axis=1)["a"]
    np.testing.assert_allclose(np.asarray(out), np.asarray(x.mean(1)),
                               atol=1e-6)


@settings(**SET)
@given(st.integers(2, 5), st.integers(2, 6), st.integers(0),
       st.integers(0, 100))
def test_masked_mean_ignores_masked_rows(m, n, seed, mseed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, n, 2)).astype(np.float32)
    mask = np.random.default_rng(mseed).integers(0, 2, (m, n)).astype(
        np.float32)
    fb = rng.normal(size=(m, 2)).astype(np.float32)
    out = np.asarray(_masked_mean({"a": jnp.asarray(x)},
                                  jnp.asarray(mask), axis=1,
                                  fallback={"a": jnp.asarray(fb)})["a"])
    for i in range(m):
        sel = mask[i] > 0
        want = x[i][sel].mean(0) if sel.any() else fb[i]
        np.testing.assert_allclose(out[i], want, atol=1e-5)


# ---------------------------------------------------------------------------
# Fixed point / pull-strength invariants
# ---------------------------------------------------------------------------

@settings(**SET)
@given(small_f, st.integers(0))
def test_identical_optimum_is_fixed_point(cval, seed):
    """If every device's optimum is the same c and all tiers start at c,
    one round leaves the state at c (gradients vanish, pulls vanish)."""
    m, n, d = 2, 3, 4
    c = jnp.full((m, n, d), cval, jnp.float32)
    hp = PerMFLHParams(alpha=0.1, eta=0.05, beta=0.3, lam=1.0, gamma=2.0,
                       k_team=2, l_local=3)
    st0 = init_state(jnp.full((d,), cval), m, n)
    st1 = permfl_round(st0, {"c": c}, hp, quad_loss, m_teams=m, n_devices=n)
    np.testing.assert_allclose(np.asarray(st1.x), cval, atol=1e-6)
    np.testing.assert_allclose(np.asarray(st1.w), cval, atol=1e-6)
    np.testing.assert_allclose(np.asarray(st1.theta), cval, atol=1e-6)


@settings(**SET)
@given(st.integers(0), st.floats(5.0, 50.0))
def test_larger_gamma_keeps_teams_closer_to_global(seed, gamma_hi):
    """gamma controls the team<->global pull: larger gamma => smaller
    ||w_i - x|| after a round (paper §3.2)."""
    m, n, d = 3, 2, 4
    rng = np.random.default_rng(seed)
    c = jnp.asarray(rng.normal(size=(m, n, d)).astype(np.float32))
    st0 = init_state(jnp.zeros(d), m, n)

    def spread(gamma):
        # eta scaled to respect eta <= 1/(2(lam+gamma)) for both gammas
        hp = PerMFLHParams(alpha=0.05, eta=1.0 / (2 * (0.5 + gamma_hi + 1)),
                           beta=0.1, lam=0.5, gamma=gamma, k_team=4,
                           l_local=4)
        s = permfl_round(st0, {"c": c}, hp, quad_loss, m_teams=m,
                         n_devices=n)
        # distance of team models from the (x0 = 0) global anchor
        return float(jnp.sum(jnp.square(s.w)))

    lo = spread(1.0)
    hi = spread(gamma_hi)
    assert hi <= lo + 1e-9, (lo, hi)


@settings(**SET)
@given(st.integers(0), st.floats(5.0, 40.0))
def test_larger_lambda_keeps_devices_closer_to_team(seed, lam_hi):
    m, n, d = 2, 3, 4
    rng = np.random.default_rng(seed)
    c = jnp.asarray(rng.normal(size=(m, n, d)).astype(np.float32))
    st0 = init_state(jnp.zeros(d), m, n)

    def spread(lam):
        alpha = 1.0 / (1.0 + lam_hi + 1)   # alpha <= 1/(L_f+lam)
        hp = PerMFLHParams(alpha=alpha, eta=0.01, beta=0.1, lam=lam,
                           gamma=2 * lam_hi + 1, k_team=2, l_local=6)
        s = permfl_round(st0, {"c": c}, hp, quad_loss, m_teams=m,
                         n_devices=n)
        return float(jnp.sum((s.theta - np.asarray(s.w)[:, None]) ** 2))

    assert spread(lam_hi) <= spread(0.5) + 1e-9


@settings(**SET)
@given(st.integers(0))
def test_round_is_deterministic(seed):
    m, n, d = 2, 2, 3
    rng = np.random.default_rng(seed)
    c = jnp.asarray(rng.normal(size=(m, n, d)).astype(np.float32))
    hp = PerMFLHParams(k_team=2, l_local=2)
    st0 = init_state(jnp.zeros(d), m, n)
    s1 = permfl_round(st0, {"c": c}, hp, quad_loss, m_teams=m, n_devices=n)
    s2 = permfl_round(st0, {"c": c}, hp, quad_loss, m_teams=m, n_devices=n)
    np.testing.assert_array_equal(np.asarray(s1.x), np.asarray(s2.x))
    np.testing.assert_array_equal(np.asarray(s1.theta), np.asarray(s2.theta))


# ---------------------------------------------------------------------------
# prox_sgd ref formula properties
# ---------------------------------------------------------------------------

@settings(**SET)
@given(st.integers(1, 300), small_f, st.floats(0.0, 2.0),
       st.floats(0.001, 0.3))
def test_prox_step_interpolates_toward_anchor(n, val, lam, alpha):
    """With zero gradient the prox step is a convex pull toward the anchor:
    theta' = theta - alpha*lam*(theta - w), strictly between theta and w."""
    from repro.kernels.prox_update.ref import prox_sgd_ref

    theta = jnp.full((n,), val + 1.0)
    w = jnp.full((n,), val)
    g = jnp.zeros((n,))
    t2, _ = prox_sgd_ref(theta, g, w, alpha=alpha, lam=lam)
    expect = theta - alpha * lam * (theta - w)
    np.testing.assert_allclose(np.asarray(t2), np.asarray(expect), atol=1e-6)
    if lam > 0 and alpha * lam < 1:
        assert ((np.asarray(t2) >= np.asarray(w)).all() and
                (np.asarray(t2) <= np.asarray(theta)).all())


# ---------------------------------------------------------------------------
# participation sampling
# ---------------------------------------------------------------------------

@settings(**SET)
@given(st.integers(0, 1000), st.floats(0.1, 1.0), st.floats(0.1, 1.0))
def test_sample_masks_counts(seed, tf, df):
    from repro.core.participation import sample_masks

    m, n = 8, 10
    tm, dm = sample_masks(jax.random.PRNGKey(seed), m, n, team_frac=tf,
                          device_frac=df)
    tm, dm = np.asarray(tm), np.asarray(dm)
    assert tm.shape == (m,) and dm.shape == (m, n)
    assert set(np.unique(tm)) <= {0.0, 1.0}
    # at least one team participates; devices only within sampled teams
    assert tm.sum() >= 1
    assert (dm.sum(1)[tm > 0] >= 1).all()
    assert (dm.sum(1)[tm == 0] == 0).all()
    assert tm.sum() == max(1, round(tf * m))
