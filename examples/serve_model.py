"""Batched serving: prefill + autoregressive decode with a KV/state cache.

    PYTHONPATH=src python examples/serve_model.py --arch rwkv6-7b --new 24

Loads a REDUCED variant of any assigned arch (dense KV cache, RWKV/Mamba
recurrent state, or Whisper cross-attention — all four cache families),
generates continuations for a batch of prompts, and reports tokens/s.
The same prefill/decode steps are what the decode_32k / long_500k
dry-runs lower onto the production mesh.

Serving quickstart — the *personalized* path (DESIGN.md §12):

    PYTHONPATH=src python examples/serve_model.py --personalized

trains a tiny PerMFL scenario, exports the (team, device)-keyed
`ModelStore` (exact bit-pattern deltas against each team's anchor),
round-trips it through disk, and serves one batch where every request
carries its own (team, device) tag — including an unknown device and an
unknown team, which fall back to the team anchor and the global model.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_reduced_config
from repro.models import model as M
from repro.serve.engine import ServeEngine


def personalized_demo(tmp="/tmp/permfl_store.zip"):
    """Train -> export ModelStore -> reload -> serve a tagged batch."""
    from repro.models import paper_models
    from repro.scenarios import SCENARIOS, build_scenario, run_scenario
    from repro.serve import ModelStore, PersonalizedServer

    s = SCENARIOS["table1/mnist/mclr/permfl"].scaled(
        m_teams=2, n_devices=3, samples_per_device=16, rounds=2)
    res = run_scenario(s, seed=0)
    b = build_scenario(s, seed=0)

    store = ModelStore.from_result(b.algo, res, m=b.m, n=b.n)
    store.save(tmp)
    store = ModelStore.load(tmp)
    print(f"store: {b.m}x{b.n} devices, encoding={store.encoding}, "
          f"device tier {store.device_tier_nbytes() / 1e3:.0f} kB -> {tmp}")

    server = PersonalizedServer(
        store, lambda p, x: paper_models.apply(p, b.config, x[None])[0])
    xv = np.asarray(b.val["x"], np.float32)
    xs = jnp.asarray(xv.reshape((-1,) + xv.shape[3:])[:4])
    # one known device, a second known device, an unknown device (team
    # fallback), an unknown team (global fallback) — one batched call
    teams, devices = np.array([0, 1, 0, 9]), np.array([0, 2, 7, 0])
    logits = server.serve(teams, devices, xs)
    for t, d, row in zip(teams, devices, np.asarray(logits)):
        tier = ("device" if d < b.n and t < b.m
                else "team" if t < b.m else "global")
        print(f"  request (team={t}, device={d}) -> {tier}-tier model, "
              f"class {int(row.argmax())}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--sample", default="greedy", choices=["greedy", "temp"])
    ap.add_argument("--personalized", action="store_true",
                    help="run the personalized (team, device) store demo "
                         "instead of the LLM decode loop")
    args = ap.parse_args(argv)

    if args.personalized:
        return personalized_demo()

    cfg = get_reduced_config(args.arch).replace(vocab_size=512)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg=cfg, params=params,
                         max_len=args.prompt_len + args.new,
                         sample=args.sample)

    key = jax.random.PRNGKey(1)
    prompt = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, 512)}
    if cfg.family == "vlm":
        prompt = {"embeds": jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model)) * 0.2,
            "mrope_positions": jnp.tile(jnp.arange(
                args.prompt_len, dtype=jnp.int32)[None, :, None],
                (args.batch, 1, 3))}
    if cfg.is_encoder_decoder:
        prompt["enc_frames"] = jax.random.normal(
            key, (args.batch, cfg.encoder_seq_len, cfg.d_model)) * 0.2

    engine.generate(prompt, max_new_tokens=2)        # compile
    t0 = time.perf_counter()
    out = engine.generate(prompt, max_new_tokens=args.new)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0

    print(f"arch={args.arch} family={cfg.family} cache="
          + ("recurrent-state" if cfg.family == "ssm" else
             "hybrid" if cfg.family == "hybrid" else "kv"))
    for i, row in enumerate(out.tolist()):
        print(f"  request {i}: {row}")
    print(f"{args.batch * args.new} tokens in {dt:.2f}s = "
          f"{args.batch * args.new / dt:.1f} tok/s (reduced model, CPU)")


if __name__ == "__main__":
    main()
