"""Batched serving: prefill + autoregressive decode with a KV/state cache.

    PYTHONPATH=src python examples/serve_model.py --arch rwkv6-7b --new 24

Loads a REDUCED variant of any assigned arch (dense KV cache, RWKV/Mamba
recurrent state, or Whisper cross-attention — all four cache families),
generates continuations for a batch of prompts, and reports tokens/s.
The same prefill/decode steps are what the decode_32k / long_500k
dry-runs lower onto the production mesh.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_reduced_config
from repro.models import model as M
from repro.serve.engine import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--sample", default="greedy", choices=["greedy", "temp"])
    args = ap.parse_args(argv)

    cfg = get_reduced_config(args.arch).replace(vocab_size=512)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg=cfg, params=params,
                         max_len=args.prompt_len + args.new,
                         sample=args.sample)

    key = jax.random.PRNGKey(1)
    prompt = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, 512)}
    if cfg.family == "vlm":
        prompt = {"embeds": jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model)) * 0.2,
            "mrope_positions": jnp.tile(jnp.arange(
                args.prompt_len, dtype=jnp.int32)[None, :, None],
                (args.batch, 1, 3))}
    if cfg.is_encoder_decoder:
        prompt["enc_frames"] = jax.random.normal(
            key, (args.batch, cfg.encoder_seq_len, cfg.d_model)) * 0.2

    engine.generate(prompt, max_new_tokens=2)        # compile
    t0 = time.perf_counter()
    out = engine.generate(prompt, max_new_tokens=args.new)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0

    print(f"arch={args.arch} family={cfg.family} cache="
          + ("recurrent-state" if cfg.family == "ssm" else
             "hybrid" if cfg.family == "hybrid" else "kv"))
    for i, row in enumerate(out.tolist()):
        print(f"  request {i}: {row}")
    print(f"{args.batch * args.new} tokens in {dt:.2f}s = "
          f"{args.batch * args.new / dt:.1f} tok/s (reduced model, CPU)")


if __name__ == "__main__":
    main()
