"""PerMFL at LLM scale — the production "tier mode" (DESIGN.md §2).

    PYTHONPATH=src python examples/tiered_llm_training.py --arch phi3-mini-3.8b

Runs the tiered PerMFL round (device prox steps -> team update -> server
update) on a REDUCED variant of an assigned architecture, with federated
LM data where each team has its own topic distribution — the LM analogue
of the paper's label skew. Shows personalized perplexity < global
perplexity on each team's distribution.

At production scale the same `make_tier_round` step is what
`repro.launch.dryrun` lowers onto the (pod, data, model) mesh: pods play
teams, DCN carries only the per-round server aggregate.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_reduced_config
from repro.data.tokens import federated_lm_data
from repro.models import model as M
from repro.train.trainer import make_tier_round

VOCAB = 256


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b", choices=ARCH_IDS)
    ap.add_argument("--teams", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--seq-len", type=int, default=64)
    args = ap.parse_args(argv)

    cfg = get_reduced_config(args.arch).replace(vocab_size=VOCAB)
    data = federated_lm_data(np.random.default_rng(0), VOCAB,
                             m_teams=args.teams, n_devices=1,
                             seq_len=args.seq_len, seqs_per_device=8)

    key = jax.random.PRNGKey(0)
    x = M.init_params(key, cfg)                      # global model
    thetas = [jax.tree.map(jnp.copy, x) for _ in range(args.teams)]
    ws = [jax.tree.map(jnp.copy, x) for _ in range(args.teams)]

    round_fn = jax.jit(make_tier_round(
        cfg, alpha=3e-3, lam=0.5, gamma=1.5, eta=0.03, beta=0.3, l_local=2))

    def team_batch(i):
        toks = jnp.asarray(data["tokens"][i, 0])     # (S, seq)
        tgts = jnp.asarray(data["targets"][i, 0])
        return {"tokens": toks, "targets": tgts}

    loss_of = jax.jit(lambda p, b: M.loss_fn(p, cfg, b))

    for t in range(args.rounds):
        xs = []
        for i in range(args.teams):                  # pods, in production
            thetas[i], ws[i], xi, metrics = round_fn(
                thetas[i], ws[i], x, team_batch(i))
            xs.append(xi)
        # server aggregation over the `pod` axis (here: a mean)
        x = jax.tree.map(lambda *leaves: sum(leaves) / len(leaves), *xs)
        if t % 10 == 0 or t == args.rounds - 1:
            pm = np.mean([float(loss_of(thetas[i], team_batch(i)))
                          for i in range(args.teams)])
            gm = np.mean([float(loss_of(x, team_batch(i)))
                          for i in range(args.teams)])
            print(f"round {t:3d}: personalized loss {pm:.4f} "
                  f"(ppl {np.exp(pm):7.1f})   global loss {gm:.4f} "
                  f"(ppl {np.exp(gm):7.1f})")

    assert pm <= gm + 1e-6, "personalized should fit team topics at least as well"
    print("\npersonalized models fit their team's topic better than the "
          "global model — the paper's mechanism, at LM scale.")


if __name__ == "__main__":
    main()
