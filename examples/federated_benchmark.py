"""End-to-end driver: the paper's experiment, faithful shape.

    PYTHONPATH=src python examples/federated_benchmark.py \
        --dataset fmnist --model cnn --rounds 30 --teams 4 --devices 10

Trains PerMFL *and* FedAvg on the same non-IID partition for a few hundred
aggregate optimization steps (rounds x K x L device steps), evaluates the
personalized/team/global models each round, and writes a CSV of the
convergence curves plus a final comparison line. This is the "train a
model for a few hundred steps" end-to-end example; `--full` scales to the
paper's 4x10 devices x 400-round setting if you have the time budget.
"""
import argparse
import csv
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_cnn import CONFIG as CNN
from repro.configs.paper_dnn import CONFIG as DNN
from repro.configs.paper_mclr import CONFIG as MCLR
from repro.core.permfl import PerMFLHParams
from repro.core.theory import mclr_constants, pick_hparams_strongly_convex
from repro.data.federated import partition_label_skew, partition_tabular
from repro.data.synthetic import make_dataset, synthetic_tabular
from repro.models import paper_models as PM
from repro.train.fl_trainer import run_fedavg, run_permfl


def build(args):
    rng = np.random.default_rng(args.seed)
    if args.dataset == "synthetic":
        devs = synthetic_tabular(rng, args.teams * args.devices,
                                 min_samples=48, max_samples=400)
        fed = partition_tabular(devs, m_teams=args.teams,
                                n_devices=args.devices,
                                samples_per_device=48)
        cfg = {"mclr": MCLR, "dnn": DNN}[args.model]
        if args.model == "mclr":
            import dataclasses
            cfg = dataclasses.replace(cfg, input_shape=(60,))
    else:
        x, y = make_dataset(args.dataset, rng,
                            n_per_class=40 * args.devices)
        fed = partition_label_skew(rng, x, y, m_teams=args.teams,
                                   n_devices=args.devices,
                                   classes_per_device=2,
                                   samples_per_device=48,
                                   strategy=args.formation)
        cfg = {"mclr": MCLR, "cnn": CNN}[args.model]
    return fed, cfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="fmnist",
                    choices=["mnist", "fmnist", "emnist10", "synthetic"])
    ap.add_argument("--model", default="mclr",
                    choices=["mclr", "cnn", "dnn"])
    ap.add_argument("--rounds", type=int, default=15)
    ap.add_argument("--teams", type=int, default=4)
    ap.add_argument("--devices", type=int, default=10)
    ap.add_argument("--team-frac", type=float, default=1.0)
    ap.add_argument("--device-frac", type=float, default=1.0)
    ap.add_argument("--formation", default="random",
                    choices=["random", "worst", "average"])
    ap.add_argument("--theory-hparams", action="store_true",
                    help="derive (alpha,eta,beta,lam,gamma) from Theorem 1")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="CSV path for curves")
    args = ap.parse_args(argv)

    fed, cfg = build(args)
    loss = lambda p, b: PM.loss_fn(p, cfg, b)
    met = lambda p, b: PM.accuracy(p, cfg, b)
    tr = {"x": jnp.asarray(fed.train_x), "y": jnp.asarray(fed.train_y)}
    va = {"x": jnp.asarray(fed.val_x), "y": jnp.asarray(fed.val_y)}
    p0 = PM.init_params(jax.random.PRNGKey(args.seed), cfg)

    if args.theory_hparams and args.model == "mclr":
        mu, lf = mclr_constants(fed.train_x.reshape(-1, *cfg.input_shape),
                                cfg.l2_reg)
        th = pick_hparams_strongly_convex(mu, lf, safety=0.9)
        hp = PerMFLHParams(alpha=th["alpha"], eta=th["eta"], beta=th["beta"],
                           lam=th["lam"], gamma=th["gamma"], k_team=5,
                           l_local=10)
        print(f"theory hparams: {th}")
    else:
        hp = PerMFLHParams(alpha=0.01, eta=0.03, beta=0.6, lam=0.5,
                           gamma=1.5, k_team=5, l_local=10)

    print(f"== PerMFL: {args.rounds} rounds x K={hp.k_team} x L={hp.l_local}"
          f" = {args.rounds * hp.k_team * hp.l_local} device steps ==")
    res = run_permfl(p0, tr, va, loss_fn=loss, metric_fn=met, hp=hp,
                     rounds=args.rounds, m=fed.m_teams, n=fed.n_devices,
                     team_frac=args.team_frac, device_frac=args.device_frac)
    print(f"== FedAvg baseline ==")
    ref = run_fedavg(p0, tr, va, loss_fn=loss, metric_fn=met,
                     lr=hp.alpha * 3, local_steps=hp.k_team * hp.l_local,
                     rounds=args.rounds, m=fed.m_teams, n=fed.n_devices)

    rows = [("round", "permfl_pm", "permfl_tm", "permfl_gm", "fedavg_gm")]
    for t in range(len(res.pm_acc)):
        rows.append((t, res.pm_acc[t], res.tm_acc[t], res.gm_acc[t],
                     ref.gm_acc[min(t, len(ref.gm_acc) - 1)]))
        print(f"round {t:3d}  PM {res.pm_acc[t]:.3f}  TM {res.tm_acc[t]:.3f}"
              f"  GM {res.gm_acc[t]:.3f} | FedAvg {rows[-1][4]:.3f}")
    if args.out:
        with open(args.out, "w", newline="") as f:
            csv.writer(f).writerows(rows)
        print(f"curves -> {args.out}")
    print(f"\nfinal: PerMFL(PM) {res.pm_acc[-1]:.3f} vs FedAvg(GM) "
          f"{ref.gm_acc[-1]:.3f}  (paper's claim: PM wins under non-IID)")


if __name__ == "__main__":
    sys.exit(main())
