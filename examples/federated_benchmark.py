"""End-to-end driver: the paper's experiment, faithful shape.

    PYTHONPATH=src python examples/federated_benchmark.py \
        --dataset fmnist --model cnn --rounds 30 --teams 4 --devices 10

Builds an ad-hoc `FLScenario` from the CLI arguments (the same spec type
the registry holds — dump it with --dump-spec), trains PerMFL *and*
FedAvg on the same non-IID partition, evaluates the personalized/team/
global models each round, and writes a CSV of the convergence curves
plus a final comparison line. ``--partitioner dirichlet --alpha 0.3``
switches to Dirichlet label skew; ``--formation worst`` exercises the
team-formation ablation.
"""
import argparse
import csv
import dataclasses
import json
import sys

from repro.core.theory import mclr_constants, pick_hparams_strongly_convex
from repro.scenarios import (AlgoSpec, DataSpec, FLScenario, ModelSpec,
                             build_scenario, run_scenario)


def scenario_from_args(args) -> FLScenario:
    """The CLI arguments as one declarative spec."""
    tabular = args.dataset == "synthetic"
    if args.model == "cnn" and tabular:
        sys.exit("--model cnn needs an image dataset")
    data = DataSpec(
        dataset=args.dataset,
        partitioner="tabular" if tabular else args.partitioner,
        m_teams=args.teams, n_devices=args.devices,
        samples_per_device=48, strategy=args.formation, alpha=args.alpha)
    return FLScenario(
        name=f"cli/{args.dataset}/{args.model}",
        data=data, model=ModelSpec(args.model), algo=AlgoSpec("permfl"),
        rounds=args.rounds, team_frac=args.team_frac,
        device_frac=args.device_frac, data_seed=args.seed,
        notes="ad-hoc scenario from examples/federated_benchmark.py")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="fmnist",
                    choices=["mnist", "fmnist", "emnist10", "synthetic"])
    ap.add_argument("--model", default="mclr",
                    choices=["mclr", "cnn", "dnn"])
    ap.add_argument("--partitioner", default="label_skew",
                    choices=["label_skew", "dirichlet", "quantity"])
    ap.add_argument("--alpha", type=float, default=0.5,
                    help="dirichlet concentration (with --partitioner)")
    ap.add_argument("--rounds", type=int, default=15)
    ap.add_argument("--teams", type=int, default=4)
    ap.add_argument("--devices", type=int, default=10)
    ap.add_argument("--team-frac", type=float, default=1.0)
    ap.add_argument("--device-frac", type=float, default=1.0)
    ap.add_argument("--formation", default="random",
                    choices=["random", "worst", "average"])
    ap.add_argument("--theory-hparams", action="store_true",
                    help="derive (alpha,eta,beta,lam,gamma) from Theorem 1")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="CSV path for curves")
    ap.add_argument("--dump-spec", action="store_true",
                    help="print the scenario spec as JSON and exit")
    args = ap.parse_args(argv)

    scn = scenario_from_args(args)
    if args.dump_spec:
        print(json.dumps(scn.to_dict(), indent=2))
        return

    if args.theory_hparams and args.model == "mclr":
        b = build_scenario(scn, args.seed)
        cfg = b.config
        mu, lf = mclr_constants(
            b.fd.train_x.reshape(-1, *cfg.input_shape), cfg.l2_reg)
        th = pick_hparams_strongly_convex(mu, lf, safety=0.9)
        print(f"theory hparams: {th}")
        scn = dataclasses.replace(
            scn, algo=AlgoSpec("permfl", tuple(th.items())))
    hp = scn.algo.hparams()

    print(f"== PerMFL: {scn.rounds} rounds x K={hp.k_team} x L={hp.l_local}"
          f" = {scn.rounds * hp.k_team * hp.l_local} device steps ==")
    res = run_scenario(scn, seed=args.seed)
    print(f"== FedAvg baseline ==")
    fedavg = dataclasses.replace(
        scn, algo=AlgoSpec("fedavg", (("lr", hp.alpha * 3),
                                      ("local_steps",
                                       hp.k_team * hp.l_local))),
        team_frac=1.0, device_frac=1.0)
    ref = run_scenario(fedavg, seed=args.seed)

    rows = [("round", "permfl_pm", "permfl_tm", "permfl_gm", "fedavg_gm")]
    for t in range(len(res.pm_acc)):
        rows.append((t, res.pm_acc[t], res.tm_acc[t], res.gm_acc[t],
                     ref.gm_acc[min(t, len(ref.gm_acc) - 1)]))
        print(f"round {t:3d}  PM {res.pm_acc[t]:.3f}  TM {res.tm_acc[t]:.3f}"
              f"  GM {res.gm_acc[t]:.3f} | FedAvg {rows[-1][4]:.3f}")
    if args.out:
        with open(args.out, "w", newline="") as f:
            csv.writer(f).writerows(rows)
        print(f"curves -> {args.out}")
    print(f"\nfinal: PerMFL(PM) {res.pm_acc[-1]:.3f} vs FedAvg(GM) "
          f"{ref.gm_acc[-1]:.3f}  (paper's claim: PM wins under non-IID)")


if __name__ == "__main__":
    sys.exit(main())
