"""Quickstart: PerMFL on a non-IID federated image problem in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py

Every experiment in this repo is a named *scenario* — one serializable
spec covering data x topology x model x algorithm x comm. The paper's
setting (4 teams x 10 devices, each device holding two classes) is
``table1/mnist/mclr/permfl`` in the registry; running it through
``run_scenario`` compiles the whole experiment — rounds, evals — into a
single program. Browse the catalog:

    PYTHONPATH=src python -m repro.scenarios list
"""
from repro.scenarios import SCENARIOS, build_scenario, run_scenario


def main():
    scn = SCENARIOS["table1/mnist/mclr/permfl"]
    b = build_scenario(scn)
    print(f"scenario {scn.name} (hash {scn.spec_hash()}): "
          f"teams={b.m} devices/team={b.n} "
          f"train shape={b.fd.train_x.shape}")

    res = run_scenario(scn, rounds=10)
    for t, (pm, tm, gm) in enumerate(zip(res.pm_acc, res.tm_acc,
                                         res.gm_acc)):
        print(f"round {t:2d}: PM={pm:.3f} TM={tm:.3f} GM={gm:.3f}")
    print(f"\nPersonalized beats global by "
          f"{100 * (res.pm_acc[-1] - res.gm_acc[-1]):.1f} points "
          f"({res.seconds:.1f}s)")

    # Same setting, but the uplinks ship top-10% sparsified deltas with
    # error feedback (scenario ``comm/.../topk_10`` differs only in its
    # CommConfig and data seed); the CommLedger accounts bytes per tier.
    res_c = run_scenario(SCENARIOS["comm/mnist/mclr/topk_10"], rounds=10)
    s = res_c.comm.summary()
    print(f"\ncompressed uplinks (top-10% + EF): PM={res_c.pm_acc[-1]:.3f} "
          f"(vs {res.pm_acc[-1]:.3f} uncompressed)")
    print(f"moved {s['total_bytes'] / 1e6:.1f} MB total vs "
          f"{s['uncompressed_bytes'] / 1e6:.1f} MB at fp32 "
          f"(uplink shrunk {s['uplink_ratio']:.0f}x; "
          f"WAN up {s['wan_up_bytes'] / 1e6:.2f} MB, "
          f"LAN up {s['lan_up_bytes'] / 1e6:.2f} MB)")

    # Bytes are a proxy — price the same two runs in simulated wall-clock
    # seconds on a cellular-WAN system profile (repro.system): the
    # compressed uplinks buy *time*, and accuracy-vs-seconds curves fall
    # out of res.sim_seconds.
    t_full = run_scenario(SCENARIOS["comm/mnist/mclr/uncompressed"],
                          rounds=10, system="wan-cellular")
    t_comp = run_scenario(SCENARIOS["comm/mnist/mclr/topk_10"],
                          rounds=10, system="wan-cellular")
    print(f"\non wan-cellular: fp32 uplinks take "
          f"{t_full.timeline.total_seconds():.1f} simulated s, top-10% "
          f"takes {t_comp.timeline.total_seconds():.1f}s to the same "
          f"round budget")
    t, pm = t_comp.sim_seconds[-1], t_comp.pm_acc[-1]
    print(f"time-to-accuracy curve tail: PM={pm:.3f} @ {t:.1f}s simulated")


if __name__ == "__main__":
    main()
