"""Quickstart: PerMFL on a non-IID federated image problem in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's setting — 4 teams x 10 devices, each device holding two
classes — runs a few PerMFL global rounds, and prints the three models'
accuracies (personalized / team / global) per round.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import CommConfig
from repro.configs.paper_mclr import CONFIG as MCLR
from repro.core import PerMFL
from repro.core.permfl import PerMFLHParams
from repro.data.federated import partition_label_skew
from repro.data.synthetic import make_dataset
from repro.models import paper_models as PM
from repro.train.engine import run_experiment


def main():
    rng = np.random.default_rng(0)
    x, y = make_dataset("mnist", rng, n_per_class=400)
    fed = partition_label_skew(rng, x, y, m_teams=4, n_devices=10,
                               classes_per_device=2, samples_per_device=48)
    print(f"teams={fed.m_teams} devices/team={fed.n_devices} "
          f"train shape={fed.train_x.shape}")

    params = PM.init_params(jax.random.PRNGKey(0), MCLR)
    hp = PerMFLHParams(alpha=0.01, eta=0.03, beta=0.6, lam=0.5, gamma=1.5,
                       k_team=5, l_local=10)   # paper §4.1.4 values
    train = {"x": jnp.asarray(fed.train_x), "y": jnp.asarray(fed.train_y)}
    val = {"x": jnp.asarray(fed.val_x), "y": jnp.asarray(fed.val_y)}

    loss = lambda p, b: PM.loss_fn(p, MCLR, b)
    metric = lambda p, b: PM.accuracy(p, MCLR, b)

    # the whole experiment — 10 rounds + evals — is one compiled program
    res = run_experiment(PerMFL(loss, hp), params, train, val,
                         metric_fn=metric, rounds=10,
                         m=fed.m_teams, n=fed.n_devices)

    for t, (pm, tm, gm) in enumerate(zip(res.pm_acc, res.tm_acc,
                                         res.gm_acc)):
        print(f"round {t:2d}: PM={pm:.3f} TM={tm:.3f} GM={gm:.3f}")
    print(f"\nPersonalized beats global by "
          f"{100 * (res.pm_acc[-1] - res.gm_acc[-1]):.1f} points "
          f"({res.seconds:.1f}s)")

    # Same run, but the uplinks ship top-10% sparsified deltas with error
    # feedback; the CommLedger accounts bytes per tier per round.
    res_c = run_experiment(
        PerMFL(loss, hp, comm=CommConfig(compressor="topk", k_frac=0.1)),
        params, train, val, metric_fn=metric, rounds=10,
        m=fed.m_teams, n=fed.n_devices)
    s = res_c.comm.summary()
    print(f"\ncompressed uplinks (top-10% + EF): PM={res_c.pm_acc[-1]:.3f} "
          f"(vs {res.pm_acc[-1]:.3f} uncompressed)")
    print(f"moved {s['total_bytes'] / 1e6:.1f} MB total vs "
          f"{s['uncompressed_bytes'] / 1e6:.1f} MB at fp32 "
          f"(uplink shrunk {s['uplink_ratio']:.0f}x; "
          f"WAN up {s['wan_up_bytes'] / 1e6:.2f} MB, "
          f"LAN up {s['lan_up_bytes'] / 1e6:.2f} MB)")


if __name__ == "__main__":
    main()
