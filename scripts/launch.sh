#!/usr/bin/env bash
# Env-tuned launcher: `scripts/launch.sh <command...>` runs the command
# with the allocator/XLA settings the benchmarks assume, so interactive
# runs, CI bench steps, and the committed perf baselines all see the
# same runtime configuration.
#
#   scripts/launch.sh python benchmarks/bench_engine.py --smoke
#   scripts/launch.sh python -m repro.scenarios run NAME --smoke
#
# Everything here is an override-able default: variables already set in
# the environment win.
set -euo pipefail

# tcmalloc beats glibc malloc on the host-side assembly paths (trace
# collection, ledger/timeline building); preload it when present.
if [ -z "${LD_PRELOAD:-}" ]; then
    for so in /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
              /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
              /usr/lib/libtcmalloc.so.4; do
        if [ -e "$so" ]; then
            export LD_PRELOAD="$so"
            break
        fi
    done
fi

# silence large-numpy-allocation reports and TF/absl dataset chatter
export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD="${TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD:-60000000000}"
export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-4}"

# deterministic memory footprint: grab buffers on demand instead of
# preallocating most of the accelerator (keeps bench runs and parallel
# CI jobs from fighting over one device)
export XLA_PYTHON_CLIENT_PREALLOCATE="${XLA_PYTHON_CLIENT_PREALLOCATE:-false}"
export XLA_PYTHON_CLIENT_ALLOCATOR="${XLA_PYTHON_CLIENT_ALLOCATOR:-platform}"

# XLA_FLAGS passes through untouched: flag sets differ per backend
# build (e.g. --xla_step_marker_location exists on TPU but aborts CPU
# wheels at startup), so per-flag tuning belongs to the caller.

exec "$@"
