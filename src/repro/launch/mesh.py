"""Production mesh construction.

Single pod: (16, 16) = 256 v5e chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — `pod` is the
DCN-connected axis, which PerMFL's team/global tier structure maps onto
(DESIGN.md §2).

These are FUNCTIONS (not module constants) so importing this module never
touches jax device state — dryrun.py sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init,
and only dryrun does.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single-pod (data, model) or multi-pod (pod, data, model) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_sweep_mesh(n_sweep: int, *, n_data: int = 16, n_model: int = 16):
    """(sweep, data, model) mesh for batched hyperparameter/seed sweeps.

    The sweep axis takes the pod (DCN) tier: configs are embarrassingly
    parallel — no cross-config collectives ever cross it — so the slowest
    links carry zero sweep traffic, and each config's (M, N) state shards
    over the fast in-pod (data, model) axes exactly as a single
    experiment would (DESIGN.md §6).
    """
    return jax.make_mesh((n_sweep, n_data, n_model),
                         ("sweep", "data", "model"))


def batch_axes(mesh) -> tuple:
    """The axes the global batch shards over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def mesh_batch_size(mesh) -> int:
    out = 1
    for a in batch_axes(mesh):
        out *= mesh.shape[a]
    return out


def make_host_mesh(n_data: int = 1, n_model: int = 1,
                   n_sweep: int = None):
    """Tiny mesh over whatever devices exist (CPU tests). Passing
    n_sweep prepends a sweep axis: (sweep, data, model)."""
    if n_sweep is not None:
        return make_sweep_mesh(n_sweep, n_data=n_data, n_model=n_model)
    return jax.make_mesh((n_data, n_model), ("data", "model"))


# Hardware constants for the roofline model (TPU v5e)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
