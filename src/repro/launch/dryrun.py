import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production meshes, print memory/cost analysis, extract roofline terms.

MUST be run as its own process (the device-count flag is locked at first
jax init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-mini-3.8b \
        --shape train_4k --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/

The train step lowered here is the PerMFL *device step* (eq. 4 prox-SGD
with momentum toward the team anchor) — the paper's technique as the
first-class training unit (DESIGN.md §2); --plain lowers vanilla SGD
instead (the paper's implicit ERM baseline). Decode shapes lower
``serve_step``: ONE token against a seq_len cache.
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, ARCH_IDS, get_config
from repro.configs.base import active_param_count, param_count
from repro.launch.mesh import (batch_axes, make_production_mesh,
                               mesh_batch_size)
from repro.models import model as model_lib
from repro.roofline import analyze, model_flops_decode, model_flops_train
from repro.sharding.specs import (batch_pspecs, cache_pspecs, param_pspecs,
                                  to_named)

SWA_WINDOW = 8192           # sliding window used for dense long_500k
ACT_DTYPE = jnp.bfloat16


def resolve_config(arch: str, shape_name: str):
    """Arch config adjusted per input shape policy (DESIGN.md §5).

    Returns (cfg, skip_reason | None)."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape_name == "long_500k":
        if not cfg.supports_long_decode():
            return cfg, ("enc-dec decoder context is 448 by construction; "
                         "524k decode contradicts the architecture")
        needs_swa = any(k == "attn" for k in cfg.layer_kinds()) and \
            cfg.family not in ("hybrid",)
        if needs_swa:
            cfg = cfg.replace(sliding_window=SWA_WINDOW)
    if shape.kind == "decode" and cfg.is_encoder_decoder and \
            shape_name == "long_500k":
        return cfg, "skip"
    return cfg, None


def cache_len_for(cfg, shape) -> int:
    if cfg.sliding_window > 0:
        # steady-state ring-buffer window (the live KV state under SWA)
        return min(shape.seq_len, cfg.sliding_window)
    return shape.seq_len


def build_step_and_args(cfg, shape, mesh, *, plain=False):
    """Returns (fn, arg_specs, in_shardings, out_shardings)."""
    baxes = batch_axes(mesh)
    baxes_spec = baxes if len(baxes) > 1 else baxes[0]
    mesh_b = mesh_batch_size(mesh)
    p_specs = model_lib.param_specs(cfg, dtype=ACT_DTYPE)
    p_shard = to_named(param_pspecs(p_specs), mesh, p_specs)

    if shape.kind == "train":
        from repro.kernels.prox_update import prox_sgd_tree

        def step(theta, w, mom, batch):
            def loss(params):
                return model_lib.loss_fn(params, cfg, batch, remat=True)
            lv, grads = jax.value_and_grad(loss)(theta)
            if plain:
                theta2 = jax.tree.map(lambda t, g: t - 0.01 * g, theta, grads)
                return theta2, mom, {"loss": lv}
            theta2, mom2 = prox_sgd_tree(theta, grads, w, mom,
                                         alpha=0.01, lam=0.5, momentum=0.9)
            return theta2, mom2, {"loss": lv}

        batch = model_lib.input_specs(cfg, batch=shape.global_batch,
                                      seq_len=shape.seq_len, kind="train",
                                      act_dtype=ACT_DTYPE)
        mom_specs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), p_specs)
        b_shard = to_named(batch_pspecs(batch, batch_axes=baxes_spec), mesh,
                           batch)
        mom_shard = to_named(param_pspecs(mom_specs), mesh, mom_specs)
        args = (p_specs, p_specs, mom_specs, batch)
        in_sh = (p_shard, p_shard, mom_shard, b_shard)
        out_sh = (p_shard, mom_shard, None)
        return step, args, in_sh, out_sh

    if shape.kind == "prefill":
        def step(params, batch, cache):
            return model_lib.prefill(params, cfg, batch, cache,
                                     last_only=True)

        batch = model_lib.input_specs(cfg, batch=shape.global_batch,
                                      seq_len=shape.seq_len, kind="prefill",
                                      act_dtype=ACT_DTYPE)
        cache = model_lib.cache_specs(cfg, shape.global_batch,
                                      shape.seq_len, dtype=ACT_DTYPE)
        b_shard = to_named(batch_pspecs(batch, batch_axes=baxes_spec), mesh,
                           batch)
        c_shard = to_named(cache_pspecs(cache, batch_axes=baxes_spec,
                                        mesh_batch=mesh_b), mesh, cache)
        args = (p_specs, batch, cache)
        in_sh = (p_shard, b_shard, c_shard)
        out_sh = (None, c_shard)
        return step, args, in_sh, out_sh

    # decode
    max_len = cache_len_for(cfg, shape)
    # Decode sharding (beyond-paper, §Perf hillclimb 2): FSDP would
    # all-gather every weight once PER TOKEN (one decode step has no
    # sequence dim to amortize it) — rwkv6-7b decode_32k was
    # collective-bound purely on those gathers. Serving uses pure TP
    # (params sharded over `model` only, never gathered) WHEN the TP shard
    # fits comfortably in HBM; very large models (dbrx 16.5 GB/dev,
    # jamba 50 GB/dev at TP-16) keep FSDP — replicating their banks over
    # `data` cannot fit a 16 GB v5e. REPRO_DECODE_FSDP=1 forces the
    # FSDP baseline everywhere (§Perf).
    # ... and only for batch-dense decode: at global_batch=1 (long_500k)
    # the per-token weight read amortizes over nothing, so keeping weights
    # FSDP-sharded (each device streams 1/16 of them + ICI) beats local
    # full-TP-shard reads (measured 0.1-0.7x regressions otherwise).
    tp_param_bytes = 2 * param_count(cfg) / mesh.shape["model"]
    if os.environ.get("REPRO_DECODE_FSDP") != "1" and \
            tp_param_bytes < 4e9 and shape.global_batch >= 16:
        p_shard = to_named(param_pspecs(p_specs, fsdp=False), mesh, p_specs)

    def step(params, cache, batch, pos):
        return model_lib.decode_step(params, cfg, cache, batch, pos)

    batch = model_lib.input_specs(cfg, batch=shape.global_batch,
                                  seq_len=shape.seq_len, kind="decode",
                                  act_dtype=ACT_DTYPE)
    cache = model_lib.cache_specs(cfg, shape.global_batch, max_len,
                                  dtype=ACT_DTYPE)
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
    b_shard = to_named(batch_pspecs(batch, batch_axes=baxes_spec), mesh,
                           batch)
    c_shard = to_named(cache_pspecs(cache, batch_axes=baxes_spec,
                                    mesh_batch=mesh_b), mesh, cache)
    args = (p_specs, cache, batch, pos_spec)
    in_sh = (p_shard, c_shard, b_shard, NamedSharding(mesh, P()))
    out_sh = (None, c_shard)
    return step, args, in_sh, out_sh


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            plain: bool = False, verbose: bool = True):
    shape = INPUT_SHAPES[shape_name]
    cfg, skip = resolve_config(arch, shape_name)
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "params": param_count(get_config(arch)),
        "active_params": active_param_count(get_config(arch)),
    }
    if skip:
        record["status"] = "skipped"
        record["reason"] = skip
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    step, args, in_sh, out_sh = build_step_and_args(cfg, shape, mesh,
                                                    plain=plain)
    with mesh:
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    if shape.kind == "train":
        mflops = model_flops_train(cfg, tokens)
    elif shape.kind == "prefill":
        mflops = model_flops_decode(cfg, tokens)  # forward-only
    else:
        mflops = model_flops_decode(cfg, tokens)
    hlo_text = compiled.as_text()
    roof = analyze(compiled, chips=chips, model_flops=mflops,
                   hlo_text=hlo_text)

    record.update({
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "chips": chips,
        "hlo_flops": roof.flops,
        "hbm_bytes": roof.hbm_bytes,
        "collective_bytes": roof.collective_bytes,
        "collectives": roof.collectives,
        "compute_s": roof.compute_s,
        "memory_s": roof.memory_s,
        "collective_s": roof.collective_s,
        "dominant": roof.dominant,
        "model_flops": mflops,
        "useful_ratio": roof.useful_ratio,
        "bytes_per_device": {
            "argument": getattr(mem, "argument_size_in_bytes", None),
            "output": getattr(mem, "output_size_in_bytes", None),
            "temp": getattr(mem, "temp_size_in_bytes", None),
            "peak": (getattr(mem, "argument_size_in_bytes", 0) or 0)
            + (getattr(mem, "temp_size_in_bytes", 0) or 0),
        },
    })
    if verbose:
        print(f"== {arch} x {shape_name} x {mesh_name} ==")
        print(f"  lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"  memory_analysis: {mem}")
        print(f"  roofline: {roof.summary()}")
        print(f"  collectives: { {k: f'{v/1e9:.3f}GB' for k, v in roof.collectives.items()} }")
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--plain", action="store_true",
                    help="vanilla SGD step instead of PerMFL device step")
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args(argv)

    records = []
    if args.all:
        combos = [(a, s, m) for a in ARCH_IDS for s in INPUT_SHAPES
                  for m in ("pod", "multipod")]
    else:
        combos = [(args.arch, args.shape, args.mesh)]
    for arch, shape, meshname in combos:
        try:
            rec = run_one(arch, shape, multi_pod=(meshname == "multipod"),
                          plain=args.plain)
        except Exception as e:  # a failure here is a bug in the system
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape, "mesh": meshname,
                   "status": "FAILED", "error": repr(e)}
        records.append(rec)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
    bad = [r for r in records if r["status"] == "FAILED"]
    print(f"\n{len(records) - len(bad)}/{len(records)} combos OK")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
