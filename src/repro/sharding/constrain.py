"""Mesh-aware activation sharding constraints (MaxText-style).

``constrain(x, "batch", None, "model")`` pins an intermediate's sharding
when tracing happens under an active mesh, and is a no-op otherwise (CPU
unit tests, paper-scale FL sims). Logical names:

  * "batch" -> every batch-ish axis present in the mesh ("pod", "data")
  * "model" -> the tensor/expert-parallel axis
  * "data"  -> the FSDP axis alone

The critical use is scan carries (online-softmax accumulators, SSM/WKV
states): their zeros-init has no sharding preference, and without a
constraint GSPMD can keep the whole carry replicated, exploding the
backward-pass residuals (observed: 150+ GiB/device before, ~2 GiB after).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _active_mesh():
    try:
        mesh = jax._src.mesh.thread_resources.env.physical_mesh
        if mesh is not None and not mesh.empty:
            return mesh
    except Exception:
        pass
    try:
        amesh = jax.sharding.get_abstract_mesh()
        if amesh is not None and not amesh.empty:
            return amesh
    except Exception:
        pass
    return None


def _resolve(axis, mesh_axes):
    if axis is None:
        return None
    if axis == "batch":
        got = tuple(a for a in ("pod", "data") if a in mesh_axes)
        return got if got else None
    if isinstance(axis, (tuple, list)):
        got = tuple(a for a in axis if a in mesh_axes)
        return got if got else None
    return axis if axis in mesh_axes else None


def _axis_size(mesh, axes):
    if axes is None:
        return 1
    if isinstance(axes, tuple):
        out = 1
        for a in axes:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axes]


def constrain(x, *spec):
    """Apply a logical PartitionSpec if a mesh is active; no-op otherwise.

    Axes that do not divide the corresponding dim are dropped (e.g. the
    seq-dim constraint on a decode step's single token)."""
    mesh = _active_mesh()
    if mesh is None:
        return x
    names = mesh.axis_names
    resolved = []
    for dim, s in zip(x.shape, spec):
        axes = _resolve(s, names)
        if axes is not None and dim % _axis_size(mesh, axes) != 0:
            axes = None
        resolved.append(axes)
    try:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*resolved)))
    except Exception:
        return x


def constrain_tree(tree, specs):
    """specs: pytree of tuples matching tree."""
    return jax.tree.map(lambda x, s: constrain(x, *s), tree, specs,
                        is_leaf=lambda v: isinstance(v, tuple))
