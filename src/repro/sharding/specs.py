"""PartitionSpec rules: path-pattern -> logical sharding, per leaf.

Strategy (DESIGN.md §2/§5):
  * `model` axis: tensor parallel on attention head / FFN-hidden dims;
    EXPERT parallel on MoE banks (the expert axis shards, expert interiors
    stay whole — fine-grained MoE's natural layout);
  * `data` axis: FSDP on the d_model ("reduce") dim of the big projections
    + batch sharding of activations;
  * `pod` axis (multi-pod): batch/teams (DCN only sees per-round PerMFL
    aggregates + gradient all-reduce).

Non-divisible cases (56 q-heads / 16, kv=8 / 16, vocab 51865 / 16) rely on
GSPMD's implicit padding — structural waste is counted by the
MODEL_FLOPS/HLO_FLOPs ratio in §Roofline.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# (path regex, spec builder(leaf_ndim) -> PartitionSpec)
# Paths look like: blocks/pos0/attn/wq, blocks/pos3/moe/experts/w_gate, ...
# Leaves under blocks/ carry a leading n_blocks axis (the scan axis).

def _rules(data_axes):
    """data_axes: name or tuple for the FSDP/"reduce" dim."""
    da = data_axes
    return [
        # --- attention ---
        (r"attn/wq$|attn/wk$|attn/wv$|cross/wq$|cross/wk$|cross/wv$",
         lambda nd: P(*([None] * (nd - 2)), da, "model")),
        (r"attn/wo$|cross/wo$",
         lambda nd: P(*([None] * (nd - 2)), "model", da)),
        (r"attn/b[qkv]$", lambda nd: P(*([None] * (nd - 1)), "model")),
        # --- dense mlp ---
        (r"mlp/w_gate$|mlp/w_up$|shared/w_gate$|shared/w_up$|mlp/w_in$",
         lambda nd: P(*([None] * (nd - 2)), da, "model")),
        (r"mlp/w_down$|shared/w_down$|mlp/w_out$",
         lambda nd: P(*([None] * (nd - 2)), "model", da)),
        (r"mlp/b_in$", lambda nd: P(*([None] * (nd - 1)), "model")),
        # --- moe: expert parallel over `model`, FSDP on the d dim ---
        (r"experts/w_(gate|up)$",
         lambda nd: P(*([None] * (nd - 3)), "model", da, None)),
        (r"experts/w_down$",
         lambda nd: P(*([None] * (nd - 3)), "model", None, da)),
        (r"moe/router$", lambda nd: P()),
        # --- mamba ---
        (r"mamba/in_proj$", lambda nd: P(*([None] * (nd - 2)), da, "model")),
        (r"mamba/out_proj$", lambda nd: P(*([None] * (nd - 2)), "model", da)),
        (r"mamba/conv_w$", lambda nd: P(*([None] * (nd - 1)), "model")),
        (r"mamba/conv_b$|mamba/dt_bias$|mamba/D$",
         lambda nd: P(*([None] * (nd - 1)), "model")),
        (r"mamba/x_proj$", lambda nd: P(*([None] * (nd - 2)), "model", None)),
        (r"mamba/dt_proj$", lambda nd: P(*([None] * (nd - 2)), None, "model")),
        (r"mamba/A_log$", lambda nd: P(*([None] * (nd - 2)), "model", None)),
        # --- rwkv ---
        (r"tm/w_[rkvg]$", lambda nd: P(*([None] * (nd - 2)), da, "model")),
        (r"tm/w_o$", lambda nd: P(*([None] * (nd - 2)), "model", da)),
        (r"tm/decay_A$", lambda nd: P(*([None] * (nd - 2)), da, None)),
        (r"tm/decay_B$", lambda nd: P(*([None] * (nd - 2)), None, "model")),
        (r"tm/bonus_u$", lambda nd: P(*([None] * (nd - 2)), "model", None)),
        (r"cm/w_k$", lambda nd: P(*([None] * (nd - 2)), da, "model")),
        (r"cm/w_v$", lambda nd: P(*([None] * (nd - 2)), "model", da)),
        (r"cm/w_r$", lambda nd: P(*([None] * (nd - 2)), da, "model")),
        # --- embeddings / head ---
        (r"^embed$", lambda nd: P("model", None)),
        (r"^lm_head$", lambda nd: P(None, "model")),
        # everything else (norm scales, mu_*, decay_w0, biases) replicated
    ]


def _leaf_path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def param_pspecs(params_tree, *, fsdp: bool = True,
                 fsdp_axes="data") -> dict:
    """Returns a pytree of PartitionSpec matching `params_tree`.

    fsdp=False replicates the `data` dim (pure TP) — a perf-iteration knob.
    """
    rules = _rules(fsdp_axes if fsdp else None)

    def spec_for(path, leaf):
        pstr = _leaf_path_str(path)
        for pat, builder in rules:
            if re.search(pat, pstr):
                spec = builder(leaf.ndim)
                # drop None-fsdp placeholders
                if not fsdp:
                    spec = P(*[None if s == fsdp_axes or s is None and False
                               else s for s in spec])
                return spec
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, params_tree)


def batch_pspecs(batch_tree, *, batch_axes) -> dict:
    """Shard the leading (batch) dim of every input over `batch_axes`;
    replicate if the batch is smaller than the axes product."""
    def spec_for(leaf):
        return P(batch_axes, *([None] * (leaf.ndim - 1)))
    return jax.tree.map(spec_for, batch_tree)


def cache_pspecs(cache_tree, *, batch_axes, mesh_batch: int) -> dict:
    """KV/state cache sharding: batch over data axes when divisible,
    heads/feature dim over model."""
    def spec_for(path, leaf):
        pstr = _leaf_path_str(path)
        b_ok = leaf.ndim >= 2 and leaf.shape[1] % mesh_batch == 0 and \
            leaf.shape[1] >= mesh_batch
        b_ax = batch_axes if b_ok else None
        if re.search(r"/k$|/v$|cross_k$|cross_v$", pstr):
            # (n_blocks, b, s, h_kv, hd). When the batch can't shard
            # (long_500k: b=1), shard the KV *sequence* over the data axes
            # instead — the long-context cache is the dominant buffer and
            # must not be replicated 256x.
            s_ok = (b_ax is None and leaf.ndim >= 3 and
                    leaf.shape[2] % mesh_batch == 0 and
                    leaf.shape[2] >= mesh_batch)
            return P(None, b_ax, batch_axes if s_ok else None, "model", None)
        if re.search(r"/conv$", pstr):      # (n_blocks, b, d_conv-1, d_in)
            return P(None, b_ax, None, "model")
        if re.search(r"/ssm$", pstr):       # (n_blocks, b, d_in, N)
            return P(None, b_ax, "model", None)
        if re.search(r"/wkv$", pstr):       # (n_blocks, b, h, n, n)
            return P(None, b_ax, "model", None, None)
        if re.search(r"tm_last$|cm_last$", pstr):  # (n_blocks, b, d)
            return P(None, b_ax, "model")
        return P()
    return jax.tree_util.tree_map_with_path(spec_for, cache_tree)


def _axes_size(mesh, axes):
    if axes is None:
        return 1
    if isinstance(axes, (tuple, list)):
        out = 1
        for a in axes:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axes]


def validate_pspecs(shape_tree, pspec_tree, mesh: Mesh):
    """Drop spec axes that don't divide the corresponding dim (explicit
    pjit arg shardings require exact divisibility — e.g. whisper's vocab
    51865 on a 16-way model axis, or 8 kv heads on 16)."""
    def fix(leaf, spec):
        out = []
        for i, axes in enumerate(spec):
            if axes is not None and (i >= len(leaf.shape) or
                                     leaf.shape[i] % _axes_size(mesh, axes)
                                     or leaf.shape[i] < _axes_size(mesh, axes)):
                out.append(None)
            else:
                out.append(axes)
        return P(*out)
    return jax.tree_util.tree_map(fix, shape_tree, pspec_tree)


def to_named(tree_of_pspecs, mesh: Mesh, shape_tree=None):
    """PartitionSpec tree -> NamedSharding tree; if shape_tree is given,
    non-dividing axes are dropped first."""
    if shape_tree is not None:
        tree_of_pspecs = validate_pspecs(shape_tree, tree_of_pspecs, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def fl_pspecs(stacked_tree, *, team_axis="pod", device_axis="data"):
    """Stacked-FL sharding (DESIGN.md §2 mode 1): theta (M, N, ...) shards
    teams over `team_axis` and devices over `device_axis`."""
    def spec_for(leaf):
        if leaf.ndim >= 2:
            return P(team_axis, device_axis, *([None] * (leaf.ndim - 2)))
        return P(team_axis)
    return jax.tree.map(spec_for, stacked_tree)


def store_pspecs(store_tree, *, m: int, population: int,
                 population_axis="data", sweep: bool = False,
                 sweep_axis="sweep"):
    """Device-state-store sharding (DESIGN.md §11): store leaves are
    stacked (M, N_pop, ...) over the *resident population*, so the
    population axis — the one that grows to 10^4-10^6 — shards over
    ``population_axis`` (the mesh `data` axis, next to the `sweep` axis
    run_sweep already uses). Teams stay replicated: M is small and the
    per-round gather indexes within each team row.

    With ``sweep=True`` leaves carry a leading (S,) config axis sharded
    over ``sweep_axis`` (the per-config stores run_sweep vmaps over).
    m / population disambiguate the tier axes from model dims; route
    through ``to_named(..., shape_tree=...)`` so non-dividing axes drop.
    """
    lead = (sweep_axis,) if sweep else ()
    off = len(lead)

    def spec_for(leaf):
        if (leaf.ndim >= off + 2 and leaf.shape[off] == m
                and leaf.shape[off + 1] == population):
            return P(*lead, None, population_axis,
                     *([None] * (leaf.ndim - off - 2)))
        return P(*lead, *([None] * (leaf.ndim - off)))
    return jax.tree.map(spec_for, store_tree)


def sweep_pspecs(sweep_tree, *, m: int, n: int, sweep_axis="sweep",
                 team_axis="data", device_axis="model"):
    """Sweep-stacked FL sharding (DESIGN.md §6): every leaf carries a
    leading (S,) config axis, sharded over `sweep_axis` (the repurposed
    pod/DCN tier — configs never talk to each other). Behind it, tiers are
    recognized by shape: (S, M, N, ...) leaves additionally shard teams
    over `team_axis` and devices over `device_axis`; (S, M, ...) leaves
    shard teams; anything else (global models, PRNG keys, round counters)
    shards only the config axis.

    m, n disambiguate team/device axes from model dims. Route the result
    through ``to_named(..., shape_tree=...)`` so non-dividing axes drop.
    """
    def spec_for(leaf):
        if leaf.ndim >= 3 and leaf.shape[1] == m and leaf.shape[2] == n:
            return P(sweep_axis, team_axis, device_axis,
                     *([None] * (leaf.ndim - 3)))
        if leaf.ndim >= 2 and leaf.shape[1] == m:
            return P(sweep_axis, team_axis, *([None] * (leaf.ndim - 2)))
        return P(sweep_axis, *([None] * (leaf.ndim - 1)))
    return jax.tree.map(spec_for, sweep_tree)
