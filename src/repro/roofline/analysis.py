"""Roofline extraction from a compiled dry-run artifact.

Three terms per (arch x shape x mesh), DESIGN/EXPERIMENTS §Roofline:

    compute    = HLO_FLOPs / (chips * 197 TF/s bf16)
    memory     = HLO_bytes / (chips * 819 GB/s)
    collective = collective_bytes / (chips * 50 GB/s per ICI link)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``; collective bytes
are parsed from the optimized HLO text (cost_analysis does not expose
them): we sum operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops. Loop bodies are multiplied by trip
count when the enclosing while op carries a known trip count annotation —
XLA's cost analysis already folds loops into its totals.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[16,1024]' -> bytes. '(bf16[..], f32[..])' handled by caller."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self):
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum result sizes of collective ops in the optimized HLO module,
    weighted by call-graph multiplicity (while bodies x known_trip_count).
    Thin wrapper over the full HLO walker in :mod:`hlo_analysis`."""
    from repro.roofline.hlo_analysis import HloModule

    agg = HloModule(hlo_text).aggregate()
    stats = CollectiveStats()
    stats.bytes_by_kind = {k: int(v)
                           for k, v in agg["collective_bytes_by_kind"].items()}
    stats.count_by_kind = dict(agg["collective_counts_by_kind"])
    return stats


@dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float
    collectives: dict

    def summary(self) -> str:
        return (f"compute={self.compute_s:.3e}s memory={self.memory_s:.3e}s "
                f"collective={self.collective_s:.3e}s -> {self.dominant}-bound"
                f" | useful={self.useful_ratio:.2f}")


def analyze(compiled, *, chips: int, model_flops: float,
            hlo_text: str | None = None) -> Roofline:
    """model_flops is GLOBAL (6*N*D); HLO numbers are per-device (the HLO
    is SPMD-partitioned), so the useful-compute ratio compares
    model_flops/chips against per-device HLO flops."""
    from repro.roofline.hlo_analysis import analyze_hlo_text
    text = hlo_text if hlo_text is not None else compiled.as_text()
    agg = analyze_hlo_text(text)
    flops = agg["flops"]                      # per device
    hbm = agg["hbm_bytes"]                    # per device
    coll_bytes = agg["collective_bytes"]      # per device

    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = hbm / HBM_BW
    collective_s = coll_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf_dev = model_flops / chips
    return Roofline(
        flops=flops, hbm_bytes=hbm, collective_bytes=coll_bytes,
        chips=chips, compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, dominant=dominant,
        model_flops=model_flops,
        useful_ratio=(mf_dev / flops) if flops else 0.0,
        collectives=dict(agg["collective_bytes_by_kind"]))


def model_flops_train(cfg, tokens: int) -> float:
    """6 * N_active * D (trained tokens)."""
    from repro.configs.base import active_param_count
    return 6.0 * active_param_count(cfg) * tokens


def model_flops_decode(cfg, tokens: int) -> float:
    """2 * N_active * D for forward-only decode."""
    from repro.configs.base import active_param_count
    return 2.0 * active_param_count(cfg) * tokens
