"""HLO-text cost model with call-graph multiplicity.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, which
makes scanned-layer models (all of ours) look ~n_layers too cheap. This
module re-derives the three roofline inputs from the optimized HLO text,
walking the call graph with multiplicities:

  * while body/condition  x known_trip_count (backend_config)
  * fusion called computations: FLOPs counted, HBM bytes NOT (internal to
    the fusion's VMEM tile) — the fusion op itself pays operands+result
  * FLOPs: dot ops (2 * prod(out) * prod(contracted lhs dims));
    elementwise flops are ignored (matmul-dominated workloads)
  * HBM bytes: operands+result of every non-fused top-level op (the
    fusion-boundary traffic model XLA itself uses)
  * collective bytes: result sizes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, by kind

All numbers are PER DEVICE (the HLO is already SPMD-partitioned).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from functools import lru_cache

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id",
    "replica-id", "iota",
}
_COLLECTIVE_OPS = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "collective-permute-start",
}


def _parse_shape(type_str):
    """-> (total_bytes, [(dtype, dims), ...])."""
    total = 0
    parts = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims_s = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in dims_s.split(",") if d]
        n = math.prod(dims) if dims else 1
        total += n * _DTYPE_BYTES[dt]
        parts.append((dt, dims))
    return total, parts


@dataclass
class Instruction:
    name: str
    opcode: str
    out_bytes: int
    out_dims: list
    operands: list          # operand op names
    raw: str
    called: list = field(default_factory=list)   # (comp_name, kind)
    trip_count: int = 1
    contracting: list = field(default_factory=list)  # lhs contracting dims


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*([^=]+?)\s+([\w\-]+)\(")
_COMP_HEAD_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _parse_instr(line):
    # strip /*index=N*/ comments inside big tuple types — their '=' breaks
    # the regexes
    line = re.sub(r"/\*.*?\*/", "", line)
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name, type_str, opcode = m.group(1), m.group(2), m.group(3)
    out_bytes, parts = _parse_shape(type_str)
    out_dims = parts[0][1] if len(parts) == 1 else None
    # operands: inside the first (...) — up to the closing paren at depth 0
    args_start = line.index(opcode + "(") + len(opcode) + 1
    depth = 1
    i = args_start
    while i < len(line) and depth:
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
        i += 1
    args_str = line[args_start:i - 1]
    operands = _OPERAND_RE.findall(args_str)
    instr = Instruction(name=name, opcode=opcode, out_bytes=out_bytes,
                        out_dims=out_dims, operands=operands, raw=line)
    rest = line[i:]
    # called computations; to_apply= is a real call for `call`/`custom-call`
    # ops but a scalar applier for reduce/scatter/sort/map/select-and-scatter
    apply_kind = "call" if opcode in ("call", "custom-call", "async-start") \
        else "apply"
    for attr, kind in (("calls=", "fusion"), ("body=", "body"),
                       ("condition=", "cond"), ("to_apply=", apply_kind)):
        for m2 in re.finditer(re.escape(attr) + r"%?([\w.\-]+)", rest):
            instr.called.append((m2.group(1), kind))
    m3 = re.search(r"branch_computations=\{([^}]*)\}", rest)
    if m3:
        for nm in _OPERAND_RE.findall(m3.group(1)):
            instr.called.append((nm, "branch"))
    m4 = re.search(r'known_trip_count..?:?.?\{"?n"?[:=]"?(\d+)"?\}', rest)
    if m4:
        instr.trip_count = int(m4.group(1))
    m5 = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
    if m5:
        instr.contracting = [int(d) for d in m5.group(1).split(",") if d]
    return instr


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[Instruction]] = {}
        self.shape_of: dict[str, tuple] = {}   # name -> (bytes, dims)
        self.entry = None
        cur = None
        for line in text.splitlines():
            mh = _COMP_HEAD_RE.match(line)
            if mh and line.rstrip().endswith("{"):
                cur = mh.group(2)
                self.computations[cur] = []
                if mh.group(1):
                    self.entry = cur
                # register parameters' shapes from the header
                hdr = line[line.index("("):]
                for pm in re.finditer(r"([\w.\-]+):\s*([^,)]+)", hdr):
                    b, parts = _parse_shape(pm.group(2))
                    dims = parts[0][1] if len(parts) == 1 else None
                    self.shape_of[pm.group(1)] = (b, dims)
                continue
            if cur is None:
                continue
            instr = _parse_instr(line)
            if instr is not None:
                self.computations[cur].append(instr)
                self.shape_of[instr.name] = (instr.out_bytes, instr.out_dims)

        # computations reached via fusion calls (internal: no HBM bytes)
        self.fused: set[str] = set()
        for instrs in self.computations.values():
            for ins in instrs:
                for nm, kind in ins.called:
                    if kind == "fusion":
                        self.fused.add(nm)

    @lru_cache(maxsize=None)
    def _fusion_param_access(self, comp_name: str):
        """Per fusion computation: how each parameter index is accessed.

        Returns (param_bytes: {idx: effective_read_bytes or None for full},
                 root_dus_update_bytes or None).

        Scan bodies wrap per-step reads/writes of big (seq, ...) buffers in
        fusions: a parameter consumed ONLY by dynamic-slice reads just the
        slice; a root dynamic-update-slice writes just the update (XLA
        aliases the buffer in place). Charging the full buffer per loop
        iteration overstates HBM traffic by the trip count (~4096x for a
        4k-seq scan) — this is the fusion-aware correction."""
        instrs = self.computations.get(comp_name, [])
        param_name_to_idx = {}
        for ins in instrs:
            if ins.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", ins.raw)
                if m:
                    param_name_to_idx[ins.name] = int(m.group(1))
        # consumers of each parameter
        eff: dict[int, float] = {}
        for pname, pidx in param_name_to_idx.items():
            consumers = [i for i in instrs if pname in i.operands]
            if consumers and all(c.opcode == "dynamic-slice" and
                                 c.operands and c.operands[0] == pname
                                 for c in consumers):
                eff[pidx] = sum(c.out_bytes for c in consumers)
        root = instrs[-1] if instrs else None
        root_dus = None
        aliased_pidx = None
        if root is not None and root.opcode == "dynamic-update-slice" and \
                len(root.operands) > 1:
            root_dus = self.shape_of.get(root.operands[1], (0, None))[0]
            # the updated buffer (operand 0): if it is a fusion parameter
            # (directly or through a bitcast), it is aliased in place
            buf = root.operands[0]
            seen = set()
            while buf not in param_name_to_idx and buf not in seen:
                seen.add(buf)
                src = next((i for i in instrs if i.name == buf), None)
                if src is not None and src.opcode in ("bitcast", "copy") \
                        and src.operands:
                    buf = src.operands[0]
                else:
                    break
            aliased_pidx = param_name_to_idx.get(buf)
        return eff, root_dus, aliased_pidx

    @lru_cache(maxsize=None)
    def _is_pure_convert(self, comp_name: str) -> bool:
        """True for fusions that only change dtype (optionally through
        bitcast/copy/transpose). The CPU backend materializes bf16->f32
        weight conversions before every GEMM because the host has no bf16
        matmul units — a TPU compile feeds bf16 to the MXU directly, so
        these fusions (and their full-weight traffic) do not exist on the
        target hardware and are excluded from the HBM model."""
        instrs = self.computations.get(comp_name, [])
        ops = {i.opcode for i in instrs}
        return bool(instrs) and ops <= {"parameter", "convert", "bitcast",
                                        "copy", "transpose"}

    def _fusion_bytes(self, ins: Instruction) -> float:
        """Operand+result bytes of a fusion op, slice-aware."""
        comp = next((nm for nm, kind in ins.called if kind == "fusion"), None)
        if comp is None:
            return ins.out_bytes + self._operand_bytes(ins)
        if self._is_pure_convert(comp):
            return 0.0
        eff, root_dus, aliased_pidx = self._fusion_param_access(comp)
        total = 0.0
        for i, op in enumerate(ins.operands):
            if root_dus is not None and i == aliased_pidx:
                continue  # in-place buffer: charged via the update below
            if i in eff:
                total += eff[i]
            else:
                total += self.shape_of.get(op, (0, None))[0]
        if root_dus is not None:
            # in-place update: read+write of the update region only
            total += 2 * root_dus
        else:
            total += ins.out_bytes
        return total

    # ------------------------------------------------------------------
    def _dot_flops(self, ins: Instruction) -> float:
        if ins.out_dims is None:
            return 0.0
        out_n = math.prod(ins.out_dims) if ins.out_dims else 1
        lhs = ins.operands[0] if ins.operands else None
        lhs_dims = self.shape_of.get(lhs, (0, None))[1]
        if lhs_dims is None:
            # fall back: inline shape in raw text
            m = _SHAPE_RE.search(ins.raw[ins.raw.index("("):])
            lhs_dims = [int(d) for d in m.group(2).split(",") if d] if m else []
        k = math.prod([lhs_dims[i] for i in ins.contracting]) \
            if ins.contracting and lhs_dims else 1
        return 2.0 * out_n * k

    def _conv_flops(self, ins: Instruction) -> float:
        if ins.out_dims is None or len(ins.operands) < 2:
            return 0.0
        out_n = math.prod(ins.out_dims)
        rhs_dims = self.shape_of.get(ins.operands[1], (0, None))[1] or []
        # kernel: spatial... x in_ch x out_ch (approx: all but out features)
        k = math.prod(rhs_dims[:-1]) if rhs_dims else 1
        return 2.0 * out_n * k

    def _operand_bytes(self, ins: Instruction) -> int:
        return sum(self.shape_of.get(o, (0, None))[0] for o in ins.operands)

    # ------------------------------------------------------------------
    def aggregate(self):
        """Returns dict with per-device flops, hbm_bytes, collective bytes
        by kind, and counts."""
        memo: dict[tuple, tuple] = {}

        def comp_cost(name, top_level):
            key = (name, top_level)
            if key in memo:
                return memo[key]
            flops = 0.0
            hbm = 0.0
            coll = {}
            ccnt = {}

            def add_coll(d, cnt, mult=1):
                for k, v in d.items():
                    coll[k] = coll.get(k, 0.0) + v * mult
                for k, v in cnt.items():
                    ccnt[k] = ccnt.get(k, 0) + v * mult

            for ins in self.computations.get(name, []):
                if ins.opcode == "dot":
                    flops += self._dot_flops(ins)
                elif ins.opcode == "convolution":
                    flops += self._conv_flops(ins)
                base = ins.opcode.replace("-start", "") \
                    if ins.opcode.endswith("-start") else ins.opcode
                if base in _COLLECTIVE_OPS:
                    coll[base] = coll.get(base, 0.0) + ins.out_bytes
                    ccnt[base] = ccnt.get(base, 0) + 1
                if top_level and ins.opcode not in _SKIP_BYTES_OPS and \
                        not ins.opcode.endswith("-done"):
                    if ins.opcode == "dynamic-slice":
                        # reads only the sliced window, not the whole operand
                        hbm += 2 * ins.out_bytes
                    elif ins.opcode == "dynamic-update-slice":
                        # writes/reads the update region within the buffer
                        upd = self.shape_of.get(
                            ins.operands[1], (0, None))[0] \
                            if len(ins.operands) > 1 else ins.out_bytes
                        hbm += 3 * upd
                    elif ins.opcode == "fusion":
                        hbm += self._fusion_bytes(ins)
                    else:
                        hbm += ins.out_bytes + self._operand_bytes(ins)
                for nm, kind in ins.called:
                    sub_top = top_level and kind != "fusion"
                    f2, h2, c2, n2 = comp_cost(nm, sub_top)
                    mult = ins.trip_count if kind in ("body", "cond") else 1
                    if kind == "apply":
                        continue  # scalar reduce bodies: negligible
                    flops += f2 * mult
                    hbm += h2 * mult
                    add_coll(c2, n2, mult)
            memo[key] = (flops, hbm, coll, ccnt)
            return memo[key]

        entry = self.entry or next(iter(self.computations))
        flops, hbm, coll, ccnt = comp_cost(entry, True)
        return {
            "flops": flops,
            "hbm_bytes": hbm,
            "collective_bytes_by_kind": coll,
            "collective_counts_by_kind": ccnt,
            "collective_bytes": sum(coll.values()),
        }


def analyze_hlo_text(text: str) -> dict:
    return HloModule(text).aggregate()
