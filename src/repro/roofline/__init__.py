from repro.roofline.analysis import (Roofline, analyze, model_flops_decode,
                                     model_flops_train, parse_collectives)

__all__ = ["Roofline", "analyze", "model_flops_decode", "model_flops_train",
           "parse_collectives"]
