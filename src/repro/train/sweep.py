"""Batched sweep engine: a whole hyperparameter/seed grid as ONE program.

The paper's empirical claims are sweeps — Fig 3 varies beta/gamma/lambda,
Tables 1/2 average over seeds — and the scanned engine (engine.py) still
dispatched them one configuration at a time: S sequential compiles+runs.
This module runs all S configurations in a single compiled program:

    jit( vmap over the (S,) config axis:
           chunked scan over rounds (the engine's round program, verbatim)
         -> per-config metric histories, final states, realized counts )

What makes this possible is the hyperparameter split (`tree_hparams` on
every FLAlgorithm): float hyperparameters are *sweepable leaves* that
stack into (S,) f32 arrays and trace, while loop bounds, loss functions,
and branch-selecting knobs stay static structure shared by every config.
Each vmap lane rebuilds its own algorithm instance from its slice of the
stacked leaves — same round code, S sets of values, one XLA program.

Seeds ride the same axis. A seed contributes (a) the in-graph
participation-sampling PRNG chain (exactly run_experiment's) and
(b) optionally the model init, when ``params0`` is a callable
``seed -> params`` evaluated per config on the host.

On hardware, the (S,) axis shards over the mesh's ``sweep`` axis — the
repurposed pod/DCN tier, since configs never communicate — while each
config's (M, N) state shards over (data, model) as before; see
``launch.mesh.make_sweep_mesh`` / ``sharding.specs.sweep_pspecs`` and
DESIGN.md §6. Byte accounting stays on the host: realized participation
counts come back per config and feed one CommLedger each.
"""
from __future__ import annotations

import functools
import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.engine import (_METRIC_FIELDS, FLResult, _chunk_runner,
                                check_participation, hparam_skeleton)

__all__ = ["FLSweepResult", "grid_product", "run_sweep"]


def grid_product(**axes) -> list:
    """Cartesian product of named value lists as a list of config dicts.

    ``grid_product(beta=[0.1, 0.5], lam=[1.0])`` ->
    ``[{"beta": 0.1, "lam": 1.0}, {"beta": 0.5, "lam": 1.0}]``.
    """
    names = list(axes)
    return [dict(zip(names, vals))
            for vals in itertools.product(*axes.values())]


@dataclass
class FLSweepResult:
    """One vmapped sweep: S = len(grid) * len(seeds) configurations.

    configs: resolved per-config dicts — every sweepable hyperparameter
        plus the config's ``seed`` — in grid-major order (all seeds of
        grid[0], then grid[1], ...).
    results: one FLResult per config (trajectories, final state slice,
        participation, per-config CommLedger). ``FLResult.seconds`` is
        the sweep wall time amortized over S.
    state_stacked: final-state pytree with the leading (S,) config axis
        intact (sharded over the mesh's sweep axis when one was given).
    dispatches: jitted calls that executed the whole sweep (1, or 2 when
        rounds % eval_every != 0 leaves a remainder chunk).
    """
    configs: list = field(default_factory=list)
    results: list = field(default_factory=list)
    state_stacked: Any = None
    seconds: float = 0.0
    dispatches: int = 0

    def __len__(self):
        return len(self.results)

    def __getitem__(self, i) -> FLResult:
        return self.results[i]

    def __iter__(self):
        return iter(self.results)

    def best(self, which="pm") -> list:
        """Per-config best metric (see FLResult.best)."""
        return [r.best(which) for r in self.results]

    def final(self, which="pm") -> list:
        """Per-config final-eval metric."""
        return [r.last(which) for r in self.results]


# One compiled program per (hparam skeleton, metric_fn, dims,
# participation) — every grid/seed stacking with matching static
# structure reuses it, whatever the hyperparameter values are (they are
# traced operands), and each vmap lane runs the engine's chunk program
# (_chunk_runner) verbatim.
@functools.lru_cache(maxsize=64)
def _sweep_program(skel, metric_fn, m, n, team_frac, device_frac):
    run_chunks = _chunk_runner(skel, metric_fn, m, n, team_frac,
                               device_frac)

    @functools.partial(jax.jit, static_argnames=("length", "n_steps"))
    def swept(hstack, states, keys, tr, va, *, length, n_steps):
        """vmap over the (S,) axis of (hstack, states, keys)."""
        return jax.vmap(lambda h, s, k: run_chunks(
            h, s, k, tr, va, length=length, n_steps=n_steps))(
                hstack, states, keys)

    return swept


def _stack_trees(trees):
    """[pytree, ...] -> one pytree with a leading (S,) axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def run_sweep(algo, grid, seeds, params0, train_data, val_data, *,
              metric_fn: Callable, rounds: int, m: int, n: int,
              team_frac: float = 1.0, device_frac: float = 1.0,
              eval_every: int = 1, mesh=None) -> FLSweepResult:
    """Run ``len(grid) * len(seeds)`` experiments as one compiled program.

    algo: the template FLAlgorithm instance — its float hyperparameters
        (``algo.tree_hparams()``) are the sweepable names; static config
        (loop bounds, loss_fn, comm) is shared by every configuration.
    grid: list of {hparam: value} overrides, one per grid point (dicts may
        set different keys — unset names keep the template's value), or a
        {name: [values...]} dict taken as the full cartesian product.
    seeds: int or sequence of ints; every grid point runs once per seed.
        The seed drives the in-graph participation-sampling chain exactly
        as ``run_experiment(seed=...)`` does.
    params0: initial (unstacked) model pytree shared by all configs, or a
        callable ``seed -> params`` for per-seed inits (multi-seed tables).
    mesh: optional Mesh with a ``sweep`` axis — inputs are placed so the
        (S,) config axis shards across it and XLA runs configurations on
        disjoint devices (``launch.mesh.make_sweep_mesh``).
    Remaining arguments match ``run_experiment``.

    Returns an FLSweepResult; equivalence with the sequential loop
    ``[run_experiment(rebuild(cfg), ...) for cfg in configs]`` is pinned
    by tests/test_sweep.py.
    """
    if isinstance(grid, dict):
        grid = grid_product(**grid)
    grid = [dict(g) for g in grid]
    if not grid:
        raise ValueError("empty grid: pass [{}] for a seeds-only sweep")
    if isinstance(seeds, int):
        seeds = (seeds,)
    seeds = tuple(int(s) for s in seeds)
    if not seeds:
        raise ValueError("empty seeds: pass at least one PRNG seed")
    check_participation(algo, team_frac, device_frac)

    leaves0, _ = algo.tree_hparams()
    for g in grid:
        unknown = set(g) - set(leaves0)
        if unknown:
            raise ValueError(
                f"unknown sweepable hyperparameter(s) {sorted(unknown)}; "
                f"{type(algo).__name__} sweeps over {sorted(leaves0)}")

    combos = [(g, s) for g in grid for s in seeds]   # grid-major
    configs = [dict(leaves0, **g, seed=s) for g, s in combos]
    hstack = {k: jnp.asarray([float(dict(leaves0, **g)[k])
                              for g, _ in combos], jnp.float32)
              for k in leaves0}
    keys = jnp.stack([jax.random.PRNGKey(s) for _, s in combos])

    if callable(params0):
        p_by_seed = {s: params0(s) for s in seeds}
        # one init per seed, however many grid points share it
        st_by_seed = {s: algo.init_state(p_by_seed[s], m, n)
                      for s in seeds}
        states = _stack_trees([st_by_seed[s] for _, s in combos])
        ledger_params = p_by_seed[seeds[0]]
    else:
        state0 = algo.init_state(params0, m, n)
        S = len(combos)
        states = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (S,) + x.shape), state0)
        ledger_params = params0

    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.sharding.specs import sweep_pspecs, to_named

        def place(tree):
            specs = to_named(sweep_pspecs(tree, m=m, n=n), mesh,
                             shape_tree=tree)
            return jax.tree.map(jax.device_put, tree, specs)

        states, hstack = place(states), place(hstack)
        # keys are (S, 2) uint32: place explicitly — the shape heuristic
        # would mistake the 2 key words for a team axis when m == 2
        keys = jax.device_put(keys, NamedSharding(mesh, P("sweep", None)))
        repl = NamedSharding(mesh, P())
        train_data = jax.tree.map(lambda x: jax.device_put(x, repl),
                                  train_data)
        val_data = jax.tree.map(lambda x: jax.device_put(x, repl),
                                val_data)

    skel, _ = hparam_skeleton(algo)
    swept = _sweep_program(skel, metric_fn, m, n, team_frac, device_frac)
    n_chunks, rem = divmod(rounds, eval_every)

    metric_hist = {}           # field -> list of (S, n_steps) arrays
    count_hist = []            # list of ((S, n_steps, len), (S, ...)) pairs
    dispatches = 0
    t0 = time.time()
    for length, n_steps in ((eval_every, n_chunks), (rem, 1)):
        if length == 0 or n_steps == 0:
            continue
        (states, keys), (metrics, counts) = swept(
            hstack, states, keys, train_data, val_data, length=length,
            n_steps=n_steps)
        dispatches += 1
        for k, v in metrics.items():
            metric_hist.setdefault(k, []).append(np.asarray(v))
        count_hist.append(tuple(np.asarray(c) for c in counts))
    seconds = time.time() - t0

    out = FLSweepResult(configs=configs, state_stacked=states,
                        seconds=seconds, dispatches=dispatches)
    for i in range(len(combos)):
        res = FLResult(seconds=seconds / len(combos))
        for k, segs in metric_hist.items():
            getattr(res, _METRIC_FIELDS[k]).extend(
                float(x) for seg in segs for x in seg[i])
        for tc, dc in count_hist:
            res.participation.extend(zip(tc[i].reshape(-1).tolist(),
                                         dc[i].reshape(-1).tolist()))
        res.state = jax.tree.map(lambda x: x[i], states)
        ledger = algo.make_ledger(ledger_params)
        if ledger is not None:
            for n_teams, n_devices in res.participation:
                algo.log_comm_round(ledger, n_teams=n_teams,
                                    n_devices=n_devices)
            res.comm = ledger
        out.results.append(res)
    return out
