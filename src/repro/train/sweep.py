"""Batched sweep engine: a whole hyperparameter/seed grid as ONE program.

The paper's empirical claims are sweeps — Fig 3 varies beta/gamma/lambda,
Tables 1/2 average over seeds — and the scanned engine (engine.py) still
dispatched them one configuration at a time: S sequential compiles+runs.
This module runs all S configurations in a single compiled program:

    jit( vmap over the (S,) config axis:
           chunked scan over rounds (the engine's round program, verbatim)
         -> per-config metric histories, final states, realized counts )

What makes this possible is the hyperparameter split (`tree_hparams` on
every FLAlgorithm): float hyperparameters are *sweepable leaves* that
stack into (S,) f32 arrays and trace, while loop bounds, loss functions,
and branch-selecting knobs stay static structure shared by every config.
Each vmap lane rebuilds its own algorithm instance from its slice of the
stacked leaves — same round code, S sets of values, one XLA program.

Seeds ride the same axis. A seed contributes (a) the in-graph
participation-sampling PRNG chain (exactly run_experiment's) and
(b) optionally the model init, when ``params0`` is a callable
``seed -> params`` evaluated per config on the host.

System profiles (`repro.system.SystemSpec`) ride the axis too: a spec
splits into float leaves exactly like hyperparameters
(``tree_floats``), so ``system=[...]`` stacks several wall-clock worlds
— LAN campus vs cellular WAN vs IoT edge — into (S,) operands of the
same program, and each config comes back with its own simulated
`Timeline` (DESIGN.md §8). For grids whose *static* structure differs —
e.g. different compressors, which change the round graph itself —
``run_multi_sweep`` fuses several prepared sweeps into one jitted
program so they still cost a single dispatch.

On hardware, the (S,) axis shards over the mesh's ``sweep`` axis — the
repurposed pod/DCN tier, since configs never communicate — while each
config's (M, N) state shards over (data, model) as before; see
``launch.mesh.make_sweep_mesh`` / ``sharding.specs.sweep_pspecs`` and
DESIGN.md §6. Byte accounting stays on the host: realized participation
counts come back per config and feed one CommLedger each.
"""
from __future__ import annotations

import functools
import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.interface import dispatch_key
from repro.obs.events import write_sweep
from repro.obs.health import HealthReport
from repro.obs.spans import SpanLog, current_log, span
from repro.obs.trace import RunTrace, TraceConfig
from repro.system import get_profile
from repro.train.engine import (_METRIC_FIELDS, FLResult,
                                assemble_timeline, _chunk_runner,
                                check_participation, hparam_skeleton)

__all__ = ["FLSweepResult", "grid_product", "run_multi_sweep", "run_sweep"]


def grid_product(**axes) -> list:
    """Cartesian product of named value lists as a list of config dicts.

    ``grid_product(beta=[0.1, 0.5], lam=[1.0])`` ->
    ``[{"beta": 0.1, "lam": 1.0}, {"beta": 0.5, "lam": 1.0}]``.
    """
    names = list(axes)
    return [dict(zip(names, vals))
            for vals in itertools.product(*axes.values())]


@dataclass
class FLSweepResult:
    """One vmapped sweep: S = len(grid) * len(seeds) * len(profiles)
    configurations.

    configs: resolved per-config dicts — every sweepable hyperparameter
        plus the config's ``seed`` (and ``system`` profile name when
        system models ride the axis) — in grid-major order (all seeds of
        grid[0], then grid[1], ...; profiles innermost).
    results: one FLResult per config (trajectories, final state slice,
        participation, per-config CommLedger and Timeline). Wall times
        on each FLResult are the sweep's, amortized over S, with the
        same ``seconds = compile_seconds + run_seconds`` split as
        ``run_experiment``.
    state_stacked: final-state pytree with the leading (S,) config axis
        intact (sharded over the mesh's sweep axis when one was given).
    dispatches: jitted calls that executed the whole sweep (1, or 2 when
        rounds % eval_every != 0 leaves a remainder chunk).
    """
    configs: list = field(default_factory=list)
    results: list = field(default_factory=list)
    state_stacked: Any = None
    seconds: float = 0.0
    compile_seconds: float = 0.0
    run_seconds: float = 0.0
    dispatches: int = 0
    events_path: Optional[str] = None    # JSONL event log (trace_dir runs)

    def __len__(self):
        return len(self.results)

    def __getitem__(self, i) -> FLResult:
        return self.results[i]

    def __iter__(self):
        return iter(self.results)

    def best(self, which="pm") -> list:
        """Per-config best metric (see FLResult.best)."""
        return [r.best(which) for r in self.results]

    def final(self, which="pm") -> list:
        """Per-config final-eval metric."""
        return [r.last(which) for r in self.results]


# One compiled program per (hparam skeleton, metric_fn, dims,
# participation, system skeleton) — every grid/seed/profile stacking
# with matching static structure reuses it, whatever the hyperparameter
# or system values are (they are traced operands), and each vmap lane
# runs the engine's chunk program (_chunk_runner) verbatim.
@functools.lru_cache(maxsize=64)
def _sweep_program(skel, metric_fn, m, n, team_frac, device_frac,
                   sys_key=None, trace=None, kdispatch=None, cohort=None):
    run_chunks = _chunk_runner(skel, metric_fn, m, n, team_frac,
                               device_frac, sys_key, trace, cohort)

    @functools.partial(jax.jit, static_argnames=("length", "n_steps"))
    def swept(hstack, states, keys, sstack, tr, va, *, length, n_steps):
        """vmap over the (S,) axis of (hstack, states, keys[, sstack])."""
        if sys_key is None:
            return jax.vmap(lambda h, s, k: run_chunks(
                h, s, k, tr, va, length=length, n_steps=n_steps))(
                    hstack, states, keys)
        return jax.vmap(lambda h, s, k, sl: run_chunks(
            h, s, k, tr, va, sleaves=sl, length=length,
            n_steps=n_steps))(hstack, states, keys, sstack)

    return swept


def _stack_trees(trees):
    """[pytree, ...] -> one pytree with a leading (S,) axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


@dataclass
class _Prepared:
    """One sweep's validated, stacked operands + static program key."""
    algo: Any
    skel: Any
    sys_key: Any               # (SystemSpec skeleton, RoundWorkload) | None
    team_frac: float
    device_frac: float
    hstack: dict
    sstack: Optional[dict]
    states: Any
    keys: Any
    configs: list
    profiles: list             # per-combo SystemSpec | None
    ledger_params: Any


def _prepare(algo, grid, seeds, params0, m, n, team_frac, device_frac,
             system) -> _Prepared:
    """Validate one sweep and stack its (S,) operands (shared by
    run_sweep and run_multi_sweep)."""
    if isinstance(grid, dict):
        grid = grid_product(**grid)
    grid = [dict(g) for g in grid]
    if not grid:
        raise ValueError("empty grid: pass [{}] for a seeds-only sweep")
    if isinstance(seeds, int):
        seeds = (seeds,)
    seeds = tuple(int(s) for s in seeds)
    if not seeds:
        raise ValueError("empty seeds: pass at least one PRNG seed")
    check_participation(algo, team_frac, device_frac)

    if system is None:
        profiles = [None]
    else:
        if isinstance(system, (str, dict)) or not isinstance(
                system, (list, tuple)):
            system = [system]
        profiles = [get_profile(p) for p in system]
        # unreachable today — every SystemSpec skeleton zeroes the same
        # all-float fields — but guards the day the spec grows static
        # structure (e.g. a distribution-kind switch), which would
        # silently compile the wrong program for mixed profiles
        skels = {p.skeleton() for p in profiles}
        if len(skels) != 1:
            raise ValueError(
                "system profiles on one sweep axis must share a static "
                f"skeleton; got {len(skels)} distinct ones")

    leaves0, _ = algo.tree_hparams()
    for g in grid:
        unknown = set(g) - set(leaves0)
        if unknown:
            raise ValueError(
                f"unknown sweepable hyperparameter(s) {sorted(unknown)}; "
                f"{type(algo).__name__} sweeps over {sorted(leaves0)}")

    combos = [(g, s, p) for g in grid for s in seeds for p in profiles]
    configs = [dict(leaves0, **g, seed=s,
                    **({"system": p.name} if p is not None else {}))
               for g, s, p in combos]
    hstack = {k: jnp.asarray([float(dict(leaves0, **g)[k])
                              for g, _, _ in combos], jnp.float32)
              for k in leaves0}
    keys = jnp.stack([jax.random.PRNGKey(s) for _, s, _ in combos])

    sys_key = sstack = None
    if profiles[0] is not None:
        sys_leaves = [p.tree_floats()[0] for _, _, p in combos]
        sstack = {k: jnp.asarray([sl[k] for sl in sys_leaves], jnp.float32)
                  for k in sys_leaves[0]}

    if callable(params0):
        p_by_seed = {s: params0(s) for s in seeds}
        # one init per seed, however many grid points share it
        st_by_seed = {s: algo.init_state(p_by_seed[s], m, n)
                      for s in seeds}
        states = _stack_trees([st_by_seed[s] for _, s, _ in combos])
        ledger_params = p_by_seed[seeds[0]]
    else:
        state0 = algo.init_state(params0, m, n)
        S = len(combos)
        states = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (S,) + x.shape), state0)
        ledger_params = params0

    if profiles[0] is not None:
        from repro.system import workload_for
        sys_key = (profiles[0].skeleton(),
                   workload_for(algo, ledger_params))

    skel, _ = hparam_skeleton(algo)
    return _Prepared(algo=algo, skel=skel, sys_key=sys_key,
                     team_frac=team_frac, device_frac=device_frac,
                     hstack=hstack, sstack=sstack, states=states,
                     keys=keys, configs=configs,
                     profiles=[p for _, _, p in combos],
                     ledger_params=ledger_params)


def _collect(prep: _Prepared, states, metric_hist, outs_hist, *,
             seconds, compile_seconds, run_seconds, dispatches, rounds,
             eval_every, trace=None, cohort=None,
             population=None) -> FLSweepResult:
    """Slice one sweep's stacked outputs into per-config FLResults.

    metric_hist: field -> list of (S, n_steps) arrays; outs_hist: list of
    per-segment dicts of (S, n_steps, length) per-round output arrays.
    trace: the sweep's TraceConfig — when set, each config's ``probe:``
    output streams become a per-config `RunTrace` (and its ``health:``
    streams a per-config `HealthReport`, checked immediately per config
    under ``trace.fail_fast``).
    cohort/population: the sweep's virtualized-engine dims, recorded on
    each FLResult; per-config ``cohort_idx`` streams land in
    ``FLResult.cohort_indices``.
    """
    S = len(prep.configs)
    out = FLSweepResult(configs=prep.configs, state_stacked=states,
                        seconds=seconds, compile_seconds=compile_seconds,
                        run_seconds=run_seconds, dispatches=dispatches)
    for i in range(S):
        res = FLResult(seconds=seconds / S,
                       compile_seconds=compile_seconds / S,
                       run_seconds=run_seconds / S, rounds=rounds,
                       eval_every=eval_every, dispatches=dispatches,
                       cohort=cohort, population=population)
        for k, segs in metric_hist.items():
            getattr(res, _METRIC_FIELDS[k]).extend(
                float(x) for seg in segs for x in seg[i])
        flat = {}
        for seg in outs_hist:
            for k, v in seg.items():
                if k == "cohort_idx":
                    arr = np.asarray(v[i])
                    res.cohort_indices.extend(
                        arr.reshape((-1,) + arr.shape[-2:]).astype(int)
                        .tolist())
                    continue
                flat.setdefault(k, []).extend(v[i].reshape(-1).tolist())
        if trace is not None:
            res.trace = RunTrace(config=trace, series={
                k.split(":", 1)[1]: flat.pop(k)
                for k in sorted(flat) if k.startswith("probe:")})
            if trace.health:
                res.health = HealthReport(series={
                    k.split(":", 1)[1]: flat.pop(k)
                    for k in sorted(flat) if k.startswith("health:")})
                if trace.fail_fast:
                    res.health.check(f"config {i}")
        res.participation = list(zip([int(x) for x in flat["teams"]],
                                     [int(x) for x in flat["devices"]]))
        if "t_round" in flat:
            assemble_timeline(res, prep.profiles[i].name, flat["t_round"],
                              flat["dropped_teams"],
                              flat["dropped_devices"], rounds, eval_every)
        res.state = jax.tree.map(lambda x: x[i], states)
        ledger = prep.algo.make_ledger(prep.ledger_params)
        if ledger is not None:
            for n_teams, n_devices in res.participation:
                prep.algo.log_comm_round(ledger, n_teams=n_teams,
                                         n_devices=n_devices)
            res.comm = ledger
        out.results.append(res)
    return out


def run_sweep(algo, grid, seeds, params0, train_data, val_data, *,
              metric_fn: Callable, rounds: int, m: int, n: int,
              team_frac: float = 1.0, device_frac: float = 1.0,
              eval_every: int = 1, mesh=None, system=None, trace=None,
              trace_dir=None, event_meta=None,
              cohort: Optional[int] = None) -> FLSweepResult:
    """Run ``len(grid) * len(seeds) [* len(system)]`` experiments as one
    compiled program.

    algo: the template FLAlgorithm instance — its float hyperparameters
        (``algo.tree_hparams()``) are the sweepable names; static config
        (loop bounds, loss_fn, comm) is shared by every configuration.
    grid: list of {hparam: value} overrides, one per grid point (dicts may
        set different keys — unset names keep the template's value), or a
        {name: [values...]} dict taken as the full cartesian product.
    seeds: int or sequence of ints; every grid point runs once per seed.
        The seed drives the in-graph participation-sampling chain exactly
        as ``run_experiment(seed=...)`` does.
    params0: initial (unstacked) model pytree shared by all configs, or a
        callable ``seed -> params`` for per-seed inits (multi-seed tables).
    mesh: optional Mesh with a ``sweep`` axis — inputs are placed so the
        (S,) config axis shards across it and XLA runs configurations on
        disjoint devices (``launch.mesh.make_sweep_mesh``).
    system: optional wall-clock model(s): one SystemSpec / profile name /
        spec dict, or a sequence of them — a sequence adds a *system
        profile* axis to the sweep (innermost), every profile sharing the
        compiled program via its float-leaf split. Each config's FLResult
        gains a simulated `Timeline` + `sim_seconds`.
    trace: optional `repro.obs.TraceConfig` (or True): probe scalars ride
        the vmapped scan outputs and each config's FLResult gains its own
        `RunTrace` — identical streams to running the config alone.
    trace_dir / event_meta: when set, write the whole sweep's JSONL event
        stream (sweep_header + per-config run sections) into trace_dir.
    cohort: optional cohort width — every config runs on the virtualized
        cohort engine (`run_experiment(cohort=...)`) with its own
        per-config device-state store riding the vmap axis.
    Remaining arguments match ``run_experiment``.

    Returns an FLSweepResult; equivalence with the sequential loop
    ``[run_experiment(rebuild(cfg), ...) for cfg in configs]`` is pinned
    by tests/test_sweep.py.
    """
    kw = dict(metric_fn=metric_fn, rounds=rounds, m=m, n=n,
              team_frac=team_frac, device_frac=device_frac,
              eval_every=eval_every, mesh=mesh, system=system,
              trace=trace, trace_dir=trace_dir, event_meta=event_meta,
              cohort=cohort)
    # span-log ownership mirrors run_experiment: outermost trace_dir
    # caller creates and saves; an already-active log absorbs our spans
    if trace_dir is None or current_log() is not None:
        return _run_sweep(algo, grid, seeds, params0, train_data,
                          val_data, **kw)
    tag = f"sweep-{getattr(algo, 'name', None) or 'run'}"
    log = SpanLog(meta={"kind": "sweep", "algo": getattr(algo, "name",
                                                         None)})
    with log.activate():
        try:
            return _run_sweep(algo, grid, seeds, params0, train_data,
                              val_data, **kw)
        finally:
            log.save(trace_dir, tag=tag)


def _run_sweep(algo, grid, seeds, params0, train_data, val_data, *,
               metric_fn, rounds, m, n, team_frac, device_frac,
               eval_every, mesh, system, trace, trace_dir, event_meta,
               cohort) -> FLSweepResult:
    if trace is True:
        trace = TraceConfig()
    if cohort is not None:
        cohort = int(cohort)
        if not 1 <= cohort <= n:
            raise ValueError(
                f"cohort must be in [1, n_devices={n}], got {cohort}")
    with span("build", algo=getattr(algo, "name", "?"), m=m, n=n,
              rounds=rounds):
        prep = _prepare(algo, grid, seeds, params0, m, n, team_frac,
                        device_frac, system)
    states, keys, hstack, sstack = (prep.states, prep.keys, prep.hstack,
                                    prep.sstack)

    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.sharding.specs import sweep_pspecs, to_named

        def place(tree):
            specs = to_named(sweep_pspecs(tree, m=m, n=n), mesh,
                             shape_tree=tree)
            return jax.tree.map(jax.device_put, tree, specs)

        states, hstack = place(states), place(hstack)
        if sstack is not None:
            sstack = place(sstack)
        # keys are (S, 2) uint32: place explicitly — the shape heuristic
        # would mistake the 2 key words for a team axis when m == 2
        keys = jax.device_put(keys, NamedSharding(mesh, P("sweep", None)))
        repl = NamedSharding(mesh, P())
        train_data = jax.tree.map(lambda x: jax.device_put(x, repl),
                                  train_data)
        val_data = jax.tree.map(lambda x: jax.device_put(x, repl),
                                val_data)

    swept = _sweep_program(prep.skel, metric_fn, m, n, team_frac,
                           device_frac, prep.sys_key, trace,
                           dispatch_key(), cohort)
    n_chunks, rem = divmod(rounds, eval_every)

    metric_hist = {}           # field -> list of (S, n_steps) arrays
    outs_hist = []             # list of per-segment output dicts
    dispatches = 0
    t0 = time.time()
    t_first = None
    for length, n_steps in ((eval_every, n_chunks), (rem, 1)):
        if length == 0 or n_steps == 0:
            continue
        first = t_first is None
        with span("compile" if first else "dispatch",
                  configs=len(prep.configs), chunks=n_steps):
            (states, keys), (metrics, outs) = swept(
                hstack, states, keys, sstack, train_data, val_data,
                length=length, n_steps=n_steps)
            if first:
                jax.block_until_ready(states)
                t_first = time.time()
        dispatches += 1
        for k, v in metrics.items():
            metric_hist.setdefault(k, []).append(np.asarray(v))
        outs_hist.append({k: np.asarray(v) for k, v in outs.items()})
    t_end = time.time()
    t_first = t_first if t_first is not None else t_end

    with span("collect", configs=len(prep.configs)):
        out = _collect(prep, states, metric_hist, outs_hist,
                       seconds=t_end - t0, compile_seconds=t_first - t0,
                       run_seconds=t_end - t_first, dispatches=dispatches,
                       rounds=rounds, eval_every=eval_every, trace=trace,
                       cohort=cohort,
                       population=n if cohort is not None else None)
    if trace_dir is not None:
        out.events_path = str(write_sweep(
            trace_dir, out, algo=algo,
            meta={"m": m, "n": n, "team_frac": team_frac,
                  "device_frac": device_frac, **(event_meta or {})}))
    return out


# Fused multi-sweep programs are cached per tuple of member static keys:
# each member's chunk program is inlined into one jitted body, so N
# structurally-different sweeps (e.g. different compressors) still cost
# one dispatch per segment.
@functools.lru_cache(maxsize=32)
def _multi_program(member_keys, metric_fn, m, n, kdispatch=None):
    runners = [_chunk_runner(skel, metric_fn, m, n, tf, df, sys_key,
                             trace, cohort)
               for skel, sys_key, tf, df, trace, cohort in member_keys]

    @functools.partial(jax.jit, static_argnames=("length", "n_steps"))
    def multi(ops, tr, va, *, length, n_steps):
        outs = []
        for run_chunks, (h, st, k, sl) in zip(runners, ops):
            if sl is None:
                outs.append(jax.vmap(lambda h_, s_, k_, rc=run_chunks: rc(
                    h_, s_, k_, tr, va, length=length,
                    n_steps=n_steps))(h, st, k))
            else:
                outs.append(jax.vmap(
                    lambda h_, s_, k_, sl_, rc=run_chunks: rc(
                        h_, s_, k_, tr, va, sleaves=sl_, length=length,
                        n_steps=n_steps))(h, st, k, sl))
        return tuple(outs)

    return multi


def run_multi_sweep(variants, train_data, val_data, *,
                    metric_fn: Callable, rounds: int, m: int, n: int,
                    eval_every: int = 1) -> list:
    """Run several *structurally different* sweeps as ONE jitted program.

    ``run_sweep`` batches everything that differs only in float values
    (hyperparameters, seeds, system profiles) on one vmap axis; what it
    cannot batch is a change to the round graph itself — a different
    compressor, a different algorithm. This entry point takes a list of
    such sweeps, inlines each one's vmapped chunk program into a single
    jitted body, and dispatches them together: N compressors x P system
    profiles in one call (``benchmarks/fig_time_to_accuracy.py``).

    variants: sequence of dicts, each with keys ``algo`` and ``params0``
        plus optional ``grid`` (default ``[{}]``), ``seeds`` (default
        ``(0,)``), ``team_frac`` / ``device_frac`` (default 1.0),
        ``system``, ``trace``, and ``cohort`` (as in ``run_sweep`` —
        per-variant, so probed and probe-free — or virtualized and
        stacked — members can share the program). Data, metric_fn,
        rounds, and dims are shared — variants are views of one
        experiment family.

    Returns one FLSweepResult per variant, in order; every result
    reports the same ``dispatches`` count (1, or 2 with a remainder
    chunk) because the members executed together.
    """
    preps = []
    traces = []
    cohorts = []
    for v in variants:
        v = dict(v)
        preps.append(_prepare(
            v["algo"], v.get("grid", [{}]), v.get("seeds", (0,)),
            v["params0"], m, n, v.get("team_frac", 1.0),
            v.get("device_frac", 1.0), v.get("system")))
        t = v.get("trace")
        traces.append(TraceConfig() if t is True else t)
        c = v.get("cohort")
        cohorts.append(None if c is None else int(c))

    member_keys = tuple(
        (p.skel, p.sys_key, p.team_frac, p.device_frac, t, c)
        for p, t, c in zip(preps, traces, cohorts))
    multi = _multi_program(member_keys, metric_fn, m, n, dispatch_key())
    ops = tuple((p.hstack, p.states, p.keys, p.sstack) for p in preps)
    n_chunks, rem = divmod(rounds, eval_every)

    metric_hist = [{} for _ in preps]
    outs_hist = [[] for _ in preps]
    carries = None
    dispatches = 0
    t0 = time.time()
    t_first = None
    for length, n_steps in ((eval_every, n_chunks), (rem, 1)):
        if length == 0 or n_steps == 0:
            continue
        results = multi(ops, train_data, val_data, length=length,
                        n_steps=n_steps)
        if t_first is None:
            jax.block_until_ready(results)
            t_first = time.time()
        dispatches += 1
        carries = [carry for carry, _ in results]
        ops = tuple((h, st, k, sl) for (h, _, _, sl), (st, k) in
                    zip(ops, carries))
        for i, (_, (metrics, outs)) in enumerate(results):
            for k, v in metrics.items():
                metric_hist[i].setdefault(k, []).append(np.asarray(v))
            outs_hist[i].append({k: np.asarray(v)
                                 for k, v in outs.items()})
    t_end = time.time()
    t_first = t_first if t_first is not None else t_end

    n_total = sum(len(p.configs) for p in preps) or 1
    out = []
    for i, p in enumerate(preps):
        share = len(p.configs) / n_total
        out.append(_collect(
            p, carries[i][0] if carries else p.states, metric_hist[i],
            outs_hist[i], seconds=(t_end - t0) * share,
            compile_seconds=(t_first - t0) * share,
            run_seconds=(t_end - t_first) * share, dispatches=dispatches,
            rounds=rounds, eval_every=eval_every, trace=traces[i],
            cohort=cohorts[i],
            population=n if cohorts[i] is not None else None))
    return out
