"""Pytree checkpointing: msgpack index + raw npy payloads in a zip.

No orbax in this environment; this is a self-contained format:
np.savez with flattened key paths, plus a msgpack manifest carrying tree
structure and metadata (step, config name).
"""
from __future__ import annotations

import io
import json
import os
import zipfile

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, tree, *, metadata: dict | None = None):
    flat = _flatten_with_paths(tree)
    treedef = jax.tree.structure(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with zipfile.ZipFile(path, "w", zipfile.ZIP_STORED) as zf:
        manifest = {
            "keys": list(flat.keys()),
            "treedef": str(treedef),
            "metadata": metadata or {},
        }
        zf.writestr("manifest.json", json.dumps(manifest))
        for k, v in flat.items():
            buf = io.BytesIO()
            np.save(buf, v)
            zf.writestr(f"arrays/{k.replace('/', '__')}.npy", buf.getvalue())


def restore_checkpoint(path: str, like_tree):
    """Restores into the structure of `like_tree` (leaf order match)."""
    with zipfile.ZipFile(path, "r") as zf:
        manifest = json.loads(zf.read("manifest.json"))
        arrays = {}
        for k in manifest["keys"]:
            buf = io.BytesIO(zf.read(f"arrays/{k.replace('/', '__')}.npy"))
            arrays[k] = np.load(buf)
    ref = _flatten_with_paths(like_tree)
    assert set(ref.keys()) == set(arrays.keys()), \
        f"checkpoint/tree key mismatch: {set(ref) ^ set(arrays)}"
    leaves, treedef = jax.tree.flatten(like_tree)
    paths = [
        "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pth)
        for pth, _ in jax.tree_util.tree_flatten_with_path(like_tree)[0]]
    new_leaves = [arrays[p] for p in paths]
    return jax.tree.unflatten(treedef, new_leaves), \
        json.loads(json.dumps(manifest["metadata"]))
