"""Pytree checkpointing: JSON manifest + raw npy payloads in a zip.

No orbax in this environment; this is a self-contained format:
one ``.npy`` payload per leaf, named by the leaf's flattened key path,
plus a JSON manifest carrying the key list, the tree structure string,
and caller metadata (step, config name, store layout ...).

``restore_checkpoint`` matches payloads to the template tree *by key
path* — a checkpoint whose key set differs from the template's raises a
``CheckpointKeyError`` naming the missing and extra paths instead of
silently zipping leaves together by position. ``load_checkpoint_arrays``
reads a checkpoint without any template (flat ``{key path: array}``) —
what the serving `ModelStore` reloads its manifest through
(DESIGN.md §12).
"""
from __future__ import annotations

import io
import json
import os
import zipfile

import jax
import numpy as np

__all__ = ["CheckpointKeyError", "load_checkpoint_arrays",
           "restore_checkpoint", "save_checkpoint"]


class CheckpointKeyError(KeyError):
    """A checkpoint's key paths do not match the restore template's."""


def _flatten_with_paths(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, tree, *, metadata: dict | None = None):
    """Write `tree` to `path`: one npy member per leaf (key-path named)
    plus a JSON manifest with the key list and `metadata`."""
    flat = _flatten_with_paths(tree)
    treedef = jax.tree.structure(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with zipfile.ZipFile(path, "w", zipfile.ZIP_STORED) as zf:
        manifest = {
            "keys": list(flat.keys()),
            # npy round-trips extension dtypes (bfloat16 & co.) as raw
            # void records; the manifest keeps the real name so load can
            # reinterpret the bytes
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "treedef": str(treedef),
            "metadata": metadata or {},
        }
        zf.writestr("manifest.json", json.dumps(manifest))
        for k, v in flat.items():
            buf = io.BytesIO()
            np.save(buf, v)
            zf.writestr(f"arrays/{k.replace('/', '__')}.npy", buf.getvalue())


def load_checkpoint_arrays(path: str):
    """Read a checkpoint with no template: returns
    ``({key path: np.ndarray}, metadata dict)`` straight from the
    manifest — the caller owns re-assembling a structure (the serving
    ModelStore rebuilds its nested-dict layout from the key paths)."""
    with zipfile.ZipFile(path, "r") as zf:
        manifest = json.loads(zf.read("manifest.json"))
        dtypes = manifest.get("dtypes", {})
        arrays = {}
        for k in manifest["keys"]:
            buf = io.BytesIO(zf.read(f"arrays/{k.replace('/', '__')}.npy"))
            arr = np.load(buf)
            want = dtypes.get(k)
            if want and str(arr.dtype) != want:
                arr = arr.view(jax.numpy.dtype(want))
            arrays[k] = arr
    return arrays, json.loads(json.dumps(manifest["metadata"]))


def restore_checkpoint(path: str, like_tree):
    """Restore into the structure of `like_tree`, matching every payload
    to its leaf by flattened key path (manifest order and template leaf
    order are irrelevant). Returns ``(tree, metadata)``.

    Raises :class:`CheckpointKeyError` listing the offending paths when
    the checkpoint is missing template keys or carries extra ones — a
    renamed layer or a layout drift fails loudly instead of restoring
    arrays into the wrong slots.
    """
    arrays, metadata = load_checkpoint_arrays(path)
    paths = [
        "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                 for p in pth)
        for pth, _ in jax.tree_util.tree_flatten_with_path(like_tree)[0]]
    missing = sorted(set(paths) - set(arrays))
    extra = sorted(set(arrays) - set(paths))
    if missing or extra:
        raise CheckpointKeyError(
            f"checkpoint {path!r} does not match the template tree: "
            f"missing from checkpoint {missing or '[]'}; "
            f"extra in checkpoint {extra or '[]'}")
    treedef = jax.tree.structure(like_tree)
    return jax.tree.unflatten(treedef, [arrays[p] for p in paths]), metadata
