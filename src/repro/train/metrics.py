"""Small metric utilities shared by trainers and benchmarks."""
from __future__ import annotations

import jax.numpy as jnp


def token_accuracy(logits, targets):
    mask = (targets >= 0)
    pred = jnp.argmax(logits, -1)
    return ((pred == targets) & mask).sum() / jnp.maximum(mask.sum(), 1)


def perplexity(loss):
    return jnp.exp(loss)


class RunningMean:
    def __init__(self):
        self.total = 0.0
        self.count = 0

    def update(self, value, n: int = 1):
        self.total += float(value) * n
        self.count += n

    @property
    def mean(self):
        return self.total / max(self.count, 1)
