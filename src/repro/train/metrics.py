"""Small metric utilities shared by trainers and benchmarks."""
from __future__ import annotations

import operator

import jax.numpy as jnp

__all__ = ["RunningMean", "perplexity", "token_accuracy"]


def token_accuracy(logits, targets):
    """Fraction of non-padding tokens (targets >= 0) predicted exactly;
    0 when every position is padding."""
    mask = (targets >= 0)
    pred = jnp.argmax(logits, -1)
    return ((pred == targets) & mask).sum() / jnp.maximum(mask.sum(), 1)


def perplexity(loss):
    """exp(mean cross-entropy) — the LM eval number."""
    return jnp.exp(loss)


class RunningMean:
    """Weighted streaming mean of host-side scalars.

    ``update(value, n)`` folds in a batch mean over ``n`` samples; the
    weight must be a positive integer — zero or negative counts would
    silently skew (or poison) the aggregate, so they raise instead.
    """

    def __init__(self):
        self.total = 0.0
        self.count = 0

    def update(self, value, n: int = 1):
        """Fold in `value` with integer weight ``n >= 1``."""
        n = operator.index(n)
        if n <= 0:
            raise ValueError(f"RunningMean.update needs n >= 1, got {n}")
        self.total += float(value) * n
        self.count += n

    def reset(self):
        """Forget everything; the instance is reusable across epochs."""
        self.total = 0.0
        self.count = 0

    @property
    def mean(self):
        """Current weighted mean; 0.0 before any update."""
        return self.total / max(self.count, 1)
