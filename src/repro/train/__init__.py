from repro.train import (checkpoint, engine, fl_trainer, metrics, optim,
                         sweep, trainer)
from repro.train.engine import FLResult, run_experiment
from repro.train.optim import adamw, momentum, sgd
from repro.train.sweep import FLSweepResult, grid_product, run_sweep
from repro.train.train_state import TrainState

__all__ = ["checkpoint", "engine", "fl_trainer", "metrics", "optim",
           "sweep", "trainer", "FLResult", "run_experiment",
           "FLSweepResult", "grid_product", "run_sweep", "adamw",
           "momentum", "sgd", "TrainState"]
