from repro.train import checkpoint, engine, fl_trainer, metrics, optim, trainer
from repro.train.engine import FLResult, run_experiment
from repro.train.optim import adamw, momentum, sgd
from repro.train.train_state import TrainState

__all__ = ["checkpoint", "engine", "fl_trainer", "metrics", "optim",
           "trainer", "FLResult", "run_experiment", "adamw", "momentum",
           "sgd", "TrainState"]
