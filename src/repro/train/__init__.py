from repro.train import checkpoint, fl_trainer, metrics, optim, trainer
from repro.train.optim import adamw, momentum, sgd
from repro.train.train_state import TrainState

__all__ = ["checkpoint", "fl_trainer", "metrics", "optim", "trainer",
           "adamw", "momentum", "sgd", "TrainState"]
