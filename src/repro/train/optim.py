"""Optimizers on pytrees (no optax in this environment).

sgd / momentum / adamw, each as (init(params) -> opt_state,
update(grads, opt_state, params, lr) -> (updates, opt_state)). Updates are
*subtracted* by the caller (TrainState.apply_gradients).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., Any]
    name: str = "opt"


def sgd() -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, lr):
        return jax.tree.map(lambda g: lr * g, grads), state

    return Optimizer(init, update, "sgd")


def momentum(mu: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, buf, params, lr):
        buf = jax.tree.map(lambda b, g: mu * b + g.astype(jnp.float32),
                           buf, grads)
        if nesterov:
            upd = jax.tree.map(lambda b, g: lr * (mu * b + g), buf, grads)
        else:
            upd = jax.tree.map(lambda b: lr * b, buf)
        return upd, buf

    return Optimizer(init, update, "momentum")


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) *
                         g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) *
                         jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(mm, vv, p):
            mhat = mm / bc1
            vhat = vv / bc2
            u = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (lr * u).astype(p.dtype)

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"m": m, "v": v, "t": t}

    return Optimizer(init, update, "adamw")


def global_norm(tree):
    return jnp.sqrt(sum(jnp.vdot(x, x).real
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm
