"""FL trainer: drives PerMFL (and the baselines) over stacked federated
data — the paper-faithful experiment loop behind benchmarks/ and examples/.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import CommConfig, CommLedger
from repro.core import (PerMFLHParams, eval_stacked, init_state,
                        permfl_round)
from repro.core import baselines as B
from repro.core.participation import sample_masks


@dataclass
class FLResult:
    pm_acc: list = field(default_factory=list)   # per-round personalized acc
    tm_acc: list = field(default_factory=list)
    gm_acc: list = field(default_factory=list)
    train_loss: list = field(default_factory=list)
    seconds: float = 0.0
    state: Any = None    # final state (set by run_permfl / run_fedavg)
    comm: Optional[CommLedger] = None    # per-tier byte ledger (PerMFL+comm)

    def last(self, which="pm"):
        hist = {"pm": self.pm_acc, "tm": self.tm_acc, "gm": self.gm_acc}[which]
        return hist[-1] if hist else float("nan")

    def best(self, which="pm"):
        hist = {"pm": self.pm_acc, "tm": self.tm_acc, "gm": self.gm_acc}[which]
        return max(hist) if hist else float("nan")


def run_permfl(params0, train_data, val_data, *, loss_fn, metric_fn,
               hp: PerMFLHParams, rounds: int, m: int, n: int,
               team_frac: float = 1.0, device_frac: float = 1.0,
               seed: int = 0, eval_every: int = 1,
               comm: Optional[CommConfig] = None) -> FLResult:
    state = init_state(params0, m, n, comm=comm)
    key = jax.random.PRNGKey(seed)
    res = FLResult()
    if comm is not None:
        res.comm = CommLedger.for_params(comm, params0)
    t0 = time.time()
    for t in range(rounds):
        if team_frac < 1.0 or device_frac < 1.0:
            key, sub = jax.random.split(key)
            tm, dm = sample_masks(sub, m, n, team_frac=team_frac,
                                  device_frac=device_frac)
        else:
            tm = dm = None
        state = permfl_round(state, train_data, hp, loss_fn,
                             m_teams=m, n_devices=n,
                             team_mask=tm, device_mask=dm, comm=comm)
        if res.comm is not None:
            res.comm.log_round(
                k_team=hp.k_team,
                n_teams=m if tm is None else int(tm.sum()),
                n_devices=m * n if dm is None else int(dm.sum()))
        if t % eval_every == 0 or t == rounds - 1:
            res.pm_acc.append(float(
                eval_stacked(state, val_data, metric_fn, which="pm").mean()))
            res.tm_acc.append(float(
                eval_stacked(state, val_data, metric_fn, which="tm").mean()))
            res.gm_acc.append(float(
                eval_stacked(state, val_data, metric_fn, which="gm").mean()))
            res.train_loss.append(float(jax.vmap(jax.vmap(loss_fn))(
                state.theta, train_data).mean()))
    res.seconds = time.time() - t0
    res.state = state
    return res


def _eval_global(x, val_data, metric_fn):
    return float(jax.vmap(jax.vmap(lambda d: metric_fn(x, d)))
                 (val_data).mean())


def _eval_stackedq(theta, val_data, metric_fn):
    return float(jax.vmap(jax.vmap(metric_fn))(theta, val_data).mean())


def run_fedavg(params0, train_data, val_data, *, loss_fn, metric_fn,
               lr: float, local_steps: int, rounds: int, m: int,
               n: int, eval_every: int = 1) -> FLResult:
    x = params0
    res = FLResult()
    t0 = time.time()
    for t in range(rounds):
        x = B.fedavg_round(x, train_data, loss_fn=loss_fn, lr=lr,
                           local_steps=local_steps, m=m, n=n)
        if t % eval_every == 0 or t == rounds - 1:
            res.gm_acc.append(_eval_global(x, val_data, metric_fn))
    res.seconds = time.time() - t0
    res.state = x
    return res


def run_perfedavg(params0, train_data, val_data, *, loss_fn, metric_fn,
                  lr: float, inner_lr: float, local_steps: int, rounds: int,
                  m: int, n: int, eval_every: int = 1) -> FLResult:
    x = params0
    res = FLResult()
    t0 = time.time()
    for t in range(rounds):
        x = B.perfedavg_round(x, train_data, loss_fn=loss_fn, lr=lr,
                              inner_lr=inner_lr, local_steps=local_steps,
                              m=m, n=n)
        if t % eval_every == 0 or t == rounds - 1:
            theta = B.perfedavg_personalize(x, train_data, loss_fn=loss_fn,
                                            inner_lr=inner_lr, m=m, n=n)
            res.pm_acc.append(_eval_stackedq(theta, val_data, metric_fn))
            res.gm_acc.append(_eval_global(x, val_data, metric_fn))
    res.seconds = time.time() - t0
    return res


def run_pfedme(params0, train_data, val_data, *, loss_fn, metric_fn,
               lr: float, inner_lr: float, lam: float, inner_steps: int,
               local_rounds: int, rounds: int, m: int, n: int,
               eval_every: int = 1) -> FLResult:
    x = params0
    res = FLResult()
    t0 = time.time()
    for t in range(rounds):
        x, theta = B.pfedme_round(
            x, train_data, loss_fn=loss_fn, lr=lr, inner_lr=inner_lr,
            lam=lam, inner_steps=inner_steps, local_rounds=local_rounds,
            m=m, n=n)
        if t % eval_every == 0 or t == rounds - 1:
            res.pm_acc.append(_eval_stackedq(theta, val_data, metric_fn))
            res.gm_acc.append(_eval_global(x, val_data, metric_fn))
    res.seconds = time.time() - t0
    return res


def run_ditto(params0, train_data, val_data, *, loss_fn, metric_fn,
              lr: float, lam: float, local_steps: int, rounds: int,
              m: int, n: int, eval_every: int = 1) -> FLResult:
    x = params0
    v = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None, None], (m, n) + p.shape).copy(),
        params0)
    res = FLResult()
    t0 = time.time()
    for t in range(rounds):
        x, v = B.ditto_round(x, v, train_data, loss_fn=loss_fn, lr=lr,
                             lam=lam, local_steps=local_steps, m=m, n=n)
        if t % eval_every == 0 or t == rounds - 1:
            res.pm_acc.append(_eval_stackedq(v, val_data, metric_fn))
            res.gm_acc.append(_eval_global(x, val_data, metric_fn))
    res.seconds = time.time() - t0
    return res


def run_hsgd(params0, train_data, val_data, *, loss_fn, metric_fn,
             lr: float, k_team: int, l_local: int, rounds: int,
             m: int, n: int, eval_every: int = 1) -> FLResult:
    x = params0
    res = FLResult()
    t0 = time.time()
    for t in range(rounds):
        x = B.hsgd_round(x, train_data, loss_fn=loss_fn, lr=lr,
                         k_team=k_team, l_local=l_local, m=m, n=n)
        if t % eval_every == 0 or t == rounds - 1:
            res.gm_acc.append(_eval_global(x, val_data, metric_fn))
    res.seconds = time.time() - t0
    return res


def run_l2gd(params0, train_data, val_data, *, loss_fn, metric_fn,
             lr: float, lam_c: float, lam_g: float, k_team: int,
             l_local: int, rounds: int, m: int, n: int,
             eval_every: int = 1) -> FLResult:
    x = params0
    theta = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None, None], (m, n) + p.shape).copy(),
        params0)
    res = FLResult()
    t0 = time.time()
    for t in range(rounds):
        x, theta = B.l2gd_round(x, theta, train_data, loss_fn=loss_fn,
                                lr=lr, lam_c=lam_c, lam_g=lam_g,
                                k_team=k_team, l_local=l_local, m=m, n=n)
        if t % eval_every == 0 or t == rounds - 1:
            res.pm_acc.append(_eval_stackedq(theta, val_data, metric_fn))
            res.gm_acc.append(_eval_global(x, val_data, metric_fn))
    res.seconds = time.time() - t0
    return res


ALGORITHMS = {
    "permfl": run_permfl,
    "fedavg": run_fedavg,
    "perfedavg": run_perfedavg,
    "pfedme": run_pfedme,
    "ditto": run_ditto,
    "hsgd": run_hsgd,
    "l2gd": run_l2gd,
}
