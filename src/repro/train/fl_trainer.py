"""FL trainer — thin compatibility shims over the scanned engine.

The seven ``run_<algo>`` entry points keep their historical signatures
(benchmarks/, examples/, and tests call them), but each now just builds
the matching `FLAlgorithm` instance (core.algorithm / core.baselines) and
hands it to `repro.train.engine.run_experiment`, which compiles the whole
experiment — rounds, in-graph participation sampling, and eval — into a
single program instead of dispatching one jitted round at a time.

Every runner sets ``FLResult.state`` to the algorithm's final state
(historically only run_permfl/run_fedavg did):

    permfl    -> PerMFLState
    fedavg    -> x                       (global model pytree)
    perfedavg -> x
    pfedme    -> (x, theta)              (global, personalized)
    ditto     -> (x, v)
    hsgd      -> x
    l2gd      -> (x, theta)

Eval cadence: metrics are recorded every ``eval_every`` rounds counting
from the first (i.e. after rounds eval_every, 2*eval_every, ...) and
always after the final round; with the default eval_every=1 this is
identical to the legacy per-round loop.
"""
from __future__ import annotations

from typing import Optional

from repro.comm import CommConfig
from repro.core import PerMFL, PerMFLHParams
from repro.core import baselines as B
from repro.train.engine import FLResult, run_experiment

__all__ = ["FLResult", "ALGORITHMS", "run_permfl", "run_fedavg",
           "run_perfedavg", "run_pfedme", "run_ditto", "run_hsgd",
           "run_l2gd"]


def run_permfl(params0, train_data, val_data, *, loss_fn, metric_fn,
               hp: PerMFLHParams, rounds: int, m: int, n: int,
               team_frac: float = 1.0, device_frac: float = 1.0,
               seed: int = 0, eval_every: int = 1,
               comm: Optional[CommConfig] = None,
               scan: bool = True) -> FLResult:
    """PerMFL (Algorithm 1); optional comm compresses uplinks and fills FLResult.comm."""
    return run_experiment(
        PerMFL(loss_fn, hp, comm=comm), params0, train_data, val_data,
        metric_fn=metric_fn, rounds=rounds, m=m, n=n, team_frac=team_frac,
        device_frac=device_frac, seed=seed, eval_every=eval_every, scan=scan)


def run_fedavg(params0, train_data, val_data, *, loss_fn, metric_fn,
               lr: float, local_steps: int, rounds: int, m: int,
               n: int, eval_every: int = 1, scan: bool = True) -> FLResult:
    """FedAvg: local SGD + global averaging; metrics report GM only."""
    return run_experiment(
        B.FedAvg(loss_fn, lr=lr, local_steps=local_steps),
        params0, train_data, val_data, metric_fn=metric_fn, rounds=rounds,
        m=m, n=n, eval_every=eval_every, scan=scan)


def run_perfedavg(params0, train_data, val_data, *, loss_fn, metric_fn,
                  lr: float, inner_lr: float, local_steps: int, rounds: int,
                  m: int, n: int, eval_every: int = 1,
                  scan: bool = True) -> FLResult:
    """Per-FedAvg (first-order MAML); PM is one adaptation step from GM."""
    return run_experiment(
        B.PerFedAvg(loss_fn, lr=lr, inner_lr=inner_lr,
                    local_steps=local_steps),
        params0, train_data, val_data, metric_fn=metric_fn, rounds=rounds,
        m=m, n=n, eval_every=eval_every, scan=scan)


def run_pfedme(params0, train_data, val_data, *, loss_fn, metric_fn,
               lr: float, inner_lr: float, lam: float, inner_steps: int,
               local_rounds: int, rounds: int, m: int, n: int,
               eval_every: int = 1, scan: bool = True) -> FLResult:
    """pFedMe: Moreau-envelope personalization, single tier."""
    return run_experiment(
        B.PFedMe(loss_fn, lr=lr, inner_lr=inner_lr, lam=lam,
                 inner_steps=inner_steps, local_rounds=local_rounds),
        params0, train_data, val_data, metric_fn=metric_fn, rounds=rounds,
        m=m, n=n, eval_every=eval_every, scan=scan)


def run_ditto(params0, train_data, val_data, *, loss_fn, metric_fn,
              lr: float, lam: float, local_steps: int, rounds: int,
              m: int, n: int, eval_every: int = 1,
              scan: bool = True) -> FLResult:
    """Ditto: FedAvg GM + per-device prox-regularized PM."""
    return run_experiment(
        B.Ditto(loss_fn, lr=lr, lam=lam, local_steps=local_steps),
        params0, train_data, val_data, metric_fn=metric_fn, rounds=rounds,
        m=m, n=n, eval_every=eval_every, scan=scan)


def run_hsgd(params0, train_data, val_data, *, loss_fn, metric_fn,
             lr: float, k_team: int, l_local: int, rounds: int,
             m: int, n: int, eval_every: int = 1,
             scan: bool = True) -> FLResult:
    """h-SGD: hierarchical local SGD (team avg every L, global every K*L)."""
    return run_experiment(
        B.HSGD(loss_fn, lr=lr, k_team=k_team, l_local=l_local),
        params0, train_data, val_data, metric_fn=metric_fn, rounds=rounds,
        m=m, n=n, eval_every=eval_every, scan=scan)


def run_l2gd(params0, train_data, val_data, *, loss_fn, metric_fn,
             lr: float, lam_c: float, lam_g: float, k_team: int,
             l_local: int, rounds: int, m: int, n: int,
             eval_every: int = 1, scan: bool = True) -> FLResult:
    """L2GD (synchronous variant): global/cluster/personal mixture."""
    return run_experiment(
        B.L2GD(loss_fn, lr=lr, lam_c=lam_c, lam_g=lam_g, k_team=k_team,
               l_local=l_local),
        params0, train_data, val_data, metric_fn=metric_fn, rounds=rounds,
        m=m, n=n, eval_every=eval_every, scan=scan)


ALGORITHMS = {
    "permfl": run_permfl,
    "fedavg": run_fedavg,
    "perfedavg": run_perfedavg,
    "pfedme": run_pfedme,
    "ditto": run_ditto,
    "hsgd": run_hsgd,
    "l2gd": run_l2gd,
}
