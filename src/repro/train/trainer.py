"""Central trainer: standard (sharded) LM training of any zoo architecture.

This is the substrate the tiered PerMFL trainer builds on; it is also the
paper's implicit baseline (1) — plain ERM with a single decision variable.
``make_train_step`` returns the jittable step used both for real CPU/TPU
training (examples/) and for the multi-pod dry-run lowering (launch/).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import model as model_lib
from repro.train.optim import Optimizer, clip_by_global_norm
from repro.train.train_state import TrainState


def make_train_step(cfg, opt: Optimizer, *, lr: float = 3e-4,
                    grad_clip: float = 1.0, remat: bool = False):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def train_step(state: TrainState, batch):
        def loss(params):
            return model_lib.loss_fn(params, cfg, batch, remat=remat)

        loss_val, grads = jax.value_and_grad(loss)(state.params)
        if grad_clip:
            grads, gnorm = clip_by_global_norm(grads, grad_clip)
        else:
            gnorm = jnp.zeros(())
        state = state.apply_gradients(grads, opt, lr)
        return state, {"loss": loss_val, "grad_norm": gnorm}

    return train_step


def make_permfl_device_step(cfg, *, alpha: float, lam: float,
                            remat: bool = False):
    """PerMFL device step at LLM scale (tier mode, DESIGN.md §2): one
    prox-SGD step of theta toward the team anchor w (eq. 4), as the jittable
    unit the launcher lowers for the dry-run.

    step(theta, w, batch) -> (theta', metrics). theta/w: model params
    pytrees (w is the team model, replicated within a team's mesh slice).
    """
    from repro.kernels.prox_update import prox_sgd_tree

    def device_step(theta, w, batch):
        def loss(params):
            return model_lib.loss_fn(params, cfg, batch, remat=remat)

        loss_val, grads = jax.value_and_grad(loss)(theta)
        theta, _ = prox_sgd_tree(theta, grads, w, alpha=alpha, lam=lam)
        return theta, {"loss": loss_val}

    return device_step


def make_tier_round(cfg, *, alpha: float, lam: float, gamma: float,
                    eta: float, beta: float, l_local: int,
                    data_axis: str = "data", pod_axis: Optional[str] = "pod",
                    remat: bool = False):
    """Tiered PerMFL round at LLM scale for the multi-pod mesh.

    Mapping (DESIGN.md §2): each pod is a team — devices are the
    data-parallel replicas inside the pod (ICI collectives); the global
    server averaging runs over the `pod` axis (DCN collective), once per
    round instead of once per step — the paper's communication saving.

    step(theta, w, x, batch) -> (theta', w', x', metrics), designed to be
    jitted with in/out shardings where theta/w/x are identically sharded
    over the `model` axis and batch is sharded over (pod, data).

    Per-replica gradients are implicitly averaged over (pod, data) by jit
    (batch is sharded, loss is a mean); the *tier structure* is expressed
    through which model gets pulled toward which anchor and how often.
    """
    from repro.kernels.prox_update import prox_sgd_tree

    def round_fn(theta, w, x, batch):
        loss_val = jnp.zeros(())

        def one_local(i, carry):
            theta, loss_acc = carry

            def loss(params):
                return model_lib.loss_fn(params, cfg, batch, remat=remat)

            lv, grads = jax.value_and_grad(loss)(theta)
            theta, _ = prox_sgd_tree(theta, grads, w, alpha=alpha, lam=lam)
            return theta, loss_acc + lv

        theta, loss_val = jax.lax.fori_loop(0, l_local, one_local,
                                            (theta, loss_val))
        # team update (eq. 9): theta-bar == theta here (one replica's view;
        # cross-replica averaging of theta is the psum jit inserts when the
        # outputs are requested replicated).
        c = 1.0 - eta * lam - eta * gamma
        w = jax.tree.map(lambda wl, xl, tb: c * wl + eta * gamma * xl
                         + lam * eta * tb, w, x, theta)
        # server update (eq. 13) over pods
        x = jax.tree.map(lambda xl, wl: (1 - beta * gamma) * xl
                         + beta * gamma * wl, x, w)
        return theta, w, x, {"loss": loss_val / l_local}

    return round_fn


def train_loop(cfg, batches, *, opt: Optimizer, lr: float = 3e-4,
               steps: int = 100, seed: int = 0, log_every: int = 10,
               param_dtype=jnp.float32, callback=None):
    """Simple single-host loop used by examples and integration tests."""
    params = model_lib.init_params(jax.random.PRNGKey(seed), cfg,
                                   dtype=param_dtype)
    state = TrainState.create(params, opt)
    step_fn = jax.jit(make_train_step(cfg, opt, lr=lr))
    history = []
    for i, batch in zip(range(steps), batches):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = step_fn(state, batch)
        if i % log_every == 0 or i == steps - 1:
            loss = float(metrics["loss"])
            history.append((i, loss))
            if callback:
                callback(i, loss)
    return state, history
