"""Virtualized device-state store for cohort-sampled training
(DESIGN.md §11).

The stacked engine materializes every device's personal state as
(M, N, ...) leaves each round, so memory — not compute — caps the
population. This module inverts that layout: the full population lives
in a :class:`DeviceStateStore` (stacked leaves keyed by (team, device),
shardable over the mesh `data` axis via
:func:`repro.sharding.specs.store_pspecs`), and each round the engine
gathers only the sampled cohort `(M, n_cohort)` in-graph, runs the
unchanged algorithm round at cohort width, and scatters the updated
rows back. Personal params, error-feedback ``CommState`` residuals and
probe state all ride the same gather, selected per-algorithm by
``FLAlgorithm.device_axes``.

Cohort sampling is without replacement and index maps are sorted
(:func:`repro.core.participation.sample_cohort`), so ``scatter ∘
gather`` is an exact round-trip: non-sampled rows are bit-unchanged and
sampled rows carry exactly the round's update — the property
tests/test_cohort_store.py pins. With ``cohort == n`` the index map is
``arange(n)`` and the whole machinery degenerates to an identity copy,
which is why the full-population path stays bit-identical.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Tuple

import jax

__all__ = ["DeviceStateStore", "gather_cohort", "scatter_cohort",
           "split_device_state"]


def gather_cohort(tree, idx):
    """Materialize the cohort rows of a device-tier pytree.

    tree: leaves stacked (M, N, ...); idx: (M, C) i32 per-team device
    indices. Returns the same structure with (M, C, ...) leaves —
    ``leaf[t, idx[t]]`` per team, as one in-graph vmapped take.
    """
    def take(leaf):
        return jax.vmap(lambda row, i: row[i])(leaf, idx)
    return jax.tree.map(take, tree)


def scatter_cohort(tree, idx, update):
    """Write cohort rows back into a device-tier pytree.

    Inverse of :func:`gather_cohort` for sampled rows: returns ``tree``
    with ``leaf[t, idx[t]] <- update_leaf[t]`` per team and every
    non-sampled row untouched. ``idx`` rows are distinct (sampling is
    without replacement), so the scatter is unambiguous.
    """
    def put(leaf, up):
        return jax.vmap(lambda row, i, u: row.at[i].set(u))(leaf, idx, up)
    return jax.tree.map(put, tree, update)


def split_device_state(algo, state, m: int, n: int
                       ) -> Tuple[tuple, tuple, Callable]:
    """Split an algorithm state into (device-tier leaves, resident rest).

    Flags come from ``algo.device_axes(state, m, n)``; ``n`` is the
    width of the device axis *in this state* — the population when
    splitting the resident store, the cohort size when splitting a
    post-round cohort state.

    Returns ``(dev, rest, merge)``: two leaf tuples and a closure
    reassembling the original structure, so the engine can carry the
    store and the resident tiers separately through the scan and
    rebuild full states at eval boundaries.
    """
    leaves, treedef = jax.tree.flatten(state)
    flags = jax.tree.leaves(algo.device_axes(state, m, n))
    if len(flags) != len(leaves):
        raise ValueError(
            f"device_axes returned {len(flags)} flags for "
            f"{len(leaves)} state leaves ({algo.name})")
    flags = tuple(bool(f) for f in flags)
    dev = tuple(l for l, f in zip(leaves, flags) if f)
    rest = tuple(l for l, f in zip(leaves, flags) if not f)

    def merge(dev_leaves, rest_leaves):
        """Reassemble a full state pytree from the two leaf tuples."""
        di, ri = iter(dev_leaves), iter(rest_leaves)
        return jax.tree.unflatten(
            treedef, [next(di) if f else next(ri) for f in flags])

    return dev, rest, merge


@jax.tree_util.register_pytree_node_class
@dataclass
class DeviceStateStore:
    """The resident population's device-tier state: a pytree of stacked
    (M, N, ...) leaves keyed by (team, device), carried through the
    engine's scan while only gathered cohorts are ever materialized at
    round width. ``m``/``n`` are static pytree aux data, so stores nest
    in scan carries and vmap over a sweep axis like any other state.
    """
    tree: Any
    m: int
    n: int

    def tree_flatten(self):
        """Pytree protocol: leaves are the store tree, (m, n) is aux."""
        return (self.tree,), (self.m, self.n)

    @classmethod
    def tree_unflatten(cls, aux, children):
        """Pytree protocol: rebuild from ((tree,), (m, n))."""
        return cls(children[0], *aux)

    def gather(self, idx):
        """Cohort view: :func:`gather_cohort` over the store tree."""
        return gather_cohort(self.tree, idx)

    def scatter(self, idx, update) -> "DeviceStateStore":
        """New store with cohort rows replaced by ``update``
        (:func:`scatter_cohort`); non-sampled rows bit-unchanged."""
        return DeviceStateStore(scatter_cohort(self.tree, idx, update),
                                self.m, self.n)

    def pspecs(self, *, sweep: bool = False):
        """PartitionSpecs sharding the population axis over the mesh
        `data` axis (:func:`repro.sharding.specs.store_pspecs`)."""
        from repro.sharding.specs import store_pspecs
        return store_pspecs(self.tree, m=self.m, population=self.n,
                            sweep=sweep)
