"""TrainState: params + optimizer state + step, as a registered pytree."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.train.optim import Optimizer


@jax.tree_util.register_pytree_node_class
@dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jnp.ndarray

    def tree_flatten(self):
        return (self.params, self.opt_state, self.step), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @classmethod
    def create(cls, params, opt: Optimizer):
        return cls(params=params, opt_state=opt.init(params),
                   step=jnp.zeros((), jnp.int32))

    def apply_gradients(self, grads, opt: Optimizer, lr):
        updates, new_opt = opt.update(grads, self.opt_state, self.params, lr)
        new_params = jax.tree.map(lambda p, u: (p - u).astype(p.dtype),
                                  self.params, updates)
        return TrainState(params=new_params, opt_state=new_opt,
                          step=self.step + 1)
