"""Scanned multi-round FL engine: one compiled program per experiment.

The legacy drivers dispatched one jitted round at a time from Python and
re-traced eval on every call; at paper scale (hundreds of rounds x seven
algorithms x hyperparameter sweeps) the experiments were bottlenecked on
host dispatch, not hardware. This engine runs any `FLAlgorithm`
(core.algorithm) as a *single* jitted program:

    jit( scan over eval chunks:
           scan over eval_every rounds:
             sample participation masks in-graph (PRNG key in the carry)
             state = algo.round(state, data, masks)
             emit realized (gated) participation counts   # scan outputs
           metrics = algo.eval(state, ...)                # traced, cached
         -> metric history + per-round counts )

Participation sampling lives in the graph (core.participation), threading
the PRNG key through the scan carry — the same split-per-round chain the
legacy loop used, so trajectories match bit-for-bit. Byte accounting
stays on the host: the per-round team/device counts come back as scan
outputs and feed `CommLedger` post-hoc, counting only devices whose team
also participated (device_mask * team_mask[:, None] — the legacy loop's
ungated `dm.sum()` overcounted).

``scan=False`` runs the same semantics as a per-round host-dispatch loop
(the legacy execution model) — kept for equivalence tests and for
benchmarks/bench_engine.py to quantify the dispatch win.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import CommLedger
from repro.core.participation import sample_masks

__all__ = ["FLResult", "run_experiment"]


@dataclass
class FLResult:
    """One experiment's outcome: metric histories (one entry per eval
    point), wall time, final algorithm state, optional per-tier byte
    ledger, and realized (team-gated) per-round participation counts."""
    pm_acc: list = field(default_factory=list)   # per-eval personalized acc
    tm_acc: list = field(default_factory=list)
    gm_acc: list = field(default_factory=list)
    train_loss: list = field(default_factory=list)
    seconds: float = 0.0
    state: Any = None    # final algorithm state (set for every algorithm)
    comm: Optional[CommLedger] = None    # per-tier byte ledger (comm runs)
    participation: list = field(default_factory=list)  # (teams, devices)/rnd

    def last(self, which="pm"):
        """Final-eval value of metric `which` ('pm'|'tm'|'gm'); NaN if the
        algorithm never reported it."""
        hist = {"pm": self.pm_acc, "tm": self.tm_acc, "gm": self.gm_acc}[which]
        return hist[-1] if hist else float("nan")

    def best(self, which="pm"):
        """Best eval value of metric `which` over the whole run."""
        hist = {"pm": self.pm_acc, "tm": self.tm_acc, "gm": self.gm_acc}[which]
        return max(hist) if hist else float("nan")


_METRIC_FIELDS = {"pm": "pm_acc", "tm": "tm_acc", "gm": "gm_acc",
                  "train_loss": "train_loss"}


def check_participation(algo, team_frac: float, device_frac: float):
    """Reject sampled participation for algorithms that ignore the masks —
    FLResult.participation must never report sampling that didn't gate
    anything. Shared by run_experiment and train.sweep.run_sweep."""
    if (team_frac < 1.0 or device_frac < 1.0) and \
            not getattr(algo, "supports_participation", False):
        raise ValueError(
            f"{getattr(algo, 'name', type(algo).__name__)} ignores "
            "participation masks; team_frac/device_frac < 1 would sample "
            "masks that never gate anything")


def _round_body(algo, m, n, team_frac, device_frac):
    """Scan step: in-graph mask sampling (key in the carry), one algorithm
    round, realized gated participation counts as outputs."""
    sampled = team_frac < 1.0 or device_frac < 1.0

    def body(carry, _, data):
        state, key = carry
        if sampled:
            key, sub = jax.random.split(key)
            tm, dm = sample_masks(sub, m, n, team_frac=team_frac,
                                  device_frac=device_frac)
        else:
            tm = jnp.ones((m,), jnp.float32)
            dm = jnp.ones((m, n), jnp.float32)
        state = algo.round(state, data, team_mask=tm, device_mask=dm)
        gated = dm * tm[:, None]
        counts = (jnp.sum(tm).astype(jnp.int32),
                  jnp.sum(gated).astype(jnp.int32))
        return (state, key), counts

    return body


def hparam_skeleton(algo):
    """A value-independent cache key + the split for one algorithm: the
    instance with every sweepable float zeroed (hashable, shared by all
    hyperparameter values) plus its (leaves, rebuild) pair. Compiled
    programs key on the skeleton and take the float leaves as traced
    operands, so rerunning with new values never recompiles."""
    leaves, rebuild = algo.tree_hparams()
    return rebuild({k: 0.0 for k in leaves}), leaves


def _chunk_runner(skel, metric_fn, m, n, team_frac, device_frac):
    """The traceable heart of an experiment — shared verbatim by the
    per-experiment program below and train.sweep's vmapped grid program:
    rebuild the algorithm from its hparam leaves, then scan `n_steps`
    chunks of `length` rounds with a traced eval after each chunk."""
    _, rebuild = skel.tree_hparams()

    def run_chunks(hleaves, state, key, tr, va, *, length, n_steps):
        algo = rebuild(hleaves)
        body = _round_body(algo, m, n, team_frac, device_frac)

        def chunk(carry, _):
            state, key = carry
            (state, key), counts = jax.lax.scan(
                lambda c, x: body(c, x, tr), (state, key), length=length)
            return (state, key), (algo.eval(state, tr, va, metric_fn),
                                  counts)

        return jax.lax.scan(chunk, (state, key), length=n_steps)

    return run_chunks


# Compiled programs are cached per (hparam skeleton, metric_fn, dims):
# every experiment with the same static structure — whatever its float
# hyperparameter values — shares one compile and pays one dispatch.
@functools.lru_cache(maxsize=128)
def _scan_program(skel, metric_fn, m, n, team_frac, device_frac):
    run_chunks = _chunk_runner(skel, metric_fn, m, n, team_frac,
                               device_frac)
    return functools.partial(jax.jit, static_argnames=(
        "length", "n_steps"))(run_chunks)


@functools.lru_cache(maxsize=128)
def _eval_program(skel, metric_fn):
    _, rebuild = skel.tree_hparams()
    return jax.jit(lambda hleaves, state, tr, va: rebuild(hleaves).eval(
        state, tr, va, metric_fn))


def run_experiment(algo, params0, train_data, val_data, *,
                   metric_fn: Callable, rounds: int, m: int, n: int,
                   team_frac: float = 1.0, device_frac: float = 1.0,
                   seed: int = 0, eval_every: int = 1,
                   scan: bool = True) -> FLResult:
    """Drive `algo` for `rounds` global rounds, evaluating every
    `eval_every` rounds (and after the final round). Returns an FLResult
    whose metric histories hold one entry per eval point.

    scan=True compiles the whole experiment into one program (chunked
    lax.scan); scan=False dispatches round-by-round from the host with
    identical semantics — same mask PRNG chain, same eval points.
    """
    check_participation(algo, team_frac, device_frac)
    state = algo.init_state(params0, m, n)
    key = jax.random.PRNGKey(seed)
    n_chunks, rem = divmod(rounds, eval_every)

    skel, hleaves = hparam_skeleton(algo)
    scanned = _scan_program(skel, metric_fn, m, n, team_frac, device_frac)
    round_body = _round_body(algo, m, n, team_frac, device_frac)
    eval_jit = _eval_program(skel, metric_fn)

    res = FLResult()
    ledger = algo.make_ledger(params0)
    t0 = time.time()

    def record(metrics_hist, counts_hist):
        """metrics_hist: dict of (chunks,) arrays; counts: (chunks, len)."""
        for k, v in metrics_hist.items():
            getattr(res, _METRIC_FIELDS[k]).extend(
                float(x) for x in np.asarray(v))
        tc, dc = counts_hist
        res.participation.extend(
            zip(np.asarray(tc).reshape(-1).tolist(),
                np.asarray(dc).reshape(-1).tolist()))

    if scan:
        for length, n_steps in ((eval_every, n_chunks), (rem, 1)):
            if length == 0 or n_steps == 0:
                continue
            (state, key), (metrics, counts) = scanned(
                hleaves, state, key, train_data, val_data, length=length,
                n_steps=n_steps)
            record(metrics, counts)
    else:
        for t in range(rounds):
            (state, key), counts = round_body((state, key), None,
                                              train_data)
            res.participation.append(
                (int(counts[0]), int(counts[1])))
            if (t + 1) % eval_every == 0 or t == rounds - 1:
                metrics = eval_jit(hleaves, state, train_data, val_data)
                for k, v in metrics.items():
                    getattr(res, _METRIC_FIELDS[k]).append(float(v))

    res.seconds = time.time() - t0
    res.state = state

    if ledger is not None:
        for n_teams, n_devices in res.participation:
            algo.log_comm_round(ledger, n_teams=n_teams, n_devices=n_devices)
        res.comm = ledger
    return res
