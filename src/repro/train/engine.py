"""Scanned multi-round FL engine: one compiled program per experiment.

The legacy drivers dispatched one jitted round at a time from Python and
re-traced eval on every call; at paper scale (hundreds of rounds x seven
algorithms x hyperparameter sweeps) the experiments were bottlenecked on
host dispatch, not hardware. This engine runs any `FLAlgorithm`
(core.algorithm) as a *single* jitted program:

    jit( scan over eval chunks:
           scan over eval_every rounds:
             sample participation masks in-graph (PRNG key in the carry)
             state = algo.round(state, data, masks)
             emit realized (gated) participation counts   # scan outputs
           metrics = algo.eval(state, ...)                # traced, cached
         -> metric history + per-round counts )

Participation sampling lives in the graph (core.participation), threading
the PRNG key through the scan carry — the same split-per-round chain the
legacy loop used, so trajectories match bit-for-bit. Byte accounting
stays on the host: the per-round team/device counts come back as scan
outputs and feed `CommLedger` post-hoc, counting only devices whose team
also participated (device_mask * team_mask[:, None] — the legacy loop's
ungated `dm.sum()` overcounted).

A wall-clock system model (`repro.system`) rides the same machinery:
when one is given, the round body simulates each round's duration along
the hierarchy's critical path (and, in deadline mode, thins the
participation masks by dropping stragglers *before* the algorithm round
runs), the simulated times come back as scan outputs exactly like the
gated mask counts, and the host assembles a `Timeline` next to the
`CommLedger` — `FLResult.sim_seconds` holds the cumulative simulated
time at each eval point, so accuracy-vs-seconds curves fall out.

``scan=False`` runs the same semantics as a per-round host-dispatch loop
(the legacy execution model) — kept for equivalence tests and for
benchmarks/bench_engine.py to quantify the dispatch win.

``cohort=c`` switches to the virtualized cohort engine (DESIGN.md §11):
the scan carry holds the device-tier store (`repro.train.store`) next
to the resident tiers, and each round samples a per-team index map
(`core.participation.sample_cohort`, PRNG stream salted off the round's
mask key so mask chains never move), gathers the cohort's data + device
state to (M, c), runs the unchanged algorithm round at cohort width,
and scatters the updated rows back. Participation masks, ledger counts,
the system round-time model and the probes all see the (M, c) cohort —
the population only ever exists as store rows. ``cohort=None`` (and,
bit-for-bit, ``cohort=n``) is the stacked full-population path.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import CommLedger
from repro.core.participation import sample_cohort, sample_masks
from repro.kernels.interface import dispatch_key
from repro.obs.events import write_run
from repro.obs.health import HealthReport
from repro.obs.profiling import compiled_cost, profile_ctx
from repro.obs.spans import SpanLog, current_log, span
from repro.obs.trace import RunTrace, TraceConfig, eval_points
from repro.system import (Timeline, get_profile, simulate_round,
                          workload_for)
from repro.train.store import (gather_cohort, scatter_cohort,
                               split_device_state)

__all__ = ["FLResult", "eval_points", "run_experiment"]


@dataclass
class FLResult:
    """One experiment's outcome: metric histories (one entry per eval
    point), wall time (compile vs steady-state split), final algorithm
    state, optional per-tier byte ledger and simulated-time `Timeline`,
    and realized (team-gated) per-round participation counts.

    ``seconds = compile_seconds + run_seconds`` always holds:
    ``compile_seconds`` is wall time until the first jitted dispatch
    returns — dominated by trace+compile on a cold program cache, by
    that dispatch's execution on a warm one — and ``run_seconds`` is
    everything after. A scanned experiment issues only 1-2 dispatches,
    so ``run_seconds`` is near 0 there (and on a cold cache a remainder
    chunk's own compile lands in it); steady-state throughput is a warm
    rerun's ``seconds`` (what benchmarks/bench_engine.py reports)."""
    pm_acc: list = field(default_factory=list)   # per-eval personalized acc
    tm_acc: list = field(default_factory=list)
    gm_acc: list = field(default_factory=list)
    train_loss: list = field(default_factory=list)
    seconds: float = 0.0                 # total wall time (compile + run)
    compile_seconds: float = 0.0         # first dispatch (trace/compile)
    run_seconds: float = 0.0             # post-first-dispatch remainder
    state: Any = None    # final algorithm state (set for every algorithm)
    comm: Optional[CommLedger] = None    # per-tier byte ledger (comm runs)
    participation: list = field(default_factory=list)  # (teams, devices)/rnd
    timeline: Optional[Timeline] = None  # per-round simulated clock
    sim_seconds: list = field(default_factory=list)  # cum sim time @ evals
    trace: Optional[RunTrace] = None     # per-round probe streams (obs)
    health: Optional[HealthReport] = None  # per-round detector streams
    rounds: int = 0                      # round budget this result ran
    eval_every: int = 1                  # eval cadence (aligns histories)
    dispatches: int = 0                  # jitted calls that executed it
    events_path: Optional[str] = None    # JSONL event log (trace_dir runs)
    cohort: Optional[int] = None         # cohort width (virtualized runs)
    population: Optional[int] = None     # resident devices/team (ditto)
    cohort_indices: list = field(default_factory=list)  # (M, C) idx / rnd

    def last(self, which="pm"):
        """Final-eval value of metric `which` ('pm'|'tm'|'gm'); NaN if the
        algorithm never reported it."""
        hist = {"pm": self.pm_acc, "tm": self.tm_acc, "gm": self.gm_acc}[which]
        return hist[-1] if hist else float("nan")

    def best(self, which="pm"):
        """Best eval value of metric `which` over the whole run."""
        hist = {"pm": self.pm_acc, "tm": self.tm_acc, "gm": self.gm_acc}[which]
        return max(hist) if hist else float("nan")


_METRIC_FIELDS = {"pm": "pm_acc", "tm": "tm_acc", "gm": "gm_acc",
                  "train_loss": "train_loss"}

# fold_in constant separating the system simulator's per-round PRNG
# stream from the participation-sampling stream (ASCII "SYST")
_SYSTEM_SALT = 0x53595354

# ditto for the cohort-sampling stream (ASCII "CHRT"): cohort indices are
# folded out of the round's mask key, never split off the carry chain, so
# running with any cohort_size — or none — leaves the mask and system
# streams bit-identical (pinned by tests/test_cohort_engine.py)
_COHORT_SALT = 0x43485254


def check_participation(algo, team_frac: float, device_frac: float):
    """Reject sampled participation for algorithms that ignore the masks —
    FLResult.participation must never report sampling that didn't gate
    anything. Shared by run_experiment and train.sweep.run_sweep."""
    if (team_frac < 1.0 or device_frac < 1.0) and \
            not getattr(algo, "supports_participation", False):
        raise ValueError(
            f"{getattr(algo, 'name', type(algo).__name__)} ignores "
            "participation masks; team_frac/device_frac < 1 would sample "
            "masks that never gate anything")


def _round_body(algo, m, n, team_frac, device_frac, system=None,
                trace=None, cohort=None, merge=None):
    """Scan step: in-graph mask sampling (key in the carry), optional
    system simulation (round time + deadline mask thinning), one
    algorithm round, and a dict of realized per-round outputs — gated
    participation counts, plus simulated time and straggler counts when
    a system model is active, plus ``probe:``-prefixed scalar
    diagnostics when a `TraceConfig` is (and ``health:``-prefixed
    detector values when its ``health`` flag is on too).

    system: None, or a static ``(SystemSpec skeleton, RoundWorkload)``
    pair; the spec's float values arrive as the traced ``sleaves``
    operand (see `repro.system.spec.SystemSpec.tree_floats`).
    trace: None (default — the emitted graph is byte-identical to the
    pre-trace engine), or a `TraceConfig`: ``algo.probe_round`` runs on
    the post-round state and its scalars ride the scan outputs.
    cohort: None for the stacked full-population body (carry is
    ``(state, key)``), or the cohort width: the carry becomes
    ``(dev_store, rest, key)`` (see `repro.train.store`), the round runs
    on the gathered (M, cohort) slice, and ``merge`` (from
    `split_device_state` at population width) rebuilds cohort states.
    Masks, system model and probes all run at cohort width, so
    participation/ledger counts and probe reductions cover exactly the
    materialized devices; the sampled index map rides the outputs as
    ``cohort_idx``.
    """
    sampled = team_frac < 1.0 or device_frac < 1.0
    nc = n if cohort is None else cohort

    def body(carry, _, data, sleaves=None):
        if cohort is None:
            state, key = carry
        else:
            dev, rest, key = carry
        if sampled:
            key, sub = jax.random.split(key)
            tm, dm = sample_masks(sub, m, nc, team_frac=team_frac,
                                  device_frac=device_frac)
        else:
            sub = None
            tm = jnp.ones((m,), jnp.float32)
            dm = jnp.ones((m, nc), jnp.float32)
        out = {}
        if cohort is not None:
            if sub is None:
                # full participation consumes no mask key; split one for
                # the cohort (and, below, the system) stream instead —
                # the split matches the stacked engine's unsampled
                # system split, so system streams stay bit-identical
                key, sub = jax.random.split(key)
            idx = sample_cohort(jax.random.fold_in(sub, _COHORT_SALT),
                                m, n, cohort)
            data = gather_cohort(data, idx)
            state = merge(gather_cohort(dev, idx), rest)
            out["cohort_idx"] = idx
        if system is not None:
            _, workload = system
            if sampled:
                # fold the system stream out of this round's mask key
                # instead of advancing the carry chain: the sampled mask
                # sequence stays bit-identical to a system-free run, so
                # a no-deadline system model is pure measurement under
                # every participation mode
                skey = jax.random.fold_in(sub, _SYSTEM_SALT)
            elif cohort is not None:
                skey = sub
            else:
                key, skey = jax.random.split(key)
            tm, dm, t_round, drop_t, drop_d = simulate_round(
                sleaves, workload, skey, tm, dm)
            out.update(t_round=t_round, dropped_teams=drop_t,
                       dropped_devices=drop_d)
        prev = state
        state = algo.round(state, data, team_mask=tm, device_mask=dm)
        gated = dm * tm[:, None]
        out.update(teams=jnp.sum(tm).astype(jnp.int32),
                   devices=jnp.sum(gated).astype(jnp.int32))
        if trace is not None:
            probes = algo.probe_round(prev, state, data, team_mask=tm,
                                      device_mask=dm, trace=trace)
            out.update({f"probe:{k}": jnp.asarray(v, jnp.float32)
                        for k, v in probes.items()})
            if trace.health:
                checks = algo.health_round(prev, state, data,
                                           team_mask=tm, device_mask=dm,
                                           trace=trace)
                out.update({f"health:{k}": jnp.asarray(v, jnp.float32)
                            for k, v in checks.items()})
        if cohort is None:
            return (state, key), out
        cdev, crest, _ = split_device_state(algo, state, m, cohort)
        return (scatter_cohort(dev, idx, cdev), crest, key), out

    return body


def hparam_skeleton(algo):
    """A value-independent cache key + the split for one algorithm: the
    instance with every sweepable float zeroed (hashable, shared by all
    hyperparameter values) plus its (leaves, rebuild) pair. Compiled
    programs key on the skeleton and take the float leaves as traced
    operands, so rerunning with new values never recompiles."""
    leaves, rebuild = algo.tree_hparams()
    return rebuild({k: 0.0 for k in leaves}), leaves


def _chunk_runner(skel, metric_fn, m, n, team_frac, device_frac,
                  system=None, trace=None, cohort=None):
    """The traceable heart of an experiment — shared verbatim by the
    per-experiment program below and train.sweep's vmapped grid program:
    rebuild the algorithm from its hparam leaves, then scan `n_steps`
    chunks of `length` rounds with a traced eval after each chunk.
    ``sleaves`` (the system model's float values, when `system` names a
    static skeleton/workload pair) is a traced operand like the hparam
    leaves — sweeps stack system profiles the same way they stack
    hyperparameters. ``trace`` (a static `TraceConfig` or None) selects
    the probe outputs the round body emits. ``cohort`` (static) splits
    the state into a device-tier store + resident rest for the inner
    scan — rounds run on gathered (M, cohort) slices, eval still sees
    the merged full-population state at each chunk boundary — and the
    external contract is unchanged: full state in, full state out."""
    _, rebuild = skel.tree_hparams()

    def run_chunks(hleaves, state, key, tr, va, *, sleaves=None, length,
                   n_steps):
        algo = rebuild(hleaves)
        if cohort is None:
            body = _round_body(algo, m, n, team_frac, device_frac, system,
                               trace)

            def chunk(carry, _):
                state, key = carry
                (state, key), outs = jax.lax.scan(
                    lambda c, x: body(c, x, tr, sleaves), (state, key),
                    length=length)
                return (state, key), (algo.eval(state, tr, va, metric_fn),
                                      outs)

            return jax.lax.scan(chunk, (state, key), length=n_steps)

        dev, rest, merge = split_device_state(algo, state, m, n)
        body = _round_body(algo, m, n, team_frac, device_frac, system,
                           trace, cohort=cohort, merge=merge)

        def chunk(carry, _):
            carry, outs = jax.lax.scan(
                lambda c, x: body(c, x, tr, sleaves), carry, length=length)
            dev, rest, _ = carry
            return carry, (algo.eval(merge(dev, rest), tr, va, metric_fn),
                           outs)

        (dev, rest, key), hist = jax.lax.scan(chunk, (dev, rest, key),
                                              length=n_steps)
        return (merge(dev, rest), key), hist

    return run_chunks


# Compiled programs are cached per (hparam skeleton, metric_fn, dims,
# system skeleton, trace config, kernel-dispatch key): every experiment
# with the same static structure — whatever its float hyperparameter or
# system-profile values — shares one compile and pays one dispatch. A
# TraceConfig is part of the static key (probes add scan outputs), so
# probes-off runs keep hitting the original program; the kernel-dispatch
# key (repro.kernels.interface.dispatch_key) rides the key the same way,
# so flipping REPRO_KERNEL_MODE / REPRO_COMPRESS_FUSED between runs
# re-traces instead of reusing a program that baked in the old kernels.
@functools.lru_cache(maxsize=128)
def _scan_program(skel, metric_fn, m, n, team_frac, device_frac,
                  system=None, trace=None, kdispatch=None, cohort=None):
    run_chunks = _chunk_runner(skel, metric_fn, m, n, team_frac,
                               device_frac, system, trace, cohort)
    return functools.partial(jax.jit, static_argnames=(
        "length", "n_steps"))(run_chunks)


@functools.lru_cache(maxsize=128)
def _eval_program(skel, metric_fn, kdispatch=None):
    _, rebuild = skel.tree_hparams()
    return jax.jit(lambda hleaves, state, tr, va: rebuild(hleaves).eval(
        state, tr, va, metric_fn))


# eval_points moved to repro.obs.trace (the event log aligns on the same
# grid) and is re-exported here for its original callers.


def assemble_timeline(res: FLResult, profile: str, round_times, drop_t,
                      drop_d, rounds: int, eval_every: int) -> None:
    """Attach a host-side Timeline (and the cumulative simulated time at
    each eval point) to `res` from per-round scan outputs. Shared with
    train.sweep."""
    res.timeline = Timeline(
        profile=profile,
        round_seconds=[float(x) for x in round_times],
        dropped_teams=[int(x) for x in drop_t],
        dropped_devices=[int(x) for x in drop_d])
    res.sim_seconds = res.timeline.at_rounds(
        eval_points(rounds, eval_every))


def run_experiment(algo, params0, train_data, val_data, *,
                   metric_fn: Callable, rounds: int, m: int, n: int,
                   team_frac: float = 1.0, device_frac: float = 1.0,
                   seed: int = 0, eval_every: int = 1, scan: bool = True,
                   system=None, trace=None, trace_dir=None,
                   event_meta: Optional[dict] = None,
                   cohort: Optional[int] = None) -> FLResult:
    """Drive `algo` for `rounds` global rounds, evaluating every
    `eval_every` rounds (and after the final round). Returns an FLResult
    whose metric histories hold one entry per eval point.

    scan=True compiles the whole experiment into one program (chunked
    lax.scan); scan=False dispatches round-by-round from the host with
    identical semantics — same mask PRNG chain, same eval points.
    system: optional wall-clock model (a `repro.system.SystemSpec`, a
    profile name, or a spec dict): simulate each round's duration and —
    in deadline mode — drop stragglers from the participation masks;
    the result grows a `Timeline` and `sim_seconds` history.
    trace: optional `repro.obs.TraceConfig` (or True for the default
    one): emit per-round probe scalars — and, under ``trace.health``,
    the algorithm's health detectors — as extra scan outputs, assembled
    into ``FLResult.trace`` / ``FLResult.health``; also gates the
    cost-analysis capture, the ``jax.profiler`` context, and
    ``trace.fail_fast`` (raise `repro.obs.health.HealthError` naming
    the first bad round as soon as a dispatched chunk's detectors
    fire). None (default) leaves the compiled program — and the
    trajectory — untouched.
    trace_dir: when set, write the run's JSONL event log (header / eval
    points / footer, `repro.obs.events`) into this directory, plus a
    Chrome-trace span file (`repro.obs.spans`) covering
    build/compile/dispatch/eval — unless a caller already activated a
    `SpanLog`, in which case our spans land there and the caller saves;
    ``event_meta`` is merged into the header (scenario identity etc.).
    cohort: optional cohort width for the virtualized engine (module
    docstring / DESIGN.md §11): only a sampled (M, cohort) slice of the
    population is materialized per round; ``FLResult.cohort_indices``
    records each round's index map and participation/ledger counts
    cover cohort devices only. ``team_frac``/``device_frac`` then
    sample within the cohort.
    """
    kw = dict(metric_fn=metric_fn, rounds=rounds, m=m, n=n,
              team_frac=team_frac, device_frac=device_frac, seed=seed,
              eval_every=eval_every, scan=scan, system=system,
              trace=trace, trace_dir=trace_dir, event_meta=event_meta,
              cohort=cohort)
    # span-log ownership (repro.obs.spans): the outermost layer with a
    # trace_dir creates, activates, and saves one; when a caller
    # (run_scenario, the scenarios CLI) already activated a log, our
    # spans land there and the caller saves
    if trace_dir is None or current_log() is not None:
        return _run_experiment(algo, params0, train_data, val_data, **kw)
    tag = getattr(algo, "name", None) or "run"
    log = SpanLog(meta={"kind": "experiment", "algo": tag})
    with log.activate():
        try:
            return _run_experiment(algo, params0, train_data, val_data,
                                   **kw)
        finally:
            log.save(trace_dir, tag=tag)


def _run_experiment(algo, params0, train_data, val_data, *, metric_fn,
                    rounds, m, n, team_frac, device_frac, seed,
                    eval_every, scan, system, trace, trace_dir,
                    event_meta, cohort) -> FLResult:
    check_participation(algo, team_frac, device_frac)
    if cohort is not None:
        cohort = int(cohort)
        if not 1 <= cohort <= n:
            raise ValueError(
                f"cohort must be in [1, n_devices={n}], got {cohort}")
    if trace is True:
        trace = TraceConfig()
    with span("build", algo=getattr(algo, "name", "?"), m=m, n=n,
              rounds=rounds):
        state = algo.init_state(params0, m, n)
        key = jax.random.PRNGKey(seed)
        n_chunks, rem = divmod(rounds, eval_every)

        sys_key = sleaves = None
        if system is not None:
            system = get_profile(system)
            sys_key = (system.skeleton(), workload_for(algo, params0))
            sleaves, _ = system.tree_floats()

        skel, hleaves = hparam_skeleton(algo)
        kdisp = dispatch_key()
        scanned = _scan_program(skel, metric_fn, m, n, team_frac,
                                device_frac, sys_key, trace, kdisp,
                                cohort)
        eval_jit = _eval_program(skel, metric_fn, kdisp)

    res = FLResult(rounds=rounds, eval_every=eval_every, cohort=cohort,
                   population=n if cohort is not None else None)
    ledger = algo.make_ledger(params0)
    outs_flat = {}          # output name -> flat per-round list
    t0 = time.time()
    t_first = None

    def record(metrics_hist, outs):
        """metrics_hist: dict of (chunks,) arrays; outs: dict of
        (chunks, length) per-round output arrays (cohort_idx rides as
        (chunks, length, M, C) and lands in res.cohort_indices)."""
        for k, v in metrics_hist.items():
            getattr(res, _METRIC_FIELDS[k]).extend(
                float(x) for x in np.asarray(v))
        for k, v in outs.items():
            if k == "cohort_idx":
                arr = np.asarray(v)
                res.cohort_indices.extend(
                    arr.reshape((-1,) + arr.shape[-2:]).astype(int)
                    .tolist())
                continue
            outs_flat.setdefault(k, []).extend(
                np.asarray(v).reshape(-1).tolist())

    fail_ctx = (event_meta or {}).get("scenario") \
        or getattr(algo, "name", None) or "run"

    def check_health():
        """Fail fast on the detector streams accumulated so far —
        outs_flat spans chunks, so indices are global 1-based rounds."""
        if trace is None or not (trace.health and trace.fail_fast):
            return
        HealthReport(series={
            k.split(":", 1)[1]: v for k, v in outs_flat.items()
            if k.startswith("health:")}).check(fail_ctx)

    compile_span = None
    with profile_ctx(trace):
        if scan:
            for length, n_steps in ((eval_every, n_chunks), (rem, 1)):
                if length == 0 or n_steps == 0:
                    continue
                first = t_first is None
                with span("compile" if first else "dispatch",
                          chunks=n_steps, rounds_per_chunk=length) as sp:
                    (state, key), (metrics, outs) = scanned(
                        hleaves, state, key, train_data, val_data,
                        sleaves=sleaves, length=length, n_steps=n_steps)
                    res.dispatches += 1
                    if first:
                        jax.block_until_ready(state)
                        t_first = time.time()
                        compile_span = sp
                with span("eval", chunks=n_steps):
                    record(metrics, outs)
                check_health()
        else:
            if cohort is None:
                round_body = _round_body(algo, m, n, team_frac,
                                         device_frac, sys_key, trace)
                carry, unpack = (state, key), lambda c: c[0]
            else:
                dev, rest, mrg = split_device_state(algo, state, m, n)
                round_body = _round_body(algo, m, n, team_frac,
                                         device_frac, sys_key, trace,
                                         cohort=cohort, merge=mrg)
                carry, unpack = (dev, rest, key), lambda c: mrg(c[0], c[1])
            for t in range(rounds):
                first = t_first is None
                with span("compile" if first else "dispatch",
                          round=t + 1) as sp:
                    carry, outs = round_body(carry, None, train_data,
                                             sleaves)
                    res.dispatches += 1
                    if first:
                        jax.block_until_ready(carry)
                        t_first = time.time()
                        compile_span = sp
                for k, v in outs.items():
                    if k == "cohort_idx":
                        res.cohort_indices.append(
                            np.asarray(v).astype(int).tolist())
                        continue
                    outs_flat.setdefault(k, []).append(
                        float(v) if k == "t_round"
                        or k.startswith(("probe:", "health:")) else int(v))
                check_health()
                if (t + 1) % eval_every == 0 or t == rounds - 1:
                    with span("eval", round=t + 1):
                        metrics = eval_jit(hleaves, unpack(carry),
                                           train_data, val_data)
                        res.dispatches += 1
                        for k, v in metrics.items():
                            getattr(res, _METRIC_FIELDS[k]).append(
                                float(v))
            state, key = unpack(carry), carry[-1]

    t_end = time.time()
    res.compile_seconds = (t_first if t_first is not None else t_end) - t0
    res.run_seconds = t_end - (t_first if t_first is not None else t_end)
    res.seconds = res.compile_seconds + res.run_seconds
    res.state = state

    probe_series = {k.split(":", 1)[1]: outs_flat.pop(k)
                    for k in sorted(outs_flat) if k.startswith("probe:")}
    health_series = {k.split(":", 1)[1]: outs_flat.pop(k)
                     for k in sorted(outs_flat)
                     if k.startswith("health:")}
    if trace is not None:
        cost = None
        if trace.cost_analysis and scan and n_chunks:
            # shapes are all that matter; the live operands carry them
            cost = compiled_cost(scanned, hleaves, state, key, train_data,
                                 val_data, sleaves=sleaves,
                                 length=eval_every, n_steps=n_chunks)
            if cost and compile_span is not None:
                # late-stamp the static cost next to the measured compile
                # time — Span.set works after close, the log saves later
                compile_span.set(**cost)
        res.trace = RunTrace(config=trace, series=probe_series, cost=cost)
        if trace.health:
            res.health = HealthReport(series=health_series)

    res.participation = list(zip(
        [int(x) for x in outs_flat.get("teams", [])],
        [int(x) for x in outs_flat.get("devices", [])]))
    if system is not None:
        assemble_timeline(res, system.name, outs_flat["t_round"],
                          outs_flat["dropped_teams"],
                          outs_flat["dropped_devices"], rounds, eval_every)

    if ledger is not None:
        for n_teams, n_devices in res.participation:
            algo.log_comm_round(ledger, n_teams=n_teams, n_devices=n_devices)
        res.comm = ledger

    if trace_dir is not None:
        res.events_path = str(write_run(
            trace_dir, res, algo=algo,
            meta={"m": m, "n": n, "seed": seed, "team_frac": team_frac,
                  "device_frac": device_frac, "scan": scan,
                  "system": system.name if system is not None else None,
                  **({"cohort": cohort} if cohort is not None else {}),
                  **(event_meta or {})}))
    return res
