"""Decoder stack (+ Whisper encoder-decoder) with scanned layer blocks.

Layers are grouped into *blocks*: the smallest repeating pattern of
(mixer kind, MoE?) signatures — size lcm(attn_period, moe_period). Per-layer
params are stacked over blocks on a leading axis and the stack is applied
with ``jax.lax.scan``, so HLO size and compile time are independent of
depth (9 scanned blocks of 8 heterogeneous layers for 72-layer Jamba).

The same block structure carries the KV/SSM/RWKV caches: cache leaves are
stacked (n_blocks, ...) and scanned together with the params.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.models import attention, layers, mamba, moe, rwkv


# ---------------------------------------------------------------------------
# block pattern
# ---------------------------------------------------------------------------

def block_pattern(cfg):
    """Returns (n_blocks, [(kind, is_moe), ...] per position-in-block)."""
    kinds = cfg.layer_kinds()
    moe_mask = cfg.moe_layer_mask()
    period = 1
    if cfg.attn_period and cfg.attn_period > 1:
        period = cfg.attn_period
    if cfg.moe.num_experts and cfg.moe_layer_period > 1:
        period = math.lcm(period, cfg.moe_layer_period)
    if cfg.num_layers % period:
        period = cfg.num_layers  # fall back to one unscanned mega-block
    pattern = [(kinds[i], moe_mask[i]) for i in range(period)]
    # verify periodicity
    for i in range(cfg.num_layers):
        assert (kinds[i], moe_mask[i]) == pattern[i % period], \
            f"layer pattern not periodic at {i}"
    return cfg.num_layers // period, pattern


# ---------------------------------------------------------------------------
# per-position init/apply
# ---------------------------------------------------------------------------

def _position_init(key, cfg, kind, is_moe, dtype):
    ks = jax.random.split(key, 4)
    p = {"norm1": layers.norm_init(cfg, dtype=dtype),
         "norm2": layers.norm_init(cfg, dtype=dtype)}
    if kind == "attn":
        p["attn"] = attention.attn_init(ks[0], cfg, dtype)
    elif kind == "mamba":
        p["mamba"] = mamba.mamba_init(ks[0], cfg, dtype)
    elif kind == "rwkv":
        p["tm"] = rwkv.timemix_init(ks[0], cfg, dtype)
    if kind == "rwkv":
        p["cm"] = rwkv.channelmix_init(ks[1], cfg, dtype)
    elif is_moe:
        p["moe"] = moe.moe_init(ks[1], cfg, dtype)
    else:
        p["mlp"] = layers.swiglu_init(ks[1], cfg.d_model, cfg.d_ff, dtype)
    if cfg.is_encoder_decoder:
        p["norm_x"] = layers.norm_init(cfg, dtype=dtype)
        p["cross"] = attention.attn_init(ks[2], cfg, dtype)
    return p


def _position_cache(cfg, kind, batch, max_len, dtype):
    if kind == "attn":
        c = attention.init_kv_cache(cfg, batch, max_len, dtype)
    elif kind == "mamba":
        # conv window follows activation dtype; ssm state stays f32
        c = mamba.init_mamba_cache(cfg, batch, dtype)
    elif kind == "rwkv":
        # token-shift buffers follow activation dtype; wkv state stays f32
        c = rwkv.init_rwkv_cache(cfg, batch, dtype)
    else:
        raise ValueError(kind)
    if cfg.is_encoder_decoder and kind == "attn":
        hd = cfg.resolved_head_dim
        c["cross_k"] = jnp.zeros((batch, cfg.encoder_seq_len,
                                  cfg.num_kv_heads, hd), dtype)
        c["cross_v"] = jnp.zeros_like(c["cross_k"])
    return c


def _apply_position(p, cfg, kind, is_moe, x, *, mode, cache=None, pos=None,
                    mrope_positions=None, enc_out=None):
    """One layer. mode: 'full' | 'decode'. Returns (x, new_cache, aux)."""
    aux = 0.0
    h = layers.norm_apply(cfg, p["norm1"], x)
    new_cache = dict(cache) if cache is not None else None
    if kind == "attn":
        if mode == "full":
            if cache is not None:
                y, kvc = attention.attn_prefill(
                    p["attn"], cfg, h, mrope_positions=mrope_positions,
                    cache={"k": cache["k"], "v": cache["v"]})
                new_cache.update(kvc)
            else:
                y = attention.attn_apply(p["attn"], cfg, h,
                                         mrope_positions=mrope_positions)
        else:
            y, kvc = attention.attn_decode(
                p["attn"], cfg, h, {"k": cache["k"], "v": cache["v"]}, pos,
                mrope_positions=mrope_positions)
            new_cache.update(kvc)
    elif kind == "mamba":
        if mode == "full":
            y, mc = mamba.mamba_apply(
                p["mamba"], cfg, h,
                cache=({"conv": cache["conv"], "ssm": cache["ssm"]}
                       if cache is not None else None))
            if new_cache is not None:
                new_cache.update(mc)
        else:
            y, mc = mamba.mamba_decode(p["mamba"], cfg, h,
                                       {"conv": cache["conv"],
                                        "ssm": cache["ssm"]})
            new_cache.update(mc)
    elif kind == "rwkv":
        if mode == "full":
            y, (tm_last, wkv_state) = rwkv.timemix_apply(
                p["tm"], cfg, h,
                last=cache["tm_last"] if cache is not None else None,
                state=cache["wkv"] if cache is not None else None)
        else:
            y, (tm_last, wkv_state) = rwkv.timemix_apply(
                p["tm"], cfg, h, last=cache["tm_last"], state=cache["wkv"])
        if new_cache is not None:
            new_cache["tm_last"] = tm_last.astype(
                cache["tm_last"].dtype if cache is not None else y.dtype)
            new_cache["wkv"] = wkv_state
    x = x + y

    if cfg.is_encoder_decoder and kind == "attn":
        hx = layers.norm_apply(cfg, p["norm_x"], x)
        hd = cfg.resolved_head_dim
        if mode == "full":
            # compute + (optionally) cache cross K/V from encoder output
            b, se, _ = enc_out.shape
            ck = (enc_out @ p["cross"]["wk"]).reshape(b, se,
                                                      cfg.num_kv_heads, hd)
            cv = (enc_out @ p["cross"]["wv"]).reshape(b, se,
                                                      cfg.num_kv_heads, hd)
            if new_cache is not None:
                new_cache["cross_k"] = ck.astype(new_cache["cross_k"].dtype)
                new_cache["cross_v"] = cv.astype(new_cache["cross_v"].dtype)
        else:
            ck, cv = cache["cross_k"], cache["cross_v"]
        bq, sq, _ = hx.shape
        q = (hx @ p["cross"]["wq"]).reshape(bq, sq, cfg.num_heads, hd)
        from repro.kernels.flash_attention import attention as attn_op
        y = attn_op(q, ck, cv, causal=False, q_offset=0)
        x = x + y.reshape(bq, sq, -1) @ p["cross"]["wo"]

    h2 = layers.norm_apply(cfg, p["norm2"], x)
    if kind == "rwkv":
        y2, cm_last = rwkv.channelmix_apply(
            p["cm"], cfg, h2,
            last=cache["cm_last"] if cache is not None else None)
        if new_cache is not None:
            new_cache["cm_last"] = cm_last.astype(
                cache["cm_last"].dtype if cache is not None else y2.dtype)
    elif is_moe:
        y2, aux = moe.moe_apply(p["moe"], cfg, h2)
    else:
        y2 = layers.swiglu_apply(p["mlp"], h2)
    x = x + y2
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# stack init / apply
# ---------------------------------------------------------------------------

def stack_init(key, cfg, dtype=jnp.float32):
    n_blocks, pattern = block_pattern(cfg)

    def one_block(k):
        ks = jax.random.split(k, len(pattern))
        return {f"pos{i}": _position_init(ks[i], cfg, kind, is_moe, dtype)
                for i, (kind, is_moe) in enumerate(pattern)}

    keys = jax.random.split(key, n_blocks)
    return jax.vmap(one_block)(keys)


def stack_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    n_blocks, pattern = block_pattern(cfg)
    one = {f"pos{i}": _position_cache(cfg, kind, batch, max_len, dtype)
           for i, (kind, _) in enumerate(pattern)}
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_blocks,) + x.shape).copy(), one)


def stack_apply(params, cfg, x, *, mode="full", cache=None, pos=None,
                mrope_positions=None, enc_out=None, remat=False):
    """Scan the block stack. Returns (x, new_cache, total_aux)."""
    _, pattern = block_pattern(cfg)

    from repro.sharding.constrain import constrain

    def block_fn(carry, xs):
        x, aux_tot = carry
        # between-block activations are sequence-sharded over `model`
        # (Megatron-SP): divides the remat residual footprint by the TP
        # degree; GSPMD re-gathers at each mixer's QKV projection.
        x = constrain(x, "batch", "model", None)
        blk_params, blk_cache = xs
        new_blk_cache = {} if blk_cache is not None else None
        for i, (kind, is_moe) in enumerate(pattern):
            c = blk_cache[f"pos{i}"] if blk_cache is not None else None
            x, nc, aux = _apply_position(
                blk_params[f"pos{i}"], cfg, kind, is_moe, x, mode=mode,
                cache=c, pos=pos, mrope_positions=mrope_positions,
                enc_out=enc_out)
            if new_blk_cache is not None:
                new_blk_cache[f"pos{i}"] = nc
        return (x, aux_tot + aux), new_blk_cache

    if remat:
        block_fn = jax.checkpoint(block_fn)

    if cache is None:
        (x, aux), _ = jax.lax.scan(
            lambda c, p: block_fn(c, (p, None)), (x, 0.0), params)
        return x, None, aux
    (x, aux), new_cache = jax.lax.scan(block_fn, (x, 0.0), (params, cache))
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# whisper encoder
# ---------------------------------------------------------------------------

def encoder_init(key, cfg, dtype=jnp.float32):
    def one(k):
        k1, k2 = jax.random.split(k)
        return {
            "norm1": layers.norm_init(cfg, dtype=dtype),
            "attn": attention.attn_init(k1, cfg, dtype),
            "norm2": layers.norm_init(cfg, dtype=dtype),
            "mlp": layers.gelu_mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
        }
    keys = jax.random.split(key, cfg.encoder_layers)
    return {"layers": jax.vmap(one)(keys),
            "final_norm": layers.norm_init(cfg, dtype=dtype)}


def encoder_apply(params, cfg, frames):
    """frames: (b, encoder_seq, d) precomputed embeddings (frontend stub)."""
    b, s, d = frames.shape
    pos = layers.sinusoidal_positions(s, d).astype(frames.dtype)
    x = frames + pos[None]

    def layer_fn(x, p):
        h = layers.norm_apply(cfg, p["norm1"], x)
        y = attention.attn_apply(p["attn"], cfg, h, causal=False,
                                 positions=None)
        x = x + y
        h2 = layers.norm_apply(cfg, p["norm2"], x)
        x = x + layers.gelu_mlp_apply(p["mlp"], h2)
        return x, None

    x, _ = jax.lax.scan(layer_fn, x, params["layers"])
    return layers.norm_apply(cfg, params["final_norm"], x)
