"""Shared neural-net building blocks (pure-pytree style, no framework).

Every "module" here is a pair of functions: ``*_init(key, ...) -> params``
and ``*_apply(params, x, ...) -> y``, with params as plain dicts of
jnp arrays. Model-parallel sharding is attached later by path-based
PartitionSpec rules (repro/sharding/specs.py), which is why leaf names are
stable and descriptive.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in, d_out, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab, d, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(params, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm_apply(params, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def norm_init(cfg, d=None, dtype=jnp.float32):
    d = d or cfg.d_model
    return rmsnorm_init(d, dtype) if cfg.use_rmsnorm else layernorm_init(d, dtype)


def norm_apply(cfg, params, x):
    if cfg.use_rmsnorm:
        return rmsnorm_apply(params, x, cfg.norm_eps)
    return layernorm_apply(params, x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE + Qwen2-VL's M-RoPE)
# ---------------------------------------------------------------------------

def _rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta=10_000.0):
    """x: (b, s, h, d); positions: (b, s) int32 -> same shape."""
    d = x.shape[-1]
    freqs = _rope_freqs(d, theta)                       # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (b, s, d/2)
    sin = jnp.sin(angles)[:, :, None, :]
    cos = jnp.cos(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta=10_000.0, sections=(2, 1, 1)):
    """Qwen2-VL multimodal RoPE.

    x: (b, s, h, d); positions3: (b, s, 3) — (temporal, height, width)
    position ids. The d/2 frequency slots are split between the three
    components in ratio ``sections`` (Qwen2-VL uses 16/24/24 of 64; we use
    the same 1/4-3/8-3/8 proportions scaled to head_dim).
    """
    d = x.shape[-1]
    half = d // 2
    freqs = _rope_freqs(d, theta)                       # (half,)
    total = sum(sections)
    bounds = [half * sum(sections[:i + 1]) // total for i in range(3)]
    starts = [0, bounds[0], bounds[1]]
    comp = jnp.zeros(half, jnp.int32)
    comp = comp.at[starts[1]:bounds[1]].set(1)
    comp = comp.at[starts[2]:bounds[2]].set(2)
    # pick, per frequency slot, the position component it rotates with
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),                 # (b, s, 3)
        jnp.broadcast_to(comp[None, None, :],
                         positions3.shape[:2] + (half,)).astype(jnp.int32),
        axis=-1)                                        # (b, s, half)
    angles = pos * freqs
    sin = jnp.sin(angles)[:, :, None, :]
    cos = jnp.cos(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(max_len, d):
    """Whisper-style fixed sinusoidal embeddings: (max_len, d)."""
    pos = jnp.arange(max_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / (10_000.0 ** (dim / d))
    emb = jnp.zeros((max_len, d), jnp.float32)
    emb = emb.at[:, 0::2].set(jnp.sin(angle))
    emb = emb.at[:, 1::2].set(jnp.cos(angle))
    return emb


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu_init(key, d, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": dense_init(k1, d, d_ff, dtype),
            "w_up": dense_init(k2, d, d_ff, dtype),
            "w_down": dense_init(k3, d_ff, d, dtype)}


def swiglu_apply(params, x):
    g = jax.nn.silu(x @ params["w_gate"])
    return (g * (x @ params["w_up"])) @ params["w_down"]


def gelu_mlp_init(key, d, d_ff, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {"w_in": dense_init(k1, d, d_ff, dtype),
            "b_in": jnp.zeros((d_ff,), dtype),
            "w_out": dense_init(k2, d_ff, d, dtype),
            "b_out": jnp.zeros((d,), dtype)}


def gelu_mlp_apply(params, x):
    h = jax.nn.gelu(x @ params["w_in"] + params["b_in"])
    return h @ params["w_out"] + params["b_out"]
