"""Mamba (S6) block — the SSM mixer used by Jamba's non-attention layers.

Selective state space: input-dependent (Δ, B, C), diagonal A.
    h_t = exp(Δ_t ⊙ A) h_{t-1} + Δ_t B_t x_t
    y_t = C_t · h_t + D x_t
Training/prefill runs a time scan carrying the (d_in, d_state) state;
decode is a single recurrence step against a (conv window, ssm state)
cache. The sequential scan is deliberate on TPU: materializing per-step
states for an associative scan costs seq×d_in×d_state HBM, which at Jamba
scale (d_in=16384) dwarfs the win — see DESIGN.md §3.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers


def _dims(cfg):
    d_in = cfg.mamba_expand * cfg.d_model
    dt_rank = max(1, cfg.d_model // 16)
    return d_in, dt_rank, cfg.mamba_d_state, cfg.mamba_d_conv


def mamba_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    d_in, dt_rank, d_state, d_conv = _dims(cfg)
    ks = jax.random.split(key, 6)
    a = jnp.broadcast_to(jnp.arange(1, d_state + 1, dtype=jnp.float32),
                         (d_in, d_state))
    return {
        "in_proj": layers.dense_init(ks[0], d, 2 * d_in, dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, d_in)) *
                   (1.0 / jnp.sqrt(d_conv))).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": layers.dense_init(ks[2], d_in, dt_rank + 2 * d_state, dtype),
        "dt_proj": layers.dense_init(ks[3], dt_rank, d_in, dtype),
        "dt_bias": (jnp.log(jnp.expm1(0.01)) *
                    jnp.ones((d_in,))).astype(jnp.float32),
        "A_log": jnp.log(a),                       # f32: decay-critical
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": layers.dense_init(ks[4], d_in, d, dtype),
    }


def init_mamba_cache(cfg, batch, dtype=jnp.float32):
    d_in, _, d_state, d_conv = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, d_conv - 1, d_in), dtype),
        "ssm": jnp.zeros((batch, d_in, d_state), jnp.float32),
    }


def _ssm_params(params, xc, cfg):
    """xc: (..., d_in) conv output -> (dt, B, C) input-dependent params."""
    _, dt_rank, d_state, _ = _dims(cfg)
    proj = xc @ params["x_proj"]
    dt, b_mat, c_mat = jnp.split(
        proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(dt @ params["dt_proj"] +
                         params["dt_bias"].astype(dt.dtype))
    return dt, b_mat, c_mat


# Unrolled-chunk length for the selective scan. Larger chunks cut HBM
# round-trips on the carried state linearly but grow the unrolled HLO (and
# compile time) linearly; 16 puts the memory term at compute parity for
# jamba-398b while keeping XLA compile tractable (§Perf hillclimb 1).
# REPRO_MAMBA_CHUNK=1 restores the per-timestep scan (the naive-port
# baseline recorded in EXPERIMENTS.md §Perf).
import os as _os
MAMBA_CHUNK = int(_os.environ.get("REPRO_MAMBA_CHUNK", "16"))


def mamba_apply(params, cfg, x, cache=None, *, chunk: int = MAMBA_CHUNK):
    """Full-sequence mamba. x: (b, s, d) -> (y, new_cache or None).

    If ``cache`` is given, the scan starts from its (conv, ssm) state and
    the returned cache holds the post-sequence state (prefill semantics).

    The selective scan is CHUNKED (TPU adaptation): the outer
    ``jax.lax.scan`` carries the (b, d_in, N) state across s/chunk chunks
    and the inner `chunk` steps are unrolled, so the per-step recurrence
    stays inside one fusion's VMEM working set. A per-timestep lax.scan
    would re-touch HBM every step — at Jamba scale that is ~30x the whole
    step's compute time (EXPERIMENTS.md §Perf, hillclimb 1). Streams
    (xc/dt/B/C) stay in the activation dtype; only the carried state is
    f32 (decay-critical).
    """
    b, s, d = x.shape
    d_in, dt_rank, d_state, d_conv = _dims(cfg)
    xz = x @ params["in_proj"]
    xr, z = jnp.split(xz, 2, axis=-1)                  # (b, s, d_in) ×2

    # causal depthwise conv1d (history from cache if present)
    if cache is not None:
        hist = cache["conv"].astype(xr.dtype)          # (b, d_conv-1, d_in)
        xp = jnp.concatenate([hist, xr], axis=1)
    else:
        xp = jnp.pad(xr, ((0, 0), (d_conv - 1, 0), (0, 0)))
    xc = sum(xp[:, i:i + s, :] * params["conv_w"][i][None, None, :]
             for i in range(d_conv))
    xc = jax.nn.silu(xc + params["conv_b"])

    dt, b_mat, c_mat = _ssm_params(params, xc, cfg)    # (b,s,d_in),(b,s,N)×2
    a = -jnp.exp(params["A_log"])                      # (d_in, N) f32

    from repro.sharding.constrain import constrain
    h0 = (cache["ssm"] if cache is not None
          else jnp.zeros((b, d_in, d_state), jnp.float32))
    h0 = constrain(h0, "batch", "model", None)

    chunk = min(chunk, s)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s

    def to_chunks(t):                                   # (b, s, f) -> (nc, chunk, b, f)
        tp = jnp.pad(t, ((0, 0), (0, pad), (0, 0))) if pad else t
        return jnp.moveaxis(tp.reshape(b, n_chunks, chunk, -1), 0, 2)

    xs = tuple(to_chunks(t) for t in (xc, dt, b_mat, c_mat))

    @jax.checkpoint
    def chunk_fn(h, inp):
        xc_c, dt_c, b_c, c_c = inp                     # (chunk, b, ...)
        ys = []
        for i in range(chunk):
            dt_t = dt_c[i].astype(jnp.float32)         # (b, d_in)
            da = jnp.exp(dt_t[..., None] * a)          # (b, d_in, N)
            bb = (dt_t * xc_c[i].astype(jnp.float32))[..., None] * \
                b_c[i].astype(jnp.float32)[:, None, :]
            h = da * h + bb
            ys.append(jnp.einsum(
                "bdn,bn->bd", h, c_c[i].astype(jnp.float32)))
        # NB: keeping ys f32 across the scan and casting once afterwards
        # was tried and REFUTED (§Perf hillclimb 1 iter 2): the f32
        # stacked buffer made the backward loop's whole-buffer traffic
        # 5x WORSE (94 s -> 463 s). Cast per chunk.
        return h, jnp.stack(ys).astype(x.dtype)        # (chunk, b, d_in)

    h_final, ys = jax.lax.scan(chunk_fn, h0, xs)
    y = jnp.moveaxis(ys.reshape(n_chunks * chunk, b, d_in), 0, 1)[:, :s]
    y = y + xc * params["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": xp[:, -(d_conv - 1):, :].astype(
            cache["conv"].dtype), "ssm": h_final}
    return y @ params["out_proj"], new_cache


def mamba_decode(params, cfg, x, cache):
    """Single-token step. x: (b, 1, d); cache from init_mamba_cache."""
    b = x.shape[0]
    d_in, dt_rank, d_state, d_conv = _dims(cfg)
    xz = x[:, 0, :] @ params["in_proj"]
    xr, z = jnp.split(xz, 2, axis=-1)                  # (b, d_in)

    window = jnp.concatenate([cache["conv"],
                              xr[:, None, :].astype(cache["conv"].dtype)],
                             axis=1)                   # (b, d_conv, d_in)
    xc = jnp.einsum("bcd,cd->bd", window, params["conv_w"].astype(window.dtype))
    xc = jax.nn.silu(xc + params["conv_b"])

    dt, b_mat, c_mat = _ssm_params(params, xc, cfg)
    a = -jnp.exp(params["A_log"])
    da = jnp.exp(dt.astype(jnp.float32)[..., None] * a)
    h = (da * cache["ssm"] +
         (dt * xc).astype(jnp.float32)[..., None] *
         b_mat.astype(jnp.float32)[:, None, :])
    y = jnp.einsum("bdn,bn->bd", h, c_mat.astype(jnp.float32)).astype(x.dtype)
    y = y + xc * params["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    new_cache = {"conv": window[:, 1:, :], "ssm": h}
    return (y @ params["out_proj"])[:, None, :], new_cache
