"""Fine-grained Mixture-of-Experts layer (DeepSeek-MoE / DBRX style).

Shared experts (always on) + routed experts with top-k gating and
capacity-based dispatch. Routing is the fused kernel
(repro.kernels.moe_router); dispatch/combine are one-hot einsums over token
*groups* (GShard style) so the dispatch tensor is
(groups, group_size, E, capacity) with a bounded group_size — the
expert matmuls are plain batched einsums the MXU loves, and the experts
dimension is what the `model`/expert-parallel mesh axis shards.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.moe_router import route_topk
from repro.models import layers

DEFAULT_GROUP = 1024


def moe_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    m = cfg.moe
    e_ff = m.expert_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    scale = 1.0 / jnp.sqrt(d)

    def expert_bank(k_, n):
        k1, k2, k3 = jax.random.split(k_, 3)
        return {
            "w_gate": (jax.random.normal(k1, (n, d, e_ff)) * scale).astype(dtype),
            "w_up": (jax.random.normal(k2, (n, d, e_ff)) * scale).astype(dtype),
            "w_down": (jax.random.normal(k3, (n, e_ff, d)) *
                       (1.0 / jnp.sqrt(e_ff))).astype(dtype),
        }

    p = {
        "router": layers.dense_init(ks[0], d, m.num_experts, jnp.float32),
        "experts": expert_bank(ks[1], m.num_experts),
    }
    if m.num_shared_experts:
        p["shared"] = layers.swiglu_init(ks[2], d, e_ff * m.num_shared_experts,
                                         dtype)
    return p


def _capacity(group_size: int, num_experts: int, top_k: int,
              factor: float) -> int:
    cap = int(group_size * top_k / num_experts * factor)
    return max(cap, top_k)


@functools.partial(jax.jit, static_argnames=("cfg", "group_size"))
def moe_apply(params, cfg, x, *, group_size: int = DEFAULT_GROUP):
    """x: (b, s, d) -> (y: (b, s, d), aux_loss: scalar).

    Tokens over capacity are dropped (their contribution is the shared
    experts + residual only) — standard capacity-based MoE semantics.
    """
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)

    gs = min(group_size, t)
    n_groups = -(-t // gs)
    pad = n_groups * gs - t
    xp = jnp.pad(xt, ((0, pad), (0, 0))) if pad else xt

    logits = (xp.astype(jnp.float32) @ params["router"])      # (T, E)
    gates, idx, aux = route_topk(logits, top_k=m.top_k)       # (T,k) ×2
    # drop gates of padded tokens so they don't consume capacity weights
    if pad:
        valid = jnp.arange(n_groups * gs) < t
        gates = jnp.where(valid[:, None], gates, 0.0)

    e = m.num_experts
    cap = _capacity(gs, e, m.top_k, m.capacity_factor)
    gates_g = gates.reshape(n_groups, gs, m.top_k)
    idx_g = idx.reshape(n_groups, gs, m.top_k)

    # position of each (token, choice) within its expert's capacity buffer
    sel = jax.nn.one_hot(idx_g, e, dtype=jnp.float32)         # (g, gs, k, E)
    # priority: earlier tokens (and earlier choices) win capacity
    sel_flat = sel.reshape(n_groups, gs * m.top_k, e)
    pos_in_expert = jnp.cumsum(sel_flat, axis=1) - sel_flat    # (g, gs*k, E)
    pos_in_expert = pos_in_expert.reshape(n_groups, gs, m.top_k, e)
    within_cap = pos_in_expert < cap
    sel = sel * within_cap

    pos_idx = (pos_in_expert * sel).sum(-1).astype(jnp.int32)  # (g, gs, k)
    cap_onehot = jax.nn.one_hot(pos_idx, cap, dtype=jnp.float32)  # (g,gs,k,C)
    # dispatch: (g, gs, E, C)
    dispatch = jnp.einsum("gske,gskc->gsec", sel, cap_onehot)
    combine = jnp.einsum("gske,gskc,gsk->gsec", sel, cap_onehot,
                         gates_g.astype(jnp.float32))

    from repro.sharding.constrain import constrain
    xg = xp.reshape(n_groups, gs, d)
    dispatch = constrain(dispatch.astype(x.dtype),
                         "batch", None, "model", None)
    combine = constrain(combine, "batch", None, "model", None)
    expert_in = constrain(jnp.einsum("gsec,gsd->gecd", dispatch, xg),
                          "batch", "model", None, None)
    w_g, w_u, w_d = (params["experts"][k_] for k_ in ("w_gate", "w_up",
                                                      "w_down"))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, w_g))
    h = h * jnp.einsum("gecd,edf->gecf", expert_in, w_u)
    h = constrain(h, "batch", "model", None, None)
    expert_out = constrain(jnp.einsum("gecf,efd->gecd", h, w_d),
                           "batch", "model", None, None)
    yt = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), expert_out)
    yt = yt.reshape(n_groups * gs, d)[:t]

    if m.num_shared_experts:
        yt = yt + layers.swiglu_apply(params["shared"], xt)

    aux_loss = m.router_aux_weight * e * jnp.sum(
        aux["frac_tokens"] * aux["mean_prob"])
    return yt.reshape(b, s, d), aux_loss
