"""RWKV-6 ("Finch") block: time-mix with data-dependent decay + channel-mix.

The WKV recurrence is the Pallas kernel (repro.kernels.rwkv6_scan); this
module provides the surrounding token-shift interpolation, the decay LoRA
(the data-dependent w_t that distinguishes RWKV-6 from RWKV-4/5), gating,
and the squared-ReLU channel mix. Decode carries (last hidden token per
mix, WKV state) — O(1) in sequence length, which is why rwkv6-7b runs
long_500k natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_scan import wkv
from repro.models import layers

DECAY_LORA = 64


def timemix_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    h = cfg.num_heads
    n = cfg.rwkv_head_dim
    assert h * n == d, f"rwkv heads {h} x head_dim {n} != d_model {d}"
    ks = jax.random.split(key, 10)
    p = {
        # token-shift interpolation weights per stream
        "mu_r": jnp.full((d,), 0.5, dtype),
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_v": jnp.full((d,), 0.5, dtype),
        "mu_w": jnp.full((d,), 0.5, dtype),
        "mu_g": jnp.full((d,), 0.5, dtype),
        "w_r": layers.dense_init(ks[0], d, d, dtype),
        "w_k": layers.dense_init(ks[1], d, d, dtype),
        "w_v": layers.dense_init(ks[2], d, d, dtype),
        "w_g": layers.dense_init(ks[3], d, d, dtype),
        "w_o": layers.dense_init(ks[4], d, d, dtype),
        # data-dependent decay LoRA: w_t = exp(-exp(w0 + tanh(x A) B))
        "decay_w0": jnp.full((d,), -6.0, jnp.float32),
        "decay_A": layers.dense_init(ks[5], d, DECAY_LORA, dtype),
        "decay_B": layers.dense_init(ks[6], DECAY_LORA, d, dtype,
                                     scale=0.01),
        "bonus_u": (jax.random.normal(ks[7], (h, n)) * 0.1).astype(jnp.float32),
        "ln_x": layers.rmsnorm_init(d, dtype),   # per-head group norm stand-in
    }
    return p


def channelmix_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    ff = cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_r": jnp.full((d,), 0.5, dtype),
        "w_k": layers.dense_init(ks[0], d, ff, dtype),
        "w_v": layers.dense_init(ks[1], ff, d, dtype),
        "w_r": layers.dense_init(ks[2], d, d, dtype),
    }


def init_rwkv_cache(cfg, batch, dtype=jnp.float32):
    d = cfg.d_model
    h, n = cfg.num_heads, cfg.rwkv_head_dim
    return {
        "tm_last": jnp.zeros((batch, d), dtype),     # token shift (time mix)
        "cm_last": jnp.zeros((batch, d), dtype),     # token shift (chan mix)
        "wkv": jnp.zeros((batch, h, n, n), jnp.float32),
    }


def _shift(x, last=None):
    """token shift: x_{t-1} (zeros or `last` for t=0). x: (b, s, d)."""
    prev = jnp.zeros_like(x[:, :1]) if last is None else last[:, None, :]
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _lerp(x, x_prev, mu):
    return x + (x_prev - x) * mu


def _decay(params, xw):
    logw = params["decay_w0"] + jnp.tanh(
        xw.astype(jnp.float32) @ params["decay_A"].astype(jnp.float32)
    ) @ params["decay_B"].astype(jnp.float32)
    return jnp.exp(-jnp.exp(logw))          # in (0, 1)


def timemix_apply(params, cfg, x, *, last=None, state=None):
    """x: (b, s, d) -> (y, (new_last, new_state))."""
    b, s, d = x.shape
    h, n = cfg.num_heads, cfg.rwkv_head_dim
    xs = _shift(x, last)
    r = _lerp(x, xs, params["mu_r"]) @ params["w_r"]
    k = _lerp(x, xs, params["mu_k"]) @ params["w_k"]
    v = _lerp(x, xs, params["mu_v"]) @ params["w_v"]
    g = _lerp(x, xs, params["mu_g"]) @ params["w_g"]
    w = _decay(params, _lerp(x, xs, params["mu_w"]))         # (b, s, d)

    rh = r.reshape(b, s, h, n)
    kh = k.reshape(b, s, h, n)
    vh = v.reshape(b, s, h, n)
    wh = w.reshape(b, s, h, n)
    out, new_state = wkv(rh, kh, vh, wh, params["bonus_u"], state)
    out = out.reshape(b, s, d)
    out = layers.rmsnorm_apply(params["ln_x"], out, cfg.norm_eps)
    out = out * jax.nn.silu(g)
    y = out @ params["w_o"]
    return y, (x[:, -1, :], new_state)


def channelmix_apply(params, cfg, x, *, last=None):
    """x: (b, s, d) -> (y, new_last)."""
    xs = _shift(x, last)
    k = _lerp(x, xs, params["mu_k"]) @ params["w_k"]
    k = jnp.square(jax.nn.relu(k))
    kv = k @ params["w_v"]
    r = jax.nn.sigmoid(_lerp(x, xs, params["mu_r"]) @ params["w_r"])
    return r * kv, x[:, -1, :]
