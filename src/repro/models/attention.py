"""GQA attention layer with KV cache, qk-norm, QKV bias, RoPE/M-RoPE, SWA.

The attention math itself is delegated to ``repro.kernels.flash_attention``
(Pallas on TPU, blocked-jnp elsewhere).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import attention
from repro.models import layers


def attn_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.dense_init(ks[0], d, hq * hd, dtype),
        "wk": layers.dense_init(ks[1], d, hkv * hd, dtype),
        "wv": layers.dense_init(ks[2], d, hkv * hd, dtype),
        "wo": layers.dense_init(ks[3], hq * hd, d, dtype),
    }
    if cfg.use_qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    if cfg.use_qk_norm:
        p["q_norm"] = layers.rmsnorm_init(hd, dtype)
        p["k_norm"] = layers.rmsnorm_init(hd, dtype)
    return p


def init_kv_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, hd), dtype),
    }


def _project_qkv(params, cfg, x, positions, mrope_positions=None):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.use_qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    from repro.sharding.constrain import constrain
    q = constrain(q.reshape(b, s, cfg.num_heads, hd),
                  "batch", None, "model", None)
    k = constrain(k.reshape(b, s, cfg.num_kv_heads, hd),
                  "batch", None, "model", None)
    v = constrain(v.reshape(b, s, cfg.num_kv_heads, hd),
                  "batch", None, "model", None)
    if cfg.use_qk_norm:
        q = layers.rmsnorm_apply(params["q_norm"], q, cfg.norm_eps)
        k = layers.rmsnorm_apply(params["k_norm"], k, cfg.norm_eps)
    if cfg.use_mrope and mrope_positions is not None:
        q = layers.apply_mrope(q, mrope_positions, cfg.rope_theta)
        k = layers.apply_mrope(k, mrope_positions, cfg.rope_theta)
    elif positions is not None:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_apply(params, cfg, x, *, positions=None, mrope_positions=None,
               window=None, causal=True):
    """Full-sequence attention (train / prefill). x: (b, s, d)."""
    b, s, _ = x.shape
    if positions is None and not cfg.use_mrope:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    q, k, v = _project_qkv(params, cfg, x, positions, mrope_positions)
    w = cfg.sliding_window if window is None else window
    out = attention(q, k, v, causal=causal, window=w, q_offset=0)
    return out.reshape(b, s, -1) @ params["wo"]


def attn_prefill(params, cfg, x, *, positions=None, mrope_positions=None,
                 window=None, cache=None):
    """Like attn_apply but also writes K/V into the cache at [0:s]."""
    b, s, _ = x.shape
    if positions is None and not cfg.use_mrope:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    q, k, v = _project_qkv(params, cfg, x, positions, mrope_positions)
    w = cfg.sliding_window if window is None else window
    out = attention(q, k, v, causal=True, window=w, q_offset=0)
    new_cache = None
    if cache is not None:
        new_cache = {
            "k": jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
        }
    return out.reshape(b, s, -1) @ params["wo"], new_cache


def attn_decode(params, cfg, x, cache, pos, *, mrope_positions=None,
                window=None):
    """Single-token decode. x: (b, 1, d); pos: scalar int32 (cache length).

    Returns (y: (b, 1, d), new_cache).
    """
    b = x.shape[0]
    positions = jnp.broadcast_to(pos[None, None] if jnp.ndim(pos) == 0
                                 else pos[:, None], (b, 1))
    q, k, v = _project_qkv(params, cfg, x, positions, mrope_positions)
    new_cache = {
        "k": jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0)),
        "v": jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0)),
    }
    w = cfg.sliding_window if window is None else window
    # q_offset = pos: causal mask admits cache slots [0..pos] and excludes
    # the not-yet-written zeros beyond pos.
    out = attention(q, new_cache["k"], new_cache["v"], causal=True,
                    window=w, q_offset=pos)
    return out.reshape(b, 1, -1) @ params["wo"], new_cache
