"""Public model API: init / forward / loss / prefill / decode + input specs.

Batch conventions (all archs):
  * plain LM (dense/moe/ssm/hybrid):
      train/prefill: {"tokens": (b,s) i32, "targets": (b,s) i32}
      decode:        {"tokens": (b,1) i32}
  * vlm (qwen2-vl; vision frontend stubbed):
      train/prefill: {"embeds": (b,s,d), "mrope_positions": (b,s,3) i32,
                      "targets": (b,s) i32}
      decode:        {"tokens": (b,1) i32, "mrope_positions": (b,1,3) i32}
  * audio enc-dec (whisper; conv/mel frontend stubbed):
      train/prefill: {"enc_frames": (b,enc_seq,d), "tokens": (b,s) i32,
                      "targets": (b,s) i32}
      decode:        {"tokens": (b,1) i32}
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers, transformer


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def padded_vocab(cfg: ModelConfig) -> int:
    """Vocab rounded up so the `model` mesh axis divides it (production
    trick): whisper's 51865 would otherwise leave the (b, s, V) f32 logits
    FULLY REPLICATED on every device (13.6 GB each at train_4k scale plus
    a 31 GB softmax chain — measured, EXPERIMENTS.md §Perf). Pad rows are
    masked to -inf in `_logits_out`, so losses/sampling are unchanged."""
    v = cfg.vocab_size
    return v if v % 16 == 0 else -(-v // 128) * 128


def init_params(key, cfg: ModelConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    pv = padded_vocab(cfg)
    p = {
        "embed": layers.embed_init(ks[0], pv, cfg.d_model, dtype),
        "blocks": transformer.stack_init(ks[1], cfg, dtype),
        "final_norm": layers.norm_init(cfg, dtype=dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = layers.dense_init(ks[2], cfg.d_model, pv, dtype)
    if cfg.is_encoder_decoder:
        p["encoder"] = transformer.encoder_init(ks[3], cfg, dtype)
    return p


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    return {"layers": transformer.stack_cache(cfg, batch, max_len, dtype)}


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _embed_in(params, cfg, batch):
    if "embeds" in batch:
        x = batch["embeds"]
    else:
        x = params["embed"][batch["tokens"]]
    return x


def _logits_out(params, cfg, x):
    from repro.sharding.constrain import constrain
    x = layers.norm_apply(cfg, params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = constrain((x @ head).astype(jnp.float32),
                       "batch", None, "model")
    pv = head.shape[-1]
    if pv != cfg.vocab_size:
        # vocab-padding rows never win an argmax / contribute to softmax
        pad_mask = jax.lax.broadcasted_iota(
            jnp.int32, (pv,), 0) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    return logits


def forward(params, cfg: ModelConfig, batch, *, remat: bool = False):
    """Full-sequence forward -> (logits (b,s,V) f32, aux_loss)."""
    x = _embed_in(params, cfg, batch)
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = transformer.encoder_apply(params["encoder"], cfg,
                                            batch["enc_frames"])
    x, _, aux = transformer.stack_apply(
        params["blocks"], cfg, x, mode="full",
        mrope_positions=batch.get("mrope_positions"), enc_out=enc_out,
        remat=remat)
    return _logits_out(params, cfg, x), aux


def loss_fn(params, cfg: ModelConfig, batch, *, remat: bool = False):
    """Mean next-token CE + MoE aux. Targets of -100 are masked."""
    logits, aux = forward(params, cfg, batch, remat=remat)
    targets = batch["targets"]
    mask = (targets >= 0).astype(jnp.float32)
    tgt = jnp.maximum(targets, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    ce = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return ce + aux


def prefill(params, cfg: ModelConfig, batch, cache, *, last_only=False):
    """Forward + populate cache. Returns (logits, new_cache).

    last_only=True computes logits for the final position only (serving:
    avoids the (b, s, V) matmul at 32k prefill)."""
    x = _embed_in(params, cfg, batch)
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = transformer.encoder_apply(params["encoder"], cfg,
                                            batch["enc_frames"])
    x, new_layers, aux = transformer.stack_apply(
        params["blocks"], cfg, x, mode="full", cache=cache["layers"],
        mrope_positions=batch.get("mrope_positions"), enc_out=enc_out)
    if last_only:
        x = x[:, -1:, :]
    return _logits_out(params, cfg, x), {"layers": new_layers}


def decode_step(params, cfg: ModelConfig, cache, batch, pos):
    """One-token decode. batch: {"tokens": (b,1), ...}; pos: scalar i32.

    Returns (logits (b,1,V) f32, new_cache).
    """
    x = _embed_in(params, cfg, batch)
    x, new_layers, _ = transformer.stack_apply(
        params["blocks"], cfg, x, mode="decode", cache=cache["layers"],
        pos=pos, mrope_positions=batch.get("mrope_positions"))
    return _logits_out(params, cfg, x), {"layers": new_layers}


# ---------------------------------------------------------------------------
# ShapeDtypeStruct input specs for dry-runs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, *, batch: int, seq_len: int, kind: str,
                act_dtype=jnp.bfloat16):
    """Stand-in inputs (no allocation) for (arch x input-shape) lowering."""
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if kind in ("train", "prefill"):
        s = seq_len
        spec = {}
        if cfg.family == "vlm":
            spec["embeds"] = sds((batch, s, cfg.d_model), act_dtype)
            spec["mrope_positions"] = sds((batch, s, 3), i32)
        elif cfg.is_encoder_decoder:
            spec["enc_frames"] = sds((batch, cfg.encoder_seq_len,
                                      cfg.d_model), act_dtype)
            spec["tokens"] = sds((batch, s), i32)
        else:
            spec["tokens"] = sds((batch, s), i32)
        if kind == "train":
            spec["targets"] = sds((batch, s), i32)
        return spec
    if kind == "decode":
        spec = {"tokens": sds((batch, 1), i32)}
        if cfg.family == "vlm":
            spec["mrope_positions"] = sds((batch, 1, 3), i32)
        return spec
    raise ValueError(kind)


def param_specs(cfg: ModelConfig, dtype=jnp.bfloat16):
    """abstract param tree via eval_shape (no allocation)."""
    return jax.eval_shape(
        functools.partial(init_params, cfg=cfg, dtype=dtype),
        jax.random.PRNGKey(0))


def cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16):
    return jax.eval_shape(
        functools.partial(init_cache, cfg, batch, max_len, dtype=dtype))
