from repro.models import (attention, layers, mamba, model, moe,
                          paper_models, rwkv, transformer)

__all__ = ["attention", "layers", "mamba", "model", "moe", "paper_models",
           "rwkv", "transformer"]
