"""The paper's own learning models: MCLR, 2-layer CNN, 2-hidden-layer DNN.

MCLR (multinomial logistic regression with l2) is the strongly-convex model
of Theorem 1 — its loss is (l2_reg)-strongly convex and smooth, so the
linear-rate validation tests run against it. The CNN/DNN cover Theorem 2's
smooth non-convex setting, matching §4 of the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import PaperModelConfig


def init_params(key, cfg: PaperModelConfig, dtype=jnp.float32):
    if cfg.kind == "mclr":
        d = int(jnp.prod(jnp.array(cfg.input_shape)))
        return {"w": jnp.zeros((d, cfg.num_classes), dtype),
                "b": jnp.zeros((cfg.num_classes,), dtype)}
    if cfg.kind == "dnn":
        dims = [int(jnp.prod(jnp.array(cfg.input_shape)))] + \
            list(cfg.hidden) + [cfg.num_classes]
        ks = jax.random.split(key, len(dims) - 1)
        return {f"layer{i}": {
            "w": (jax.random.normal(ks[i], (dims[i], dims[i + 1])) *
                  jnp.sqrt(2.0 / dims[i])).astype(dtype),
            "b": jnp.zeros((dims[i + 1],), dtype)}
            for i in range(len(dims) - 1)}
    if cfg.kind == "cnn":
        h, w, c_in = cfg.input_shape
        chans = [c_in] + list(cfg.conv_channels)
        ks = jax.random.split(key, len(chans) + 1)
        p = {}
        for i in range(len(chans) - 1):
            fan_in = 9 * chans[i]
            p[f"conv{i}"] = {
                "w": (jax.random.normal(ks[i], (3, 3, chans[i], chans[i + 1]))
                      * jnp.sqrt(2.0 / fan_in)).astype(dtype),
                "b": jnp.zeros((chans[i + 1],), dtype)}
        # two 2x2 maxpools -> spatial /4
        flat = (h // 4) * (w // 4) * chans[-1]
        dims = [flat] + list(cfg.hidden) + [cfg.num_classes]
        for i in range(len(dims) - 1):
            p[f"dense{i}"] = {
                "w": (jax.random.normal(ks[len(chans) + i - 1],
                                        (dims[i], dims[i + 1])) *
                      jnp.sqrt(2.0 / dims[i])).astype(dtype),
                "b": jnp.zeros((dims[i + 1],), dtype)}
        return p
    raise ValueError(cfg.kind)


def _maxpool2(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def apply(params, cfg: PaperModelConfig, x):
    """x: (b, *input_shape) -> logits (b, num_classes)."""
    if cfg.kind == "mclr":
        xf = x.reshape(x.shape[0], -1)
        return xf @ params["w"] + params["b"]
    if cfg.kind == "dnn":
        h = x.reshape(x.shape[0], -1)
        n = len(params)
        for i in range(n):
            h = h @ params[f"layer{i}"]["w"] + params[f"layer{i}"]["b"]
            if i < n - 1:
                h = jax.nn.relu(h)
        return h
    if cfg.kind == "cnn":
        h = x
        i = 0
        while f"conv{i}" in params:
            # 3x3 SAME conv as im2col + matmul: XLA-CPU's conv emitter is
            # ~100x slower than its GEMM under the stacked-FL double vmap,
            # and on TPU the matmul form feeds the MXU directly.
            w = params[f"conv{i}"]["w"]                  # (3, 3, cin, cout)
            hp = jnp.pad(h, ((0, 0), (1, 1), (1, 1), (0, 0)))
            bsz, hh, ww = h.shape[0], h.shape[1], h.shape[2]
            patches = jnp.concatenate(
                [hp[:, dy:dy + hh, dx:dx + ww, :]
                 for dy in range(3) for dx in range(3)], axis=-1)
            h = patches @ w.reshape(9 * w.shape[2], w.shape[3])
            h = jax.nn.relu(h + params[f"conv{i}"]["b"])
            h = _maxpool2(h)
            i += 1
        h = h.reshape(h.shape[0], -1)
        j = 0
        while f"dense{j}" in params:
            h = h @ params[f"dense{j}"]["w"] + params[f"dense{j}"]["b"]
            if f"dense{j + 1}" in params:
                h = jax.nn.relu(h)
            j += 1
        return h
    raise ValueError(cfg.kind)


def loss_fn(params, cfg: PaperModelConfig, batch):
    """Mean CE (+ l2 for the strongly-convex MCLR)."""
    logits = apply(params, cfg, batch["x"])
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["y"][:, None], axis=-1).mean()
    if cfg.l2_reg > 0.0:
        sq = sum(jnp.vdot(a, a) for a in jax.tree.leaves(params))
        nll = nll + 0.5 * cfg.l2_reg * sq
    return nll


def accuracy(params, cfg: PaperModelConfig, batch):
    logits = apply(params, cfg, batch["x"])
    return (jnp.argmax(logits, -1) == batch["y"]).mean()
