"""Tiered communication subsystem: what crosses the WAN/LAN links, how it
is compressed, and what it costs (DESIGN.md §3)."""
from repro.comm.compressors import compress_tree, leaf_k, make_leaf_compressor
from repro.comm.config import (COMPRESSORS, CommConfig, CommState,
                               init_comm_state)
from repro.comm.ledger import (CommLedger, RoundBytes, compressed_leaf_bytes,
                               downlink_uplink_bytes, full_leaf_bytes,
                               model_bytes)

__all__ = ["CommConfig", "CommState", "CommLedger", "RoundBytes",
           "COMPRESSORS", "init_comm_state", "compress_tree",
           "make_leaf_compressor", "leaf_k", "compressed_leaf_bytes",
           "downlink_uplink_bytes", "full_leaf_bytes", "model_bytes"]
