"""Tiered communication subsystem: what crosses the WAN/LAN links, how it
is compressed, and what it costs (DESIGN.md §3). Compression routes
through the fused Pallas stack in ``repro.kernels.compress`` (DESIGN.md
§10); ``compress_tree_ef`` is the fused error-feedback entrypoint."""
from repro.comm.compressors import (LeafPlan, compress_tree,
                                    compress_tree_ef, compression_plan,
                                    leaf_k, leaf_plan, make_leaf_compressor,
                                    make_leaf_ef_compressor)
from repro.comm.config import (COMPRESSORS, CommConfig, CommState,
                               init_comm_state)
from repro.comm.ledger import (CommLedger, RoundBytes, compressed_leaf_bytes,
                               downlink_uplink_bytes, full_leaf_bytes,
                               model_bytes)

__all__ = ["CommConfig", "CommState", "CommLedger", "RoundBytes",
           "COMPRESSORS", "init_comm_state", "compress_tree",
           "compress_tree_ef", "make_leaf_compressor",
           "make_leaf_ef_compressor", "LeafPlan", "leaf_plan",
           "compression_plan", "leaf_k", "compressed_leaf_bytes",
           "downlink_uplink_bytes", "full_leaf_bytes", "model_bytes"]
