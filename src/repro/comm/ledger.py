"""Per-tier, per-round byte accounting for the PerMFL hierarchy.

Two links, four directions per global round t (DESIGN.md §3):

  WAN  server -> team   x broadcast, once per round, fp32
  WAN  team -> server   compressed w delta, once per round
  LAN  team -> device   w broadcast, once per team iteration (K per round),
                        fp32
  LAN  device -> team   compressed theta delta, once per team iteration

Only *participating* teams/devices move bytes, so ``log_round`` takes the
realized mask counts — and a device only transmits when its *team* also
participates (``ef_gate`` in ``permfl_round``), so device counts must be
computed from the gated mask ``device_mask * team_mask[:, None]``
(``log_round_masks`` does this; the engine's scan outputs are pre-gated).
Wire sizes are static functions of the compressor
config and the leaf shapes — the ledger runs entirely on the host, outside
jit, and costs nothing on the hot path.

Wire-format byte model per leaf of p elements:

  identity  4p
  topk      8k            (4B value + 4B index, k = leaf_k(k_frac, p))
  randk     4k + 4        (shared seed reconstructs the indices)
  int8      p + 4*ceil(p/128)   (packed int8 + one f32 scale per 128-row)
  sign      ceil(p/8) + 4       (bit-packed signs + one f32 scale)
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

import jax
import numpy as np

from repro.comm.config import CommConfig
from repro.comm.compressors import leaf_k


def full_leaf_bytes(p: int) -> int:
    """Wire bytes of one fp32 leaf of p elements."""
    return 4 * p


def compressed_leaf_bytes(cfg: CommConfig, p: int) -> int:
    """Wire bytes of one compressed leaf of p elements (see the wire-format
    byte model in the module docstring)."""
    name = cfg.compressor
    if name == "identity":
        return 4 * p
    if name == "topk":
        return 8 * leaf_k(cfg.k_frac, p)
    if name == "randk":
        return 4 * leaf_k(cfg.k_frac, p) + 4
    if name == "int8":
        return p + 4 * math.ceil(p / 128)
    if name == "sign":
        return math.ceil(p / 8) + 4
    raise ValueError(name)


def model_bytes(leaf_sizes, cfg: Optional[CommConfig] = None) -> int:
    """Wire size of one model/delta; cfg=None means full fp32."""
    if cfg is None:
        return sum(full_leaf_bytes(p) for p in leaf_sizes)
    return sum(compressed_leaf_bytes(cfg, p) for p in leaf_sizes)


def downlink_uplink_bytes(leaf_sizes, cfg: Optional[CommConfig] = None):
    """(downlink, uplink) wire bytes of one model/delta: downlinks always
    carry fp32 anchors, uplinks carry the compressed delta (cfg=None means
    uncompressed both ways). The pairing the wall-clock system simulator
    (`repro.system`) prices links with."""
    return model_bytes(leaf_sizes), model_bytes(leaf_sizes, cfg)


@dataclass
class RoundBytes:
    """One global round's traffic, bytes per link-direction."""
    wan_up: int = 0
    wan_down: int = 0
    lan_up: int = 0
    lan_down: int = 0

    @property
    def total(self) -> int:
        return self.wan_up + self.wan_down + self.lan_up + self.lan_down


@dataclass
class CommLedger:
    """Accumulates RoundBytes; built by run_permfl when comm is enabled."""
    cfg: CommConfig
    leaf_sizes: tuple
    rounds: list = field(default_factory=list)

    @classmethod
    def for_params(cls, cfg: CommConfig, params) -> "CommLedger":
        """Ledger sized from an (unstacked) model pytree's leaf shapes."""
        sizes = tuple(int(np.prod(l.shape, dtype=np.int64))
                      for l in jax.tree.leaves(params))
        return cls(cfg=cfg, leaf_sizes=sizes)

    def log_round(self, *, k_team: int, n_teams: int, n_devices: int):
        """n_teams / n_devices: participating counts this round; n_devices
        must already be gated by team participation (see module docstring,
        or use log_round_masks)."""
        full = model_bytes(self.leaf_sizes)
        comp = model_bytes(self.leaf_sizes, self.cfg)
        self.rounds.append(RoundBytes(
            wan_up=n_teams * comp,
            wan_down=n_teams * full,
            lan_up=k_team * n_devices * comp,
            lan_down=k_team * n_devices * full))

    def log_round_masks(self, *, k_team: int, team_mask, device_mask):
        """log_round from raw participation masks: devices of masked-out
        teams never transmit (nor receive), whatever device_mask says."""
        tm = np.asarray(team_mask)
        gated = np.asarray(device_mask) * tm[:, None]
        self.log_round(k_team=k_team, n_teams=int(tm.sum()),
                       n_devices=int(gated.sum()))

    # -- aggregates ---------------------------------------------------------

    def totals(self) -> RoundBytes:
        """Sum of all logged rounds, per link-direction."""
        out = RoundBytes()
        for r in self.rounds:
            out.wan_up += r.wan_up
            out.wan_down += r.wan_down
            out.lan_up += r.lan_up
            out.lan_down += r.lan_down
        return out

    def total_bytes(self) -> int:
        """Grand total across links, directions, and rounds."""
        return self.totals().total

    def cum_total_bytes(self) -> np.ndarray:
        """Cumulative grand total after each logged round — the byte
        axis of a bytes-to-accuracy curve (`repro.obs.events` joins it
        against the metric history at the eval points)."""
        return np.cumsum(np.asarray([r.total for r in self.rounds],
                                    dtype=np.int64))

    def uncompressed_total(self) -> int:
        """What the same rounds would have cost shipping fp32 everywhere."""
        full = model_bytes(self.leaf_sizes)
        comp = model_bytes(self.leaf_sizes, self.cfg)
        t = self.totals()
        up_models = (t.wan_up + t.lan_up) // comp if comp else 0
        return t.wan_down + t.lan_down + up_models * full

    def summary(self) -> dict:
        """Flat dict of per-direction totals, compressed-vs-fp32 totals,
        and the uplink compression ratio — benchmark CSV material."""
        t = self.totals()
        return {"compressor": self.cfg.compressor,
                "rounds": len(self.rounds),
                "wan_up_bytes": t.wan_up, "wan_down_bytes": t.wan_down,
                "lan_up_bytes": t.lan_up, "lan_down_bytes": t.lan_down,
                "total_bytes": t.total,
                "uncompressed_bytes": self.uncompressed_total(),
                "uplink_ratio": (model_bytes(self.leaf_sizes)
                                 / max(model_bytes(self.leaf_sizes, self.cfg),
                                       1))}
