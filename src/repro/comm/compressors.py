"""Delta compressors for the tiered uplinks.

Each compressor maps a flat per-sender slice of one pytree leaf to its
decompressed-at-the-receiver value (the simulator never materializes the
wire format except in the int8 path, whose packed (q, scales) pair comes
from the fused Pallas kernel on TPU / its XLA reference elsewhere — see
``repro.kernels.quantize``). Byte costs of the wire formats live in
``repro.comm.ledger``; the error-feedback arithmetic lives in the PerMFL
round itself (``msg = delta + ef; ef' = msg - C(msg)``).

All shapes/k are static at trace time, so everything here jits and vmaps
over the stacked (M, N) sender axes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.config import CommConfig
from repro.kernels.quantize import quantize_int8


def leaf_k(k_frac: float, p: int) -> int:
    """Coordinates kept per leaf by topk/randk (static)."""
    return max(1, min(p, int(round(k_frac * p))))


def _topk(v, k):
    _, idx = jax.lax.top_k(jnp.abs(v), k)
    return jnp.zeros_like(v).at[idx].set(v[idx])


def _randk(key, v, k, unbiased):
    u = jax.random.uniform(key, v.shape)
    _, idx = jax.lax.top_k(u, k)          # k uniform indices, no replacement
    kept = v[idx] * (v.size / k if unbiased else 1.0)
    return jnp.zeros_like(v).at[idx].set(kept)


def _int8(key, v):
    noise = jax.random.uniform(key, v.shape)
    _, _, dq = quantize_int8(v, noise)
    return dq


def _sign(v):
    return jnp.mean(jnp.abs(v)) * jnp.sign(v)


def make_leaf_compressor(cfg: CommConfig, p: int):
    """Returns fn(key, v_flat (p,)) -> v_hat (p,), specialized per leaf."""
    name = cfg.compressor
    if name == "identity":
        return lambda key, v: v
    if name == "topk":
        k = leaf_k(cfg.k_frac, p)
        return lambda key, v: _topk(v, k)
    if name == "randk":
        k = leaf_k(cfg.k_frac, p)
        unbiased = not cfg.error_feedback
        return lambda key, v: _randk(key, v, k, unbiased)
    if name == "int8":
        return _int8
    if name == "sign":
        return lambda key, v: _sign(v)
    raise ValueError(name)


def compress_tree(cfg: CommConfig, key, tree, batch_shape: tuple):
    """Compress each sender's slice of each leaf independently.

    tree leaves have shape batch_shape + param_shape; every (sender, leaf)
    pair gets its own fold_in'd key so stochastic compressors decorrelate
    across the fleet. Returns the decompressed tree, same structure/shapes.
    """
    leaves, treedef = jax.tree.flatten(tree)
    b = int(np.prod(batch_shape, dtype=np.int64)) if batch_shape else 1
    out = []
    for i, leaf in enumerate(leaves):
        p = int(np.prod(leaf.shape[len(batch_shape):], dtype=np.int64))
        fn = make_leaf_compressor(cfg, p)
        keys = jax.random.split(jax.random.fold_in(key, i), b)
        v2 = leaf.reshape(b, p)
        out.append(jax.vmap(fn)(keys, v2).reshape(leaf.shape))
    return treedef.unflatten(out)
