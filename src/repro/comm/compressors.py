"""Delta compressors for the tiered uplinks: thin routers over the fused
Pallas compression stack.

Every compressor maps a flat per-sender slice of one pytree leaf to its
decompressed-at-the-receiver value. The actual select/quantize/pack math
lives in ``repro.kernels.compress`` — fused Pallas kernels with an XLA
reference, dispatched through :func:`repro.kernels.interface.kernel_mode`
— so this module only derives per-leaf plans and PRNG streams and calls
the right op. ``REPRO_COMPRESS_FUSED=0`` falls back to the historical
unfused implementations (bit-identical selections by construction: the
fused select reproduces ``lax.top_k``'s lowest-index tie-breaking, so
even tied magnitudes or colliding float32 uniforms keep the same set;
used by the fused-vs-unfused engine benchmark).

Static per-leaf facts (k, wire-buffer shapes) are computed once per
(CommConfig, leaf size) by the cached :func:`leaf_plan` and reused across
rounds, so no per-round host work remains and all kernel shapes are
static at trace time. Byte costs of the wire formats live in
``repro.comm.ledger``; the error-feedback arithmetic
(``msg = delta + ef; ef' = msg - C(msg)``) is fused into the kernels via
:func:`compress_tree_ef`.

All shapes/k are static at trace time, so everything here jits and vmaps
over the stacked (M, N) sender axes.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.config import CommConfig
from repro.kernels.compress import ops as _cops
from repro.kernels.interface import compress_fused
from repro.kernels.quantize import quantize_int8

LANES = 128


def leaf_k(k_frac: float, p: int) -> int:
    """Coordinates kept per leaf by topk/randk (static)."""
    return max(1, min(p, int(round(k_frac * p))))


@dataclass(frozen=True)
class LeafPlan:
    """Static per-(CommConfig, leaf) compression facts, derived once and
    reused across rounds: the kept-coordinate count ``k`` (top-k/rand-k),
    the padded row count, and the wire-buffer shapes each compressor
    ships (what the byte ledger prices and ``pack_topk`` materializes)."""
    compressor: str
    p: int
    k: Optional[int]
    rows: int
    wire: tuple

    @staticmethod
    def build(cfg: CommConfig, p: int) -> "LeafPlan":
        """Derive the plan for one flat leaf of ``p`` coordinates."""
        rows = -(-p // LANES)
        name = cfg.compressor
        k = leaf_k(cfg.k_frac, p) if name in ("topk", "randk") else None
        wire = {
            "identity": ((("values", (p,), "f32"),)),
            "topk": (("values", (k, ), "f32"), ("indices", (k,), "i32")),
            "randk": (("values", (k,), "f32"), ("seed", (), "u32")),
            "int8": (("q", (p,), "i8"), ("scales", (rows,), "f32")),
            "sign": (("bits", (rows, LANES // 8), "u8"), ("scale", (), "f32")),
        }[name]
        return LeafPlan(name, p, k, rows, wire)


@functools.lru_cache(maxsize=4096)
def leaf_plan(cfg: CommConfig, p: int) -> LeafPlan:
    """Cached :meth:`LeafPlan.build` — the once-per-(config, leaf-size)
    precompute that keeps per-round host work at zero."""
    return LeafPlan.build(cfg, p)


@functools.lru_cache(maxsize=1024)
def compression_plan(cfg: CommConfig, leaf_sizes: tuple) -> tuple:
    """Plans for a whole flattened tree (one entry per leaf), cached per
    (CommConfig, tree-structure sizes)."""
    return tuple(leaf_plan(cfg, p) for p in leaf_sizes)


# --------------------------------------------------- legacy (unfused) path

def _legacy_topk(v, k):
    _, idx = jax.lax.top_k(jnp.abs(v), k)
    return jnp.zeros_like(v).at[idx].set(v[idx])


def _legacy_randk(key, v, k, unbiased):
    u = jax.random.uniform(key, v.shape)
    _, idx = jax.lax.top_k(u, k)          # k uniform indices, no replacement
    kept = v[idx] * (v.size / k if unbiased else 1.0)
    return jnp.zeros_like(v).at[idx].set(kept)


def _legacy_int8(key, v):
    noise = jax.random.uniform(key, v.shape)
    _, _, dq = quantize_int8(v, noise)
    return dq


def _legacy_sign(v):
    return jnp.mean(jnp.abs(v)) * jnp.sign(v)


# ----------------------------------------------------------- fused routers

def make_leaf_compressor(cfg: CommConfig, p: int, *, mode=None):
    """Returns fn(key, v_flat (p,)) -> v_hat (p,), specialized per leaf.

    Routes through the fused ``repro.kernels.compress`` ops (``mode``
    overrides the ``KernelType`` dispatch); ``REPRO_COMPRESS_FUSED=0``
    selects the historical unfused implementations instead.
    """
    name = cfg.compressor
    if name == "identity":
        return lambda key, v: v
    plan = leaf_plan(cfg, p)
    if not compress_fused():
        if name == "topk":
            return lambda key, v: _legacy_topk(v, plan.k)
        if name == "randk":
            unbiased = not cfg.error_feedback
            return lambda key, v: _legacy_randk(key, v, plan.k, unbiased)
        if name == "int8":
            return _legacy_int8
        if name == "sign":
            return lambda key, v: _legacy_sign(v)
    if name == "topk":
        return lambda key, v: _cops.topk_compress(v, plan.k, mode=mode)[0]
    if name == "randk":
        unbiased = not cfg.error_feedback

        def _randk(key, v):
            u = jax.random.uniform(key, v.shape)
            return _cops.randk_compress(u, v, plan.k, unbiased=unbiased,
                                        mode=mode)[0]
        return _randk
    if name == "int8":
        def _int8(key, v):
            noise = jax.random.uniform(key, v.shape)
            return quantize_int8(v, noise, mode=mode)[2]
        return _int8
    if name == "sign":
        return lambda key, v: _cops.sign_compress(v, mode=mode)[2]
    raise ValueError(name)


def make_leaf_ef_compressor(cfg: CommConfig, p: int, *, mode=None):
    """Returns fn(key, delta (p,), ef (p,)) -> (chat (p,), ef_new (p,)),
    the fused error-feedback form: ``msg = delta + ef`` and the residual
    update happen inside one kernel pass (``repro.kernels.compress``).
    The unfused fallback computes ``msg`` first and reuses
    :func:`make_leaf_compressor` — the EF arithmetic is identical.
    """
    name = cfg.compressor
    if name == "identity":
        return lambda key, d, e: (d + e, jnp.zeros_like(d))
    if not compress_fused():
        fn = make_leaf_compressor(cfg, p, mode=mode)

        def _unfused(key, d, e):
            msg = d + e
            chat = fn(key, msg)
            return chat, msg - chat
        return _unfused
    plan = leaf_plan(cfg, p)
    if name == "topk":
        def _topk(key, d, e):
            dq, _, ef_new = _cops.ef_topk_compress(d, e, plan.k, mode=mode)
            return dq, ef_new
        return _topk
    if name == "randk":
        def _randk(key, d, e):
            u = jax.random.uniform(key, d.shape)
            dq, _, ef_new = _cops.ef_randk_compress(u, d, e, plan.k,
                                                    mode=mode)
            return dq, ef_new
        return _randk
    if name == "int8":
        def _int8(key, d, e):
            noise = jax.random.uniform(key, d.shape)
            _, _, dq, ef_new = _cops.ef_quantize_int8(d, e, noise, mode=mode)
            return dq, ef_new
        return _int8
    if name == "sign":
        def _sign(key, d, e):
            _, _, dq, ef_new = _cops.ef_sign_compress(d, e, mode=mode)
            return dq, ef_new
        return _sign
    raise ValueError(name)


def _leaf_keys(key, i: int, b: int):
    """Per-(sender, leaf) PRNG streams: fold the leaf index, split per
    sender. Shared by both tree entrypoints so fused and unfused paths
    draw identical noise."""
    return jax.random.split(jax.random.fold_in(key, i), b)


def compress_tree(cfg: CommConfig, key, tree, batch_shape: tuple):
    """Compress each sender's slice of each leaf independently.

    tree leaves have shape batch_shape + param_shape; every (sender, leaf)
    pair gets its own fold_in'd key so stochastic compressors decorrelate
    across the fleet. Returns the decompressed tree, same structure/shapes.
    """
    leaves, treedef = jax.tree.flatten(tree)
    b = int(np.prod(batch_shape, dtype=np.int64)) if batch_shape else 1
    sizes = tuple(
        int(np.prod(leaf.shape[len(batch_shape):], dtype=np.int64))
        for leaf in leaves)
    compression_plan(cfg, sizes)          # warm the per-leaf plan cache
    out = []
    for i, (leaf, p) in enumerate(zip(leaves, sizes)):
        fn = make_leaf_compressor(cfg, p)
        keys = _leaf_keys(key, i, b)
        v2 = leaf.reshape(b, p)
        out.append(jax.vmap(fn)(keys, v2).reshape(leaf.shape))
    return treedef.unflatten(out)


def compress_tree_ef(cfg: CommConfig, key, delta_tree, ef_tree,
                     batch_shape: tuple):
    """Fused error-feedback compression over a tree pair.

    Equivalent to ``msg = delta + ef; chat = compress(msg);
    ef_new = msg - chat`` but with the EF arithmetic fused into the
    kernels; PRNG streams match :func:`compress_tree` exactly. Returns
    (chat_tree, ef_new_tree), both with the input structure/shapes.
    """
    leaves, treedef = jax.tree.flatten(delta_tree)
    ef_leaves = treedef.flatten_up_to(ef_tree)
    b = int(np.prod(batch_shape, dtype=np.int64)) if batch_shape else 1
    sizes = tuple(
        int(np.prod(leaf.shape[len(batch_shape):], dtype=np.int64))
        for leaf in leaves)
    compression_plan(cfg, sizes)
    chat, ef_new = [], []
    for i, (d, e, p) in enumerate(zip(leaves, ef_leaves, sizes)):
        fn = make_leaf_ef_compressor(cfg, p)
        keys = _leaf_keys(key, i, b)
        c2, e2 = jax.vmap(fn)(keys, d.reshape(b, p), e.reshape(b, p))
        chat.append(c2.reshape(d.shape))
        ef_new.append(e2.reshape(d.shape))
    return treedef.unflatten(chat), treedef.unflatten(ef_new)
