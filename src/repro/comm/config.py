"""Communication config and per-tier error-feedback state.

``CommConfig`` is a frozen (hashable) dataclass so it can ride through
``jax.jit`` as a static argument, exactly like ``PerMFLHParams``.
``CommState`` is the jit-carried pytree of error-feedback residuals: one
buffer per device (theta-shaped, (M, N, ...)) for the device->team LAN
uplink and one per team (w-shaped, (M, ...)) for the team->server WAN
uplink, plus the PRNG key the stochastic compressors fold the round/iter
counters into (DESIGN.md §3).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

COMPRESSORS = ("identity", "topk", "randk", "int8", "sign")


@dataclass(frozen=True)
class CommConfig:
    """What crosses the links, and how it is shrunk.

    compressor: one of COMPRESSORS, applied to the model *deltas* on the
        two uplink aggregation paths (device->team theta deltas inside the
        K-loop, team->server w deltas once per round). Downlinks stay fp32
        — they carry the anchors the algorithm re-initializes from.
    k_frac: fraction of coordinates kept per leaf by topk / randk.
    error_feedback: accumulate the compression residual into the sender's
        buffer and add it to the next message (EF-SGD style). With EF on,
        randk is left unscaled (contractive form); with EF off it is
        rescaled by p/k to stay unbiased.
    seed: base PRNG seed for the stochastic compressors (randk, int8).
    """
    compressor: str = "identity"
    k_frac: float = 0.1
    error_feedback: bool = True
    seed: int = 0

    def __post_init__(self):
        if self.compressor not in COMPRESSORS:
            raise ValueError(
                f"unknown compressor {self.compressor!r}; "
                f"expected one of {COMPRESSORS}")
        if not 0.0 < self.k_frac <= 1.0:
            raise ValueError(f"k_frac must be in (0, 1], got {self.k_frac}")


@jax.tree_util.register_pytree_node_class
@dataclass
class CommState:
    """ef_dev: (M, N, ...) device-uplink residuals; ef_team: (M, ...)
    team-uplink residuals; key: base PRNG key (never advanced in place —
    per-round streams are derived by fold_in on the round counter)."""
    ef_dev: Any
    ef_team: Any
    key: jnp.ndarray

    def tree_flatten(self):
        return (self.ef_dev, self.ef_team, self.key), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_comm_state(params, m_teams: int, n_devices: int,
                    cfg: CommConfig) -> CommState:
    """Zero residuals shaped like the stacked tiers."""
    def zeros(lead):
        return jax.tree.map(
            lambda p: jnp.zeros(lead + p.shape, jnp.float32), params)
    return CommState(ef_dev=zeros((m_teams, n_devices)),
                     ef_team=zeros((m_teams,)),
                     key=jax.random.PRNGKey(cfg.seed))
