"""In-graph probe selection (`TraceConfig`) and its host-side product
(`RunTrace`).

The paper's convergence theory is stated in terms of quantities the
engine never used to surface: the personalization gap ``||theta_ij -
w_i||`` (device vs team model), the tier drift ``||w_i - x||`` (team vs
server model), gradient/update norms, and — under compression — the
error-feedback residual magnitudes. A `TraceConfig` selects which of
these cheap scalar diagnostics an algorithm's ``probe_round`` emits as
extra ``lax.scan`` outputs from the engine's round body; the engine
assembles the per-round streams host-side into a `RunTrace` that sits on
``FLResult.trace`` next to ``comm`` (bytes) and ``timeline`` (seconds).

Probes are pure measurement: with ``trace=None`` (the default) the round
program is byte-for-byte the pre-trace graph, and with probes on the
trajectory is bit-identical — probes only *read* the state
(tests/test_engine.py pins both).

`TraceConfig` is frozen/hashable because compiled programs key on it:
flipping a probe group on is a different program (extra scan outputs),
flipping it back reuses the original.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

__all__ = ["RunTrace", "TraceConfig", "eval_points"]


def eval_points(rounds: int, eval_every: int) -> list:
    """1-based round indices at which the engine evaluates: every
    `eval_every` rounds plus the final round. The engine, the sweep, and
    the event log all align metric histories on these points."""
    n_chunks, rem = divmod(rounds, eval_every)
    return [eval_every * (k + 1) for k in range(n_chunks)] \
        + ([rounds] if rem else [])


@dataclass(frozen=True)
class TraceConfig:
    """Which in-graph diagnostics to emit, plus the profiling hooks.

    Probe groups (each adds scalar ``lax.scan`` outputs per round):

    drift: personalization gap ``||theta_ij - w_i||`` (mean/max over
        participating devices) and tier drift ``||w_i - x||`` (mean/max
        over participating teams) — the residuals Theorems 1-2 bound.
    grads: whole-state update norm, and the post-round gradient norm of
        the device objective (one extra grad evaluation per round —
        ~1/(K*L) of the round's grad work).
    residuals: per-tier error-feedback residual norms (device and team
        senders), when the algorithm runs compressed uplinks.
    loss: participation-weighted train loss of the personalized models
        (only devices whose team also participated contribute).

    Health monitors (`repro.obs.health` — same off-⇒-byte-identical
    contract as the probe groups):

    health: emit the algorithm's ``health_round`` detectors (nonfinite
        param/update counts, loss-explosion flag) as extra scan outputs,
        assembled into ``FLResult.health``.
    fail_fast: raise `repro.obs.health.HealthError` host-side naming the
        first bad round as soon as a dispatched chunk's detectors fire
        (requires ``health``; no effect on the compiled program).
    health_loss_max: participation-weighted train loss above this
        threshold trips the loss-explosion detector.

    Host-side hooks (no effect on the compiled round program):

    cost_analysis: capture XLA's ``Compiled.cost_analysis()`` (flops /
        bytes accessed per dispatch) onto ``RunTrace.cost``.
    profile_dir: when set, wrap the experiment's dispatches in a
        ``jax.profiler.trace`` context writing to this directory.
    """
    drift: bool = True
    grads: bool = True
    residuals: bool = True
    loss: bool = True
    health: bool = True
    fail_fast: bool = False
    health_loss_max: float = 1e6
    cost_analysis: bool = False
    profile_dir: Optional[str] = None


@dataclass
class RunTrace:
    """Host-side per-round probe streams for one experiment.

    config: the `TraceConfig` that selected the probes.
    series: probe name -> per-round list of floats (one entry per global
        round, aligned with ``FLResult.participation``).
    cost: normalized ``cost_analysis()`` summary of the compiled round
        program (flops / bytes accessed), when the config asked for it.
    """
    config: TraceConfig
    series: dict = field(default_factory=dict)
    cost: Optional[dict] = None

    def __len__(self):
        return max((len(v) for v in self.series.values()), default=0)

    def names(self) -> list:
        """Probe names present in this trace, sorted."""
        return sorted(self.series)

    def __getitem__(self, name: str) -> list:
        return self.series[name]

    def last(self, name: str) -> float:
        """Final-round value of one probe (NaN when the stream is empty)."""
        s = self.series.get(name, [])
        return float(s[-1]) if s else float("nan")

    def at_points(self, points) -> list:
        """Per-eval-segment probe summaries: for each 1-based round index
        in `points`, the mean of every series over the rounds since the
        previous point — the join key the JSONL eval events use."""
        out, lo = [], 0
        for p in points:
            seg = {}
            for k, v in self.series.items():
                window = np.asarray(v[lo:p], dtype=np.float64)
                seg[k] = float(window.mean()) if window.size else float("nan")
            out.append(seg)
            lo = p
        return out

    def summary(self) -> dict:
        """Per-probe {mean, max, last} over the whole run — run-footer
        material."""
        out = {}
        for k, v in self.series.items():
            a = np.asarray(v, dtype=np.float64)
            if a.size:
                out[k] = {"mean": float(a.mean()), "max": float(a.max()),
                          "last": float(a[-1])}
        return out
