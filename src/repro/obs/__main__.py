"""CLI for the run-telemetry layer.

    PYTHONPATH=src python -m repro.obs summarize PATH [PATH2]
    PYTHONPATH=src python -m repro.obs report DIR
    PYTHONPATH=src python -m repro.obs regress BASELINE CURRENT [--tol T]

``summarize PATH`` reads a JSONL trace (one file, or every ``*.jsonl``
in a directory) and renders each run: header identity, the eval-point
table joining metrics x bytes x simulated seconds x probe summaries, and
the footer cost split — plus, when the directory holds span trace
files, the wall-clock span breakdown. With two paths it also diffs the
final runs of each (metric deltas, wall/bytes deltas). ``report DIR``
renders the full joined picture — events × spans × metrics × health
(see `repro.obs.report`). ``regress`` is the CI perf gate (see
`repro.obs.regress`).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from repro.obs import events as E
from repro.obs import regress as R
from repro.obs import report as REP


def _fmt_run(run: list) -> None:
    s = E.summarize_run(run)
    who = s["run"]
    if s.get("scenario"):
        who += f"  [{s['scenario']} @{s.get('spec_hash')}]"
    print(f"run {who}  algo={s.get('algo')}  rounds={s.get('rounds')}  "
          f"evals={s['evals']}")
    evals = [e for e in run if e.get("event") == "eval"]
    if evals:
        probe_names = sorted(evals[-1].get("probes", {}))[:3]
        head = f"  {'round':>6} " + "".join(
            f"{m:>11}" for m in sorted(evals[-1].get("metrics", {})))
        head += f" {'MB':>9} {'sim_s':>9}"
        head += "".join(f" {p[:14]:>15}" for p in probe_names)
        print(head)
        for e in evals:
            row = f"  {e['round']:>6} " + "".join(
                f"{v:>11.4f}" for _, v in sorted(e["metrics"].items()))
            row += (f" {e['cum_bytes'] / 1e6:>9.2f}"
                    if "cum_bytes" in e else f" {'-':>9}")
            row += (f" {e['sim_seconds']:>9.2f}"
                    if "sim_seconds" in e else f" {'-':>9}")
            for p in probe_names:
                v = e.get("probes", {}).get(p)
                row += (f" {v:>15.4e}" if v is not None else f" {'-':>15}")
            print(row)
    cost = f", {s['cost'].get('flops', 0):.3g} flops/dispatch" \
        if s.get("cost") else ""
    print(f"  footer: {s.get('seconds', 0):.2f}s "
          f"(compile {s.get('compile_seconds', 0):.2f}s), "
          f"{s.get('dispatches')} dispatch(es){cost}")


def _print_spans(path) -> None:
    p = pathlib.Path(path)
    if not p.is_dir():
        return
    traces = []
    for f in sorted(p.glob("spans-*.trace.json")):
        try:
            traces.append(json.loads(f.read_text()))
        except (json.JSONDecodeError, OSError):
            continue
    lines = REP.format_spans(traces)
    if lines:
        print(f"spans ({len(traces)} trace file(s)):")
        for line in lines:
            print(line)


def _cmd_summarize(args) -> int:
    records = E.read_jsonl(args.path)
    runs = E.split_runs([r for r in records if "event" in r])
    if not runs:
        print(f"no run events under {args.path}")
        return 1
    for run in runs:
        _fmt_run(run)
    _print_spans(args.path)
    if args.path2:
        other = E.split_runs([r for r in E.read_jsonl(args.path2)
                              if "event" in r])
        if not other:
            print(f"no run events under {args.path2}")
            return 1
        a = E.summarize_run(runs[-1])
        b = E.summarize_run(other[-1])
        print(f"\ndiff {a['run']} -> {b['run']} (b - a):")
        delta = E.diff_summaries(a, b)
        if not delta:
            print("  no shared numeric fields")
        for k, v in sorted(delta.items()):
            print(f"  {k:>24}: {v:+.6g}")
    return 0


def _cmd_report(args) -> int:
    print(REP.report_text(args.path), end="")
    art = REP.load_artifacts(args.path)
    if not (art["runs"] or art["spans"] or art["metrics"]):
        print(f"no observability artifacts under {args.path}")
        return 1
    return 0


def main(argv=None) -> int:
    """Entry point: dispatch summarize / regress."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Read, render, and gate run-telemetry artifacts.")
    sub = ap.add_subparsers(dest="cmd", required=True)
    p = sub.add_parser("summarize",
                       help="render a JSONL run trace (or diff two)")
    p.add_argument("path", help="trace file or directory")
    p.add_argument("path2", nargs="?", default=None,
                   help="second trace to diff against")
    p.set_defaults(fn=_cmd_summarize)
    p = sub.add_parser("report",
                       help="joined events x spans x metrics x health")
    p.add_argument("path", help="trace directory")
    p.set_defaults(fn=_cmd_report)
    p = sub.add_parser("regress",
                       help="gate BENCH_engine.json against a baseline")
    p.add_argument("baseline")
    p.add_argument("current")
    p.add_argument("--tol", type=float, default=R.DEFAULT_TOL)
    args = ap.parse_args(argv)
    if args.cmd == "regress":
        return R.main([args.baseline, args.current, "--tol",
                       str(args.tol)])
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
