"""Profiling hooks: XLA cost analysis and `jax.profiler` trace contexts.

Both are host-side and opt-in via `TraceConfig` — they never alter the
compiled round program. ``compiled_cost`` answers "what does one dispatch
of this experiment cost in flops/bytes" (the static complement to the
benchmark's measured rounds/sec); ``profile_ctx`` wraps the dispatches in
a TensorBoard-readable trace when a directory is configured.
"""
from __future__ import annotations

import contextlib
from typing import Optional

__all__ = ["compiled_cost", "profile_ctx"]

# cost_analysis key -> normalized name (XLA uses spaces in some keys)
_COST_KEYS = {"flops": "flops", "bytes accessed": "bytes_accessed",
              "transcendentals": "transcendentals",
              "optimal_seconds": "optimal_seconds"}


def profile_ctx(trace):
    """``jax.profiler.trace`` context for ``trace.profile_dir`` when set;
    otherwise a no-op context manager."""
    if trace is not None and getattr(trace, "profile_dir", None):
        import jax
        return jax.profiler.trace(trace.profile_dir)
    return contextlib.nullcontext()


def compiled_cost(jitfn, *args, **kwargs) -> Optional[dict]:
    """Lower + compile ``jitfn(*args, **kwargs)`` and return a normalized
    ``cost_analysis()`` summary ({flops, bytes_accessed, ...}), or None
    when the backend doesn't expose one. Shapes are what matter — passing
    the live operands of a dispatch that already ran reuses their avals.
    """
    try:
        analysis = jitfn.lower(*args, **kwargs).compile().cost_analysis()
    except Exception:           # backend without cost analysis support
        return None
    if isinstance(analysis, (list, tuple)):
        analysis = analysis[0] if analysis else None
    if not isinstance(analysis, dict):
        return None
    out = {norm: float(analysis[k]) for k, norm in _COST_KEYS.items()
           if isinstance(analysis.get(k), (int, float))}
    return out or None
