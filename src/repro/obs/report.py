"""Joined run report: events × spans × metrics × health from one dir.

A traced run leaves up to four artifact kinds in its ``--trace-dir``:
the JSONL run-event stream (`repro.obs.events`), Chrome-trace span files
(`repro.obs.spans`, ``spans-*.trace.json``), metrics snapshots
(`repro.obs.metrics`, ``metrics-*.jsonl``), and the health section each
run footer now carries. ``python -m repro.obs report DIR`` — backed by
:func:`report_text` here — renders them as one document: per-run eval
tables and health verdicts, the wall-clock span breakdown (with the
compile span's cost-analysis attrs), and the metrics table.

Loading is forgiving by design: any subset of the four may be present
(a pure-serving dir has spans + metrics but no run events), and the
report says what it found rather than failing on what it didn't.
"""
from __future__ import annotations

import json
import pathlib
from typing import Optional

__all__ = ["load_artifacts", "report_text"]


def load_artifacts(trace_dir) -> dict:
    """Collect everything observability wrote under ``trace_dir``:
    ``{"runs": [per-run event lists], "spans": [chrome-trace dicts],
    "metrics": [snapshot records], "health": {run_id: summary}}``.
    Metrics records (``metric`` key, no ``event`` key) may share a
    directory — or even a file — with run events; they are partitioned
    by shape, not filename."""
    from repro.obs import events as E
    d = pathlib.Path(trace_dir)
    records = E.read_jsonl(d) if d.exists() else []
    ev = [r for r in records if "event" in r]
    metrics = [r for r in records if "metric" in r and "event" not in r]
    runs = E.split_runs(ev)
    spans = []
    if d.is_dir():
        for f in sorted(d.glob("spans-*.trace.json")):
            try:
                spans.append(json.loads(f.read_text()))
            except (json.JSONDecodeError, OSError):
                continue
    health = {}
    for run in runs:
        footer = next((e for e in run if e.get("event") == "run_footer"),
                      {})
        if "health" in footer:
            health[footer.get("run", "?")] = footer["health"]
    return {"runs": runs, "spans": spans, "metrics": metrics,
            "health": health}


def _span_summary(trace: dict) -> dict:
    out: dict = {}
    for ev in trace.get("traceEvents", []):
        if ev.get("ph") != "X":
            continue
        agg = out.setdefault(ev["name"],
                             {"count": 0, "total_ms": 0.0, "args": {}})
        agg["count"] += 1
        agg["total_ms"] += float(ev.get("dur", 0.0)) / 1e3
        for k, v in (ev.get("args") or {}).items():
            agg["args"].setdefault(k, v)
    return out


def format_spans(spans: list) -> list:
    """Per-name span aggregate lines across all trace files — count,
    total/mean wall ms, plus any cost-analysis attrs the compile span
    carries."""
    merged: dict = {}
    for tr in spans:
        for name, agg in _span_summary(tr).items():
            m = merged.setdefault(name, {"count": 0, "total_ms": 0.0,
                                         "args": {}})
            m["count"] += agg["count"]
            m["total_ms"] += agg["total_ms"]
            for k, v in agg["args"].items():
                m["args"].setdefault(k, v)
    lines = []
    for name, m in sorted(merged.items(),
                          key=lambda kv: -kv[1]["total_ms"]):
        extra = "".join(
            f"  {k}={v:.3g}" if isinstance(v, float) else f"  {k}={v}"
            for k, v in sorted(m["args"].items())
            if k in ("flops", "bytes_accessed", "rounds", "chunks",
                     "requests", "batches", "hit"))
        lines.append(f"  {name:<18} x{m['count']:<4} "
                     f"{m['total_ms']:>10.2f} ms total  "
                     f"{m['total_ms'] / m['count']:>9.3f} ms mean{extra}")
    return lines


def _fmt_metric(rec: dict) -> str:
    lbl = ",".join(f"{k}={v}" for k, v in
                   sorted((rec.get("labels") or {}).items()))
    who = rec["metric"] + (f"{{{lbl}}}" if lbl else "")
    if rec.get("type") == "histogram":
        return (f"  {who:<42} n={rec.get('count', 0):<6} "
                f"p50={rec.get('p50', float('nan')):.4g} "
                f"p95={rec.get('p95', float('nan')):.4g} "
                f"p99={rec.get('p99', float('nan')):.4g}")
    return f"  {who:<42} {rec.get('value', float('nan')):.6g}"


def report_text(trace_dir) -> str:
    """The joined report ``python -m repro.obs report DIR`` prints."""
    from repro.obs import events as E
    art = load_artifacts(trace_dir)
    lines = [f"obs report: {trace_dir}"]

    if art["runs"]:
        lines.append(f"\n== runs ({len(art['runs'])}) ==")
        for run in art["runs"]:
            s = E.summarize_run(run)
            who = s["run"]
            if s.get("scenario"):
                who += f"  [{s['scenario']}]"
            final = "  ".join(f"{k}={v:.4f}"
                              for k, v in sorted(s["final"].items()))
            lines.append(f"  {who}  algo={s.get('algo')} "
                         f"rounds={s.get('rounds')} evals={s['evals']}  "
                         f"{final}")
            h = art["health"].get(s["run"])
            if h is not None:
                if h.get("ok"):
                    lines.append(f"    health: ok "
                                 f"({len(h.get('series', {}))} detectors"
                                 f" clean)")
                else:
                    fired = ", ".join(
                        f"{k} x{v['fired_rounds']}"
                        for k, v in sorted(h.get("series", {}).items())
                        if v.get("fired_rounds"))
                    lines.append(f"    health: FAILED at round "
                                 f"{h.get('first_bad_round')} ({fired})")
    else:
        lines.append("\n== runs ==\n  (no run events)")

    lines.append(f"\n== spans ({len(art['spans'])} trace file(s)) ==")
    span_lines = format_spans(art["spans"])
    lines.extend(span_lines or ["  (no spans)"])

    lines.append(f"\n== metrics ({len(art['metrics'])}) ==")
    if art["metrics"]:
        lines.extend(_fmt_metric(r) for r in art["metrics"])
    else:
        lines.append("  (no metrics)")
    return "\n".join(lines) + "\n"
