"""Counter / gauge / histogram registry with JSONL + Prometheus export.

Serving needed what the training side already had: the training loop
reports through probe streams and run events (`repro.obs.trace` /
`repro.obs.events`), but `ModelStore`'s LRU, the tier-fallback ladder,
and traffic replay had nothing to report *into*. A `MetricsRegistry` is
that sink: a small host-side label-aware registry of the three standard
instrument kinds —

* :class:`Counter` — monotone totals (requests served, LRU hits/misses,
  per-tier resolution counts);
* :class:`Gauge` — last-write-wins values (cache hit rate, store bytes);
* :class:`Histogram` — raw observation lists with rank-based percentiles
  (per-batch replay latency, gather-decode vs forward stage splits).

Exports: :meth:`MetricsRegistry.write_jsonl` emits one JSON object per
instrument (the form ``python -m repro.obs report`` joins with events,
spans, and health), and :meth:`MetricsRegistry.write_prom` emits
Prometheus text exposition (counters/gauges as samples, histograms as
summaries with quantile labels) so the same numbers scrape into a real
monitoring stack. ``replay_traffic`` and ``benchmarks/bench_serving.py``
publish into a registry end-to-end (DESIGN.md §13).
"""
from __future__ import annotations

import pathlib
import re
from typing import Optional

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "percentile"]


def percentile(values, p: float) -> float:
    """Nearest-rank percentile (ceil(p/100 * n)-th smallest) over raw
    observations — the convention `replay_traffic` always used, shared
    here so benchmark and registry report identical numbers."""
    a = np.sort(np.asarray(values, dtype=np.float64))
    if a.size == 0:
        return float("nan")
    rank = min(a.size - 1, int(np.ceil(p / 100 * a.size)) - 1)
    return float(a[max(rank, 0)])


class Counter:
    """Monotone counter: ``inc`` only ever adds (negative increments are
    rejected — a counter that can fall is a gauge)."""

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        """Add ``v`` (>= 0) to the running total."""
        if v < 0:
            raise ValueError(f"counter increment must be >= 0, got {v}")
        self.value += float(v)


class Gauge:
    """Last-write-wins scalar."""

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        """Record the current value."""
        self.value = float(v)


class Histogram:
    """Raw-observation histogram; percentiles computed at read time via
    the shared nearest-rank :func:`percentile`."""

    def __init__(self):
        self.observations: list = []

    def observe(self, v: float) -> None:
        """Record one observation."""
        self.observations.append(float(v))

    def count(self) -> int:
        """Number of observations recorded."""
        return len(self.observations)

    def sum(self) -> float:
        """Sum of all observations."""
        return float(np.sum(self.observations)) if self.observations \
            else 0.0

    def quantile(self, p: float) -> float:
        """Nearest-rank percentile over the raw observations."""
        return percentile(self.observations, p)

    def summary(self) -> dict:
        """{count, sum, mean, p50, p95, p99, max} over the observations
        (NaNs when empty)."""
        n = self.count()
        return {"count": n, "sum": self.sum(),
                "mean": self.sum() / n if n else float("nan"),
                "p50": self.quantile(50), "p95": self.quantile(95),
                "p99": self.quantile(99),
                "max": float(max(self.observations)) if n
                else float("nan")}


class MetricsRegistry:
    """Get-or-create instrument registry keyed on (name, labels).

    Names are dotted (``serving.lru.hits``); labels are keyword pairs
    (``encoding="delta"``). The JSONL export keeps dotted names; the
    Prometheus export sanitizes them to ``_``-separated metric names.
    """

    def __init__(self):
        self._instruments: dict = {}

    def _get(self, kind, name: str, labels: dict):
        key = (name, tuple(sorted(labels.items())))
        inst = self._instruments.get(key)
        if inst is None:
            inst = self._instruments[key] = kind()
        elif not isinstance(inst, kind):
            raise TypeError(
                f"metric {name!r}{labels} already registered as "
                f"{type(inst).__name__}, requested {kind.__name__}")
        return inst

    def counter(self, name: str, **labels) -> Counter:
        """Get-or-create the `Counter` for (name, labels)."""
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        """Get-or-create the `Gauge` for (name, labels)."""
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        """Get-or-create the `Histogram` for (name, labels)."""
        return self._get(Histogram, name, labels)

    def __len__(self):
        return len(self._instruments)

    # ------------------------------------------------------------ export

    _TYPE = {Counter: "counter", Gauge: "gauge", Histogram: "histogram"}

    def snapshot(self) -> list:
        """One dict per instrument: ``{metric, type, labels, value}`` for
        counters/gauges, ``{metric, type, labels, **summary}`` for
        histograms — sorted by (metric, labels) for stable output."""
        out = []
        for (name, labels), inst in sorted(
                self._instruments.items(), key=lambda kv: kv[0]):
            rec = {"metric": name, "type": self._TYPE[type(inst)],
                   "labels": dict(labels)}
            if isinstance(inst, Histogram):
                rec.update(inst.summary())
            else:
                rec["value"] = inst.value
            out.append(rec)
        return out

    def write_jsonl(self, path) -> pathlib.Path:
        """Write :meth:`snapshot` as JSONL (one instrument per line)."""
        from repro.obs.events import write_jsonl
        return write_jsonl(path, self.snapshot())

    def to_prometheus(self) -> str:
        """Prometheus text exposition: counters and gauges as plain
        samples, histograms as summaries (quantile-labelled samples plus
        ``_count``/``_sum``)."""
        lines = []
        for rec in self.snapshot():
            name = re.sub(r"[^a-zA-Z0-9_:]", "_", rec["metric"])
            lbl = ",".join(f'{k}="{v}"'
                           for k, v in sorted(rec["labels"].items()))
            lbl_b = "{" + lbl + "}" if lbl else ""
            if rec["type"] == "histogram":
                lines.append(f"# TYPE {name} summary")
                for q in (50, 95, 99):
                    ql = (lbl + "," if lbl else "") + \
                        f'quantile="0.{q}"'
                    lines.append(
                        f"{name}{{{ql}}} {rec[f'p{q}']:.6g}")
                lines.append(f"{name}_count{lbl_b} {rec['count']}")
                lines.append(f"{name}_sum{lbl_b} {rec['sum']:.6g}")
            else:
                lines.append(f"# TYPE {name} {rec['type']}")
                lines.append(f"{name}{lbl_b} {rec['value']:.6g}")
        return "\n".join(lines) + ("\n" if lines else "")

    def write_prom(self, path) -> pathlib.Path:
        """Write :meth:`to_prometheus` text to ``path``."""
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.to_prometheus())
        return path
