"""Unified run-telemetry layer: probes, run events, profiling, gating.

Three parts, all riding the existing engine/sweep/scenario machinery
(DESIGN.md §9):

* in-graph probes — a frozen `TraceConfig` selects cheap scalar
  diagnostics (drift/grad/residual/loss norms) that an algorithm's
  ``probe_round`` emits as extra ``lax.scan`` outputs; the engine
  assembles them into a `RunTrace` on ``FLResult.trace``. Probes-off is
  the default and leaves the compiled program untouched.
* structured run events — one JSONL schema (`repro.obs.events`) written
  by ``run_experiment(trace_dir=...)`` / ``run_sweep`` / the scenarios
  CLI, read back by ``python -m repro.obs summarize``.
* profiling + regression hooks — ``cost_analysis`` / ``jax.profiler``
  capture behind `TraceConfig`, and the `repro.obs.regress` comparator
  CI uses to gate ``BENCH_engine.json`` against a committed baseline.
"""
from repro.obs.events import (read_jsonl, run_events, summarize_run,
                              sweep_events, write_jsonl, write_run,
                              write_sweep)
from repro.obs.profiling import compiled_cost, profile_ctx
from repro.obs.regress import compare as compare_bench
from repro.obs.trace import RunTrace, TraceConfig, eval_points

__all__ = ["RunTrace", "TraceConfig", "compare_bench", "compiled_cost",
           "eval_points", "profile_ctx", "read_jsonl", "run_events",
           "summarize_run", "sweep_events", "write_jsonl", "write_run",
           "write_sweep"]
