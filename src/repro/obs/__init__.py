"""Unified run-telemetry layer: probes, spans, metrics, health, gating.

Five parts, all riding the existing engine/sweep/scenario/serving
machinery (DESIGN.md §9, §13):

* in-graph probes — a frozen `TraceConfig` selects cheap scalar
  diagnostics (drift/grad/residual/loss norms) that an algorithm's
  ``probe_round`` emits as extra ``lax.scan`` outputs; the engine
  assembles them into a `RunTrace` on ``FLResult.trace``. Probes-off is
  the default and leaves the compiled program untouched.
* in-graph health monitors (`repro.obs.health`) — nonfinite/explosion
  detectors riding the same scan-output contract, assembled into a
  `HealthReport` on ``FLResult.health``, with opt-in fail-fast raising
  `HealthError` naming the first bad round.
* host-side spans (`repro.obs.spans`) — nested wall-clock intervals
  (build/compile/dispatch/eval, store export, replay batches) exported
  as Chrome-trace-event JSON into the run's trace dir.
* metrics (`repro.obs.metrics`) — a counter/gauge/histogram registry
  with JSONL + Prometheus-text export; the serving path publishes LRU
  hit/miss, per-tier fallback counts, and replay latency into it.
* structured run events — one JSONL schema (`repro.obs.events`) written
  by ``run_experiment(trace_dir=...)`` / ``run_sweep`` / the scenarios
  CLI, read back by ``python -m repro.obs summarize``; ``python -m
  repro.obs report DIR`` joins events × spans × metrics × health.
* profiling + regression hooks — ``cost_analysis`` / ``jax.profiler``
  capture behind `TraceConfig`, and the `repro.obs.regress` comparator
  CI uses to gate ``BENCH_*.json`` against committed baselines.
"""
from repro.obs.events import (read_jsonl, run_events, summarize_run,
                              sweep_events, write_jsonl, write_run,
                              write_sweep)
from repro.obs.health import HealthError, HealthReport, nonfinite_count
from repro.obs.metrics import MetricsRegistry
from repro.obs.profiling import compiled_cost, profile_ctx
from repro.obs.regress import compare as compare_bench
from repro.obs.report import report_text
from repro.obs.spans import SpanLog, current_log, span
from repro.obs.trace import RunTrace, TraceConfig, eval_points

__all__ = ["HealthError", "HealthReport", "MetricsRegistry", "RunTrace",
           "SpanLog", "TraceConfig", "compare_bench", "compiled_cost",
           "current_log", "eval_points", "nonfinite_count",
           "profile_ctx", "read_jsonl", "report_text", "run_events",
           "span", "summarize_run", "sweep_events", "write_jsonl",
           "write_run", "write_sweep"]
