"""Perf-regression gate over ``BENCH_*.json`` markers.

CI used to only *upload* the benchmark marker; this comparator makes it a
gate: load the committed baseline and the freshly produced marker,
extract every throughput metric present in both (engine rounds/sec per
execution model, sweep configs/sec, probes-on rounds/sec, comm-round
rounds/sec fused and unfused, cohort-engine rounds/sec per population
size, per-compressor kernel XLA rates from ``BENCH_kernels.json``, and
the personalized-serving qps / inverted-latency rates from
``BENCH_serving.json``), and fail when any current rate falls more than
``tol`` below its baseline:

    python -m repro.obs.regress benchmarks/baselines/BENCH_engine.json \
        BENCH_engine.json --tol 0.2
    python -m repro.obs.regress benchmarks/baselines/BENCH_kernels.json \
        BENCH_kernels.json --tol 0.5
    python -m repro.obs.regress benchmarks/baselines/BENCH_serving.json \
        BENCH_serving.json --tol 0.5

Rate shapes are normalized across bench modes: smoke mode reports single
scalars (the scanned/vmapped paths only), quick/full mode per-model
dicts — a scalar compares against the dict's matching entry, so a smoke
run in CI can gate against any committed baseline. Improvements always
pass; a missing baseline warns and passes (first run bootstraps it).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

__all__ = ["compare", "load_rates", "main"]

# default tolerance band: fail on >20% throughput regression (ROADMAP)
DEFAULT_TOL = 0.2


def load_rates(payload: dict) -> dict:
    """Flatten one marker's gateable throughput metrics to
    ``{dotted.path: rate}``. Scalars are normalized to the execution
    model they measure (smoke's engine scalar is the scanned path, its
    sweep scalar the vmapped path)."""
    out = {}

    def rate_group(group: str, value, scalar_key: str):
        if isinstance(value, dict):
            for k, v in value.items():
                if isinstance(v, (int, float)):
                    out[f"{group}.{k}"] = float(v)
        elif isinstance(value, (int, float)):
            out[f"{group}.{scalar_key}"] = float(value)

    rate_group("engine.rounds_per_sec",
               payload.get("engine", {}).get("rounds_per_sec"), "scan")
    rate_group("sweep.configs_per_sec",
               payload.get("sweep", {}).get("configs_per_sec"), "sweep")
    rate_group("obs.rounds_per_sec",
               payload.get("obs", {}).get("rounds_per_sec_probes"),
               "probes")
    # cohort section: gate the absolute per-N rounds/sec rates (the
    # N-scaling *ratio* is asserted inside bench_engine itself — a ratio
    # is not a throughput, so gating it here would invert the direction)
    rate_group("cohort.rounds_per_sec",
               payload.get("cohort", {}).get("rounds_per_sec"), "cohort")

    # BENCH_serving section: every entry is a higher-is-better rate by
    # construction (qps, inverted-latency rates, the LRU hit rate, and
    # the per-tier resolution rates; raw ms latencies and counts live in
    # the ungated serving_detail section), so the generic flatten is the
    # whole gate — serving.cache_hit_rate / serving.tier_*_rate gate a
    # broken cache or fallback ladder, not just throughput
    rate_group("serving", payload.get("serving"), "qps")

    # BENCH_engine comm section: fused/unfused compressed-round rates
    comm = payload.get("comm")
    if isinstance(comm, dict):
        for k in ("rounds_per_sec_fused", "rounds_per_sec_unfused"):
            if isinstance(comm.get(k), (int, float)):
                out[f"comm.{k}"] = float(comm[k])

    # BENCH_kernels compress section: gate the XLA rate per compressor
    # (the pallas column is interpret-mode on CPU — a correctness probe
    # whose wall-time is meaningless, so it is reported but never gated)
    compress = payload.get("compress")
    if isinstance(compress, dict):
        for name, entry in compress.items():
            if isinstance(entry, dict) and \
                    isinstance(entry.get("xla_meps"), (int, float)):
                out[f"compress.{name}.xla_meps"] = float(entry["xla_meps"])
    return out


def compare(baseline: dict, current: dict, tol: float = DEFAULT_TOL):
    """Compare two marker payloads; returns ``(failures, report)`` line
    lists. A metric fails when ``current < baseline * (1 - tol)``; metrics
    present in only one payload are reported but never gate."""
    base, cur = load_rates(baseline), load_rates(current)
    failures, report = [], []
    for k in sorted(set(base) | set(cur)):
        if k not in base or k not in cur:
            report.append(f"  {k}: only in "
                          f"{'current' if k in cur else 'baseline'} — skipped")
            continue
        floor = base[k] * (1.0 - tol)
        ratio = cur[k] / base[k] if base[k] else float("inf")
        line = (f"  {k}: baseline {base[k]:.2f} -> current {cur[k]:.2f} "
                f"({ratio:.2f}x, floor {floor:.2f})")
        if cur[k] < floor:
            failures.append(f"REGRESSION {k}: {cur[k]:.2f} < "
                            f"{floor:.2f} (baseline {base[k]:.2f}, "
                            f"tol {tol:.0%})")
            line += "  FAIL"
        report.append(line)
    if not (set(base) & set(cur)):
        report.append("  (no shared throughput metrics — nothing gated)")
    return failures, report


def main(argv=None) -> int:
    """CLI: compare a committed baseline marker against a fresh one."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.regress",
        description="Gate BENCH_engine.json against a committed baseline.")
    ap.add_argument("baseline", help="committed baseline marker (JSON)")
    ap.add_argument("current", help="freshly produced marker (JSON)")
    ap.add_argument("--tol", type=float, default=DEFAULT_TOL,
                    help="allowed fractional regression (default 0.2)")
    args = ap.parse_args(argv)

    base_path = pathlib.Path(args.baseline)
    if not base_path.exists():
        print(f"regress: no baseline at {base_path} — nothing to gate "
              "(commit the current marker to bootstrap)")
        return 0
    baseline = json.loads(base_path.read_text())
    current = json.loads(pathlib.Path(args.current).read_text())

    failures, report = compare(baseline, current, tol=args.tol)
    print(f"regress: {args.current} vs {args.baseline} "
          f"(tol {args.tol:.0%}, baseline mode "
          f"{baseline.get('mode')!r}, current mode {current.get('mode')!r})")
    for line in report:
        print(line)
    for f in failures:
        print(f)
    print(f"regress: {'FAIL' if failures else 'OK'} "
          f"({len(failures)} regression(s))")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
