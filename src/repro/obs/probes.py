"""Traceable norm/aggregation helpers the probe implementations share.

Everything here runs *inside* the engine's scanned round body, so it must
be cheap and traceable: reductions over pytrees with stacked leading
axes, masked by the (M,) / (M, N) participation arrays. Integer and
PRNG-key leaves (round counters, comm keys) are skipped — probes measure
the float state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["masked_mean", "masked_max", "stacked_sq_norm", "tree_diff_norm"]


def _float_leaves(tree):
    return [l for l in jax.tree.leaves(tree)
            if jnp.issubdtype(jnp.asarray(l).dtype, jnp.inexact)]


def stacked_sq_norm(tree, lead: int):
    """Squared l2 norm summed over leaves, keeping the first `lead` axes.

    ``stacked_sq_norm(theta, 2)`` on (M, N, ...) leaves gives the (M, N)
    matrix of per-device squared model norms; ``lead=0`` a scalar.
    """
    total = jnp.float32(0.0)
    for leaf in _float_leaves(tree):
        leaf = jnp.asarray(leaf, jnp.float32)
        total = total + jnp.sum(jnp.square(leaf),
                                axis=tuple(range(lead, leaf.ndim)))
    return total


def tree_diff_norm(a, b) -> jnp.ndarray:
    """Scalar l2 distance between two pytrees' float leaves — the generic
    whole-state update norm."""
    total = jnp.float32(0.0)
    for la, lb in zip(_float_leaves(a), _float_leaves(b)):
        total = total + jnp.sum(jnp.square(jnp.asarray(la, jnp.float32)
                                           - jnp.asarray(lb, jnp.float32)))
    return jnp.sqrt(total)


def masked_mean(values, mask) -> jnp.ndarray:
    """Participation-weighted mean of `values` (mask-shaped); 0 when the
    mask is empty."""
    return jnp.sum(values * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def masked_max(values, mask) -> jnp.ndarray:
    """Max of `values` over set mask entries. Values must be >= 0 (norms
    are): masked-out entries contribute 0, and an all-zero mask gives 0."""
    return jnp.max(values * mask)
