"""Structured run events: one JSONL schema for every experiment.

Each run is a short stream of JSON objects, one per line:

  run_header   — identity + configuration: run id, algorithm name, the
                 sweepable hyperparameter leaves, dims/rounds/seed, and
                 any caller metadata (scenario name, ``spec_hash``, ...).
  eval         — one record per eval point, *joining* the quantities the
                 repo previously surfaced in separate objects: metrics
                 (pm/tm/gm/train_loss) x cumulative bytes (CommLedger) x
                 cumulative simulated seconds (Timeline) x probe-segment
                 summaries (RunTrace).
  run_footer   — outcome + cost: final metrics, wall-clock split
                 (compile/run seconds), dispatch count, byte and
                 timeline totals, probe summaries, the health-detector
                 verdict (``HealthReport.summary()``) when monitors ran,
                 and the compiled program's flops/bytes when cost
                 analysis was on.

A sweep writes one file: a ``sweep_header`` followed by each
configuration's header/eval/footer section (run ids ``<base>/c<i>``).
Every record carries ``run`` and ``schema`` so files concatenate and
stream safely. ``python -m repro.obs summarize`` renders or diffs them.

The writers take anything FLResult-shaped (duck-typed on the metric
histories and the ``comm``/``timeline``/``trace`` attachments) — this
module never imports the engine, the engine imports it.
"""
from __future__ import annotations

import json
import os
import pathlib
import uuid
from typing import Any, Optional

from repro.obs.trace import eval_points

__all__ = ["diff_summaries", "new_run_id", "read_jsonl", "run_events",
           "split_runs", "summarize_run", "sweep_events", "write_jsonl",
           "write_run", "write_sweep"]

SCHEMA = 1

_METRICS = ("pm", "tm", "gm", "train_loss")
_HIST = {"pm": "pm_acc", "tm": "tm_acc", "gm": "gm_acc",
         "train_loss": "train_loss"}


def new_run_id(tag: str = "run") -> str:
    """Fresh run id ``<tag>-<8 hex>`` — public so callers that emit
    several artifacts for one run (events + spans + metrics) can mint
    the id once and thread it through."""
    return f"{tag}-{uuid.uuid4().hex[:8]}"


_new_run_id = new_run_id


def _metric_hists(res) -> dict:
    return {m: list(getattr(res, _HIST[m], []) or []) for m in _METRICS
            if getattr(res, _HIST[m], None)}


def run_events(res, *, run_id: Optional[str] = None, algo: Any = None,
               meta: Optional[dict] = None) -> list:
    """Build one run's event stream (header, evals, footer) from an
    FLResult-shaped object.

    res must carry ``rounds`` / ``eval_every`` (the engine sets them);
    algo, when given, contributes its name and hyperparameter leaves to
    the header; meta is merged into the header verbatim.
    """
    run_id = run_id or _new_run_id(getattr(algo, "name", None) or "run")
    hists = _metric_hists(res)
    rounds = int(getattr(res, "rounds", 0))
    eval_every = max(int(getattr(res, "eval_every", 1)), 1)
    points = eval_points(rounds, eval_every)

    header = {"event": "run_header", "schema": SCHEMA, "run": run_id,
              "algo": getattr(algo, "name", None),
              "hparams": (dict(algo.tree_hparams()[0])
                          if hasattr(algo, "tree_hparams") else {}),
              "rounds": rounds, "eval_every": eval_every}
    cohort = getattr(res, "cohort", None)
    if cohort is not None:
        header["cohort"] = int(cohort)
        header["population"] = int(getattr(res, "population", 0) or 0)
    header.update(meta or {})
    events = [header]

    comm = getattr(res, "comm", None)
    cum_bytes = comm.cum_total_bytes() if comm is not None else None
    sim = list(getattr(res, "sim_seconds", []) or [])
    trace = getattr(res, "trace", None)
    probe_segs = trace.at_points(points) if trace is not None else None
    cohort_idx = (list(getattr(res, "cohort_indices", []) or [])
                  if cohort is not None else None)

    prev_rnd = 0
    for i, rnd in enumerate(points):
        ev = {"event": "eval", "schema": SCHEMA, "run": run_id,
              "round": rnd,
              "metrics": {m: float(h[i]) for m, h in hists.items()
                          if i < len(h)}}
        if cum_bytes is not None and rnd - 1 < len(cum_bytes):
            ev["cum_bytes"] = int(cum_bytes[rnd - 1])
        if i < len(sim):
            ev["sim_seconds"] = float(sim[i])
        if probe_segs is not None:
            ev["probes"] = probe_segs[i]
        if cohort_idx:
            ev["cohort_indices"] = cohort_idx[prev_rnd:rnd]
        prev_rnd = rnd
        events.append(ev)

    footer = {"event": "run_footer", "schema": SCHEMA, "run": run_id,
              "final": {m: float(h[-1]) for m, h in hists.items() if h},
              "seconds": float(getattr(res, "seconds", 0.0)),
              "compile_seconds": float(getattr(res, "compile_seconds", 0.0)),
              "run_seconds": float(getattr(res, "run_seconds", 0.0)),
              "dispatches": int(getattr(res, "dispatches", 0))}
    if comm is not None:
        footer["comm"] = comm.summary()
    timeline = getattr(res, "timeline", None)
    if timeline is not None:
        footer["timeline"] = timeline.summary()
    if trace is not None:
        footer["probes"] = trace.summary()
        if trace.cost is not None:
            footer["cost"] = trace.cost
    health = getattr(res, "health", None)
    if health is not None:
        footer["health"] = health.summary()
    events.append(footer)
    return events


def sweep_events(sweep, *, run_id: Optional[str] = None, algo: Any = None,
                 meta: Optional[dict] = None) -> list:
    """Event stream for a whole FLSweepResult: a ``sweep_header`` then
    each configuration's run section (run ids ``<base>/c<i>``)."""
    run_id = run_id or _new_run_id("sweep")
    events = [{"event": "sweep_header", "schema": SCHEMA, "run": run_id,
               "configs": len(sweep.results),
               "dispatches": int(getattr(sweep, "dispatches", 0)),
               "seconds": float(getattr(sweep, "seconds", 0.0)),
               **(meta or {})}]
    for i, res in enumerate(sweep.results):
        cfg = sweep.configs[i] if i < len(sweep.configs) else {}
        events.extend(run_events(
            res, run_id=f"{run_id}/c{i}", algo=algo,
            meta={"config": {k: v for k, v in cfg.items()}}))
    return events


# ---------------------------------------------------------------------------
# file I/O
# ---------------------------------------------------------------------------

def write_jsonl(path, events) -> pathlib.Path:
    """Write one event per line; parent directories are created."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with path.open("w") as f:
        for ev in events:
            f.write(json.dumps(ev, sort_keys=True) + "\n")
    return path


def _unique_path(trace_dir, run_id: str) -> pathlib.Path:
    safe = run_id.replace("/", "_")
    return pathlib.Path(trace_dir) / f"{safe}-{os.getpid()}.jsonl"


def write_run(trace_dir, res, *, algo: Any = None,
              meta: Optional[dict] = None,
              run_id: Optional[str] = None) -> pathlib.Path:
    """Serialize one run's events into ``<trace_dir>/<run_id>.jsonl``."""
    run_id = run_id or _new_run_id(getattr(algo, "name", None) or "run")
    return write_jsonl(_unique_path(trace_dir, run_id),
                       run_events(res, run_id=run_id, algo=algo, meta=meta))


def write_sweep(trace_dir, sweep, *, algo: Any = None,
                meta: Optional[dict] = None,
                run_id: Optional[str] = None) -> pathlib.Path:
    """Serialize a sweep's events into one ``<trace_dir>/*.jsonl`` file."""
    run_id = run_id or _new_run_id("sweep")
    return write_jsonl(_unique_path(trace_dir, run_id),
                       sweep_events(sweep, run_id=run_id, algo=algo,
                                    meta=meta))


def read_jsonl(path) -> list:
    """Load events from a ``.jsonl`` file, or from every ``*.jsonl`` in a
    directory (sorted by name)."""
    p = pathlib.Path(path)
    files = sorted(p.glob("*.jsonl")) if p.is_dir() else [p]
    events = []
    for f in files:
        for line in f.read_text().splitlines():
            line = line.strip()
            if line:
                events.append(json.loads(line))
    return events


def split_runs(events) -> list:
    """Group a flat event stream into per-run lists (keyed on each
    record's ``run`` id; sweep headers form their own group)."""
    by_run, order = {}, []
    for ev in events:
        rid = ev.get("run", "?")
        if rid not in by_run:
            by_run[rid] = []
            order.append(rid)
        by_run[rid].append(ev)
    return [by_run[r] for r in order
            if any(e.get("event") != "sweep_header" for e in by_run[r])]


# ---------------------------------------------------------------------------
# summaries
# ---------------------------------------------------------------------------

def summarize_run(run: list) -> dict:
    """Flatten one run's events into the fields the CLI renders/diffs."""
    header = next((e for e in run if e.get("event") == "run_header"), {})
    footer = next((e for e in run if e.get("event") == "run_footer"), {})
    evals = [e for e in run if e.get("event") == "eval"]
    out = {"run": header.get("run", footer.get("run", "?")),
           "algo": header.get("algo"),
           "scenario": header.get("scenario"),
           "spec_hash": header.get("spec_hash"),
           "rounds": header.get("rounds"),
           "evals": len(evals),
           "final": footer.get("final", {}),
           "seconds": footer.get("seconds"),
           "compile_seconds": footer.get("compile_seconds"),
           "dispatches": footer.get("dispatches")}
    if evals:
        last = evals[-1]
        out["cum_bytes"] = last.get("cum_bytes")
        out["sim_seconds"] = last.get("sim_seconds")
    if "probes" in footer:
        out["probes"] = footer["probes"]
    if "cost" in footer:
        out["cost"] = footer["cost"]
    return out


def diff_summaries(a: dict, b: dict) -> dict:
    """Numeric deltas (b - a) for every shared metric/cost field of two
    run summaries — the two-run comparison the CLI prints."""
    out = {}
    for m, va in (a.get("final") or {}).items():
        vb = (b.get("final") or {}).get(m)
        if vb is not None:
            out[f"final.{m}"] = float(vb) - float(va)
    for k in ("seconds", "compile_seconds", "cum_bytes", "sim_seconds"):
        va, vb = a.get(k), b.get(k)
        if va is not None and vb is not None:
            out[k] = float(vb) - float(va)
    return out
