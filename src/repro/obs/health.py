"""In-graph health monitors: nonfinite/explosion detection per round.

Probes (`repro.obs.trace`) measure convergence quantities; health
monitors answer a blunter question — *is this run still numerically
alive?* Each algorithm's ``health_round`` emits a few scalar detector
values per global round as extra ``lax.scan`` outputs (``health:``-
prefixed, exactly like ``probe:`` streams): counts of nonfinite entries
in the post-round state and in the round's update, plus an
algorithm-specific loss-explosion flag. The engine assembles them into a
:class:`HealthReport` on ``FLResult.health``.

The contract matches PR 6's probes: with ``TraceConfig.health`` off the
round program is byte-identical to the unmonitored one, and with it on
the trajectory is bit-identical — detectors only *read* the state
(pinned in tests/test_obs_health.py, scan ≡ dispatch).

A detector value > 0 marks the round as bad. ``TraceConfig.fail_fast``
turns detection into action: the engine raises :class:`HealthError`
host-side naming the first bad 1-based round, so a poisoned sweep dies
at its first diverged eval chunk instead of burning hours silently.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["HealthError", "HealthReport", "first_bad_round",
           "nonfinite_count"]


def nonfinite_count(tree) -> jnp.ndarray:
    """Scalar f32 count of non-finite entries over a pytree's float
    leaves (integer / PRNG-key leaves are skipped — round counters and
    comm keys can't go NaN). Traceable; runs inside the scanned round
    body."""
    total = jnp.float32(0.0)
    for leaf in jax.tree.leaves(tree):
        leaf = jnp.asarray(leaf)
        if jnp.issubdtype(leaf.dtype, jnp.inexact):
            total = total + jnp.sum(
                (~jnp.isfinite(leaf)).astype(jnp.float32))
    return total


def _bad(value: float) -> bool:
    v = float(value)
    return v > 0.0 or not math.isfinite(v)


def first_bad_round(series: dict) -> Optional[int]:
    """First 1-based round at which any detector stream fired (value > 0
    or itself nonfinite — a NaN count means the detector's own reduction
    saw garbage), or None when every round is clean."""
    rounds = max((len(v) for v in series.values()), default=0)
    for r in range(rounds):
        for v in series.values():
            if r < len(v) and _bad(v[r]):
                return r + 1
    return None


class HealthError(RuntimeError):
    """Raised by the engine under ``TraceConfig.fail_fast`` when a health
    detector fires; carries the first bad 1-based round index."""

    def __init__(self, round_index: int, detectors: dict,
                 context: str = ""):
        """detectors: {name: value} of the streams that fired at that
        round; context: optional run identity for the message."""
        self.round_index = int(round_index)
        self.detectors = dict(detectors)
        where = f" [{context}]" if context else ""
        fired = ", ".join(f"{k}={float(v):g}"
                          for k, v in sorted(detectors.items()))
        super().__init__(
            f"health check failed at round {self.round_index}{where}: "
            f"{fired}")


@dataclass
class HealthReport:
    """Host-side per-round health detector streams for one experiment.

    series: detector name -> per-round list of floats (aligned with the
        run's global rounds, like ``RunTrace.series``); a value > 0 at
        round r means that detector fired there.
    """
    series: dict = field(default_factory=dict)

    def __len__(self):
        return max((len(v) for v in self.series.values()), default=0)

    def names(self) -> list:
        """Detector names present, sorted."""
        return sorted(self.series)

    def __getitem__(self, name: str) -> list:
        return self.series[name]

    def first_bad_round(self) -> Optional[int]:
        """First 1-based round where any detector fired, or None."""
        return first_bad_round(self.series)

    def ok(self) -> bool:
        """True when no detector fired at any round."""
        return self.first_bad_round() is None

    def check(self, context: str = "") -> "HealthReport":
        """Raise :class:`HealthError` naming the first bad round if any
        detector fired; return self otherwise (chainable). The engine's
        fail-fast path is exactly this call."""
        bad = self.first_bad_round()
        if bad is not None:
            r = bad - 1
            fired = {k: v[r] for k, v in self.series.items()
                     if r < len(v) and _bad(v[r])}
            raise HealthError(bad, fired, context)
        return self

    def summary(self) -> dict:
        """Footer material: ``{ok, first_bad_round, series: {name:
        {fired_rounds, max}}}`` — compact enough for the JSONL run
        footer, complete enough for ``obs report``."""
        per = {}
        for k, v in self.series.items():
            a = np.asarray(v, dtype=np.float64)
            if a.size:
                bad = ~np.isfinite(a) | (a > 0)
                per[k] = {"fired_rounds": int(bad.sum()),
                          "max": float(np.nanmax(a))
                          if np.isfinite(a).any() else float("nan")}
        return {"ok": self.ok(), "first_bad_round": self.first_bad_round(),
                "series": per}
