"""Nestable host-side spans with Chrome-trace-event export.

The run-event log (`repro.obs.events`) answers *what happened* at each
eval point; spans answer *where the wall-clock went*. A `SpanLog` is a
per-run collector of named, nested host-side intervals — build, compile,
first dispatch, chunk dispatches, eval assembly on the training side;
store export/save/load and replay batches on the serving side — written
out as Chrome trace-event JSON that loads directly into Perfetto or
``chrome://tracing``.

Instrumented library code never creates a log itself: it calls the
module-level :func:`span` context manager, which records into whichever
`SpanLog` is *active* (a contextvar set by :meth:`SpanLog.activate`) and
degrades to a near-zero-cost no-op when none is. The outermost caller —
``run_experiment(trace_dir=...)``, ``run_scenario``, the scenarios CLI's
``serve --trace-dir`` — owns the log: it activates one around the whole
operation, so nested layers (scenario build → engine dispatch → store
export → replay batches) all land in a single trace, and saves it next
to the JSONL event log. ``python -m repro.obs report DIR`` joins the
result with events, metrics, and health.

Spans carry free-form attributes (``span("compile", rounds=8)``) and the
yielded `Span` accepts late ones via :meth:`Span.set` — the engine stamps
``compiled_cost`` flops/bytes onto its compile span after XLA's cost
analysis runs, so the exported trace shows static cost next to measured
time.
"""
from __future__ import annotations

import contextlib
import contextvars
import json
import os
import pathlib
import time
from dataclasses import dataclass, field
from typing import Optional

__all__ = ["Span", "SpanLog", "current_log", "span"]

_ACTIVE: contextvars.ContextVar = contextvars.ContextVar(
    "repro_span_log", default=None)


@dataclass
class Span:
    """One named host-side interval: begin/duration (seconds, relative to
    the owning log's epoch), nesting depth, and free-form attributes."""
    name: str
    t0: float                       # start, seconds since log epoch
    depth: int = 0                  # nesting level at begin time
    dur: Optional[float] = None     # seconds; None while still open
    attrs: dict = field(default_factory=dict)

    def set(self, **attrs) -> "Span":
        """Attach (or overwrite) attributes; usable after the span closed
        — attrs serialize at export time, so late annotations (e.g. the
        compile span's cost-analysis flops) still land in the trace."""
        self.attrs.update(attrs)
        return self


class SpanLog:
    """Collector for one run's spans, exportable as Chrome trace events.

    Use :meth:`span` directly, or :meth:`activate` the log so library
    code's module-level :func:`span` calls feed it. Spans nest via a
    stack; the export encodes each as a complete ("X") trace event whose
    ``tid`` is the nesting depth, which Perfetto renders as a flame-like
    track per level.
    """

    def __init__(self, meta: Optional[dict] = None):
        """meta: free-form identity recorded in the exported trace's
        ``metadata`` section (run id, scenario name, ...)."""
        self.meta = dict(meta or {})
        self.spans: list = []
        self._stack: list = []
        self._epoch = time.perf_counter()

    def __len__(self):
        return len(self.spans)

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        """Record one nested interval; yields the open `Span` so callers
        can :meth:`Span.set` more attributes. Exceptions propagate after
        the span is closed, so aborted phases still show in the trace."""
        sp = Span(name=name, t0=time.perf_counter() - self._epoch,
                  depth=len(self._stack), attrs=dict(attrs))
        self.spans.append(sp)
        self._stack.append(sp)
        try:
            yield sp
        finally:
            sp.dur = (time.perf_counter() - self._epoch) - sp.t0
            self._stack.pop()

    @contextlib.contextmanager
    def activate(self):
        """Make this the process-wide active log for the dynamic extent:
        every module-level :func:`span` call inside records here. One
        owner at a time — activating while another log is active raises,
        enforcing the ownership rule (nested layers contribute spans via
        :func:`span` instead of owning a second log)."""
        if _ACTIVE.get() is not None:
            raise RuntimeError(
                "a SpanLog is already active; nested layers should "
                "record via span(...) instead of activating their own")
        token = _ACTIVE.set(self)
        try:
            yield self
        finally:
            _ACTIVE.reset(token)

    def to_chrome(self) -> dict:
        """Chrome trace-event JSON object: ``{"traceEvents": [...],
        "metadata": ...}`` with one complete ("X") event per closed span
        (timestamps/durations in microseconds), loadable by Perfetto and
        ``chrome://tracing``."""
        pid = os.getpid()
        events = []
        for sp in self.spans:
            if sp.dur is None:          # still open — skip, not droppable
                continue
            events.append({
                "name": sp.name, "cat": "repro", "ph": "X",
                "ts": sp.t0 * 1e6, "dur": sp.dur * 1e6,
                "pid": pid, "tid": sp.depth,
                "args": {k: v for k, v in sp.attrs.items()
                         if isinstance(v, (str, int, float, bool,
                                           type(None)))},
            })
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "metadata": self.meta}

    def save(self, trace_dir, tag: str = "run") -> pathlib.Path:
        """Write the Chrome-trace JSON to
        ``<trace_dir>/spans-<tag>-<pid>.trace.json`` and return the path
        (parent directories are created)."""
        safe = "".join(c if c.isalnum() or c in "-_." else "_"
                       for c in str(tag))
        path = pathlib.Path(trace_dir) / \
            f"spans-{safe}-{os.getpid()}.trace.json"
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_chrome(), sort_keys=True))
        return path

    def summary(self) -> dict:
        """Per-name aggregate over closed spans: ``{name: {count,
        total_ms, mean_ms}}`` — what ``obs report`` and ``summarize``
        render."""
        out: dict = {}
        for sp in self.spans:
            if sp.dur is None:
                continue
            agg = out.setdefault(sp.name, {"count": 0, "total_ms": 0.0})
            agg["count"] += 1
            agg["total_ms"] += sp.dur * 1e3
        for agg in out.values():
            agg["mean_ms"] = agg["total_ms"] / agg["count"]
        return out


class _NullSpan:
    """No-op stand-in yielded by :func:`span` when no log is active."""

    def set(self, **attrs):
        """Discard attributes (no log to record them)."""
        return self


_NULL_SPAN = _NullSpan()


def current_log() -> Optional[SpanLog]:
    """The `SpanLog` activated for the current context, or None."""
    return _ACTIVE.get()


@contextlib.contextmanager
def _null_span():
    yield _NULL_SPAN


def span(name: str, **attrs):
    """Record a span into the active log, or no-op when none is active.

    The instrumentation seam: library code (engine, sweep, scenario
    builds, the serving store, traffic replay) calls this unconditionally
    — two dict lookups and a perf_counter when a log is active, one
    contextvar read when not.
    """
    log = _ACTIVE.get()
    if log is None:
        return _null_span()
    return log.span(name, **attrs)
