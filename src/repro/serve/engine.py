"""Batched serving engine: prefill once, decode autoregressively.

``make_prefill_step`` / ``make_decode_step`` return the jittable units that
the launcher lowers for the decode-shape dry-runs (decode_32k, long_500k);
``ServeEngine`` drives them for real generation in examples/tests.

The decode step is exactly "ONE new token against a seq_len KV cache":
cache layout is preallocated to max_len, `pos` is a traced scalar.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import model as model_lib
from repro.serve import sampler as sampler_lib


def make_prefill_step(cfg):
    """Jittable prefill unit: ``(params, batch, cache) -> (last-token
    logits, filled cache)`` for one whole-prompt forward under ``cfg``."""
    def prefill_step(params, batch, cache):
        logits, cache = model_lib.prefill(params, cfg, batch, cache,
                                          last_only=True)
        return logits, cache
    return prefill_step


def make_decode_step(cfg, *, sample: str = "greedy", temp: float = 1.0):
    """Jittable decode unit: one new token against a ``max_len`` KV cache
    at traced position ``pos``, sampled greedily or by temperature."""
    def decode_step(params, cache, tokens, pos, key):
        batch = {"tokens": tokens}
        if cfg.family == "vlm":
            b = tokens.shape[0]
            batch["mrope_positions"] = jnp.broadcast_to(
                pos, (b, 1))[..., None].repeat(3, -1).astype(jnp.int32)
        logits, cache = model_lib.decode_step(params, cfg, cache, batch, pos)
        if sample == "greedy":
            next_tok = sampler_lib.greedy(logits)
        else:
            next_tok = sampler_lib.temperature(logits, key, temp)
        return next_tok, cache
    return decode_step


@dataclass
class ServeEngine:
    """Single-model autoregressive serving loop: jitted prefill once,
    then jitted one-token decode steps up to ``max_new_tokens``. (The
    personalized multi-model path is `repro.serve.personalized`.)"""
    cfg: object
    params: object
    max_len: int
    cache_dtype: object = jnp.float32
    sample: str = "greedy"
    temp: float = 1.0

    def __post_init__(self):
        self._prefill = jax.jit(make_prefill_step(self.cfg))
        self._decode = jax.jit(make_decode_step(self.cfg, sample=self.sample,
                                                temp=self.temp))

    def generate(self, batch, *, max_new_tokens: int, seed: int = 0):
        """batch: prefill inputs (tokens (b, s) etc.). Returns (b, new) i32."""
        b = next(iter(batch.values())).shape[0]
        prompt_len = batch["tokens"].shape[1] if "tokens" in batch else \
            batch["embeds"].shape[1]
        cache = model_lib.init_cache(self.cfg, b, self.max_len,
                                     dtype=self.cache_dtype)
        logits, cache = self._prefill(self.params, batch, cache)
        key = jax.random.PRNGKey(seed)
        tok = sampler_lib.greedy(logits) if self.sample == "greedy" else \
            sampler_lib.temperature(logits, key, self.temp)
        out = [tok]
        pos = jnp.int32(prompt_len)
        for i in range(max_new_tokens - 1):
            key, sub = jax.random.split(key)
            tok, cache = self._decode(self.params, cache, tok, pos + i, sub)
            out.append(tok)
        return jnp.concatenate(out, axis=1)
