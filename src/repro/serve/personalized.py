"""Batched personalized inference over (team, device)-tagged requests.

The serving half of the store (DESIGN.md §12): a
:class:`PersonalizedServer` wraps a :class:`repro.serve.store.ModelStore`
and a single-example forward function, and answers request batches where
every row carries its own ``(team, device)`` tag. One jitted program
does the whole step — tier-fallback gather of each request's personal
params (the store's in-graph decode) followed by one vmapped forward —
so a 64-request batch over 64 *different* personalized models costs one
XLA dispatch, not 64.

Two paths answer the same question two ways and must agree — bit-for-bit
under the exact encodings, to float tolerance under lossy ``int8``,
whose multiply-add decode is sensitive to XLA fusion boundaries
(tests/test_serve_store.py): :meth:`PersonalizedServer.serve` gathers
and delta-decodes every request row in-graph, while
:meth:`PersonalizedServer.serve_cached` first collapses the batch to
its unique principals, pulls each one's decoded params through the
store's host-side LRU (hot devices skip decode entirely), and stacks.
Replay traffic whose popularity is Zipf-skewed — i.e. real traffic —
mostly hits the cache; :func:`replay_traffic` generates exactly that
workload and measures p50/p95/p99 latency and queries/sec, which is
what `benchmarks/bench_serving.py` publishes to ``BENCH_serving.json``.

Serving telemetry (`repro.obs`) rides both paths: the jitted serve step
also emits per-batch tier-resolution counts (how many requests landed on
their personal model vs fell back to team / global — computed in-graph
from the same masks as the gather, so XLA shares the work), accumulated
on ``PersonalizedServer.tier_counts``; replay publishes those counts,
the LRU hit rate, raw per-batch latencies, and a gather-vs-forward stage
split into a :class:`repro.obs.metrics.MetricsRegistry` when one is
passed.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.spans import span
from repro.serve.store import ModelStore

__all__ = ["PersonalizedServer", "replay_traffic", "zipf_requests"]


class PersonalizedServer:
    """Batched tier-resolved inference in front of a :class:`ModelStore`.

    ``apply_fn(params, x) -> logits`` is the *single-example* forward for
    one model; the server vmaps it over the batch axis shared by the
    gathered params and the inputs, and jits the combined
    gather-then-forward step once per input shape.
    """

    def __init__(self, store: ModelStore, apply_fn: Callable[[Any, Any], Any]):
        """Wrap ``store`` and a single-example ``apply_fn``."""
        self.store = store
        self.apply_fn = apply_fn
        # the tier counts are extra outputs of the same jitted step —
        # they reuse the gather's validity masks, so telemetry costs a
        # couple of reductions, not a second pass over the tags
        self._step = jax.jit(
            lambda st, t, d, xs: (jax.vmap(apply_fn)(st.gather(t, d), xs),
                                  st.resolve_tiers(t, d)))
        self._fwd = jax.jit(lambda params, xs: jax.vmap(apply_fn)(params, xs))
        self.tier_counts = {"device": 0, "team": 0, "global": 0}

    def reset_tier_counts(self) -> None:
        """Zero the accumulated tier-resolution counts (call after
        warm-up so timed traffic reports clean telemetry)."""
        self.tier_counts = {"device": 0, "team": 0, "global": 0}

    def serve(self, teams, devices, xs):
        """Answer a request batch fully in-graph.

        teams/devices: ``(B,)`` int tags (out-of-range falls down the
        tier ladder — device → team → global); xs: ``(B, ...)`` inputs.
        Returns ``(B, ...)`` outputs, row ``i`` computed under request
        ``i``'s resolved personal params. Tier-resolution counts for the
        batch accumulate onto :attr:`tier_counts`.
        """
        out, tiers = self._step(self.store,
                                jnp.asarray(teams, jnp.int32),
                                jnp.asarray(devices, jnp.int32), xs)
        for k, v in tiers.items():
            self.tier_counts[k] += int(v)
        return out

    def serve_cached(self, teams, devices, xs):
        """Answer a request batch through the store's LRU hot path.

        Collapses the batch to its unique ``(team, device)`` principals,
        fetches each one's decoded params via
        :meth:`ModelStore.params_for` (LRU-cached on the host), stacks
        the unique models, and runs the same vmapped forward. Output
        matches :meth:`serve` bit-for-bit under the exact encodings
        (``"delta"``/``"raw"`` decode in integer arithmetic, immune to
        fusion) and to float tolerance under ``"int8"``; it wins when
        traffic is skewed enough that the unique count is far below the
        batch size.
        """
        t = np.asarray(teams, np.int64)
        d = np.asarray(devices, np.int64)
        # same ladder as ModelStore.resolve_tiers, host-side (the batch
        # never goes through the jitted step on this path)
        ok_t = (t >= 0) & (t < self.store.m)
        ok_d = ok_t & (d >= 0) & (d < self.store.n)
        self.tier_counts["device"] += int(ok_d.sum())
        self.tier_counts["team"] += int((ok_t & ~ok_d).sum())
        self.tier_counts["global"] += int((~ok_t).sum())
        pairs, inverse = np.unique(np.stack([t, d], axis=1), axis=0,
                                   return_inverse=True)
        per_uniq = [self.store.params_for(int(a), int(b)) for a, b in pairs]
        uniq_params = jax.tree.map(lambda *ls: jnp.stack(ls), *per_uniq)
        params = jax.tree.map(lambda l: l[jnp.asarray(inverse)], uniq_params)
        return self._fwd(params, xs)


def zipf_requests(m: int, n: int, count: int, *, alpha: float = 1.2,
                  unknown_frac: float = 0.0, seed: int = 0):
    """Zipf-skewed request tags over an ``m x n`` device population.

    Device popularity rank is drawn from a Zipf(``alpha``) law and
    mapped onto the population through a fixed random permutation (so
    the hot set is scattered across teams, not clustered in team 0). A
    ``unknown_frac`` share of requests is tagged with an out-of-range
    device (and half of those with an out-of-range team) to exercise the
    fallback ladder the way stale production IDs would. Returns
    ``(teams, devices)`` int64 arrays of length ``count``.
    """
    rng = np.random.default_rng(seed)
    population = m * n
    ranks = (rng.zipf(alpha, size=count) - 1) % population
    flat = rng.permutation(population)[ranks]
    teams, devices = flat // n, flat % n
    if unknown_frac > 0.0:
        bad = rng.random(count) < unknown_frac
        devices = np.where(bad, n + 1, devices)
        teams = np.where(bad & (rng.random(count) < 0.5), m + 1, teams)
    return teams.astype(np.int64), devices.astype(np.int64)


def replay_traffic(server: PersonalizedServer, inputs, *, requests: int = 512,
                   batch: int = 64, alpha: float = 1.2,
                   unknown_frac: float = 0.0, seed: int = 0,
                   cached: bool = False, metrics: Optional[Any] = None,
                   ) -> dict:
    """Replay Zipf-popularity traffic and measure serving latency.

    Draws ``requests`` tags via :func:`zipf_requests`, pairs each with a
    row sampled from ``inputs`` (a ``(P, ...)`` pool), and serves them
    in fixed ``batch``-size steps through :meth:`PersonalizedServer.serve`
    (or :meth:`~PersonalizedServer.serve_cached` when ``cached``). The
    first batch is replayed once untimed to absorb compilation, then the
    server's tier counters (and the store's LRU counters) are reset so
    the report covers exactly the timed traffic; each timed batch is
    ``block_until_ready``-synced. A second pass times the gather-decode
    and forward stages separately (same batches, each stage jitted and
    warmed on its own) so the latency split is visible.

    Returns a dict with ``qps``, ``p50_ms``/``p95_ms``/``p99_ms``,
    ``mean_ms``, the raw per-batch latencies (``lat_ms``, timing order),
    ``tier_counts`` (summing to ``requests``), the stage split
    (``stage_gather_ms``/``stage_forward_ms`` means), ``cache_hit_rate``
    on cached runs, the workload knobs, and the store's encoded
    device-tier size. When ``metrics`` (a
    :class:`repro.obs.metrics.MetricsRegistry`) is given, the same
    telemetry is published as counters/gauges/histograms.
    """
    store = server.store
    requests = max(batch, (requests // batch) * batch)
    n_batches = requests // batch
    teams, devices = zipf_requests(store.m, store.n, requests, alpha=alpha,
                                   unknown_frac=unknown_frac, seed=seed)
    rng = np.random.default_rng(seed + 1)
    pool = np.asarray(inputs)
    xs = jnp.asarray(pool[rng.integers(0, pool.shape[0], size=requests)])
    step = server.serve_cached if cached else server.serve

    with span("replay", requests=requests, batches=n_batches,
              cached=bool(cached)):
        jax.block_until_ready(
            step(teams[:batch], devices[:batch], xs[:batch]))
        # warm-up served the first batch once outside the timed loop —
        # drop its tier/LRU contributions so the counters below cover
        # exactly the `requests` timed requests
        server.reset_tier_counts()
        store.reset_cache_stats()
        lat = []
        t_all = time.perf_counter()
        for lo in range(0, requests, batch):
            hi = lo + batch
            with span("replay_batch", lo=lo):
                t0 = time.perf_counter()
                jax.block_until_ready(
                    step(teams[lo:hi], devices[lo:hi], xs[lo:hi]))
                lat.append(time.perf_counter() - t0)
        total = time.perf_counter() - t_all

    lat_ms = np.asarray(lat) * 1e3
    lat_sorted = np.sort(lat_ms)

    def pct(p):
        return float(lat_sorted[min(len(lat_sorted) - 1,
                                    int(np.ceil(p / 100 * len(lat_sorted)))
                                    - 1)])

    # stage split: gather-decode vs forward, timed separately over the
    # same batches (each stage warmed on its own so neither pays the
    # other's compile)
    with span("replay_stages", batches=n_batches):
        gather_fn = jax.jit(lambda st, t, d: st.gather(t, d))
        p0 = jax.block_until_ready(
            gather_fn(store, teams[:batch], devices[:batch]))
        jax.block_until_ready(server._fwd(p0, xs[:batch]))
        g_ms, f_ms = [], []
        for lo in range(0, requests, batch):
            hi = lo + batch
            t0 = time.perf_counter()
            params = jax.block_until_ready(
                gather_fn(store, teams[lo:hi], devices[lo:hi]))
            t1 = time.perf_counter()
            jax.block_until_ready(server._fwd(params, xs[lo:hi]))
            t2 = time.perf_counter()
            g_ms.append((t1 - t0) * 1e3)
            f_ms.append((t2 - t1) * 1e3)

    stats = {
        "requests": requests, "batch": batch, "alpha": alpha,
        "unknown_frac": unknown_frac, "cached": bool(cached),
        "encoding": store.encoding, "m": store.m, "n": store.n,
        "device_tier_bytes": store.device_tier_nbytes(),
        "qps": float(requests / total),
        "p50_ms": pct(50), "p95_ms": pct(95), "p99_ms": pct(99),
        "mean_ms": float(lat_ms.mean()),
        "lat_ms": [float(v) for v in lat_ms],
        "tier_counts": dict(server.tier_counts),
        "stage_gather_ms": float(np.mean(g_ms)),
        "stage_forward_ms": float(np.mean(f_ms)),
    }
    if cached:
        stats["cache_hit_rate"] = store.cache_stats()["hit_rate"]

    if metrics is not None:
        metrics.counter("serving.requests").inc(requests)
        for tier, cnt in stats["tier_counts"].items():
            metrics.counter(f"serving.tier.{tier}").inc(cnt)
        h = metrics.histogram("serving.replay.latency_ms")
        for v in lat_ms:
            h.observe(float(v))
        hg = metrics.histogram("serving.stage.gather_ms")
        hf = metrics.histogram("serving.stage.forward_ms")
        for g, f in zip(g_ms, f_ms):
            hg.observe(g)
            hf.observe(f)
        if cached:
            cs = store.cache_stats()
            metrics.counter("serving.lru.hits").inc(cs["hits"])
            metrics.counter("serving.lru.misses").inc(cs["misses"])
            metrics.gauge("serving.cache_hit_rate").set(cs["hit_rate"])
    return stats
