"""(team, device)-keyed model store for personalized serving (DESIGN.md §12).

Training ends with every device owning its own model (PerMFL's theta,
pFedMe/Ditto/L2GD's personal tier, or one shared model for the global
baselines) — this module is where those models live between training and
inference. A :class:`ModelStore` is exported from a trained state through
the ``FLAlgorithm.serving_params`` hook and holds three tiers:

* **global** — one template pytree, the last-resort fallback;
* **team** — ``(M, ...)`` stacked team anchors;
* **device** — ``(M, N, ...)`` personal models, stored as *deltas
  against the owning team's anchor* so the per-device cost is the
  residual, not a full copy.

Two delta encodings: ``"delta"`` (default) stores the *bit-pattern*
difference — the float leaves bitcast to same-width integers and
subtracted with wrapping arithmetic — so decode is exactly invertible
and a served device is bit-identical to its trained params; ``"int8"``
feeds the float residual through the fused stochastic-quantize kernel
(PR 7) for ~3.9x smaller device tiers at bounded error. ``"raw"`` keeps
full per-device copies (debug / size baseline).

Lookup resolves down the tier ladder in-graph: a request tagged with an
unknown device falls back to its team anchor, an unknown team to the
global model — out-of-range indices are clipped and masked, never an
error, because serving traffic is exactly where stale IDs show up. The
store is a registered pytree (tiers are leaves, layout is aux data), so
:meth:`ModelStore.gather` jits and batches like any other model code,
and a host-side LRU keeps hot devices' decoded params out of the decode
path entirely. Persistence rides `repro.train.checkpoint`.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.quantize.ops import dequantize_int8, quantize_int8
from repro.kernels.quantize.ref import LANES
from repro.obs.spans import span
from repro.train.checkpoint import load_checkpoint_arrays, save_checkpoint

__all__ = ["ENCODINGS", "ModelStore"]

ENCODINGS = ("delta", "int8", "raw")


def _int_twin(dtype):
    """Same-width signed integer dtype for bit-pattern arithmetic."""
    return jnp.dtype(f"int{jnp.dtype(dtype).itemsize * 8}")


def _bitcast(x, dtype):
    return jax.lax.bitcast_convert_type(x, dtype)


def _padded_len(leaf_size: int) -> int:
    return -(-leaf_size // LANES) * LANES


def _encode_device_tier(device_tree, team_tree, encoding: str):
    """device_tree: (M, N, ...) leaves; team_tree: (M, ...) anchors."""
    if encoding == "raw":
        return device_tree

    def anchor_like(dev, team):
        return jnp.broadcast_to(jnp.expand_dims(team, 1), dev.shape)

    if encoding == "delta":
        def enc(dev, team):
            a = anchor_like(dev, team)
            if jnp.issubdtype(dev.dtype, jnp.floating):
                it = _int_twin(dev.dtype)
                return _bitcast(dev, it) - _bitcast(a, it)
            return dev - a
        return jax.tree.map(enc, device_tree, team_tree)

    if encoding == "int8":
        def enc(dev, team):
            if not jnp.issubdtype(dev.dtype, jnp.floating):
                raise ValueError(
                    f"int8 encoding needs float leaves, got {dev.dtype}")
            m, n = dev.shape[:2]
            size = int(np.prod(dev.shape[2:], dtype=np.int64))
            lp = _padded_len(size)
            resid = (dev - anchor_like(dev, team)).reshape(m, n, size)
            resid = jnp.pad(resid, ((0, 0), (0, 0), (0, lp - size)))
            # noise 0.5 = deterministic round-to-nearest: the store is an
            # export artifact, not an unbiased-in-expectation uplink.
            q, scales, _ = quantize_int8(
                resid, jnp.full(resid.shape, 0.5, resid.dtype))
            return {"q": q, "scales": scales.reshape(m, n, lp // LANES)}
        return jax.tree.map(enc, device_tree, team_tree)

    raise ValueError(f"unknown encoding {encoding!r}; want one of {ENCODINGS}")


@jax.tree_util.register_pytree_node_class
class ModelStore:
    """Three-tier (global / team / device) parameter store with in-graph
    tier fallback, exported from a trained algorithm state and served
    batched (see `repro.serve.personalized`)."""

    def __init__(self, global_params, team_params, device_payload,
                 *, encoding: str, m: int, n: int, cache_size: int = 64):
        """Normally built via :meth:`from_state` / :meth:`load` rather
        than directly. ``device_payload`` is the encoded device tier:
        the template tree of bit-pattern ints for ``"delta"``, of
        ``{"q", "scales"}`` dicts for ``"int8"``, of full copies for
        ``"raw"``."""
        self.global_params = global_params
        self.team_params = team_params
        self.device_payload = device_payload
        self.encoding = encoding
        self.m = int(m)
        self.n = int(n)
        self.cache_size = int(cache_size)
        self._cache: OrderedDict = OrderedDict()
        self.cache_hits = 0
        self.cache_misses = 0

    def tree_flatten(self):
        """Pytree protocol: the three tiers are leaves; layout is aux.
        The LRU cache is host state and is reborn empty on unflatten."""
        return ((self.global_params, self.team_params, self.device_payload),
                (self.encoding, self.m, self.n, self.cache_size))

    @classmethod
    def tree_unflatten(cls, aux, children):
        """Pytree protocol: rebuild from tiers + (encoding, m, n, lru)."""
        encoding, m, n, cache_size = aux
        return cls(*children, encoding=encoding, m=m, n=n,
                   cache_size=cache_size)

    @classmethod
    def from_state(cls, algo, state, *, m: int, n: int,
                   encoding: str = "delta", cache_size: int = 64):
        """Export a trained algorithm ``state`` into a store.

        Materializes the tiers by vmapping ``algo.serving_params`` over
        ``arange(m)`` (team anchors) and ``arange(m) x arange(n)``
        (device models) — one gather per tier, no per-device Python —
        then encodes the device tier as deltas against its team anchor.
        """
        if encoding not in ENCODINGS:
            raise ValueError(
                f"unknown encoding {encoding!r}; want one of {ENCODINGS}")
        with span("store_export", encoding=encoding, m=m, n=n):
            g = jax.tree.map(jnp.asarray, algo.serving_params(state))
            team = jax.vmap(lambda t: algo.serving_params(state, t))(
                jnp.arange(m))
            dev = jax.vmap(lambda t: jax.vmap(
                lambda d: algo.serving_params(state, t, d))(
                jnp.arange(n)))(jnp.arange(m))
            payload = _encode_device_tier(dev, team, encoding)
            return cls(g, team, payload, encoding=encoding, m=m, n=n,
                       cache_size=cache_size)

    @classmethod
    def from_result(cls, algo, result, *, m: int, n: int,
                    encoding: str = "delta", cache_size: int = 64):
        """:meth:`from_state` on a finished ``FLResult.state``."""
        return cls.from_state(algo, result.state, m=m, n=n,
                              encoding=encoding, cache_size=cache_size)

    # ---------------------------------------------------------- lookup

    def _decode_rows(self, t, d, team_rows):
        """Decoded device models for index arrays ``t``/``d`` (already
        clipped in-range), given the matching gathered team anchors."""
        batch_shape = t.shape

        if self.encoding == "raw":
            return jax.tree.map(lambda l: l[t, d], self.device_payload)

        if self.encoding == "delta":
            def dec(g, tm, leaf):
                delta = leaf[t, d]
                if jnp.issubdtype(g.dtype, jnp.floating):
                    it = _int_twin(g.dtype)
                    return _bitcast(_bitcast(tm, it) + delta, g.dtype)
                return tm + delta
            return jax.tree.map(dec, self.global_params, team_rows,
                                self.device_payload)

        def dec(g, tm, pack):
            size = int(np.prod(g.shape, dtype=np.int64))
            q, scales = pack["q"][t, d], pack["scales"][t, d]
            dq = dequantize_int8(q, scales.reshape(-1))
            dq = dq.reshape(batch_shape + (-1,))[..., :size]
            return tm + dq.reshape(batch_shape + g.shape).astype(g.dtype)
        return jax.tree.map(dec, self.global_params, team_rows,
                            self.device_payload)

    def gather(self, team, device):
        """Batched tier-resolved lookup: ``(B,)`` int team/device tags in,
        ``(B, ...)``-stacked params out, fully in-graph (jit/vmap safe).

        Fallback ladder per request: in-range ``(team, device)`` → the
        decoded personal model; in-range team with unknown device → the
        team anchor; unknown team → the global model. Out-of-range
        indices are clipped for the gather and masked out of the result.
        """
        team = jnp.asarray(team, jnp.int32)
        device = jnp.asarray(device, jnp.int32)
        ok_t = (team >= 0) & (team < self.m)
        ok_d = ok_t & (device >= 0) & (device < self.n)
        t = jnp.clip(team, 0, self.m - 1)
        d = jnp.clip(device, 0, self.n - 1)
        team_rows = jax.tree.map(lambda l: l[t], self.team_params)
        dev_rows = self._decode_rows(t, d, team_rows)

        def pick(g, tm, dv):
            okd = ok_d.reshape(ok_d.shape + (1,) * g.ndim)
            okt = ok_t.reshape(ok_t.shape + (1,) * g.ndim)
            return jnp.where(okd, dv,
                             jnp.where(okt, tm,
                                       jnp.broadcast_to(g, tm.shape)))
        return jax.tree.map(pick, self.global_params, team_rows, dev_rows)

    def resolve_tiers(self, team, device):
        """Per-batch tier-resolution counts, fully in-graph.

        Returns ``{"device", "team", "global"}`` int32 scalars counting
        how many requests in the batch resolved at each tier under the
        same masks :meth:`gather` uses (XLA CSEs the shared subgraph
        when both ride one jitted step), so the three always sum to the
        batch size.
        """
        team = jnp.asarray(team, jnp.int32)
        device = jnp.asarray(device, jnp.int32)
        ok_t = (team >= 0) & (team < self.m)
        ok_d = ok_t & (device >= 0) & (device < self.n)
        return {"device": jnp.sum(ok_d.astype(jnp.int32)),
                "team": jnp.sum((ok_t & ~ok_d).astype(jnp.int32)),
                "global": jnp.sum((~ok_t).astype(jnp.int32))}

    def cache_stats(self) -> dict:
        """Host-side LRU telemetry: ``{hits, misses, hit_rate, size}``.
        ``hit_rate`` is hits / (hits + misses), 0.0 before any lookup."""
        total = self.cache_hits + self.cache_misses
        return {"hits": self.cache_hits, "misses": self.cache_misses,
                "hit_rate": self.cache_hits / total if total else 0.0,
                "size": len(self._cache)}

    def reset_cache_stats(self) -> None:
        """Zero the hit/miss counters (cached entries stay). Call after
        warm-up so timed traffic reports a clean hit rate."""
        self.cache_hits = 0
        self.cache_misses = 0

    def params_for(self, team=None, device=None):
        """Single-principal lookup with the host-side LRU in front.

        ``params_for()`` is the global model, ``params_for(t)`` the team
        anchor, ``params_for(t, d)`` the decoded personal model — each
        with the same fallback ladder as :meth:`gather`. Decoded params
        are cached (``cache_size`` hot principals, least-recently-used
        eviction), so repeat traffic skips delta decode entirely.
        """
        if team is None:
            return self.global_params
        key = (int(team), None if device is None else int(device))
        hit = self._cache.get(key)
        if hit is not None:
            self.cache_hits += 1
            self._cache.move_to_end(key)
            return hit
        self.cache_misses += 1
        t = jnp.asarray([key[0]], jnp.int32)
        d = jnp.asarray([-1 if device is None else key[1]], jnp.int32)
        val = jax.tree.map(lambda l: l[0], self.gather(t, d))
        self._cache[key] = val
        while len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)
        return val

    # ----------------------------------------------------- persistence

    def device_tier_nbytes(self) -> int:
        """On-disk footprint of the encoded device tier, in bytes."""
        return int(sum(np.asarray(l).nbytes
                       for l in jax.tree.leaves(self.device_payload)))

    def save(self, path: str):
        """Persist all three tiers + layout metadata as one checkpoint
        (`repro.train.checkpoint` zip-of-npy format)."""
        tree = {"global": self.global_params, "team": self.team_params,
                "device": self.device_payload}
        with span("store_save", encoding=self.encoding):
            save_checkpoint(path, tree, metadata={
                "kind": "model_store", "encoding": self.encoding,
                "m": self.m, "n": self.n, "cache_size": self.cache_size})

    @classmethod
    def load(cls, path: str, *, cache_size: int | None = None):
        """Rebuild a store from :meth:`save` output — no template tree
        needed; the nested layout is recovered from the manifest's key
        paths (stores are nested string-keyed mappings by construction).
        """
        with span("store_load"):
            arrays, meta = load_checkpoint_arrays(path)
            if meta.get("kind") != "model_store":
                raise ValueError(f"{path!r} is not a saved ModelStore "
                                 f"(metadata kind={meta.get('kind')!r})")
            root: dict = {}
            for key, arr in arrays.items():
                parts = key.split("/")
                d = root
                for p in parts[:-1]:
                    d = d.setdefault(p, {})
                d[parts[-1]] = jnp.asarray(arr)
            return cls(root["global"], root["team"], root["device"],
                       encoding=meta["encoding"], m=meta["m"], n=meta["n"],
                       cache_size=(meta.get("cache_size", 64)
                                   if cache_size is None else cache_size))
