"""Token samplers: greedy / temperature / top-k."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits, key=None):
    """logits: (b, 1, V) -> (b, 1) i32."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature(logits, key, temp: float = 1.0, top_k: int = 0):
    x = logits.astype(jnp.float32) / max(temp, 1e-6)
    if top_k:
        v, _ = jax.lax.top_k(x, top_k)
        cutoff = v[..., -1:]
        x = jnp.where(x < cutoff, -1e30, x)
    b, s, _ = x.shape
    flat = x.reshape(b * s, -1)
    toks = jax.random.categorical(key, flat)
    return toks.reshape(b, s).astype(jnp.int32)
