from repro.serve.engine import ServeEngine, make_decode_step, \
    make_prefill_step
from repro.serve import sampler

__all__ = ["ServeEngine", "make_decode_step", "make_prefill_step", "sampler"]
