"""Serving: deploy trained models and answer inference traffic.

Two serving shapes live here. `repro.serve.engine` is the single-model
autoregressive loop (prefill + decode against a KV cache) used by the
LLM-side examples and launcher dry-runs. `repro.serve.store` +
`repro.serve.personalized` are the *personalized* path the PerMFL
reproduction actually needs: a (team, device)-keyed :class:`ModelStore`
exported from a trained federated state, and a
:class:`PersonalizedServer` that batches requests tagged with their
principal and resolves each one down the device → team → global tier
ladder in-graph (DESIGN.md §12).
"""
from repro.serve.engine import ServeEngine, make_decode_step, \
    make_prefill_step
from repro.serve import personalized, sampler, store
from repro.serve.personalized import PersonalizedServer, replay_traffic
from repro.serve.store import ModelStore

__all__ = ["ModelStore", "PersonalizedServer", "ServeEngine",
           "make_decode_step", "make_prefill_step", "personalized",
           "replay_traffic", "sampler", "store"]
