"""Wall-clock system simulator: heterogeneous devices and links, straggler
deadlines, and time-to-accuracy (DESIGN.md §8).

The repo's other subsystems count rounds and bytes; this one converts
them into simulated *seconds*. A frozen ``SystemSpec`` models per-device
compute rates and per-tier LAN/WAN links (sampled per round, in-graph);
``simulate_round`` prices each round along the hierarchy's critical path
from the comm subsystem's static byte model, and — in deadline mode —
drops stragglers from the engine's participation masks before the
algorithm round runs. The engine assembles the emitted times into a
host-side ``Timeline`` next to the ``CommLedger``:

    from repro.scenarios import run_scenario
    res = run_scenario("table1/mnist/mclr/permfl", system="wan-cellular")
    res.sim_seconds        # cumulative simulated time at each eval point
    res.timeline.summary()

Profiles: ``uniform`` | ``lan-campus`` | ``wan-cellular`` | ``edge-iot``
(``SYSTEM_PROFILES``), each ``with_deadline(s)``-able.
"""
from repro.system.simulate import sample_links, simulate_round
from repro.system.spec import (SYSTEM_PROFILES, RoundWorkload, SystemSpec,
                               get_profile, workload_for)
from repro.system.timeline import Timeline

__all__ = ["SYSTEM_PROFILES", "RoundWorkload", "SystemSpec", "Timeline",
           "get_profile", "sample_links", "simulate_round", "workload_for"]
