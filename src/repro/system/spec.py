"""`SystemSpec` — the frozen, serializable wall-clock system model.

A system model answers "how long does one global round take" for a
hierarchy of heterogeneous devices behind heterogeneous links: every
device has a compute rate, every device<->team link is a LAN link
(bandwidth + latency), every team<->server link is a WAN link. Rates
and bandwidths are *distributions* — lognormal around the spec's means,
sampled per round from a PRNG key in-graph (``repro.system.simulate``) —
so a spec with nonzero sigmas models jitter and stragglers, and a spec
with zero sigmas is fully deterministic.

Every field except ``name`` is a float, and the spec splits exactly like
the algorithms' hyperparameters (``tree_floats``): the floats are traced
operands of the compiled round program, the zeroed ``skeleton()`` is the
static cache key. That is what lets a vmapped sweep batch *system
profiles* on the same axis as hyperparameters and seeds — three WAN
worlds in one dispatch (``train.sweep``, DESIGN.md §8).

``SYSTEM_PROFILES`` names four reference worlds: ``uniform`` (homogeneous
fast links — time is pure accounting), ``lan-campus`` (fast LAN, decent
WAN, mild compute spread), ``wan-cellular`` (cellular last hop, slow WAN,
heavy jitter), ``edge-iot`` (weak devices, thin links). ``deadline_s``
turns any of them into a straggler-dropping world (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax
import numpy as np

from repro.comm.ledger import downlink_uplink_bytes

__all__ = ["SYSTEM_PROFILES", "RoundWorkload", "SystemSpec", "get_profile",
           "workload_for"]


@dataclass(frozen=True)
class SystemSpec:
    """Per-device compute and per-tier link models, one frozen value.

    name: profile label (presentation only — excluded from ``skeleton()``
        exactly like FLScenario presentation metadata).
    compute_gflops: mean per-device compute rate, GFLOP/s.
    compute_sigma: lognormal spread of the per-device rate (0 = uniform
        fleet; ~1 = order-of-magnitude stragglers). Resampled per round.
    flops_per_param: FLOPs one local step spends per model parameter
        (forward + backward; 6 is the usual dense estimate).
    lan_mbps / lan_sigma / lan_latency_ms: device<->team link — mean
        bandwidth (megabits/s), lognormal spread, one-way latency.
    wan_mbps / wan_sigma / wan_latency_ms: team<->server link.
    deadline_s: per-round straggler deadline in simulated seconds; any
        device (or team) whose critical chain would finish after the
        deadline is dropped from the round's participation masks.
        0 disables deadlines entirely.
    """
    name: str = "uniform"
    compute_gflops: float = 10.0
    compute_sigma: float = 0.0
    flops_per_param: float = 6.0
    lan_mbps: float = 1000.0
    lan_sigma: float = 0.0
    lan_latency_ms: float = 1.0
    wan_mbps: float = 100.0
    wan_sigma: float = 0.0
    wan_latency_ms: float = 20.0
    deadline_s: float = 0.0

    def __post_init__(self):
        for f in ("compute_gflops", "flops_per_param", "lan_mbps",
                  "wan_mbps"):
            if not getattr(self, f) > 0:
                raise ValueError(f"{f} must be positive, got "
                                 f"{getattr(self, f)}")
        for f in ("compute_sigma", "lan_sigma", "wan_sigma",
                  "lan_latency_ms", "wan_latency_ms", "deadline_s"):
            if getattr(self, f) < 0:
                raise ValueError(f"{f} must be >= 0, got "
                                 f"{getattr(self, f)}")

    # -- hparam-style split (mirrors FLAlgorithmBase.tree_hparams) ----------

    def tree_floats(self):
        """(leaves, rebuild): every float field as a traced-operand dict
        plus a rebuilder. ``rebuild`` accepts traced values, so sweeps can
        stack profiles into (S,) arrays and vmap one program over them."""
        leaves = {f.name: float(getattr(self, f.name))
                  for f in dataclasses.fields(self) if f.name != "name"}

        def rebuild(values):
            return dataclasses.replace(self, **values)

        return leaves, rebuild

    def skeleton(self) -> "SystemSpec":
        """Value-independent static cache key: the spec with ``name``
        stripped and every float zeroed (bypassing validation). Two
        profiles share compiled programs iff their skeletons are equal."""
        s = object.__new__(SystemSpec)
        object.__setattr__(s, "name", "")
        for f in dataclasses.fields(self):
            if f.name != "name":
                object.__setattr__(s, f.name, 0.0)
        return s

    # -- derivation ---------------------------------------------------------

    def with_deadline(self, seconds: float) -> "SystemSpec":
        """This profile with a per-round straggler deadline attached."""
        return dataclasses.replace(self, deadline_s=float(seconds))

    # -- (de)serialization --------------------------------------------------

    def to_dict(self) -> dict:
        """Plain JSON-able dict; ``from_dict`` inverts it exactly."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "SystemSpec":
        """Rebuild a spec from ``to_dict()`` output or hand-written JSON."""
        return cls(**d)


# Four reference worlds. Bandwidths/latencies are order-of-magnitude
# realistic (gigabit campus LAN, LTE uplinks, LoRa-class IoT backhaul);
# sigmas grow as the fleet gets scrappier.
SYSTEM_PROFILES = {
    "uniform": SystemSpec(name="uniform"),
    "lan-campus": SystemSpec(
        name="lan-campus", compute_gflops=5.0, compute_sigma=0.25,
        lan_mbps=1000.0, lan_sigma=0.1, lan_latency_ms=0.5,
        wan_mbps=200.0, wan_sigma=0.1, wan_latency_ms=10.0),
    "wan-cellular": SystemSpec(
        name="wan-cellular", compute_gflops=2.0, compute_sigma=0.5,
        lan_mbps=20.0, lan_sigma=0.5, lan_latency_ms=10.0,
        wan_mbps=5.0, wan_sigma=0.5, wan_latency_ms=80.0),
    "edge-iot": SystemSpec(
        name="edge-iot", compute_gflops=0.2, compute_sigma=1.0,
        lan_mbps=8.0, lan_sigma=0.5, lan_latency_ms=5.0,
        wan_mbps=2.0, wan_sigma=0.3, wan_latency_ms=40.0),
}


def get_profile(name_or_spec) -> SystemSpec:
    """Resolve a profile name, a spec dict, or a SystemSpec to the spec
    itself (KeyError lists the registry for unknown names)."""
    if isinstance(name_or_spec, SystemSpec):
        return name_or_spec
    if isinstance(name_or_spec, dict):
        return SystemSpec.from_dict(name_or_spec)
    name = str(name_or_spec)
    if name not in SYSTEM_PROFILES:
        raise KeyError(f"unknown system profile {name!r}; "
                       f"known: {sorted(SYSTEM_PROFILES)}")
    return SYSTEM_PROFILES[name]


# ---------------------------------------------------------------------------
# per-round workload — what the simulator needs to know about an algorithm
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RoundWorkload:
    """Static per-round shape of one algorithm x model: loop counts and
    wire sizes. Hashable — part of the compiled-program cache key.

    k_team: team iterations per global round (LAN phases).
    local_steps: device SGD steps per team iteration (compute per phase).
    n_params: model parameters (the compute-work proxy).
    full_bytes / comp_bytes: fp32 downlink vs compressed uplink wire size
        of one model/delta, from the comm subsystem's static byte model —
        so every compressor changes simulated *time*, not just bytes.
    """
    k_team: int
    local_steps: int
    n_params: int
    full_bytes: int
    comp_bytes: int


def workload_for(algo, params) -> RoundWorkload:
    """Derive the RoundWorkload of one FLAlgorithm instance on a model.

    Loop counts come from the algorithm's own fields (``hp.k_team`` /
    ``hp.l_local`` for PerMFL and the hierarchical baselines,
    ``local_steps`` / ``inner_steps * local_rounds`` for the flat ones);
    wire sizes come from ``repro.comm.ledger``'s static model using the
    algorithm's CommConfig (None = fp32 both ways).
    """
    leaf_sizes = tuple(int(np.prod(l.shape, dtype=np.int64))
                       for l in jax.tree.leaves(params))
    full, comp = downlink_uplink_bytes(leaf_sizes,
                                       getattr(algo, "comm", None))
    src = getattr(algo, "hp", None) or algo
    k = int(getattr(src, "k_team", 1))
    for attr in ("l_local", "local_steps"):
        if hasattr(src, attr):
            steps = int(getattr(src, attr))
            break
    else:
        steps = int(getattr(src, "inner_steps", 1)) * \
            int(getattr(src, "local_rounds", 1))
    return RoundWorkload(k_team=k, local_steps=max(1, steps),
                         n_params=sum(leaf_sizes), full_bytes=full,
                         comp_bytes=comp)
