"""Host-side `Timeline`: per-round simulated time, next to `CommLedger`.

The split mirrors the byte accounting (DESIGN.md §3/§8): the in-graph
round program emits one simulated round time and the deadline casualty
counts as scan outputs, and the engine assembles them into a Timeline on
the host after the dispatch — nothing here runs on the hot path. Where
``CommLedger`` answers "what did the run cost in bytes", ``Timeline``
answers "what did it cost in seconds" — and joining it with a metric
history gives time-to-accuracy curves (``FLResult.sim_seconds``,
``benchmarks/fig_time_to_accuracy.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

__all__ = ["Timeline"]


@dataclass
class Timeline:
    """One run's simulated clock: per-round durations and deadline drops.

    profile: the SystemSpec's name (presentation).
    round_seconds: simulated duration of each global round.
    dropped_teams / dropped_devices: per-round counts of participants
        removed by the straggler deadline (all zeros without one).
    """
    profile: str = ""
    round_seconds: list = field(default_factory=list)
    dropped_teams: list = field(default_factory=list)
    dropped_devices: list = field(default_factory=list)

    def __len__(self):
        return len(self.round_seconds)

    def total_seconds(self) -> float:
        """Simulated wall-clock of the whole run."""
        return float(np.sum(self.round_seconds))

    def cum_seconds(self) -> np.ndarray:
        """Cumulative simulated time after each round (monotone
        non-decreasing — round durations are strictly positive)."""
        return np.cumsum(np.asarray(self.round_seconds, dtype=np.float64))

    def at_rounds(self, points) -> list:
        """Cumulative simulated seconds at each 1-based round index —
        the eval-point alignment helper behind ``FLResult.sim_seconds``
        (pass ``repro.obs.eval_points(rounds, eval_every)``)."""
        cum = self.cum_seconds()
        return [float(cum[p - 1]) for p in points]

    def stragglers(self) -> int:
        """Total device drops across the run (deadline casualties)."""
        return int(np.sum(self.dropped_devices))

    def summary(self) -> dict:
        """Flat dict of totals — benchmark CSV material."""
        rs = np.asarray(self.round_seconds, dtype=np.float64)
        return {"profile": self.profile,
                "rounds": len(self),
                "sim_seconds": float(rs.sum()),
                "mean_round_seconds": float(rs.mean()) if len(rs) else 0.0,
                "max_round_seconds": float(rs.max()) if len(rs) else 0.0,
                "dropped_teams": int(np.sum(self.dropped_teams)),
                "dropped_devices": int(np.sum(self.dropped_devices))}
