"""In-graph wall-clock simulation of one global round (DESIGN.md §8).

Everything here is traceable: the engine calls ``simulate_round`` inside
its ``lax.scan`` round body, with the SystemSpec's float leaves as traced
operands (so sweeps can vmap over system profiles) and the per-round PRNG
key from the scan carry (so timelines are deterministic given
(SystemSpec, seed) and stragglers decorrelate across rounds).

The hierarchy-aware critical-path model prices one round as

    t_round =  max_i  [ wan_lat + full_bytes / wan_bw_i ]        broadcast
             + max_i  K * max_j [ compute_ij
                                  + 2 lan_lat
                                  + (full + comp bytes) / lan_bw_ij ]
             + max_i  [ wan_lat + comp_bytes / wan_bw_i ]        uplink

with i over *participating* teams and j over *participating* devices:
the server broadcast completes when the slowest surviving team has the
model, each team repeats K LAN phases paced by its slowest surviving
device (downlink anchor + L local steps of compute + compressed uplink),
and the round closes when the slowest surviving team's compressed WAN
uplink lands. Wire sizes come from the comm subsystem's static byte
model (``RoundWorkload``), so every compressor changes *time*.

Deadline mode: when ``deadline_s > 0``, any device whose own critical
chain (its team's WAN down + its K LAN phases + its team's WAN up) would
finish after the deadline is dropped from the participation masks before
the algorithm round runs; teams whose devices all miss are dropped with
them. If everyone would miss, the single fastest chain is kept so the
round stays well-defined (``core.participation.keep_fastest``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.participation import keep_fastest
from repro.system.spec import RoundWorkload

__all__ = ["sample_links", "simulate_round"]

_MBPS_TO_BPS = 125_000.0   # megabits/s -> bytes/s


def _lognormal(key, mean, sigma, shape):
    # mean-preserving lognormal: E[mean * exp(sigma z - sigma^2/2)] = mean
    z = jax.random.normal(key, shape)
    return mean * jnp.exp(sigma * z - 0.5 * sigma * sigma)


def sample_links(leaves: dict, key, m: int, n: int):
    """One round's draws from a SystemSpec's distributions.

    leaves: the spec's ``tree_floats()`` dict (traced or concrete).
    Returns (rate (M, N) FLOP/s, lan_bps (M, N), wan_bps (M,)).
    """
    kc, kl, kw = jax.random.split(key, 3)
    rate = _lognormal(kc, leaves["compute_gflops"] * 1e9,
                      leaves["compute_sigma"], (m, n))
    lan = _lognormal(kl, leaves["lan_mbps"] * _MBPS_TO_BPS,
                     leaves["lan_sigma"], (m, n))
    wan = _lognormal(kw, leaves["wan_mbps"] * _MBPS_TO_BPS,
                     leaves["wan_sigma"], (m,))
    return rate, lan, wan


def simulate_round(leaves: dict, wl: RoundWorkload, key, team_mask,
                   device_mask):
    """Simulate one round: deadline-thinned masks + critical-path time.

    leaves: SystemSpec float leaves (traced operands).
    wl: the static RoundWorkload (loop counts, wire bytes).
    key: this round's PRNG key (fresh split from the scan carry).
    team_mask (M,) / device_mask (M, N): sampled participation in {0,1}.
        Under the virtualized cohort engine N here is the cohort width C,
        not the population — all shapes derive from the mask, so the
        round is priced over exactly the devices that were materialized.

    Returns ``(team_mask', device_mask', t_round, dropped_teams,
    dropped_devices)`` — masks after deadline drops (device mask
    team-gated), the realized round time in simulated seconds over the
    survivors, and int32 counts of deadline casualties. With
    ``deadline_s == 0`` the masks pass through bit-identically.
    """
    m, n = device_mask.shape
    rate, lan_bps, wan_bps = sample_links(leaves, key, m, n)
    lan_lat = leaves["lan_latency_ms"] * 1e-3
    wan_lat = leaves["wan_latency_ms"] * 1e-3

    work = wl.local_steps * wl.n_params * leaves["flops_per_param"]
    t_iter = (work / rate
              + 2.0 * lan_lat
              + (wl.full_bytes + wl.comp_bytes) / lan_bps)   # (M, N)
    t_down = wan_lat + wl.full_bytes / wan_bps               # (M,)
    t_up = wan_lat + wl.comp_bytes / wan_bps                 # (M,)
    chain = t_down[:, None] + wl.k_team * t_iter + t_up[:, None]

    gated = device_mask * team_mask[:, None]
    deadline = jnp.where(leaves["deadline_s"] > 0.0,
                         leaves["deadline_s"], jnp.inf)
    ok = (chain <= deadline).astype(jnp.float32)
    dm = gated * ok
    tm = team_mask * (jnp.sum(dm, axis=1) > 0).astype(jnp.float32)
    tm, dm = keep_fastest(tm, dm, chain, gated)

    t_bcast = jnp.max(t_down * tm)
    t_lan = jnp.max(wl.k_team * jnp.max(t_iter * dm, axis=1) * tm)
    t_round = t_bcast + t_lan + jnp.max(t_up * tm)

    dropped_t = (jnp.sum(team_mask) - jnp.sum(tm)).astype(jnp.int32)
    dropped_d = (jnp.sum(gated) - jnp.sum(dm)).astype(jnp.int32)
    return tm, dm, t_round, dropped_t, dropped_d
