"""Non-IID federated partitioning + team assembly (paper §4 / §D.2.7).

The paper's dissemination: each device holds data from at most
``classes_per_device`` classes (2 for MNIST-family, 3 for FEMNIST/CIFAR100);
devices are then grouped into teams, either randomly or per a team-formation
label-pool strategy (worst/average case, §4.1.4). Output is the *stacked*
layout PerMFL consumes: arrays with leading (M, N, S).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.team_formation import label_pools


@dataclass
class FederatedData:
    """Stacked train/val tensors: x (M,N,S,...) f32, y (M,N,S) i32."""
    train_x: np.ndarray
    train_y: np.ndarray
    val_x: np.ndarray
    val_y: np.ndarray

    @property
    def m_teams(self):
        return self.train_x.shape[0]

    @property
    def n_devices(self):
        return self.train_x.shape[1]

    def train_batch(self):
        return {"x": self.train_x, "y": self.train_y}

    def val_batch(self):
        return {"x": self.val_x, "y": self.val_y}


def partition_label_skew(rng: np.random.Generator, x, y, *, m_teams: int,
                         n_devices: int, classes_per_device: int = 2,
                         samples_per_device: int = 64,
                         strategy: str = "random",
                         val_fraction: float = 0.25) -> FederatedData:
    """Give each device `classes_per_device` classes drawn from its team's
    label pool, then `samples_per_device` samples of those classes
    (3:1 train/val split as in the paper)."""
    num_classes = int(y.max()) + 1
    pools = label_pools(strategy, m_teams, num_classes)
    by_class = {c: np.where(y == c)[0] for c in range(num_classes)}
    for c in by_class:
        by_class[c] = rng.permutation(by_class[c])
    cursor = {c: 0 for c in range(num_classes)}

    def take(c, n):
        idx = by_class[c]
        start = cursor[c]
        out = [idx[(start + i) % len(idx)] for i in range(n)]
        cursor[c] = (start + n) % len(idx)
        return np.array(out)

    xs = np.zeros((m_teams, n_devices, samples_per_device) + x.shape[1:],
                  np.float32)
    ys = np.zeros((m_teams, n_devices, samples_per_device), np.int32)
    for i in range(m_teams):
        pool = pools[i]
        for j in range(n_devices):
            classes = rng.choice(pool, size=min(classes_per_device,
                                                len(pool)), replace=False)
            per = samples_per_device // len(classes)
            rem = samples_per_device - per * len(classes)
            idx = np.concatenate(
                [take(c, per + (1 if k < rem else 0))
                 for k, c in enumerate(classes)])
            rng.shuffle(idx)
            xs[i, j] = x[idx]
            ys[i, j] = y[idx]

    n_val = max(1, int(samples_per_device * val_fraction))
    return FederatedData(
        train_x=xs[:, :, n_val:], train_y=ys[:, :, n_val:],
        val_x=xs[:, :, :n_val], val_y=ys[:, :, :n_val])


def partition_tabular(devices, *, m_teams: int, n_devices: int,
                      samples_per_device: int = 64,
                      val_fraction: float = 0.25) -> FederatedData:
    """Stack the per-device synthetic tabular data (truncate/cycle to a
    common per-device sample count so the stacked layout is rectangular)."""
    assert len(devices) >= m_teams * n_devices
    dim = devices[0][0].shape[1]
    xs = np.zeros((m_teams, n_devices, samples_per_device, dim), np.float32)
    ys = np.zeros((m_teams, n_devices, samples_per_device), np.int32)
    it = iter(devices)
    for i in range(m_teams):
        for j in range(n_devices):
            dx, dy = next(it)
            idx = np.resize(np.arange(len(dy)), samples_per_device)
            xs[i, j] = dx[idx]
            ys[i, j] = dy[idx]
    n_val = max(1, int(samples_per_device * val_fraction))
    return FederatedData(
        train_x=xs[:, :, n_val:], train_y=ys[:, :, n_val:],
        val_x=xs[:, :, :n_val], val_y=ys[:, :, :n_val])
