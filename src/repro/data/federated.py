"""Non-IID federated partitioning + team assembly (paper §4 / §D.2.7).

The paper's dissemination: each device holds data from at most
``classes_per_device`` classes (2 for MNIST-family, 3 for FEMNIST/CIFAR100);
devices are then grouped into teams, either randomly or per a team-formation
label-pool strategy (worst/average case, §4.1.4). Output is the *stacked*
layout PerMFL consumes: arrays with leading (M, N, S).

Beyond the paper's label-skew dissemination, two further heterogeneity
regimes are available as first-class partitioners (surfaced through the
``repro.scenarios`` registry):

  * ``partition_dirichlet`` — statistical label skew: each device's class
    mix is drawn from Dir(alpha); alpha -> 0 recovers single-class
    devices, alpha -> inf recovers IID.
  * ``partition_quantity_skew`` — quantity skew: devices hold power-law
    distributed *effective* dataset sizes (unique-sample counts) while
    the stacked layout stays rectangular.

All partitioners draw per-class samples through one shared ``_ClassPool``
that detects exhaustion: when cumulative demand for a class exceeds its
pool, samples are silently reused across devices (and potentially across
a device's train/val split), which can inflate accuracy — the pool now
warns with per-class reuse factors instead of wrapping silently.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass

import numpy as np

from repro.core.team_formation import label_pools


@dataclass
class FederatedData:
    """Stacked train/val tensors: x (M,N,S,...) f32, y (M,N,S) i32."""
    train_x: np.ndarray
    train_y: np.ndarray
    val_x: np.ndarray
    val_y: np.ndarray

    @property
    def m_teams(self):
        return self.train_x.shape[0]

    @property
    def n_devices(self):
        return self.train_x.shape[1]

    def train_batch(self):
        return {"x": self.train_x, "y": self.train_y}

    def val_batch(self):
        return {"x": self.val_x, "y": self.val_y}


class _ClassPool:
    """Per-class shuffled index pools with cumulative-demand accounting.

    ``take(c, n)`` hands out the next ``n`` indices of class ``c``,
    wrapping modulo the pool exactly like the historical inline helper
    (so existing partitions are bit-identical) — but it records how much
    of each class was consumed, and ``warn_if_exhausted`` reports any
    class whose demand exceeded its pool (i.e. samples were reused).
    """

    def __init__(self, rng: np.random.Generator, y: np.ndarray,
                 num_classes: int):
        self.by_class = {c: np.where(y == c)[0] for c in range(num_classes)}
        for c in self.by_class:
            self.by_class[c] = rng.permutation(self.by_class[c])
        self.cursor = {c: 0 for c in range(num_classes)}
        self.taken = {c: 0 for c in range(num_classes)}

    def take(self, c: int, n: int) -> np.ndarray:
        idx = self.by_class[c]
        start = self.cursor[c]
        out = [idx[(start + i) % len(idx)] for i in range(n)]
        self.cursor[c] = (start + n) % len(idx)
        self.taken[c] += n
        return np.array(out)

    def warn_if_exhausted(self, where: str) -> None:
        reused = {c: self.taken[c] / len(self.by_class[c])
                  for c in self.taken
                  if self.taken[c] > len(self.by_class[c])}
        if reused:
            detail = ", ".join(f"class {c}: {r:.1f}x its pool of "
                               f"{len(self.by_class[c])}"
                               for c, r in sorted(reused.items()))
            warnings.warn(
                f"{where}: class pool(s) exhausted — samples are reused "
                f"across devices (and possibly across a device's "
                f"train/val split), which can inflate accuracy ({detail}). "
                f"Grow the dataset (n_per_class) or shrink "
                f"samples_per_device.", UserWarning, stacklevel=3)


def _split_train_val(xs, ys, samples_per_device: int, val_fraction: float):
    """First n_val samples of each device are validation (3:1 split as in
    the paper); per-device order was shuffled by the partitioner."""
    n_val = max(1, int(samples_per_device * val_fraction))
    return FederatedData(
        train_x=xs[:, :, n_val:], train_y=ys[:, :, n_val:],
        val_x=xs[:, :, :n_val], val_y=ys[:, :, :n_val])


def stack_virtual(xs, ys, *, samples_per_device: int,
                  val_fraction: float = 0.25) -> FederatedData:
    """Wrap pre-stacked (M, N, S, ...) arrays — e.g. from
    ``repro.data.synthetic.virtual_tabular`` — as FederatedData with the
    standard 3:1 train/val split. The cohort-scale path: no per-device
    partitioning loop ever touches the population."""
    return _split_train_val(xs, ys, samples_per_device, val_fraction)


def partition_label_skew(rng: np.random.Generator, x, y, *, m_teams: int,
                         n_devices: int, classes_per_device: int = 2,
                         samples_per_device: int = 64,
                         strategy: str = "random",
                         val_fraction: float = 0.25) -> FederatedData:
    """Give each device `classes_per_device` classes drawn from its team's
    label pool, then `samples_per_device` samples of those classes
    (3:1 train/val split as in the paper)."""
    num_classes = int(y.max()) + 1
    pools = label_pools(strategy, m_teams, num_classes)
    pool = _ClassPool(rng, y, num_classes)

    xs = np.zeros((m_teams, n_devices, samples_per_device) + x.shape[1:],
                  np.float32)
    ys = np.zeros((m_teams, n_devices, samples_per_device), np.int32)
    for i in range(m_teams):
        team_pool = pools[i]
        for j in range(n_devices):
            classes = rng.choice(team_pool,
                                 size=min(classes_per_device,
                                          len(team_pool)), replace=False)
            per = samples_per_device // len(classes)
            rem = samples_per_device - per * len(classes)
            idx = np.concatenate(
                [pool.take(c, per + (1 if k < rem else 0))
                 for k, c in enumerate(classes)])
            rng.shuffle(idx)
            xs[i, j] = x[idx]
            ys[i, j] = y[idx]
    pool.warn_if_exhausted("partition_label_skew")
    return _split_train_val(xs, ys, samples_per_device, val_fraction)


def partition_dirichlet(rng: np.random.Generator, x, y, *, m_teams: int,
                        n_devices: int, alpha: float = 0.5,
                        samples_per_device: int = 64,
                        strategy: str = "random",
                        val_fraction: float = 0.25) -> FederatedData:
    """Dirichlet label skew: each device's class proportions are drawn
    from Dir(alpha) over its team's label pool, then its
    ``samples_per_device`` samples follow that multinomial mix.

    alpha -> 0 concentrates each device on ~1 class (harsher than the
    paper's fixed 2-class skew); alpha -> inf approaches IID devices.
    The team-formation ``strategy`` composes as in
    ``partition_label_skew`` (worst/average restrict team pools).
    """
    if alpha <= 0.0:
        raise ValueError(f"dirichlet alpha must be > 0, got {alpha}")
    num_classes = int(y.max()) + 1
    pools = label_pools(strategy, m_teams, num_classes)
    pool = _ClassPool(rng, y, num_classes)

    xs = np.zeros((m_teams, n_devices, samples_per_device) + x.shape[1:],
                  np.float32)
    ys = np.zeros((m_teams, n_devices, samples_per_device), np.int32)
    for i in range(m_teams):
        team_pool = list(pools[i])
        for j in range(n_devices):
            p = rng.dirichlet(np.full(len(team_pool), alpha))
            counts = rng.multinomial(samples_per_device, p)
            idx = np.concatenate(
                [pool.take(c, k)
                 for c, k in zip(team_pool, counts) if k > 0])
            rng.shuffle(idx)
            xs[i, j] = x[idx]
            ys[i, j] = y[idx]
    pool.warn_if_exhausted("partition_dirichlet")
    return _split_train_val(xs, ys, samples_per_device, val_fraction)


def partition_quantity_skew(rng: np.random.Generator, x, y, *,
                            m_teams: int, n_devices: int,
                            samples_per_device: int = 64,
                            min_frac: float = 0.25,
                            val_fraction: float = 0.25) -> FederatedData:
    """Quantity skew: devices draw power-law *unique*-sample counts.

    Each device holds ``u`` unique samples (IID over classes) with
    ``u`` power-law distributed in [max(n_val+1, min_frac*S), S]; the
    stacked layout stays rectangular by cycling the device's *train*
    uniques to fill its train slots. Validation rows are always unique
    and never appear among the train rows, so train/val stay disjoint
    per device — the heterogeneity is purely in effective dataset size.
    """
    if not 0.0 < min_frac <= 1.0:
        raise ValueError(f"min_frac must be in (0, 1], got {min_frac}")
    S = samples_per_device
    n_val = max(1, int(S * val_fraction))
    lo = max(n_val + 1, int(np.ceil(min_frac * S)))
    if lo > S:
        raise ValueError(
            f"samples_per_device={S} too small for val_fraction="
            f"{val_fraction} (needs > {n_val + 1} unique samples)")

    order = rng.permutation(len(y))       # one global shuffled pool
    cursor = 0

    # power-law unique counts: most devices near `lo`, a heavy tail at S
    u_frac = rng.power(0.4, size=(m_teams, n_devices))
    uniques = (lo + np.round(u_frac * (S - lo))).astype(int)
    if int(uniques.sum()) > len(order):   # realized demand, not the bound
        warnings.warn(
            f"partition_quantity_skew: devices draw {int(uniques.sum())} "
            f"unique samples from a pool of {len(order)} — the pool wraps "
            f"and samples are reused across devices, which can inflate "
            f"accuracy. Grow the dataset or shrink samples_per_device.",
            UserWarning, stacklevel=2)

    xs = np.zeros((m_teams, n_devices, S) + x.shape[1:], np.float32)
    ys = np.zeros((m_teams, n_devices, S), np.int32)
    for i in range(m_teams):
        for j in range(n_devices):
            u = int(uniques[i, j])
            idx = np.array([order[(cursor + k) % len(order)]
                            for k in range(u)])
            cursor += u
            # val: first n_val uniques; train: remaining uniques cycled
            train_u = idx[n_val:]
            fill = train_u[np.resize(np.arange(len(train_u)), S - n_val)]
            rng.shuffle(fill)
            dev = np.concatenate([idx[:n_val], fill])
            xs[i, j] = x[dev]
            ys[i, j] = y[dev]
    return _split_train_val(xs, ys, S, val_fraction)


def partition_tabular(devices, *, m_teams: int, n_devices: int,
                      samples_per_device: int = 64,
                      val_fraction: float = 0.25) -> FederatedData:
    """Stack the per-device synthetic tabular data (truncate/cycle to a
    common per-device sample count so the stacked layout is rectangular)."""
    assert len(devices) >= m_teams * n_devices
    dim = devices[0][0].shape[1]
    xs = np.zeros((m_teams, n_devices, samples_per_device, dim), np.float32)
    ys = np.zeros((m_teams, n_devices, samples_per_device), np.int32)
    it = iter(devices)
    for i in range(m_teams):
        for j in range(n_devices):
            dx, dy = next(it)
            idx = np.resize(np.arange(len(dy)), samples_per_device)
            xs[i, j] = dx[idx]
            ys[i, j] = dy[idx]
    return _split_train_val(xs, ys, samples_per_device, val_fraction)
