from repro.data import federated, synthetic, tokens
from repro.data.federated import FederatedData, partition_label_skew, \
    partition_tabular
from repro.data.synthetic import make_dataset, synthetic_images, \
    synthetic_tabular

__all__ = ["federated", "synthetic", "tokens", "FederatedData",
           "partition_label_skew", "partition_tabular", "make_dataset",
           "synthetic_images", "synthetic_tabular"]
