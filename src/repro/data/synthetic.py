"""Synthetic datasets.

``synthetic_tabular`` reproduces the paper's Synthetic dataset exactly as
specified (§D.2.6 / Li et al. [36] "Federated optimization in heterogeneous
networks"): 60 features, 10 classes, per-device model heterogeneity
controlled by alpha-bar and data heterogeneity by beta-bar (both 0.5 in the
paper), device sample sizes drawn from a power law.

``synthetic_images`` stands in for MNIST/FMNIST/EMNIST in this offline
container: class-conditional 28x28 images (a class-specific low-rank
template + noise) with the same shapes, class counts, and separability
ordering; the paper's numbers are quoted alongside for qualitative
comparison (DESIGN.md §2).

``virtual_tabular`` is the cohort-scale variant of the feature-shift
construction: fully vectorized (no per-device Python loop) so the
virtualized cohort engine's 10^4-10^6 devices-per-team scenarios
(DESIGN.md §11) can materialize their populations in milliseconds.
"""
from __future__ import annotations

import numpy as np


def synthetic_tabular(rng: np.random.Generator, n_devices: int, *,
                      alpha: float = 0.5, beta: float = 0.5,
                      dim: int = 60, num_classes: int = 10,
                      min_samples: int = 250, max_samples: int = 25_810):
    """Returns list of (x (S,60) f32, y (S,) i32) per device."""
    # power-law sample sizes (Li et al. use lognormal; power law per §D.2.6)
    sizes = (np.random.default_rng(rng.integers(1 << 31))
             .pareto(1.2, n_devices) + 1)
    sizes = sizes / sizes.max()
    sizes = (min_samples + sizes * (max_samples - min_samples)).astype(int)
    sizes = np.clip(sizes, min_samples, max_samples)

    # global feature covariance: diag(j^-1.2)
    cov_diag = np.arange(1, dim + 1, dtype=np.float64) ** -1.2
    devices = []
    for i in range(n_devices):
        b_i = rng.normal(0, alpha)            # model heterogeneity
        u_i = rng.normal(0, beta)             # data heterogeneity
        v_i = rng.normal(u_i, 1.0, dim)       # device feature mean
        w_i = rng.normal(b_i, 1.0, (dim, num_classes))
        c_i = rng.normal(b_i, 1.0, num_classes)
        x = rng.normal(v_i, np.sqrt(cov_diag), (sizes[i], dim))
        logits = x @ w_i + c_i
        y = np.argmax(logits, axis=1)
        devices.append((x.astype(np.float32), y.astype(np.int32)))
    return devices


def feature_shift_tabular(rng: np.random.Generator, m_teams: int,
                          n_devices: int, *, dim: int = 60,
                          num_classes: int = 10, shift: float = 2.0,
                          samples_per_device: int = 64):
    """Feature-shift (covariate-shift) tabular devices: one *shared*
    labeling concept, team-specific feature distributions.

    A single global linear model labels every sample, so P(y|x) is
    identical across the federation; each team draws its features around
    a team-specific mean offset of magnitude ``shift`` (devices jitter
    slightly around their team's mean). Larger ``shift`` pushes teams
    into disjoint regions of feature space — the regime where per-team /
    per-device personalization pays even though the concept is shared
    (cf. the shared/personal split of Distributed Personalized Empirical
    Risk Minimization).

    Returns a team-major list of ``m_teams * n_devices`` devices, each
    ``(x (S, dim) f32, y (S,) i32)`` — stack with ``partition_tabular``.
    """
    w = rng.normal(0, 1, (dim, num_classes))
    c = rng.normal(0, 1, num_classes)
    cov_diag = np.arange(1, dim + 1, dtype=np.float64) ** -1.2
    devices = []
    for _ in range(m_teams):
        mu_team = rng.normal(0, shift, dim)       # team feature shift
        for _ in range(n_devices):
            v = mu_team + rng.normal(0, 0.1, dim)  # small device jitter
            x = rng.normal(v, np.sqrt(cov_diag), (samples_per_device, dim))
            y = np.argmax(x @ w + c, axis=1)
            devices.append((x.astype(np.float32), y.astype(np.int32)))
    return devices


def virtual_tabular(rng: np.random.Generator, m_teams: int,
                    n_devices: int, *, dim: int = 60,
                    num_classes: int = 10, shift: float = 2.0,
                    samples_per_device: int = 8):
    """Cohort-scale feature-shift tabular federation, fully vectorized.

    Same construction as ``feature_shift_tabular`` — one shared labeling
    concept, team-shifted feature means, small per-device jitter — but
    every tier is drawn in a handful of broadcasted numpy calls instead
    of a per-device Python loop, so materializing the 10^4-10^6 devices
    per team the virtualized cohort engine targets (DESIGN.md §11)
    takes milliseconds, not minutes. Noise is drawn directly in float32
    to halve the transient footprint at population scale.

    Returns stacked arrays ``(x (M, N, S, dim) f32, y (M, N, S) i32)``;
    feed them to ``repro.data.federated.stack_virtual`` for the
    train/val split.
    """
    w = rng.normal(0, 1, (dim, num_classes)).astype(np.float32)
    c = rng.normal(0, 1, num_classes).astype(np.float32)
    scale = (np.arange(1, dim + 1, dtype=np.float64) ** -0.6
             ).astype(np.float32)                     # sqrt of diag(j^-1.2)
    mu_team = rng.normal(0, shift, (m_teams, 1, 1, dim)).astype(np.float32)
    v = mu_team + rng.standard_normal(
        (m_teams, n_devices, 1, dim), dtype=np.float32) * 0.1
    x = v + rng.standard_normal(
        (m_teams, n_devices, samples_per_device, dim),
        dtype=np.float32) * scale
    y = np.argmax(x @ w + c, axis=-1)
    return x, y.astype(np.int32)


def synthetic_images(rng: np.random.Generator, n_per_class: int, *,
                     num_classes: int = 10, shape=(28, 28, 1),
                     noise: float = 0.35, rank: int = 6,
                     class_sep: float = 0.35):
    """Class-conditional image generator: (x (C*n, *shape), y).

    Templates share a common base and differ by a `class_sep`-scaled
    deviation, so the 10-way global problem is genuinely hard at moderate
    noise while any 2-way per-device problem stays much easier — the
    structure that produces the paper's PM >> GM gap under label skew.
    """
    h, w, c = shape
    base_rng = np.random.default_rng(999)
    ub = base_rng.normal(0, 1, (h, rank))
    vb = base_rng.normal(0, 1, (rank, w))
    xs, ys = [], []
    for cls in range(num_classes):
        crng = np.random.default_rng(1000 + cls)  # fixed per-class templates
        u = ub + class_sep * crng.normal(0, 1, (h, rank))
        v = vb + class_sep * crng.normal(0, 1, (rank, w))
        template = np.tanh(u @ v / np.sqrt(rank))
        x = template[None, :, :, None] + rng.normal(0, noise,
                                                    (n_per_class, h, w, c))
        xs.append(x.astype(np.float32))
        ys.append(np.full(n_per_class, cls, np.int32))
    x = np.concatenate(xs)
    y = np.concatenate(ys)
    perm = rng.permutation(len(y))
    return x[perm], y[perm]


DATASETS = {
    # name -> (input_shape, num_classes) matching the paper's suite
    "mnist": ((28, 28, 1), 10),
    "fmnist": ((28, 28, 1), 10),
    "emnist10": ((28, 28, 1), 10),
    "femnist": ((28, 28, 1), 62),
    "cifar100": ((32, 32, 3), 100),
    "synthetic": ((60,), 10),
    "virtual": ((60,), 10),
}


def make_dataset(name: str, rng: np.random.Generator, n_per_class: int = 300):
    shape, ncls = DATASETS[name]
    if name == "synthetic":
        raise ValueError("use synthetic_tabular for the tabular dataset")
    if name == "virtual":
        raise ValueError("use virtual_tabular for the cohort-scale "
                         "tabular dataset")
    # different dataset name -> different noise level => different
    # difficulty ordering (mnist < emnist10 < fmnist, like the real suite)
    noise = {"mnist": 0.80, "fmnist": 1.10, "emnist10": 0.95,
             "femnist": 1.00, "cifar100": 1.30}[name]
    return synthetic_images(rng, n_per_class, num_classes=ncls, shape=shape,
                            noise=noise)
