"""Synthetic token streams for LM training (offline container).

A fixed-seed Zipfian n-gram process: structured enough that a model's loss
decreases during the example training runs, cheap enough to generate on the
fly. Also provides the federated variant: per-device token streams with
device-specific topic mixtures (the LM analogue of label skew).
"""
from __future__ import annotations

import numpy as np


def zipf_bigram_stream(rng: np.random.Generator, vocab_size: int,
                       length: int, *, topic: int = 0, n_topics: int = 8):
    """Token stream from a topic-dependent bigram chain."""
    # deterministic per-(vocab,topic) transition structure
    base = np.random.default_rng(123 + topic)
    # each token maps to a small successor set; topic shifts the mapping
    succ = base.integers(0, vocab_size, size=(vocab_size, 4))
    probs = np.array([0.5, 0.25, 0.15, 0.1])
    out = np.empty(length, np.int32)
    tok = int(rng.integers(0, vocab_size))
    for i in range(length):
        out[i] = tok
        if rng.random() < 0.1:        # restart with zipf marginal
            tok = min(vocab_size - 1, int(rng.zipf(1.3)) - 1)
        else:
            tok = int(succ[tok, rng.choice(4, p=probs)])
    return out


def lm_batches(rng: np.random.Generator, vocab_size: int, *, batch: int,
               seq_len: int, steps: int, topic: int = 0):
    """Yields {"tokens", "targets"} batches."""
    stream = zipf_bigram_stream(rng, vocab_size,
                                batch * (seq_len + 1) * steps + 1,
                                topic=topic)
    for s in range(steps):
        off = s * batch * (seq_len + 1)
        chunk = stream[off:off + batch * (seq_len + 1) + 1]
        tok = np.stack([chunk[i * (seq_len + 1):(i + 1) * (seq_len + 1)]
                        for i in range(batch)])
        yield {"tokens": tok[:, :-1].astype(np.int32),
               "targets": tok[:, 1:].astype(np.int32)}


def federated_lm_data(rng: np.random.Generator, vocab_size: int, *,
                      m_teams: int, n_devices: int, seq_len: int,
                      seqs_per_device: int):
    """Stacked (M, N, S, seq) token tensors; team i uses topic i."""
    toks = np.zeros((m_teams, n_devices, seqs_per_device, seq_len + 1),
                    np.int32)
    for i in range(m_teams):
        for j in range(n_devices):
            stream = zipf_bigram_stream(
                rng, vocab_size, seqs_per_device * (seq_len + 1) + 1,
                topic=i)
            toks[i, j] = stream[:seqs_per_device * (seq_len + 1)].reshape(
                seqs_per_device, seq_len + 1)
    return {"tokens": toks[..., :-1], "targets": toks[..., 1:]}
