"""Team formation strategies for the §4.1.4 ablation.

The paper: teams are static; PerMFL accommodates any formation mechanism.
  * worst    — teams own disjoint label groups (team 1: {0..4}, team 2:
               {5..9}) — maximal inter-team heterogeneity.
  * average  — overlapping label groups (team 1: {0..6}, team 2:
               {5..9,0,1}).
  * random   — devices shuffled into teams regardless of labels (the
               default of §4's main experiments).

These return, for each team, the *label pool* its devices draw from;
repro.data.federated partitions samples accordingly.
"""
from __future__ import annotations

import numpy as np


def label_pools(strategy: str, m_teams: int, num_classes: int,
                overlap: int = 2):
    if strategy == "worst":
        per = num_classes // m_teams
        return [list(range(i * per, (i + 1) * per)) +
                (list(range(m_teams * per, num_classes)) if i == m_teams - 1
                 else [])
                for i in range(m_teams)]
    if strategy == "average":
        per = num_classes // m_teams
        pools = []
        for i in range(m_teams):
            base = [(i * per + j) % num_classes for j in range(per + overlap)]
            pools.append(sorted(set(base)))
        return pools
    if strategy == "random":
        return [list(range(num_classes)) for _ in range(m_teams)]
    raise ValueError(strategy)


def assign_devices(rng: np.random.Generator, m_teams: int, n_devices: int):
    """Random grouping of M*N device ids into M teams (paper §4: 'devices
    were randomly grouped into four teams')."""
    ids = rng.permutation(m_teams * n_devices)
    return ids.reshape(m_teams, n_devices)
