"""Team/device participation sampling — the paper's four modes (§3.1):

  1. full teams, full devices
  2. full teams, partial devices
  3. partial teams, full devices
  4. partial teams, partial devices

Masks are sampled per global round; at least one team (and one device per
participating team) is always kept so the round is well-defined.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_masks(key, m_teams: int, n_devices: int, *,
                 team_frac: float = 1.0, device_frac: float = 1.0):
    """Returns (team_mask (M,), device_mask (M, N)) f32 in {0, 1}."""
    k1, k2 = jax.random.split(key)
    n_t = max(1, round(m_teams * team_frac))
    n_d = max(1, round(n_devices * device_frac))

    t_perm = jax.random.permutation(k1, m_teams)
    team_mask = jnp.zeros((m_teams,), jnp.float32).at[t_perm[:n_t]].set(1.0)

    def one_team(k):
        perm = jax.random.permutation(k, n_devices)
        return jnp.zeros((n_devices,), jnp.float32).at[perm[:n_d]].set(1.0)

    device_mask = jax.vmap(one_team)(jax.random.split(k2, m_teams))
    device_mask = device_mask * team_mask[:, None]
    return team_mask, device_mask


def sample_cohort(key, m_teams: int, n_devices: int, cohort_size: int):
    """Per-team cohort indices for the virtualized engine (DESIGN.md §11).

    Returns an (M, cohort_size) i32 index map: for each team, a sorted
    uniform sample of ``cohort_size`` distinct device slots out of the
    ``n_devices`` resident in the store. Sorting makes the map canonical
    (gather/scatter order-independent) and means ``cohort_size ==
    n_devices`` degenerates to ``arange(n_devices)`` — an identity
    gather, which is what makes the full-population equivalence in
    tests/test_cohort_engine.py *bit*-exact rather than approximate.

    The engine derives ``key`` by folding a salt into the round's mask
    key, so consuming cohort indices never advances the participation
    mask stream (see ``_COHORT_SALT`` in repro.train.engine).

    Sampled as the top-``cohort_size`` of N iid uniforms per team (the
    Gumbel-top-k trick degenerated to uniform weights) rather than
    ``jax.random.permutation``: a full random permutation runs several
    sort rounds over the population and dominates the round at
    N >= 10^4, while one uniform draw + ``lax.top_k`` keeps per-round
    sampling cost negligible up to N = 10^6.
    """
    def one_team(k):
        z = jax.random.uniform(k, (n_devices,))
        return jnp.sort(jax.lax.top_k(z, cohort_size)[1]).astype(jnp.int32)

    return jax.vmap(one_team)(jax.random.split(key, m_teams))


def keep_fastest(team_mask, device_mask, score, candidates):
    """Guarantee a non-empty round after mask-thinning (e.g. deadline
    straggler drops, `repro.system`): if ``device_mask * team_mask[:,N]``
    kept nobody, fall back to the single (team, device) pair with the
    smallest ``score`` among ``candidates`` — the same "at least one
    participant" contract ``sample_masks`` provides by construction.

    team_mask (M,) / device_mask (M, N): the thinned masks.
    score (M, N): per-device priority (lower wins), e.g. chain times.
    candidates (M, N): {0,1} mask of pairs eligible for the fallback.
    Returns (team_mask, device_mask) with device_mask team-gated.
    """
    gated = device_mask * team_mask[:, None]
    alive = jnp.sum(gated) > 0
    masked = jnp.where(candidates > 0, score, jnp.inf)
    idx = jnp.argmin(masked.reshape(-1))
    one = jnp.zeros((masked.size,), jnp.float32).at[idx].set(1.0)
    one = one.reshape(masked.shape)
    fb_tm = jnp.clip(jnp.sum(one, axis=1), 0.0, 1.0)
    return (jnp.where(alive, team_mask, fb_tm),
            jnp.where(alive, gated, one))


MODES = {
    "full": dict(team_frac=1.0, device_frac=1.0),
    "partial_devices": dict(team_frac=1.0, device_frac=0.5),
    "partial_teams": dict(team_frac=0.5, device_frac=1.0),
    "partial_both": dict(team_frac=0.5, device_frac=0.5),
}
