"""PerMFL — Algorithm 1 of the paper, as a fully-jitted stacked simulator.

State layout ("stacked FL"): device models are a pytree whose leaves carry
leading axes (M, N, ...) — M teams x N devices — team models carry (M, ...),
and the global model is unstacked. Device-local steps are vmapped over
(M, N); team aggregation is a (masked) mean over N; global aggregation a
(masked) mean over M. Under pjit the (M, N) axes shard over the
(pod, data) mesh axes, which maps the paper's WAN/LAN communication
hierarchy onto DCN/ICI (DESIGN.md §2).

One call = one global round t:

    w_i^{t,0} = x^t
    repeat K:  theta^{k,0} = w^k;  L prox-SGD device steps (eq. 4, the
               fused kernel);  team update (eq. 9)
    x^{t+1} = (1 - beta*gamma) x^t + beta*gamma * mean_i w_i^{t,K}  (eq. 13)
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.kernels.prox_update import prox_sgd_tree


@dataclass(frozen=True)
class PerMFLHParams:
    alpha: float = 0.01      # device LR
    eta: float = 0.03        # team LR
    beta: float = 0.6        # server LR
    lam: float = 0.5         # device<->team proximity (lambda)
    gamma: float = 1.5       # team<->global proximity (gamma)
    k_team: int = 10         # K: team iterations per global round
    l_local: int = 20        # L: device iterations per team iteration
    momentum: float = 0.0    # optional heavy-ball on the device step
    weight_decay: float = 0.0


@jax.tree_util.register_pytree_node_class
@dataclass
class PerMFLState:
    """x: global model; w: (M, ...); theta: (M, N, ...)."""
    x: Any
    w: Any
    theta: Any
    round: jnp.ndarray  # scalar i32

    def tree_flatten(self):
        return (self.x, self.w, self.theta, self.round), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_state(params, m_teams: int, n_devices: int) -> PerMFLState:
    """All tiers initialized from a single model (Algorithm 1, init)."""
    def bc(x, lead):
        return jnp.broadcast_to(x[(None,) * len(lead)], lead + x.shape).copy()
    w = jax.tree.map(lambda p: bc(p, (m_teams,)), params)
    theta = jax.tree.map(lambda p: bc(p, (m_teams, n_devices)), params)
    return PerMFLState(x=params, w=w, theta=theta, round=jnp.int32(0))


def _masked_mean(tree, mask, axis, fallback=None):
    """Mean over `axis` weighted by mask; if the mask is all-zero along the
    axis, fall back to `fallback` (or the unmasked mean)."""
    denom = mask.sum(axis=axis)

    def leaf(x, fb):
        extra = x.ndim - mask.ndim
        m = mask.reshape(mask.shape + (1,) * extra)
        num = (x * m).sum(axis=axis)
        d = denom.reshape(denom.shape + (1,) * (num.ndim - denom.ndim))
        mean = num / jnp.maximum(d, 1.0)
        if fb is not None:
            take = (d > 0)
            mean = jnp.where(take, mean, fb)
        return mean

    if fallback is None:
        return jax.tree.map(lambda x: leaf(x, None), tree)
    return jax.tree.map(leaf, tree, fallback)


@functools.partial(
    jax.jit,
    static_argnames=("loss_fn", "hp", "m_teams", "n_devices"))
def permfl_round(state: PerMFLState, data, hp: PerMFLHParams,
                 loss_fn: Callable, *, m_teams: int, n_devices: int,
                 team_mask=None, device_mask=None):
    """One global round.

    data: pytree of arrays with leading (M, N, ...) — each device's (full)
        batch; loss_fn(params, device_batch) -> scalar.
    team_mask: (M,) f32 in {0,1}; device_mask: (M, N) f32. None = full
        participation (paper's default mode 1).
    """
    if team_mask is None:
        team_mask = jnp.ones((m_teams,), jnp.float32)
    if device_mask is None:
        device_mask = jnp.ones((m_teams, n_devices), jnp.float32)

    x = state.x
    grad_fn = jax.grad(loss_fn)
    per_device_grad = jax.vmap(jax.vmap(grad_fn))

    def device_loop(theta, w):
        """L prox-SGD steps (eq. 4), vmapped over (M, N)."""
        anchor = jax.tree.map(
            lambda wl: jnp.broadcast_to(
                wl[:, None], (m_teams, n_devices) + wl.shape[1:]), w)

        def one_step(_, carry):
            theta, mom = carry
            g = per_device_grad(theta, data)
            theta, mom = prox_sgd_tree(
                theta, g, anchor, mom, alpha=hp.alpha, lam=hp.lam,
                momentum=hp.momentum, weight_decay=hp.weight_decay)
            return theta, mom

        mom0 = jax.tree.map(lambda t: jnp.zeros(t.shape, jnp.float32), theta)
        theta, _ = jax.lax.fori_loop(0, hp.l_local, one_step, (theta, mom0))
        return theta

    def team_iter(k, carry):
        """One team round: re-init theta from w, L device steps, eq. 9."""
        w, _ = carry
        theta = jax.tree.map(
            lambda wl: jnp.broadcast_to(
                wl[:, None], (m_teams, n_devices) + wl.shape[1:]).copy(), w)
        theta = device_loop(theta, w)
        theta_bar = _masked_mean(theta, device_mask, axis=1, fallback=w)
        c = 1.0 - hp.eta * hp.lam - hp.eta * hp.gamma
        w = jax.tree.map(
            lambda wl, xl, tb: c * wl + hp.eta * hp.gamma * xl[None]
            + hp.lam * hp.eta * tb,
            w, x, theta_bar)
        return w, theta

    # w_i^{t,0} = x^t
    w0 = jax.tree.map(
        lambda xl: jnp.broadcast_to(xl[None], (m_teams,) + xl.shape).copy(), x)
    theta0 = state.theta
    w, theta = jax.lax.fori_loop(0, hp.k_team, team_iter, (w0, theta0))

    # eq. 13 (global) — non-participating teams keep w out of the average,
    # and also do not move (their w snaps back to x next round anyway).
    w_eff = jax.tree.map(
        lambda wl, old: jnp.where(
            team_mask.reshape((-1,) + (1,) * (wl.ndim - 1)) > 0, wl, old),
        w, state.w)
    w_bar = _masked_mean(w_eff, team_mask, axis=0,
                         fallback=x)
    x_new = jax.tree.map(
        lambda xl, wb: (1.0 - hp.beta * hp.gamma) * xl
        + hp.beta * hp.gamma * wb, x, w_bar)

    # devices/teams that did not participate keep their previous theta/w
    th_eff = jax.tree.map(
        lambda t_new, t_old: jnp.where(
            device_mask.reshape(device_mask.shape +
                                (1,) * (t_new.ndim - 2)) > 0, t_new, t_old),
        theta, state.theta)

    return PerMFLState(x=x_new, w=w_eff, theta=th_eff,
                       round=state.round + 1)


# ---------------------------------------------------------------------------
# Evaluation helpers
# ---------------------------------------------------------------------------

def eval_stacked(state: PerMFLState, data, metric_fn, *, which: str = "pm"):
    """metric_fn(params, batch) -> scalar; data leading (M, N, ...).

    which: 'pm'  — per-device personalized models theta_ij on their data
           'tm'  — team models w_i on each device's data
           'gm'  — global model x on each device's data
    Returns (M, N) matrix of metric values.
    """
    if which == "pm":
        return jax.vmap(jax.vmap(metric_fn))(state.theta, data)
    if which == "tm":
        f = jax.vmap(lambda w, d: jax.vmap(lambda dd: metric_fn(w, dd))(d))
        return f(state.w, data)
    if which == "gm":
        return jax.vmap(jax.vmap(lambda d: metric_fn(state.x, d)))(data)
    raise ValueError(which)
