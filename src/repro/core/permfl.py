"""PerMFL — Algorithm 1 of the paper, as a fully-jitted stacked simulator.

State layout ("stacked FL"): device models are a pytree whose leaves carry
leading axes (M, N, ...) — M teams x N devices — team models carry (M, ...),
and the global model is unstacked. Device-local steps are vmapped over
(M, N); team aggregation is a (masked) mean over N; global aggregation a
(masked) mean over M. Under pjit the (M, N) axes shard over the
(pod, data) mesh axes, which maps the paper's WAN/LAN communication
hierarchy onto DCN/ICI (DESIGN.md §2).

One call = one global round t:

    w_i^{t,0} = x^t
    repeat K:  theta^{k,0} = w^k;  L prox-SGD device steps (eq. 4, the
               fused kernel);  team update (eq. 9)
    x^{t+1} = (1 - beta*gamma) x^t + beta*gamma * mean_i w_i^{t,K}  (eq. 13)
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.comm import (CommConfig, CommState, compress_tree,
                        compress_tree_ef, init_comm_state)
from repro.kernels.interface import dispatch_key
from repro.kernels.prox_update import prox_sgd_tree


# The sweepable hyperparameters: the float knobs the paper's Fig 3 / §D.4
# grids vary. They are the pytree *leaves* of PerMFLHParams, so a jitted
# round traced once serves every value (and run_sweep can vmap a whole
# grid); the loop bounds (k_team, l_local) and the structural knobs
# (momentum, weight_decay — they select kernel branches) stay static.
SWEEPABLE_HPARAMS = ("alpha", "eta", "beta", "lam", "gamma")


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class PerMFLHParams:
    """Algorithm 1 hyperparameters (paper §3 / Theorem 1 notation).

    A frozen dataclass registered as a pytree: the SWEEPABLE_HPARAMS
    floats flatten to traced leaves (so compiled rounds are shared across
    values and grids vmap), while k_team / l_local / momentum /
    weight_decay ride in the static treedef. Instances built from plain
    floats stay hashable and usable as cache keys.
    """
    alpha: float = 0.01      # device LR
    eta: float = 0.03        # team LR
    beta: float = 0.6        # server LR
    lam: float = 0.5         # device<->team proximity (lambda)
    gamma: float = 1.5       # team<->global proximity (gamma)
    k_team: int = 10         # K: team iterations per global round
    l_local: int = 20        # L: device iterations per team iteration
    momentum: float = 0.0    # optional heavy-ball on the device step
    weight_decay: float = 0.0

    def tree_flatten(self):
        """Sweepable floats as children; loop bounds/branch knobs as aux."""
        children = tuple(getattr(self, k) for k in SWEEPABLE_HPARAMS)
        aux = (self.k_team, self.l_local, self.momentum, self.weight_decay)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        k_team, l_local, momentum, weight_decay = aux
        return cls(*children, k_team=k_team, l_local=l_local,
                   momentum=momentum, weight_decay=weight_decay)


@jax.tree_util.register_pytree_node_class
@dataclass
class PerMFLState:
    """x: global model; w: (M, ...); theta: (M, N, ...); comm: optional
    CommState (per-tier error-feedback residuals) when compression is on."""
    x: Any
    w: Any
    theta: Any
    round: jnp.ndarray  # scalar i32
    comm: Optional[CommState] = None

    def tree_flatten(self):
        return (self.x, self.w, self.theta, self.round, self.comm), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_state(params, m_teams: int, n_devices: int,
               comm: Optional[CommConfig] = None) -> PerMFLState:
    """All tiers initialized from a single model (Algorithm 1, init)."""
    def bc(x, lead):
        return jnp.broadcast_to(x[(None,) * len(lead)], lead + x.shape).copy()
    w = jax.tree.map(lambda p: bc(p, (m_teams,)), params)
    theta = jax.tree.map(lambda p: bc(p, (m_teams, n_devices)), params)
    cs = None if comm is None else init_comm_state(params, m_teams,
                                                   n_devices, comm)
    return PerMFLState(x=params, w=w, theta=theta, round=jnp.int32(0),
                       comm=cs)


def _keep_where(mask, new_tree, old_tree):
    """Leaf-wise participation gate: keep `new` where the leading-axes
    mask is set, else `old`. mask shape is a prefix of every leaf shape."""
    def leaf(n, o):
        m = mask.reshape(mask.shape + (1,) * (n.ndim - mask.ndim))
        return jnp.where(m > 0, n, o)
    return jax.tree.map(leaf, new_tree, old_tree)


def _masked_mean(tree, mask, axis, fallback=None):
    """Mean over `axis` weighted by mask; if the mask is all-zero along the
    axis, fall back to `fallback` (or the unmasked mean)."""
    denom = mask.sum(axis=axis)

    def leaf(x, fb):
        extra = x.ndim - mask.ndim
        m = mask.reshape(mask.shape + (1,) * extra)
        num = (x * m).sum(axis=axis)
        d = denom.reshape(denom.shape + (1,) * (num.ndim - denom.ndim))
        mean = num / jnp.maximum(d, 1.0)
        if fb is not None:
            take = (d > 0)
            mean = jnp.where(take, mean, fb)
        return mean

    if fallback is None:
        return jax.tree.map(lambda x: leaf(x, None), tree)
    return jax.tree.map(leaf, tree, fallback)


def normalize_masks(team_mask, device_mask, m_teams: int, n_devices: int):
    """None -> all-ones participation arrays. Masks always enter the jitted
    round as (M,) / (M, N) f32 arrays so a single trace serves every
    participation pattern (full rounds and team_frac<1 rounds alike)."""
    if team_mask is None:
        team_mask = jnp.ones((m_teams,), jnp.float32)
    if device_mask is None:
        device_mask = jnp.ones((m_teams, n_devices), jnp.float32)
    return jnp.asarray(team_mask, jnp.float32), \
        jnp.asarray(device_mask, jnp.float32)


def permfl_round(state: PerMFLState, data, hp: PerMFLHParams,
                 loss_fn: Callable, *, m_teams: int, n_devices: int,
                 team_mask=None, device_mask=None,
                 comm: Optional[CommConfig] = None):
    """One global round.

    data: pytree of arrays with leading (M, N, ...) — each device's (full)
        batch; loss_fn(params, device_batch) -> scalar.
    team_mask: (M,) f32 in {0,1}; device_mask: (M, N) f32. None = full
        participation (paper's default mode 1). Masks are normalized to
        arrays here, at the boundary, so flipping between None and arrays
        across rounds never re-traces the compiled round.
    comm: optional CommConfig. When given, the device->team theta deltas
        (each team iteration) and the team->server w deltas (once per
        round) cross their links compressed, with per-sender error
        feedback carried in state.comm; local/personalized models stay
        exact (DESIGN.md §3).
    """
    if comm is not None and state.comm is None:
        raise ValueError("comm config given but state carries no CommState; "
                         "build the state with init_state(..., comm=cfg)")
    team_mask, device_mask = normalize_masks(team_mask, device_mask,
                                             m_teams, n_devices)
    return _permfl_round(state, data, hp, loss_fn, m_teams=m_teams,
                         n_devices=n_devices, team_mask=team_mask,
                         device_mask=device_mask, comm=comm,
                         kdispatch=dispatch_key())


# hp is NOT static: its float leaves trace, so one compiled round serves
# every hyperparameter value (fig3's 9-point grid used to pay 9 compiles)
# and run_sweep can vmap a stacked grid through the same program.
# kdispatch (the KernelType/fused pair from dispatch_key()) is a pure
# cache salt: kernel choices are read from the environment at trace time,
# so it must ride the jit key or flipping REPRO_KERNEL_MODE between
# calls would silently reuse a stale trace.
@functools.partial(
    jax.jit,
    static_argnames=("loss_fn", "m_teams", "n_devices", "comm", "kdispatch"))
def _permfl_round(state: PerMFLState, data, hp: PerMFLHParams,
                  loss_fn: Callable, *, m_teams: int, n_devices: int,
                  team_mask, device_mask,
                  comm: Optional[CommConfig] = None, kdispatch=None):
    x = state.x
    grad_fn = jax.grad(loss_fn)
    per_device_grad = jax.vmap(jax.vmap(grad_fn))
    if comm is not None:
        round_key = jax.random.fold_in(state.comm.key, state.round)
        # devices of masked-out teams may run locally but never transmit:
        # their EF residuals must not record undelivered messages, even if
        # the caller passed masks that disagree.
        ef_gate = device_mask * team_mask[:, None]

    def bcast_n(w):
        return jax.tree.map(
            lambda wl: jnp.broadcast_to(
                wl[:, None], (m_teams, n_devices) + wl.shape[1:]), w)

    def device_loop(theta, w):
        """L prox-SGD steps (eq. 4), vmapped over (M, N)."""
        anchor = bcast_n(w)

        def one_step(_, carry):
            theta, mom = carry
            g = per_device_grad(theta, data)
            theta, mom = prox_sgd_tree(
                theta, g, anchor, mom, alpha=hp.alpha, lam=hp.lam,
                momentum=hp.momentum, weight_decay=hp.weight_decay)
            return theta, mom

        mom0 = jax.tree.map(lambda t: jnp.zeros(t.shape, jnp.float32), theta)
        theta, _ = jax.lax.fori_loop(0, hp.l_local, one_step, (theta, mom0))
        return theta

    def run_devices(w):
        """Re-init theta from w (LAN downlink), L device steps."""
        theta = jax.tree.map(
            lambda wl: jnp.broadcast_to(
                wl[:, None], (m_teams, n_devices) + wl.shape[1:]).copy(), w)
        return device_loop(theta, w)

    def team_update(w, theta_bar):
        c = 1.0 - hp.eta * hp.lam - hp.eta * hp.gamma
        return jax.tree.map(
            lambda wl, xl, tb: c * wl + hp.eta * hp.gamma * xl[None]
            + hp.lam * hp.eta * tb,
            w, x, theta_bar)

    def team_iter(k, carry):
        """One team round: re-init theta from w, L device steps, eq. 9."""
        w, _ = carry
        theta = run_devices(w)
        theta_bar = _masked_mean(theta, device_mask, axis=1, fallback=w)
        return team_update(w, theta_bar), theta

    def team_iter_comm(k, carry):
        """team_iter with a compressed device->team uplink: each device
        ships C(theta - w + ef); the team aggregates the decompressed
        deltas on top of the anchor w it already holds. With error
        feedback on, the EF add and residual update are fused into the
        compression kernels (compress_tree_ef)."""
        w, _, ef_dev = carry
        theta = run_devices(w)
        anchor = bcast_n(w)
        kk = jax.random.fold_in(round_key, k)
        if comm.error_feedback:
            delta = jax.tree.map(lambda t, a: t - a, theta, anchor)
            chat, ef_new = compress_tree_ef(comm, kk, delta, ef_dev,
                                            (m_teams, n_devices))
            ef_dev = _keep_where(ef_gate, ef_new, ef_dev)
        else:
            msg = jax.tree.map(lambda t, a, e: t - a + e,
                               theta, anchor, ef_dev)
            chat = compress_tree(comm, kk, msg, (m_teams, n_devices))
        theta_hat = jax.tree.map(lambda a, ch: a + ch, anchor, chat)
        theta_bar = _masked_mean(theta_hat, device_mask, axis=1, fallback=w)
        return team_update(w, theta_bar), theta, ef_dev

    # w_i^{t,0} = x^t
    w0 = jax.tree.map(
        lambda xl: jnp.broadcast_to(xl[None], (m_teams,) + xl.shape).copy(), x)
    theta0 = state.theta
    if comm is None:
        w, theta = jax.lax.fori_loop(0, hp.k_team, team_iter, (w0, theta0))
    else:
        w, theta, ef_dev = jax.lax.fori_loop(
            0, hp.k_team, team_iter_comm, (w0, theta0, state.comm.ef_dev))

    # eq. 13 (global) — non-participating teams keep w out of the average,
    # and also do not move (their w snaps back to x next round anyway).
    w_eff = _keep_where(team_mask, w, state.w)
    if comm is None:
        w_bar = _masked_mean(w_eff, team_mask, axis=0, fallback=x)
        comm_state = state.comm
    else:
        # team->server WAN uplink: each team ships C(w - x + ef); the
        # server reconstructs w_hat = x + C(...) against the x it holds.
        # Masked-out teams need no substitute value — the masked mean
        # zeroes their contribution.
        ef_team = state.comm.ef_team
        kk = jax.random.fold_in(round_key, hp.k_team)
        if comm.error_feedback:
            delta = jax.tree.map(lambda wl, xl: wl - xl[None], w, x)
            chat, ef_new = compress_tree_ef(comm, kk, delta, ef_team,
                                            (m_teams,))
            ef_team = _keep_where(team_mask, ef_new, ef_team)
        else:
            msg = jax.tree.map(lambda wl, xl, e: wl - xl[None] + e,
                               w, x, ef_team)
            chat = compress_tree(comm, kk, msg, (m_teams,))
        w_hat = jax.tree.map(lambda xl, ch: xl[None] + ch, x, chat)
        w_bar = _masked_mean(w_hat, team_mask, axis=0, fallback=x)
        comm_state = CommState(ef_dev=ef_dev, ef_team=ef_team,
                               key=state.comm.key)
    x_new = jax.tree.map(
        lambda xl, wb: (1.0 - hp.beta * hp.gamma) * xl
        + hp.beta * hp.gamma * wb, x, w_bar)

    # devices/teams that did not participate keep their previous theta/w
    th_eff = _keep_where(device_mask, theta, state.theta)

    return PerMFLState(x=x_new, w=w_eff, theta=th_eff,
                       round=state.round + 1, comm=comm_state)


# ---------------------------------------------------------------------------
# Evaluation helpers
# ---------------------------------------------------------------------------

def tier_norms(state: PerMFLState):
    """The drift quantities the paper's rates are stated in, per tier:
    ``(pers_gap, tier_drift)`` where ``pers_gap`` is the (M, N) matrix of
    personalization gaps ``||theta_ij - w_i||`` and ``tier_drift`` the
    (M,) vector of team-vs-server drifts ``||w_i - x||``. Traceable —
    the engine's probe path calls this inside the scanned round body."""
    from repro.obs.probes import stacked_sq_norm

    gap = jax.tree.map(lambda t, wl: t - wl[:, None], state.theta, state.w)
    drift = jax.tree.map(lambda wl, xl: wl - xl[None], state.w, state.x)
    return jnp.sqrt(stacked_sq_norm(gap, 2)), \
        jnp.sqrt(stacked_sq_norm(drift, 1))


def eval_stacked(state: PerMFLState, data, metric_fn, *, which: str = "pm"):
    """metric_fn(params, batch) -> scalar; data leading (M, N, ...).

    which: 'pm'  — per-device personalized models theta_ij on their data
           'tm'  — team models w_i on each device's data
           'gm'  — global model x on each device's data
    Returns (M, N) matrix of metric values.
    """
    if which == "pm":
        return jax.vmap(jax.vmap(metric_fn))(state.theta, data)
    if which == "tm":
        f = jax.vmap(lambda w, d: jax.vmap(lambda dd: metric_fn(w, dd))(d))
        return f(state.w, data)
    if which == "gm":
        return jax.vmap(jax.vmap(lambda d: metric_fn(state.x, d)))(data)
    raise ValueError(which)
