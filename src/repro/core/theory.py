"""Theorem 1/2 hyperparameter bounds — implementation guidance from §3.3.

Given smoothness/strong-convexity constants of the device losses, these
helpers return the admissible step sizes and the K/L schedules the theory
requires (K = Omega(T), L = Omega(K)). The MCLR model with l2 regularizer
sigma has mu_f = sigma and L_f <= max_eig(X^T X)/n + sigma, so the
strongly-convex experiments can be run strictly inside the theory.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class TheoryBounds:
    alpha_max: float
    eta_max: float
    beta_max: float
    mu_f_tilde_big: float      # mu_{F~} (strong convexity of the envelope)
    gamma_ok: bool             # gamma > 2*lambda > 4*L_f
    rate: float                # contraction factor per global round (sc case)


def strongly_convex_bounds(mu_f: float, l_f: float, lam: float,
                           gamma: float) -> TheoryBounds:
    """Theorem 1: beta <= mu_F~/(4 gamma), eta <= 1/(2(lam+gamma)),
    alpha <= 1/(L_f + lam), gamma > 2 lam > 4 L_f."""
    mu_ft = (lam * gamma * mu_f) / (lam * mu_f + gamma * mu_f + lam * gamma)
    beta_max = mu_ft / (4.0 * gamma)
    return TheoryBounds(
        alpha_max=1.0 / (l_f + lam),
        eta_max=1.0 / (2.0 * (lam + gamma)),
        beta_max=beta_max,
        mu_f_tilde_big=mu_ft,
        gamma_ok=(gamma > 2.0 * lam > 4.0 * l_f),
        rate=1.0 - beta_max,
    )


def nonconvex_bounds(l_f: float, lam: float, gamma: float) -> TheoryBounds:
    """Theorem 2: beta <= 1/(4 gamma), eta <= 1/(lam+gamma),
    alpha <= 1/lam, gamma > 2 lam > 4 L_f."""
    return TheoryBounds(
        alpha_max=1.0 / lam,
        eta_max=1.0 / (lam + gamma),
        beta_max=1.0 / (4.0 * gamma),
        mu_f_tilde_big=0.0,
        gamma_ok=(gamma > 2.0 * lam > 4.0 * l_f),
        rate=float("nan"),
    )


def inner_iteration_schedule(t_rounds: int, *, mu_f: float, l_f: float,
                             lam: float, gamma: float, alpha: float,
                             eta: float, beta: float,
                             c_k: float = 1.0, c_l: float = 1.0):
    """K = Omega(T), L = Omega(K) with the log-ratio slopes of eqs. (58)
    and (61): K >= ln(1 - beta*mu_F~/2)/ln(1 - eta*(mu_F+gamma)/2) * T and
    L >= ln(1 - eta*(mu_F+gamma)/2)/ln(1 - alpha*mu_f) * K (constants c_K,
    c_L absorb the Gamma terms)."""
    mu_big_f = lam * mu_f / (lam + mu_f)
    mu_ft = (lam * gamma * mu_f) / (lam * mu_f + gamma * mu_f + lam * gamma)
    k_slope = math.log(max(1e-12, 1 - beta * mu_ft / 2)) / \
        math.log(max(1e-12, 1 - eta * (mu_big_f + gamma) / 2))
    l_slope = math.log(max(1e-12, 1 - eta * (mu_big_f + gamma) / 2)) / \
        math.log(max(1e-12, 1 - alpha * (mu_f + lam)))
    k = max(1, math.ceil(c_k * k_slope * t_rounds))
    l = max(1, math.ceil(c_l * l_slope * k))
    return k, l


def mclr_constants(x_data: np.ndarray, l2_reg: float):
    """(mu_f, L_f) for l2-regularized multinomial logistic regression.

    CE-softmax Hessian is bounded by 0.5 * max_eig(X^T X / n); with the l2
    term, mu_f = l2_reg, L_f = 0.5 * eig_max + l2_reg.
    """
    xf = np.asarray(x_data, np.float64).reshape(x_data.shape[0], -1)
    n = xf.shape[0]
    cov = xf.T @ xf / n
    eig_max = float(np.linalg.eigvalsh(cov).max())
    return l2_reg, 0.5 * eig_max + l2_reg


def pick_hparams_strongly_convex(mu_f: float, l_f: float, *,
                                 safety: float = 1.0):
    """A theory-consistent default hyperparameter set: the paper requires
    gamma > 2 lam > 4 L_f; we take lam = 2.5 L_f, gamma = 2.5 lam and the
    max admissible step sizes scaled by `safety`."""
    lam = 2.5 * l_f
    gamma = 2.5 * lam
    b = strongly_convex_bounds(mu_f, l_f, lam, gamma)
    return {
        "lam": lam, "gamma": gamma,
        "alpha": safety * b.alpha_max,
        "eta": safety * b.eta_max,
        "beta": safety * b.beta_max,
    }
