"""Unified FL algorithm API — one protocol, seven implementations.

Every algorithm in the repo (PerMFL and the six Table-1 baselines) is a
stateless *instance* capturing its hyperparameters and loss function, and
exposing three pure methods the engine (`repro.train.engine`) drives:

    init_state(params, m, n)       -> state pytree (stacked tiers)
    round(state, data, team_mask=, device_mask=) -> new state
    eval(state, train_data, val_data, metric_fn) -> {metric: scalar}

``round`` must be traceable: the engine calls it inside ``jax.lax.scan``
under a single ``jit``, so one compiled program covers the whole
experiment instead of one host dispatch per round. Masks are always (M,)
/ (M, N) f32 arrays (the engine normalizes/samples them in-graph);
algorithms without a participation notion ignore them. ``eval`` returns a
dict of scalar metrics (keys among "pm" / "tm" / "gm" / "train_loss") and
also runs traced, so it compiles once per experiment instead of being
re-dispatched eagerly every eval round.

Byte accounting stays on the host: algorithms that move compressed bytes
implement ``make_ledger`` / ``log_comm_round`` and the engine feeds them
the *realized* participation counts it emitted as scan outputs
(DESIGN.md §5).

Implementations are *frozen* dataclasses: the engine caches compiled
programs keyed on the instance, so configuration must be immutable —
change a hyperparameter by constructing a new instance, never by
mutating one (mutation raises FrozenInstanceError).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.comm import CommConfig, CommLedger, CommState
from repro.core import permfl as P
from repro.obs.health import nonfinite_count
from repro.obs.probes import (masked_max, masked_mean, stacked_sq_norm,
                              tree_diff_norm)

__all__ = ["FLAlgorithm", "FLAlgorithmBase", "PerMFL", "eval_global",
           "eval_personal"]


@runtime_checkable
class FLAlgorithm(Protocol):
    """Structural type the engine drives; see module docstring.

    Implementations are frozen dataclasses named by ``name``; state is an
    arbitrary pytree with stacked (M, ...) / (M, N, ...) tiers; masks are
    (M,) / (M, N) f32 participation arrays.
    """
    name: str

    def init_state(self, params, m: int, n: int) -> Any:
        """Build the initial state pytree from one model for M teams x N
        devices."""
        ...

    def round(self, state, data, *, team_mask, device_mask) -> Any:
        """One traceable global round: state + (M, N, ...) data batches +
        participation masks -> new state."""
        ...

    def eval(self, state, train_data, val_data,
             metric_fn: Callable) -> dict:
        """Traced metrics: {'pm'|'tm'|'gm'|'train_loss': scalar}."""
        ...


class FLAlgorithmBase:
    """Defaults: no participation support (round ignores the masks — the
    engine refuses team_frac/device_frac < 1 so FLResult.participation
    never reports sampling that didn't happen), no comm ledger, and a
    generic float-field hyperparameter split for sweeps."""

    supports_participation = False

    def make_ledger(self, params) -> Optional[CommLedger]:
        """Host-side byte ledger for this config, or None (no comm
        accounting). params: an (unstacked) model pytree giving the wire
        leaf sizes."""
        return None

    def log_comm_round(self, ledger: CommLedger, *, n_teams: int,
                       n_devices: int) -> None:
        """Account one round's bytes from realized (team-gated)
        participation counts. No-op unless the algorithm moves bytes."""
        pass

    def probe_round(self, prev_state, state, data, *, team_mask,
                    device_mask, trace):
        """Traced per-round scalar diagnostics (`repro.obs`): called by
        the engine's round body right after ``round`` when a
        `TraceConfig` is active, returning ``{name: f32 scalar}`` probe
        values that ride the scan outputs. Pure measurement — reads the
        states, never changes them.

        Default: the whole-state update norm (``trace.grads``).
        Algorithms with tiered state override to add drift / residual /
        loss probes.
        """
        out = {}
        if trace.grads:
            out["update_norm"] = tree_diff_norm(prev_state, state)
        return out

    def health_round(self, prev_state, state, data, *, team_mask,
                     device_mask, trace):
        """Traced per-round health detectors (`repro.obs.health`): called
        by the engine's round body when ``trace.health`` is on, returning
        ``{name: f32 scalar}`` values where > 0 means "this round is
        bad". Same purity contract as ``probe_round`` — detectors only
        read the states, so health-on is trajectory-bit-identical and
        health-off is program-byte-identical.

        Default: counts of non-finite entries in the post-round state
        and in the round's update (delta vs ``prev_state``) — the delta
        catches an inf-minus-inf that cancels back to a finite state.
        Algorithms with a cheap loss at hand override to add an
        explosion flag against ``trace.health_loss_max``.
        """
        delta = jax.tree.map(
            lambda a, b: jnp.asarray(b) - jnp.asarray(a)
            if jnp.issubdtype(jnp.asarray(a).dtype, jnp.inexact) else a,
            prev_state, state)
        return {"nonfinite_params": nonfinite_count(state),
                "nonfinite_update": nonfinite_count(delta)}

    def serving_params(self, state, team=None, device=None):
        """The model this algorithm serves to one principal — the export
        hook the personalized serving subsystem (`repro.serve.store`,
        DESIGN.md §12) builds its (team, device)-keyed `ModelStore`
        from. Tier selection by argument:

            serving_params(state)              -> global-tier model
            serving_params(state, t)           -> team t's model
            serving_params(state, t, d)        -> device (t, d)'s model

        ``team`` / ``device`` may be traced indices, so the exporter can
        vmap the hook over ``arange(m)`` x ``arange(n)`` and materialize
        whole tiers as one gather. Default: the state *is* one global
        model served to everybody (FedAvg / h-SGD / Per-FedAvg — the
        latter personalizes at eval time from data, which a parameter
        store cannot carry). Personalized algorithms override to route
        the personal tier.
        """
        return state

    def device_axes(self, state, m: int, n: int):
        """Which state leaves are device-tier, i.e. stacked (M, N, ...)
        per (team, device) — the split the virtualized cohort engine
        uses to decide what lives in the `DeviceStateStore` and rides
        each round's gather/scatter (DESIGN.md §11).

        Returns a pytree of bools matching ``state``'s structure: True
        leaves are gathered to cohort width per round, False leaves
        (team/global tiers, counters, PRNG keys) stay resident at full
        shape. The default is a shape heuristic — a leaf is device-tier
        iff its leading axes are exactly (m, n) — which is ambiguous
        when a trailing dimension collides with n, so stateful
        algorithms override it with their explicit tier split.
        """
        return jax.tree.map(
            lambda l: bool(getattr(l, "ndim", 0) >= 2
                           and l.shape[:2] == (m, n)), state)

    def tree_hparams(self):
        """Split this config into sweepable leaves vs static structure.

        Returns ``(leaves, rebuild)`` where ``leaves`` maps hyperparameter
        name -> float for every float field of the dataclass (ints — loop
        bounds — and callables stay static), and ``rebuild(values)``
        returns an equivalent instance with those fields replaced.
        ``rebuild`` accepts traced values, so ``run_sweep`` can stack a
        grid into (S,) arrays and vmap one compiled program over it; the
        rebuilt instance is only ever used inside that trace, never as a
        compilation-cache key.
        """
        # select by annotation, not value type: a float-annotated field
        # passed an int literal (lr=1) must still sweep; coercing also
        # keeps the hparam skeleton cache key value-normalized
        leaves = {f.name: float(getattr(self, f.name))
                  for f in dataclasses.fields(self)
                  if f.type in (float, "float")}

        def rebuild(values):
            return dataclasses.replace(self, **values)

        return leaves, rebuild


# ---------------------------------------------------------------------------
# metric helpers shared by the implementations
# ---------------------------------------------------------------------------

def eval_global(x, val_data, metric_fn):
    """Unstacked model x evaluated on every device's data; scalar mean."""
    return jax.vmap(jax.vmap(lambda d: metric_fn(x, d)))(val_data).mean()


def eval_personal(theta, val_data, metric_fn):
    """(M, N, ...) stacked models on their own devices' data; scalar mean."""
    return jax.vmap(jax.vmap(metric_fn))(theta, val_data).mean()


# ---------------------------------------------------------------------------
# PerMFL as an FLAlgorithm
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PerMFL(FLAlgorithmBase):
    """Algorithm 1 (core.permfl) behind the unified API.

    comm: optional CommConfig — uplinks cross compressed with per-sender
    error feedback; the engine accounts bytes via make_ledger /
    log_comm_round from realized (gated) participation counts.
    """
    loss_fn: Callable
    hp: P.PerMFLHParams
    comm: Optional[CommConfig] = None

    name = "permfl"
    supports_participation = True   # paper modes 1-4 (§3.1)

    def init_state(self, params, m: int, n: int) -> P.PerMFLState:
        """All tiers (x / w / theta) broadcast from one model; EF
        residuals zeroed when comm is configured."""
        return P.init_state(params, m, n, comm=self.comm)

    def round(self, state, data, *, team_mask, device_mask):
        """One Algorithm-1 global round (K team iters x L device steps)."""
        m, n = device_mask.shape
        return P.permfl_round(state, data, self.hp, self.loss_fn,
                              m_teams=m, n_devices=n, team_mask=team_mask,
                              device_mask=device_mask, comm=self.comm)

    def tree_hparams(self):
        """Sweepable leaves live one level down, inside ``hp``: the
        SWEEPABLE_HPARAMS floats (alpha/eta/beta/lam/gamma). k_team and
        l_local are loop bounds, momentum/weight_decay kernel-branch
        selectors — all static structure."""
        leaves = {k: float(getattr(self.hp, k))
                  for k in P.SWEEPABLE_HPARAMS}

        def rebuild(values):
            return dataclasses.replace(
                self, hp=dataclasses.replace(self.hp, **values))

        return leaves, rebuild

    def eval(self, state, train_data, val_data, metric_fn):
        """PM/TM/GM mean accuracy over all devices + mean train loss."""
        return {
            "pm": P.eval_stacked(state, val_data, metric_fn,
                                 which="pm").mean(),
            "tm": P.eval_stacked(state, val_data, metric_fn,
                                 which="tm").mean(),
            "gm": P.eval_stacked(state, val_data, metric_fn,
                                 which="gm").mean(),
            "train_loss": jax.vmap(jax.vmap(self.loss_fn))(
                state.theta, train_data).mean(),
        }

    def probe_round(self, prev_state, state, data, *, team_mask,
                    device_mask, trace):
        """PerMFL's full probe set on top of the generic update norm: the
        personalization gap and tier drift Theorems 1-2 bound (mean/max
        over participants), the post-round device gradient norm,
        per-tier error-feedback residual norms (compressed runs), and
        the participation-weighted train loss."""
        out = super().probe_round(prev_state, state, data,
                                  team_mask=team_mask,
                                  device_mask=device_mask, trace=trace)
        gated = device_mask * team_mask[:, None]
        if trace.drift:
            gap, drift = P.tier_norms(state)      # (M, N), (M,)
            out["pers_gap_mean"] = masked_mean(gap, gated)
            out["pers_gap_max"] = masked_max(gap, gated)
            out["tier_drift_mean"] = masked_mean(drift, team_mask)
            out["tier_drift_max"] = masked_max(drift, team_mask)
        if trace.grads:
            g = jax.vmap(jax.vmap(jax.grad(self.loss_fn)))(state.theta,
                                                           data)
            out["grad_norm"] = masked_mean(
                jnp.sqrt(stacked_sq_norm(g, 2)), gated)
        if trace.residuals and state.comm is not None:
            out["ef_dev_norm"] = masked_mean(
                jnp.sqrt(stacked_sq_norm(state.comm.ef_dev, 2)),
                gated)
            out["ef_team_norm"] = masked_mean(
                jnp.sqrt(stacked_sq_norm(state.comm.ef_team, 1)),
                team_mask)
        if trace.loss:
            losses = jax.vmap(jax.vmap(self.loss_fn))(state.theta, data)
            out["part_loss"] = masked_mean(losses, gated)
        return out

    def health_round(self, prev_state, state, data, *, team_mask,
                     device_mask, trace):
        """Generic nonfinite detectors plus a loss-explosion flag: the
        participation-weighted personalized train loss trips when it
        goes non-finite or exceeds ``trace.health_loss_max``."""
        out = super().health_round(prev_state, state, data,
                                   team_mask=team_mask,
                                   device_mask=device_mask, trace=trace)
        gated = device_mask * team_mask[:, None]
        losses = jax.vmap(jax.vmap(self.loss_fn))(state.theta, data)
        ploss = masked_mean(losses, gated)
        out["loss_exploded"] = (
            (~jnp.isfinite(ploss))
            | (ploss > trace.health_loss_max)).astype(jnp.float32)
        return out

    def serving_params(self, state, team=None, device=None):
        """Full three-tier serving: device (t, d) gets its personal
        ``theta[t, d]``, a team-only principal gets ``w[t]``, and the
        global tier is ``x`` — exactly the fallback ladder the serving
        store resolves unknown principals down (DESIGN.md §12)."""
        if team is None:
            return state.x
        if device is None:
            return jax.tree.map(lambda l: l[team], state.w)
        return jax.tree.map(lambda l: l[team, device], state.theta)

    def device_axes(self, state, m, n):
        """Explicit tier split (the shape heuristic would misfire when a
        model dimension collides with n): device models ``theta`` and
        per-device EF residuals ``ef_dev`` are device-tier; team models
        ``x``/``w``, the round counter, team residuals and the comm
        PRNG key stay resident."""
        comm = None
        if state.comm is not None:
            comm = CommState(
                ef_dev=jax.tree.map(lambda _: True, state.comm.ef_dev),
                ef_team=jax.tree.map(lambda _: False, state.comm.ef_team),
                key=False)
        return P.PerMFLState(
            x=jax.tree.map(lambda _: False, state.x),
            w=jax.tree.map(lambda _: False, state.w),
            theta=jax.tree.map(lambda _: True, state.theta),
            round=False, comm=comm)

    # -- byte accounting (host side) ----------------------------------------

    def make_ledger(self, params):
        """CommLedger sized from the model's leaf shapes; None when no
        compression is configured."""
        if self.comm is None:
            return None
        return CommLedger.for_params(self.comm, params)

    def log_comm_round(self, ledger, *, n_teams, n_devices):
        """Bill one round: K LAN uplinks per participating device, one WAN
        uplink per participating team (counts pre-gated by the engine)."""
        ledger.log_round(k_team=self.hp.k_team, n_teams=n_teams,
                         n_devices=n_devices)
