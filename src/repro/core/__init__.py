from repro.core.permfl import (PerMFLHParams, PerMFLState, eval_stacked,
                               init_state, permfl_round)
from repro.core import baselines, participation, team_formation, theory

__all__ = ["PerMFLHParams", "PerMFLState", "eval_stacked", "init_state",
           "permfl_round", "baselines", "participation", "team_formation",
           "theory"]
