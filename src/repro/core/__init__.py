"""PerMFL core: Algorithm 1 (permfl), the unified FLAlgorithm API
(algorithm), Table-1 baselines, participation sampling, team formation,
and Theorem-1/2 rate helpers."""
from repro.core.permfl import (PerMFLHParams, PerMFLState, eval_stacked,
                               init_state, normalize_masks, permfl_round)
from repro.core.algorithm import FLAlgorithm, FLAlgorithmBase, PerMFL
from repro.core import (algorithm, baselines, participation, team_formation,
                        theory)

__all__ = ["PerMFLHParams", "PerMFLState", "eval_stacked", "init_state",
           "normalize_masks", "permfl_round", "FLAlgorithm",
           "FLAlgorithmBase", "PerMFL", "algorithm", "baselines",
           "participation", "team_formation", "theory"]
