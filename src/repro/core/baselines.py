"""Baselines from Table 1 / Fig 2, in the same stacked-FL representation.

All operate on data with leading (M, N, ...) so results are directly
comparable to PerMFL on identical partitions. Conventional (single-tier)
methods treat all M*N devices as one flat pool.

  FedAvg      [1]  — local SGD + global averaging (GM).
  Per-FedAvg  [13] — MAML-style: the PM is one adaptation step from GM.
  pFedMe      [11] — Moreau-envelope personalization, single tier
                     (PerMFL with M=1 team recovers its structure).
  Ditto       [10] — FedAvg GM + per-device PM trained with a prox term
                     toward the GM.
  h-SGD       [5]  — hierarchical local SGD: device steps, team average
                     every L steps, global average every K*L (GM).
  L2GD        [18] — global/cluster/personal mixture; we implement the
                     synchronous variant of the loopless method (the paper's
                     AL2GD is asynchronous — deviation noted in DESIGN.md).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


def _bcast(tree, lead):
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[(None,) * len(lead)],
                                   lead + x.shape).copy(), tree)


def _mean01(tree):
    return jax.tree.map(lambda x: x.mean(axis=(0, 1)), tree)


def _sgd_steps(theta, data, grad_fn, lr, steps):
    def one(_, th):
        g = jax.vmap(jax.vmap(grad_fn))(th, data)
        return jax.tree.map(lambda t, gg: t - lr * gg, th, g)
    return jax.lax.fori_loop(0, steps, one, theta)


# ---------------------------------------------------------------------------
# FedAvg
# ---------------------------------------------------------------------------

# NOTE (here and below): float hyperparameters (lr, lam, ...) are traced
# arguments, not static — one trace serves every value and run_sweep can
# vmap stacked grids of them. Loop bounds and loss_fn stay static.
@functools.partial(jax.jit, static_argnames=("loss_fn", "local_steps",
                                              "m", "n"))
def fedavg_round(x, data, *, loss_fn: Callable, lr: float, local_steps: int,
                 m: int, n: int):
    grad_fn = jax.grad(loss_fn)
    theta = _bcast(x, (m, n))
    theta = _sgd_steps(theta, data, grad_fn, lr, local_steps)
    return _mean01(theta)


# ---------------------------------------------------------------------------
# Per-FedAvg (first-order MAML)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("loss_fn", "local_steps",
                                              "m", "n"))
def perfedavg_round(x, data, *, loss_fn: Callable, lr: float,
                    inner_lr: float, local_steps: int, m: int, n: int):
    grad_fn = jax.grad(loss_fn)

    def meta_loss(params, batch):
        g = grad_fn(params, batch)
        adapted = jax.tree.map(lambda p, gg: p - inner_lr * gg, params, g)
        return loss_fn(adapted, batch)

    meta_grad = jax.grad(meta_loss)
    theta = _bcast(x, (m, n))

    def one(_, th):
        g = jax.vmap(jax.vmap(meta_grad))(th, data)
        return jax.tree.map(lambda t, gg: t - lr * gg, th, g)

    theta = jax.lax.fori_loop(0, local_steps, one, theta)
    return _mean01(theta)


def perfedavg_personalize(x, data, *, loss_fn, inner_lr, m: int, n: int):
    """PM = one adaptation step of the converged GM on each device."""
    grad_fn = jax.grad(loss_fn)
    theta = _bcast(x, (m, n))
    g = jax.vmap(jax.vmap(grad_fn))(theta, data)
    return jax.tree.map(lambda t, gg: t - inner_lr * gg, theta, g)


# ---------------------------------------------------------------------------
# pFedMe
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=(
    "loss_fn", "inner_steps", "local_rounds", "m", "n"))
def pfedme_round(x, data, *, loss_fn: Callable, lr: float, inner_lr: float,
                 lam: float, inner_steps: int, local_rounds: int,
                 m: int, n: int):
    """Returns (new_x, theta) — theta are the personalized models."""
    grad_fn = jax.grad(loss_fn)
    w = _bcast(x, (m, n))     # local copies of the global model

    def local_round(_, w):
        # solve the Moreau subproblem approximately from w
        def prox_steps(i, th):
            g = jax.vmap(jax.vmap(grad_fn))(th, data)
            return jax.tree.map(
                lambda t, gg, ww: t - inner_lr * (gg + lam * (t - ww)),
                th, g, w)
        theta = jax.lax.fori_loop(0, inner_steps, prox_steps, w)
        # w <- w - lr * lam * (w - theta)
        return jax.tree.map(lambda ww, th: ww - lr * lam * (ww - th),
                            w, theta)

    w = jax.lax.fori_loop(0, local_rounds, local_round, w)
    new_x = _mean01(w)
    # final personalized models from the new anchor
    def prox_steps(i, th):
        g = jax.vmap(jax.vmap(grad_fn))(th, data)
        return jax.tree.map(
            lambda t, gg, ww: t - inner_lr * (gg + lam * (t - ww)), th, g, w)
    theta = jax.lax.fori_loop(0, inner_steps, prox_steps, w)
    return new_x, theta


# ---------------------------------------------------------------------------
# Ditto
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("loss_fn", "local_steps",
                                              "m", "n"))
def ditto_round(x, v, data, *, loss_fn: Callable, lr: float, lam: float,
                local_steps: int, m: int, n: int):
    """Returns (new_x, new_v). v: personal models (M, N, ...)."""
    grad_fn = jax.grad(loss_fn)
    theta = _bcast(x, (m, n))
    theta = _sgd_steps(theta, data, grad_fn, lr, local_steps)
    new_x = _mean01(theta)

    anchor = _bcast(x, (m, n))
    def one(_, vv):
        g = jax.vmap(jax.vmap(grad_fn))(vv, data)
        return jax.tree.map(
            lambda t, gg, a: t - lr * (gg + lam * (t - a)), vv, g, anchor)
    new_v = jax.lax.fori_loop(0, local_steps, one, v)
    return new_x, new_v


# ---------------------------------------------------------------------------
# h-SGD (hierarchical FedAvg)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("loss_fn", "k_team",
                                              "l_local", "m", "n"))
def hsgd_round(x, data, *, loss_fn: Callable, lr: float, k_team: int,
               l_local: int, m: int, n: int):
    grad_fn = jax.grad(loss_fn)
    w = _bcast(x, (m,))

    def team_iter(_, w):
        theta = jax.tree.map(
            lambda wl: jnp.broadcast_to(wl[:, None],
                                        (m, n) + wl.shape[1:]).copy(), w)
        theta = _sgd_steps(theta, data, grad_fn, lr, l_local)
        return jax.tree.map(lambda t: t.mean(axis=1), theta)

    w = jax.lax.fori_loop(0, k_team, team_iter, w)
    return jax.tree.map(lambda wl: wl.mean(axis=0), w)


# ---------------------------------------------------------------------------
# L2GD (synchronous variant of the cluster/loopless method)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=(
    "loss_fn", "k_team", "l_local", "m", "n"))
def l2gd_round(x, theta, data, *, loss_fn: Callable, lr: float,
               lam_c: float, lam_g: float, k_team: int, l_local: int,
               m: int, n: int):
    """Three models: global x, cluster c_i = team mean of theta,
    personal theta. Devices mix gradient steps with pulls toward the
    cluster mean; clusters pull toward the global mean.
    Returns (new_x, new_theta)."""
    grad_fn = jax.grad(loss_fn)

    def team_iter(_, th):
        cluster = jax.tree.map(lambda t: t.mean(axis=1, keepdims=True), th)
        def local(_, th):
            g = jax.vmap(jax.vmap(grad_fn))(th, data)
            return jax.tree.map(
                lambda t, gg, c: t - lr * (gg + lam_c * (t - c)),
                th, g, cluster)
        th = jax.lax.fori_loop(0, l_local, local, th)
        # cluster pull toward global
        cl = jax.tree.map(lambda t: t.mean(axis=1, keepdims=True), th)
        return jax.tree.map(
            lambda t, c, xl: t - lr * lam_g * (c - xl[None, None]),
            th, cl, x)

    theta = jax.lax.fori_loop(0, k_team, team_iter, theta)
    new_x = _mean01(theta)
    return new_x, theta


# ---------------------------------------------------------------------------
# FLAlgorithm adapters — the round functions above behind the unified API
# (core.algorithm), so every baseline runs through the scanned engine.
# Single-tier methods ignore the participation masks (the paper ablates
# participation for PerMFL only); their round stays a pure function of
# (state, data) and scans unchanged.
# ---------------------------------------------------------------------------

from repro.core.algorithm import (FLAlgorithmBase, eval_global,  # noqa: E402
                                  eval_personal)


def _serve_personal(state, team, device):
    """Shared `serving_params` for the single-tier personalized baselines
    whose state is ``(global x, personal (M, N, ...) models)``: a device
    principal gets its personal row, team and global principals both get
    x (these methods have no team tier to fall back through)."""
    x, personal = state
    if team is None or device is None:
        return x
    return jax.tree.map(lambda l: l[team, device], personal)


@dataclass(frozen=True)
class FedAvg(FLAlgorithmBase):
    loss_fn: Callable
    lr: float
    local_steps: int

    name = "fedavg"

    def init_state(self, params, m, n):
        return params

    def round(self, x, data, *, team_mask, device_mask):
        m, n = device_mask.shape
        return fedavg_round(x, data, loss_fn=self.loss_fn, lr=self.lr,
                            local_steps=self.local_steps, m=m, n=n)

    def eval(self, x, train_data, val_data, metric_fn):
        return {"gm": eval_global(x, val_data, metric_fn)}

    def device_axes(self, state, m, n):
        """Global-model-only state: nothing rides the cohort gather."""
        return jax.tree.map(lambda _: False, state)


@dataclass(frozen=True)
class PerFedAvg(FLAlgorithmBase):
    loss_fn: Callable
    lr: float
    inner_lr: float
    local_steps: int

    name = "perfedavg"

    def init_state(self, params, m, n):
        return params

    def round(self, x, data, *, team_mask, device_mask):
        m, n = device_mask.shape
        return perfedavg_round(x, data, loss_fn=self.loss_fn, lr=self.lr,
                               inner_lr=self.inner_lr,
                               local_steps=self.local_steps, m=m, n=n)

    def eval(self, x, train_data, val_data, metric_fn):
        m, n = jax.tree.leaves(train_data)[0].shape[:2]
        theta = perfedavg_personalize(x, train_data, loss_fn=self.loss_fn,
                                      inner_lr=self.inner_lr, m=m, n=n)
        return {"pm": eval_personal(theta, val_data, metric_fn),
                "gm": eval_global(x, val_data, metric_fn)}

    def device_axes(self, state, m, n):
        """Global-model-only state (personalization is eval-time)."""
        return jax.tree.map(lambda _: False, state)


@dataclass(frozen=True)
class PFedMe(FLAlgorithmBase):
    loss_fn: Callable
    lr: float
    inner_lr: float
    lam: float
    inner_steps: int
    local_rounds: int

    name = "pfedme"

    def init_state(self, params, m, n):
        return (params, _bcast(params, (m, n)))

    def round(self, state, data, *, team_mask, device_mask):
        x, _ = state
        m, n = device_mask.shape
        return pfedme_round(x, data, loss_fn=self.loss_fn, lr=self.lr,
                            inner_lr=self.inner_lr, lam=self.lam,
                            inner_steps=self.inner_steps,
                            local_rounds=self.local_rounds, m=m, n=n)

    def eval(self, state, train_data, val_data, metric_fn):
        x, theta = state
        return {"pm": eval_personal(theta, val_data, metric_fn),
                "gm": eval_global(x, val_data, metric_fn)}

    def device_axes(self, state, m, n):
        """(global x, per-device theta): only theta is device-tier."""
        x, theta = state
        return (jax.tree.map(lambda _: False, x),
                jax.tree.map(lambda _: True, theta))

    def serving_params(self, state, team=None, device=None):
        """Device (t, d) gets its Moreau-envelope personal theta; pFedMe
        is single-tier, so team and global requests both get x."""
        return _serve_personal(state, team, device)


@dataclass(frozen=True)
class Ditto(FLAlgorithmBase):
    loss_fn: Callable
    lr: float
    lam: float
    local_steps: int

    name = "ditto"

    def init_state(self, params, m, n):
        return (params, _bcast(params, (m, n)))

    def round(self, state, data, *, team_mask, device_mask):
        x, v = state
        m, n = device_mask.shape
        return ditto_round(x, v, data, loss_fn=self.loss_fn, lr=self.lr,
                           lam=self.lam, local_steps=self.local_steps,
                           m=m, n=n)

    def eval(self, state, train_data, val_data, metric_fn):
        x, v = state
        return {"pm": eval_personal(v, val_data, metric_fn),
                "gm": eval_global(x, val_data, metric_fn)}

    def device_axes(self, state, m, n):
        """(global x, per-device v): the persistent personal models v
        are the device tier the cohort store virtualizes."""
        x, v = state
        return (jax.tree.map(lambda _: False, x),
                jax.tree.map(lambda _: True, v))

    def serving_params(self, state, team=None, device=None):
        """Device (t, d) gets its prox-regularized personal v; team and
        global requests get the FedAvg global x (single-tier method)."""
        return _serve_personal(state, team, device)


@dataclass(frozen=True)
class HSGD(FLAlgorithmBase):
    loss_fn: Callable
    lr: float
    k_team: int
    l_local: int

    name = "hsgd"

    def init_state(self, params, m, n):
        return params

    def round(self, x, data, *, team_mask, device_mask):
        m, n = device_mask.shape
        return hsgd_round(x, data, loss_fn=self.loss_fn, lr=self.lr,
                          k_team=self.k_team, l_local=self.l_local, m=m, n=n)

    def eval(self, x, train_data, val_data, metric_fn):
        return {"gm": eval_global(x, val_data, metric_fn)}

    def device_axes(self, state, m, n):
        """Global-model-only state: nothing rides the cohort gather."""
        return jax.tree.map(lambda _: False, state)


@dataclass(frozen=True)
class L2GD(FLAlgorithmBase):
    loss_fn: Callable
    lr: float
    lam_c: float
    lam_g: float
    k_team: int
    l_local: int

    name = "l2gd"

    def init_state(self, params, m, n):
        return (params, _bcast(params, (m, n)))

    def round(self, state, data, *, team_mask, device_mask):
        x, theta = state
        m, n = device_mask.shape
        return l2gd_round(x, theta, data, loss_fn=self.loss_fn, lr=self.lr,
                          lam_c=self.lam_c, lam_g=self.lam_g,
                          k_team=self.k_team, l_local=self.l_local, m=m, n=n)

    def eval(self, state, train_data, val_data, metric_fn):
        x, theta = state
        return {"pm": eval_personal(theta, val_data, metric_fn),
                "gm": eval_global(x, val_data, metric_fn)}

    def device_axes(self, state, m, n):
        """(global x, per-device theta): only theta is device-tier."""
        x, theta = state
        return (jax.tree.map(lambda _: False, x),
                jax.tree.map(lambda _: True, theta))

    def serving_params(self, state, team=None, device=None):
        """Device (t, d) gets its personal theta; the cluster tier is a
        derived team mean (not carried in the state), so team and
        global requests both resolve to the global x."""
        return _serve_personal(state, team, device)
