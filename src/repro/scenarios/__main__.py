"""CLI for the scenario registry.

    PYTHONPATH=src python -m repro.scenarios list [--family F]
    PYTHONPATH=src python -m repro.scenarios describe NAME
    PYTHONPATH=src python -m repro.scenarios dump NAME
    PYTHONPATH=src python -m repro.scenarios profiles
    PYTHONPATH=src python -m repro.scenarios run NAME [--rounds R]
        [--seed S] [--eval-every E] [--system PROFILE]
        [--deadline SECONDS] [--smoke] [--cohort C] [--trace-dir DIR]
        [--profile-dir DIR] [--fail-fast] [--hparam NAME=VALUE] [--json]
    PYTHONPATH=src python -m repro.scenarios serve NAME [--rounds R]
        [--seed S] [--smoke] [--encoding delta|int8|raw] [--store PATH]
        [--requests Q] [--batch B] [--alpha A] [--unknown-frac F]
        [--cached] [--trace-dir DIR] [--json]

``list`` prints one line per registered scenario (name, topology,
partitioner, algorithm, default rounds, spec hash); ``describe`` shows
the full spec plus paper references and a reproduce one-liner; ``dump``
emits the spec as JSON (feed it back via FLScenario.from_dict);
``profiles`` lists the wall-clock system profiles (`repro.system`);
``run`` executes through the scanned engine and prints the final
metrics; ``serve`` closes the train → deploy → measure loop — it trains
the scenario, exports the personalized (team, device) `ModelStore`
(DESIGN.md §12; ``--store PATH`` persists it and reloads it from disk,
``--encoding`` picks the device-tier delta encoding), then replays
Zipf-popularity traffic through the tier-fallback batched server and
prints p50/p95/p99 latency + queries/sec — with ``--system`` the run is priced on that device/link
profile (simulated time-to-accuracy, optional ``--deadline`` straggler
drops). ``--smoke`` shrinks the scenario to 2 teams x 3 devices x 16
samples for 2 rounds — the CI liveness check (pair with
FORCE_PALLAS_INTERPRET=1 on CPU). ``--trace-dir DIR`` turns on the
run-telemetry probes + health monitors (`repro.obs`) and writes the
JSONL event log, a Chrome-trace span file, and — for ``serve`` — the
serving metrics snapshot (JSONL + Prometheus text) there (read it all
back joined with ``python -m repro.obs report DIR``); ``--profile-dir
DIR`` additionally wraps the dispatches in a ``jax.profiler`` trace
(`repro.obs.profiling.profile_ctx`); ``--fail-fast`` raises on the
first unhealthy round (exit code 3, naming the round); ``--hparam
NAME=VALUE`` (repeatable) overrides one of the algorithm's sweepable
hyperparameters; ``--json`` prints the run-footer event as one JSON
object on stdout — the machine-readable outcome line for CI and
scripts.
"""
from __future__ import annotations

import argparse
import json
import sys


def _cmd_list(args) -> int:
    from repro.scenarios import SCENARIOS, families

    rows = [s for s in SCENARIOS.values()
            if not args.family or s.family == args.family]
    if not rows:
        print(f"no scenarios in family {args.family!r}; "
              f"families: {families()}")
        return 1
    print(f"{'name':44} {'M x N':7} {'partition':10} {'model':5} "
          f"{'algo':9} {'rounds':6} hash")
    for s in rows:
        d = s.data
        print(f"{s.name:44} {d.m_teams}x{d.n_devices:<5} "
              f"{d.partitioner:10} {s.model.kind:5} {s.algo.name:9} "
              f"{s.rounds:<6} {s.spec_hash()}")
    print(f"\n{len(rows)} scenario(s)"
          + ("" if args.family else f" in {len(families())} families"))
    return 0


def _cmd_describe(args) -> int:
    from repro.scenarios import get_scenario

    s = get_scenario(args.name)
    print(f"{s.name}  [{s.family}]  hash={s.spec_hash()}")
    if s.notes:
        print(f"  {s.notes}")
    print(f"  data:  {s.data}")
    print(f"  model: {s.model.kind} -> {s.model_config().name}")
    print(f"  algo:  {s.algo.name} {dict(s.algo.overrides) or '(paper defaults)'}")
    print(f"  rounds={s.rounds} team_frac={s.team_frac} "
          f"device_frac={s.device_frac} data_seed={s.data_seed}")
    if s.cohort_size is not None:
        print(f"  cohort: {s.cohort_size} of {s.data.n_devices} devices "
              "materialized per team per round")
    if s.comm is not None:
        print(f"  comm:  {s.comm}")
    if s.system is not None:
        print(f"  system: {s.system}")
    for metric, acc in s.paper_ref:
        print(f"  paper: {metric} = {acc}%")
    print(f"\n  reproduce: PYTHONPATH=src python -m repro.scenarios "
          f"run {s.name}")
    return 0


def _cmd_dump(args) -> int:
    from repro.scenarios import get_scenario

    print(json.dumps(get_scenario(args.name).to_dict(), indent=2))
    return 0


def _cmd_profiles(args) -> int:
    from repro.system import SYSTEM_PROFILES

    print(f"{'profile':14} {'compute':16} {'LAN':22} {'WAN':22}")
    for name, p in SYSTEM_PROFILES.items():
        print(f"{name:14} "
              f"{p.compute_gflops:g}GF/s s={p.compute_sigma:g}   "
              f"{p.lan_mbps:g}Mbps {p.lan_latency_ms:g}ms "
              f"s={p.lan_sigma:<5g} "
              f"{p.wan_mbps:g}Mbps {p.wan_latency_ms:g}ms "
              f"s={p.wan_sigma:g}")
    print("\nattach one with: run NAME --system PROFILE "
          "[--deadline SECONDS]")
    return 0


def _cmd_run(args) -> int:
    from repro.obs import HealthError, TraceConfig
    from repro.scenarios import get_scenario, run_scenario

    s = get_scenario(args.name)
    if args.smoke:
        s = s.scaled(m_teams=2, n_devices=3, samples_per_device=16,
                     rounds=2)
    if args.cohort is not None:
        import dataclasses

        s = dataclasses.replace(s, cohort_size=args.cohort or None)
    if args.system:
        s = s.with_system(args.system)
    if args.deadline:
        if s.system is None:
            print("error: --deadline needs a system model (pass --system "
                  "PROFILE, or run a scenario whose spec carries one)")
            return 2
        s = s.with_system(s.system.with_deadline(args.deadline))
    if args.hparam:
        import dataclasses

        overrides = dict(s.algo.overrides)
        for item in args.hparam:
            name, sep, val = item.partition("=")
            if not sep:
                print(f"error: --hparam wants NAME=VALUE, got {item!r}")
                return 2
            try:
                overrides[name] = float(val)
            except ValueError:
                print(f"error: --hparam value {val!r} is not a number")
                return 2
        try:
            s = dataclasses.replace(s, algo=dataclasses.replace(
                s.algo, overrides=tuple(sorted(overrides.items()))))
        except ValueError as e:
            print(f"error: {e}")
            return 2
    trace = None
    if args.trace_dir or args.profile_dir or args.fail_fast:
        # cost_analysis rides trace_dir so the saved compile span carries
        # the program's flops/bytes next to its measured wall time
        trace = TraceConfig(cost_analysis=bool(args.trace_dir),
                            profile_dir=args.profile_dir,
                            fail_fast=args.fail_fast)
    try:
        res = run_scenario(s, rounds=args.rounds, seed=args.seed,
                           eval_every=args.eval_every, trace=trace,
                           trace_dir=args.trace_dir)
    except HealthError as e:
        print(f"error: {e}")
        return 3
    if args.json:
        from repro.obs.events import run_events

        footer = run_events(
            res, algo=None,
            meta={"scenario": s.name, "spec_hash": s.spec_hash()})[-1]
        footer["scenario"] = s.name
        footer["spec_hash"] = s.spec_hash()
        if res.events_path:
            footer["events_path"] = res.events_path
        print(json.dumps(footer, sort_keys=True))
        return 0
    finals = []
    for metric in ("pm", "tm", "gm"):
        hist = getattr(res, f"{metric}_acc")
        if hist:
            finals.append(f"{metric}={hist[-1]:.4f}")
    print(f"{args.name}: rounds={args.rounds or s.rounds} "
          + " ".join(finals) + f" train_loss={res.train_loss[-1]:.4f} "
          f"({res.seconds:.1f}s)")
    if res.comm is not None:
        t = res.comm.totals()
        print(f"  comm: {t.total / 1e6:.2f} MB total "
              f"(wan_up {t.wan_up / 1e6:.2f} MB, "
              f"lan_up {t.lan_up / 1e6:.2f} MB)")
    if res.timeline is not None:
        tl = res.timeline.summary()
        print(f"  system[{tl['profile']}]: {tl['sim_seconds']:.2f} "
              f"simulated s over {tl['rounds']} rounds "
              f"(mean {tl['mean_round_seconds']:.3f}s/round, "
              f"{tl['dropped_devices']} device straggler drops)")
    if res.health is not None:
        h = res.health.summary()
        print("  health: ok" if h["ok"] else
              f"  health: FAILED at round {h['first_bad_round']}")
    if res.events_path:
        print(f"  events: {res.events_path} "
              f"(python -m repro.obs report {args.trace_dir})")
    for metric, acc in s.paper_ref:
        print(f"  paper {metric}: {acc}% (A100, full rounds)")
    return 0


def _cmd_serve(args) -> int:
    import contextlib

    import numpy as np

    from repro.models import paper_models as pm
    from repro.obs import MetricsRegistry, SpanLog
    from repro.scenarios import build_scenario, get_scenario, run_scenario
    from repro.serve import ModelStore, PersonalizedServer, replay_traffic

    s = get_scenario(args.name)
    if args.smoke:
        s = s.scaled(m_teams=2, n_devices=3, samples_per_device=16,
                     rounds=2)
    # with --trace-dir the CLI owns one span log across the whole
    # train -> export -> replay loop, so training spans and serving
    # spans land in a single Chrome trace; metrics ride next to it
    log = metrics = None
    if args.trace_dir:
        log = SpanLog(meta={"kind": "serve", "scenario": s.name})
        metrics = MetricsRegistry()
    with log.activate() if log is not None else contextlib.nullcontext():
        res = run_scenario(s, rounds=args.rounds, seed=args.seed,
                           trace=True if args.trace_dir else None,
                           trace_dir=args.trace_dir)
        b = build_scenario(s, seed=args.seed)
        store = ModelStore.from_result(b.algo, res, m=b.m, n=b.n,
                                       encoding=args.encoding)
        if args.store:
            store.save(args.store)
            store = ModelStore.load(args.store)
            print(f"# store: {args.store} ({store.encoding}, "
                  f"{store.m}x{store.n}, device tier "
                  f"{store.device_tier_nbytes() / 1e6:.2f} MB)")
        cfg = b.config
        xv = np.asarray(b.val["x"], np.float32)
        pool = xv.reshape((-1,) + xv.shape[3:])
        server = PersonalizedServer(
            store, lambda p, x: pm.apply(p, cfg, x[None])[0])
        stats = replay_traffic(server, pool, requests=args.requests,
                               batch=args.batch, alpha=args.alpha,
                               unknown_frac=args.unknown_frac,
                               seed=args.seed, cached=args.cached,
                               metrics=metrics)
    stats["scenario"] = s.name
    if args.trace_dir:
        log.save(args.trace_dir, tag=f"serve-{s.name}")
        metrics.write_jsonl(f"{args.trace_dir}/metrics-serve.jsonl")
        metrics.write_prom(f"{args.trace_dir}/metrics-serve.prom")
    if args.json:
        print(json.dumps(
            {k: v for k, v in stats.items() if k != "lat_ms"},
            sort_keys=True))
        return 0
    print(f"{s.name}: served {stats['requests']} requests "
          f"(batch {stats['batch']}, Zipf a={stats['alpha']:g}, "
          f"{stats['unknown_frac']:.0%} unknown, "
          f"encoding={stats['encoding']}"
          + (", cached" if stats["cached"] else "") + ")")
    print(f"  qps={stats['qps']:.1f} p50={stats['p50_ms']:.3f}ms "
          f"p95={stats['p95_ms']:.3f}ms p99={stats['p99_ms']:.3f}ms "
          f"mean={stats['mean_ms']:.3f}ms")
    tiers = stats.get("tier_counts")
    if tiers:
        print(f"  tiers: device={tiers['device']} team={tiers['team']} "
              f"global={tiers['global']}"
              + (f"  cache_hit_rate={stats['cache_hit_rate']:.2%}"
                 if "cache_hit_rate" in stats else ""))
    print(f"  device tier: {stats['device_tier_bytes'] / 1e6:.2f} MB "
          f"({stats['m']}x{stats['n']} devices)")
    if args.trace_dir:
        print(f"  telemetry: {args.trace_dir} "
              f"(python -m repro.obs report {args.trace_dir})")
    return 0


def main(argv=None) -> int:
    """Entry point: dispatch list / describe / dump / run."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.scenarios",
        description="Browse and run the declarative scenario registry.")
    sub = ap.add_subparsers(dest="cmd", required=True)
    p = sub.add_parser("list", help="list registered scenarios")
    p.add_argument("--family", default=None)
    p.set_defaults(fn=_cmd_list)
    p = sub.add_parser("describe", help="show one scenario's full spec")
    p.add_argument("name")
    p.set_defaults(fn=_cmd_describe)
    p = sub.add_parser("dump", help="print one scenario as JSON")
    p.add_argument("name")
    p.set_defaults(fn=_cmd_dump)
    p = sub.add_parser("profiles",
                       help="list wall-clock system profiles")
    p.set_defaults(fn=_cmd_profiles)
    p = sub.add_parser("run", help="run a scenario via the scanned engine")
    p.add_argument("name")
    p.add_argument("--rounds", type=int, default=None)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--eval-every", type=int, default=1)
    p.add_argument("--system", default=None,
                   help="wall-clock profile (see `profiles`)")
    p.add_argument("--deadline", type=float, default=None,
                   help="per-round straggler deadline, simulated seconds")
    p.add_argument("--smoke", action="store_true",
                   help="2x3x16 topology, 2 rounds (CI liveness)")
    p.add_argument("--cohort", type=int, default=None,
                   help="override cohort_size (devices materialized per "
                        "team per round); 0 disables cohort sampling")
    p.add_argument("--trace-dir", default=None,
                   help="enable probes + health monitors and write the "
                        "JSONL event log + Chrome-trace spans here")
    p.add_argument("--profile-dir", default=None,
                   help="wrap dispatches in a jax.profiler trace "
                        "writing here (TensorBoard-loadable)")
    p.add_argument("--fail-fast", action="store_true",
                   help="raise on the first unhealthy round "
                        "(nonfinite state / exploded loss); exit code 3")
    p.add_argument("--hparam", action="append", default=None,
                   metavar="NAME=VALUE",
                   help="override one sweepable hyperparameter "
                        "(repeatable)")
    p.add_argument("--json", action="store_true",
                   help="print the run-footer event as JSON on stdout")
    p.set_defaults(fn=_cmd_run)
    p = sub.add_parser(
        "serve", help="train -> export personalized store -> replay "
                      "Zipf traffic (DESIGN.md §12)")
    p.add_argument("name")
    p.add_argument("--rounds", type=int, default=None)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--smoke", action="store_true",
                   help="2x3x16 topology, 2 rounds (CI liveness)")
    p.add_argument("--encoding", default="delta",
                   choices=("delta", "int8", "raw"),
                   help="device-tier encoding (delta = exact bit-pattern "
                        "residual, int8 = fused-quantized residual)")
    p.add_argument("--store", default=None,
                   help="persist the exported store here and reload it "
                        "from disk before serving")
    p.add_argument("--requests", type=int, default=512)
    p.add_argument("--batch", type=int, default=64)
    p.add_argument("--alpha", type=float, default=1.2,
                   help="Zipf popularity exponent (>1)")
    p.add_argument("--unknown-frac", type=float, default=0.0,
                   help="fraction of requests tagged with unknown "
                        "principals (exercises tier fallback)")
    p.add_argument("--cached", action="store_true",
                   help="serve through the LRU unique-principal path")
    p.add_argument("--trace-dir", default=None,
                   help="write spans + serving metrics (JSONL and "
                        "Prometheus text) + training events here")
    p.add_argument("--json", action="store_true",
                   help="print the replay stats as JSON on stdout")
    p.set_defaults(fn=_cmd_serve)
    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
