"""The declarative `FLScenario` spec: data x topology x model x algorithm
x participation x comm as one frozen, serializable value.

Every experiment in the repo is a *scenario* — the paper's claims are all
scenario claims (PerMFL wins under known team structures, label-skew
dissemination, partial participation, constrained uplinks), and every
future workload is added as a new spec, not a new benchmark script. A
scenario is four nested frozen dataclasses:

    FLScenario
      ├── DataSpec   dataset + partitioner + (M, N) topology + team
      │              formation strategy + heterogeneity knobs
      ├── ModelSpec  which paper model (mclr | cnn | dnn)
      └── AlgoSpec   algorithm name + hyperparameter overrides
      plus rounds, team/device participation fractions, an optional
      CommConfig, the data seed, and presentation metadata (family,
      paper reference numbers, notes).

Being frozen and built from hashable fields, a scenario is usable as a
cache key end-to-end: `spec_hash()` digests the physical fields (name
and presentation metadata excluded), and `repro.scenarios.runner` keys
its build cache on it so repeated runs of one scenario share loss/metric
closures — which is exactly what lets the engine's compiled-program
cache (`train.engine`, DESIGN.md §5/§7) hit across calls.

`to_dict()` / `from_dict()` round-trip through plain JSON-able dicts, so
specs can be dumped, diffed, and checked into experiment configs.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import CommConfig
from repro.configs.base import PaperModelConfig
from repro.system import SystemSpec, get_profile
from repro.core import PerMFL
from repro.core import baselines as B
from repro.core.permfl import PerMFLHParams
from repro.data.federated import (FederatedData, partition_dirichlet,
                                  partition_label_skew,
                                  partition_quantity_skew, partition_tabular,
                                  stack_virtual)
from repro.data.synthetic import (feature_shift_tabular, make_dataset,
                                  synthetic_tabular, virtual_tabular)
from repro.models import paper_models as PM

__all__ = ["ALGO_METRICS", "AlgoSpec", "DataSpec", "FLScenario",
           "ModelSpec", "PAPER_HP", "fns_for", "init_model", "to_jax"]

# paper §4.1.4 hyperparameters — the PerMFL defaults every scenario
# starts from (AlgoSpec overrides replace individual fields)
PAPER_HP = PerMFLHParams(alpha=0.01, eta=0.03, beta=0.6, lam=0.5,
                         gamma=1.5, k_team=5, l_local=10)

# metrics each algorithm reports (keys of FLAlgorithm.eval): the Table-1
# columns — personalized/team/global for PerMFL, GM-only for the purely
# global baselines, PM+GM for the personalized ones
ALGO_METRICS = {
    "permfl": ("pm", "tm", "gm"),
    "fedavg": ("gm",),
    "perfedavg": ("pm", "gm"),
    "pfedme": ("pm", "gm"),
    "ditto": ("pm", "gm"),
    "hsgd": ("gm",),
    "l2gd": ("pm", "gm"),
}

_TABULAR_DATASETS = ("synthetic", "featshift", "virtual")
_PARTITIONERS = ("label_skew", "dirichlet", "quantity", "tabular")


# ---------------------------------------------------------------------------
# helpers shared with the benchmarks (historically benchmarks/fl_common.py)
# ---------------------------------------------------------------------------

def fns_for(cfg: PaperModelConfig):
    """(loss_fn, metric_fn) closures over one paper model config."""
    loss = lambda p, b: PM.loss_fn(p, cfg, b)
    met = lambda p, b: PM.accuracy(p, cfg, b)
    return loss, met


def init_model(cfg: PaperModelConfig, seed: int = 0):
    """Model parameters for `cfg` from PRNG seed `seed`."""
    return PM.init_params(jax.random.PRNGKey(seed), cfg)


def to_jax(fd: FederatedData):
    """FederatedData -> (train, val) dicts of stacked jnp arrays."""
    tr = {"x": jnp.asarray(fd.train_x), "y": jnp.asarray(fd.train_y)}
    va = {"x": jnp.asarray(fd.val_x), "y": jnp.asarray(fd.val_y)}
    return tr, va


# ---------------------------------------------------------------------------
# DataSpec
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DataSpec:
    """What the federation holds: dataset, partitioner, and topology.

    dataset: "mnist" | "fmnist" | "emnist10" (image sets), "synthetic"
        (the paper's §D.2.6 tabular set), "featshift" (covariate-shift
        tabular — shared concept, team-shifted features), or "virtual"
        (the cohort-scale featshift variant: fully vectorized
        construction, viable at 10^4-10^6 devices per team).
    partitioner: "label_skew" (paper §4.1.4), "dirichlet" (Dir(alpha)
        class mixes), "quantity" (power-law effective sizes), or
        "tabular" (per-device tabular stacking; implied by the tabular
        datasets).
    m_teams / n_devices: the (M, N) topology.
    samples_per_device: S — stacked sample slots per device.
    classes_per_device: label-skew classes per device.
    strategy: team-formation label pools ("random" | "worst" | "average").
    alpha: Dirichlet concentration (partitioner="dirichlet").
    min_frac: minimum unique-sample fraction (partitioner="quantity").
    shift: team feature-shift magnitude (dataset="featshift").
    n_per_class: image-dataset pool size per class; 0 = auto
        (40 * n_devices, the benchmarks' historical sizing).
    """
    dataset: str = "mnist"
    partitioner: str = "label_skew"
    m_teams: int = 4
    n_devices: int = 10
    samples_per_device: int = 48
    classes_per_device: int = 2
    strategy: str = "random"
    alpha: float = 0.5
    min_frac: float = 0.25
    shift: float = 2.0
    n_per_class: int = 0

    def __post_init__(self):
        if self.partitioner not in _PARTITIONERS:
            raise ValueError(f"unknown partitioner {self.partitioner!r}; "
                             f"expected one of {_PARTITIONERS}")
        if (self.dataset in _TABULAR_DATASETS) != \
                (self.partitioner == "tabular"):
            raise ValueError(
                f"partitioner 'tabular' and the tabular datasets "
                f"{_TABULAR_DATASETS} go together; got dataset="
                f"{self.dataset!r} with partitioner={self.partitioner!r}")

    def build(self, seed: int) -> FederatedData:
        """Materialize the stacked FederatedData for PRNG seed `seed`
        (deterministic: same spec + seed -> identical arrays)."""
        rng = np.random.default_rng(seed)
        m, n, spd = self.m_teams, self.n_devices, self.samples_per_device
        if self.dataset == "synthetic":
            devs = synthetic_tabular(rng, m * n, min_samples=spd,
                                     max_samples=spd * 8)
            return partition_tabular(devs, m_teams=m, n_devices=n,
                                     samples_per_device=spd)
        if self.dataset == "featshift":
            devs = feature_shift_tabular(rng, m, n, shift=self.shift,
                                         samples_per_device=spd)
            return partition_tabular(devs, m_teams=m, n_devices=n,
                                     samples_per_device=spd)
        if self.dataset == "virtual":
            x, y = virtual_tabular(rng, m, n, shift=self.shift,
                                   samples_per_device=spd)
            return stack_virtual(x, y, samples_per_device=spd)
        x, y = make_dataset(self.dataset, rng,
                            n_per_class=self.n_per_class or 40 * n)
        if self.partitioner == "label_skew":
            return partition_label_skew(
                rng, x, y, m_teams=m, n_devices=n,
                classes_per_device=self.classes_per_device,
                samples_per_device=spd, strategy=self.strategy)
        if self.partitioner == "dirichlet":
            return partition_dirichlet(
                rng, x, y, m_teams=m, n_devices=n, alpha=self.alpha,
                samples_per_device=spd, strategy=self.strategy)
        assert self.partitioner == "quantity", self.partitioner
        return partition_quantity_skew(
            rng, x, y, m_teams=m, n_devices=n, samples_per_device=spd,
            min_frac=self.min_frac)


# ---------------------------------------------------------------------------
# ModelSpec
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelSpec:
    """Which paper model trains on the scenario: "mclr" (strongly convex)
    | "cnn" | "dnn" (non-convex). The concrete PaperModelConfig is
    resolved against the DataSpec (input shape follows the dataset)."""
    kind: str = "mclr"

    def config(self, data: DataSpec) -> PaperModelConfig:
        """Resolve to the concrete paper config for `data`'s shapes."""
        from repro.configs.paper_cnn import CONFIG as CNN
        from repro.configs.paper_dnn import CONFIG as DNN
        from repro.configs.paper_mclr import CONFIG as MCLR

        tabular = data.dataset in _TABULAR_DATASETS
        if self.kind == "mclr":
            return dataclasses.replace(MCLR, input_shape=(60,)) if tabular \
                else MCLR
        if self.kind == "dnn":
            return DNN
        if self.kind == "cnn":
            if tabular:
                raise ValueError("cnn needs image data, got "
                                 f"{data.dataset!r}")
            return CNN
        raise ValueError(f"unknown model kind {self.kind!r}")


# ---------------------------------------------------------------------------
# AlgoSpec
# ---------------------------------------------------------------------------

# paper-default constructor arguments per algorithm (Table-1 settings);
# AlgoSpec.overrides replaces individual entries
_ALGO_DEFAULTS = {
    "permfl": dict(alpha=0.01, eta=0.03, beta=0.6, lam=0.5, gamma=1.5,
                   k_team=5, l_local=10, momentum=0.0, weight_decay=0.0),
    "fedavg": dict(lr=0.03, local_steps=50),
    "perfedavg": dict(lr=0.03, inner_lr=0.03, local_steps=20),
    "pfedme": dict(lr=1.0, inner_lr=0.03, lam=15.0, inner_steps=10,
                   local_rounds=5),
    "ditto": dict(lr=0.03, lam=0.5, local_steps=20),
    "hsgd": dict(lr=0.03, k_team=5, l_local=10),
    "l2gd": dict(lr=0.03, lam_c=0.5, lam_g=0.5, k_team=5, l_local=10),
}


@dataclass(frozen=True)
class AlgoSpec:
    """Algorithm name + hyperparameter overrides on the paper defaults.

    overrides: sorted tuple of (field, value) pairs replacing entries of
    the algorithm's paper-default constructor arguments (PerMFLHParams
    fields for "permfl", constructor kwargs for the baselines) — a tuple
    so the spec stays hashable and JSON-round-trippable.
    """
    name: str = "permfl"
    overrides: Tuple[Tuple[str, Any], ...] = ()

    def __post_init__(self):
        if self.name not in _ALGO_DEFAULTS:
            raise ValueError(f"unknown algorithm {self.name!r}; expected "
                             f"one of {sorted(_ALGO_DEFAULTS)}")
        unknown = set(dict(self.overrides)) - set(_ALGO_DEFAULTS[self.name])
        if unknown:
            raise ValueError(
                f"unknown {self.name} override(s) {sorted(unknown)}; "
                f"valid: {sorted(_ALGO_DEFAULTS[self.name])}")
        # normalize: sorted, tuple-of-tuples (from_dict hands us lists)
        object.__setattr__(self, "overrides", tuple(
            sorted((str(k), v) for k, v in self.overrides)))

    def resolved(self) -> dict:
        """Paper defaults with this spec's overrides applied."""
        kw = dict(_ALGO_DEFAULTS[self.name])
        kw.update(dict(self.overrides))
        return kw

    def hparams(self) -> PerMFLHParams:
        """The resolved PerMFLHParams ("permfl" only)."""
        if self.name != "permfl":
            raise ValueError(f"{self.name} has no PerMFLHParams")
        return PerMFLHParams(**self.resolved())

    def build(self, loss_fn: Callable,
              comm: Optional[CommConfig] = None):
        """Construct the frozen FLAlgorithm instance for the engine."""
        kw = self.resolved()
        if self.name == "permfl":
            return PerMFL(loss_fn, PerMFLHParams(**kw), comm=comm)
        if comm is not None:
            raise ValueError(f"comm compression is a PerMFL feature; "
                             f"{self.name} does not route tiered uplinks")
        cls = {"fedavg": B.FedAvg, "perfedavg": B.PerFedAvg,
               "pfedme": B.PFedMe, "ditto": B.Ditto, "hsgd": B.HSGD,
               "l2gd": B.L2GD}[self.name]
        return cls(loss_fn, **kw)

    @property
    def metrics(self) -> tuple:
        """Eval metrics this algorithm reports (Table-1 columns)."""
        return ALGO_METRICS[self.name]


# ---------------------------------------------------------------------------
# FLScenario
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FLScenario:
    """One named, reproducible experiment: the unit the registry stores,
    `run_scenario` / `sweep_scenario` execute, and the build cache keys.

    data / model / algo: the nested physical specs.
    rounds: default global-round budget (overridable at run time).
    team_frac / device_frac: participation fractions (paper §3.1 modes).
    comm: optional CommConfig — compressed uplinks + byte accounting.
    system: optional SystemSpec — wall-clock simulation on a named
        device/link profile (`repro.system`); results gain a Timeline +
        sim_seconds, and a deadline_s drops stragglers from the masks.
        Serialized only when set, so legacy specs hash unchanged.
    cohort_size: optional per-team cohort width C — the engine samples C
        of the N devices each round and materializes only the (M, C)
        slab (the virtualized cohort engine, DESIGN.md §11). None keeps
        the full-population stacked path bit-identical to before.
        Serialized only when set, so legacy specs hash unchanged.
    data_seed: PRNG seed the federated partition is built from (model
        init / participation seeds are run-time arguments, so one data
        universe serves multi-seed sweeps — the paper's table protocol).
    family / paper_ref / notes: presentation metadata — excluded from
        `spec_hash()` and from the build cache key. paper_ref holds
        (metric, paper accuracy %) pairs for cells quoted in the paper.
    """
    name: str
    data: DataSpec = field(default_factory=DataSpec)
    model: ModelSpec = field(default_factory=ModelSpec)
    algo: AlgoSpec = field(default_factory=AlgoSpec)
    rounds: int = 10
    team_frac: float = 1.0
    device_frac: float = 1.0
    comm: Optional[CommConfig] = None
    system: Optional[SystemSpec] = None
    cohort_size: Optional[int] = None
    data_seed: int = 0
    family: str = ""
    paper_ref: Tuple[Tuple[str, float], ...] = ()
    notes: str = ""

    def __post_init__(self):
        object.__setattr__(self, "paper_ref", tuple(
            (str(k), float(v)) for k, v in self.paper_ref))
        if self.cohort_size is not None and not (
                1 <= self.cohort_size <= self.data.n_devices):
            raise ValueError(
                f"cohort_size must be in [1, n_devices="
                f"{self.data.n_devices}], got {self.cohort_size}")

    # -- identity ----------------------------------------------------------

    def canonical(self) -> "FLScenario":
        """The physics only: presentation metadata stripped (including
        the system profile's label — two identically-parameterized
        profiles are one world). Two registry entries with equal
        canonical() forms share builds and compiled programs."""
        system = (dataclasses.replace(self.system, name="")
                  if self.system is not None else None)
        return dataclasses.replace(self, name="", family="", paper_ref=(),
                                   notes="", system=system)

    def spec_hash(self) -> str:
        """Stable 16-hex digest of the canonical spec — the key the
        runner's build cache (and through it the engine's compiled-
        program cache) is organized around."""
        blob = json.dumps(self.canonical().to_dict(), sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    # -- (de)serialization -------------------------------------------------

    def to_dict(self) -> dict:
        """Plain JSON-able dict; `from_dict` inverts it exactly. The
        ``system`` and ``cohort_size`` keys appear only when set, so
        pre-existing specs (and their spec_hash) are byte-stable."""
        d = {
            "name": self.name,
            "data": dataclasses.asdict(self.data),
            "model": dataclasses.asdict(self.model),
            "algo": {"name": self.algo.name,
                     "overrides": [[k, v] for k, v in self.algo.overrides]},
            "rounds": self.rounds,
            "team_frac": self.team_frac,
            "device_frac": self.device_frac,
            "comm": dataclasses.asdict(self.comm) if self.comm else None,
            "data_seed": self.data_seed,
            "family": self.family,
            "paper_ref": [[k, v] for k, v in self.paper_ref],
            "notes": self.notes,
        }
        if self.system is not None:
            d["system"] = self.system.to_dict()
        if self.cohort_size is not None:
            d["cohort_size"] = self.cohort_size
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "FLScenario":
        """Rebuild a spec from `to_dict()` output (or hand-written JSON);
        `from_dict(to_dict(s)) == s` for every registered scenario."""
        return cls(
            name=d["name"],
            data=DataSpec(**d["data"]),
            model=ModelSpec(**d["model"]),
            algo=AlgoSpec(d["algo"]["name"],
                          tuple(tuple(p) for p in d["algo"]["overrides"])),
            rounds=d["rounds"],
            team_frac=d["team_frac"],
            device_frac=d["device_frac"],
            comm=CommConfig(**d["comm"]) if d.get("comm") else None,
            system=(SystemSpec.from_dict(d["system"])
                    if d.get("system") else None),
            cohort_size=d.get("cohort_size"),
            data_seed=d["data_seed"],
            family=d.get("family", ""),
            paper_ref=tuple(tuple(p) for p in d.get("paper_ref", ())),
            notes=d.get("notes", ""),
        )

    # -- derivation --------------------------------------------------------

    def scaled(self, *, m_teams: Optional[int] = None,
               n_devices: Optional[int] = None,
               samples_per_device: Optional[int] = None,
               rounds: Optional[int] = None,
               cohort_size: Optional[int] = None,
               algo_overrides: Optional[dict] = None) -> "FLScenario":
        """A derived scenario at a different scale (the benchmarks' quick
        mode shrinks CNN cells this way). Unset arguments keep the
        spec's values; `algo_overrides` merge over `algo.overrides`. An
        inherited or given cohort_size is clamped to the (possibly
        shrunk) population so `--smoke` derivations stay valid."""
        data = dataclasses.replace(
            self.data,
            m_teams=m_teams if m_teams is not None else self.data.m_teams,
            n_devices=(n_devices if n_devices is not None
                       else self.data.n_devices),
            samples_per_device=(samples_per_device
                                if samples_per_device is not None
                                else self.data.samples_per_device))
        algo = self.algo
        if algo_overrides:
            merged = dict(algo.overrides)
            merged.update(algo_overrides)
            algo = AlgoSpec(algo.name, tuple(merged.items()))
        cohort = cohort_size if cohort_size is not None else self.cohort_size
        if cohort is not None:
            cohort = min(int(cohort), data.n_devices)
        return dataclasses.replace(
            self, data=data, algo=algo, cohort_size=cohort,
            rounds=rounds if rounds is not None else self.rounds)

    def with_system(self, profile) -> "FLScenario":
        """This scenario on a wall-clock system model: `profile` is a
        SystemSpec, a named profile ("wan-cellular", ...), a spec dict,
        or None to detach."""
        return dataclasses.replace(
            self, system=None if profile is None else get_profile(profile))

    # -- materialization ---------------------------------------------------

    def model_config(self) -> PaperModelConfig:
        """The resolved PaperModelConfig for this scenario's data."""
        return self.model.config(self.data)

    def build(self, seed: int = 0):
        """Materialize (FederatedData, FLAlgorithm, params0, metric_fn)
        for model-init seed `seed` (data comes from `data_seed`).

        Thin uncached wrapper around `runner.build_scenario` — prefer
        that entry point inside loops; it shares data, closures, and
        thereby compiled programs across calls.
        """
        from repro.scenarios.runner import build_scenario
        b = build_scenario(self, seed)
        return b.fd, b.algo, b.params0, b.metric_fn
