"""Declarative scenario layer: one serializable spec per experiment.

The paper's claims are scenario claims — this package makes the scenario
a first-class, named, hashable value (`FLScenario` = data x topology x
model x algorithm x participation x comm), keeps every paper cell plus
the new heterogeneity families in the `SCENARIOS` registry, and routes
execution through the scanned engine (`run_scenario`) and the vmapped
sweep (`sweep_scenario`) with spec-hash-keyed build caching.

    from repro.scenarios import SCENARIOS, run_scenario
    res = run_scenario("table1/mnist/mclr/permfl", rounds=10)

CLI: ``python -m repro.scenarios list|describe|dump|run`` (DESIGN.md §7).
"""
from repro.scenarios.paper_refs import (PAPER_TABLE1_MCLR,
                                        PAPER_TABLE1_NONCONVEX, table1_ref)
from repro.scenarios.registry import (SCENARIOS, TABLE1_ALGOS,
                                      TABLE1_DATASETS, families,
                                      get_scenario, register)
from repro.scenarios.runner import (ScenarioBuild, build_scenario,
                                    run_scenario, sweep_scenario)
from repro.scenarios.spec import (ALGO_METRICS, AlgoSpec, DataSpec,
                                  FLScenario, ModelSpec, PAPER_HP, fns_for,
                                  init_model, to_jax)

__all__ = ["ALGO_METRICS", "AlgoSpec", "DataSpec", "FLScenario",
           "ModelSpec", "PAPER_HP", "PAPER_TABLE1_MCLR",
           "PAPER_TABLE1_NONCONVEX", "SCENARIOS", "ScenarioBuild",
           "TABLE1_ALGOS", "TABLE1_DATASETS", "build_scenario", "families",
           "fns_for", "get_scenario", "init_model", "register",
           "run_scenario", "sweep_scenario", "table1_ref", "to_jax"]
