"""Scenario execution: build caching + the run/sweep entry points.

``run_scenario`` routes one scenario through the scanned engine
(`train.engine.run_experiment`, one compiled program per experiment);
``sweep_scenario`` routes a hyperparameter/seed grid through the vmapped
sweep (`train.sweep.run_sweep`, the whole grid as one program).

The compiled-program caches in both engines key on the *identity* of the
loss/metric closures (they ride inside the frozen algorithm instances).
This module therefore memoizes scenario materialization by
``FLScenario.canonical()`` — the spec-hash identity — so every run of
the same scenario (any seed, any rounds) reuses one set of closures, one
FederatedData, and one algorithm template, and the engines' caches hit
instead of retracing (DESIGN.md §7).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional

from repro.obs.spans import SpanLog, current_log, span
from repro.scenarios.registry import get_scenario
from repro.scenarios.spec import FLScenario, fns_for, init_model, to_jax
from repro.train.engine import FLResult, run_experiment
from repro.train.sweep import FLSweepResult, run_sweep

__all__ = ["ScenarioBuild", "build_scenario", "run_scenario",
           "sweep_scenario"]

# default for the run/sweep `system` argument: "not passed — keep the
# spec's own system model". Distinct from None, which explicitly
# disables simulation on a system-bearing spec.
_KEEP_SPEC_SYSTEM = object()


@dataclass
class ScenarioBuild:
    """Everything materialized from one (scenario, seed): the stacked
    data (host + device), resolved model config, shared loss/metric
    closures, the frozen algorithm instance, and the seed's params."""
    scenario: FLScenario
    fd: Any            # FederatedData (host numpy)
    config: Any        # PaperModelConfig
    train: Any         # stacked jnp train batch
    val: Any           # stacked jnp val batch
    loss_fn: Callable
    metric_fn: Callable
    algo: Any          # frozen FLAlgorithm template
    params0: Any       # model init for this seed

    @property
    def m(self) -> int:
        """M: number of teams."""
        return self.fd.m_teams

    @property
    def n(self) -> int:
        """N: devices per team."""
        return self.fd.n_devices


@functools.lru_cache(maxsize=32)
def _data(data_spec, data_seed: int):
    """One federated partition (host + device arrays) per (DataSpec,
    seed) — scenarios differing only in algorithm/comm/rounds (e.g. the
    seven Table-1 cells of one row) share it instead of re-partitioning
    and holding duplicate stacked arrays."""
    # the span only fires on a cache miss — exactly when data-build work
    # actually happens; memoized rebuilds show as scenario_build hits
    with span("data_build", seed=data_seed):
        fd = data_spec.build(data_seed)
        train, val = to_jax(fd)
    return fd, train, val


@functools.lru_cache(maxsize=16)
def _fns(cfg):
    """One (loss, metric) closure pair per resolved model config. Shared
    closure identity across scenarios is what lets equal algorithm
    instances (same hparams, same loss object) hit one compiled
    program in the engine caches."""
    return fns_for(cfg)


@functools.lru_cache(maxsize=128)
def _materialize(canon: FLScenario):
    """Resolved build for one canonical spec, composed from the shared
    data/closure caches (the per-spec part — the frozen algorithm
    template — is tiny)."""
    fd, train, val = _data(canon.data, canon.data_seed)
    cfg = canon.model_config()
    loss, metric = _fns(cfg)
    algo = canon.algo.build(loss, comm=canon.comm)
    return fd, cfg, train, val, loss, metric, algo


@functools.lru_cache(maxsize=512)
def _params0(cfg, seed: int):
    return init_model(cfg, seed)


def build_scenario(name_or_spec, seed: int = 0) -> ScenarioBuild:
    """Materialize a scenario (registry name, spec dict, or FLScenario)
    for model-init seed ``seed``.

    Memoized on ``(spec_hash identity, seed)``: repeated builds return
    the same data arrays and the same closure/algorithm objects, which
    is what keys the engine's compiled-program cache across calls.
    """
    s = get_scenario(name_or_spec)
    # the system model is pure measurement — it never changes what gets
    # built, so strip it from the cache key: every profile of one
    # scenario shares data, closures, and the algorithm template
    canon = dataclasses.replace(s.canonical(), system=None)
    hits0 = _materialize.cache_info().hits
    with span("scenario_build", scenario=s.name, seed=seed) as sp:
        fd, cfg, train, val, loss, metric, algo = _materialize(canon)
        sp.set(memoized=_materialize.cache_info().hits > hits0)
        params0 = _params0(cfg, seed)
    return ScenarioBuild(scenario=s, fd=fd, config=cfg, train=train,
                         val=val, loss_fn=loss, metric_fn=metric,
                         algo=algo, params0=params0)


def run_scenario(name_or_spec, *, rounds: Optional[int] = None,
                 seed: int = 0, init_seed: Optional[int] = None,
                 eval_every: int = 1, scan: bool = True,
                 system=_KEEP_SPEC_SYSTEM, trace=None,
                 trace_dir=None) -> FLResult:
    """Run one scenario through the scanned engine.

    rounds: override the spec's default round budget.
    seed: drives the in-graph participation-sampling PRNG chain and (by
        default) the model init.
    init_seed: separate model-init seed when it must differ from the
        participation seed (fig4 reproduces the paper this way).
    system: wall-clock model (SystemSpec / profile name / spec dict)
        overriding the scenario's own ``system`` field; pass None to
        disable simulation on a system-bearing spec. Unpassed, the
        spec's own model (if any) applies.
    trace / trace_dir: run-telemetry (`repro.obs`) — probe streams on
        ``FLResult.trace`` (health detectors on ``FLResult.health``), a
        JSONL event log whose header carries the scenario identity
        (name, family, spec_hash), and one Chrome-trace span file
        covering the scenario build plus the engine's
        build/compile/dispatch/eval phases.
    Remaining arguments match ``train.engine.run_experiment``.
    """
    s = get_scenario(name_or_spec)
    # span-log ownership: run_scenario is the outermost layer here, so
    # the scenario-build spans and the engine's spans share one file
    own_log = SpanLog(meta={"kind": "scenario", "scenario": s.name}) \
        if trace_dir is not None and current_log() is None else None
    with contextlib.ExitStack() as stack:
        if own_log is not None:
            stack.enter_context(own_log.activate())
            stack.callback(own_log.save, trace_dir, s.name)
        b = build_scenario(s, seed if init_seed is None else init_seed)
        return run_experiment(
            b.algo, b.params0, b.train, b.val, metric_fn=b.metric_fn,
            rounds=s.rounds if rounds is None else rounds, m=b.m, n=b.n,
            team_frac=s.team_frac, device_frac=s.device_frac, seed=seed,
            eval_every=eval_every, scan=scan, cohort=s.cohort_size,
            system=s.system if system is _KEEP_SPEC_SYSTEM else system,
            trace=trace, trace_dir=trace_dir,
            event_meta={"scenario": s.name, "family": s.family,
                        "spec_hash": s.spec_hash()})


def sweep_scenario(name_or_spec, grid=({},), seeds=(0,), *,
                   rounds: Optional[int] = None, eval_every: int = 1,
                   mesh=None, system=_KEEP_SPEC_SYSTEM, trace=None,
                   trace_dir=None) -> FLSweepResult:
    """Run a hyperparameter grid x seeds over one scenario as a single
    vmapped program (``train.sweep.run_sweep``).

    grid: list of {hparam: value} overrides on the scenario algorithm's
        sweepable floats (or a {name: [values...]} product dict); pass
        ``[{}]`` for a seeds-only sweep.
    seeds: each seed gets its own model init (the tables' multi-seed
        protocol) and participation chain; the shared data comes from
        the spec's ``data_seed``.
    system: wall-clock model(s) — one profile, or a sequence batching a
        *system profile axis* into the same dispatch (run_sweep); None
        disables simulation on a system-bearing spec, and unpassed the
        scenario's own ``system`` field applies.
    trace / trace_dir: run-telemetry (`repro.obs`), as in run_scenario —
        per-config RunTraces and one sweep JSONL event file.
    """
    s = get_scenario(name_or_spec)
    if isinstance(seeds, int):
        seeds = (seeds,)
    seeds = tuple(int(x) for x in seeds)
    own_log = SpanLog(meta={"kind": "scenario_sweep",
                            "scenario": s.name}) \
        if trace_dir is not None and current_log() is None else None
    with contextlib.ExitStack() as stack:
        if own_log is not None:
            stack.enter_context(own_log.activate())
            stack.callback(own_log.save, trace_dir, f"sweep-{s.name}")
        b = build_scenario(s, seeds[0] if seeds else 0)
        return run_sweep(
            b.algo, grid, seeds, lambda sd: _params0(b.config, int(sd)),
            b.train, b.val, metric_fn=b.metric_fn,
            rounds=s.rounds if rounds is None else rounds, m=b.m, n=b.n,
            team_frac=s.team_frac, device_frac=s.device_frac,
            eval_every=eval_every, mesh=mesh, cohort=s.cohort_size,
            system=s.system if system is _KEEP_SPEC_SYSTEM else system,
            trace=trace, trace_dir=trace_dir,
            event_meta={"scenario": s.name, "family": s.family,
                        "spec_hash": s.spec_hash()})
