"""`SCENARIOS` — the string-keyed registry of every named experiment.

Every paper cell is here (Table 1 MCLR/non-convex x 4 datasets, Table 2
team structures, figs 2/3/4, the comm tradeoff sweep), each carrying its
published reference numbers, plus the *new* scenario families the paper
never ran — Dirichlet label skew, quantity skew, feature-shift tabular,
and worst/average team formation at larger (M, N) grids. Benchmarks,
examples, and tests construct their experiments by name from this dict;
adding a workload means registering a spec, not writing a script.

Naming: ``{family}/{...}`` with the family as the first segment —
``table1/{dataset}/{model}/{algo}``, ``table2/{dataset}/{strategy}``,
``fig2/{dataset}/{model}/{algo}``, ``fig4/.../{mode}``,
``comm/.../{compressor}``, ``dirichlet/{dataset}/a{alpha}``,
``quantity/{dataset}/q{min_frac}``, ``featshift/{model}/s{shift}``,
``teams/{strategy}/m{M}n{N}``, ``cohort/virtual/n{N}``.

Registered ``rounds`` are the paper-scale (--full) budgets; quick-mode
benchmarks override rounds (and derive shrunken CNN variants via
``FLScenario.scaled``) at run time.
"""
from __future__ import annotations

from repro.comm import CommConfig
from repro.scenarios.paper_refs import table1_ref
from repro.scenarios.spec import (ALGO_METRICS, AlgoSpec, DataSpec,
                                  FLScenario, ModelSpec)

__all__ = ["SCENARIOS", "families", "get_scenario", "register"]

SCENARIOS: dict = {}

# the Table-1 suite (benchmarks iterate this order)
TABLE1_DATASETS = ("mnist", "fmnist", "emnist10", "synthetic")
TABLE1_ALGOS = ("permfl", "fedavg", "perfedavg", "pfedme", "ditto",
                "hsgd", "l2gd")


def register(scenario: FLScenario) -> FLScenario:
    """Add `scenario` under its name; duplicate names are an error."""
    if scenario.name in SCENARIOS:
        raise ValueError(f"duplicate scenario name {scenario.name!r}")
    SCENARIOS[scenario.name] = scenario
    return scenario


def get_scenario(name_or_spec) -> FLScenario:
    """Resolve a registry name, a spec dict, or an FLScenario instance
    to the FLScenario itself (KeyError lists near-misses for names)."""
    if isinstance(name_or_spec, FLScenario):
        return name_or_spec
    if isinstance(name_or_spec, dict):
        return FLScenario.from_dict(name_or_spec)
    name = str(name_or_spec)
    if name in SCENARIOS:
        return SCENARIOS[name]
    near = [k for k in SCENARIOS
            if name.split("/")[0] == k.split("/")[0]][:8]
    raise KeyError(f"unknown scenario {name!r}; "
                   + (f"same family: {near}" if near
                      else f"families: {sorted(families())}"))


def families() -> list:
    """Sorted list of registered scenario families (name prefixes)."""
    return sorted({k.split("/")[0] for k in SCENARIOS})


# ---------------------------------------------------------------------------
# paper cells
# ---------------------------------------------------------------------------

def _image_data(dataset, **kw):
    if dataset in ("synthetic", "featshift"):
        return DataSpec(dataset=dataset, partitioner="tabular", **kw)
    return DataSpec(dataset=dataset, **kw)


def _table1_algo(algo: str, convex: bool) -> AlgoSpec:
    """Table-1 constructor settings: device lr 0.03 (convex) / 0.01
    (non-convex); PerMFL keeps the paper §4.1.4 hyperparameters."""
    lr = 0.03 if convex else 0.01
    ov = {
        "permfl": {},
        "fedavg": {"lr": lr, "local_steps": 50},
        "perfedavg": {"lr": lr, "inner_lr": lr, "local_steps": 20},
        "pfedme": {"inner_lr": lr},
        "ditto": {"lr": lr, "local_steps": 20},
        "hsgd": {"lr": lr},
        "l2gd": {"lr": lr},
    }[algo]
    return AlgoSpec(algo, tuple(ov.items()))


def _register_table1():
    for ds in TABLE1_DATASETS:
        for convex in (True, False):
            kind = "mclr" if convex else ("dnn" if ds == "synthetic"
                                          else "cnn")
            for algo in TABLE1_ALGOS:
                ref = tuple(
                    (m, v) for m in ALGO_METRICS[algo]
                    if (v := table1_ref(ds, convex, f"{algo}_{m}"))
                    is not None)
                register(FLScenario(
                    name=f"table1/{ds}/{kind}/{algo}",
                    data=_image_data(ds),
                    model=ModelSpec(kind),
                    algo=_table1_algo(algo, convex),
                    rounds=60 if convex else 40,
                    data_seed=0, family="table1", paper_ref=ref,
                    notes="Table 1: PerMFL vs baselines on identical "
                          "non-IID partitions"))


def _register_table2():
    for ds in ("mnist", "fmnist"):
        for strategy in ("worst", "average"):
            register(FLScenario(
                name=f"table2/{ds}/{strategy}",
                data=DataSpec(dataset=ds, m_teams=2, n_devices=10,
                              strategy=strategy),
                rounds=30, data_seed=3, family="table2",
                notes="Table 2: team-formation ablation (PM robust, GM "
                      "degrades in the worst case)"))


def _register_fig2():
    for kind in ("mclr", "cnn"):
        lr = 0.03 if kind == "mclr" else 0.01
        for algo in ("permfl", "hsgd", "l2gd"):
            ov = () if algo == "permfl" else (("lr", lr),)
            register(FLScenario(
                name=f"fig2/fmnist/{kind}/{algo}",
                data=DataSpec(dataset="fmnist"),
                model=ModelSpec(kind),
                algo=AlgoSpec(algo, ov),
                rounds=40, data_seed=1, family="fig2",
                notes="Fig 2: convergence vs multi-tier SOTA"))


def _register_fig3_fig4():
    register(FLScenario(
        name="fig3/mnist/mclr",
        data=DataSpec(dataset="mnist"),
        rounds=20, data_seed=2, family="fig3",
        notes="Fig 3: beta/gamma/lambda sweep base — apply the grid via "
              "sweep_scenario"))
    for mode, tf, df in (("full", 1.0, 1.0), ("devices_50", 1.0, 0.5),
                         ("teams_50", 0.5, 1.0), ("both_25", 0.25, 0.25)):
        register(FLScenario(
            name=f"fig4/mnist/mclr/{mode}",
            data=DataSpec(dataset="mnist"),
            team_frac=tf, device_frac=df,
            rounds=40, data_seed=4, family="fig4",
            notes="Fig 4: partial team/device participation"))


def _register_comm():
    comms = [("uncompressed", None),
             ("identity", CommConfig("identity")),
             ("topk_10", CommConfig("topk", k_frac=0.1)),
             ("topk_25", CommConfig("topk", k_frac=0.25)),
             ("randk_10", CommConfig("randk", k_frac=0.1)),
             ("int8", CommConfig("int8")),
             ("sign", CommConfig("sign"))]
    for cname, ccfg in comms:
        register(FLScenario(
            name=f"comm/mnist/mclr/{cname}",
            data=DataSpec(dataset="mnist"),
            comm=ccfg,
            rounds=40, data_seed=6, family="comm",
            notes="accuracy-vs-MB tradeoff for the tiered comm subsystem"))


# ---------------------------------------------------------------------------
# new scenario families (beyond the paper)
# ---------------------------------------------------------------------------

def _register_dirichlet():
    """Dirichlet-style statistical heterogeneity (cf. Personalized FL for
    Statistical Heterogeneity): alpha sweeps from near-single-class
    devices to near-IID."""
    for ds, alphas in (("mnist", (0.1, 0.5, 1.0)), ("fmnist", (0.5,))):
        for a in alphas:
            register(FLScenario(
                name=f"dirichlet/{ds}/a{a:g}",
                data=DataSpec(dataset=ds, partitioner="dirichlet",
                              alpha=a),
                rounds=12, data_seed=10, family="dirichlet",
                notes=f"Dir({a:g}) per-device class mixes; alpha->0 is "
                      "harsher than the paper's 2-class skew"))


def _register_quantity():
    for ds, frac in (("mnist", 0.25), ("fmnist", 0.10)):
        register(FLScenario(
            name=f"quantity/{ds}/q{int(frac * 100)}",
            data=DataSpec(dataset=ds, partitioner="quantity",
                          min_frac=frac),
            rounds=12, data_seed=11, family="quantity",
            notes="power-law effective dataset sizes, IID classes"))


def _register_featshift():
    """Covariate shift with a shared concept (cf. Distributed
    Personalized Empirical Risk Minimization's shared/personal split)."""
    for kind, shifts in (("mclr", (0.5, 2.0)), ("dnn", (2.0,))):
        for s in shifts:
            register(FLScenario(
                name=f"featshift/{kind}/s{s:g}",
                data=DataSpec(dataset="featshift", partitioner="tabular",
                              shift=s),
                model=ModelSpec(kind),
                rounds=12, data_seed=12, family="featshift",
                notes="team-shifted features, shared labeling concept"))


def _register_cohort():
    """Virtualized cohort-engine scale-out (DESIGN.md §11): populations
    of 10^3-10^6 devices per team, of which only a ``cohort_size`` slab
    is materialized per round. Uses the fully vectorized "virtual"
    dataset so even the 10^6 population builds in seconds; PerMFL runs
    with shallow inner loops — the point is the N-scaling, not the
    paper's accuracy cells."""
    algo = AlgoSpec("permfl", (("k_team", 2), ("l_local", 2)))
    for n, cohort, rounds in ((1_000, 64, 20), (10_000, 64, 10),
                              (100_000, 128, 10), (1_000_000, 256, 5)):
        register(FLScenario(
            name=f"cohort/virtual/n{n}",
            data=DataSpec(dataset="virtual", partitioner="tabular",
                          m_teams=2, n_devices=n, samples_per_device=8),
            algo=algo,
            cohort_size=cohort,
            rounds=rounds, data_seed=21, family="cohort",
            notes=f"sample-then-materialize: {cohort} of {n} devices "
                  "per team per round"))


def _register_team_grids():
    """Worst/average-case formation at larger (M, N) than the paper's
    2x10 ablation; n_per_class grows so worst-case single-class team
    pools aren't exhausted."""
    for m, n in ((6, 15), (8, 20)):
        for strategy in ("worst", "average"):
            register(FLScenario(
                name=f"teams/{strategy}/m{m}n{n}",
                data=DataSpec(dataset="mnist", m_teams=m, n_devices=n,
                              strategy=strategy, n_per_class=60 * n),
                rounds=20, data_seed=13, family="teams",
                notes=f"{strategy}-case formation at {m} teams x {n} "
                      "devices"))


_register_table1()
_register_table2()
_register_fig2()
_register_fig3_fig4()
_register_comm()
_register_dirichlet()
_register_quantity()
_register_featshift()
_register_team_grids()
_register_cohort()
