"""The paper's published numbers — single source of truth.

Table 1 validation accuracies (%) from the PerMFL paper's A100 runs,
quoted next to our offline-synthetic reproductions for qualitative
side-by-side comparison (orderings, not magnitudes, are the reproduction
target). Historically these lived in ``benchmarks/fl_common.py``; they
now ride on the scenario registry (each Table-1 scenario carries its
``paper_ref`` pairs) and both the benchmarks and the table generators
read them from here.

Keys are ``{algo}_{metric}`` in the paper's naming — note the paper
calls our ``l2gd`` baseline *AL2GD*, so lookups fall back to the
``a``-prefixed key.
"""
from __future__ import annotations

__all__ = ["PAPER_TABLE1_MCLR", "PAPER_TABLE1_NONCONVEX", "table1_ref"]

# {dataset: {algo_metric: paper accuracy %}}
PAPER_TABLE1_MCLR = {
    "mnist": {"fedavg_gm": 84.87, "perfedavg_pm": 94.81, "pfedme_pm": 88.89,
              "ditto_gm": 84.81, "hsgd_gm": 87.41, "al2gd_pm": 93.70,
              "permfl_gm": 86.92, "permfl_pm": 96.87},
    "synthetic": {"fedavg_gm": 79.80, "perfedavg_pm": 83.91,
                  "pfedme_pm": 87.61, "ditto_gm": 74.02, "hsgd_gm": 84.29,
                  "al2gd_pm": 84.75, "permfl_gm": 84.92, "permfl_pm": 87.94},
    "fmnist": {"fedavg_gm": 84.87, "perfedavg_pm": 94.75, "pfedme_pm": 91.23,
               "ditto_gm": 82.35, "hsgd_gm": 92.33, "al2gd_pm": 98.52,
               "permfl_gm": 83.71, "permfl_pm": 96.77},
    "emnist10": {"fedavg_gm": 91.60, "perfedavg_pm": 97.57,
                 "pfedme_pm": 91.32, "ditto_gm": 91.03, "hsgd_gm": 81.65,
                 "al2gd_pm": 98.72, "permfl_gm": 91.68, "permfl_pm": 96.49},
}
PAPER_TABLE1_NONCONVEX = {
    "mnist": {"fedavg_gm": 93.17, "perfedavg_pm": 91.85, "pfedme_pm": 97.40,
              "ditto_gm": 87.30, "hsgd_gm": 86.59, "al2gd_pm": 91.04,
              "permfl_gm": 89.39, "permfl_pm": 98.15},
    "synthetic": {"fedavg_gm": 84.53, "perfedavg_pm": 75.93,
                  "pfedme_pm": 87.86, "ditto_gm": 81.12, "hsgd_gm": 87.42,
                  "al2gd_pm": 84.92, "permfl_gm": 87.53, "permfl_pm": 87.89},
    "fmnist": {"fedavg_gm": 84.14, "perfedavg_pm": 88.69, "pfedme_pm": 96.30,
               "ditto_gm": 57.80, "hsgd_gm": 79.84, "al2gd_pm": 71.32,
               "permfl_gm": 79.15, "permfl_pm": 98.67},
    "emnist10": {"fedavg_gm": 92.73, "perfedavg_pm": 97.37,
                 "pfedme_pm": 97.18, "ditto_gm": 90.58, "hsgd_gm": 96.03,
                 "al2gd_pm": 92.94, "permfl_gm": 93.12, "permfl_pm": 98.79},
}


def table1_ref(dataset: str, convex: bool, key: str):
    """Paper accuracy for ``{algo}_{metric}`` ``key`` on ``dataset``
    (convex selects the MCLR vs non-convex table), or None if the paper
    does not quote that cell. ``l2gd_*`` falls back to the paper's
    ``al2gd_*`` naming."""
    table = PAPER_TABLE1_MCLR if convex else PAPER_TABLE1_NONCONVEX
    row = table.get(dataset, {})
    return row.get(key, row.get("a" + key))
