"""Paper model: multi-class logistic regression (strongly convex w/ l2)."""
from repro.configs.base import PaperModelConfig

CONFIG = PaperModelConfig(
    name="paper-mclr", kind="mclr", input_shape=(784,), num_classes=10,
    l2_reg=1e-2, convex=True)
