"""DBRX 132B [moe] — hf:databricks/dbrx-base.

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, MoE 16 experts
top-4, fine-grained.
"""
from repro.configs.base import ModelConfig, MoEConfig, reduce_config

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100_352,
    moe=MoEConfig(num_experts=16, num_shared_experts=0, top_k=4,
                  expert_d_ff=10752, router_aux_weight=0.05),
    moe_layer_period=1,
    rope_theta=500_000.0,
    citation="hf:databricks/dbrx-base",
)

REDUCED = reduce_config(CONFIG)
