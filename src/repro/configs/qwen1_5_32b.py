"""Qwen1.5-32B [dense] — hf:Qwen/Qwen1.5-0.5B (family card).

64L d_model=5120 40H (GQA kv=40) d_ff=27392 vocab=152064 — QKV bias.
"""
from repro.configs.base import ModelConfig, reduce_config

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=27392,
    vocab_size=152_064,
    use_qkv_bias=True,
    rope_theta=1_000_000.0,
    citation="hf:Qwen/Qwen1.5-0.5B",
)

REDUCED = reduce_config(CONFIG)
