"""Qwen3-14B [dense] — hf:Qwen/Qwen3-8B (family card).

40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936 — qk_norm, GQA.
"""
from repro.configs.base import ModelConfig, reduce_config

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151_936,
    use_qk_norm=True,
    rope_theta=1_000_000.0,
    citation="hf:Qwen/Qwen3-8B",
)

REDUCED = reduce_config(CONFIG)
