"""Qwen2-VL 2B [vlm] — arXiv:2409.12191.

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936 — M-RoPE, dynamic
resolution. Vision encoder (ViT) is a stub per the brief: ``input_specs``
provides precomputed patch embeddings; this config is the LM backbone that
consumes them (mixed text tokens + vision embeds).
"""
from repro.configs.base import ModelConfig, reduce_config

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151_936,
    use_mrope=True,
    use_qkv_bias=True,
    embedding_inputs=True,   # frontend stub: patch embeddings arrive precomputed
    rope_theta=1_000_000.0,
    citation="arXiv:2409.12191",
)

REDUCED = reduce_config(CONFIG)
