"""Config system: model/arch configs, input shapes, and the registry.

Every assigned architecture gets a module in ``repro/configs/<id>.py`` that
builds a :class:`ModelConfig` with the exact dimensions from its source
paper/model card, plus a ``reduced()`` variant used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Sequence

# ---------------------------------------------------------------------------
# Input shapes (assigned; fixed across architectures)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0           # routed experts
    num_shared_experts: int = 0    # always-on experts (DeepSeek-MoE)
    top_k: int = 0
    expert_d_ff: int = 0           # per-expert FFN width (fine-grained MoE)
    router_aux_weight: float = 0.01
    capacity_factor: float = 1.25  # tokens over capacity are dropped


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    # attention features
    rope_theta: float = 10_000.0
    use_qk_norm: bool = False
    use_qkv_bias: bool = False
    use_mrope: bool = False        # multimodal rotary (Qwen2-VL)
    sliding_window: int = 0        # 0 = full attention; >0 = SWA window
    # norm / act
    norm_eps: float = 1e-6
    use_rmsnorm: bool = True
    tie_embeddings: bool = False
    # MoE
    moe: MoEConfig = field(default_factory=MoEConfig)
    moe_layer_period: int = 1      # every n-th layer is MoE (1 = all, when moe on)
    # hybrid (Jamba): 1 attention layer per `attn_period` layers, rest Mamba
    attn_period: int = 0           # 0 = pure attention (or pure ssm for rwkv)
    # ssm dims
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    rwkv_head_dim: int = 64
    # encoder-decoder (Whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq_len: int = 0       # e.g. 1500 audio frames
    max_decoder_len: int = 0       # architecture-native decoder context (0 = unlimited)
    # modality frontend stub: inputs arrive as precomputed embeddings
    embedding_inputs: bool = False
    citation: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def layer_kinds(self) -> list[str]:
        """Per-layer kind sequence: 'attn' | 'mamba' for the mixer."""
        if self.family == "ssm":
            return ["rwkv"] * self.num_layers
        if self.attn_period and self.attn_period > 1:
            # Jamba: one attention layer per attn_period, at position
            # (attn_period//2) within each block (matches Jamba's 1:7).
            kinds = []
            for i in range(self.num_layers):
                kinds.append("attn" if i % self.attn_period == self.attn_period // 2
                             else "mamba")
            return kinds
        return ["attn"] * self.num_layers

    def moe_layer_mask(self) -> list[bool]:
        if self.moe.num_experts == 0:
            return [False] * self.num_layers
        p = max(self.moe_layer_period, 1)
        return [(i % p == p - 1) if p > 1 else True for i in range(self.num_layers)]

    def supports_long_decode(self) -> bool:
        """long_500k policy (DESIGN.md §5): native for ssm/hybrid, via SWA for
        decoder-only attention archs, skipped for enc-dec (whisper)."""
        if self.is_encoder_decoder:
            return False
        return True

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Paper-scale model configs (MCLR / CNN / DNN from the PerMFL experiments)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PaperModelConfig:
    name: str
    kind: str                      # "mclr" | "cnn" | "dnn"
    input_shape: tuple             # e.g. (784,) or (28, 28, 1) or (60,)
    num_classes: int = 10
    hidden: Sequence[int] = ()     # dnn hidden widths
    conv_channels: Sequence[int] = ()  # cnn channels
    l2_reg: float = 0.0            # strongly-convex regularizer for MCLR
    convex: bool = False


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS: list[str] = [
    "phi3-mini-3.8b",
    "qwen2-vl-2b",
    "qwen1.5-32b",
    "deepseek-moe-16b",
    "whisper-small",
    "qwen3-14b",
    "dbrx-132b",
    "jamba-1.5-large-398b",
    "yi-34b",
    "rwkv6-7b",
]

_MODULE_FOR_ARCH = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
                    for a in ARCH_IDS}
# paper-scale configs used by the faithful reproduction
PAPER_IDS = ["paper-mclr", "paper-cnn", "paper-dnn"]
_MODULE_FOR_ARCH.update({a: "repro.configs." + a.replace("-", "_") for a in PAPER_IDS})


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULE_FOR_ARCH:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULE_FOR_ARCH)}")
    mod = importlib.import_module(_MODULE_FOR_ARCH[arch])
    return mod.CONFIG


def get_reduced_config(arch: str) -> ModelConfig:
    """Smoke-test variant: ≤2 layers, d_model ≤ 512, ≤4 experts."""
    mod = importlib.import_module(_MODULE_FOR_ARCH[arch])
    if hasattr(mod, "REDUCED"):
        return mod.REDUCED
    return reduce_config(mod.CONFIG)


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Generic reducer preserving the family's structural features."""
    heads = min(cfg.num_heads, 4)
    kv = max(1, min(cfg.num_kv_heads, heads))
    # keep GQA ratio if it was grouped
    if cfg.num_kv_heads < cfg.num_heads:
        kv = max(1, heads // max(1, cfg.num_heads // cfg.num_kv_heads))
    d_model = min(cfg.d_model, 256)
    hd = max(32, d_model // heads)
    moe = cfg.moe
    if moe.num_experts:
        moe = dataclasses.replace(
            moe, num_experts=min(moe.num_experts, 4),
            num_shared_experts=min(moe.num_shared_experts, 1),
            top_k=min(moe.top_k, 2),
            expert_d_ff=min(moe.expert_d_ff or 128, 128))
    return cfg.replace(
        num_layers=2 if not cfg.attn_period else min(cfg.num_layers, cfg.attn_period),
        d_model=d_model, num_heads=heads, num_kv_heads=kv, head_dim=hd,
        d_ff=min(cfg.d_ff, 512), vocab_size=min(cfg.vocab_size, 512),
        moe=moe, encoder_layers=min(cfg.encoder_layers, 2),
        encoder_seq_len=min(cfg.encoder_seq_len, 64) if cfg.encoder_seq_len else 0,
        attn_period=min(cfg.attn_period, 2) if cfg.attn_period else 0,
    )


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (used for 6ND model FLOPs)."""
    d, v = cfg.d_model, cfg.vocab_size
    hd = cfg.resolved_head_dim
    n_q, n_kv = cfg.num_heads, cfg.num_kv_heads
    total = v * d  # embed
    if not cfg.tie_embeddings:
        total += v * d  # lm head
    kinds = cfg.layer_kinds()
    moe_mask = cfg.moe_layer_mask()
    for kind, is_moe in zip(kinds, moe_mask):
        if kind == "attn":
            attn = d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d
            if cfg.use_qkv_bias:
                attn += (n_q + 2 * n_kv) * hd
            total += attn
        elif kind == "mamba":
            d_in = cfg.mamba_expand * d
            total += (2 * d * d_in            # in_proj (x, z)
                      + d_in * cfg.mamba_d_conv
                      + d_in * (2 * cfg.mamba_d_state + d_in // 16 + 1)
                      + d_in * d)             # out_proj
        elif kind == "rwkv":
            # time-mix: r,k,v,g,o projections + data-dependent decay lora
            total += 5 * d * d + 4 * d * 64 + d * 32
            # channel-mix
            total += 2 * d * cfg.d_ff // 2 + d * d
        if is_moe:
            e_ff = cfg.moe.expert_d_ff or cfg.d_ff
            total += (cfg.moe.num_experts + cfg.moe.num_shared_experts) * 3 * d * e_ff
            total += d * cfg.moe.num_experts  # router
        elif kind != "rwkv":
            total += 3 * d * cfg.d_ff  # SwiGLU
        total += 2 * d  # norms
    if cfg.is_encoder_decoder:
        for _ in range(cfg.encoder_layers):
            total += 4 * d * d + 2 * d * cfg.d_ff + 2 * d     # enc self-attn + mlp(gelu)
        total += cfg.num_layers * (4 * d * d + d)              # decoder cross-attn
    return int(total)


def active_param_count(cfg: ModelConfig) -> int:
    """Active (per-token) params for MoE: routed top_k + shared only."""
    if cfg.moe.num_experts == 0:
        return param_count(cfg)
    full = param_count(cfg)
    e_ff = cfg.moe.expert_d_ff or cfg.d_ff
    per_expert = 3 * cfg.d_model * e_ff
    n_moe_layers = sum(cfg.moe_layer_mask())
    inactive = n_moe_layers * (cfg.moe.num_experts - cfg.moe.top_k) * per_expert
    return int(full - inactive)
