"""RWKV-6 (Finch) 7B [ssm] — arXiv:2404.05892.

32L d_model=4096 (attention-free) d_ff=14336 vocab=65536 — data-dependent
decay linear attention (WKV6). num_heads below is the WKV head count
(head_dim=64 per the RWKV-6 paper).
"""
from repro.configs.base import ModelConfig, reduce_config

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,            # wkv heads = d_model / rwkv_head_dim
    num_kv_heads=64,
    d_ff=14336,
    vocab_size=65_536,
    rwkv_head_dim=64,
    citation="arXiv:2404.05892",
)

REDUCED = reduce_config(CONFIG).replace(num_heads=4, num_kv_heads=4,
                                        rwkv_head_dim=64, d_model=256)
