"""Paper model: 2-hidden-layer DNN for the synthetic tabular dataset."""
from repro.configs.base import PaperModelConfig

CONFIG = PaperModelConfig(
    name="paper-dnn", kind="dnn", input_shape=(60,), num_classes=10,
    hidden=(64, 32))
