"""Phi-3-mini 3.8B [dense] — arXiv:2404.14219.

32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064 — RoPE SwiGLU GQA.
"""
from repro.configs.base import ModelConfig, reduce_config

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=10_000.0,
    tie_embeddings=False,
    citation="arXiv:2404.14219",
)

REDUCED = reduce_config(CONFIG)
