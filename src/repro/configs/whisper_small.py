"""Whisper-small [audio] — arXiv:2212.04356.

12L d_model=768 12H (kv=12) d_ff=3072 vocab=51865 — encoder-decoder; the
mel-spectrogram + conv frontend is a stub: ``input_specs`` hands the encoder
precomputed frame embeddings (1500 frames after the conv stride-2).
Decode shapes: decode_32k is lowered mechanically against the requested KV
length; long_500k is SKIPPED (448-token native decoder context; see
DESIGN.md §5).
"""
from repro.configs.base import ModelConfig, reduce_config

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,            # decoder layers
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51_865,
    use_rmsnorm=False,        # whisper uses LayerNorm + GELU
    is_encoder_decoder=True,
    encoder_layers=12,
    encoder_seq_len=1500,
    max_decoder_len=448,
    embedding_inputs=True,    # frontend stub: frame embeddings precomputed
    citation="arXiv:2212.04356",
)

REDUCED = reduce_config(CONFIG)
