"""Jamba-1.5-Large 398B [hybrid] — arXiv:2403.19887.

72L d_model=8192 64H (GQA kv=8) d_ff=24576, MoE 16e top-2 — Mamba+attention
1:7 interleave (attn_period=8: one attention layer per 8-layer block), MoE on
every other layer (Jamba places MoE at period 2).
"""
from repro.configs.base import ModelConfig, MoEConfig, reduce_config

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65_536,
    moe=MoEConfig(num_experts=16, num_shared_experts=0, top_k=2,
                  expert_d_ff=24576, router_aux_weight=0.01),
    moe_layer_period=2,
    attn_period=8,             # 1 attention : 7 mamba
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    citation="arXiv:2403.19887",
)

REDUCED = reduce_config(CONFIG)
