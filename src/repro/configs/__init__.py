from repro.configs.base import (ARCH_IDS, INPUT_SHAPES, PAPER_IDS, InputShape,
                                ModelConfig, MoEConfig, PaperModelConfig,
                                active_param_count, get_config,
                                get_reduced_config, param_count,
                                reduce_config)

__all__ = [
    "ARCH_IDS", "INPUT_SHAPES", "PAPER_IDS", "InputShape", "ModelConfig",
    "MoEConfig", "PaperModelConfig", "active_param_count", "get_config",
    "get_reduced_config", "param_count", "reduce_config",
]
