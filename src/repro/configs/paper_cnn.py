"""Paper model: two-layer CNN for image datasets (non-convex)."""
from repro.configs.base import PaperModelConfig

CONFIG = PaperModelConfig(
    name="paper-cnn", kind="cnn", input_shape=(28, 28, 1), num_classes=10,
    conv_channels=(16, 32), hidden=(128,))
