"""DeepSeek-MoE 16B [moe] — arXiv:2401.06066.

28L d_model=2048 16H (GQA kv=16) expert d_ff=1408 vocab=102400,
fine-grained MoE: 2 shared + 64 routed experts, top-6.
"""
from repro.configs.base import ModelConfig, MoEConfig, reduce_config

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,               # expert FFN width (fine-grained)
    vocab_size=102_400,
    moe=MoEConfig(num_experts=64, num_shared_experts=2, top_k=6,
                  expert_d_ff=1408, router_aux_weight=0.01),
    moe_layer_period=1,
    citation="arXiv:2401.06066",
)

REDUCED = reduce_config(CONFIG)
