"""Pure-jnp oracle for the fused int8 stochastic quantize/pack kernel.

Row-wise (128-lane) symmetric int8 quantization with stochastic rounding:

    scale_r = max(|v_r|) / 127          (one f32 scale per 128 elements)
    q       = clip(floor(v / scale + u), -127, 127)     u ~ U[0, 1)
    dq      = q * scale

``floor(x + u)`` is unbiased stochastic rounding: E[q] = v / scale. The
noise is an explicit input (not an internal PRNG) so the Pallas kernel and
this oracle are bit-comparable and the compressed-round simulation is
deterministic under a fixed key. The packed wire format is (q int8, scales
f32): 1 byte/element + 4 bytes per 128-element row, a 3.9x size reduction
over fp32 that the CommLedger byte model mirrors.
"""
from __future__ import annotations

import jax.numpy as jnp

LANES = 128


def _to_rows(v, size):
    rows = -(-size // LANES)
    pad = rows * LANES - size
    if pad:
        v = jnp.pad(v, (0, pad))
    return v.reshape(rows, LANES), rows


def quantize_int8_ref(v, noise):
    """v, noise: flat (size,). Returns (q (size,) i8, scales (rows,) f32,
    dq (size,) of v.dtype)."""
    (size,) = v.shape
    v2, rows = _to_rows(v.astype(jnp.float32), size)
    n2, _ = _to_rows(noise.astype(jnp.float32), size)
    absmax = jnp.max(jnp.abs(v2), axis=1, keepdims=True)
    scale = jnp.maximum(absmax * (1.0 / 127.0), 1e-12)
    q = jnp.clip(jnp.floor(v2 / scale + n2), -127.0, 127.0)
    dq = q * scale
    return (q.astype(jnp.int8).reshape(-1)[:size],
            scale.reshape(-1),
            dq.reshape(-1)[:size].astype(v.dtype))


def dequantize_int8_ref(q, scales, size=None):
    """Inverse of the pack: q (size,) i8, scales (rows,) f32 -> (size,) f32."""
    size = q.shape[0] if size is None else size
    q2, _ = _to_rows(q.astype(jnp.float32), size)
    out = q2 * scales[:, None]
    return out.reshape(-1)[:size]
