from repro.kernels.quantize.ops import dequantize_int8, quantize_int8
from repro.kernels.quantize.quantize import quantize_int8_flat
from repro.kernels.quantize.ref import dequantize_int8_ref, quantize_int8_ref

__all__ = ["quantize_int8", "dequantize_int8", "quantize_int8_flat",
           "quantize_int8_ref", "dequantize_int8_ref"]
