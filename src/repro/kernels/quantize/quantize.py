"""Pallas TPU kernel: fused int8 stochastic quantize/pack (comm uplink).

Unfused, XLA materializes |v|, the row-max, v/scale, the noised round and
the dequantized echo as separate HBM round trips. Fused, v and the noise
stream through VMEM once and three outputs (packed q, per-row scales, the
dequantized value the simulator aggregates) are written in the same pass:
the bandwidth floor for the compression step that runs K times per global
round on every device's delta. Blocks are (block_rows, 128) — lane-aligned
for the VPU; arrays are flattened and padded to a multiple of 128 by the
wrapper, matching ref.py exactly so interpret mode is bit-comparable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _quant_kernel(v_ref, n_ref, q_out, s_out, dq_out):
    v = v_ref[...].astype(jnp.float32)
    u = n_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(v), axis=1, keepdims=True)
    scale = jnp.maximum(absmax * (1.0 / 127.0), 1e-12)
    q = jnp.clip(jnp.floor(v / scale + u), -127.0, 127.0)
    q_out[...] = q.astype(jnp.int8)
    s_out[...] = scale
    dq_out[...] = (q * scale).astype(dq_out.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def quantize_int8_flat(v, noise, *, block_rows: int = 256,
                       interpret: bool = False):
    """1-D inputs (already flat). Returns (q (size,) i8, scales (rows,) f32,
    dq (size,) of v.dtype)."""
    (size,) = v.shape
    rows = pl.cdiv(size, LANES)
    pad = rows * LANES - size

    def prep(x):
        if pad:
            x = jnp.pad(x, (0, pad))
        return x.reshape(rows, LANES)

    v2 = prep(v.astype(jnp.float32))
    n2 = prep(noise.astype(jnp.float32))
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    s_spec = pl.BlockSpec((block_rows, 1), lambda i: (i, 0))
    q, s, dq = pl.pallas_call(
        _quant_kernel, grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, s_spec, spec],
        out_shape=[jax.ShapeDtypeStruct((rows, LANES), jnp.int8),
                   jax.ShapeDtypeStruct((rows, 1), jnp.float32),
                   jax.ShapeDtypeStruct((rows, LANES), v.dtype)],
        interpret=interpret,
    )(v2, n2)
    return q.reshape(-1)[:size], s.reshape(-1), dq.reshape(-1)[:size]
