"""Public fused int8 quantize/pack op, with backend dispatch.

``quantize_int8`` quantizes an array of any shape to (int8 values, per-row
f32 scales) and also returns the dequantized echo — the value the stacked
simulator aggregates after a compressed uplink. Pallas kernel on TPU, the
jnp reference elsewhere; both consume the same explicit noise so results
are identical across backends.
"""
from __future__ import annotations

import os

import jax

from repro.kernels.quantize.quantize import LANES, quantize_int8_flat
from repro.kernels.quantize.ref import dequantize_int8_ref, quantize_int8_ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def quantize_int8(v, noise):
    """v, noise same shape (any). Returns (q int8 like v, scales (rows,) f32,
    dq like v) where rows = ceil(v.size / 128)."""
    shape = v.shape
    vf, nf = v.reshape(-1), noise.reshape(-1)
    if _on_tpu() or os.environ.get("FORCE_PALLAS_INTERPRET") == "1":
        q, s, dq = quantize_int8_flat(vf, nf, interpret=not _on_tpu())
    else:
        q, s, dq = quantize_int8_ref(vf, nf)
    return q.reshape(shape), s, dq.reshape(shape)


def dequantize_int8(q, scales):
    """Unpack (q int8 any shape, scales (rows,) f32) -> f32 like q."""
    out = dequantize_int8_ref(q.reshape(-1), scales)
    return out.reshape(q.shape)
