"""Public fused int8 quantize/pack op, with backend dispatch.

``quantize_int8`` quantizes an array of any shape to (int8 values, per-row
f32 scales) and also returns the dequantized echo — the value the stacked
simulator aggregates after a compressed uplink. Dispatch goes through the
unified :func:`repro.kernels.interface.kernel_mode` (Pallas on TPU, the
jnp reference elsewhere, ``REPRO_KERNEL_MODE`` to override); both paths
consume the same explicit noise so results are identical across backends.
"""
from __future__ import annotations

from repro.kernels.interface import KernelType, kernel_mode
from repro.kernels.quantize.quantize import LANES, quantize_int8_flat
from repro.kernels.quantize.ref import dequantize_int8_ref, quantize_int8_ref


def quantize_int8(v, noise, *, mode=None):
    """v, noise same shape (any). Returns (q int8 like v, scales (rows,) f32,
    dq like v) where rows = ceil(v.size / 128). ``mode`` overrides the
    ``KernelType`` dispatch (default: environment / backend)."""
    shape = v.shape
    vf, nf = v.reshape(-1), noise.reshape(-1)
    kt = kernel_mode(mode)
    if kt is KernelType.XLA:
        q, s, dq = quantize_int8_ref(vf, nf)
    else:
        q, s, dq = quantize_int8_flat(vf, nf,
                                      interpret=kt is not KernelType.PALLAS)
    return q.reshape(shape), s, dq.reshape(shape)


def dequantize_int8(q, scales):
    """Unpack (q int8 any shape, scales (rows,) f32) -> f32 like q."""
    out = dequantize_int8_ref(q.reshape(-1), scales)
    return out.reshape(q.shape)
