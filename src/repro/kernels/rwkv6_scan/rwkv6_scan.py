"""Pallas TPU kernel for the RWKV-6 WKV recurrence, chunked over time.

Grid: (batch, heads, time_chunks) — time chunks are the innermost,
sequential grid dimension. The (N x N) f32 recurrent state lives in VMEM
scratch and is carried across chunks, so HBM sees each (r,k,v,w) element
exactly once and the state never round-trips to HBM (the CUDA kernel in the
RWKV repo achieves the same with shared memory; VMEM is the TPU analogue).

Within a chunk the recurrence is evaluated stepwise on the VPU
(data-dependent diagonal decay makes the per-step update elementwise); the
chunk size only amortizes grid and DMA overhead. A matmul (MXU) formulation
via log-space cumulative decays is the recorded hillclimb candidate —
see EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sout_ref,
                 s_scr, *, chunk: int, seq_len: int):
    c_idx = pl.program_id(2)
    n_chunks = pl.num_programs(2)

    @pl.when(c_idx == 0)
    def _init():
        s_scr[...] = s0_ref[0, 0, :, :].astype(jnp.float32)

    u = u_ref[0, :].astype(jnp.float32)              # (n,)

    def step(i, S):
        r_t = r_ref[0, i, 0, :].astype(jnp.float32)  # (n,)
        k_t = k_ref[0, i, 0, :].astype(jnp.float32)
        v_t = v_ref[0, i, 0, :].astype(jnp.float32)
        w_t = w_ref[0, i, 0, :].astype(jnp.float32)
        kv = k_t[:, None] * v_t[None, :]             # (n, n)
        out = ((S + u[:, None] * kv) * r_t[:, None]).sum(axis=0)
        o_ref[0, i, 0, :] = out.astype(o_ref.dtype)
        # positions past seq_len (padded final chunk) must not advance state
        valid = (c_idx * chunk + i) < seq_len
        S_new = jnp.where(valid, w_t[:, None] * S + kv, S)
        return S_new

    s_scr[...] = jax.lax.fori_loop(0, chunk, step, s_scr[...])

    @pl.when(c_idx == n_chunks - 1)
    def _finish():
        sout_ref[0, 0, :, :] = s_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, state=None, *, chunk: int = 128,
         interpret: bool = False):
    """r,k,v,w: (b, t, h, n); u: (h, n); state: (b, h, n, n) f32 or None."""
    b, t, h, n = r.shape
    if state is None:
        state = jnp.zeros((b, h, n, n), jnp.float32)
    chunk = min(chunk, t)
    n_chunks = pl.cdiv(t, chunk)
    pad = n_chunks * chunk - t
    if pad:
        z = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, w = z(r), z(k), z(v), z(w)

    grid = (b, h, n_chunks)
    kernel = functools.partial(_wkv6_kernel, chunk=chunk, seq_len=t)
    tspec = pl.BlockSpec((1, chunk, 1, n), lambda b_, h_, c: (b_, c, h_, 0))
    out, s_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            tspec, tspec, tspec, tspec,
            pl.BlockSpec((1, n), lambda b_, h_, c: (h_, 0)),
            pl.BlockSpec((1, 1, n, n), lambda b_, h_, c: (b_, h_, 0, 0)),
        ],
        out_specs=[
            tspec,
            pl.BlockSpec((1, 1, n, n), lambda b_, h_, c: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n_chunks * chunk, h, n), r.dtype),
            jax.ShapeDtypeStruct((b, h, n, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, state)
    return out[:, :t], s_out
