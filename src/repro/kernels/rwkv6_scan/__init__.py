from repro.kernels.rwkv6_scan.ops import wkv
from repro.kernels.rwkv6_scan.ref import wkv6_ref
from repro.kernels.rwkv6_scan.rwkv6_scan import wkv6

__all__ = ["wkv", "wkv6_ref", "wkv6"]
