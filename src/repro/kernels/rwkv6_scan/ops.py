"""Public WKV-6 op with backend dispatch (TPU Pallas / interpret / jnp ref)."""
from __future__ import annotations

import os

import jax

from repro.kernels.rwkv6_scan.ref import wkv6_ref
from repro.kernels.rwkv6_scan.rwkv6_scan import wkv6


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def wkv(r, k, v, w, u, state=None, *, chunk: int = 128):
    if _on_tpu():
        return wkv6(r, k, v, w, u, state, chunk=chunk)
    if os.environ.get("FORCE_PALLAS_INTERPRET") == "1":
        return wkv6(r, k, v, w, u, state, chunk=chunk, interpret=True)
    return wkv6_ref(r, k, v, w, u, state)
