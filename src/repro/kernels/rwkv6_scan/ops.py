"""Public WKV-6 op dispatched through the unified ``kernel_mode()``."""
from __future__ import annotations

from repro.kernels.interface import KernelType, kernel_mode
from repro.kernels.rwkv6_scan.ref import wkv6_ref
from repro.kernels.rwkv6_scan.rwkv6_scan import wkv6


def wkv(r, k, v, w, u, state=None, *, chunk: int = 128, mode=None):
    """WKV-6 linear-attention scan over (B, T, H, N) inputs.

    Routes through ``kernel_mode(mode)``: ``xla`` runs the jnp reference,
    otherwise the chunked Pallas scan (interpret unless on TPU). Returns
    ``(out, final_state)``.
    """
    kt = kernel_mode(mode)
    if kt is KernelType.XLA:
        return wkv6_ref(r, k, v, w, u, state)
    return wkv6(r, k, v, w, u, state, chunk=chunk,
                interpret=kt is not KernelType.PALLAS)
