"""Pure-jnp oracle for the RWKV-6 (Finch) WKV recurrence.

Per head with head_dim N, state S in R^{N x N} (key-dim x value-dim):

    out_t = r_t @ (S_{t-1} + diag(u) k_t v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T

with data-dependent decay w_t in (0, 1) (RWKV-6's headline feature) and
per-head bonus u. lax.scan over time, vmapped over (batch, head).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=())
def wkv6_ref(r, k, v, w, u, state=None):
    """r,k,v,w: (b, t, h, n); u: (h, n); state: (b, h, n, n) or None.

    Returns (out: (b, t, h, n), final_state: (b, h, n, n)). Math in f32.
    """
    b, t, h, n = r.shape
    dtype = r.dtype
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)
    from repro.sharding.constrain import constrain
    if state is None:
        state = jnp.zeros((b, h, n, n), jnp.float32)
    else:
        state = state.astype(jnp.float32)
    state = constrain(state, "batch", "model", None, None)

    # chunked scan: the outer (checkpointed) scan carries only per-chunk
    # state snapshots, so backward residuals are O(t/chunk * n^2) instead of
    # O(t * n^2) — without chunking the 4096-step backward residuals
    # dominate the training-memory roofline.
    chunk = min(128, t)
    n_chunks = -(-t // chunk)
    pad = n_chunks * chunk - t

    def head_scan(S0, rs, ks, vs, ws, us):
        # rs..: (t, n); us: (n,); S0: (n, n)
        if pad:
            z = lambda x: jnp.pad(x, ((0, pad), (0, 0)))
            rs, ks, vs = z(rs), z(ks), z(vs)
            ws = jnp.pad(ws, ((0, pad), (0, 0)), constant_values=1.0)
        rs, ks, vs, ws = (x.reshape(n_chunks, chunk, n)
                          for x in (rs, ks, vs, ws))

        def step(S, inp):
            r_t, k_t, v_t, w_t = inp
            kv = k_t[:, None] * v_t[None, :]              # (n, n)
            out = r_t @ (S + us[:, None] * kv)            # (n,)
            S = w_t[:, None] * S + kv
            return S, out

        @jax.checkpoint
        def chunk_fn(S, inp):
            return jax.lax.scan(step, S, inp)

        S_fin, out = jax.lax.scan(chunk_fn, S0, (rs, ks, vs, ws))
        return S_fin, out.reshape(n_chunks * chunk, n)[:t]

    scan_bh = jax.vmap(jax.vmap(head_scan, in_axes=(0, 1, 1, 1, 1, 0),
                                out_axes=(0, 1)),
                       in_axes=(0, 0, 0, 0, 0, None), out_axes=(0, 0))
    final_state, out = scan_bh(state, rf, kf, vf, wf, uf)
    return out.astype(dtype), final_state
