"""Unified kernel dispatch: one ``KernelType`` enum for every Pallas op.

Every kernel package under ``repro.kernels`` ships a Pallas TPU kernel
and a pure-jnp XLA reference that stays the ground truth (DESIGN.md §10).
This module is the single place that decides which one runs — the
``KernelType`` enum-dispatch pattern from ddrous/mamba-jax's
``kernels/interface.py`` (SNIPPETS.md 1-2), grown an interpret mode so CI
can execute the actual Pallas kernel bodies on CPU:

  * ``PALLAS``    — compiled ``pl.pallas_call`` (needs a TPU backend)
  * ``XLA``       — the jnp reference implementation (``ref.py``)
  * ``INTERPRET`` — ``pl.pallas_call(..., interpret=True)``: the Pallas
                    body on any backend, bit-comparable to ``XLA``

Resolution precedence for :func:`kernel_mode`:

  1. an explicit ``mode=`` argument (string or ``KernelType``)
  2. ``REPRO_KERNEL_MODE`` = ``pallas`` | ``xla`` | ``interpret``
  3. the legacy ``FORCE_PALLAS_INTERPRET=1`` switch (-> ``INTERPRET``)
  4. backend default: ``PALLAS`` on TPU, ``XLA`` elsewhere

The resolved mode is an env lookup, so it is read at *trace* time; any
compiled program that bakes a kernel choice in must carry the mode on
its cache key — :func:`dispatch_key` is that key (the engine's compiled
program caches and ``permfl_round``'s jit include it, exactly like
``TraceConfig`` rides the probe path's keys). It also folds in
:func:`compress_fused` (``REPRO_COMPRESS_FUSED=0`` falls back to the
legacy unfused compressor ops — kept for the fused-vs-unfused engine
benchmark and as an escape hatch).
"""
from __future__ import annotations

import os
from enum import Enum

import jax

__all__ = ["KernelType", "KERNEL_MODES", "kernel_mode", "compress_fused",
           "dispatch_key", "on_tpu"]


class KernelType(Enum):
    """Which implementation of a kernel runs (see module docstring)."""
    PALLAS = "pallas"
    XLA = "xla"
    INTERPRET = "interpret"


# the REPRO_KERNEL_MODE spellings, mamba-jax's KernelTypeMapping pattern
KERNEL_MODES = {t.value: t for t in KernelType}


def on_tpu() -> bool:
    """True when the default JAX backend is a TPU."""
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def _parse(spelling: str, source: str) -> KernelType:
    try:
        return KERNEL_MODES[spelling.strip().lower()]
    except KeyError:
        raise ValueError(
            f"unknown kernel mode {spelling!r} (from {source}); expected "
            f"one of {sorted(KERNEL_MODES)}") from None


def kernel_mode(mode=None) -> KernelType:
    """Resolve the kernel dispatch mode (precedence in module docstring).

    ``mode`` may be a ``KernelType``, one of its string spellings, or
    None (read the environment / backend default).
    """
    if mode is not None:
        if isinstance(mode, KernelType):
            return mode
        return _parse(str(mode), "mode argument")
    env = os.environ.get("REPRO_KERNEL_MODE")
    if env:
        return _parse(env, "REPRO_KERNEL_MODE")
    if os.environ.get("FORCE_PALLAS_INTERPRET") == "1":
        return KernelType.INTERPRET
    return KernelType.PALLAS if on_tpu() else KernelType.XLA


def compress_fused() -> bool:
    """False when ``REPRO_COMPRESS_FUSED=0`` asks for the legacy unfused
    compressor ops (the fused `repro.kernels.compress` stack is the
    default); `benchmarks/bench_engine.py` measures the difference."""
    return os.environ.get("REPRO_COMPRESS_FUSED", "1") != "0"


def dispatch_key(mode=None) -> tuple:
    """Hashable (KernelType, fused?) pair capturing every env knob that
    changes a traced program's kernel choices. Compiled-program caches
    (engine/sweep programs, ``permfl_round``'s jit) take it as a static
    argument so flipping ``REPRO_KERNEL_MODE`` / ``REPRO_COMPRESS_FUSED``
    between runs re-traces instead of reusing a stale kernel choice."""
    return (kernel_mode(mode), compress_fused())
