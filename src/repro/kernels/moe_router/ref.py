"""Pure-jnp oracle for fused MoE top-k routing.

Given router logits (tokens, experts): softmax -> top-k -> renormalized
gates, plus the load-balance auxiliary statistics (Switch/DeepSeek-MoE
style: mean gate probability and token fraction per expert).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("top_k", "renormalize"))
def route_ref(logits, *, top_k: int, renormalize: bool = True):
    """logits: (tokens, experts) -> (gates (t,k), idx (t,k) int32,
    probs (t,E), aux dict)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)
    if renormalize:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-20)
    e = logits.shape[-1]
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32).sum(1)  # (t, E)
    aux = {
        "mean_prob": probs.mean(0),                 # (E,)
        "frac_tokens": onehot.mean(0) / top_k,      # (E,)
    }
    return gates.astype(logits.dtype), idx.astype(jnp.int32), probs, aux


def load_balance_loss(aux, num_experts: int):
    """Switch-transformer aux loss: E * sum(frac_tokens * mean_prob)."""
    return num_experts * jnp.sum(aux["frac_tokens"] * aux["mean_prob"])
