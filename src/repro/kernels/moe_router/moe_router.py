"""Pallas TPU kernel: fused MoE routing (softmax + top-k + renormalize).

One pass over the (block_tokens, experts) logits tile in VMEM produces the
top-k gate values and expert ids plus the per-expert load statistics that
feed the load-balance loss — XLA would otherwise materialize the full
softmax, run k sort passes, and re-read probs for the statistics.

top-k is computed by k iterations of (max, mask) — experts <= 64 here, so
each iteration is one VPU reduction over the lane dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _router_kernel(logits_ref, gates_ref, idx_ref, stats_ref, *,
                   top_k: int, renormalize: bool, num_tokens: int,
                   block_tokens: int):
    blk = pl.program_id(0)
    x = logits_ref[...].astype(jnp.float32)           # (bt, E)
    bt, e = x.shape
    row = blk * block_tokens + jax.lax.broadcasted_iota(jnp.int32, (bt, 1), 0)
    valid = row < num_tokens                           # (bt, 1)

    m = x.max(axis=-1, keepdims=True)
    p = jnp.exp(x - m)
    p = p / p.sum(axis=-1, keepdims=True)              # softmax (bt, E)

    work = p
    gsum = jnp.zeros((bt, 1), jnp.float32)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bt, e), 1)
    sel_mask = jnp.zeros((bt, e), jnp.float32)         # k-hot selection
    for j in range(top_k):
        g = work.max(axis=-1, keepdims=True)           # (bt, 1)
        amax = jnp.argmax(work, axis=-1)               # (bt,)
        hot = cols == amax[:, None]
        work = jnp.where(hot, NEG_INF, work)
        sel_mask = sel_mask + hot.astype(jnp.float32)
        gates_ref[:, j] = g[:, 0].astype(gates_ref.dtype)
        idx_ref[:, j] = amax.astype(jnp.int32)
        gsum = gsum + g
    if renormalize:
        gates_ref[...] = (gates_ref[...].astype(jnp.float32) /
                          jnp.maximum(gsum, 1e-20)).astype(gates_ref.dtype)
    # per-expert stats for this block: sum of probs, count of selections
    pv = jnp.where(valid, p, 0.0)
    sv = jnp.where(valid, sel_mask, 0.0)
    stats_ref[0, 0, :] = pv.sum(axis=0)
    stats_ref[0, 1, :] = sv.sum(axis=0)


@functools.partial(jax.jit, static_argnames=(
    "top_k", "renormalize", "block_tokens", "interpret"))
def route(logits, *, top_k: int, renormalize: bool = True,
          block_tokens: int = 1024, interpret: bool = False):
    """logits: (tokens, experts). Returns (gates, idx, aux) like ref
    (without the full probs tensor — the kernel's point is not to emit it).
    """
    t, e = logits.shape
    block_tokens = min(block_tokens, t)
    n_blocks = pl.cdiv(t, block_tokens)
    kernel = functools.partial(
        _router_kernel, top_k=top_k, renormalize=renormalize,
        num_tokens=t, block_tokens=block_tokens)
    gates, idx, stats = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((block_tokens, e), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_tokens, top_k), lambda i: (i, 0)),
            pl.BlockSpec((block_tokens, top_k), lambda i: (i, 0)),
            pl.BlockSpec((1, 2, e), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks * block_tokens, top_k), logits.dtype),
            jax.ShapeDtypeStruct((n_blocks * block_tokens, top_k), jnp.int32),
            jax.ShapeDtypeStruct((n_blocks, 2, e), jnp.float32),
        ],
        interpret=interpret,
    )(logits)
    gates, idx = gates[:t], idx[:t]
    aux = {
        "mean_prob": stats[:, 0, :].sum(0) / t,
        "frac_tokens": stats[:, 1, :].sum(0) / (t * top_k),
    }
    return gates, idx, aux
