from repro.kernels.moe_router.ops import route_topk
from repro.kernels.moe_router.ref import load_balance_loss, route_ref
from repro.kernels.moe_router.moe_router import route

__all__ = ["route_topk", "route_ref", "route", "load_balance_loss"]
