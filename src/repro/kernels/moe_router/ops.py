"""Public MoE routing op with backend dispatch."""
from __future__ import annotations

import os

import jax

from repro.kernels.moe_router.moe_router import route
from repro.kernels.moe_router.ref import load_balance_loss, route_ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def route_topk(logits, *, top_k: int, renormalize: bool = True):
    """Returns (gates (t,k), idx (t,k), aux dict)."""
    if _on_tpu():
        return route(logits, top_k=top_k, renormalize=renormalize)
    if os.environ.get("FORCE_PALLAS_INTERPRET") == "1":
        return route(logits, top_k=top_k, renormalize=renormalize,
                     interpret=True)
    gates, idx, _, aux = route_ref(logits, top_k=top_k,
                                   renormalize=renormalize)
    return gates, idx, aux
