"""Public MoE routing op dispatched through the unified ``kernel_mode()``."""
from __future__ import annotations

from repro.kernels.interface import KernelType, kernel_mode
from repro.kernels.moe_router.moe_router import route
from repro.kernels.moe_router.ref import load_balance_loss, route_ref


def route_topk(logits, *, top_k: int, renormalize: bool = True, mode=None):
    """Returns (gates (t,k), idx (t,k), aux dict)."""
    kt = kernel_mode(mode)
    if kt is KernelType.XLA:
        gates, idx, _, aux = route_ref(logits, top_k=top_k,
                                       renormalize=renormalize)
        return gates, idx, aux
    return route(logits, top_k=top_k, renormalize=renormalize,
                 interpret=kt is not KernelType.PALLAS)
