"""Pure-jnp oracle for the fused PerMFL device prox step (paper eq. 4).

    theta_new = theta - alpha * grad - alpha * lam * (theta - anchor)

optionally with momentum (heavy ball) and decoupled weight decay, applied to
flat f32/bf16 blocks. The Moreau-envelope anchor term is what distinguishes
this from a vanilla SGD step — it is executed L*K*T times per device, the
hottest loop in PerMFL.
"""
from __future__ import annotations

import jax.numpy as jnp


def prox_sgd_ref(theta, grad, anchor, *, alpha, lam, momentum=0.0,
                 mom_buf=None, weight_decay=0.0):
    """All tensors same shape. Returns (theta_new, mom_buf_new)."""
    tf = theta.astype(jnp.float32)
    gf = grad.astype(jnp.float32)
    af = anchor.astype(jnp.float32)
    update = gf + lam * (tf - af) + weight_decay * tf
    if momentum > 0.0:
        mb = jnp.zeros_like(tf) if mom_buf is None else mom_buf.astype(jnp.float32)
        mb = momentum * mb + update
        update = mb
    else:
        mb = jnp.zeros_like(tf) if mom_buf is None else mom_buf
    new = tf - alpha * update
    return new.astype(theta.dtype), mb
