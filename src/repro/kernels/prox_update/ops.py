"""Public fused prox-SGD op over pytrees, with backend dispatch.

``prox_sgd_tree`` applies the PerMFL device update (eq. 4) leaf-wise to a
parameter pytree, dispatching through the unified
:func:`repro.kernels.interface.kernel_mode` (Pallas kernel on TPU, jnp
reference elsewhere, ``REPRO_KERNEL_MODE`` to override); momentum buffers
are threaded as a matching pytree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.interface import KernelType, kernel_mode
from repro.kernels.prox_update.prox_update import prox_sgd_flat
from repro.kernels.prox_update.ref import prox_sgd_ref


def prox_sgd(theta, grad, anchor, mom_buf=None, *, alpha, lam,
             momentum=0.0, weight_decay=0.0, mode=None):
    """Single-array fused prox step; any shape."""
    if mom_buf is None:
        mom_buf = jnp.zeros(theta.shape, jnp.float32)
    kt = kernel_mode(mode)
    if kt is not KernelType.XLA:
        shape = theta.shape
        t, m = prox_sgd_flat(theta.reshape(-1), grad.reshape(-1),
                             anchor.reshape(-1), mom_buf.reshape(-1),
                             alpha=alpha, lam=lam, momentum=momentum,
                             weight_decay=weight_decay,
                             interpret=kt is not KernelType.PALLAS)
        return t.reshape(shape), m.reshape(shape)
    return prox_sgd_ref(theta, grad, anchor, mom_buf=mom_buf, alpha=alpha,
                        lam=lam, momentum=momentum, weight_decay=weight_decay)


def prox_sgd_tree(theta, grad, anchor, mom_tree=None, *, alpha, lam,
                  momentum=0.0, weight_decay=0.0, mode=None):
    """Pytree-wise PerMFL device step. Returns (theta_new, mom_tree_new)."""
    if mom_tree is None:
        mom_tree = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), theta)
    flat_t, treedef = jax.tree.flatten(theta)
    flat_g = treedef.flatten_up_to(grad)
    flat_a = treedef.flatten_up_to(anchor)
    flat_m = treedef.flatten_up_to(mom_tree)
    new_t, new_m = [], []
    for t, g, a, m in zip(flat_t, flat_g, flat_a, flat_m):
        tn, mn = prox_sgd(t, g, a, m, alpha=alpha, lam=lam,
                          momentum=momentum, weight_decay=weight_decay,
                          mode=mode)
        new_t.append(tn)
        new_m.append(mn)
    return treedef.unflatten(new_t), treedef.unflatten(new_m)
