"""Pallas TPU kernel: fused PerMFL prox-SGD device step (paper eq. 4).

Unfused, XLA issues (read theta, read grad, read anchor, write theta) plus a
temporary for (theta - anchor): ~5 HBM round trips of the parameter block.
Fused, each of theta/grad/anchor/momentum streams through VMEM exactly once:
1 write + 3..4 reads, the bandwidth floor. Blocks are (block_rows, 128) —
lane-aligned for the VPU; arrays are flattened and padded to a multiple of
128 by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128


def _prox_kernel(s_ref, t_ref, g_ref, a_ref, m_ref, t_out, m_out, *,
                 momentum, weight_decay):
    # alpha/lam ride in SMEM as a (1, 2) scalar operand: they are sweepable
    # hyperparameters (run_sweep vmaps grids of them), so they must be
    # runtime values, not compile-time constants. momentum/weight_decay
    # select the kernel branch and stay static.
    alpha = s_ref[0, 0]
    lam = s_ref[0, 1]
    t = t_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)
    upd = g + lam * (t - a) + weight_decay * t
    if momentum > 0.0:
        mb = m_ref[...].astype(jnp.float32)
        mb = momentum * mb + upd
        m_out[...] = mb.astype(m_out.dtype)
        upd = mb
    else:
        m_out[...] = m_ref[...]
    t_out[...] = (t - alpha * upd).astype(t_out.dtype)


@functools.partial(jax.jit, static_argnames=(
    "momentum", "weight_decay", "block_rows", "interpret"))
def prox_sgd_flat(theta, grad, anchor, mom_buf, *, alpha, lam,
                  momentum=0.0, weight_decay=0.0, block_rows: int = 256,
                  interpret: bool = False):
    """1-D inputs (already flat). alpha/lam may be traced scalars (they
    enter the kernel via SMEM). Returns (theta_new, mom_new)."""
    (size,) = theta.shape
    rows = pl.cdiv(size, LANES)
    pad = rows * LANES - size
    def prep(x):
        if pad:
            x = jnp.pad(x, (0, pad))
        return x.reshape(rows, LANES)
    t2, g2, a2, m2 = prep(theta), prep(grad), prep(anchor), prep(mom_buf)
    scal = jnp.stack([jnp.asarray(alpha, jnp.float32),
                      jnp.asarray(lam, jnp.float32)]).reshape(1, 2)
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)
    spec = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    sspec = pl.BlockSpec((1, 2), lambda i: (0, 0),
                         memory_space=pltpu.SMEM)
    kernel = functools.partial(_prox_kernel, momentum=momentum,
                               weight_decay=weight_decay)
    t_new, m_new = pl.pallas_call(
        kernel, grid=grid,
        in_specs=[sspec, spec, spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct(t2.shape, theta.dtype),
                   jax.ShapeDtypeStruct(m2.shape, jnp.float32)],
        interpret=interpret,
    )(scal, t2, g2, a2, m2)
    return t_new.reshape(-1)[:size], m_new.reshape(-1)[:size]
