from repro.kernels.prox_update.ops import prox_sgd, prox_sgd_tree
from repro.kernels.prox_update.ref import prox_sgd_ref
from repro.kernels.prox_update.prox_update import prox_sgd_flat

__all__ = ["prox_sgd", "prox_sgd_tree", "prox_sgd_ref", "prox_sgd_flat"]
