"""Pallas TPU kernels: fused select/pack/EF for the comm uplink.

Three single-pass kernels cover the whole compressor zoo (semantics and
wire formats defined by ``ref.py`` — these must match it bit-for-bit in
interpret mode):

* **select** (top-k / rand-k): given the k-th-largest score as a (1,1)
  scalar operand, compute the keep set (strictly-above entries plus
  ``== threshold`` ties filled in flat-index order — ``lax.top_k``'s
  exact kept set, see ``ref._select``), each kept coordinate's global
  rank (its slot in the ``(k,)`` wire buffer), the dense decompressed
  value, and — in the EF variant — the error-feedback residual, in one
  VMEM-resident pass. The strict/tie prefix counts are cumulative
  sums done as MXU matmuls against triangular 0/1 matrices (lane-axis
  prefix via a (128,128) upper-triangle, row-axis prefix via a
  (rows,rows) strict lower-triangle) — no scatter, no sort, no
  unsupported scan.
* **ef-quantize-int8**: ``msg = delta + ef`` -> row absmax scale ->
  stochastic round -> packed int8 + scales + dq + ef_new. Subsumes the
  ``kernels/quantize`` forward (that kernel remains for the bare op).
* **sign**: sign bits packed 8-per-byte via one MXU matmul against a
  (128,16) group-indicator matrix, plus ``dq = scale * sign`` and the
  EF residual. The global ``mean(|msg|)`` scale is computed by the XLA
  wrapper and passed in, keeping it bit-identical to the unfused path.

All kernels are gridless single blocks: the whole (rows, 128) array is
one VMEM block, so they vmap safely over the stacked (M, N) sender axes
(no program_id / scratch state for the batching rule to break). That
bounds leaf size to VMEM — ``PALLAS_MAX_ELEMS`` floats per leaf per
sender, far above this repo's model zoo — bigger leaves are routed to
the XLA reference by ``ops.resolve_leaf_mode`` (DESIGN.md §10).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

LANES = 128

# VMEM ceiling for the gridless kernels: the largest flat leaf size (in
# elements) a single-block pallas_call can hold — a handful of f32
# (rows, 128) operands/outputs must fit in ~16 MiB of VMEM at once.
# ``ops.resolve_leaf_mode`` falls back to the XLA reference (same bits)
# for bigger leaves instead of failing at Mosaic compile time.
PALLAS_MAX_ELEMS = 256 * 1024


def _pad_rows(x, size):
    rows = pl.cdiv(size, LANES)
    pad = rows * LANES - size
    if pad:
        x = jnp.pad(x, (0, pad))
    return x.reshape(rows, LANES), rows


def _select_core(score, v, thresh, k, scale, size):
    """Shared select math, mirroring ``ref._select``: keep strictly-above
    entries unconditionally, fill the remaining k - n_strict slots with
    ``== thresh`` ties in flat-index order (``lax.top_k``'s exact kept
    set), global ranks via matmul prefix counts."""
    rows = score.shape[0]
    ridx = lax.broadcasted_iota(jnp.int32, (rows, LANES), 0)
    lidx = lax.broadcasted_iota(jnp.int32, (rows, LANES), 1)
    real = (ridx * LANES + lidx) < size
    strict = (score > thresh) & real
    tie = (score == thresh) & real
    li = lax.broadcasted_iota(jnp.int32, (LANES, LANES), 0)
    lj = lax.broadcasted_iota(jnp.int32, (LANES, LANES), 1)
    lane_tri = (li <= lj).astype(jnp.float32)
    ri = lax.broadcasted_iota(jnp.int32, (rows, rows), 0)
    rj = lax.broadcasted_iota(jnp.int32, (rows, rows), 1)
    row_tri = (rj < ri).astype(jnp.float32)

    def inc_count(mask):
        # Inclusive flat-order prefix count of ``mask``. HIGHEST
        # precision: the MXU's default f32 matmul is inexact above ~2^8
        # and these products must be exact integer counts.
        incl = jnp.dot(mask.astype(jnp.float32), lane_tri,
                       precision=lax.Precision.HIGHEST)
        prefix = jnp.dot(row_tri, incl[:, LANES - 1:LANES],
                         precision=lax.Precision.HIGHEST)
        return prefix + incl

    inc_s = inc_count(strict)
    inc_t = inc_count(tie)
    # slots left for ties; counts are exact integers in f32 (< 2^24)
    cap = jnp.float32(k) - inc_s[rows - 1:rows, LANES - 1:LANES]
    sel = strict | (tie & (inc_t <= cap))
    rank = (inc_s + jnp.minimum(inc_t, cap)).astype(jnp.int32) - 1
    dq = jnp.where(sel, v * scale, jnp.zeros((), v.dtype))
    ranks = jnp.where(sel, rank, -1)
    return dq, ranks


def _topk_kernel(t_ref, v_ref, dq_ref, rk_ref, *, k, size):
    v = v_ref[...]
    dq, rk = _select_core(jnp.abs(v.astype(jnp.float32)), v,
                          t_ref[0, 0], k, 1.0, size)
    dq_ref[...] = dq
    rk_ref[...] = rk


def _ef_topk_kernel(t_ref, d_ref, e_ref, dq_ref, rk_ref, ef_ref, *, k, size):
    msg = d_ref[...] + e_ref[...]
    dq, rk = _select_core(jnp.abs(msg.astype(jnp.float32)), msg,
                          t_ref[0, 0], k, 1.0, size)
    dq_ref[...] = dq
    rk_ref[...] = rk
    ef_ref[...] = msg - dq


def _randk_kernel(t_ref, u_ref, v_ref, dq_ref, rk_ref, *, k, scale, size):
    dq, rk = _select_core(u_ref[...].astype(jnp.float32), v_ref[...],
                          t_ref[0, 0], k, scale, size)
    dq_ref[...] = dq
    rk_ref[...] = rk


def _ef_randk_kernel(t_ref, u_ref, d_ref, e_ref, dq_ref, rk_ref, ef_ref,
                     *, k, size):
    msg = d_ref[...] + e_ref[...]
    dq, rk = _select_core(u_ref[...].astype(jnp.float32), msg,
                          t_ref[0, 0], k, 1.0, size)
    dq_ref[...] = dq
    rk_ref[...] = rk
    ef_ref[...] = msg - dq


def _ef_quant_kernel(d_ref, e_ref, n_ref, q_ref, s_ref, dq_ref, ef_ref):
    msg = d_ref[...] + e_ref[...]
    m = msg.astype(jnp.float32)
    u = n_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(m), axis=1, keepdims=True)
    scale = jnp.maximum(absmax * (1.0 / 127.0), 1e-12)
    q = jnp.clip(jnp.floor(m / scale + u), -127.0, 127.0)
    dq = (q * scale).astype(msg.dtype)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale
    dq_ref[...] = dq
    ef_ref[...] = msg - dq


def _pack_bits(v):
    """(rows,128) values -> (rows,16) uint8 sign bits via one MXU matmul:
    lane 8c+j contributes 2^j to byte c, matching ref._pack_bits."""
    rows = v.shape[0]
    lidx = lax.broadcasted_iota(jnp.int32, (rows, LANES), 1)
    w = jnp.exp2((lidx % 8).astype(jnp.float32))
    gl = lax.broadcasted_iota(jnp.int32, (LANES, LANES // 8), 0)
    gc = lax.broadcasted_iota(jnp.int32, (LANES, LANES // 8), 1)
    group = ((gl // 8) == gc).astype(jnp.float32)
    nonneg = (v >= 0).astype(jnp.float32)
    return jnp.dot(nonneg * w, group,
                   precision=lax.Precision.HIGHEST).astype(jnp.uint8)


def _sign_kernel(s_ref, v_ref, b_ref, dq_ref):
    v = v_ref[...]
    b_ref[...] = _pack_bits(v)
    dq_ref[...] = (s_ref[0, 0] * jnp.sign(v.astype(jnp.float32))
                   ).astype(v.dtype)


def _ef_sign_kernel(s_ref, d_ref, e_ref, b_ref, dq_ref, ef_ref):
    msg = d_ref[...] + e_ref[...]
    b_ref[...] = _pack_bits(msg)
    dq = (s_ref[0, 0] * jnp.sign(msg.astype(jnp.float32))).astype(msg.dtype)
    dq_ref[...] = dq
    ef_ref[...] = msg - dq


def _call(kernel, outs, *ins, interpret):
    """Gridless pallas_call: every operand/output is one whole block."""
    return pl.pallas_call(
        kernel,
        out_shape=[jax.ShapeDtypeStruct(s, d) for s, d in outs],
        interpret=interpret,
    )(*ins)


def _scalar(x):
    return jnp.asarray(x, jnp.float32).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def topk_select_flat(v, thresh, *, k: int, interpret: bool = False):
    """Flat (p,) fused top-k select+rank. thresh is the k-th largest
    |v| (see ref.kth_threshold). Returns (dq (p,), ranks (p,) i32)."""
    (size,) = v.shape
    v2, rows = _pad_rows(v, size)
    dq, rk = _call(functools.partial(_topk_kernel, k=k, size=size),
                   [((rows, LANES), v.dtype), ((rows, LANES), jnp.int32)],
                   _scalar(thresh), v2, interpret=interpret)
    return dq.reshape(-1)[:size], rk.reshape(-1)[:size]


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def ef_topk_select_flat(delta, ef, thresh, *, k: int,
                        interpret: bool = False):
    """Fused EF + top-k on flat (p,) arrays. thresh is the k-th largest
    |delta + ef|. Returns (dq, ranks, ef_new)."""
    (size,) = delta.shape
    d2, rows = _pad_rows(delta, size)
    e2, _ = _pad_rows(ef, size)
    dq, rk, en = _call(
        functools.partial(_ef_topk_kernel, k=k, size=size),
        [((rows, LANES), delta.dtype), ((rows, LANES), jnp.int32),
         ((rows, LANES), delta.dtype)],
        _scalar(thresh), d2, e2, interpret=interpret)
    return (dq.reshape(-1)[:size], rk.reshape(-1)[:size],
            en.reshape(-1)[:size])


@functools.partial(jax.jit, static_argnames=("k", "scale", "interpret"))
def randk_select_flat(u, v, thresh, *, k: int, scale: float,
                      interpret: bool = False):
    """Flat fused rand-k select+rank; thresh is the k-th largest uniform
    score u. Returns (dq, ranks)."""
    (size,) = v.shape
    u2, rows = _pad_rows(u, size)
    v2, _ = _pad_rows(v, size)
    dq, rk = _call(
        functools.partial(_randk_kernel, k=k, scale=scale, size=size),
        [((rows, LANES), v.dtype), ((rows, LANES), jnp.int32)],
        _scalar(thresh), u2, v2, interpret=interpret)
    return dq.reshape(-1)[:size], rk.reshape(-1)[:size]


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def ef_randk_select_flat(u, delta, ef, thresh, *, k: int,
                         interpret: bool = False):
    """Fused EF + rand-k (contractive). Returns (dq, ranks, ef_new)."""
    (size,) = delta.shape
    u2, rows = _pad_rows(u, size)
    d2, _ = _pad_rows(delta, size)
    e2, _ = _pad_rows(ef, size)
    dq, rk, en = _call(
        functools.partial(_ef_randk_kernel, k=k, size=size),
        [((rows, LANES), delta.dtype), ((rows, LANES), jnp.int32),
         ((rows, LANES), delta.dtype)],
        _scalar(thresh), u2, d2, e2, interpret=interpret)
    return (dq.reshape(-1)[:size], rk.reshape(-1)[:size],
            en.reshape(-1)[:size])


@functools.partial(jax.jit, static_argnames=("interpret",))
def ef_quantize_int8_flat(delta, ef, noise, *, interpret: bool = False):
    """Fused EF + stochastic int8 quantize/pack on flat (p,) arrays.
    Returns (q (p,) i8, scales (rows,) f32, dq (p,), ef_new (p,))."""
    (size,) = delta.shape
    d2, rows = _pad_rows(delta, size)
    e2, _ = _pad_rows(ef, size)
    n2, _ = _pad_rows(noise, size)
    q, s, dq, en = _call(
        _ef_quant_kernel,
        [((rows, LANES), jnp.int8), ((rows, 1), jnp.float32),
         ((rows, LANES), delta.dtype), ((rows, LANES), delta.dtype)],
        d2, e2, n2, interpret=interpret)
    return (q.reshape(-1)[:size], s.reshape(-1), dq.reshape(-1)[:size],
            en.reshape(-1)[:size])


@functools.partial(jax.jit, static_argnames=("interpret",))
def sign_compress_flat(v, scale, *, interpret: bool = False):
    """Flat fused sign+pack; ``scale`` (the global mean |v|) is computed
    by the caller. Returns (bits (rows,16) u8, dq (p,))."""
    (size,) = v.shape
    v2, rows = _pad_rows(v, size)
    bits, dq = _call(
        _sign_kernel,
        [((rows, LANES // 8), jnp.uint8), ((rows, LANES), v.dtype)],
        _scalar(scale), v2, interpret=interpret)
    return bits, dq.reshape(-1)[:size]


@functools.partial(jax.jit, static_argnames=("interpret",))
def ef_sign_compress_flat(delta, ef, scale, *, interpret: bool = False):
    """Fused EF + sign+pack. Returns (bits, dq, ef_new)."""
    (size,) = delta.shape
    d2, rows = _pad_rows(delta, size)
    e2, _ = _pad_rows(ef, size)
    bits, dq, en = _call(
        _ef_sign_kernel,
        [((rows, LANES // 8), jnp.uint8), ((rows, LANES), delta.dtype),
         ((rows, LANES), delta.dtype)],
        _scalar(scale), d2, e2, interpret=interpret)
    return bits, dq.reshape(-1)[:size], en.reshape(-1)[:size]
