"""Public fused-compression ops: KernelType dispatch + custom VJPs.

Each op resolves a :class:`repro.kernels.interface.KernelType` (explicit
``mode=`` argument, else the ``REPRO_KERNEL_MODE`` environment) and runs
either the Pallas kernel (``compress.py``, compiled or interpret) or the
jnp reference (``ref.py``) — the two are bit-identical by construction,
so ``comm/compressors.py`` can route every compressor through here with
zero caller-visible change.

Every op carries a custom VJP so compressed rounds stay differentiable
with *identical* gradient semantics across backends:

* top-k / rand-k: the exact almost-everywhere gradient — the selection
  mask is constant under perturbation, so ``dq`` passes cotangents
  through kept coordinates and ``ef_new`` through dropped ones (this is
  what autodiff of the reference computes; the custom rule just avoids
  re-running select on the backward pass).
* int8 / sign: the straight-through estimator — quantization is treated
  as identity on the message (``d dq/d msg = I``, ``d ef/d msg = 0``),
  the standard surrogate for non-differentiable rounding.

Integer/bit outputs (ranks, q, bits) are non-differentiable and receive
zero/float0 cotangents, which the backward rules ignore.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.compress import compress as _pal
from repro.kernels.compress import ref as _ref
from repro.kernels.interface import KernelType, kernel_mode

__all__ = [
    "topk_compress", "ef_topk_compress", "randk_compress",
    "ef_randk_compress", "ef_quantize_int8", "sign_compress",
    "ef_sign_compress", "pack_topk", "unpack_topk", "sign_unpack",
    "resolve_leaf_mode",
]


def resolve_leaf_mode(kt: KernelType, p) -> KernelType:
    """Clamp compiled-Pallas dispatch to leaves that fit the gridless
    kernels' VMEM budget (``compress.PALLAS_MAX_ELEMS`` elements).

    Bigger leaves run the bit-identical XLA reference instead of dying
    at Mosaic compile/run time; interpret mode has no VMEM and is left
    alone. Every public op below routes through this, so callers never
    see the size limit."""
    if kt is KernelType.PALLAS and int(p) > _pal.PALLAS_MAX_ELEMS:
        return KernelType.XLA
    return kt


def _zeros_like(x):
    return jnp.zeros(x.shape, x.dtype)


# The XLA branch runs the reference under jit so both branches sit
# behind the same compilation boundary: eagerly, XLA fuses the
# ``ef_new = msg - dq`` arithmetic differently (low-bit drift), and
# bit-parity with the Pallas kernels is part of this package's contract.
_topk_ref = jax.jit(_ref.topk_select_ref, static_argnums=(1,))
_ef_topk_ref = jax.jit(_ref.ef_topk_select_ref, static_argnums=(2,))
_randk_ref = jax.jit(_ref.randk_select_ref, static_argnums=(2, 3))
_ef_randk_ref = jax.jit(_ref.ef_randk_select_ref, static_argnums=(3,))
_ef_int8_ref = jax.jit(_ref.ef_quantize_int8_ref)
_sign_ref = jax.jit(_ref.sign_compress_ref)
_ef_sign_ref = jax.jit(_ref.ef_sign_compress_ref)


# ---------------------------------------------------------------- top-k

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _topk(k, kt, v):
    if kt is KernelType.XLA:
        return _topk_ref(v, k)
    thresh = _ref.kth_threshold(jnp.abs(v), k)
    return _pal.topk_select_flat(v, thresh, k=k,
                                 interpret=kt is not KernelType.PALLAS)


def _topk_fwd(k, kt, v):
    dq, ranks = _topk(k, kt, v)
    return (dq, ranks), ranks


def _topk_bwd(k, kt, ranks, g):
    g_dq, _ = g
    return (jnp.where(ranks >= 0, g_dq, _zeros_like(g_dq)),)


_topk.defvjp(_topk_fwd, _topk_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _ef_topk(k, kt, delta, ef):
    if kt is KernelType.XLA:
        return _ef_topk_ref(delta, ef, k)
    thresh = _ref.kth_threshold(jnp.abs(delta + ef), k)
    return _pal.ef_topk_select_flat(delta, ef, thresh, k=k,
                                    interpret=kt is not KernelType.PALLAS)


def _ef_topk_fwd(k, kt, delta, ef):
    out = _ef_topk(k, kt, delta, ef)
    return out, out[1]


def _ef_topk_bwd(k, kt, ranks, g):
    g_dq, _, g_ef = g
    g_msg = jnp.where(ranks >= 0, g_dq, g_ef)
    return g_msg, g_msg


_ef_topk.defvjp(_ef_topk_fwd, _ef_topk_bwd)


# --------------------------------------------------------------- rand-k

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _randk(k, scale, kt, u, v):
    if kt is KernelType.XLA:
        return _randk_ref(u, v, k, scale)
    thresh = _ref.kth_threshold(u, k)
    return _pal.randk_select_flat(u, v, thresh, k=k, scale=scale,
                                  interpret=kt is not KernelType.PALLAS)


def _randk_fwd(k, scale, kt, u, v):
    dq, ranks = _randk(k, scale, kt, u, v)
    return (dq, ranks), (ranks, u)


def _randk_bwd(k, scale, kt, res, g):
    ranks, u = res
    g_dq, _ = g
    g_v = jnp.where(ranks >= 0, g_dq * scale, _zeros_like(g_dq))
    return _zeros_like(u), g_v


_randk.defvjp(_randk_fwd, _randk_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _ef_randk(k, kt, u, delta, ef):
    if kt is KernelType.XLA:
        return _ef_randk_ref(u, delta, ef, k)
    thresh = _ref.kth_threshold(u, k)
    return _pal.ef_randk_select_flat(u, delta, ef, thresh, k=k,
                                     interpret=kt is not KernelType.PALLAS)


def _ef_randk_fwd(k, kt, u, delta, ef):
    out = _ef_randk(k, kt, u, delta, ef)
    return out, (out[1], u)


def _ef_randk_bwd(k, kt, res, g):
    ranks, u = res
    g_dq, _, g_ef = g
    g_msg = jnp.where(ranks >= 0, g_dq, g_ef)
    return _zeros_like(u), g_msg, g_msg


_ef_randk.defvjp(_ef_randk_fwd, _ef_randk_bwd)


# ----------------------------------------------------------------- int8

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _ef_int8(kt, delta, ef, noise):
    if kt is KernelType.XLA:
        return _ef_int8_ref(delta, ef, noise)
    return _pal.ef_quantize_int8_flat(delta, ef, noise,
                                      interpret=kt is not KernelType.PALLAS)


def _ef_int8_fwd(kt, delta, ef, noise):
    return _ef_int8(kt, delta, ef, noise), noise


def _ef_int8_bwd(kt, noise, g):
    _, _, g_dq, _ = g           # STE: dq ~= msg, ef_new ~= 0
    return g_dq, g_dq, _zeros_like(noise)


_ef_int8.defvjp(_ef_int8_fwd, _ef_int8_bwd)


# ----------------------------------------------------------------- sign

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _sign(kt, v):
    scale = jnp.mean(jnp.abs(v))
    if kt is KernelType.XLA:
        return _sign_ref(v, scale)
    bits, dq = _pal.sign_compress_flat(v, scale,
                                       interpret=kt is not KernelType.PALLAS)
    return bits, scale, dq


def _sign_fwd(kt, v):
    return _sign(kt, v), None


def _sign_bwd(kt, _, g):
    _, _, g_dq = g              # STE: dq ~= v
    return (g_dq,)


_sign.defvjp(_sign_fwd, _sign_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _ef_sign(kt, delta, ef):
    scale = jnp.mean(jnp.abs(delta + ef))
    if kt is KernelType.XLA:
        return _ef_sign_ref(delta, ef, scale)
    bits, dq, ef_new = _pal.ef_sign_compress_flat(
        delta, ef, scale, interpret=kt is not KernelType.PALLAS)
    return bits, scale, dq, ef_new


def _ef_sign_fwd(kt, delta, ef):
    return _ef_sign(kt, delta, ef), None


def _ef_sign_bwd(kt, _, g):
    _, _, g_dq, _ = g           # STE: dq ~= msg, ef_new ~= 0
    return g_dq, g_dq


_ef_sign.defvjp(_ef_sign_fwd, _ef_sign_bwd)


# ----------------------------------------------------------- public API

def topk_compress(v, k, *, mode=None):
    """Fused magnitude top-k on flat ``v`` (p,): keep the k largest-|·|
    coordinates (ties to the lowest index, exactly like ``lax.top_k``).
    Returns (dq (p,), ranks (p,) i32 — wire slot in [0, k) or -1)."""
    return _topk(int(k), resolve_leaf_mode(kernel_mode(mode), v.shape[0]),
                 v)


def ef_topk_compress(delta, ef, k, *, mode=None):
    """Fused error-feedback + top-k: ``msg = delta + ef`` never hits HBM
    on the Pallas path. Returns (dq, ranks, ef_new = msg - dq)."""
    return _ef_topk(int(k),
                    resolve_leaf_mode(kernel_mode(mode), delta.shape[0]),
                    delta, ef)


def randk_compress(u, v, k, *, unbiased=False, mode=None):
    """Fused rand-k on flat ``v``: keep the k coordinates with the
    largest uniform scores ``u`` (k indices without replacement, same
    stream as the historical compressor — tied/colliding uniforms break
    to the lowest index like ``lax.top_k``). ``unbiased=True`` rescales
    kept values by p/k (use without EF); contractive otherwise.
    Returns (dq, ranks)."""
    scale = v.shape[0] / int(k) if unbiased else 1.0
    return _randk(int(k), scale,
                  resolve_leaf_mode(kernel_mode(mode), v.shape[0]), u, v)


def ef_randk_compress(u, delta, ef, k, *, mode=None):
    """Fused error-feedback + contractive rand-k (EF absorbs the bias,
    so no p/k rescale). Returns (dq, ranks, ef_new)."""
    return _ef_randk(int(k),
                     resolve_leaf_mode(kernel_mode(mode), delta.shape[0]),
                     u, delta, ef)


def ef_quantize_int8(delta, ef, noise, *, mode=None):
    """Fused error-feedback + stochastic int8 quantize/pack (subsumes
    ``repro.kernels.quantize`` on the EF path). Returns
    (q (p,) i8, scales (rows,) f32, dq (p,), ef_new (p,))."""
    return _ef_int8(resolve_leaf_mode(kernel_mode(mode), delta.shape[0]),
                    delta, ef, noise)


def sign_compress(v, *, mode=None):
    """Fused 1-bit sign+pack with majority-friendly ``mean(|v|)`` scale.
    Returns (bits (rows,16) u8, scale () f32, dq = scale * sign(v))."""
    return _sign(resolve_leaf_mode(kernel_mode(mode), v.shape[0]), v)


def ef_sign_compress(delta, ef, *, mode=None):
    """Fused error-feedback + sign+pack. Returns
    (bits, scale, dq, ef_new = msg - dq)."""
    return _ef_sign(resolve_leaf_mode(kernel_mode(mode), delta.shape[0]),
                    delta, ef)


def pack_topk(dq, ranks, k):
    """Dense (dq, ranks) -> the ``(k,)`` value/index wire buffers the
    byte ledger prices (8k bytes on the link)."""
    return _ref.pack_selected_ref(dq, ranks, int(k))


def unpack_topk(vals, idx, p):
    """Receiver-side scatter of the ``(k,)`` wire buffers back to a
    dense (p,) array; exact inverse of ``pack_topk`` on ``dq``."""
    return _ref.unpack_selected_ref(vals, idx, int(p))


def sign_unpack(bits, scale, p):
    """Decode the packed sign bits to ``±scale`` (p,). Exact zeros in
    the original decode as ``+scale`` — see ``ref.sign_unpack_ref``."""
    return _ref.sign_unpack_ref(bits, scale, int(p))
