"""Fused Pallas compression stack for the tiered comm uplinks.

One package fuses the whole device->team->server compression pipeline —
error-feedback update, top-k / rand-k select+pack, stochastic int8
quantize/pack, 1-bit sign+pack — into single VMEM-resident Pallas
kernels with custom VJPs, dispatched through the unified
:class:`repro.kernels.interface.KernelType` interface
(``REPRO_KERNEL_MODE`` = pallas / xla / interpret). The jnp reference in
``ref.py`` is the ground truth; the kernels match it bit-for-bit (see
tests/test_compress_kernels.py). ``comm/compressors.py`` routes every
compressor through these ops, so engine rounds, vmapped sweeps, and
scenario runs all hit the fused path with no caller-visible change.
"""
from repro.kernels.compress.ops import (
    ef_quantize_int8,
    ef_randk_compress,
    ef_sign_compress,
    ef_topk_compress,
    pack_topk,
    randk_compress,
    resolve_leaf_mode,
    sign_compress,
    sign_unpack,
    topk_compress,
    unpack_topk,
)

__all__ = [
    "topk_compress", "ef_topk_compress", "randk_compress",
    "ef_randk_compress", "ef_quantize_int8", "sign_compress",
    "ef_sign_compress", "pack_topk", "unpack_topk", "sign_unpack",
    "resolve_leaf_mode",
]
