"""Pure-jnp oracles for the fused compression kernels (ground truth).

Every Pallas kernel in ``repro.kernels.compress`` has its semantics
defined HERE — the kernels must match these functions bit-for-bit in
interpret mode (pinned by tests/test_compress_kernels.py). The shared
conventions that make that possible:

* flat ``(p,)`` arrays are zero-padded to ``(rows, 128)`` row-major,
  ``rows = ceil(p / 128)``; padding never selects (masked by index).
* top-k / rand-k selection is *strict-above + tie-fill*: every
  position whose score is strictly above the k-th largest score is
  kept unconditionally (there are < k of them by definition), and the
  remaining slots are filled with ``== threshold`` ties in flat-index
  order. ``lax.top_k`` keeps exactly that set (stable sort, ties to
  the lowest index), so the kept set — and therefore the dense
  decompressed value — is identical to the historical ``top_k`` +
  scatter implementation even under tied scores (duplicate values,
  zero-heavy leaves, colliding float32 uniforms).
* reductions that feed scales (sign's mean |v|, the int8 row absmax)
  are either order-insensitive (max) or computed once on the XLA side
  and passed into the kernel, so fused and unfused paths agree exactly.
* error feedback is fused as ``msg = delta + ef``; outputs are the
  decompressed ``dq`` and the residual ``ef_new = msg - dq``.

Wire formats (what actually crosses the simulated link):

* top-k / rand-k: dense ``ranks`` (int32, slot in [0, k) or -1) pair
  with ``dq``; :func:`pack_selected_ref` turns them into the ``(k,)``
  value/index buffers the byte ledger prices (8k bytes).
* int8: ``(q int8, per-row f32 scale)`` — same as ``repro.kernels.quantize``.
* sign: one bit per coordinate — 8 lanes per byte, ``(rows, 16)`` uint8,
  byte ``c`` of a row holds lanes ``8c..8c+7`` (lane ``8c+j`` at bit
  ``j``) — plus a single f32 scale, ``mean(|v|)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

LANES = 128


def _to_rows(v, size):
    rows = -(-size // LANES)
    pad = rows * LANES - size
    if pad:
        v = jnp.pad(v, (0, pad))
    return v.reshape(rows, LANES), rows


def kth_threshold(score, k: int):
    """k-th largest entry of flat ``score`` — the select threshold.

    Shared by the XLA reference and the Pallas wrappers so both paths
    compare against the bit-identical threshold.
    """
    vals, _ = jax.lax.top_k(score, k)
    return vals[k - 1]


def _select(score, v, k: int, scale: float, size: int):
    """Strict-above + tie-fill select on flat arrays -> (dq, ranks).

    Keep everything with ``score > thresh`` unconditionally, then fill
    the remaining ``k - n_strict`` slots with ``== thresh`` ties in
    index order — the exact kept set of ``lax.top_k``. A plain
    ``score >= thresh`` mask capped at k would let low-index ties crowd
    out strictly larger entries (catastrophically: a leaf with > p-k
    zeros has ``thresh == 0`` and would keep only leading zeros)."""
    thresh = kth_threshold(score, k)
    strict = score > thresh
    tie = score == thresh
    cap = k - jnp.sum(strict.astype(jnp.int32))       # slots left for ties
    inc_s = jnp.cumsum(strict.astype(jnp.int32))      # inclusive counts,
    inc_t = jnp.cumsum(tie.astype(jnp.int32))         # flat-index order
    sel = strict | (tie & (inc_t <= cap))
    rank = inc_s + jnp.minimum(inc_t, cap) - 1        # 0-based slot of sel
    dq = jnp.where(sel, v * scale, jnp.zeros((), v.dtype))
    ranks = jnp.where(sel, rank, -1).astype(jnp.int32)
    return dq, ranks


def topk_select_ref(v, k: int):
    """Flat ``(p,)`` magnitude top-k. Returns (dq (p,), ranks (p,) i32)."""
    return _select(jnp.abs(v), v, k, 1.0, v.shape[0])


def randk_select_ref(u, v, k: int, scale: float):
    """Flat rand-k: keep the k positions with the largest uniforms ``u``
    (k indices without replacement), values scaled by static ``scale``
    (p/k for the unbiased estimator, 1.0 contractive under EF).
    Returns (dq (p,), ranks (p,) i32)."""
    return _select(u, v, k, scale, v.shape[0])


def ef_topk_select_ref(delta, ef, k: int):
    """Fused EF + top-k: ``msg = delta + ef``; select on ``|msg|``.
    Returns (dq, ranks, ef_new = msg - dq)."""
    msg = delta + ef
    dq, ranks = topk_select_ref(msg, k)
    return dq, ranks, msg - dq


def ef_randk_select_ref(u, delta, ef, k: int):
    """Fused EF + rand-k (contractive, scale 1 — EF absorbs the bias).
    Returns (dq, ranks, ef_new = msg - dq)."""
    msg = delta + ef
    dq, ranks = randk_select_ref(u, msg, k, 1.0)
    return dq, ranks, msg - dq


def ef_quantize_int8_ref(delta, ef, noise):
    """Fused EF + stochastic int8 quantize/pack on flat ``(p,)`` arrays.

    ``msg = delta + ef``; per-128-lane-row ``scale = max(|msg|)/127``;
    ``q = clip(floor(msg/scale + noise), -127, 127)`` — identical math to
    ``repro.kernels.quantize``. Returns (q (p,) i8, scales (rows,) f32,
    dq (p,), ef_new (p,))."""
    msg = delta + ef
    (size,) = msg.shape
    m2, rows = _to_rows(msg.astype(jnp.float32), size)
    n2, _ = _to_rows(noise.astype(jnp.float32), size)
    absmax = jnp.max(jnp.abs(m2), axis=1, keepdims=True)
    scale = jnp.maximum(absmax * (1.0 / 127.0), 1e-12)
    q = jnp.clip(jnp.floor(m2 / scale + n2), -127.0, 127.0)
    dq = (q * scale).reshape(-1)[:size].astype(msg.dtype)
    return (q.astype(jnp.int8).reshape(-1)[:size], scale.reshape(-1),
            dq, msg - dq)


def _pack_bits(nonneg_rows):
    """(rows, 128) {0,1} -> (rows, 16) uint8, lane 8c+j at byte c bit j."""
    b = nonneg_rows.astype(jnp.uint8)
    return sum(b[:, j::8] << j for j in range(8))


def sign_compress_ref(v, scale=None):
    """Flat 1-bit sign compressor. ``scale`` defaults to ``mean(|v|)``
    (the majority-vote-friendly magnitude); ``dq = scale * sign(v)``
    matches the historical compressor exactly (sign(0) = 0). Returns
    (bits (rows,16) u8, scale () f32, dq (p,))."""
    (size,) = v.shape
    if scale is None:
        scale = jnp.mean(jnp.abs(v))
    v2, _ = _to_rows(v.astype(jnp.float32), size)
    bits = _pack_bits(v2 >= 0)
    dq = (scale * jnp.sign(v2)).reshape(-1)[:size].astype(v.dtype)
    return bits, scale, dq


def ef_sign_compress_ref(delta, ef, scale=None):
    """Fused EF + sign: ``msg = delta + ef``, scale = mean(|msg|).
    Returns (bits, scale, dq, ef_new = msg - dq)."""
    msg = delta + ef
    bits, scale, dq = sign_compress_ref(msg, scale)
    return bits, scale, dq, msg - dq


def sign_unpack_ref(bits, scale, size: int):
    """Decode the 1-bit wire: (rows,16) u8 + scale -> (size,) f32 of
    ``±scale``. Exact zeros in the original encode as ``+scale`` — the
    one lossy edge of the wire format (``dq`` from the compressor keeps
    sign(0) = 0 and is what the simulator aggregates)."""
    rows = bits.shape[0]
    lanes = ((bits[:, :, None] >> jnp.arange(8, dtype=jnp.uint8)) & 1)
    pm1 = lanes.reshape(rows, LANES).astype(jnp.float32) * 2.0 - 1.0
    return (scale * pm1).reshape(-1)[:size]


def pack_selected_ref(dq, ranks, k: int):
    """Dense (dq, ranks) -> the ``(k,)`` wire buffers: (vals (k,), idx
    (k,) i32). Selection always fills all k slots exactly (n_strict
    strictly-above entries plus k - n_strict ties); unused slots —
    impossible by construction — would read 0 / -1."""
    p = dq.shape[0]
    safe = jnp.where(ranks >= 0, ranks, k)
    vals = jnp.zeros((k + 1,), dq.dtype).at[safe].set(dq)[:k]
    idx = jnp.full((k + 1,), -1, jnp.int32).at[safe].set(
        jnp.arange(p, dtype=jnp.int32))[:k]
    return vals, idx


def unpack_selected_ref(vals, idx, p: int):
    """Scatter the ``(k,)`` wire buffers back to a dense (p,) array —
    the receiver side of the top-k / rand-k link."""
    safe = jnp.where(idx >= 0, idx, p)
    out = jnp.zeros((p + 1,), vals.dtype).at[safe].set(
        jnp.where(idx >= 0, vals, jnp.zeros((), vals.dtype)))
    return out[:p]
