"""Pallas TPU kernels for the perf-critical compute layers.

Each subpackage ships:
  * ``<name>.py`` — the ``pl.pallas_call`` kernel with explicit BlockSpec
    VMEM tiling (TPU target),
  * ``ops.py``    — the jit'd public wrapper with backend dispatch,
  * ``ref.py``    — the pure-jnp oracle used for allclose validation
    (and as the compiled implementation on non-TPU backends).

Dispatch is unified in :mod:`repro.kernels.interface`: every op resolves
a :class:`~repro.kernels.interface.KernelType` (``pallas`` / ``xla`` /
``interpret``) from an explicit ``mode=`` argument or the
``REPRO_KERNEL_MODE`` environment variable. ``repro.kernels.compress``
holds the fused compression stack (EF + top-k / rand-k / int8 / sign
select+pack) that the comm layer routes through.
"""
from repro.kernels import compress
from repro.kernels.flash_attention import attention
from repro.kernels.interface import KernelType, dispatch_key, kernel_mode
from repro.kernels.moe_router import route_topk
from repro.kernels.prox_update import prox_sgd_tree
from repro.kernels.quantize import quantize_int8
from repro.kernels.rwkv6_scan import wkv

__all__ = ["attention", "route_topk", "prox_sgd_tree", "quantize_int8",
           "wkv", "compress", "KernelType", "kernel_mode", "dispatch_key"]
