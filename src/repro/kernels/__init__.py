"""Pallas TPU kernels for the perf-critical compute layers.

Each subpackage ships:
  * ``<name>.py`` — the ``pl.pallas_call`` kernel with explicit BlockSpec
    VMEM tiling (TPU target),
  * ``ops.py``    — the jit'd public wrapper with backend dispatch,
  * ``ref.py``    — the pure-jnp oracle used for allclose validation
    (and as the compiled implementation on non-TPU backends).
"""
from repro.kernels.flash_attention import attention
from repro.kernels.moe_router import route_topk
from repro.kernels.prox_update import prox_sgd_tree
from repro.kernels.quantize import quantize_int8
from repro.kernels.rwkv6_scan import wkv

__all__ = ["attention", "route_topk", "prox_sgd_tree", "quantize_int8",
           "wkv"]
