"""Public attention op: dispatches Pallas-on-TPU / interpret / jnp-ref.

Model code calls :func:`attention`; the backend is chosen once per process:
  * TPU backend        -> compiled Pallas kernel
  * elsewhere          -> the blocked pure-jnp reference (same math), which
                          is what CPU smoke tests and the 512-host-device
                          dry-run compile. ``FORCE_PALLAS_INTERPRET=1`` runs
                          the Pallas kernel body in interpret mode instead
                          (used by kernel correctness tests).
"""
from __future__ import annotations

import os

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def attention(q, k, v, *, causal=True, window=0, q_offset=None,
              block_q=512, block_kv=512):
    if _on_tpu():
        return flash_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, block_q=block_q,
                               block_kv=block_kv)
    if os.environ.get("FORCE_PALLAS_INTERPRET") == "1":
        return flash_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, block_q=block_q,
                               block_kv=block_kv, interpret=True)
    return attention_ref(q, k, v, causal=causal, window=window,
                         q_offset=q_offset)
