"""Public attention op: dispatches Pallas-on-TPU / interpret / jnp-ref.

Model code calls :func:`attention`; the backend is chosen by the unified
:func:`repro.kernels.interface.kernel_mode`:
  * TPU backend        -> compiled Pallas kernel
  * elsewhere          -> the blocked pure-jnp reference (same math), which
                          is what CPU smoke tests and the 512-host-device
                          dry-run compile.
  * ``REPRO_KERNEL_MODE=interpret`` (or the legacy
    ``FORCE_PALLAS_INTERPRET=1``) runs the Pallas kernel body in interpret
    mode instead (used by kernel correctness tests).
"""
from __future__ import annotations

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.interface import KernelType, kernel_mode


def attention(q, k, v, *, causal=True, window=0, q_offset=None,
              block_q=512, block_kv=512, mode=None):
    """Multi-head (optionally causal/windowed) attention over
    (B, S, H, D) tensors, GQA-aware.

    Routes through ``kernel_mode(mode)``: ``xla`` runs the blocked jnp
    reference, otherwise the flash-attention Pallas kernel (interpret
    unless on TPU).
    """
    kt = kernel_mode(mode)
    if kt is KernelType.XLA:
        return attention_ref(q, k, v, causal=causal, window=window,
                             q_offset=q_offset)
    return flash_attention(q, k, v, causal=causal, window=window,
                           q_offset=q_offset, block_q=block_q,
                           block_kv=block_kv,
                           interpret=kt is not KernelType.PALLAS)
