"""Pure-jnp oracle for flash attention: chunked online-softmax GQA attention.

This is (a) the numerical oracle for the Pallas kernel and (b) the
implementation compiled on non-TPU backends (incl. the CPU dry-run) — it is
mathematically exact full attention, but blocked over the KV axis so the
peak temporary is O(q_chunk × kv_chunk) instead of O(seq²).

Supports: causal masking, sliding-window attention (window > 0), GQA
(num_q_heads a multiple of num_kv_heads), and an explicit kv_len for
decode (query positions offset to the end of the cache).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_expand(k, n_q_heads):
    """(b, s, n_kv, d) -> (b, s, n_q, d) by repeating kv heads."""
    b, s, n_kv, d = k.shape
    if n_kv == n_q_heads:
        return k
    rep = n_q_heads // n_kv
    return jnp.repeat(k, rep, axis=2)


@functools.partial(jax.jit, static_argnames=("causal", "window", "kv_chunk"))
def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  q_offset=None, kv_chunk: int = 1024):
    """Blocked attention.

    q: (b, sq, hq, d); k, v: (b, skv, hkv, d). Returns (b, sq, hq, d).
    q_offset: scalar int (traced OK) — absolute position of q[0]
              (decode: cache_len). None means aligned-to-end.
    window: if > 0, attend only to keys within `window` positions back.
    """
    b, sq, hq, d = q.shape
    skv = k.shape[1]
    k = _gqa_expand(k, hq)
    v = _gqa_expand(v, hq)
    orig_dtype = q.dtype
    qf = q.astype(jnp.float32) * (d ** -0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    if q_offset is None:
        q_offset = skv - sq  # aligned-to-end convention
    q_pos = jnp.arange(sq) + q_offset           # (sq,)

    kv_chunk = min(kv_chunk, skv)
    n_chunks = (skv + kv_chunk - 1) // kv_chunk
    pad = n_chunks * kv_chunk - skv
    if pad:
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kf = kf.reshape(b, n_chunks, kv_chunk, hq, d)
    vf = vf.reshape(b, n_chunks, kv_chunk, hq, d)

    def body(carry, inp):
        m, l, acc = carry          # (b,hq,sq), (b,hq,sq), (b,hq,sq,d)
        kc, vc, cidx = inp         # (b,kv_chunk,hq,d) ×2, scalar
        kv_pos = cidx * kv_chunk + jnp.arange(kv_chunk)      # (kv_chunk,)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kc)            # (b,hq,sq,kc)
        mask = kv_pos[None, :] < skv                          # padding
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        if window > 0:
            mask = mask & (kv_pos[None, :] > q_pos[:, None] - window)
        # fold the mask into s ONCE (an additive -inf bias): each extra
        # `where` over the (b,hq,sq,kc) score tensor is a full HBM pass at
        # dry-run scale — §Perf hillclimb 3. exp(NEG_INF-m) underflows to
        # exactly 0, so no second masking of p is needed once m >= 0
        # entries exist; fully-masked rows give l=0 and are guarded by the
        # final maximum(l, eps).
        s = s + jnp.where(mask[None, None], 0.0, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(jnp.minimum(m - m_new, 0.0))
        l_new = l * scale + p.sum(axis=-1)
        # p is consumed by an MXU matmul: store it in the activation dtype
        # (halves the dominant score-tensor read; the f32 row statistics
        # m/l keep the online softmax exact to bf16 rounding)
        pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(orig_dtype),
                        vc.astype(orig_dtype)).astype(jnp.float32)
        acc_new = acc * scale[..., None] + pv
        return (m_new, l_new, acc_new), None

    from repro.sharding.constrain import constrain
    m0 = constrain(jnp.full((b, hq, sq), NEG_INF, jnp.float32),
                   "batch", "model", None)
    l0 = constrain(jnp.zeros((b, hq, sq), jnp.float32),
                   "batch", "model", None)
    acc0 = constrain(jnp.zeros((b, hq, sq, d), jnp.float32),
                     "batch", "model", None, None)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0),
        (jnp.moveaxis(kf, 1, 0), jnp.moveaxis(vf, 1, 0), jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.moveaxis(out, 1, 2).astype(orig_dtype)  # (b,sq,hq,d)
