"""Pallas TPU flash attention (causal / sliding-window, GQA).

Grid: (batch, q_heads, q_blocks, kv_blocks) — kv_blocks is the innermost,
sequential dimension; the online-softmax running state (m, l, acc) lives in
VMEM scratch and is carried across kv blocks. Block shapes are MXU-aligned:
(block_q, head_dim) q tiles against (block_kv, head_dim) kv tiles, with the
lane dimension a multiple of 128 for the systolic array.

GQA is handled in the BlockSpec index maps: the kv block loaded for q-head h
is kv-head ``h // (hq // hkv)`` — no materialized head repetition, so HBM
traffic for K/V is 1/group of the MHA equivalent.

``q_offset`` (the absolute position of q[0] — the cache length during
decode) is a *traced* scalar, delivered to the kernel via scalar prefetch
(SMEM) so a single compiled decode step serves every position.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(off_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                 *, causal: bool, window: int, sm_scale: float, block_q: int,
                 block_kv: int, kv_len: int):
    kv_idx = pl.program_id(3)
    n_kv = pl.num_programs(3)
    q_offset = off_ref[0]

    @pl.when(kv_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_blk = pl.program_id(2)
    q_pos = q_offset + q_blk * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0)
    k_pos = kv_idx * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)

    def _compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32) * sm_scale   # (bq, d)
        k = k_ref[0, 0, :, :].astype(jnp.float32)              # (bkv, d)
        v = v_ref[0, 0, :, :].astype(jnp.float32)
        # zero out-of-range kv rows: beyond-kv_len blocks hold garbage and
        # 0 * garbage in the PV matmul would poison the accumulator.
        kv_valid = (kv_idx * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (block_kv, 1), 0)) < kv_len
        v = jnp.where(kv_valid, v, 0.0)
        k = jnp.where(kv_valid, k, 0.0)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = k_pos < kv_len
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        # explicit mask on p: for fully-masked rows exp(NEG_INF - NEG_INF)
        # would be 1, not 0.
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    if causal or window > 0:
        # Skip kv blocks fully masked by the causal/sliding-window structure
        # (this is where flash beats naive: ~2x for causal, seq/window for
        # SWA). Works with a traced q_offset because pl.when takes a traced
        # predicate.
        blk_min_q = q_offset + q_blk * block_q
        blk_max_q = blk_min_q + block_q - 1
        blk_min_k = kv_idx * block_kv
        blk_max_k = blk_min_k + block_kv - 1
        live = blk_min_k <= jnp.minimum(blk_max_q, kv_len - 1)
        if window > 0:
            live &= blk_max_k > blk_min_q - window
        pl.when(live)(_compute)
    else:
        _compute()

    @pl.when(kv_idx == n_kv - 1)
    def _finish():
        o_ref[0, 0, :, :] = (acc_scr[...] /
                             jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset=None, block_q: int = 512,
                    block_kv: int = 512, interpret: bool = False):
    """q: (b, sq, hq, d); k, v: (b, skv, hkv, d) -> (b, sq, hq, d).

    q_offset: None (aligned-to-end) or a scalar (traced OK).
    """
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    if q_offset is None:
        q_offset = skv - sq
    q_offset = jnp.asarray(q_offset, jnp.int32).reshape(1)
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    grid = (b, hq, pl.cdiv(sq, block_q), pl.cdiv(skv, block_kv))
    group = max(hq // hkv, 1)

    qs = jnp.moveaxis(q, 2, 1)  # (b, hq, sq, d)
    ks = jnp.moveaxis(k, 2, 1)
    vs = jnp.moveaxis(v, 2, 1)

    kernel = functools.partial(
        _attn_kernel, causal=causal, window=window, sm_scale=d ** -0.5,
        block_q=block_q, block_kv=block_kv, kv_len=skv)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h, i, j, off: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b_, h, i, j, off: (b_, h // group, j, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b_, h, i, j, off: (b_, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h, i, j, off: (b_, h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        interpret=interpret,
    )(q_offset, qs, ks, vs)
    return jnp.moveaxis(out, 1, 2)
