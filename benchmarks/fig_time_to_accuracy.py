"""Time-to-accuracy under heterogeneous system profiles x compressors.

The paper's systems pitch — multi-tier personalization with inexpensive
communication — only shows up when rounds and bytes are converted to
*wall-clock time* over real-looking links. This figure runs the MNIST/
MCLR setting on three wall-clock worlds (`repro.system` profiles:
lan-campus, wan-cellular, edge-iot) x three uplink compressors
(identity, top-10%+EF, sign+EF), and reports accuracy against cumulative
*simulated seconds* instead of round indices.

All nine configurations execute as ONE jitted dispatch per chunk: the
three profiles ride the vmapped sweep axis as traced float leaves
(``run_sweep(system=[...])``), and the three compressors — which change
the round graph itself — are fused by ``run_multi_sweep``.

Reproduction targets: (a) simulated time is monotone non-decreasing for
every configuration; (b) for a fixed compressor, the thin-link profiles
cost more simulated time than the campus LAN; (c) on the WAN-bound
profiles, both lossy compressors reach the end of the run in less
simulated time than identity (compression buys *time*, not just bytes).

    PYTHONPATH=src python -m benchmarks.fig_time_to_accuracy
"""
from __future__ import annotations

import dataclasses

from repro.comm import CommConfig
from repro.scenarios import SCENARIOS, build_scenario
from repro.train.sweep import run_multi_sweep

PROFILES = ("lan-campus", "wan-cellular", "edge-iot")
COMPRESSORS = ("identity", "topk", "sign")


def _variants():
    b = build_scenario(SCENARIOS["table1/mnist/mclr/permfl"])
    variants = []
    for comp in COMPRESSORS:
        algo = dataclasses.replace(
            b.algo, comm=CommConfig(compressor=comp, k_frac=0.1))
        variants.append(dict(algo=algo, params0=b.params0,
                             system=list(PROFILES)))
    return b, variants


def main(quick=True, csv=print) -> list:
    rounds = 8 if quick else 40
    b, variants = _variants()
    sweeps = run_multi_sweep(variants, b.train, b.val,
                             metric_fn=b.metric_fn, rounds=rounds,
                             m=b.m, n=b.n)

    total = {}
    failures = []
    for comp, sw in zip(COMPRESSORS, sweeps):
        if sw.dispatches != 1:
            failures.append(
                f"fig_tta: {comp} took {sw.dispatches} dispatches "
                "(expected the whole grid in one)")
        for res, prof in zip(sw, PROFILES):
            tl = res.timeline.summary()
            total[comp, prof] = tl["sim_seconds"]
            csv(f"fig_tta,mnist,mclr,{comp},{prof},sim_seconds,"
                f"{tl['sim_seconds']:.2f}")
            csv(f"fig_tta,mnist,mclr,{comp},{prof},final_pm,"
                f"{res.pm_acc[-1]:.4f}")
            # the accuracy-vs-simulated-seconds curve itself
            for t, pm in zip(res.sim_seconds, res.pm_acc):
                csv(f"fig_tta,mnist,mclr,{comp},{prof},curve,"
                    f"{t:.2f}:{pm:.4f}")
            if any(t2 < t1 for t1, t2 in
                   zip(res.sim_seconds, res.sim_seconds[1:])):
                failures.append(
                    f"fig_tta: {comp}/{prof} simulated time not monotone")

    for comp in COMPRESSORS:
        for prof in ("wan-cellular", "edge-iot"):
            if not total[comp, prof] > total[comp, "lan-campus"]:
                failures.append(
                    f"fig_tta: {comp}: {prof} not slower than lan-campus")
    for prof in ("wan-cellular", "edge-iot"):
        for comp in ("topk", "sign"):
            if not total[comp, prof] < total["identity", prof]:
                failures.append(
                    f"fig_tta: {comp} on {prof} not faster than identity "
                    "(compression should buy simulated time)")
    return failures


if __name__ == "__main__":
    import sys
    fails = main(quick="--full" not in sys.argv)
    for f in fails:
        print("FAIL", f)
    sys.exit(1 if fails else 0)
