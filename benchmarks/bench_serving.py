"""Serving throughput on CPU (reduced model): prefill tokens/s and decode
steps/s for a dense arch and an SSM arch — exercises the same
prefill/decode units the decode-shape dry-runs lower at scale."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_reduced_config
from repro.models import model as M
from repro.serve.engine import ServeEngine


def bench_arch(arch: str, csv=print, batch=4, prompt=64, new=16):
    cfg = get_reduced_config(arch).replace(vocab_size=256)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg=cfg, params=params, max_len=prompt + new)
    toks = jax.random.randint(jax.random.PRNGKey(1), (batch, prompt), 0, 256)
    out = eng.generate({"tokens": toks}, max_new_tokens=2)  # warmup/compile
    t0 = time.perf_counter()
    out = eng.generate({"tokens": toks}, max_new_tokens=new)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    tput = batch * new / dt
    csv(f"serving,{arch},batch={batch} prompt={prompt} new={new},"
        f"decode_tok_per_s,{tput_fmt(tput)}")
    return out


def tput_fmt(x):
    return f"{x:.1f}"


def main(quick=True, csv=print):
    for arch in ("phi3-mini-3.8b", "rwkv6-7b"):
        bench_arch(arch, csv=csv)
    return []


if __name__ == "__main__":
    main()
