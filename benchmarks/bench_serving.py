"""Serving benchmarks: personalized traffic replay + LLM decode loop.

Two serving shapes, one marker. The headline measurement is the
**personalized traffic replay** (DESIGN.md §12): train the benchmark
scenario, export the (team, device)-keyed `ModelStore`, round-trip it
through disk, and replay Zipf-popularity request traffic through the
tier-fallback batched `PersonalizedServer` — reporting queries/sec and
p50/p95/p99 batch latency for both the in-graph gather path and the
LRU-cached unique-principal path, plus the encoded device-tier bytes per
encoding (exact bit-pattern delta vs fused int8). The legacy
measurement (prefill/decode tokens/sec for the reduced LLM archs) rides
along unchanged.

    PYTHONPATH=src python -m benchmarks.bench_serving            # timed
    PYTHONPATH=src python -m benchmarks.bench_serving --smoke    # CI:
        tiny topology/batch/new-tokens, liveness + marker only

Either mode writes ``BENCH_serving.json`` at the repo root. The
``serving`` section holds only higher-is-better rates — qps, inverted
batch latencies (percentiles over *all* timed batches, from the raw
per-batch array `replay_traffic` now returns), the LRU hit rate, and
the per-tier resolution rates — so ``python -m repro.obs.regress``
gates it against the committed baseline in ``benchmarks/baselines/``
with no special-casing; raw millisecond latencies and tier counts live
in the ungated ``serving_detail`` section. The replay's full metrics
registry also lands as Prometheus text in
``BENCH_serving_metrics.prom`` next to the marker.
"""
from __future__ import annotations

import json
import pathlib
import sys
import tempfile
import time

import jax

from repro.configs import get_reduced_config
from repro.models import model as M
from repro.models import paper_models
from repro.obs.metrics import MetricsRegistry, percentile
from repro.scenarios import DataSpec, FLScenario, build_scenario, \
    run_scenario
from repro.serve import ModelStore, PersonalizedServer, replay_traffic
from repro.serve.engine import ServeEngine

# the replay workload as a declarative spec (not registered — a system
# benchmark, not a paper cell): paper-scale MCLR topology, shrunk by
# FLScenario.scaled in smoke mode
BENCH_SCENARIO = FLScenario(
    name="bench/serving/mnist-mclr", data=DataSpec(dataset="mnist"),
    rounds=4, data_seed=9,
    notes="personalized store export + Zipf traffic replay workload")

_BENCH_JSON = pathlib.Path(__file__).resolve().parents[1] / \
    "BENCH_serving.json"
_BENCH_PROM = _BENCH_JSON.with_name("BENCH_serving_metrics.prom")


def write_bench_json(payload: dict) -> None:
    """Persist the serving perf marker at the repo root; CI gates it
    against benchmarks/baselines/BENCH_serving.json via repro.obs.regress."""
    _BENCH_JSON.write_text(json.dumps(payload, indent=2, sort_keys=True)
                           + "\n")
    print(f"# bench_serving: wrote {_BENCH_JSON.name}")


def bench_arch(arch: str, csv=print, batch=4, prompt=64, new=16):
    """Decode-loop throughput for one reduced LLM arch; returns tok/s."""
    cfg = get_reduced_config(arch).replace(vocab_size=256)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg=cfg, params=params, max_len=prompt + new)
    toks = jax.random.randint(jax.random.PRNGKey(1), (batch, prompt), 0, 256)
    eng.generate({"tokens": toks}, max_new_tokens=2)  # warmup/compile
    t0 = time.perf_counter()
    out = eng.generate({"tokens": toks}, max_new_tokens=new)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    tput = batch * new / dt
    csv(f"serving,{arch},batch={batch} prompt={prompt} new={new},"
        f"decode_tok_per_s,{tput:.1f}")
    return tput


def bench_replay(csv=print, *, scenario=BENCH_SCENARIO, requests=1024,
                 batch=64, alpha=1.2, unknown_frac=0.05, seed=0):
    """Train -> export -> persist -> reload -> replay. Returns
    ``(failures, serving_rates, detail)`` — ``serving_rates`` is the
    gated higher-is-better section, ``detail`` the raw latencies and
    store-size facts."""
    res = run_scenario(scenario, seed=seed)
    b = build_scenario(scenario, seed=seed)
    cfg = b.config
    xv = jax.numpy.asarray(b.val["x"])
    pool = xv.reshape((-1,) + xv.shape[3:])
    apply1 = lambda p, x: paper_models.apply(p, cfg, x[None])[0]

    store = ModelStore.from_result(b.algo, res, m=b.m, n=b.n,
                                   encoding="delta")
    with tempfile.TemporaryDirectory() as td:
        path = str(pathlib.Path(td) / "store.zip")
        store.save(path)
        store = ModelStore.load(path)
    int8_bytes = ModelStore.from_result(
        b.algo, res, m=b.m, n=b.n, encoding="int8").device_tier_nbytes()

    server = PersonalizedServer(store, apply1)
    metrics = MetricsRegistry()
    kw = dict(requests=requests, batch=batch, alpha=alpha,
              unknown_frac=unknown_frac, seed=seed, metrics=metrics)
    stats = replay_traffic(server, pool, **kw)
    stats_cached = replay_traffic(server, pool, cached=True, **kw)
    _BENCH_PROM.write_text(metrics.to_prometheus())

    # percentiles over *all* timed batches from the raw per-batch
    # latencies — the marker's tail stats come straight from the array,
    # so two percentile points only coincide when the workload is too
    # short for them to differ (the smoke replay sizes itself to avoid
    # exactly that)
    lat_ms = stats["lat_ms"]
    p50, p95, p99 = (percentile(lat_ms, p) for p in (50, 95, 99))

    for name, st in (("gather", stats), ("cached", stats_cached)):
        csv(f"serving,replay/{name},requests={st['requests']} "
            f"batch={st['batch']} zipf={st['alpha']:g},qps,"
            f"{st['qps']:.1f}")
        csv(f"serving,replay/{name},,latency_ms,"
            f"p50={st['p50_ms']:.3f} p95={st['p95_ms']:.3f} "
            f"p99={st['p99_ms']:.3f}")
    tiers = stats["tier_counts"]
    csv(f"serving,replay/gather,,tier_counts,"
        f"device={tiers['device']} team={tiers['team']} "
        f"global={tiers['global']}")
    csv(f"serving,replay/cached,,cache_hit_rate,"
        f"{stats_cached['cache_hit_rate']:.4f}")
    csv(f"serving,store,{store.m}x{store.n},device_tier_bytes,"
        f"delta={stats['device_tier_bytes']} int8={int8_bytes}")

    failures = []
    if not (stats["qps"] > 0 and p50 > 0):
        failures.append("bench_serving: degenerate replay timings")
    if sum(tiers.values()) != stats["requests"]:
        failures.append("bench_serving: tier counts do not sum to "
                        f"requests ({tiers} vs {stats['requests']})")
    total = stats["requests"]
    rates = {
        "qps": round(stats["qps"], 2),
        # inverted batch latencies: batches/sec at each percentile, so
        # the regress gate's higher-is-better convention applies
        "rate_p50": round(1e3 / p50, 2),
        "rate_p95": round(1e3 / p95, 2),
        "rate_p99": round(1e3 / p99, 2),
        # telemetry rates, all higher-is-better under the same generic
        # flatten: the LRU hit rate and the share of requests resolved
        # at each tier (deterministic for a fixed seed/workload)
        "cache_hit_rate": round(stats_cached["cache_hit_rate"], 4),
        "tier_device_rate": round(tiers["device"] / total, 4),
        "tier_team_rate": round(tiers["team"] / total, 4),
        "tier_global_rate": round(tiers["global"] / total, 4),
    }
    detail = {
        "scenario": scenario.name, "m": store.m, "n": store.n,
        "requests": stats["requests"], "batch": stats["batch"],
        "alpha": alpha, "unknown_frac": unknown_frac,
        "encoding": store.encoding,
        "p50_ms": round(p50, 4),
        "p95_ms": round(p95, 4),
        "p99_ms": round(p99, 4),
        "mean_ms": round(stats["mean_ms"], 4),
        "timed_batches": len(lat_ms),
        "tier_counts": tiers,
        "stage_gather_ms": round(stats["stage_gather_ms"], 4),
        "stage_forward_ms": round(stats["stage_forward_ms"], 4),
        # the LRU path's numbers are workload-shaped (cold-miss heavy on
        # short replays), so they are reported here, not gated
        "cached_qps": round(stats_cached["qps"], 2),
        "cached_p50_ms": round(stats_cached["p50_ms"], 4),
        "device_tier_bytes": {"delta": stats["device_tier_bytes"],
                              "int8": int8_bytes},
    }
    return failures, rates, detail


def smoke() -> list:
    """CI guard: 2x3x16 topology for 2 rounds, a short replay through
    both serve paths, and one tiny decode loop — then the marker.

    512 requests at batch 8 give 64 timed batches, enough that the p95
    and p99 nearest-rank percentiles land on different batches (ranks 61
    and 64) — the old 8-batch smoke replay collapsed them onto the same
    sample, so the marker's two tail rates were always equal."""
    scenario = BENCH_SCENARIO.scaled(m_teams=2, n_devices=3,
                                     samples_per_device=16, rounds=2)
    failures, rates, detail = bench_replay(
        print, scenario=scenario, requests=512, batch=8)
    tput = bench_arch("phi3-mini-3.8b", print, batch=2, prompt=16, new=4)
    print(f"# bench_serving smoke: replay qps={rates['qps']:.0f}, "
          f"decode {tput:.0f} tok/s OK")
    write_bench_json({"mode": "smoke", "serving": rates,
                      "serving_detail": detail,
                      "decode": {"phi3-mini-3.8b": round(tput, 1)}})
    return failures


def main(quick: bool = True, csv=print) -> list:
    failures, rates, detail = bench_replay(
        csv, requests=1024 if quick else 4096, batch=64)
    decode = {}
    for arch in ("phi3-mini-3.8b", "rwkv6-7b"):
        decode[arch] = round(bench_arch(arch, csv=csv), 1)
    write_bench_json({"mode": "quick" if quick else "full",
                      "serving": rates, "serving_detail": detail,
                      "decode": decode})
    return failures


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        sys.exit(0 if smoke() == [] else 1)
    fails = main(quick="--full" not in sys.argv)
    for f in fails:
        print("FAIL", f)
    sys.exit(1 if fails else 0)
