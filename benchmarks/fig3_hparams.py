"""Fig 3 (+ §D.4): effect of beta, gamma, lambda on PerMFL convergence.

Reproduction target: increasing each of beta/gamma/lambda (others fixed,
within the Theorem-1 admissible ranges) speeds up PerMFL(PM) convergence —
measured as personal-model accuracy after a fixed small round budget.

All nine grid points run as ONE compiled program via sweep_scenario on
the registered ``fig3/mnist/mclr`` scenario (the sequential per-value
loop paid 9 dispatch+run cycles); per-value results are sliced out of
the single FLSweepResult. Equivalence with the old per-value loop is
pinned in tests/test_engine.py.
"""
from __future__ import annotations

from repro.scenarios import SCENARIOS, sweep_scenario

SWEEPS = {
    # paper supplementary: beta in Fig 5-10 (gamma=3.0, lam=0.5)
    "beta": ([0.05, 0.2, 0.6], dict(gamma=3.0, lam=0.5)),
    # gamma in Fig 11-16 (lam=1.5, beta=0.1)
    "gamma": ([0.5, 1.5, 3.0], dict(lam=1.5, beta=0.1)),
    # lambda in Fig 17-22 (beta=0.3, gamma=3.0)
    "lam": ([0.1, 0.5, 2.0], dict(beta=0.3, gamma=3.0)),
}


def sweep_grid() -> list:
    """The 9 Fig-3 grid points as sweep config dicts (grid order is
    SWEEPS order: 3 beta points, 3 gamma points, 3 lambda points)."""
    grid = []
    for hname, (values, fixed) in SWEEPS.items():
        for v in values:
            grid.append(dict(alpha=0.01, eta=0.03, **fixed, **{hname: v}))
    return grid


def run(dataset="mnist", rounds=6, csv=print):
    """The nine-point sweep + monotone-speedup checks."""
    failures = []
    sw = sweep_scenario(SCENARIOS[f"fig3/{dataset}/mclr"], sweep_grid(),
                        (0,), rounds=rounds)
    csv(f"# fig3: {len(sw)} grid points in {sw.dispatches} dispatch(es), "
        f"{sw.seconds:.1f}s total")

    i = 0
    for hname, (values, fixed) in SWEEPS.items():
        final_pm = []
        final_gm = []
        for v in values:
            r = sw[i]
            i += 1
            final_pm.append(r.pm_acc[-1])
            final_gm.append(r.gm_acc[-1])
            csv(f"fig3,{dataset},mclr,{hname}={v},pm,{r.pm_acc[-1]:.4f}")
            csv(f"fig3,{dataset},mclr,{hname}={v},gm,{r.gm_acc[-1]:.4f}")
        # monotone speedup (allow tiny noise)
        metric = final_gm if hname in ("beta", "gamma") else final_pm
        if not all(b >= a - 0.03 for a, b in zip(metric, metric[1:])):
            failures.append(f"fig3: {hname} not monotone: {metric}")
    return failures


def main(quick=True, csv=print):
    return run(rounds=6 if quick else 20, csv=csv)


if __name__ == "__main__":
    for f in main():
        print("FAIL", f)
