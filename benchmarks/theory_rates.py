"""Theorem 1/2 validation: observed convergence rate vs theoretical bound.

Strongly convex: run PerMFL with theory-admissible step sizes on the
l2-regularized MCLR problem and verify ||x^T - x*||^2 decays at least as
fast as 2(1-beta)^T. Non-convex: verify min-gradient-norm ~ O(1/T)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.permfl import PerMFLHParams, init_state, permfl_round
from repro.core.theory import (mclr_constants, pick_hparams_strongly_convex)

from repro.scenarios import DataSpec, FLScenario, ModelSpec, build_scenario


def quad_loss(p, b):
    return 0.5 * jnp.sum((p - b["c"]) ** 2)


def strongly_convex_rate(csv=print, T=30):
    """Quadratic (mu=L=1): closed-form x*, exact error tracking."""
    rng = np.random.default_rng(0)
    m, n, d = 4, 10, 8
    c = jnp.asarray(rng.normal(size=(m, n, d)).astype(np.float32))
    hps = pick_hparams_strongly_convex(1.0, 1.0)
    hp = PerMFLHParams(alpha=hps["alpha"], eta=hps["eta"], beta=hps["beta"],
                       lam=hps["lam"], gamma=hps["gamma"], k_team=10,
                       l_local=20)
    st = init_state(jnp.zeros(d), m, n)
    x_star = np.asarray(c.mean((0, 1)))
    e0 = float(np.sum((np.asarray(st.x) - x_star) ** 2))
    ok = True
    for t in range(1, T + 1):
        st = permfl_round(st, {"c": c}, hp, quad_loss, m_teams=m,
                          n_devices=n)
        et = float(np.sum((np.asarray(st.x) - x_star) ** 2))
        bound = 2 * (1 - hp.beta) ** t * e0
        if t % 5 == 0 or t == T:
            csv(f"theory,strongly_convex,t={t},err,{et:.3e},bound,{bound:.3e}")
        ok = ok and (et <= bound + 1e-12)
    csv(f"# theorem-1 bound satisfied for all t: {ok}")
    return [] if ok else ["theorem-1 bound violated"]


def nonconvex_rate(csv=print, T=12):
    """DNN on synthetic tabular: mean ||grad phi|| over rounds ~ decreasing;
    report the min-so-far curve (Theorem 2 guarantees min over t)."""
    b = build_scenario(FLScenario(
        name="theory/nonconvex/synthetic-dnn",
        data=DataSpec(dataset="synthetic", partitioner="tabular"),
        model=ModelSpec("dnn"), data_seed=6,
        notes="Theorem-2 rate validation workload"))
    tr, loss, p0 = b.train, b.loss_fn, b.params0
    m, n = b.m, b.n
    hp = PerMFLHParams(alpha=0.01, eta=0.03, beta=0.1, lam=0.5, gamma=1.5,
                       k_team=5, l_local=10)
    st = init_state(p0, m, n)

    def global_grad_norm(x):
        g = jax.grad(lambda p: jax.vmap(jax.vmap(
            lambda b: loss(p, b)))(tr).mean())(x)
        return float(jnp.sqrt(sum(jnp.vdot(a, a) for a in jax.tree.leaves(g))))

    norms = []
    for t in range(T):
        st = permfl_round(st, tr, hp, loss, m_teams=m, n_devices=n)
        norms.append(global_grad_norm(st.x))
        csv(f"theory,nonconvex,t={t},grad_norm,{norms[-1]:.4f},min_so_far,"
            f"{min(norms):.4f}")
    ok = min(norms) < norms[0]
    csv(f"# theorem-2 stationarity progress: {ok}")
    return [] if ok else ["theorem-2: no stationarity progress"]


def main(quick=True, csv=print):
    fails = strongly_convex_rate(csv, T=20 if quick else 50)
    fails += nonconvex_rate(csv, T=8 if quick else 25)
    return fails


if __name__ == "__main__":
    main()
