"""§Roofline table: read the dry-run sweep artifact and print the
three-term roofline per (arch x shape) on the single-pod mesh, plus the
dominant term and the MODEL_FLOPS/HLO_FLOPs useful-compute ratio."""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun_all.json")


def load(mesh="pod_16x16"):
    recs = json.load(open(RESULTS))
    return [r for r in recs if r["mesh"] == mesh]


def main(quick=True, csv=print):
    if not os.path.exists(RESULTS):
        csv("# roofline: results/dryrun_all.json missing — run "
            "`python -m repro.launch.dryrun --all --out results/dryrun_all.json`")
        return ["dry-run artifact missing"]
    csv("roofline,arch,shape,compute_s,memory_s,collective_s,dominant,"
        "useful_ratio,peak_gb_per_dev")
    fails = []
    for r in load():
        if r["status"] == "skipped":
            csv(f"roofline,{r['arch']},{r['shape']},,,,SKIPPED({r['reason'][:40]}),,")
            continue
        if r["status"] != "ok":
            fails.append((r["arch"], r["shape"]))
            continue
        peak = (r["bytes_per_device"]["peak"] or 0) / 1e9
        csv(f"roofline,{r['arch']},{r['shape']},{r['compute_s']:.3e},"
            f"{r['memory_s']:.3e},{r['collective_s']:.3e},{r['dominant']},"
            f"{r['useful_ratio']:.2f},{peak:.2f}")
    # multi-pod sanity: every combo must also be ok on 2x16x16
    for r in load("multipod_2x16x16"):
        if r["status"] == "FAILED":
            fails.append(("multipod", r["arch"], r["shape"]))
    return fails


if __name__ == "__main__":
    main()
