"""Table 2: team-formation ablation (worst vs average case).

Reproduction targets (paper §4.1.4): the personalized model is mostly
unaffected by formation; the global model degrades in the worst case.

Per formation strategy, the multi-seed runs (different model inits) go
through run_sweep as one vmapped program; reported numbers are seed-means
of the best PM/GM.
"""
from __future__ import annotations

import numpy as np

from repro.core import PerMFL
from repro.train.sweep import run_sweep

from benchmarks.fl_common import (HP_DEFAULT, fns_for, init_model,
                                  make_fed_data, model_for, to_jax)


def run(dataset="fmnist", convex=True, rounds=10, seeds=(0, 1), csv=print):
    cfg = model_for(dataset, convex)
    loss, met = fns_for(cfg)
    init_fn = lambda seed: init_model(cfg, seed)
    res = {}
    for strategy in ("worst", "average"):
        fd = make_fed_data(dataset, seed=3, m=2, n=10, strategy=strategy)
        tr, va = to_jax(fd)
        sw = run_sweep(PerMFL(loss, HP_DEFAULT), [{}], seeds, init_fn,
                       tr, va, metric_fn=met, rounds=rounds, m=2, n=10)
        pm = float(np.mean([r.best("pm") for r in sw]))
        gm = float(np.mean([r.best("gm") for r in sw]))
        res[strategy] = (pm, gm)
        mdl = "mclr" if convex else "cnn"
        csv(f"table2,{dataset},{mdl},{strategy},pm,{pm:.4f}")
        csv(f"table2,{dataset},{mdl},{strategy},gm,{gm:.4f}")

    failures = []
    pm_w, gm_w = res["worst"]
    pm_a, gm_a = res["average"]
    if pm_w < pm_a - 0.05:
        failures.append(f"table2: PM degraded in worst case {pm_w} vs {pm_a}")
    if gm_a < gm_w - 0.05:
        failures.append(f"table2: GM should not prefer worst case")
    return failures


def main(quick=True, csv=print):
    fails = []
    for ds in ("mnist", "fmnist"):
        fails += run(ds, True, rounds=8 if quick else 30,
                     seeds=(0, 1) if quick else (0, 1, 2), csv=csv)
    return fails


if __name__ == "__main__":
    main()
