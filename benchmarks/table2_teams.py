"""Table 2: team-formation ablation (worst vs average case).

Reproduction targets (paper §4.1.4): the personalized model is mostly
unaffected by formation; the global model degrades in the worst case."""
from __future__ import annotations

from repro.train import fl_trainer as FT

from benchmarks.fl_common import (HP_DEFAULT, fns_for, init_model,
                                  make_fed_data, model_for, to_jax)


def run(dataset="fmnist", convex=True, rounds=10, csv=print):
    cfg = model_for(dataset, convex)
    loss, met = fns_for(cfg)
    p0 = init_model(cfg)
    res = {}
    for strategy in ("worst", "average"):
        fd = make_fed_data(dataset, seed=3, m=2, n=10, strategy=strategy)
        tr, va = to_jax(fd)
        r = FT.run_permfl(p0, tr, va, loss_fn=loss, metric_fn=met,
                          hp=HP_DEFAULT, rounds=rounds, m=2, n=10)
        res[strategy] = (r.best("pm"), r.best("gm"))
        mdl = "mclr" if convex else "cnn"
        csv(f"table2,{dataset},{mdl},{strategy},pm,{r.best('pm'):.4f}")
        csv(f"table2,{dataset},{mdl},{strategy},gm,{r.best('gm'):.4f}")

    failures = []
    pm_w, gm_w = res["worst"]
    pm_a, gm_a = res["average"]
    if pm_w < pm_a - 0.05:
        failures.append(f"table2: PM degraded in worst case {pm_w} vs {pm_a}")
    if gm_a < gm_w - 0.05:
        failures.append(f"table2: GM should not prefer worst case")
    return failures


def main(quick=True, csv=print):
    fails = []
    for ds in ("mnist", "fmnist"):
        fails += run(ds, True, rounds=8 if quick else 30, csv=csv)
    return fails


if __name__ == "__main__":
    main()
