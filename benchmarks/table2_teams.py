"""Table 2: team-formation ablation (worst vs average case).

Reproduction targets (paper §4.1.4): the personalized model is mostly
unaffected by formation; the global model degrades in the worst case.

Each (dataset, strategy) cell is the registered scenario
``table2/{dataset}/{strategy}``; per strategy, the multi-seed runs
(different model inits) go through sweep_scenario as one vmapped
program; reported numbers are seed-means of the best PM/GM.
"""
from __future__ import annotations

import numpy as np

from repro.scenarios import SCENARIOS, sweep_scenario


def run(dataset="fmnist", rounds=10, seeds=(0, 1), csv=print):
    """Worst vs average formation on one dataset; returns failed checks."""
    res = {}
    for strategy in ("worst", "average"):
        sw = sweep_scenario(SCENARIOS[f"table2/{dataset}/{strategy}"],
                            [{}], seeds, rounds=rounds)
        pm = float(np.mean([r.best("pm") for r in sw]))
        gm = float(np.mean([r.best("gm") for r in sw]))
        res[strategy] = (pm, gm)
        csv(f"table2,{dataset},mclr,{strategy},pm,{pm:.4f}")
        csv(f"table2,{dataset},mclr,{strategy},gm,{gm:.4f}")

    failures = []
    pm_w, gm_w = res["worst"]
    pm_a, gm_a = res["average"]
    if pm_w < pm_a - 0.05:
        failures.append(f"table2: PM degraded in worst case {pm_w} vs {pm_a}")
    if gm_a < gm_w - 0.05:
        failures.append(f"table2: GM should not prefer worst case")
    return failures


def main(quick=True, csv=print):
    fails = []
    for ds in ("mnist", "fmnist"):
        fails += run(ds, rounds=8 if quick else 30,
                     seeds=(0, 1) if quick else (0, 1, 2), csv=csv)
    return fails


if __name__ == "__main__":
    main()
