"""Generate the §Dry-run and §Roofline markdown tables in EXPERIMENTS.md
from results/dryrun_all.json (single source of truth)."""
from __future__ import annotations

import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))
RESULTS = os.path.join(HERE, "..", "results", "dryrun_all.json")

MITIGATION = {
    # one sentence per (dominant-term x shape kind) on what moves it down
    ("memory", "train"): "chunk recurrent scans / fuse elementwise chains so "
    "activations stream once; remat already bounds residency",
    ("memory", "prefill"): "fuse attention epilogues; keep bf16 end-to-end "
    "through the mixer instead of f32 staging",
    ("memory", "decode"): "decode is KV-cache-read bound by construction; "
    "quantize cache to int8 or shard KV heads wider",
    ("collective", "train"): "reduce-scatter gradients instead of all-reduce "
    "and overlap FSDP all-gathers with the previous layer's compute",
    ("collective", "prefill"): "shift TP boundaries so activations cross the "
    "mesh once per block (Megatron-SP style)",
    ("collective", "decode"): "replicate the small per-step state instead of "
    "re-gathering it every token",
    ("compute", "train"): "already MXU-bound: raise arithmetic intensity via "
    "larger per-device batch",
    ("compute", "prefill"): "already MXU-bound",
    ("compute", "decode"): "already MXU-bound",
}


def fmt_bytes(b):
    return f"{(b or 0) / 1e9:.1f}"


def gen(csv=print):
    recs = json.load(open(RESULTS))
    shape_kind = {"train_4k": "train", "prefill_32k": "prefill",
                  "decode_32k": "decode", "long_500k": "decode"}

    lines = []
    lines.append("### Dry-run matrix (all 10 archs x 4 shapes x 2 meshes)\n")
    lines.append("| arch | shape | mesh | status | lower+compile (s) | "
                 "HLO GFLOPs/dev | peak GB/dev | collective GB/dev |")
    lines.append("|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"SKIP (by design) | — | — | — | — |")
            continue
        t = f"{r['lower_s'] + r['compile_s']:.1f}"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | {t} | "
            f"{r['hlo_flops'] / 1e9:.0f} | "
            f"{fmt_bytes(r['bytes_per_device']['peak'])} | "
            f"{fmt_bytes(r['collective_bytes'])} |")

    lines.append("\n### Roofline (single-pod 16x16, per device)\n")
    lines.append("| arch | shape | compute (s) | memory (s) | collective (s)"
                 " | dominant | useful FLOP ratio | mitigation |")
    lines.append("|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["mesh"] != "pod_16x16":
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | {r.get('reason', '')[:60]} |")
            continue
        kind = shape_kind[r["shape"]]
        mit = MITIGATION.get((r["dominant"], kind), "")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | {mit} |")
    baseline_path = os.path.join(HERE, "..", "results",
                                 "dryrun_baseline.json")
    if os.path.exists(baseline_path):
        base = {(r["arch"], r["shape"]): r
                for r in json.load(open(baseline_path))
                if r["mesh"] == "pod_16x16" and r["status"] == "ok"}
        opt = {(r["arch"], r["shape"]): r for r in recs
               if r["mesh"] == "pod_16x16" and r["status"] == "ok"}
        lines.append("\n### Paper-faithful baseline vs optimized "
                     "(single-pod, pairs that moved >5%)\n")
        lines.append("| arch | shape | term | baseline (s) | optimized (s) "
                     "| speedup |")
        lines.append("|---|---|---|---|---|---|")
        for k in sorted(base):
            if k not in opt:
                continue
            for term in ("compute_s", "memory_s", "collective_s"):
                b, o = base[k][term], opt[k][term]
                if b > 0 and abs(b - o) / b > 0.05 and b > 1e-4:
                    lines.append(
                        f"| {k[0]} | {k[1]} | {term[:-2]} | {b:.3e} | "
                        f"{o:.3e} | {b / o:.1f}x |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(gen())
