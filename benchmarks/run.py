"""Benchmark harness — one module per paper table/figure + system benches.

    PYTHONPATH=src python -m benchmarks.run            # quick (CPU-sized)
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale rounds
    PYTHONPATH=src python -m benchmarks.run --only table1,fig3

Output is CSV-ish lines `table,key...,value` plus `#` commentary; each
module returns a list of failed qualitative reproduction checks, and the
process exits non-zero if any check failed.
"""
from __future__ import annotations

import argparse
import sys
import time

MODULES = [
    ("table1", "benchmarks.table1"),            # Table 1 performance
    ("fig2", "benchmarks.fig2_convergence"),    # Fig 2 convergence
    ("fig3", "benchmarks.fig3_hparams"),        # Fig 3 hyperparameters
    ("table2", "benchmarks.table2_teams"),      # Table 2 team formation
    ("fig4", "benchmarks.fig4_participation"),  # Fig 4 participation
    ("fig_comm", "benchmarks.fig_comm_tradeoff"),  # acc-vs-MB comm sweep
    ("fig_tta", "benchmarks.fig_time_to_accuracy"),  # acc-vs-sim-seconds
    ("engine", "benchmarks.bench_engine"),      # scan vs dispatch rounds/s
    ("theory", "benchmarks.theory_rates"),      # Thm 1/2 rate validation
    ("roofline", "benchmarks.roofline_table"),  # §Roofline from dry-run
    ("kernels", "benchmarks.bench_kernels"),    # kernel micro-bench
    ("serving", "benchmarks.bench_serving"),    # serve engine throughput
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale round counts (slow)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: "
                    + ",".join(k for k, _ in MODULES))
    args = ap.parse_args(argv)
    subset = set(args.only.split(",")) if args.only else None

    import importlib
    failures = []
    t_start = time.time()
    for key, modname in MODULES:
        if subset and key not in subset:
            continue
        print(f"\n### {key} ({modname}) " + "#" * 40)
        t0 = time.time()
        mod = importlib.import_module(modname)
        try:
            fails = mod.main(quick=not args.full) or []
        except Exception as e:  # noqa: BLE001 — report, keep going
            import traceback
            traceback.print_exc()
            fails = [f"crashed: {e!r}"]
        failures.extend(f"{key}: {f}" for f in fails)
        print(f"### {key} done in {time.time() - t0:.0f}s")

    print(f"\n=== benchmarks finished in {time.time() - t_start:.0f}s ===")
    if failures:
        print("QUALITATIVE CHECK FAILURES:")
        for f in failures:
            print("  -", f)
        return 1
    print("all qualitative reproduction checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
