"""Fig 2: convergence of PerMFL vs multi-tier SOTA (h-SGD, L2GD) — personal
and global accuracy per global round, strongly convex + non-convex."""
from __future__ import annotations

import dataclasses

from repro.core import PerMFL
from repro.core.baselines import HSGD, L2GD
from repro.train.engine import run_experiment

from benchmarks.fl_common import (HP_DEFAULT, fns_for, init_model,
                                  make_fed_data, model_for, to_jax)


def run(dataset="fmnist", convex=True, rounds=12, csv=print, quick=True):
    small = quick and not convex
    # CNN cells are CPU-heavy: shrink in quick mode (orderings are
    # scale-stable); --full restores the paper's 4x10 / K=5 / L=10.
    hp = dataclasses.replace(HP_DEFAULT, k_team=3, l_local=5) if small \
        else HP_DEFAULT
    cfg = model_for(dataset, convex)
    fd = make_fed_data(dataset, seed=1, m=2 if small else 4,
                       n=5 if small else 10,
                       samples_per_device=24 if small else 48)
    tr, va = to_jax(fd)
    loss, met = fns_for(cfg)
    p0 = init_model(cfg)
    m, n = fd.m_teams, fd.n_devices
    lr = 0.03 if convex else 0.01

    # all three algorithms run through the same scanned engine: one
    # compiled program per curve (core.algorithm + train.engine)
    algos = {
        "permfl": PerMFL(loss, hp),
        "hsgd": HSGD(loss, lr=lr, k_team=hp.k_team, l_local=hp.l_local),
        "l2gd": L2GD(loss, lr=lr, lam_c=0.5, lam_g=0.5, k_team=hp.k_team,
                     l_local=hp.l_local),
    }
    curves = {}
    for name, algo in algos.items():
        r = run_experiment(algo, p0, tr, va, metric_fn=met,
                           rounds=rounds, m=m, n=n)
        if r.pm_acc:
            curves[f"{name}_pm"] = r.pm_acc
        if r.gm_acc:
            curves[f"{name}_gm"] = r.gm_acc

    mdl = "mclr" if convex else "cnn"
    for name, hist in curves.items():
        for t, acc in enumerate(hist):
            csv(f"fig2,{dataset},{mdl},{name},{t},{acc:.4f}")

    # reproduction target ("the convergence of PerMFL(PM) is equivalent to
    # DemLearn and faster than h-SGD and AL2GD", §4.1.2): PerMFL(PM)
    # reaches 90% of its final accuracy within one round of L2GD(PM) —
    # the one-round slack absorbs round-to-round noise at quick scale.
    def t90(hist):
        target = 0.9 * max(hist)
        return next(i for i, a in enumerate(hist) if a >= target)

    ok = t90(curves["permfl_pm"]) <= t90(curves["l2gd_pm"]) + 1
    csv(f"# fig2 {dataset}/{mdl}: permfl t90={t90(curves['permfl_pm'])} "
        f"l2gd t90={t90(curves['l2gd_pm'])} equivalent_or_faster={ok}")
    return ok


def main(quick=True, csv=print):
    oks = []
    for convex in (True, False):
        oks.append(run("fmnist", convex, rounds=12 if quick else 40,
                       csv=csv, quick=quick))
    return [] if all(oks) else ["fig2 convergence ranking"]


if __name__ == "__main__":
    main()
