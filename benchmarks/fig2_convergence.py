"""Fig 2: convergence of PerMFL vs multi-tier SOTA (h-SGD, L2GD) — personal
and global accuracy per global round, strongly convex + non-convex.

Each curve is the registered scenario ``fig2/fmnist/{model}/{algo}``,
run through the scanned engine (one compiled program per curve); quick
mode shrinks the CNN cells via ``FLScenario.scaled``.
"""
from __future__ import annotations

from repro.scenarios import SCENARIOS, run_scenario

# quick-mode shrink for the non-convex cells (orderings are scale-stable)
_QUICK_ALGO = {"permfl": {"k_team": 3, "l_local": 5},
               "hsgd": {"k_team": 3, "l_local": 5},
               "l2gd": {"k_team": 3, "l_local": 5}}


def run(dataset="fmnist", convex=True, rounds=12, csv=print, quick=True):
    """One (dataset, model-class) panel; returns the t90 ordering check."""
    kind = "mclr" if convex else "cnn"
    small = quick and not convex
    curves = {}
    for algo in ("permfl", "hsgd", "l2gd"):
        s = SCENARIOS[f"fig2/{dataset}/{kind}/{algo}"]
        if small:
            s = s.scaled(m_teams=2, n_devices=5, samples_per_device=24,
                         algo_overrides=_QUICK_ALGO[algo])
        r = run_scenario(s, rounds=rounds)
        if r.pm_acc:
            curves[f"{algo}_pm"] = r.pm_acc
        if r.gm_acc:
            curves[f"{algo}_gm"] = r.gm_acc

    for name, hist in curves.items():
        for t, acc in enumerate(hist):
            csv(f"fig2,{dataset},{kind},{name},{t},{acc:.4f}")

    # reproduction target ("the convergence of PerMFL(PM) is equivalent to
    # DemLearn and faster than h-SGD and AL2GD", §4.1.2): PerMFL(PM)
    # reaches 90% of its final accuracy within one round of L2GD(PM) —
    # the one-round slack absorbs round-to-round noise at quick scale.
    def t90(hist):
        target = 0.9 * max(hist)
        return next(i for i, a in enumerate(hist) if a >= target)

    ok = t90(curves["permfl_pm"]) <= t90(curves["l2gd_pm"]) + 1
    csv(f"# fig2 {dataset}/{kind}: permfl t90={t90(curves['permfl_pm'])} "
        f"l2gd t90={t90(curves['l2gd_pm'])} equivalent_or_faster={ok}")
    return ok


def main(quick=True, csv=print):
    oks = []
    for convex in (True, False):
        oks.append(run("fmnist", convex, rounds=12 if quick else 40,
                       csv=csv, quick=quick))
    return [] if all(oks) else ["fig2 convergence ranking"]


if __name__ == "__main__":
    main()
