"""Fig 4 (+ §D.5): team/device participation ablation.

Reproduction targets: (a) full participation converges fastest; (b) higher
device participation (at full team participation) converges faster; (c)
very low team AND device participation is slowest.

The four participation modes are the registered scenarios
``fig4/mnist/mclr/{mode}`` (the fractions live in the spec); masks are
sampled in-graph and realized counts come back on FLResult.participation.
"""
from __future__ import annotations

from repro.scenarios import SCENARIOS, run_scenario

MODES = ("full", "devices_50", "teams_50", "both_25")


def main(quick=True, csv=print):
    rounds = 10 if quick else 40
    results = {}
    for mode in MODES:
        # participation seed 5 (the paper run), model init seed 0
        r = run_scenario(SCENARIOS[f"fig4/mnist/mclr/{mode}"],
                         rounds=rounds, seed=5, init_seed=0)
        results[mode] = r
        for t, acc in enumerate(r.gm_acc):
            csv(f"fig4,mnist,mclr,{mode},gm,{t},{acc:.4f}")
        csv(f"fig4,mnist,mclr,{mode},pm_final,,{r.pm_acc[-1]:.4f}")
        teams = sum(p[0] for p in r.participation) / len(r.participation)
        devs = sum(p[1] for p in r.participation) / len(r.participation)
        csv(f"fig4,mnist,mclr,{mode},realized_mean,,{teams:.1f}t/{devs:.1f}d")

    failures = []
    # area under the GM curve orders with participation
    def auc(r):
        return sum(r.gm_acc) / len(r.gm_acc)

    if not auc(results["full"]) >= auc(results["both_25"]) - 0.02:
        failures.append("fig4: full participation not fastest (GM AUC)")
    if not results["full"].pm_acc[-1] >= results["both_25"].pm_acc[-1] - 0.05:
        failures.append("fig4: full participation PM worse than 25/25")
    return failures


if __name__ == "__main__":
    main()
