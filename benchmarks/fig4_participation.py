"""Fig 4 (+ §D.5): team/device participation ablation.

Reproduction targets: (a) full participation converges fastest; (b) higher
device participation (at full team participation) converges faster; (c)
very low team AND device participation is slowest."""
from __future__ import annotations

from repro.core import PerMFL
from repro.train.engine import run_experiment

from benchmarks.fl_common import (HP_DEFAULT, fns_for, init_model,
                                  make_fed_data, model_for, to_jax)

GRID = [
    ("full", 1.0, 1.0),
    ("devices_50", 1.0, 0.5),
    ("teams_50", 0.5, 1.0),
    ("both_25", 0.25, 0.25),
]


def main(quick=True, csv=print):
    rounds = 10 if quick else 40
    cfg = model_for("mnist", True)
    fd = make_fed_data("mnist", seed=4)
    tr, va = to_jax(fd)
    loss, met = fns_for(cfg)
    p0 = init_model(cfg)
    m, n = fd.m_teams, fd.n_devices

    results = {}
    for name, tf, df in GRID:
        # masks are sampled in-graph; realized counts come back as scan
        # outputs on FLResult.participation
        r = run_experiment(PerMFL(loss, HP_DEFAULT), p0, tr, va,
                           metric_fn=met, rounds=rounds, m=m, n=n,
                           team_frac=tf, device_frac=df, seed=5)
        results[name] = r
        for t, acc in enumerate(r.gm_acc):
            csv(f"fig4,mnist,mclr,{name},gm,{t},{acc:.4f}")
        csv(f"fig4,mnist,mclr,{name},pm_final,,{r.pm_acc[-1]:.4f}")
        teams = sum(p[0] for p in r.participation) / len(r.participation)
        devs = sum(p[1] for p in r.participation) / len(r.participation)
        csv(f"fig4,mnist,mclr,{name},realized_mean,,{teams:.1f}t/{devs:.1f}d")

    failures = []
    # area under the GM curve orders with participation
    def auc(r):
        return sum(r.gm_acc) / len(r.gm_acc)

    if not auc(results["full"]) >= auc(results["both_25"]) - 0.02:
        failures.append("fig4: full participation not fastest (GM AUC)")
    if not results["full"].pm_acc[-1] >= results["both_25"].pm_acc[-1] - 0.05:
        failures.append("fig4: full participation PM worse than 25/25")
    return failures


if __name__ == "__main__":
    main()
