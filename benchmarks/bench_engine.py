"""Engine throughput: per-round host dispatch vs the scanned engine.

Runs PerMFL on the paper-scale MCLR config (4 teams x 10 devices, K=5,
L=10, partial participation mode 4: team_frac=device_frac=0.5 — the
setting where the legacy loop also pays per-round host-side mask
sampling) through three execution models, reporting steady-state
rounds/sec:

  legacy    — what the pre-engine drivers did: one jitted round dispatched
              per Python iteration, eval re-dispatched *eagerly* (un-jitted
              vmap) at every eval point
  dispatch  — engine with scan=False: per-round dispatch but jit-cached
              eval (the engine's compatibility path)
  scan      — engine with scan=True: the whole experiment is one compiled
              program; rounds, in-graph sampling, and eval all live inside
              a chunked lax.scan

plus a `sweep` mode comparing a multi-config hyperparameter grid run as a
sequential loop of scanned experiments vs ONE vmapped program
(train.sweep.run_sweep), reporting configs/sec for both, and a `probes`
measurement re-running the scanned path with the run-telemetry probes on
(`repro.obs.TraceConfig`) to report the observability overhead, a
`comm` measurement running a comm-heavy top-k scenario probes-off with
the fused compression stack (default) vs the historical unfused chain
(`REPRO_COMPRESS_FUSED=0`), reporting rounds/sec for both, and a
`cohort` N-scaling measurement running the virtualized cohort engine
(fixed cohort width, populations N in {10^2, 10^3, 10^4}) — per-round
cost must track the cohort, not the population, so the N=10^4/N=10^2
slowdown is asserted < 2x in timed mode.

Reproduction target: the scanned path beats legacy per-round dispatch in
rounds/sec (the paper's multi-algorithm sweeps were dispatch-bound, not
hardware-bound, under the legacy model), and the vmapped sweep matches
the sequential loop's trajectories bit-for-bit in a single dispatch.

    PYTHONPATH=src python -m benchmarks.bench_engine            # timed
    PYTHONPATH=src python -m benchmarks.bench_engine --smoke    # CI: 2
        rounds through the scan path + a 2-config sweep in one dispatch,
        no timing checks

Either mode writes ``BENCH_engine.json`` at the repo root — the perf
trajectory marker future PRs diff against (rounds/sec, configs/sec,
dispatch counts, compile-vs-run seconds). CI gates it against the
committed baseline in ``benchmarks/baselines/`` via
``python -m repro.obs.regress`` (>20% rate drops fail the build).
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import sys
import time

import jax
import numpy as np

from repro.comm import CommConfig
from repro.core.participation import sample_masks
from repro.core.permfl import eval_stacked, init_state, permfl_round
from repro.train.engine import run_experiment
from repro.train.sweep import run_sweep

from repro.scenarios import AlgoSpec, DataSpec, FLScenario, build_scenario

# per-round eval, as every figure/table benchmark runs (their default)
EVAL_EVERY = 1
TEAM_FRAC = DEVICE_FRAC = 0.5   # paper participation mode 4 (Fig. 4)

# the benchmark workload as a declarative spec (not registered — this is
# a system benchmark, not a paper cell)
BENCH_SCENARIO = FLScenario(
    name="bench/engine/mnist-mclr", data=DataSpec(dataset="mnist"),
    team_frac=TEAM_FRAC, device_frac=DEVICE_FRAC, data_seed=9,
    notes="engine rounds/sec + sweep configs/sec workload")

# comm-heavy variant: top-k compression with error feedback on both
# uplinks — the workload where the fused compression stack (DESIGN.md
# §10) replaces the historical unfused select/pack chain
COMM_SCENARIO = dataclasses.replace(
    BENCH_SCENARIO, name="bench/engine/mnist-mclr-topk",
    comm=CommConfig("topk", k_frac=0.1),
    notes="fused-vs-unfused compression rounds/sec workload")

# cohort-engine N-scaling workload (DESIGN.md §11): fixed cohort width
# over growing populations — per-round cost must track the cohort
COHORT_SCENARIO = FLScenario(
    name="bench/engine/virtual-cohort",
    data=DataSpec(dataset="virtual", partitioner="tabular", m_teams=2,
                  n_devices=100, samples_per_device=8),
    algo=AlgoSpec("permfl", (("k_team", 2), ("l_local", 2))),
    cohort_size=32, data_seed=9,
    notes="cohort-engine rounds/sec vs population size")

COHORT_NS = (100, 1_000, 10_000)


def _setup():
    b = build_scenario(BENCH_SCENARIO)
    return b.algo, b.params0, b.train, b.val, b.metric_fn, b.m, b.n


def _run_legacy(algo, p0, tr, va, met, m, n, rounds):
    """The pre-engine fl_trainer loop: host-side mask sampling, per-round
    dispatch, eager eval."""
    st = init_state(p0, m, n)
    key = jax.random.PRNGKey(0)
    pm = []
    for t in range(rounds):
        key, sub = jax.random.split(key)
        tm, dm = sample_masks(sub, m, n, team_frac=TEAM_FRAC,
                              device_frac=DEVICE_FRAC)
        st = permfl_round(st, tr, algo.hp, algo.loss_fn, m_teams=m,
                          n_devices=n, team_mask=tm, device_mask=dm)
        if (t + 1) % EVAL_EVERY == 0 or t == rounds - 1:
            pm.append(float(eval_stacked(st, va, met, which="pm").mean()))
            eval_stacked(st, va, met, which="tm").mean().block_until_ready()
            eval_stacked(st, va, met, which="gm").mean().block_until_ready()
            jax.vmap(jax.vmap(algo.loss_fn))(st.theta, tr).mean()
    return pm


SWEEP_GRID = [dict(lam=0.3), dict(lam=0.5), dict(lam=0.8), dict(lam=1.2)]

_BENCH_JSON = pathlib.Path(__file__).resolve().parents[1] / \
    "BENCH_engine.json"


def write_bench_json(payload: dict) -> None:
    """Persist the perf-trajectory marker at the repo root; future PRs
    diff BENCH_engine.json to catch engine regressions."""
    _BENCH_JSON.write_text(json.dumps(payload, indent=2, sort_keys=True)
                           + "\n")
    print(f"# bench_engine: wrote {_BENCH_JSON.name}")


def _bench_comm(csv, *, rounds: int, reps: int):
    """Probes-off fused-vs-unfused compression on the comm-heavy top-k
    scenario. ``REPRO_COMPRESS_FUSED=0`` selects the historical unfused
    select/scatter chain; the default routes through the fused kernels in
    ``repro.kernels.compress``. ``dispatch_key()`` rides the program
    cache keys, so each setting compiles its own program. Returns
    ``(failures, marker_entry)``; trajectories must match exactly (top-k
    selection is bit-identical across the two paths)."""
    b = build_scenario(COMM_SCENARIO)
    kw = dict(metric_fn=b.metric_fn, rounds=rounds, m=b.m, n=b.n,
              scan=True)

    def timed():
        run = lambda: run_experiment(b.algo, b.params0, b.train, b.val,
                                     **kw)
        res = run()                   # warm-up: populate the jit cache
        best = float("inf")
        for _ in range(reps):
            t0 = time.time()
            res = run()
            best = min(best, time.time() - t0)
        return rounds / best, res

    prev = os.environ.pop("REPRO_COMPRESS_FUSED", None)
    try:
        rps_fused, res_f = timed()
        os.environ["REPRO_COMPRESS_FUSED"] = "0"
        rps_unfused, res_u = timed()
    finally:
        if prev is None:
            os.environ.pop("REPRO_COMPRESS_FUSED", None)
        else:
            os.environ["REPRO_COMPRESS_FUSED"] = prev

    drift = max(abs(a - b) for a, b in zip(res_f.pm_acc, res_u.pm_acc))
    csv(f"bench_engine,mnist,mclr-topk,comm,rounds_per_sec_fused,,"
        f"{rps_fused:.2f}")
    csv(f"bench_engine,mnist,mclr-topk,comm,rounds_per_sec_unfused,,"
        f"{rps_unfused:.2f}")
    csv(f"bench_engine,mnist,mclr-topk,comm,fused_over_unfused,,"
        f"{rps_fused / rps_unfused:.2f}")
    failures = []
    if drift > 0 or not np.isfinite(drift):
        failures.append(
            f"bench_engine: fused/unfused trajectory drift {drift:.2e}")
    entry = {"compressor": COMM_SCENARIO.comm.compressor,
             "rounds": rounds,
             "rounds_per_sec_fused": round(rps_fused, 2),
             "rounds_per_sec_unfused": round(rps_unfused, 2),
             "fused_over_unfused": round(rps_fused / rps_unfused, 2)}
    return failures, entry


def _bench_cohort(csv, *, rounds: int, reps: int, gate: bool):
    """Cohort-engine N-scaling: rounds/sec at fixed cohort width over
    populations ``COHORT_NS``, one eval at the end so timing stays
    cohort-dominated (a per-round full-population eval would scale with
    N and mask the gather/scatter cost under test). ``rounds`` should be
    large (hundreds): the one end-of-run full-population eval + final
    state materialization is an O(N) *fixed* cost per dispatch, and only
    a long scan amortizes it down to the marginal per-round cost the
    ratio is meant to measure. Also runs a 2-config vmapped sweep at the
    largest N — the engine+sweep acceptance path. With ``gate`` the
    N=10^4-over-N=10^2 slowdown must stay < 2x (not asserted in smoke
    mode, where reps=1 timings are noisy; the recorded rates still feed
    the regress gate). Returns ``(failures, marker_entry)``."""
    c = COHORT_SCENARIO.cohort_size
    rps = {}
    for n in COHORT_NS:
        b = build_scenario(COHORT_SCENARIO.scaled(n_devices=n))
        kw = dict(metric_fn=b.metric_fn, rounds=rounds, m=b.m, n=b.n,
                  cohort=c, eval_every=rounds, scan=True)
        run = lambda: run_experiment(b.algo, b.params0, b.train, b.val,
                                     **kw)
        res = run()                   # warm-up: populate the jit cache
        assert np.isfinite(res.pm_acc).all() and res.cohort == c
        best = float("inf")
        for _ in range(reps):
            t0 = time.time()
            run()
            best = min(best, time.time() - t0)
        rps[f"n{n}"] = rounds / best
        csv(f"bench_engine,virtual,mclr,cohort,rounds_per_sec,n{n},"
            f"{rps[f'n{n}']:.2f}")

    slowdown = rps[f"n{COHORT_NS[0]}"] / rps[f"n{COHORT_NS[-1]}"]
    csv(f"bench_engine,virtual,mclr,cohort,slowdown_n{COHORT_NS[-1]}"
        f"_over_n{COHORT_NS[0]},,{slowdown:.2f}")

    b = build_scenario(COHORT_SCENARIO.scaled(n_devices=COHORT_NS[-1]))
    sw = run_sweep(b.algo, SWEEP_GRID[:2], (0,), b.params0, b.train,
                   b.val, metric_fn=b.metric_fn, rounds=2, m=b.m, n=b.n,
                   cohort=c)
    failures = []
    if not (len(sw) == 2 and sw.dispatches == 1
            and all(np.isfinite(r.pm_acc).all() for r in sw)):
        failures.append("bench_engine: cohort sweep at N="
                        f"{COHORT_NS[-1]} failed")
    if gate and not slowdown < 2.0:
        failures.append(
            f"bench_engine: cohort rounds/sec degrades {slowdown:.2f}x "
            f"from N={COHORT_NS[0]} to N={COHORT_NS[-1]} (limit 2.0x — "
            "per-round cost must track the cohort, not the population)")
    entry = {"cohort_size": c, "population": list(COHORT_NS),
             "rounds": rounds,
             "rounds_per_sec": {k: round(v, 2) for k, v in rps.items()},
             f"slowdown_n{COHORT_NS[-1]}_over_n{COHORT_NS[0]}":
                 round(slowdown, 2),
             "sweep_configs": len(sw)}
    return failures, entry


def smoke() -> list:
    """CI guard: 2 rounds through the scanned path, then a 2-config x
    2-round sweep through the vmapped path — asserting both configs
    executed in a single dispatch (run with FORCE_PALLAS_INTERPRET=1 so
    the Pallas prox kernel is exercised too). Writes BENCH_engine.json
    (steady-state numbers from a second, compile-cache-warm run)."""
    algo, p0, tr, va, met, m, n = _setup()
    kw = dict(metric_fn=met, rounds=2, m=m, n=n, scan=True)
    res = run_experiment(algo, p0, tr, va, **kw)
    assert len(res.pm_acc) == 2 and res.state is not None
    warm = run_experiment(algo, p0, tr, va, **kw)   # compile cache hot
    print(f"# bench_engine smoke: 2 scanned rounds OK, "
          f"pm={res.pm_acc[-1]:.3f}")

    sw = run_sweep(algo, SWEEP_GRID[:2], (0,), p0, tr, va, metric_fn=met,
                   rounds=2, m=m, n=n)
    assert len(sw) == 2 and sw.dispatches == 1
    assert all(np.isfinite(r.pm_acc).all() for r in sw)
    sw_warm = run_sweep(algo, SWEEP_GRID[:2], (0,), p0, tr, va,
                        metric_fn=met, rounds=2, m=m, n=n)
    print(f"# bench_engine smoke: {len(sw)} sweep configs in "
          f"{sw.dispatches} dispatch OK, pm={[f'{r.pm_acc[-1]:.3f}' for r in sw]}")

    # probes-on path (repro.obs): trajectories must not move, and the
    # probe streams must materialize (overhead reported, not gated —
    # smoke runs are dispatch-dominated)
    pr = run_experiment(algo, p0, tr, va, trace=True, **kw)
    assert pr.trace is not None and len(pr.trace) == 2
    np.testing.assert_array_equal(np.asarray(pr.pm_acc),
                                  np.asarray(res.pm_acc))
    pr_warm = run_experiment(algo, p0, tr, va, trace=True, **kw)
    print(f"# bench_engine smoke: probes on, "
          f"{len(pr.trace.names())} streams OK")

    # probes-off fused-vs-unfused compression on the comm-heavy scenario
    comm_fails, comm_entry = _bench_comm(print, rounds=2, reps=1)
    print(f"# bench_engine smoke: comm fused/unfused x"
          f"{comm_entry['fused_over_unfused']} OK")

    # cohort-engine N-scaling (rates recorded; the <2x slowdown gate
    # only applies to timed runs). 300 rounds even in smoke: the scan is
    # sub-second per population and the ratio needs the amortization.
    cohort_fails, cohort_entry = _bench_cohort(print, rounds=300, reps=1,
                                               gate=False)
    print(f"# bench_engine smoke: cohort N-scaling over "
          f"{list(COHORT_NS)} OK, sweep in 1 dispatch")

    write_bench_json({
        "mode": "smoke",
        "comm": comm_entry,
        "cohort": cohort_entry,
        "engine": {"rounds": 2,
                   "rounds_per_sec": round(2 / max(warm.seconds, 1e-9), 2),
                   "cold_seconds": round(res.seconds, 3),
                   "steady_seconds": round(warm.seconds, 3),
                   "dispatches": 1},
        "sweep": {"configs": len(sw_warm),
                  "configs_per_sec": round(
                      len(sw_warm) / max(sw_warm.seconds, 1e-9), 2),
                  "cold_seconds": round(sw.seconds, 3),
                  "steady_seconds": round(sw_warm.seconds, 3),
                  "dispatches": sw_warm.dispatches},
        "obs": {"rounds_per_sec_probes": round(
                    2 / max(pr_warm.seconds, 1e-9), 2),
                "probe_streams": len(pr_warm.trace.names()),
                "overhead_pct": round(
                    (pr_warm.seconds - warm.seconds)
                    / max(warm.seconds, 1e-9) * 100, 1)},
    })
    return comm_fails + cohort_fails


def main(quick: bool = True, csv=print) -> list:
    rounds = 24 if quick else 60
    algo, p0, tr, va, met, m, n = _setup()
    kw = dict(metric_fn=met, m=m, n=n, eval_every=EVAL_EVERY,
              team_frac=TEAM_FRAC, device_frac=DEVICE_FRAC)

    runners = {
        "legacy": lambda: _run_legacy(algo, p0, tr, va, met, m, n, rounds),
        "dispatch": lambda: run_experiment(algo, p0, tr, va, rounds=rounds,
                                           scan=False, **kw).pm_acc,
        "scan": lambda: run_experiment(algo, p0, tr, va, rounds=rounds,
                                       scan=True, **kw).pm_acc,
    }

    reps = 3
    rps, pm = {}, {}
    for name, fn in runners.items():
        t0 = time.time()
        fn()            # warm-up: populate every jit cache
        warm = time.time() - t0
        best = float("inf")
        for _ in range(reps):   # steady state, best-of: what a sweep pays
            t0 = time.time()    # per experiment after the first compile
            pm[name] = fn()
            best = min(best, time.time() - t0)
        rps[name] = rounds / best
        csv(f"bench_engine,mnist,mclr,{name},rounds_per_sec,,"
            f"{rps[name]:.2f}")
        csv(f"bench_engine,mnist,mclr,{name},first_run_sec,,{warm:.1f}")

    csv(f"bench_engine,mnist,mclr,speedup,scan_over_legacy,,"
        f"{rps['scan'] / rps['legacy']:.2f}")
    csv(f"bench_engine,mnist,mclr,speedup,scan_over_dispatch,,"
        f"{rps['scan'] / rps['dispatch']:.2f}")

    # all three paths compute the same trajectory
    drift = max(abs(a - b) for name in ("dispatch", "legacy")
                for a, b in zip(pm["scan"], pm[name]))
    csv(f"bench_engine,mnist,mclr,max_pm_drift,,,{drift:.2e}")

    failures = []
    if rps["scan"] <= rps["legacy"]:
        failures.append(
            "bench_engine: scanned path not faster than legacy dispatch "
            f"({rps['scan'] / rps['legacy']:.2f}x)")
    if drift > 1e-4 or not np.isfinite(drift):
        failures.append(f"bench_engine: scan/legacy drift {drift:.2e}")
    sweep_failures, cps = _bench_sweep(algo, p0, tr, va, met, m, n,
                                       rounds=max(4, rounds // 4), csv=csv)
    failures += sweep_failures

    # probes-on scanned path (repro.obs): same program shape plus the
    # probe outputs; report the throughput tax vs probes-off scan
    probed = lambda: run_experiment(algo, p0, tr, va, rounds=rounds,
                                    scan=True, trace=True, **kw)
    probed()                      # warm-up
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        pm_probes = probed().pm_acc
        best = min(best, time.time() - t0)
    rps_probes = rounds / best
    overhead = (rps["scan"] - rps_probes) / rps["scan"] * 100
    csv(f"bench_engine,mnist,mclr,probes,rounds_per_sec,,"
        f"{rps_probes:.2f}")
    csv(f"bench_engine,mnist,mclr,probes,overhead_pct,,{overhead:.1f}")
    p_drift = max(abs(a - b) for a, b in zip(pm["scan"], pm_probes))
    if p_drift > 0:
        failures.append(
            f"bench_engine: probes-on trajectory moved ({p_drift:.2e})")

    comm_fails, comm_entry = _bench_comm(csv, rounds=max(4, rounds // 4),
                                         reps=reps)
    failures += comm_fails

    cohort_fails, cohort_entry = _bench_cohort(csv, rounds=300, reps=reps,
                                               gate=True)
    failures += cohort_fails

    write_bench_json({
        "mode": "quick" if quick else "full",
        "comm": comm_entry,
        "cohort": cohort_entry,
        "engine": {"rounds": rounds,
                   "rounds_per_sec": {k: round(v, 2)
                                      for k, v in rps.items()},
                   "scan_over_legacy": round(rps["scan"] / rps["legacy"],
                                             2),
                   "dispatches": 1},
        "sweep": {"configs": len(SWEEP_GRID),
                  "configs_per_sec": {k: round(v, 2)
                                      for k, v in cps.items()},
                  "dispatches": 1},
        "obs": {"rounds_per_sec_probes": round(rps_probes, 2),
                "overhead_pct": round(overhead, 1)},
    })
    return failures


def _bench_sweep(algo, p0, tr, va, met, m, n, *, rounds, csv):
    """Sweep mode: the SWEEP_GRID lambda grid as a sequential loop of
    scanned experiments vs one vmapped run_sweep program, configs/sec.
    Returns (failures, configs_per_sec dict)."""
    kw = dict(metric_fn=met, rounds=rounds, m=m, n=n)
    n_cfg = len(SWEEP_GRID)

    def sequential():
        return [run_experiment(
            dataclasses.replace(algo,
                                hp=dataclasses.replace(algo.hp, **g)),
            p0, tr, va, **kw).pm_acc for g in SWEEP_GRID]

    def swept():
        sw = run_sweep(algo, SWEEP_GRID, (0,), p0, tr, va, **kw)
        assert sw.dispatches == 1
        return [r.pm_acc for r in sw]

    cps, pm = {}, {}
    for name, fn in (("seq", sequential), ("sweep", swept)):
        fn()                          # warm-up: populate the jit caches
        best = float("inf")
        for _ in range(3):
            t0 = time.time()
            pm[name] = fn()
            best = min(best, time.time() - t0)
        cps[name] = n_cfg / best
        csv(f"bench_engine,mnist,mclr,{name},configs_per_sec,,"
            f"{cps[name]:.2f}")
    csv(f"bench_engine,mnist,mclr,speedup,sweep_over_seq,,"
        f"{cps['sweep'] / cps['seq']:.2f}")

    drift = max(abs(a - b) for ps, pq in zip(pm["sweep"], pm["seq"])
                for a, b in zip(ps, pq))
    csv(f"bench_engine,mnist,mclr,max_sweep_drift,,,{drift:.2e}")
    if drift > 1e-4 or not np.isfinite(drift):
        return [f"bench_engine: sweep/sequential drift {drift:.2e}"], cps
    return [], cps


if __name__ == "__main__":
    if "--smoke" in sys.argv:
        sys.exit(0 if smoke() == [] else 1)
    fails = main(quick="--full" not in sys.argv)
    for f in fails:
        print("FAIL", f)
    sys.exit(1 if fails else 0)
