"""Table 1: PerMFL vs conventional + multi-tier baselines.

Per (dataset x model-class): runs PerMFL and six baselines on identical
non-IID partitions and reports validation accuracy for PM and GM. The
paper's A100 numbers are attached for qualitative comparison (data here is
the offline synthetic re-materialization; orderings, not absolute values,
are the reproduction target)."""
from __future__ import annotations

import time

from repro.core.permfl import PerMFLHParams
from repro.train import fl_trainer as FT

from benchmarks.fl_common import (DATASETS, HP_DEFAULT, M_TEAMS, N_DEVICES,
                                  PAPER_TABLE1_MCLR, PAPER_TABLE1_NONCONVEX,
                                  fns_for, init_model, make_fed_data,
                                  model_for, to_jax)


def run_all_algorithms(dataset: str, convex: bool, rounds: int, seed=0,
                       quick: bool = True):
    # quick mode shrinks the expensive non-convex (CNN) cells: 2 teams x 5
    # devices and K=3/L=5 — the qualitative orderings are scale-stable;
    # --full restores the paper's 4x10 and K=5/L=10.
    import dataclasses
    small = quick and not convex and dataset != "synthetic"
    m_, n_ = (2, 5) if small else (M_TEAMS, N_DEVICES)
    # keep L=10: theta re-initializes from w every team iteration
    # (Algorithm 1), so PM quality needs enough consecutive device steps
    hp = dataclasses.replace(HP_DEFAULT, k_team=3, l_local=10) if small \
        else HP_DEFAULT
    cfg = model_for(dataset, convex)
    fd = make_fed_data(dataset, seed, m=m_, n=n_,
                       samples_per_device=24 if small else 48)
    tr, va = to_jax(fd)
    loss, met = fns_for(cfg)
    p0 = init_model(cfg, seed)
    m, n = fd.m_teams, fd.n_devices
    lr = 0.03 if convex else 0.01
    out = {}

    r = FT.run_permfl(p0, tr, va, loss_fn=loss, metric_fn=met,
                      hp=hp, rounds=rounds, m=m, n=n)
    out["permfl_pm"], out["permfl_gm"] = r.best("pm"), r.best("gm")
    out["permfl_tm"] = r.best("tm")

    r = FT.run_fedavg(p0, tr, va, loss_fn=loss, metric_fn=met, lr=lr,
                      local_steps=hp.k_team * hp.l_local,
                      rounds=rounds, m=m, n=n)
    out["fedavg_gm"] = r.best("gm")

    r = FT.run_perfedavg(p0, tr, va, loss_fn=loss, metric_fn=met, lr=lr,
                         inner_lr=lr, local_steps=5 if small else 20,
                         rounds=rounds, m=m, n=n)
    out["perfedavg_pm"], out["perfedavg_gm"] = r.best("pm"), r.best("gm")

    r = FT.run_pfedme(p0, tr, va, loss_fn=loss, metric_fn=met, lr=1.0,
                      inner_lr=lr, lam=15.0, inner_steps=5 if small else 10,
                      local_rounds=3 if small else 5,
                      rounds=rounds, m=m, n=n)
    out["pfedme_pm"], out["pfedme_gm"] = r.best("pm"), r.best("gm")

    r = FT.run_ditto(p0, tr, va, loss_fn=loss, metric_fn=met, lr=lr,
                     lam=0.5, local_steps=5 if small else 20,
                     rounds=rounds, m=m, n=n)
    out["ditto_pm"], out["ditto_gm"] = r.best("pm"), r.best("gm")

    r = FT.run_hsgd(p0, tr, va, loss_fn=loss, metric_fn=met, lr=lr,
                    k_team=hp.k_team, l_local=hp.l_local,
                    rounds=rounds, m=m, n=n)
    out["hsgd_gm"] = r.best("gm")

    r = FT.run_l2gd(p0, tr, va, loss_fn=loss, metric_fn=met, lr=lr,
                    lam_c=0.5, lam_g=0.5, k_team=hp.k_team,
                    l_local=hp.l_local, rounds=rounds, m=m, n=n)
    out["l2gd_pm"], out["l2gd_gm"] = r.best("pm"), r.best("gm")
    return out


def main(quick: bool = True, csv=print):
    rounds_cx = 12 if quick else 60
    rounds_ncx = 5 if quick else 40
    csv("table,dataset,model,algorithm,acc,paper_acc")
    failures = []
    for convex, rounds, paper in (
            (True, rounds_cx, PAPER_TABLE1_MCLR),
            (False, rounds_ncx, PAPER_TABLE1_NONCONVEX)):
        mdl = "mclr" if convex else "cnn/dnn"
        for ds in DATASETS:
            t0 = time.time()
            res = run_all_algorithms(ds, convex, rounds, quick=quick)
            for algo, acc in sorted(res.items()):
                ref = paper.get(ds, {}).get(algo, "")
                csv(f"table1,{ds},{mdl},{algo},{acc:.4f},{ref}")
            # qualitative checks (the reproduction targets)
            if not res["permfl_pm"] >= res["permfl_gm"]:
                failures.append((ds, mdl, "PM < GM"))
            if not res["permfl_pm"] >= res["fedavg_gm"] - 0.02:
                failures.append((ds, mdl, "PerMFL(PM) < FedAvg(GM)"))
            csv(f"# {ds}/{mdl} done in {time.time() - t0:.0f}s")
    for f in failures:
        csv(f"# QUALITATIVE-CHECK-FAILED: {f}")
    return failures


if __name__ == "__main__":
    main()
