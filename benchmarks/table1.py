"""Table 1: PerMFL vs conventional + multi-tier baselines.

Per (dataset x model-class): runs PerMFL and six baselines on identical
non-IID partitions and reports validation accuracy for PM and GM. The
paper's A100 numbers are attached for qualitative comparison (data here is
the offline synthetic re-materialization; orderings, not absolute values,
are the reproduction target).

Each algorithm's multi-seed runs (different model inits) execute as ONE
vmapped program via run_sweep — the reported cell is the seed-mean of the
best metric; quick mode keeps 2 seeds per cell, --full 3.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import PerMFL
from repro.core import baselines as B
from repro.train.sweep import run_sweep

from benchmarks.fl_common import (DATASETS, HP_DEFAULT, M_TEAMS, N_DEVICES,
                                  PAPER_TABLE1_MCLR, PAPER_TABLE1_NONCONVEX,
                                  fns_for, init_model, make_fed_data,
                                  model_for, to_jax)


def _seed_mean_best(algo, seeds, init_fn, tr, va, met, rounds, m, n,
                    fields):
    """All seeds of one algorithm as a single vmapped sweep; returns
    {field: mean over seeds of the best-eval value}."""
    sw = run_sweep(algo, [{}], seeds, init_fn, tr, va, metric_fn=met,
                   rounds=rounds, m=m, n=n)
    return {f: float(np.mean([r.best(f) for r in sw])) for f in fields}


def run_all_algorithms(dataset: str, convex: bool, rounds: int,
                       seeds=(0, 1), quick: bool = True):
    # quick mode shrinks the expensive non-convex (CNN) cells: 2 teams x 5
    # devices and K=3/L=10 — the qualitative orderings are scale-stable;
    # --full restores the paper's 4x10 and K=5/L=10.
    import dataclasses
    small = quick and not convex and dataset != "synthetic"
    m_, n_ = (2, 5) if small else (M_TEAMS, N_DEVICES)
    # keep L=10: theta re-initializes from w every team iteration
    # (Algorithm 1), so PM quality needs enough consecutive device steps
    hp = dataclasses.replace(HP_DEFAULT, k_team=3, l_local=10) if small \
        else HP_DEFAULT
    cfg = model_for(dataset, convex)
    fd = make_fed_data(dataset, 0, m=m_, n=n_,
                       samples_per_device=24 if small else 48)
    tr, va = to_jax(fd)
    loss, met = fns_for(cfg)
    init_fn = lambda seed: init_model(cfg, seed)   # per-seed model init
    m, n = fd.m_teams, fd.n_devices
    lr = 0.03 if convex else 0.01
    out = {}

    def cell(prefix, algo, fields):
        res = _seed_mean_best(algo, seeds, init_fn, tr, va, met, rounds,
                              m, n, fields)
        for f in fields:
            out[f"{prefix}_{f}"] = res[f]

    cell("permfl", PerMFL(loss, hp), ("pm", "tm", "gm"))
    cell("fedavg", B.FedAvg(loss, lr=lr,
                            local_steps=hp.k_team * hp.l_local), ("gm",))
    cell("perfedavg", B.PerFedAvg(loss, lr=lr, inner_lr=lr,
                                  local_steps=5 if small else 20),
         ("pm", "gm"))
    cell("pfedme", B.PFedMe(loss, lr=1.0, inner_lr=lr, lam=15.0,
                            inner_steps=5 if small else 10,
                            local_rounds=3 if small else 5), ("pm", "gm"))
    cell("ditto", B.Ditto(loss, lr=lr, lam=0.5,
                          local_steps=5 if small else 20), ("pm", "gm"))
    cell("hsgd", B.HSGD(loss, lr=lr, k_team=hp.k_team,
                        l_local=hp.l_local), ("gm",))
    cell("l2gd", B.L2GD(loss, lr=lr, lam_c=0.5, lam_g=0.5,
                        k_team=hp.k_team, l_local=hp.l_local),
         ("pm", "gm"))
    return out


def main(quick: bool = True, csv=print):
    rounds_cx = 12 if quick else 60
    rounds_ncx = 5 if quick else 40
    # quick mode multi-seeds only the cheap convex cells (the CNN cells
    # dominate runtime); --full multi-seeds everything
    seeds_cx = (0, 1) if quick else (0, 1, 2)
    seeds_ncx = (0,) if quick else (0, 1, 2)
    csv("table,dataset,model,algorithm,acc,paper_acc")
    failures = []
    for convex, rounds, seeds, paper in (
            (True, rounds_cx, seeds_cx, PAPER_TABLE1_MCLR),
            (False, rounds_ncx, seeds_ncx, PAPER_TABLE1_NONCONVEX)):
        mdl = "mclr" if convex else "cnn/dnn"
        for ds in DATASETS:
            t0 = time.time()
            res = run_all_algorithms(ds, convex, rounds, seeds=seeds,
                                     quick=quick)
            for algo, acc in sorted(res.items()):
                ref = paper.get(ds, {}).get(algo, "")
                csv(f"table1,{ds},{mdl},{algo},{acc:.4f},{ref}")
            # qualitative checks (the reproduction targets)
            if not res["permfl_pm"] >= res["permfl_gm"]:
                failures.append((ds, mdl, "PM < GM"))
            if not res["permfl_pm"] >= res["fedavg_gm"] - 0.02:
                failures.append((ds, mdl, "PerMFL(PM) < FedAvg(GM)"))
            csv(f"# {ds}/{mdl} done in {time.time() - t0:.0f}s "
                f"({len(seeds)} seeds/algo, vmapped)")
    for f in failures:
        csv(f"# QUALITATIVE-CHECK-FAILED: {f}")
    return failures


if __name__ == "__main__":
    main()
